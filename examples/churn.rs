//! Session-oriented serving with dynamic client churn.
//!
//! Demonstrates the `Cluster` builder + `ServingHandle` API end-to-end:
//!
//! 1. the `churn` preset's *scheduled* membership changes (a client joins
//!    a third of the way in, a resident drains out at the two-thirds
//!    mark), cross-checked against the analytic simulator;
//! 2. *external* churn on a live handle: `attach` a new session mid-run,
//!    watch it converge in `snapshot()`, `detach` a resident, `stop()`.
//!
//!     cargo run --release --example churn [-- --quick]

use std::time::Duration;

use goodspeed::configsys::{
    ChurnEvent, ChurnKind, ChurnSchedule, ClientSpec, Policy, Scenario,
};
use goodspeed::coordinator::{Cluster, Transport};
use goodspeed::experiments::{mock_engine, serve_once};
use goodspeed::simulate::analytic::AnalyticSim;

fn scheduled_churn(rounds: u64) {
    let mut s = Scenario::preset("churn").expect("preset");
    s.rounds = rounds;
    // The preset's schedule shape, re-timed to the requested length.
    s.churn = ChurnSchedule {
        events: vec![
            ChurnEvent {
                at_wave: rounds / 3,
                kind: ChurnKind::Join(ClientSpec::new("qwen-draft-06b", "cnn")),
            },
            ChurnEvent { at_wave: 2 * rounds / 3, kind: ChurnKind::Leave(1) },
        ],
    };
    println!("== scheduled churn: `churn` preset shape, {rounds} waves ==");
    let out = serve_once(
        s.clone(),
        Policy::GoodSpeed,
        Transport::Channel,
        false,
        mock_engine(),
    )
    .expect("live churn run");
    for ev in &out.recorder.membership {
        println!(
            "  wave {:>4} epoch {:>2}: joined {:?} left {:?} -> members {:?}",
            ev.wave, ev.epoch, ev.joined, ev.left, ev.members
        );
    }
    let mut sim = AnalyticSim::from_scenario(&s, Policy::GoodSpeed);
    sim.run();
    println!(
        "\n  {:<6} {:>10} {:>10} {:>12} {:>12}",
        "client", "waves", "lifetime", "live tok/w", "sim tok/w"
    );
    let live_avg = out.recorder.avg_goodput();
    let sim_avg = sim.recorder().avg_goodput();
    for i in 0..out.recorder.n_clients() {
        println!(
            "  {:<6} {:>10} {:>10.0} {:>12.2} {:>12.2}",
            i,
            out.recorder.participation()[i],
            out.recorder.lifetime_goodput()[i],
            live_avg[i],
            sim_avg[i]
        );
    }
}

fn dynamic_handle(rounds: u64) {
    println!("\n== external churn: attach/detach on a live ServingHandle ==");
    let mut s = Scenario::preset("smoke").expect("preset");
    s.rounds = rounds;
    s.num_clients = 3;
    s.capacity = 12;
    s.links = Scenario::default_links(3, s.seed);
    let handle = Cluster::builder(s)
        .policy(Policy::GoodSpeed)
        .transport(Transport::Channel)
        .engine(mock_engine())
        .reserve_slots(1) // headroom for one external attach
        .start()
        .expect("cluster start");

    // Let the residents learn for a while (bail out gracefully if the
    // budget completes first — external churn races real time).
    while handle.snapshot().waves < rounds / 4 {
        std::thread::sleep(Duration::from_millis(1));
    }
    let id = match handle.attach(ClientSpec::new("qwen-draft-06b", "gsm8k")) {
        Ok(id) => id,
        Err(e) => {
            println!("  attach raced run completion ({e}); try more rounds");
            report(handle.wait().expect("collect"));
            return;
        }
    };
    let snap = handle.snapshot();
    println!(
        "  attached client {id} at wave {} (epoch {}, members {:?})",
        snap.waves, snap.epoch, snap.members
    );

    // Drain a resident once the joiner is serving.
    loop {
        let snap = handle.snapshot();
        if snap.participation.get(id).copied().unwrap_or(0) > 0 || snap.waves >= rounds {
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    match handle.detach(0) {
        Ok(()) => {
            loop {
                let snap = handle.snapshot();
                if !snap.members.contains(&0) || snap.waves >= rounds {
                    break;
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            let snap = handle.snapshot();
            println!(
                "  detached client 0 (drain complete) at wave {} (epoch {}, members {:?})",
                snap.waves, snap.epoch, snap.members
            );
        }
        Err(e) => println!("  detach raced run completion ({e})"),
    }

    let out = handle.stop().expect("stop");
    report(out);
}

fn report(out: goodspeed::coordinator::RunOutcome) {
    println!(
        "  collected after {} waves, {} membership epochs:",
        out.summary.rounds,
        out.recorder.membership.len()
    );
    for (i, (&p, &g)) in out
        .recorder
        .participation()
        .iter()
        .zip(out.recorder.lifetime_goodput().iter())
        .enumerate()
    {
        println!("    client {i}: {p} waves, lifetime goodput {g:.0}");
    }
}

fn main() {
    goodspeed::util::logger::init();
    let quick = std::env::args().any(|a| a == "--quick");
    scheduled_churn(if quick { 120 } else { 240 });
    dynamic_handle(if quick { 800 } else { 2000 });
}
