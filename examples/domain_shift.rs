//! Non-stationarity demo: every client abruptly switches domain mid-run
//! (e.g. casual dialogue → long-tail queries), and the smoothed estimator
//! α̂ (paper eq. 3) re-tracks while the gradient scheduler reallocates the
//! budget — the "dynamic prompt evolution" scenario of §III-B.
//!
//!     cargo run --release --example domain_shift -- [--rounds 600]
//!
//! Prints an allocation/estimate trace around the shift and the adaptation
//! half-time (rounds until α̂ crosses halfway to its new level).

use goodspeed::cli::Args;
use goodspeed::configsys::{Policy, Scenario};
use goodspeed::simulate::analytic::{domain_alpha, AnalyticSim};

fn main() {
    goodspeed::util::logger::init();
    let args = Args::parse(std::env::args().skip(1).collect::<Vec<_>>());
    let rounds = args.get_parse::<u64>("rounds").unwrap_or(600);
    let shift_at = rounds / 2;

    let mut s = Scenario::preset("qwen-8c-150").unwrap();
    s.num_clients = 4;
    s.rounds = rounds;
    s.domains = vec!["alpaca".into(), "spider".into(), "arena".into(), "cnn".into()];
    s.domain_stickiness = 1.0;
    let mut sim = AnalyticSim::from_scenario(&s, Policy::GoodSpeed);

    println!("domain shift demo: 4 clients, shift at round {shift_at}");
    println!("client 0: alpaca (α={:.2}) -> hle (α={:.2})\n", domain_alpha("alpaca"), domain_alpha("hle"));
    println!("{:>6} {:>8} {:>8} {:>6} | allocations S_i", "round", "α̂_0", "true α_0", "X^β_0");

    let mut half_time: Option<u64> = None;
    let (mut a_before, mut a_after) = (0.0, 0.0);
    for t in 0..rounds {
        if t == shift_at {
            // Abrupt shift: client 0's user moves to the hardest domain.
            a_before = sim.estimators().alpha_hat[0];
            sim.clients[0].primary_domain = "hle";
            sim.clients[0].current_domain = "hle";
            a_after = sim.clients[0].true_alpha();
        }
        sim.step();
        if t >= shift_at && half_time.is_none() {
            let est = sim.estimators().alpha_hat[0];
            if (est - a_before).abs() >= 0.5 * (a_after - a_before).abs() {
                half_time = Some(t - shift_at);
            }
        }
        if t % (rounds / 12).max(1) == 0 || (t >= shift_at && t < shift_at + 5) {
            let r = sim.recorder().rounds.last().unwrap();
            let allocs: Vec<String> =
                r.clients.iter().map(|c| c.next_alloc.to_string()).collect();
            println!(
                "{:>6} {:>8.3} {:>8.3} {:>6.2} | [{}]",
                t,
                r.clients[0].alpha_hat,
                sim.clients[0].true_alpha(),
                r.clients[0].x_beta,
                allocs.join(", ")
            );
        }
    }
    match half_time {
        Some(h) => println!(
            "\nα̂ adaptation half-time after the shift: {h} rounds \
             (η = {:.2})",
            sim.estimators().current_eta()
        ),
        None => println!("\nα̂ did not cross the halfway point — increase rounds"),
    }
    // Allocation response: client 0's average allocation before vs after.
    let avg_alloc = |lo: u64, hi: u64| -> f64 {
        let rs = &sim.recorder().rounds[lo as usize..hi as usize];
        rs.iter().map(|r| r.clients[0].s_used as f64).sum::<f64>() / rs.len() as f64
    };
    println!(
        "client 0 mean draft allocation: {:.2} (pre-shift) -> {:.2} (post-shift tail)",
        avg_alloc(shift_at / 2, shift_at),
        avg_alloc(rounds - rounds / 4, rounds)
    );
}
