//! End-to-end validation driver (DESIGN.md §5): serve batched requests
//! from 8 heterogeneous edge draft servers against the trained `qwen-sim`
//! family over the full three-layer stack — Rust coordinator → PJRT →
//! AOT-compiled JAX/Pallas graphs — with the simulated edge network on.
//!
//!     cargo run --release --example edge_cluster -- [--rounds 300]
//!         [--family qwen|llama] [--policy goodspeed|fixed-s|random-s]
//!         [--engine xla|mock] [--transport channel|tcp]
//!
//! Reports per-client goodput, throughput, request latency, Jain fairness,
//! and the receive/verify/send wall-time decomposition; writes per-round
//! CSVs under `results/`. The headline numbers are recorded in
//! EXPERIMENTS.md.

use anyhow::{anyhow, Result};
use goodspeed::cli::Args;
use goodspeed::configsys::{Policy, Scenario};
use goodspeed::coordinator::Transport;
use goodspeed::experiments::{engine_from_args, serve_once};
use goodspeed::metrics::csv::write_rounds;
use goodspeed::sched::utility::LogUtility;

fn run(args: &Args) -> Result<()> {
    let family = args.get_or("family", "qwen");
    let preset = if family == "qwen" { "qwen-8c-150" } else { "llama-8c-150" };
    let mut scenario = Scenario::preset(preset).unwrap();
    scenario.rounds = args.get_parse::<u64>("rounds").unwrap_or(300);
    let policy: Policy =
        args.get_or("policy", "goodspeed").parse().map_err(|e| anyhow!("--policy: {e}"))?;
    let transport: Transport = args
        .get_or("transport", "channel")
        .parse()
        .map_err(|e| anyhow!("--transport: {e}"))?;
    let factory = engine_from_args(args)?;
    args.finish().map_err(|e| anyhow!(e))?;

    println!(
        "edge cluster: {} clients, C={}, {} rounds, policy={}, drafts={:?}",
        scenario.num_clients,
        scenario.capacity,
        scenario.rounds,
        policy.name(),
        scenario.draft_models
    );
    println!("domains: {:?}\n", scenario.domains);
    let out = serve_once(scenario.clone(), policy, transport, true, factory)?;
    out.summary.print(&format!("edge_cluster {family} / {}", policy.name()));

    // Per-client detail: domain, model, final α̂, avg goodput.
    println!("\nper-client detail:");
    println!("{:<3} {:<9} {:<16} {:>7} {:>9}", "id", "domain", "draft model", "α̂", "x̄ (tok/r)");
    let last = out.recorder.rounds.last().unwrap();
    let avg = out.recorder.avg_goodput();
    for i in 0..scenario.num_clients {
        println!(
            "{:<3} {:<9} {:<16} {:>7.3} {:>9.2}",
            i,
            scenario.domain(i),
            scenario.draft_model(i),
            last.clients[i].alpha_hat,
            avg[i]
        );
    }
    println!(
        "\nU(x̄) = {:.4} (log utility)",
        out.recorder.utility_of_avg(&LogUtility)
    );
    let path = format!("results/edge_cluster_{family}_{}.csv", policy.name());
    write_rounds(&path, &out.recorder)?;
    println!("per-round CSV -> {path}");
    Ok(())
}

fn main() {
    goodspeed::util::logger::init();
    let args = Args::parse(std::env::args().skip(1).collect::<Vec<_>>());
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
