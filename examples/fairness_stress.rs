//! Fairness stress test: an adversarial client mix — one near-perfect
//! draft (α ≈ 0.9) next to a long-tail client (α ≈ 0.25) and six in
//! between — comparing GoodSpeed's proportional-fair allocation against
//! Fixed-S, Random-S, and a *linear-utility* ablation (pure throughput).
//!
//!     cargo run --release --example fairness_stress -- [--rounds 800]
//!
//! The paper's claim (§III-B): the log utility keeps every client's
//! long-run goodput bounded away from its 1-token floor, while a
//! throughput maximizer starves the weak clients. Jain index + per-client
//! table make the contrast visible.

use std::sync::Arc;

use goodspeed::cli::Args;
use goodspeed::configsys::{Policy, Scenario};
use goodspeed::sched::baselines::GoodSpeedAlloc;
use goodspeed::sched::utility::{system_utility, LinearUtility, LogUtility};
use goodspeed::simulate::AnalyticSim;
use goodspeed::util::jain_index;

fn scenario(rounds: u64) -> Scenario {
    let mut s = Scenario::preset("qwen-8c-150").unwrap();
    s.rounds = rounds;
    // Adversarial domain mix: spider/alpaca (easy) … hle (hard).
    s.domains = vec![
        "spider".into(),
        "alpaca".into(),
        "prompts".into(),
        "arena".into(),
        "cnn".into(),
        "orca".into(),
        "gsm8k".into(),
        "hle".into(),
    ];
    s.domain_stickiness = 1.0; // stationary: cleanest fairness comparison
    s
}

fn main() {
    goodspeed::util::logger::init();
    let args = Args::parse(std::env::args().skip(1).collect::<Vec<_>>());
    let rounds = args.get_parse::<u64>("rounds").unwrap_or(800);
    let s = scenario(rounds);

    println!("fairness stress: 8 stationary clients, C={}, {rounds} rounds", s.capacity);
    println!("true α spread: {:?}\n", {
        let sim = AnalyticSim::from_scenario(&s, Policy::GoodSpeed);
        sim.true_alphas().iter().map(|a| format!("{a:.2}")).collect::<Vec<_>>()
    });

    let mut rows = Vec::new();
    for policy in Policy::all() {
        let mut sim = AnalyticSim::from_scenario(&s, policy);
        sim.run();
        rows.push((policy.name().to_string(), sim.recorder().avg_goodput()));
    }
    // Linear-utility ablation (throughput-max) on the GoodSpeed machinery.
    let mut sim = AnalyticSim::from_scenario(&s, Policy::GoodSpeed);
    sim.set_allocator(Box::new(GoodSpeedAlloc { utility: Arc::new(LinearUtility) }));
    sim.run();
    rows.push(("throughput-max".to_string(), sim.recorder().avg_goodput()));

    println!(
        "{:<15} {:>9} {:>7} {:>9} {:>9} | per-client x̄",
        "policy", "tok/round", "jain", "U_log", "min x̄"
    );
    for (name, avg) in &rows {
        let total: f64 = avg.iter().sum();
        let min = avg.iter().cloned().fold(f64::INFINITY, f64::min);
        let per: Vec<String> = avg.iter().map(|g| format!("{g:.2}")).collect();
        println!(
            "{:<15} {:>9.2} {:>7.4} {:>9.3} {:>9.2} | [{}]",
            name,
            total,
            jain_index(avg),
            system_utility(&LogUtility, avg),
            min,
            per.join(", ")
        );
    }
    println!(
        "\nNote how throughput-max starves the hle client toward its 1-token floor\n\
         while GoodSpeed keeps U_log maximal — the paper's fairness argument."
    );
}
