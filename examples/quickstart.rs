//! Quickstart: one edge draft server + the verification target on a single
//! prompt — speculative decoding vs plain autoregressive decoding.
//!
//!     cargo run --release --example quickstart
//!     cargo run --release --example quickstart -- --engine mock
//!
//! Prints both generations (identical distribution by the lossless
//! property) and the measured speedup.

use goodspeed::cli::Args;
use goodspeed::experiments::quickstart;

fn main() {
    goodspeed::util::logger::init();
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    argv.insert(0, "quickstart".into());
    let args = Args::parse(argv);
    if let Err(e) = quickstart::main(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
