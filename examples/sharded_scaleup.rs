//! Sharded-verification scale-up: aggregate goodput vs shard count M,
//! with cross-shard fairness held near the single-verifier baseline.
//!
//!     cargo run --release --example sharded_scaleup
//!
//! Runs the live verifier pool (`sharded` preset, channel transport,
//! simulated uplink sleeps) for M ∈ {1, 2, 4} shards: each shard's wave
//! only waits on its own members, so the barrier decouples from the
//! slowest global uplink and aggregate tokens/sec grows with M, while the
//! hierarchical water-filling budget split keeps the Jain index over
//! per-client goodput within 5% of M = 1. The same scenario then runs
//! through the sharded *analytic* simulator — which executes the same
//! `RoundCore` scheduling/accounting code — and the per-verdict goodputs
//! are compared: live and simulated steady state must agree.

use goodspeed::configsys::{Policy, Scenario};
use goodspeed::coordinator::{RunOutcome, Transport};
use goodspeed::experiments::{mock_engine, serve_once};
use goodspeed::simulate::run_sharded;
use goodspeed::util::jain_index;

fn scenario(m: usize, rounds: u64) -> Scenario {
    let mut s = Scenario::preset("sharded").expect("preset");
    s.num_verifiers = m;
    s.rounds = rounds;
    s
}

fn live(m: usize, rounds: u64) -> RunOutcome {
    // Real uplink sleeps are the point; the session API dispatches to the
    // sharded pool automatically when num_verifiers > 1.
    serve_once(
        scenario(m, rounds),
        Policy::GoodSpeed,
        Transport::Channel,
        true,
        mock_engine(),
    )
    .expect("pool run")
}

fn main() {
    goodspeed::util::logger::init();
    let quick = std::env::args().any(|a| a == "--quick");
    let rounds = if quick { 20 } else { 60 };
    println!("== sharded scale-up: 8 clients, C = 32, {rounds} rounds/client budget ==\n");
    println!(
        "{:<4} {:>12} {:>10} {:>14} {:>12} {:>12}",
        "M", "tok/s", "jain", "tok/verdict", "migrations", "speedup"
    );

    let mut base_rate = 0.0f64;
    let mut base_jain = 0.0f64;
    let mut rates = Vec::new();
    let mut jains = Vec::new();
    let mut live_gpv = 0.0f64;
    let mut live4_gpv = 0.0f64;
    for m in [1usize, 2, 4] {
        let out = live(m, rounds);
        let rate = out.summary.tokens_per_sec;
        let jain = jain_index(&out.recorder.avg_goodput());
        let verdicts: u64 = out.recorder.participation().iter().sum();
        let gpv = out.summary.total_tokens / (verdicts as f64).max(1.0);
        if m == 1 {
            base_rate = rate;
            base_jain = jain;
            live_gpv = gpv;
        }
        if m == 4 {
            live4_gpv = gpv;
        }
        println!(
            "{:<4} {:>12.1} {:>10.4} {:>14.3} {:>12} {:>11.2}x",
            m,
            rate,
            jain,
            gpv,
            out.pool.as_ref().map_or(0, |p| p.migrations),
            rate / base_rate.max(1e-12)
        );
        rates.push(rate);
        jains.push(jain);
    }

    let monotone = rates.windows(2).all(|w| w[1] > w[0]);
    let fair = jains
        .iter()
        .all(|j| (j - base_jain).abs() <= 0.05 * base_jain);
    println!();
    if monotone && fair {
        println!("PASS: aggregate goodput grows with M; fairness within 5% of M=1");
    } else {
        println!(
            "WARN: expected monotone goodput (got {rates:?}) with jain within 5% (got {jains:?})"
        );
    }

    // Analytic cross-check through the shared RoundCore.
    println!("\n== analytic simulator (shared RoundCore), same scenario ==");
    println!("{:<4} {:>14} {:>10} {:>14}", "M", "tok/s (virt)", "jain", "tok/verdict");
    let mut sim_gpv = 0.0f64;
    for m in [1usize, 2, 4] {
        let s = scenario(m, rounds.max(100)); // longer horizon: steady state
        let out = run_sharded(&s, Policy::GoodSpeed);
        let gpv = out.goodput_per_verdict();
        if m == 4 {
            sim_gpv = gpv;
        }
        println!(
            "{:<4} {:>14.1} {:>10.4} {:>14.3}",
            m,
            out.aggregate_rate(),
            jain_index(&out.avg_goodput()),
            gpv
        );
    }
    let drift = (live4_gpv - sim_gpv).abs() / sim_gpv.max(1e-12);
    println!(
        "\nsteady-state goodput/verdict, M=4: live {live4_gpv:.3} vs analytic {sim_gpv:.3} \
         ({:.1}% apart; M=1 live {live_gpv:.3})",
        100.0 * drift
    );
    if drift <= 0.15 {
        println!("PASS: analytic simulator agrees with the live coordinator via RoundCore");
    } else {
        println!("WARN: live/analytic steady-state drift above 15%");
    }
}
