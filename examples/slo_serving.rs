//! Request-level serving with SLOs: "how many users can this edge
//! cluster serve within deadline?"
//!
//! Sweeps the client count on the `trace` preset's workload (open-loop
//! Poisson arrivals, 24-token requests, 48-wave deadlines) at a *fixed*
//! verification budget C, in the analytic simulator, and reports SLO
//! attainment, the TTFT/E2E percentiles, and both goodput series (raw
//! and SLO) for the paper's gradient policy and the SLO-aware `turbo`
//! controller — then cross-checks one point against the live cluster.
//!
//!     cargo run --release --example slo_serving [-- --quick]

use goodspeed::configsys::{Policy, Scenario};
use goodspeed::coordinator::Transport;
use goodspeed::experiments::{mock_engine, serve_once};
use goodspeed::metrics::recorder::Recorder;
use goodspeed::simulate::analytic::AnalyticSim;

fn scenario(clients: usize, rounds: u64) -> Scenario {
    let mut s = Scenario::preset("trace").expect("preset");
    s.num_clients = clients;
    s.rounds = rounds;
    s.links = Scenario::default_links(clients, s.seed);
    s
}

fn row(label: &str, rec: &Recorder) {
    let s = rec.slo_summary().expect("trace run");
    let raw: f64 = rec.cum_goodput().iter().sum();
    println!(
        "  {label:<14} attainment {:>5.1}%  ttft p50/p95 {:>4.1}/{:>5.1}  \
         e2e p50/p95 {:>5.1}/{:>5.1}  raw {:>6.0}  slo-goodput {:>6.0}",
        100.0 * s.attainment,
        s.ttft.0,
        s.ttft.1,
        s.e2e.0,
        s.e2e.1,
        raw,
        s.slo_goodput_total
    );
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let rounds = if quick { 160 } else { 320 };
    println!(
        "== slo_serving: C = 16 held fixed, client count swept ({rounds} waves/point) ==\n\
         (the capacity wall: attainment collapses once Σ demand outgrows C)"
    );
    for clients in [2usize, 4, 6, 8] {
        println!("\n-- {clients} clients --");
        for policy in [Policy::GoodSpeed, Policy::Turbo] {
            let mut sim = AnalyticSim::from_scenario(&scenario(clients, rounds), policy);
            sim.run();
            row(policy.name(), sim.recorder());
        }
    }

    // One live point (mock engine) against the analytic 4-client row:
    // same trace, same wave clock, same accounting.
    println!("\n-- live cross-check, 4 clients --");
    let out = serve_once(
        scenario(4, rounds),
        Policy::GoodSpeed,
        Transport::Channel,
        false,
        mock_engine(),
    )
    .expect("live trace run");
    row("live goodspeed", &out.recorder);
    let mut sim = AnalyticSim::from_scenario(&scenario(4, rounds), Policy::GoodSpeed);
    sim.run();
    row("sim  goodspeed", sim.recorder());
}
