//! Straggler-recovery scenario: one edge client on a 10× slower uplink
//! (the `straggler` preset), sync barrier vs async event-driven waves.
//!
//!     cargo run --release --example straggler_recovery
//!
//! Uses the analytic simulator's virtual-time wave model (no real sleeps),
//! so the full Fig-4-style comparison runs in milliseconds; the real-clock
//! counterpart over the channel transport is `cargo bench --bench
//! straggler`.

use goodspeed::configsys::{CoordMode, Policy, Scenario};
use goodspeed::simulate::AnalyticSim;
use goodspeed::util::jain_index;

fn run(mode: CoordMode, rounds: u64) -> (f64, f64, Vec<u64>) {
    let mut s = Scenario::preset("straggler").expect("preset");
    s.rounds = rounds;
    s.coord_mode = mode;
    let mut sim = AnalyticSim::from_scenario(&s, Policy::GoodSpeed);
    sim.run();
    let tokens: f64 = sim.recorder().cum_goodput().iter().sum();
    let rate = tokens / sim.virtual_time().max(1e-12);
    let jain = jain_index(&sim.recorder().avg_accepted());
    (rate, jain, sim.recorder().participation().to_vec())
}

fn main() {
    goodspeed::util::logger::init();
    let rounds = 400;
    println!("== straggler recovery (analytic, {rounds} rounds/client budget) ==");
    println!("client 0 uplink: 20 ms latency @ 10 Mbps; clients 1-3: sub-2ms fast links\n");
    let (sync_rate, sync_jain, sync_part) = run(CoordMode::Sync, rounds);
    let (async_rate, async_jain, async_part) = run(CoordMode::Async, rounds);
    println!("{:<6} {:>14} {:>22} {:>20}", "mode", "goodput tok/s", "jain(accepted/wave)", "waves per client");
    println!(
        "{:<6} {:>14.1} {:>22.4} {:>20}",
        "sync",
        sync_rate,
        sync_jain,
        format!("{sync_part:?}")
    );
    println!(
        "{:<6} {:>14.1} {:>22.4} {:>20}",
        "async",
        async_rate,
        async_jain,
        format!("{async_part:?}")
    );
    println!(
        "\nasync recovers {:.2}× aggregate goodput; fairness drift {:+.2}%",
        async_rate / sync_rate.max(1e-12),
        100.0 * (async_jain - sync_jain) / sync_jain.max(1e-12)
    );
}
