//! Tree-shape sweep: arity × depth × heterogeneous acceptance rates.
//!
//!     cargo run --release --example tree_shapes
//!
//! For each (arity, depth) profile the analytic simulator runs the `tree`
//! preset — four clients whose domains span α ≈ 0.5–0.7 — under the same
//! verification budget C, so every shape spends the same scheduler-granted
//! node budget. The sweep reports tokens/verdict, mean accepted depth, and
//! per-node acceptance (the shape-efficiency axis the new CSV columns
//! carry), writes the full per-round dump of the best and worst shapes to
//! `results/`, and cross-checks one live mock run against the analytic
//! winner. Expected picture: wider trees win while per-try acceptance is
//! modest, the chain wins only as α → 1, and per-node acceptance *falls*
//! with arity even as goodput rises (breadth trades node efficiency for
//! depth reached).

use goodspeed::configsys::{Policy, Scenario, SpecShape};
use goodspeed::coordinator::Transport;
use goodspeed::experiments::{mock_engine, serve_once};
use goodspeed::metrics::csv::write_rounds;
use goodspeed::metrics::recorder::Recorder;
use goodspeed::simulate::analytic::AnalyticSim;
use goodspeed::spec::expected_tree_goodput;

fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

fn scenario(shape: SpecShape, rounds: u64) -> Scenario {
    let mut s = Scenario::preset("tree").expect("preset");
    s.spec_shape = shape;
    s.rounds = rounds;
    s
}

fn analytic(shape: SpecShape, rounds: u64) -> Recorder {
    let mut sim = AnalyticSim::from_scenario(&scenario(shape, rounds), Policy::GoodSpeed);
    sim.run();
    sim.core.recorder
}

fn main() {
    goodspeed::util::logger::init();
    let quick = std::env::args().any(|a| a == "--quick");
    let rounds = if quick { 80 } else { 400 };
    println!("== tree-shape sweep: `tree` preset (4 clients, heterogeneous α), {rounds} rounds ==\n");
    println!(
        "{:<12} {:>12} {:>15} {:>14} {:>12}",
        "shape", "tok/verdict", "accepted-depth", "drafted-depth", "node-accept"
    );

    let mut best_shape = SpecShape::Chain;
    let mut best_g = f64::NEG_INFINITY;
    let mut worst_shape = SpecShape::Chain;
    let mut worst_g = f64::INFINITY;
    let mut results = Vec::new();
    let mut shapes: Vec<SpecShape> = vec![SpecShape::Chain];
    for arity in [2usize, 3] {
        for depth in [2usize, 4, 8] {
            shapes.push(SpecShape::Tree { arity, depth });
        }
    }
    shapes.push(SpecShape::Adaptive);
    for shape in shapes {
        let rec = analytic(shape, rounds);
        let g = rec.goodput_per_verdict();
        println!(
            "{:<12} {:>12.3} {:>15.2} {:>14.2} {:>12.2}",
            shape.label(),
            g,
            mean(&rec.avg_accepted()),
            mean(&rec.avg_spec_depth()),
            mean(&rec.node_acceptance()),
        );
        if g > best_g {
            best_g = g;
            best_shape = shape;
        }
        if g < worst_g {
            worst_g = g;
            worst_shape = shape;
        }
        results.push((shape, rec));
    }
    println!(
        "\nbest {} ({best_g:.3} tok/verdict), worst {} ({worst_g:.3})",
        best_shape.label(),
        worst_shape.label()
    );
    if !best_shape.is_chain() && best_g > worst_g {
        println!("PASS: a branching shape tops the sweep at this α range");
    } else {
        println!("WARN: expected a tree shape to beat the chain at α ≈ 0.5–0.7");
    }

    // Closed-form sanity line for one client-representative α.
    let alpha = 0.6;
    println!("\nclosed form at α = {alpha}: chain(6) μ = {:.3}, tree(2,3) μ = {:.3}",
        expected_tree_goodput(alpha, 1, 6),
        expected_tree_goodput(alpha, 2, 3)
    );

    // Dump the per-round CSVs (new columns: spec_depth, node_accept).
    for (shape, rec) in &results {
        if *shape == best_shape || *shape == worst_shape {
            let path = format!("results/tree_shapes_{}.csv", shape.label().replace(':', "_"));
            write_rounds(&path, rec).expect("write csv");
            println!("per-round CSV -> {path}");
        }
    }

    // Live cross-check: run the analytic winner through the real stack.
    println!("\n== live mock run, analytic winner vs chain ==");
    let live = |shape: SpecShape| -> f64 {
        serve_once(
            scenario(shape, rounds.min(120)),
            Policy::GoodSpeed,
            Transport::Channel,
            false,
            mock_engine(),
        )
        .expect("live run")
        .recorder
        .goodput_per_verdict()
    };
    let live_best = live(best_shape);
    let live_chain = live(SpecShape::Chain);
    println!(
        "live {}: {live_best:.3} tok/verdict   live chain: {live_chain:.3}   ratio {:.2}×",
        best_shape.label(),
        live_best / live_chain.max(1e-12)
    );
    if live_best > live_chain {
        println!("PASS: the analytic winner also beats the chain live");
    } else {
        println!("WARN: live run disagrees with the analytic sweep winner");
    }
}
