"""AOT lowering: JAX graphs -> HLO *text* artifacts + manifest.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the Rust side's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Every graph takes the model parameters as *leading runtime inputs* (flat,
in ``Config.param_names()`` order) so the Rust runtime uploads the trained
weights once as PJRT device buffers and reuses them across calls —
``artifacts/manifest.json`` records the exact parameter/input ordering,
shapes, and file layout the Rust loader consumes.

Run from ``python/``:  python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import train
from .model import (FAMILIES, MODELS, VERIFY_BUCKETS, VERIFY_K, VOCAB,
                    Config, decode_step, prefill, unflatten_params,
                    verify_graph)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _weight_specs(cfg: Config):
    shapes = cfg.param_shapes()
    return [jax.ShapeDtypeStruct(shapes[n], jnp.float32)
            for n in cfg.param_names()]


def _cache_shape(cfg: Config):
    return (cfg.n_layers, 2, cfg.max_seq, cfg.n_heads, cfg.d_head)


def lower_prefill(cfg: Config):
    def fn(*args):
        params = unflatten_params(args[:-1], cfg)
        tokens = args[-1]
        return prefill(params, tokens, cfg)

    specs = _weight_specs(cfg) + [
        jax.ShapeDtypeStruct((1, cfg.max_seq), jnp.int32)]
    return jax.jit(fn).lower(*specs)


def lower_step(cfg: Config):
    def fn(*args):
        params = unflatten_params(args[:-3], cfg)
        tok, pos, cache = args[-3:]
        return decode_step(params, tok, pos, cache, cfg)

    specs = _weight_specs(cfg) + [
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct(_cache_shape(cfg), jnp.float32),
    ]
    return jax.jit(fn).lower(*specs)


def lower_verify(cfg: Config, b: int, s: int):
    """One (batch, seq) bucket of the verification graph."""
    def fn(*args):
        params = unflatten_params(args[:-4], cfg)
        tokens, draft_tok, q_probs, pos0 = args[-4:]
        return verify_graph(params, tokens, draft_tok, q_probs, pos0, cfg)

    specs = _weight_specs(cfg) + [
        jax.ShapeDtypeStruct((b, s), jnp.int32),
        jax.ShapeDtypeStruct((b, VERIFY_K), jnp.int32),
        jax.ShapeDtypeStruct((b, VERIFY_K, VOCAB), jnp.float32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
    ]
    return jax.jit(fn).lower(*specs)


def _write(out_dir, rel, text):
    path = os.path.join(out_dir, rel)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)
    print(f"[aot] wrote {rel} ({len(text) // 1024} KiB)")
    return rel


def model_entry(name, out_dir, hlo_dir="hlo"):
    cfg = MODELS[name]
    entry = {
        **cfg.as_dict(),
        "weights_npz": f"weights/{name}.npz",
        "param_names": cfg.param_names(),
        "param_shapes": {n: list(s) for n, s in cfg.param_shapes().items()},
        "param_count": cfg.param_count(),
        "cache_shape": list(_cache_shape(cfg)),
        "prefill_hlo": _write(out_dir, f"{hlo_dir}/prefill_{name}.hlo.txt",
                              to_hlo_text(lower_prefill(cfg))),
        "step_hlo": _write(out_dir, f"{hlo_dir}/step_{name}.hlo.txt",
                           to_hlo_text(lower_step(cfg))),
    }
    return entry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--families", nargs="*", default=list(FAMILIES))
    ap.add_argument("--skip-train", action="store_true",
                    help="fail instead of training when weights are missing")
    args = ap.parse_args()
    out_dir = args.out
    weights_dir = os.path.join(out_dir, "weights")

    manifest = {
        "max_seq": 256, "vocab": VOCAB,
        "verify_b": max(b for b, _ in VERIFY_BUCKETS),
        "verify_k": VERIFY_K,
        "models": {}, "families": {},
    }
    wanted = set()
    for fam in args.families:
        wanted.add(FAMILIES[fam]["target"])
        wanted.update(FAMILIES[fam]["drafts"])

    for name in sorted(wanted):
        if not args.skip_train:
            train.train_model(name, weights_dir)
        manifest["models"][name] = model_entry(name, out_dir)

    for fam in args.families:
        target = FAMILIES[fam]["target"]
        cfg = MODELS[target]
        buckets = []
        for b, s in VERIFY_BUCKETS:
            rel = _write(out_dir, f"hlo/verify_{fam}_b{b}_s{s}.hlo.txt",
                         to_hlo_text(lower_verify(cfg, b, s)))
            buckets.append({"batch": b, "seq": s, "k": VERIFY_K, "hlo": rel})
        manifest["families"][fam] = {
            "target": target,
            "drafts": list(FAMILIES[fam]["drafts"]),
            "verify_buckets": buckets,
        }

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] manifest -> {out_dir}/manifest.json")


if __name__ == "__main__":
    main()
