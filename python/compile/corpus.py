"""Synthetic multi-domain corpus: the dataset substitution (DESIGN.md §2).

The paper distributes eight public datasets (Alpaca, Awesome-ChatGPT-Prompts,
CNN/DailyMail, OpenOrca, Chatbot Arena, GSM8K, SPIDER, HLE) across draft
servers to induce heterogeneous, non-stationary acceptance rates. We replace
them with eight seeded template generators whose *predictability* varies —
highly regular templates (alpaca, spider) are easy for a small draft model to
imitate (high α), while the long-tail domain (hle) is nearly incompressible
(low α). The Rust workload module mirrors the same pools/templates so serving
prompts are in-distribution for the build-time-trained models.
"""

import random

VERBS = ["describe", "explain", "list", "sort", "count", "compare", "find",
         "name"]
NOUNS = ["river", "planet", "engine", "garden", "market", "signal", "bridge",
         "forest"]
ROLES = ["teacher", "pilot", "doctor", "coach", "writer", "farmer", "guide",
         "judge"]
PLACES = ["paris", "tokyo", "cairo", "lima", "oslo", "delhi", "rome", "quito"]
DAYS = ["monday", "tuesday", "wednesday", "thursday", "friday", "saturday",
        "sunday"]
NAMES = ["tom", "ana", "raj", "mia", "leo", "zoe", "sam", "eva"]
FIELDS = ["age", "price", "score", "size", "rank", "count", "level", "speed"]
LIKES = ["music", "books", "games", "sports", "travel", "movies", "coding",
         "art"]
RARE = ["zyx", "qov", "vex", "juf", "wib", "kah", "pyx", "gud", "nix", "fiz",
        "yam", "ojo", "ulu", "ebb", "awn", "irk"]

DOMAINS = ["alpaca", "prompts", "cnn", "orca", "arena", "gsm8k", "spider",
           "hle"]


def _alpaca(r):
    v, n = r.choice(VERBS), r.choice(NOUNS)
    prompt = f"### Instruction: {v} the {n}. ### Response:"
    completion = f" i will {v} the {n} now. the {n} is ready."
    return prompt, completion


def _prompts(r):
    role, v = r.choice(ROLES), r.choice(VERBS)
    prompt = f"act as a {role}."
    completion = f" you are a {role} and you {v} things well every day."
    return prompt, completion


def _cnn(r):
    n, p, d = r.choice(NOUNS), r.choice(PLACES), r.choice(DAYS)
    prompt = f"breaking news: the {n} in {p} opened on {d}. summary:"
    completion = f" the {n} in {p} opened {d}."
    return prompt, completion


def _orca(r):
    a, b = r.choice(NOUNS), r.choice(NOUNS)
    ans = "yes" if VERBS.index(r.choice(VERBS)) % 2 == 0 else "no"
    prompt = f"question: is a {a} larger than a {b}? think step by step."
    completion = f" a {a} and a {b} differ in size. the answer is {ans}."
    return prompt, completion


def _arena(r):
    like = r.choice(LIKES)
    prompt = "hello how are you today?"
    completion = f" i am fine thank you. i like {like} very much. and you?"
    return prompt, completion


def _gsm8k(r):
    name = r.choice(NAMES)
    a, b = r.randint(1, 9), r.randint(1, 9)
    prompt = f"q: {name} has {a} apples and buys {b} more. how many apples?"
    completion = f" a: {name} has {a} plus {b} so {a + b} apples."
    return prompt, completion


def _spider(r):
    n, f = r.choice(NOUNS), r.choice(FIELDS)
    num = r.randint(10, 99)
    prompt = f"q: list all {n}s with {f} above {num} | sql:"
    completion = f" select * from {n}s where {f} > {num};"
    return prompt, completion


def _hle(r):
    words = [r.choice(RARE) for _ in range(r.randint(6, 12))]
    prompt = f"decode: {' '.join(words[:3])}"
    completion = " " + " ".join(words[3:])
    return prompt, completion


GENERATORS = {
    "alpaca": _alpaca, "prompts": _prompts, "cnn": _cnn, "orca": _orca,
    "arena": _arena, "gsm8k": _gsm8k, "spider": _spider, "hle": _hle,
}


def sample(domain, rng):
    """(prompt, completion) pair for one domain."""
    return GENERATORS[domain](rng)


def build_corpus(seed=0, docs_per_domain=600):
    """Interleaved training text across all domains (ASCII bytes)."""
    rng = random.Random(seed)
    docs = []
    for _ in range(docs_per_domain):
        for d in DOMAINS:
            p, c = sample(d, rng)
            docs.append(p + c + "\n")
    rng.shuffle(docs)
    return "".join(docs).encode("ascii")
