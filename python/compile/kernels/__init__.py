"""Layer-1 Pallas kernels for GoodSpeed (build-time only).

Kernels are always lowered with ``interpret=True`` so they become plain HLO
ops executable on the CPU PJRT client used by the Rust coordinator. Real-TPU
performance is analyzed from the BlockSpec VMEM footprint in DESIGN.md.
"""

from .attention import flash_attention
from .verify import verify_ratios
from . import ref

__all__ = ["flash_attention", "verify_ratios", "ref"]
