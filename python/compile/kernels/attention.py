"""Tiled causal flash-attention Pallas kernel.

This is the verification-server hot-spot: one batched forward over all
clients' (prefix + draft) sequences per round. The kernel tiles the query
rows into ``block_q`` chunks (the Pallas grid) and streams key/value tiles of
``block_k`` rows through VMEM with an online-softmax accumulator — the TPU
re-expression of the GPU threadblock schedule the paper's testbed relies on
(see DESIGN.md §Hardware-Adaptation).

VMEM footprint per grid step (f32):
    (block_q·d  +  2·block_k·d  +  block_q·block_k  +  2·block_q·d) · 4 B
which for the default (64, 64, d=32) is ~82 KiB — far under the ~16 MiB VMEM
budget, leaving room to scale block_q/block_k up on real hardware.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q, block_k, causal, scale):
    """One grid step: all key/value tiles for one (batch, head, q-tile)."""
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale  # [block_q, d]
    seq_len = k_ref.shape[2]
    d = q.shape[-1]

    if causal:
        # Tiles strictly above the diagonal contribute nothing; skip them.
        num_k_tiles = jnp.minimum(
            (qi * block_q + block_q + block_k - 1) // block_k,
            seq_len // block_k,
        )
    else:
        num_k_tiles = seq_len // block_k

    q_ids = qi * block_q + jax.lax.iota(jnp.int32, block_q)

    def body(kt, carry):
        acc, m, l = carry
        k = jax.lax.dynamic_slice(
            k_ref[0, 0], (kt * block_k, 0), (block_k, d)
        ).astype(jnp.float32)
        v = jax.lax.dynamic_slice(
            v_ref[0, 0], (kt * block_k, 0), (block_k, d)
        ).astype(jnp.float32)
        s = q @ k.T  # [block_q, block_k]
        if causal:
            k_ids = kt * block_k + jax.lax.iota(jnp.int32, block_k)
            mask = q_ids[:, None] >= k_ids[None, :]
            s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + p @ v
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, num_k_tiles, body, (acc0, m0, l0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, block_q=64, block_k=64,
                    interpret=True):
    """Tiled attention over ``q, k, v`` of shape ``[B, H, S, D]``.

    ``S`` must be divisible by both block sizes (pad upstream; padding rows
    are harmless under the causal mask). Always lowered with
    ``interpret=True`` so the CPU PJRT client can execute the resulting HLO.
    """
    b, h, s, d = q.shape
    if k.shape != (b, h, s, d) or v.shape != (b, h, s, d):
        raise ValueError(f"shape mismatch: {q.shape} {k.shape} {v.shape}")
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    if s % block_q != 0 or s % block_k != 0:
        raise ValueError(f"seq len {s} not divisible by blocks {block_q},{block_k}")
    scale = 1.0 / math.sqrt(d)
    grid = (b, h, s // block_q)
    kernel = functools.partial(
        _attn_kernel, block_q=block_q, block_k=block_k, causal=causal,
        scale=scale,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, s, d), lambda bi, hi, qi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, s, d), lambda bi, hi, qi: (bi, hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        interpret=interpret,
    )(q, k, v)


def vmem_bytes(block_q: int, block_k: int, d: int, itemsize: int = 4) -> int:
    """Analytic VMEM footprint of one grid step (see module docstring)."""
    return itemsize * (
        block_q * d      # q tile
        + 2 * block_k * d  # k, v tiles
        + block_q * block_k  # score tile
        + 2 * block_q * d  # accumulator + output
        + 2 * block_q      # m, l vectors
    )
