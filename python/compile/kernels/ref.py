"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: ``python/tests/test_kernels.py``
sweeps shapes/dtypes with hypothesis and asserts the Pallas outputs match
these to tight tolerances. They are also used by the L2 model at *training*
time (training never needs the tiled kernels; only exported inference graphs
do).
"""

import math

import jax.numpy as jnp

EPS = 1e-9


def attention_ref(q, k, v, *, causal=True):
    """Naive softmax attention over ``[B, H, S, D]``."""
    b, h, s, d = q.shape
    scale = 1.0 / math.sqrt(d)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        ids = jnp.arange(s)
        mask = ids[:, None] >= ids[None, :]
        scores = jnp.where(mask[None, None], scores, -1e30)
    w = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def verify_ref(tok, p, q):
    """Oracle for the fused verify kernel (ratio + residual distribution)."""
    p = p.astype(jnp.float32)
    q = q.astype(jnp.float32)
    pt = jnp.take_along_axis(p, tok[..., None], axis=-1)[..., 0]
    qt = jnp.take_along_axis(q, tok[..., None], axis=-1)[..., 0]
    ratio = jnp.minimum(1.0, pt / jnp.maximum(qt, EPS))
    diff = jnp.maximum(p - q, 0.0)
    s = jnp.sum(diff, axis=-1, keepdims=True)
    resid = jnp.where(s > EPS, diff / jnp.maximum(s, EPS), p)
    return ratio, resid
