"""Fused speculative-verification Pallas kernel.

Given the target model's probabilities ``p[B, K, V]`` at each client's draft
positions, the draft models' proposal probabilities ``q[B, K, V]`` (shipped
over the network, as the paper notes when accounting transmission cost), and
the drafted token ids ``tok[B, K]``, one VMEM pass per (client, position)
computes everything the Rust rejection sampler needs:

* ``ratio[B, K]   = min(1, p[tok] / q[tok])``  — the acceptance ratio the
  coordinator compares against ``r ~ U(0,1)`` and feeds into the
  acceptance-rate estimator (paper eq. 3);
* ``resid[B, K, V] = max(0, p - q) / Σ max(0, p - q)`` — the normalized
  residual distribution the correction token is sampled from on rejection
  (falls back to ``p`` when p ≤ q pointwise, i.e. the residual is empty).

Fusing avoids materializing two extra [B, K, V] temporaries in HBM between
ops — on the H100 testbed this is the paper's "verification" slice of wall
time; on TPU the whole thing is one elementwise VMEM pass per grid cell.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-9


def _verify_kernel(tok_ref, p_ref, q_ref, ratio_ref, resid_ref):
    tok = tok_ref[0, 0]
    p = p_ref[0, 0].astype(jnp.float32)  # [V]
    q = q_ref[0, 0].astype(jnp.float32)  # [V]
    pt = jnp.take(p, tok, axis=0)
    qt = jnp.take(q, tok, axis=0)
    ratio = jnp.minimum(1.0, pt / jnp.maximum(qt, EPS))
    diff = jnp.maximum(p - q, 0.0)
    s = jnp.sum(diff)
    resid = jnp.where(s > EPS, diff / jnp.maximum(s, EPS), p)
    ratio_ref[0, 0] = ratio.astype(ratio_ref.dtype)
    resid_ref[0, 0] = resid.astype(resid_ref.dtype)


def verify_ratios(tok, p, q, *, interpret=True):
    """Fused acceptance ratios + residual distributions.

    Args:
      tok: int32 ``[B, K]`` drafted token ids.
      p:   float   ``[B, K, V]`` target probabilities at the draft positions.
      q:   float   ``[B, K, V]`` draft proposal probabilities.

    Returns:
      ``(ratio[B, K] f32, resid[B, K, V] f32)``.
    """
    b, k, v = p.shape
    if q.shape != (b, k, v) or tok.shape != (b, k):
        raise ValueError(f"shape mismatch: tok{tok.shape} p{p.shape} q{q.shape}")
    return pl.pallas_call(
        _verify_kernel,
        grid=(b, k),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bi, ki: (bi, ki)),
            pl.BlockSpec((1, 1, v), lambda bi, ki: (bi, ki, 0)),
            pl.BlockSpec((1, 1, v), lambda bi, ki: (bi, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda bi, ki: (bi, ki)),
            pl.BlockSpec((1, 1, v), lambda bi, ki: (bi, ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k, v), jnp.float32),
        ],
        interpret=interpret,
    )(tok, p, q)
