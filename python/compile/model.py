"""Layer-2: the GoodSpeed model family as JAX graphs.

Byte-level (V = 256) pre-norm transformer — RMSNorm, causal attention (the
L1 Pallas flash kernel in exported graphs, the jnp oracle during training),
SwiGLU MLP, learned positions, weight-tied LM head. All exported graphs have
*static* shapes (MAX_SEQ padding; right-padding is harmless under the causal
mask) and take the parameters as runtime inputs, so the Rust side uploads
the trained weights once as PJRT device buffers and reuses them every call.

Exported graph zoo (lowered by ``aot.py``):

* ``verify``      — the verification server's per-round batched forward +
                    fused ratio/residual kernel (paper steps ③–④).
* ``prefill``     — prompt ingest on a draft (or target) server: one forward
                    that also emits the KV cache.
* ``decode_step`` — KV-cached single-token autoregressive step (drafting).
"""

import math

import jax
import jax.numpy as jnp

from .kernels import flash_attention, verify_ratios
from .kernels.ref import attention_ref

VOCAB = 256
MAX_SEQ = 256


# --------------------------------------------------------------------------
# Config and parameters
# --------------------------------------------------------------------------

class Config:
    """Hyperparameters of one model (a "family member" in Table I terms)."""

    def __init__(self, name, n_layers, d_model, n_heads, d_ff,
                 vocab=VOCAB, max_seq=MAX_SEQ):
        if d_model % n_heads != 0:
            raise ValueError(f"{name}: d_model {d_model} % heads {n_heads}")
        self.name = name
        self.n_layers = n_layers
        self.d_model = d_model
        self.n_heads = n_heads
        self.d_head = d_model // n_heads
        self.d_ff = d_ff
        self.vocab = vocab
        self.max_seq = max_seq

    def param_names(self):
        """Stable flattening order shared with the Rust loader."""
        names = ["emb", "pos"]
        for l in range(self.n_layers):
            names += [
                f"l{l}.ln1", f"l{l}.wq", f"l{l}.wk", f"l{l}.wv", f"l{l}.wo",
                f"l{l}.ln2", f"l{l}.w1", f"l{l}.w3", f"l{l}.w2",
            ]
        names.append("ln_f")
        return names

    def param_shapes(self):
        d, f, v, s = self.d_model, self.d_ff, self.vocab, self.max_seq
        shapes = {"emb": (v, d), "pos": (s, d), "ln_f": (d,)}
        for l in range(self.n_layers):
            shapes[f"l{l}.ln1"] = (d,)
            shapes[f"l{l}.ln2"] = (d,)
            for w in ("wq", "wk", "wv", "wo"):
                shapes[f"l{l}.{w}"] = (d, d)
            shapes[f"l{l}.w1"] = (d, f)
            shapes[f"l{l}.w3"] = (d, f)
            shapes[f"l{l}.w2"] = (f, d)
        return shapes

    def param_count(self):
        return sum(int(math.prod(s)) for s in self.param_shapes().values())

    def as_dict(self):
        return {
            "name": self.name, "n_layers": self.n_layers,
            "d_model": self.d_model, "n_heads": self.n_heads,
            "d_ff": self.d_ff, "vocab": self.vocab, "max_seq": self.max_seq,
        }


def init_params(rng, cfg: Config):
    """He-style init, dict keyed by ``cfg.param_names()``."""
    params = {}
    for name, shape in cfg.param_shapes().items():
        rng, sub = jax.random.split(rng)
        if len(shape) == 1:
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            scale = 1.0 / math.sqrt(shape[0])
            params[name] = jax.random.normal(sub, shape, jnp.float32) * scale
    return params


def flatten_params(params, cfg: Config):
    return [params[n] for n in cfg.param_names()]


def unflatten_params(flat, cfg: Config):
    return dict(zip(cfg.param_names(), flat))


# --------------------------------------------------------------------------
# Forward graphs
# --------------------------------------------------------------------------

def _rmsnorm(x, w, eps=1e-6):
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * w


def _split_heads(x, cfg):
    b, s, _ = x.shape
    return x.reshape(b, s, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, s, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * dh)


def forward(params, tokens, cfg: Config, *, use_pallas=True,
            return_cache=False, return_hidden=False, interpret=True):
    """Full causal forward: ``tokens [B, S] i32 -> logits [B, S, V]``.

    ``use_pallas=False`` switches to the jnp oracle attention (training
    path). With ``return_cache=True`` also returns the stacked KV cache
    ``[L, 2, B, S, H, dh]`` for prefill export. With ``return_hidden=True``
    returns the final-norm hidden states ``[B, S, d]`` *instead of* logits —
    the verify graph gathers its K+1 rows first and projects only those
    through the (tied) vocabulary head, skipping ~(S−K)/S of the head
    matmul + softmax (EXPERIMENTS.md §Perf).
    """
    b, s = tokens.shape
    x = jnp.take(params["emb"], tokens, axis=0) + params["pos"][None, :s]
    cache = []
    for l in range(cfg.n_layers):
        h = _rmsnorm(x, params[f"l{l}.ln1"])
        q = _split_heads(h @ params[f"l{l}.wq"], cfg)
        k = _split_heads(h @ params[f"l{l}.wk"], cfg)
        v = _split_heads(h @ params[f"l{l}.wv"], cfg)
        if return_cache:
            cache.append(jnp.stack([k, v]))  # [2, B, H, S, dh]
        if use_pallas:
            att = flash_attention(q, k, v, causal=True, interpret=interpret)
        else:
            att = attention_ref(q, k, v, causal=True)
        x = x + _merge_heads(att) @ params[f"l{l}.wo"]
        hm = _rmsnorm(x, params[f"l{l}.ln2"])
        gate = jax.nn.silu(hm @ params[f"l{l}.w1"])
        x = x + (gate * (hm @ params[f"l{l}.w3"])) @ params[f"l{l}.w2"]
    x = _rmsnorm(x, params["ln_f"])
    if return_hidden:
        return x
    logits = x @ params["emb"].T
    if return_cache:
        # -> [L, 2, B, S, H, dh] (B squeezed by prefill wrapper)
        return logits, jnp.stack(cache).transpose(0, 1, 2, 4, 3, 5)
    return logits


def probs_from_logits(logits, temperature=1.0):
    return jax.nn.softmax(logits / temperature, axis=-1)


def prefill(params, tokens, cfg: Config, *, use_pallas=True, interpret=True):
    """Prompt ingest: ``tokens [1, S] -> (cache [L, 2, S, H, dh], probs [S, V])``."""
    logits, cache = forward(params, tokens, cfg, use_pallas=use_pallas,
                            return_cache=True, interpret=interpret)
    return cache[:, :, 0], probs_from_logits(logits[0])


def decode_step(params, tok, pos, cache, cfg: Config):
    """KV-cached single-token step.

    Args:
      tok:   ``[] i32`` token at sequence index ``pos``.
      pos:   ``[] i32`` current index (< max_seq).
      cache: ``[L, 2, S, H, dh] f32`` KV cache, valid rows ``< pos``.

    Returns ``(probs [V], cache')`` where ``cache'`` has row ``pos`` filled.
    """
    s = cfg.max_seq
    x = jnp.take(params["emb"], tok, axis=0) + jnp.take(params["pos"], pos, axis=0)
    pos_ids = jnp.arange(s)
    for l in range(cfg.n_layers):
        h = _rmsnorm(x, params[f"l{l}.ln1"])
        q = (h @ params[f"l{l}.wq"]).reshape(cfg.n_heads, cfg.d_head)
        k = (h @ params[f"l{l}.wk"]).reshape(cfg.n_heads, cfg.d_head)
        v = (h @ params[f"l{l}.wv"]).reshape(cfg.n_heads, cfg.d_head)
        cache = jax.lax.dynamic_update_slice(
            cache, k[None, None, None], (l, 0, pos, 0, 0))
        cache = jax.lax.dynamic_update_slice(
            cache, v[None, None, None], (l, 1, pos, 0, 0))
        ks = cache[l, 0]  # [S, H, dh]
        vs = cache[l, 1]
        scores = jnp.einsum("hd,shd->hs", q, ks) / math.sqrt(cfg.d_head)
        scores = jnp.where(pos_ids[None, :] <= pos, scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        att = jnp.einsum("hs,shd->hd", w, vs).reshape(cfg.d_model)
        x = x + att @ params[f"l{l}.wo"]
        hm = _rmsnorm(x, params[f"l{l}.ln2"])
        gate = jax.nn.silu(hm @ params[f"l{l}.w1"])
        x = x + (gate * (hm @ params[f"l{l}.w3"])) @ params[f"l{l}.w2"]
    x = _rmsnorm(x, params["ln_f"])
    logits = x @ params["emb"].T
    return probs_from_logits(logits), cache


def verify_graph(params, tokens, draft_tok, q_probs, pos0, cfg: Config, *,
                 use_pallas=True, interpret=True):
    """The verification server's whole per-round compute, one fused graph.

    Args:
      tokens:    ``[B, S] i32`` per-client (prefix ++ draft) right-padded rows.
      draft_tok: ``[B, K] i32`` the drafted token ids (row j = draft pos j).
      q_probs:   ``[B, K, V] f32`` draft proposal distributions.
      pos0:      ``[B] i32`` prefix length of each client (draft row j sits
                 at sequence index ``pos0 + j``).

    Returns:
      ratio ``[B, K]``  — min(1, p/q) at each draft token,
      resid ``[B, K, V]`` — normalized residual distributions,
      bonus ``[B, V]``  — the target's distribution after all K drafts
                          (sampled when every draft is accepted).
    """
    b, s = tokens.shape
    k = draft_tok.shape[1]
    hidden = forward(params, tokens, cfg, use_pallas=use_pallas,
                     return_hidden=True, interpret=interpret)   # [B, S, d]
    # Perf: gather the K+1 needed rows *before* the vocab head — row j
    # (j < K) is the target prob for draft j (sequence index pos0+j, whose
    # distribution lives at pos0+j−1); row K is the bonus distribution.
    rows = pos0[:, None] + jnp.arange(k + 1)[None, :] - 1       # [B, K+1]
    rows = jnp.clip(rows, 0, s - 1)
    hid = jnp.take_along_axis(hidden, rows[:, :, None], axis=1)  # [B, K+1, d]
    logits = hid @ params["emb"].T                               # [B, K+1, V]
    probs = probs_from_logits(logits)
    p_draft = probs[:, :k]                                       # [B, K, V]
    bonus = probs[:, k]                                          # [B, V]
    if use_pallas:
        ratio, resid = verify_ratios(draft_tok, p_draft, q_probs,
                                     interpret=interpret)
    else:
        from .kernels.ref import verify_ref
        ratio, resid = verify_ref(draft_tok, p_draft, q_probs)
    return ratio, resid, bonus


# --------------------------------------------------------------------------
# Model registry (the Table I substitution — see DESIGN.md §2)
# --------------------------------------------------------------------------

MODELS = {
    # "Qwen3" family stand-ins
    "qwen-target":    Config("qwen-target", n_layers=4, d_model=128, n_heads=4, d_ff=256),
    "qwen-draft-06b": Config("qwen-draft-06b", n_layers=1, d_model=64, n_heads=2, d_ff=128),
    "qwen-draft-17b": Config("qwen-draft-17b", n_layers=2, d_model=96, n_heads=3, d_ff=192),
    # "Llama-3" family stand-ins
    "llama-target":    Config("llama-target", n_layers=5, d_model=160, n_heads=5, d_ff=320),
    "llama-draft-1b":  Config("llama-draft-1b", n_layers=2, d_model=64, n_heads=2, d_ff=128),
    "llama-draft-3b":  Config("llama-draft-3b", n_layers=3, d_model=96, n_heads=3, d_ff=192),
}

FAMILIES = {
    "qwen": {"target": "qwen-target",
             "drafts": ["qwen-draft-06b", "qwen-draft-17b"]},
    "llama": {"target": "llama-target",
              "drafts": ["llama-draft-1b", "llama-draft-3b"]},
}

# Verification batch capacity (max clients per round) and max draft length
# (covers every Table I budget C ≤ 28) baked into the verify artifact.
VERIFY_B = 8
VERIFY_K = 32

# Shape buckets for the verify artifact: the coordinator picks the smallest
# (batch, seq) bucket that fits the round — the classic serving-system
# bucketing trick (vLLM/SGLang style) that roughly halves verification time
# for short-prefix rounds on this testbed (EXPERIMENTS.md §Perf).
VERIFY_BUCKETS = [(4, 128), (4, 256), (8, 128), (8, 256)]
