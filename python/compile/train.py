"""Build-time training of the tiny model zoo (DESIGN.md §2 substitution).

Next-byte cross-entropy on the synthetic multi-domain corpus. Targets train
longer than drafts, so drafts are genuinely *weaker but aligned* — exactly
the statistical relationship (heterogeneous, domain-dependent acceptance
rates α_i ∈ (0,1)) that GoodSpeed's scheduler exploits.

Weights are cached in ``artifacts/weights/<model>.npz`` and training is
skipped when the cache exists (``make artifacts`` stays incremental).
Hand-rolled AdamW (no optax dependency in the image's jax install path).
"""

import argparse
import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .model import MODELS, Config, forward, init_params

SEQ = 128
BATCH = 8
# (ce_steps, distill_steps, lr). Targets use pure next-byte CE; drafts add
# a distillation phase against their family target (KL(p_target‖q_draft)
# on corpus windows) — the alignment that makes real draft models (e.g.
# Qwen3-0.6B vs 14B) useful proposals. Bigger drafts distill longer →
# higher acceptance rate, giving the heterogeneity the scheduler exploits.
TRAIN_PLAN = {
    "qwen-target": (500, 0, 3e-3),
    "qwen-draft-06b": (200, 250, 3e-3),
    "qwen-draft-17b": (250, 420, 3e-3),
    "llama-target": (450, 0, 3e-3),
    "llama-draft-1b": (200, 250, 3e-3),
    "llama-draft-3b": (250, 420, 3e-3),
}

# Draft model → family target (distillation teacher).
TEACHERS = {
    "qwen-draft-06b": "qwen-target",
    "qwen-draft-17b": "qwen-target",
    "llama-draft-1b": "llama-target",
    "llama-draft-3b": "llama-target",
}


def _batches(data, rng, batch, seq):
    """Random contiguous windows of the corpus byte array."""
    n = len(data) - seq - 1
    while True:
        idx = rng.integers(0, n, size=(batch,))
        x = np.stack([data[i:i + seq] for i in idx])
        y = np.stack([data[i + 1:i + seq + 1] for i in idx])
        yield jnp.asarray(x, jnp.int32), jnp.asarray(y, jnp.int32)


def loss_fn(params, x, y, cfg: Config):
    logits = forward(params, x, cfg, use_pallas=False)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def adamw_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.float32)}


def adamw_update(params, grads, state, lr, b1=0.9, b2=0.99, eps=1e-8,
                 wd=1e-4):
    t = state["t"] + 1.0
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"],
                     grads)
    mh = jax.tree.map(lambda m_: m_ / (1 - b1 ** t), m)
    vh = jax.tree.map(lambda v_: v_ / (1 - b2 ** t), v)
    new = jax.tree.map(
        lambda p, m_, v_: p - lr * (m_ / (jnp.sqrt(v_) + eps) + wd * p),
        params, mh, vh)
    return new, {"m": m, "v": v, "t": t}


@functools.partial(jax.jit, static_argnames=("cfg", "lr"))
def train_step(params, opt, x, y, cfg: Config, lr: float):
    loss, grads = jax.value_and_grad(loss_fn)(params, x, y, cfg)
    params, opt = adamw_update(params, grads, opt, lr)
    return params, opt, loss


def distill_loss_fn(params, x, teacher_probs, cfg: Config):
    """Cross-entropy against the teacher's full distributions."""
    logq = jax.nn.log_softmax(forward(params, x, cfg, use_pallas=False), -1)
    return -jnp.mean(jnp.sum(teacher_probs * logq, axis=-1))


@functools.partial(jax.jit, static_argnames=("cfg", "lr"))
def distill_step(params, opt, x, teacher_probs, cfg: Config, lr: float):
    loss, grads = jax.value_and_grad(distill_loss_fn)(params, x, teacher_probs, cfg)
    params, opt = adamw_update(params, grads, opt, lr)
    return params, opt, loss


def train_model(name, out_dir, *, seed=0, verbose=True, force=False):
    cfg = MODELS[name]
    path = os.path.join(out_dir, f"{name}.npz")
    if os.path.exists(path) and not force:
        if verbose:
            print(f"[train] {name}: cached at {path}")
        return path
    steps, distill_steps, lr = TRAIN_PLAN[name]
    data = np.frombuffer(corpus.build_corpus(seed=seed), dtype=np.uint8)
    rng = np.random.default_rng(seed + hash(name) % 2**31)
    params = init_params(jax.random.PRNGKey(seed + 1), cfg)
    opt = adamw_init(params)
    gen = _batches(data, rng, BATCH, SEQ)
    t0 = time.time()
    loss = None
    for step in range(steps):
        x, y = next(gen)
        params, opt, loss = train_step(params, opt, x, y, cfg, lr)
        if verbose and (step % 100 == 0 or step == steps - 1):
            print(f"[train] {name} step {step:4d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.1f}s)")
    if distill_steps > 0:
        teacher_name = TEACHERS[name]
        # Teacher must already be trained (aot.py orders targets first).
        teacher_path = train_model(teacher_name, out_dir, seed=seed,
                                   verbose=verbose)
        del teacher_path
        tparams = load_params(teacher_name, out_dir)
        tcfg = MODELS[teacher_name]
        teacher_fwd = jax.jit(
            lambda p, x: jax.nn.softmax(forward(p, x, tcfg, use_pallas=False),
                                        -1))
        opt = adamw_init(params)
        for step in range(distill_steps):
            x, _ = next(gen)
            tp = teacher_fwd(tparams, x)
            params, opt, loss = distill_step(params, opt, x, tp, cfg, lr)
            if verbose and (step % 100 == 0 or step == distill_steps - 1):
                print(f"[distill] {name} step {step:4d} "
                      f"xent {float(loss):.4f} ({time.time() - t0:.1f}s)")
    os.makedirs(out_dir, exist_ok=True)
    np.savez(path, **{k: np.asarray(v) for k, v in params.items()})
    if verbose:
        print(f"[train] {name}: {cfg.param_count()} params, "
              f"final loss {float(loss):.4f} -> {path}")
    return path


def load_params(name, out_dir):
    cfg = MODELS[name]
    with np.load(os.path.join(out_dir, f"{name}.npz")) as z:
        return {k: jnp.asarray(z[k]) for k in cfg.param_names()}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/weights")
    ap.add_argument("--models", nargs="*", default=list(MODELS))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    for name in args.models:
        train_model(name, args.out, force=args.force)


if __name__ == "__main__":
    main()
