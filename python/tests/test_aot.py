"""AOT lowering sanity: HLO text well-formed, parameter ordering stable."""

import re

from compile.aot import lower_prefill, lower_step, lower_verify, to_hlo_text
from compile.model import MODELS, VERIFY_K

CFG = MODELS["qwen-draft-06b"]


def _entry_params(hlo: str):
    """Ordered entry parameter types from the entry_computation_layout."""
    assert "ENTRY" in hlo, "no ENTRY computation in HLO text"
    m = re.search(r"entry_computation_layout=\{\((.*?)\)->", hlo, re.S)
    assert m, "no entry_computation_layout in HLO text"
    sig = re.sub(r"/\*.*?\*/", "", m.group(1))
    return re.findall(r"(?:f32|f16|bf16|s32|u32|s8|u8|pred)\[[0-9,]*\]", sig)


def test_step_hlo_text():
    hlo = to_hlo_text(lower_step(CFG))
    assert "ENTRY" in hlo
    params = _entry_params(hlo)
    # weights + tok + pos + cache
    assert len(params) == len(CFG.param_names()) + 3
    # KV cache param present with the documented shape
    l, s, h, dh = CFG.n_layers, CFG.max_seq, CFG.n_heads, CFG.d_head
    assert f"f32[{l},2,{s},{h},{dh}]" in hlo


def test_prefill_hlo_text():
    hlo = to_hlo_text(lower_prefill(CFG))
    params = _entry_params(hlo)
    assert len(params) == len(CFG.param_names()) + 1
    assert f"s32[1,{CFG.max_seq}]" in hlo


def test_verify_hlo_buckets():
    hlo = to_hlo_text(lower_verify(CFG, 4, 128))
    params = _entry_params(hlo)
    assert len(params) == len(CFG.param_names()) + 4
    assert "s32[4,128]" in hlo
    assert f"f32[4,{VERIFY_K},{CFG.vocab}]" in hlo


def test_param_order_is_weights_then_inputs():
    """The manifest contract: HLO params follow param_names() then inputs."""
    hlo = to_hlo_text(lower_step(CFG))
    params = _entry_params(hlo)
    shapes = CFG.param_shapes()
    for i, name in enumerate(CFG.param_names()):
        dims = ",".join(str(d) for d in shapes[name])
        assert f"f32[{dims}]" in params[i], (i, name, params[i])
    assert "s32[]" in params[len(CFG.param_names())]
