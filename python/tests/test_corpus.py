"""Corpus/domain-generator invariants (the dataset substitution)."""

import random

import pytest

from compile import corpus


def test_domains_complete():
    assert len(corpus.DOMAINS) == 8  # paper uses eight datasets
    assert set(corpus.GENERATORS) == set(corpus.DOMAINS)


def test_deterministic():
    a = corpus.build_corpus(seed=7, docs_per_domain=5)
    b = corpus.build_corpus(seed=7, docs_per_domain=5)
    assert a == b
    c = corpus.build_corpus(seed=8, docs_per_domain=5)
    assert a != c


def test_ascii_only():
    data = corpus.build_corpus(seed=0, docs_per_domain=20)
    assert all(b < 128 for b in data)


@pytest.mark.parametrize("domain", corpus.DOMAINS)
def test_samples_well_formed(domain):
    rng = random.Random(0)
    for _ in range(50):
        prompt, completion = corpus.sample(domain, rng)
        assert 5 <= len(prompt) <= 120
        assert 1 <= len(completion) <= 120
        assert prompt.isascii() and completion.isascii()


def test_gsm8k_answers_correct():
    rng = random.Random(3)
    for _ in range(100):
        prompt, completion = corpus.sample("gsm8k", rng)
        # "q: NAME has A apples and buys B more..." -> " a: ... so A+B apples."
        words = prompt.split()
        a, b = int(words[3]), int(words[7])
        assert f"so {a + b} apples" in completion


def test_spider_sql_matches_prompt():
    rng = random.Random(4)
    for _ in range(50):
        prompt, completion = corpus.sample("spider", rng)
        noun = prompt.split()[3].rstrip("s")
        assert f"from {noun}s" in completion
