"""L1 kernels vs pure-jnp oracles — the core correctness signal.

Hypothesis sweeps shapes/dtypes; every Pallas output must match ``ref.py``
within tight tolerances.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import flash_attention, ref, verify_ratios
from compile.kernels.attention import vmem_bytes

SETTINGS = dict(max_examples=12, deadline=None)


def _rand(rng, shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


# ---------------------------------------------------------------- attention

@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    s_blocks=st.integers(1, 4),
    d=st.sampled_from([8, 16, 32, 64]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(b, h, s_blocks, d, causal, seed):
    s = 64 * s_blocks
    rng = np.random.default_rng(seed)
    q, k, v = (_rand(rng, (b, h, s, d)) for _ in range(3))
    out = flash_attention(q, k, v, causal=causal)
    exp = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, exp, atol=2e-5, rtol=2e-5)


@settings(**SETTINGS)
@given(
    block_q=st.sampled_from([16, 32, 64, 128]),
    block_k=st.sampled_from([16, 32, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_block_shape_invariance(block_q, block_k, seed):
    """Output must not depend on the tiling schedule."""
    rng = np.random.default_rng(seed)
    q, k, v = (_rand(rng, (1, 2, 128, 16)) for _ in range(3))
    out = flash_attention(q, k, v, block_q=block_q, block_k=block_k)
    exp = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(out, exp, atol=2e-5, rtol=2e-5)


def test_attention_bf16_tolerance():
    rng = np.random.default_rng(0)
    q, k, v = (_rand(rng, (1, 2, 64, 32), jnp.bfloat16) for _ in range(3))
    out = flash_attention(q, k, v).astype(jnp.float32)
    exp = ref.attention_ref(q, k, v).astype(jnp.float32)
    np.testing.assert_allclose(out, exp, atol=3e-2, rtol=3e-2)


def test_attention_causality():
    """Future keys must not influence earlier query rows."""
    rng = np.random.default_rng(1)
    q, k, v = (_rand(rng, (1, 1, 128, 16)) for _ in range(3))
    out1 = flash_attention(q, k, v, causal=True)
    k2 = k.at[:, :, 64:].set(999.0)
    v2 = v.at[:, :, 64:].set(-999.0)
    out2 = flash_attention(q, k2, v2, causal=True)
    np.testing.assert_allclose(out1[:, :, :64], out2[:, :, :64],
                               atol=1e-6, rtol=1e-6)


def test_attention_extreme_logits_stable():
    """Online softmax must survive large score magnitudes."""
    rng = np.random.default_rng(2)
    q = _rand(rng, (1, 1, 64, 32), scale=30.0)
    k = _rand(rng, (1, 1, 64, 32), scale=30.0)
    v = _rand(rng, (1, 1, 64, 32))
    out = flash_attention(q, k, v)
    assert bool(jnp.all(jnp.isfinite(out)))
    exp = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(out, exp, atol=1e-4, rtol=1e-4)


def test_attention_rejects_bad_shapes():
    q = jnp.zeros((1, 1, 100, 16))  # 100 not divisible by 64
    with pytest.raises(ValueError):
        flash_attention(q, q, q)
    with pytest.raises(ValueError):
        flash_attention(jnp.zeros((1, 1, 64, 16)), jnp.zeros((1, 2, 64, 16)),
                        jnp.zeros((1, 1, 64, 16)))


def test_vmem_budget_default_blocks():
    """Default tiling must fit TPU VMEM with large headroom (DESIGN.md §8)."""
    assert vmem_bytes(64, 64, 64) < 16 * 1024 * 1024 // 8


# ------------------------------------------------------------------- verify

@settings(**SETTINGS)
@given(
    b=st.integers(1, 8),
    k=st.integers(1, 32),
    v=st.sampled_from([16, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_verify_matches_ref(b, k, v, seed):
    rng = np.random.default_rng(seed)
    p = jax.nn.softmax(_rand(rng, (b, k, v), scale=2.0), axis=-1)
    q = jax.nn.softmax(_rand(rng, (b, k, v), scale=2.0), axis=-1)
    tok = jnp.asarray(rng.integers(0, v, (b, k)), jnp.int32)
    r1, res1 = verify_ratios(tok, p, q)
    r2, res2 = ref.verify_ref(tok, p, q)
    np.testing.assert_allclose(r1, r2, atol=1e-6, rtol=1e-6)
    np.testing.assert_allclose(res1, res2, atol=1e-6, rtol=1e-6)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_verify_invariants(seed):
    """Ratios in [0,1]; residuals are distributions; p==q => ratio 1."""
    rng = np.random.default_rng(seed)
    p = jax.nn.softmax(_rand(rng, (2, 4, 64), scale=3.0), axis=-1)
    q = jax.nn.softmax(_rand(rng, (2, 4, 64), scale=3.0), axis=-1)
    tok = jnp.asarray(rng.integers(0, 64, (2, 4)), jnp.int32)
    ratio, resid = verify_ratios(tok, p, q)
    assert bool(jnp.all((ratio >= 0.0) & (ratio <= 1.0)))
    np.testing.assert_allclose(jnp.sum(resid, -1), 1.0, atol=1e-5)
    assert bool(jnp.all(resid >= 0.0))
    r_eq, res_eq = verify_ratios(tok, p, p)
    np.testing.assert_allclose(r_eq, 1.0, atol=1e-6)
    # Empty residual (p == q) falls back to p.
    np.testing.assert_allclose(res_eq, p, atol=1e-6)


def test_verify_residual_zeroes_draft_support():
    """Residual mass only where p > q (rejection-sampling correctness)."""
    p = jnp.asarray([[[0.7, 0.2, 0.1]]], jnp.float32)
    q = jnp.asarray([[[0.1, 0.6, 0.3]]], jnp.float32)
    tok = jnp.asarray([[1]], jnp.int32)
    ratio, resid = verify_ratios(tok, p, q)
    np.testing.assert_allclose(ratio[0, 0], 0.2 / 0.6, atol=1e-6)
    np.testing.assert_allclose(resid[0, 0], [1.0, 0.0, 0.0], atol=1e-6)
