"""L2 model graph invariants: causality, prefill/step/verify consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.model import (FAMILIES, MODELS, VERIFY_K, Config, decode_step,
                           forward, init_params, prefill, probs_from_logits,
                           unflatten_params, flatten_params, verify_graph)

CFG = MODELS["qwen-draft-06b"]  # smallest: fastest to test
PARAMS = init_params(jax.random.PRNGKey(0), CFG)


def _tokens(rng, b, n, s=None):
    s = s or CFG.max_seq
    t = np.zeros((b, s), np.int32)
    for i in range(b):
        t[i, :n] = rng.integers(32, 120, n)
    return jnp.asarray(t)


def test_param_flattening_roundtrip():
    flat = flatten_params(PARAMS, CFG)
    back = unflatten_params(flat, CFG)
    assert set(back) == set(PARAMS)
    for k in PARAMS:
        assert back[k] is PARAMS[k]


def test_param_shapes_consistent():
    for name, cfg in MODELS.items():
        shapes = cfg.param_shapes()
        assert list(shapes) == cfg.param_names() or set(shapes) == set(
            cfg.param_names())
        assert cfg.param_count() == sum(
            int(np.prod(s)) for s in shapes.values())


def test_forward_shapes():
    rng = np.random.default_rng(0)
    logits = forward(PARAMS, _tokens(rng, 2, 10), CFG, use_pallas=False)
    assert logits.shape == (2, CFG.max_seq, CFG.vocab)


def test_forward_pallas_matches_ref_attention():
    """The exported (pallas) graph equals the training (jnp) graph."""
    rng = np.random.default_rng(1)
    toks = _tokens(rng, 2, 40)
    a = forward(PARAMS, toks, CFG, use_pallas=True)
    b = forward(PARAMS, toks, CFG, use_pallas=False)
    np.testing.assert_allclose(a, b, atol=5e-4, rtol=5e-4)


def test_causality():
    """Changing token t must not change logits at positions < t."""
    rng = np.random.default_rng(2)
    toks = _tokens(rng, 1, 50)
    base = forward(PARAMS, toks, CFG, use_pallas=False)
    mut = toks.at[0, 30].set(77)
    out = forward(PARAMS, mut, CFG, use_pallas=False)
    np.testing.assert_allclose(base[0, :29], out[0, :29], atol=1e-5)
    assert float(jnp.max(jnp.abs(base[0, 30:50] - out[0, 30:50]))) > 1e-6


@settings(max_examples=5, deadline=None)
@given(plen=st.integers(2, 60), nsteps=st.integers(1, 4),
       seed=st.integers(0, 2**31 - 1))
def test_prefill_then_steps_equals_full_forward(plen, nsteps, seed):
    """KV-cached decode must reproduce the full-forward distribution."""
    rng = np.random.default_rng(seed)
    toks = np.asarray(_tokens(rng, 1, plen))
    cache, probs = prefill(PARAMS, jnp.asarray(toks), CFG, use_pallas=False)
    nxt = int(jnp.argmax(probs[plen - 1]))
    pos = plen
    seq = toks.copy()
    pr = None
    for _ in range(nsteps):
        seq[0, pos] = nxt
        pr, cache = decode_step(PARAMS, jnp.int32(nxt), jnp.int32(pos),
                                cache, CFG)
        pos += 1
        nxt = int(jnp.argmax(pr))
    full = probs_from_logits(
        forward(PARAMS, jnp.asarray(seq), CFG, use_pallas=False))
    np.testing.assert_allclose(pr, full[0, pos - 1], atol=1e-4, rtol=1e-3)


def test_verify_graph_matches_manual_pipeline():
    tcfg = MODELS["qwen-target"]
    tparams = init_params(jax.random.PRNGKey(3), tcfg)
    rng = np.random.default_rng(3)
    B, K, V, S = 4, VERIFY_K, tcfg.vocab, 128
    toks = np.zeros((B, S), np.int32)
    pos0 = np.zeros(B, np.int32)
    dtok = np.zeros((B, K), np.int32)
    qp = np.asarray(jax.nn.softmax(
        jnp.asarray(rng.standard_normal((B, K, V)), jnp.float32), -1))
    for b in range(B):
        n = rng.integers(5, 40)
        pos0[b] = n
        toks[b, :n + K] = rng.integers(32, 120, n + K)
        dtok[b] = toks[b, n:n + K]
    ratio, resid, bonus = verify_graph(
        tparams, jnp.asarray(toks), jnp.asarray(dtok), jnp.asarray(qp),
        jnp.asarray(pos0), tcfg, use_pallas=True)
    # manual: full forward, gather, ratio
    probs = probs_from_logits(
        forward(tparams, jnp.asarray(toks), tcfg, use_pallas=False))
    for b in [0, B - 1]:
        n = int(pos0[b])
        for j in [0, K - 1]:
            pt = float(probs[b, n + j - 1, dtok[b, j]])
            qt = float(qp[b, j, dtok[b, j]])
            exp = min(1.0, pt / max(qt, 1e-9))
            np.testing.assert_allclose(float(ratio[b, j]), exp, atol=5e-4,
                                       rtol=5e-3)
        np.testing.assert_allclose(bonus[b], probs[b, n + K - 1], atol=5e-4)
    np.testing.assert_allclose(jnp.sum(resid, -1), 1.0, atol=1e-4)


def test_verify_graph_bucket_shapes():
    """Verify graph works at every (B, S) bucket the manifest exports."""
    from compile.model import VERIFY_BUCKETS
    tcfg = MODELS["qwen-draft-06b"]  # cheap stand-in, same graph code
    tparams = PARAMS
    rng = np.random.default_rng(4)
    for b, s in VERIFY_BUCKETS:
        toks = _tokens(rng, b, 20, s)
        dtok = jnp.asarray(rng.integers(32, 120, (b, VERIFY_K)), jnp.int32)
        qp = jnp.full((b, VERIFY_K, tcfg.vocab), 1.0 / tcfg.vocab, jnp.float32)
        pos0 = jnp.full((b,), 10, jnp.int32)
        ratio, resid, bonus = verify_graph(tparams, toks, dtok, qp, pos0,
                                           tcfg, use_pallas=False)
        assert ratio.shape == (b, VERIFY_K)
        assert resid.shape == (b, VERIFY_K, tcfg.vocab)
        assert bonus.shape == (b, tcfg.vocab)


def test_config_validation():
    with pytest.raises(ValueError):
        Config("bad", n_layers=1, d_model=100, n_heads=3, d_ff=64)
