"""Training-loop smoke tests (build-time substrate)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import corpus
from compile.model import MODELS, init_params
from compile.train import (TRAIN_PLAN, adamw_init, adamw_update, loss_fn,
                           train_step)

CFG = MODELS["qwen-draft-06b"]


def test_plan_covers_all_models():
    assert set(TRAIN_PLAN) == set(MODELS)
    for name, (steps, distill_steps, lr) in TRAIN_PLAN.items():
        assert steps > 0 and 0 < lr < 1
        assert distill_steps >= 0
    # drafts must CE-train strictly less than their family target…
    assert TRAIN_PLAN["qwen-draft-06b"][0] < TRAIN_PLAN["qwen-target"][0]
    assert TRAIN_PLAN["llama-draft-1b"][0] < TRAIN_PLAN["llama-target"][0]
    # …targets never distill, drafts always do
    from compile.train import TEACHERS
    for name, (_, distill_steps, _) in TRAIN_PLAN.items():
        if "target" in name:
            assert distill_steps == 0
        else:
            assert distill_steps > 0
            assert TEACHERS[name] in MODELS
    # bigger drafts distill longer (higher α by construction)
    assert TRAIN_PLAN["qwen-draft-17b"][1] > TRAIN_PLAN["qwen-draft-06b"][1]


def test_distill_step_reduces_teacher_xent():
    import jax.numpy as jnp
    from compile.train import distill_step, distill_loss_fn
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.integers(32, 120, (4, 64)), jnp.int32)
    # synthetic "teacher": peaked distributions
    t = jax.nn.softmax(
        jnp.asarray(rng.standard_normal((4, 64, 256)) * 4.0, jnp.float32), -1)
    params = init_params(jax.random.PRNGKey(2), CFG)
    opt = adamw_init(params)
    first = float(distill_loss_fn(params, x, t, CFG))
    loss = None
    for _ in range(25):
        params, opt, loss = distill_step(params, opt, x, t, CFG, 3e-3)
    assert float(loss) < first - 0.3, (first, float(loss))


def test_loss_decreases_on_fixed_batch():
    data = np.frombuffer(corpus.build_corpus(seed=0, docs_per_domain=10),
                         dtype=np.uint8)
    x = jnp.asarray(data[:4 * 64].reshape(4, 64), jnp.int32)
    y = jnp.asarray(data[1:4 * 64 + 1].reshape(4, 64), jnp.int32)
    params = init_params(jax.random.PRNGKey(0), CFG)
    opt = adamw_init(params)
    first = float(loss_fn(params, x, y, CFG))
    loss = None
    for _ in range(30):
        params, opt, loss = train_step(params, opt, x, y, CFG, 3e-3)
    assert float(loss) < first - 0.5, (first, float(loss))


def test_adamw_moves_toward_gradient():
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.asarray([1.0, -1.0, 0.0, 2.0])}
    state = adamw_init(params)
    new, state = adamw_update(params, grads, state, lr=0.1, wd=0.0)
    step = np.asarray(new["w"] - params["w"])
    assert step[0] < 0 and step[1] > 0 and abs(step[2]) < 1e-6 and step[3] < 0


def test_initial_loss_near_uniform():
    params = init_params(jax.random.PRNGKey(1), CFG)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 255, (2, 32)), jnp.int32)
    y = jnp.asarray(rng.integers(0, 255, (2, 32)), jnp.int32)
    loss = float(loss_fn(params, x, y, CFG))
    assert abs(loss - np.log(256)) < 1.5
