//! Ablation benches: η/β sensitivity, capacity sweep, greedy-vs-DP
//! scheduler timing, and the log-vs-linear utility contrast. Writes
//! `results/ablation_*.csv`.

use goodspeed::cli::Args;
use goodspeed::experiments::ablation;

mod common;

fn main() {
    goodspeed::util::logger::init();
    let args = Args::parse(vec![
        "ablation".to_string(),
        "--rounds".into(),
        common::rounds(60, 600).to_string(),
        "--out".into(),
        "results".into(),
    ]);
    if let Err(e) = ablation::main(&args) {
        eprintln!("ablation bench failed: {e:#}");
        std::process::exit(1);
    }
}
