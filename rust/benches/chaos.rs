//! Chaos benchmark: goodput-recovery envelopes under a shard crash.
//!
//! Runs the `chaos` preset shape — the highest verifier shard crashes a
//! third of the way in and is re-admitted at the halfway mark — through
//! both the live serving cluster (session API, mock engine) and the
//! analytic simulator on the *same* fault schedule and wave clock, and
//! checks:
//!
//! * the live cluster survives: the global stop never latches on the
//!   dead shard, the crashed shard's clients migrate to the survivor and
//!   keep serving, and the full verification budget is delivered;
//! * both paths log the crash→recover lifecycle with the same
//!   time-to-recover;
//! * the analytic per-sweep token series re-enters a band around the
//!   pre-fault steady state (goodput ≥ 75%, Jain ≥ 90%) after the crash
//!   and again after the heal — the recovery envelope;
//! * live and analytic steady-state goodput-per-verdict agree.
//!
//!     cargo bench --bench chaos [-- --quick]

use goodspeed::chaos::{FaultEvent, FaultKind, FaultSchedule};
use goodspeed::configsys::{Policy, Scenario};
use goodspeed::coordinator::Transport;
use goodspeed::experiments::{mock_engine, serve_once};
use goodspeed::simulate::analytic::run_sharded;
use goodspeed::util::stats::jain_index;

mod common;

/// The chaos shape scaled to `rounds`: crash shard 1 at rounds/3,
/// re-admit at rounds/2 (the preset's schedule, re-timed; see
/// `FaultSchedule::demo` for why recovery sits at the halfway mark).
fn scenario(rounds: u64) -> Scenario {
    let mut s = Scenario::preset("chaos").expect("preset");
    s.rounds = rounds;
    s.chaos = FaultSchedule {
        events: vec![FaultEvent {
            at_wave: rounds / 3,
            kind: FaultKind::ShardCrash {
                shard: s.num_verifiers - 1,
                recover_wave: Some(rounds / 2),
            },
        }],
    };
    s
}

/// Mean aggregate tokens per sweep and Jain's index over per-client
/// token totals, over the sweep window `[lo, hi)`.
fn window_stats(series: &[Vec<u64>], lo: usize, hi: usize) -> (f64, f64) {
    let hi = hi.min(series.len());
    if lo >= hi {
        return (0.0, 0.0);
    }
    let slots = series[0].len();
    let mut per = vec![0.0f64; slots];
    for row in &series[lo..hi] {
        for (i, &g) in row.iter().enumerate() {
            per[i] += g as f64;
        }
    }
    let total: f64 = per.iter().sum();
    (total / (hi - lo) as f64, jain_index(&per))
}

/// Sweeps after `from` until a `w`-wide window re-enters the band around
/// the pre-fault steady state (goodput ≥ 75%, Jain ≥ 90%); `None` if the
/// series ends first.
fn reentry(series: &[Vec<u64>], from: usize, w: usize, g_pre: f64, j_pre: f64) -> Option<usize> {
    let mut t = from;
    while t + w <= series.len() {
        let (g, j) = window_stats(series, t, t + w);
        if g >= 0.75 * g_pre && j >= 0.90 * j_pre {
            return Some(t - from);
        }
        t += 1;
    }
    None
}

fn main() {
    goodspeed::util::logger::init();
    let rounds = common::rounds(90, 180);
    let s = scenario(rounds);
    let (crash, recover) = (rounds / 3, rounds / 2);
    let victim = s.num_verifiers - 1;
    println!(
        "== chaos bench: {} clients / {} shards, crash shard {victim} @{crash}, \
         recover @{recover}  ({rounds} waves) ==",
        s.num_clients, s.num_verifiers
    );

    // Live survival: the pool must absorb the crash without latching the
    // global stop — budget delivered, every client served, lifecycle
    // logged with the schedule's exact time-to-recover.
    let live = serve_once(
        s.clone(),
        Policy::GoodSpeed,
        Transport::Channel,
        false,
        mock_engine(),
    )
    .expect("live chaos run");
    let part = live.recorder.participation().to_vec();
    let delivered: u64 = part.iter().sum();
    assert!(
        delivered >= rounds * s.num_clients as u64,
        "budget not delivered: {delivered} verdicts"
    );
    assert!(part.iter().all(|&p| p > 0), "every client must keep serving: {part:?}");
    let kinds: Vec<&str> = live.recorder.faults.iter().map(|f| f.kind.as_str()).collect();
    assert!(
        kinds.contains(&"shard-crash") && kinds.contains(&"shard-recover"),
        "live fault log must carry the crash lifecycle: {kinds:?}"
    );
    assert_eq!(live.recorder.time_to_recover, vec![recover - crash]);
    let pool = live.pool.as_ref().expect("chaos preset runs the sharded pool");
    assert!(pool.migrations >= 1, "the crash must migrate clients to the survivor");
    for f in &live.recorder.faults {
        println!("  wave {:>4} shard {}: {:<13} {}", f.wave, f.shard, f.kind, f.detail);
    }
    println!(
        "  live: {delivered} verdicts, {} migrations, time-to-recover {:?} waves",
        pool.migrations, live.recorder.time_to_recover
    );

    // Analytic mirror: same schedule, same pooled clock.
    let out = run_sharded(&s, Policy::GoodSpeed);
    let sim_kinds: Vec<String> = out.faults().iter().map(|f| f.kind.clone()).collect();
    assert!(
        sim_kinds.iter().any(|k| k == "shard-crash")
            && sim_kinds.iter().any(|k| k == "shard-recover"),
        "analytic fault log must carry the crash lifecycle: {sim_kinds:?}"
    );
    assert_eq!(out.time_to_recover(), vec![recover - crash]);

    // Recovery envelope over the analytic per-sweep token series. Sweep
    // indices: the pooled clock advances one wave per sweep while all M
    // shards are live, but only (M−1)/M as fast while one is fenced, so
    // the heal lands at crash + M·(recover − crash)/(M−1) sweeps.
    let m = s.num_verifiers as u64;
    let crash_sweep = crash as usize;
    let recover_sweep = (crash + (recover - crash) * m / (m - 1)) as usize;
    let w = ((rounds / 8) as usize).max(8);
    let series = &out.wave_tokens;
    assert!(
        series.len() >= recover_sweep + w,
        "series too short to window the heal: {} sweeps",
        series.len()
    );
    let (g_pre, j_pre) = window_stats(series, crash_sweep.saturating_sub(w), crash_sweep);
    println!(
        "\npre-fault steady state (window {w}): goodput {g_pre:.1} tokens/sweep, \
         jain {j_pre:.4}"
    );
    let after_crash = reentry(series, crash_sweep, w, g_pre, j_pre);
    let after_heal = reentry(series, recover_sweep, w, g_pre, j_pre);
    assert!(
        after_crash.is_some(),
        "goodput/fairness never re-entered the band after the crash"
    );
    assert!(
        after_heal.is_some(),
        "goodput/fairness never re-entered the band after the heal"
    );
    let (dc, dh) = (after_crash.unwrap(), after_heal.unwrap());
    println!(
        "recovery envelope: band re-entered {dc} sweeps after the crash, \
         {dh} sweeps after the heal (bound 3W = {})",
        3 * w
    );

    // Cross-check: steady-state tokens per verdict, live vs analytic.
    let live_tokens: f64 = live.recorder.cum_goodput().iter().sum();
    let live_gpv = live_tokens / delivered as f64;
    let sim_gpv = out.goodput_per_verdict();
    let gap = (live_gpv - sim_gpv).abs() / sim_gpv.max(1e-12);
    println!(
        "goodput/verdict: live {live_gpv:.3}  analytic {sim_gpv:.3}  gap {:.1}%",
        100.0 * gap
    );
    assert!(gap <= 0.35, "live and analytic goodput/verdict diverged: {gap:.3}");

    let envelope_ok = dc <= 3 * w && dh <= 3 * w;
    if envelope_ok && gap <= 0.25 {
        println!(
            "PASS: cluster survived the crash, recovery envelope within 3W on both \
             edges, live≈analytic within 25%"
        );
    } else {
        println!(
            "WARN: expected band re-entry within 3W={} sweeps (crash {dc}, heal {dh}) \
             and live≈analytic within 25% (gap {:.1}%)",
            3 * w,
            100.0 * gap
        );
    }
}
