//! Churn benchmark: fairness recovery under dynamic client membership.
//!
//! Runs the `churn` preset shape — one client joining a third of the way
//! in, one resident departing at the two-thirds mark — through both the
//! live serving cluster (session API, mock engine) and the analytic
//! simulator, and checks:
//!
//! * the joiner converges to its fair share: its relative share of the
//!   population's per-wave goodput matches the analytic sim within 10%;
//! * Jain's index over the surviving clients recovers after the
//!   departure (the freed budget water-fills over the survivors).
//!
//!     cargo bench --bench churn [-- --quick]

use goodspeed::configsys::{
    ChurnEvent, ChurnKind, ChurnSchedule, ClientSpec, Policy, Scenario,
};
use goodspeed::coordinator::Transport;
use goodspeed::experiments::{mock_engine, serve_once};
use goodspeed::metrics::recorder::Recorder;
use goodspeed::simulate::analytic::AnalyticSim;
use goodspeed::util::stats::jain_index;

mod common;

/// The churn shape scaled to `rounds`: join at rounds/3, leave client 1 at
/// 2·rounds/3 (the preset's schedule, re-timed).
fn scenario(rounds: u64) -> Scenario {
    let mut s = Scenario::preset("churn").expect("preset");
    s.rounds = rounds;
    s.churn = ChurnSchedule {
        events: vec![
            ChurnEvent {
                at_wave: rounds / 3,
                kind: ChurnKind::Join(ClientSpec::new("qwen-draft-06b", "cnn")),
            },
            ChurnEvent { at_wave: 2 * rounds / 3, kind: ChurnKind::Leave(1) },
        ],
    };
    s
}

/// Per-client mean goodput over the waves in `[lo, hi)`, restricted to
/// `clients`; `None` when a client never participated in the window.
fn window_goodput(rec: &Recorder, lo: u64, hi: u64, clients: &[usize]) -> Vec<Option<f64>> {
    let mut sum = vec![0.0f64; rec.n_clients()];
    let mut cnt = vec![0u64; rec.n_clients()];
    for r in rec.rounds.iter().filter(|r| r.round >= lo && r.round < hi) {
        for c in &r.clients {
            sum[c.client_id] += c.goodput as f64;
            cnt[c.client_id] += 1;
        }
    }
    clients
        .iter()
        .map(|&i| if cnt[i] == 0 { None } else { Some(sum[i] / cnt[i] as f64) })
        .collect()
}

/// The joiner's share relative to the always-present clients' mean, over
/// the post-join steady state (skipping a warm-up third of its lifetime).
fn joiner_relative_share(rec: &Recorder, rounds: u64, joiner: usize) -> f64 {
    let join_at = rounds / 3;
    let lo = join_at + (rounds - join_at) / 3;
    let stayers = [0usize, 2, 3];
    let g = window_goodput(rec, lo, rounds, &[joiner, stayers[0], stayers[1], stayers[2]]);
    let joiner_g = g[0].unwrap_or(0.0);
    let stay_mean: f64 =
        g[1..].iter().map(|x| x.unwrap_or(0.0)).sum::<f64>() / stayers.len() as f64;
    joiner_g / stay_mean.max(1e-12)
}

/// Jain over the surviving clients in a wave window.
fn window_jain(rec: &Recorder, lo: u64, hi: u64, clients: &[usize]) -> f64 {
    let g: Vec<f64> = window_goodput(rec, lo, hi, clients)
        .into_iter()
        .map(|x| x.unwrap_or(0.0))
        .collect();
    jain_index(&g)
}

fn main() {
    goodspeed::util::logger::init();
    let rounds = common::rounds(90, 240);
    let s = scenario(rounds);
    let joiner = s.num_clients; // first fresh slot
    println!(
        "== churn bench: {} residents, join@{} leave(1)@{}  ({rounds} waves) ==",
        s.num_clients,
        rounds / 3,
        2 * rounds / 3
    );

    let live = serve_once(
        s.clone(),
        Policy::GoodSpeed,
        Transport::Channel,
        false,
        mock_engine(),
    )
    .expect("live churn run");
    let mut sim = AnalyticSim::from_scenario(&s, Policy::GoodSpeed);
    sim.run();

    println!("membership epochs (live): {}", live.recorder.membership.len());
    for ev in &live.recorder.membership {
        println!(
            "  wave {:>4} epoch {:>2}: joined {:?} left {:?} -> members {:?}",
            ev.wave, ev.epoch, ev.joined, ev.left, ev.members
        );
    }

    // 1. Joiner fair-share convergence, live vs analytic.
    let live_rel = joiner_relative_share(&live.recorder, rounds, joiner);
    let sim_rel = joiner_relative_share(sim.recorder(), rounds, joiner);
    println!(
        "\njoiner relative share (joiner / resident mean, post-join steady state):\n\
         live {live_rel:.3}   analytic {sim_rel:.3}   gap {:+.1}%",
        100.0 * (live_rel - sim_rel) / sim_rel.max(1e-12)
    );

    // 2. Jain recovery after the departure, over the surviving clients.
    let leave_at = 2 * rounds / 3;
    let survivors = [0usize, 2, 3, joiner];
    let w = (rounds / 6).max(10);
    let jain_pre = window_jain(&live.recorder, leave_at.saturating_sub(w), leave_at, &survivors);
    let recovery = (rounds - leave_at) / 3;
    let jain_post = window_jain(&live.recorder, leave_at + recovery, rounds, &survivors);
    let sim_post = window_jain(sim.recorder(), leave_at + recovery, rounds, &survivors);
    println!(
        "jain over survivors: pre-leave {jain_pre:.4}   post-leave {jain_post:.4} \
         (analytic post {sim_post:.4})"
    );

    let share_ok = (live_rel - sim_rel).abs() <= 0.10 * sim_rel.max(1e-12);
    let jain_ok = jain_post >= 0.95 * jain_pre && jain_post >= 0.90;
    if share_ok && jain_ok {
        println!(
            "PASS: joiner within 10% of its analytic fair share, fairness recovers \
             after the departure"
        );
    } else {
        println!(
            "WARN: expected joiner share live≈analytic within 10% \
             (live {live_rel:.3} vs sim {sim_rel:.3}) and post-leave Jain ≥ max(0.90, \
             0.95·pre) (pre {jain_pre:.4}, post {jain_post:.4})"
        );
    }
}
