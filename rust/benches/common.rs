//! Shared bench-harness preamble.
//!
//! Every bench accepts `-- --quick` (the CI smoke shape: fewer rounds,
//! same comparisons and assertions). This helper is the one copy of that
//! argv convention; bench binaries include it with `mod common;`.

/// Run length for this invocation: `quick` rounds when `--quick` is on
/// the command line (the CI smoke), `full` otherwise.
pub fn rounds(quick: u64, full: u64) -> u64 {
    if std::env::args().any(|a| a == "--quick") {
        quick
    } else {
        full
    }
}
