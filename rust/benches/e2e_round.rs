//! End-to-end round benchmark: full Algorithm 1 rounds through the
//! coordinator + draft actors on the mock engine (isolates L3 coordination
//! cost from XLA compute) over both transports.

use std::time::Instant;

use goodspeed::configsys::{Policy, Scenario};
use goodspeed::coordinator::Transport;
use goodspeed::experiments::{mock_engine, serve_once};

mod common;

fn run(transport: Transport, clients: usize, rounds: u64, network: bool) -> (f64, f64) {
    let mut s = Scenario::preset("qwen-8c-150").unwrap();
    s.num_clients = clients;
    s.rounds = rounds;
    s.links = Scenario::default_links(clients, s.seed);
    let t0 = Instant::now();
    let out =
        serve_once(s, Policy::GoodSpeed, transport, network, mock_engine()).expect("run");
    let wall = t0.elapsed().as_secs_f64();
    (wall / rounds as f64 * 1e3, out.summary.total_tokens / wall)
}

fn main() {
    println!("== e2e round bench (mock engine: pure L3 coordination) ==");
    println!(
        "{:<9} {:>8} {:>8} {:>12} {:>12}",
        "transport", "clients", "netsim", "ms/round", "tok/s"
    );
    let rounds = common::rounds(15, 150);
    for (transport, name) in [(Transport::Channel, "channel"), (Transport::Tcp, "tcp")] {
        for clients in [2usize, 8] {
            for network in [false, true] {
                let (ms, tps) = run(transport, clients, rounds, network);
                println!(
                    "{name:<9} {clients:>8} {:>8} {ms:>12.3} {tps:>12.0}",
                    if network { "on" } else { "off" }
                );
            }
        }
    }
}
