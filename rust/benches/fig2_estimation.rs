//! Fig 2 regeneration bench: goodput-estimation fidelity over the full
//! stack. Writes `results/fig2_*.csv` + `.svg` and prints the alignment
//! metrics (mean |est − real| and ±1σ band coverage).
//!
//! Engine: XLA when artifacts exist, mock otherwise. Override rounds with
//! GOODSPEED_BENCH_ROUNDS.

use goodspeed::cli::Args;
use goodspeed::experiments::fig2;

mod common;

fn main() {
    goodspeed::util::logger::init();
    let rounds = std::env::var("GOODSPEED_BENCH_ROUNDS")
        .ok()
        .unwrap_or_else(|| common::rounds(20, 100).to_string());
    let args = Args::parse(vec![
        "fig2".to_string(),
        "--rounds".into(),
        rounds,
        "--out".into(),
        "results".into(),
    ]);
    if let Err(e) = fig2::main(&args) {
        eprintln!("fig2 bench failed: {e:#}");
        std::process::exit(1);
    }
}
