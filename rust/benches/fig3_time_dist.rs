//! Fig 3 regeneration bench: receive/verify/send wall-time decomposition
//! for GoodSpeed vs Fixed-S vs Random-S on both families, with the
//! simulated edge network on. Writes `results/fig3_time_distribution.csv`.

use goodspeed::cli::Args;
use goodspeed::experiments::fig3;

mod common;

fn main() {
    goodspeed::util::logger::init();
    let rounds = std::env::var("GOODSPEED_BENCH_ROUNDS")
        .ok()
        .unwrap_or_else(|| common::rounds(10, 50).to_string());
    let args = Args::parse(vec![
        "fig3".to_string(),
        "--rounds".into(),
        rounds,
        "--out".into(),
        "results".into(),
    ]);
    if let Err(e) = fig3::main(&args) {
        eprintln!("fig3 bench failed: {e:#}");
        std::process::exit(1);
    }
}
