//! Fig 4 regeneration bench: U(x̄(T)) convergence over 600 iterations for
//! all policies × families × client counts (analytic simulator — the same
//! estimators/scheduler code as the real stack). Writes
//! `results/fig4_convergence.csv` and per-panel SVGs.

use goodspeed::cli::Args;
use goodspeed::experiments::fig4;

mod common;

fn main() {
    goodspeed::util::logger::init();
    let args = Args::parse(vec![
        "fig4".to_string(),
        "--rounds".into(),
        common::rounds(60, 600).to_string(),
        "--out".into(),
        "results".into(),
    ]);
    if let Err(e) = fig4::main(&args) {
        eprintln!("fig4 bench failed: {e:#}");
        std::process::exit(1);
    }
}
