//! Theorem 1 validation bench: β-sweep of the stationary distance
//! ‖X^β − x*‖ (must shrink as β → 0) plus fluid-path attraction checks.
//! Writes `results/fluid_beta_sweep.csv`.

use goodspeed::cli::Args;
use goodspeed::experiments::fluid_exp;

mod common;

fn main() {
    goodspeed::util::logger::init();
    let args = Args::parse(vec![
        "fluid".to_string(),
        "--rounds".into(),
        common::rounds(400, 4000).to_string(),
        "--out".into(),
        "results".into(),
    ]);
    if let Err(e) = fluid_exp::main(&args) {
        eprintln!("fluid bench failed: {e:#}");
        std::process::exit(1);
    }
}
