//! Runtime-layer benchmark: PJRT artifact compile time and per-call
//! latency of every exported graph — draft prefill, KV-cached draft step,
//! and the bucketed verification forward. This is the layer the paper's
//! "verification time" (Fig 3) lives in; the bucket rows quantify the
//! shape-bucketing optimization (EXPERIMENTS.md §Perf).
//!
//! Skips cleanly when artifacts are absent.

use std::time::Instant;

use goodspeed::runtime::engine::{EngineFactory, VerifyRequest};
use goodspeed::runtime::{default_artifacts_dir, Manifest, XlaEngineFactory};

fn main() -> anyhow::Result<()> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("runtime_xla: artifacts missing (run `make artifacts`) — skipping");
        return Ok(());
    }
    let manifest = Manifest::load(&dir)?;
    let f = XlaEngineFactory::new(manifest);
    println!("== XLA runtime bench (CPU PJRT) ==");

    for model in ["qwen-draft-06b", "qwen-draft-17b", "qwen-target"] {
        let t0 = Instant::now();
        let mut d = f.make_drafter(model)?;
        let setup = t0.elapsed().as_secs_f64();
        let prompt = goodspeed::tokenizer::encode(
            "### Instruction: describe the garden. ### Response:",
        );
        let t1 = Instant::now();
        let _ = d.prefill(&prompt)?;
        let mut dist;
        let prefill_ms = t1.elapsed().as_secs_f64() * 1e3;
        let reps = 40;
        let t2 = Instant::now();
        let mut tok = b' ';
        for _ in 0..reps {
            dist = d.step(tok)?;
            tok = dist
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0 as u8;
        }
        let step_ms = t2.elapsed().as_secs_f64() * 1e3 / reps as f64;
        println!(
            "{model:<16} setup {setup:>6.2}s  prefill {prefill_ms:>7.2}ms  step {step_ms:>6.2}ms/tok"
        );
    }

    println!("\n-- verify buckets (batch fwd + fused ratio/residual kernel) --");
    let mut ver = f.make_verifier("qwen")?;
    let k = f.verify_k();
    let v = f.vocab();
    for (b, s) in ver.buckets() {
        let req = VerifyRequest {
            tokens: vec![65i32; b * s],
            batch: b,
            seq: s,
            draft_tok: vec![65i32; b * k],
            q_probs: vec![1.0 / v as f32; b * k * v],
            pos0: vec![40i32; b],
            parent: goodspeed::runtime::chain_parent_array(b, k),
            k,
            vocab: v,
        };
        let t0 = Instant::now();
        ver.verify(&req)?; // includes lazy compile
        let first_ms = t0.elapsed().as_secs_f64() * 1e3;
        let reps = 6;
        let t1 = Instant::now();
        for _ in 0..reps {
            ver.verify(&req)?;
        }
        let ms = t1.elapsed().as_secs_f64() * 1e3 / reps as f64;
        println!(
            "verify qwen b={b} s={s:<4} compile+first {first_ms:>8.1}ms  steady {ms:>8.1}ms  ({:.1} tok verified/s)",
            (b * k) as f64 / (ms / 1e3)
        );
    }
    Ok(())
}
