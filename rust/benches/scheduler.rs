//! Microbenchmark: the gradient scheduler hot path (GOODSPEED-SCHED is
//! solved once per round on the verification server — it must be invisible
//! next to the verification forward).
//!
//! Reports greedy-solver ns/op across (N, C) sizes, the exact-DP oracle
//! for contrast, and estimator-update ns/op.

use std::time::Instant;

use goodspeed::configsys::Smoothing;
use goodspeed::sched::gradient::{objective, solve_dp, solve_greedy, AllocInput};
use goodspeed::sched::Estimators;
use goodspeed::util::Rng;

mod common;

fn bench<F: FnMut()>(label: &str, iters: u64, mut f: F) -> f64 {
    // warmup
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let ns = t0.elapsed().as_nanos() as f64 / iters as f64;
    println!("{label:<44} {ns:>12.0} ns/op");
    ns
}

fn main() {
    println!("== scheduler microbench ==");
    // `--quick` scales every iteration count down 10× (same sizes, same
    // greedy-vs-DP assertions).
    let scale = common::rounds(1, 10);
    let mut rng = Rng::new(1);
    for (n, c) in [(4usize, 24usize), (8, 20), (8, 28), (64, 256), (256, 1024), (1024, 4096)] {
        let weights: Vec<f64> = (0..n).map(|_| rng.f64() + 0.05).collect();
        let alphas: Vec<f64> = (0..n).map(|_| rng.f64() * 0.95).collect();
        let caps = vec![32usize; n];
        let input =
            AllocInput { weights: &weights, alphas: &alphas, capacity: c, max_per_client: &caps };
        let mut sink = 0usize;
        bench(&format!("greedy  N={n:<5} C={c}"), scale * 2_000.min(200_000 / c as u64), || {
            sink += solve_greedy(&input).iter().sum::<usize>();
        });
        if n <= 64 {
            bench(&format!("dp      N={n:<5} C={c}"), scale * 20, || {
                sink += solve_dp(&input).iter().sum::<usize>();
            });
            let g = objective(&input, &solve_greedy(&input));
            let d = objective(&input, &solve_dp(&input));
            assert!((g - d).abs() < 1e-7 * (1.0 + d.abs()), "greedy suboptimal!");
        }
        std::hint::black_box(sink);
    }

    println!("\n== estimator update (eq. 3–4) ==");
    for n in [8usize, 64, 1024] {
        let mut est = Estimators::new(n, Smoothing::Fixed(0.3), Smoothing::Fixed(0.5));
        let obs: Vec<Option<(f64, f64)>> = (0..n).map(|i| Some((0.5, i as f64))).collect();
        bench(&format!("estimators.update_round N={n}"), scale * 10_000, || {
            est.update_round(&obs);
        });
    }
}
