//! Sharded-pool benchmark: aggregate goodput scaling with the shard count
//! M under real simulated link sleeps (the `sharded` preset's 2–8 ms
//! uplinks), with the cross-shard Jain index held against the M = 1
//! baseline.
//!
//!     cargo bench --bench sharded [-- --quick]
//!
//! `--quick` runs the CI smoke shape (fewer rounds, same assertions).

use goodspeed::configsys::{Policy, Scenario};
use goodspeed::coordinator::{RunOutcome, Transport};
use goodspeed::experiments::{mock_engine, serve_once};
use goodspeed::util::stats::jain_index;

mod common;

fn run(m: usize, rounds: u64) -> RunOutcome {
    let mut s = Scenario::preset("sharded").expect("preset");
    s.num_verifiers = m;
    s.rounds = rounds;
    // Real uplink sleeps are the whole point.
    serve_once(s, Policy::GoodSpeed, Transport::Channel, true, mock_engine())
        .expect("pool run")
}

fn report(out: &RunOutcome, m: usize) -> (f64, f64) {
    let jain = jain_index(&out.recorder.avg_goodput());
    println!(
        "M={m}  waves {:>5}  tokens {:>8.0}  aggregate {:>8.1} tok/s  jain {:.4}  migrations {}",
        out.summary.rounds,
        out.summary.total_tokens,
        out.summary.tokens_per_sec,
        jain,
        out.pool.as_ref().map_or(0, |p| p.migrations)
    );
    (out.summary.tokens_per_sec, jain)
}

fn main() {
    let rounds = common::rounds(15, 50);
    println!("== sharded bench: 8 clients / C = 32, {rounds} rounds/client budget ==");
    let mut results = Vec::new();
    for m in [1usize, 2, 4] {
        let out = run(m, rounds);
        results.push(report(&out, m));
    }
    let (base_rate, base_jain) = results[0];
    println!(
        "\nscaling: M=2 {:.2}x  M=4 {:.2}x   fairness drift: M=2 {:+.2}%  M=4 {:+.2}%",
        results[1].0 / base_rate.max(1e-12),
        results[2].0 / base_rate.max(1e-12),
        100.0 * (results[1].1 - base_jain) / base_jain.max(1e-12),
        100.0 * (results[2].1 - base_jain) / base_jain.max(1e-12),
    );
    let monotone = results.windows(2).all(|w| w[1].0 > w[0].0);
    let fair = results
        .iter()
        .all(|&(_, j)| (j - base_jain).abs() <= 0.05 * base_jain);
    if monotone && fair {
        println!("PASS: goodput scales with M, cross-shard fairness within 5% of M=1");
    } else {
        println!("WARN: expected monotone scaling with fairness within 5%");
    }
}
