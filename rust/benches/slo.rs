//! SLO benchmark: closed-loop speculation control (`policy=turbo`) vs
//! the fixed-speculation gradient baseline at the *same* verifier
//! budget C, on a trace where deadline pressure is heterogeneous.
//!
//! The workload: three "light" clients stream small requests with loose
//! deadlines; one "tight" client streams large requests whose deadline
//! requires more than a fair C/N share of speculation. The plain
//! gradient policy splits the budget by goodput fairness and lets the
//! tight client miss; turbo sheds speculation from the comfortably-ahead
//! light clients (whose loose SLOs survive a shorter draft) and
//! water-fills the freed budget onto the tight one — trading a little
//! raw goodput for more *SLO-goodput* (tokens of deadline-met requests).
//!
//!     cargo bench --bench slo [-- --quick]
//!
//! The `--quick` CI smoke *asserts* (not just prints) that turbo's
//! SLO-goodput is ≥ the gradient baseline's on the deterministic
//! analytic model, and within noise of it live.

use std::fmt::Write as _;

use goodspeed::configsys::{ArrivalProcess, Policy, Scenario, TraceConfig};
use goodspeed::coordinator::Transport;
use goodspeed::experiments::{mock_engine, serve_once};
use goodspeed::metrics::recorder::Recorder;
use goodspeed::serve::SloSummary;
use goodspeed::simulate::analytic::AnalyticSim;

mod common;

/// Write the deterministic benchmark trace: clients 0–2 light and loose
/// (16 tokens every 12 waves, SLO 48), client 3 heavy and tight (40
/// tokens every 8 waves, SLO 8 — needs ≫ C/N speculation to meet).
fn write_trace(rounds: u64) -> String {
    let mut clients = Vec::new();
    for _ in 0..3 {
        let mut reqs = String::new();
        let mut t = 0;
        while t + 60 < rounds {
            let _ = write!(reqs, "{{\"arrival\": {t}, \"tokens\": 16, \"slo\": 48}},");
            t += 12;
        }
        clients.push(format!("[{}]", reqs.trim_end_matches(',')));
    }
    let mut reqs = String::new();
    let mut t = 0;
    while t + 30 < rounds {
        let _ = write!(reqs, "{{\"arrival\": {t}, \"tokens\": 40, \"slo\": 8}},");
        t += 8;
    }
    clients.push(format!("[{}]", reqs.trim_end_matches(',')));
    let dir = std::env::temp_dir().join("goodspeed_slo_bench");
    std::fs::create_dir_all(&dir).expect("temp dir");
    let path = dir.join(format!("trace_{rounds}.json"));
    std::fs::write(&path, format!("{{\"clients\": [{}]}}", clients.join(",")))
        .expect("write trace");
    path.to_string_lossy().into_owned()
}

fn scenario(rounds: u64, trace_path: &str) -> Scenario {
    let mut s = Scenario::preset("trace").expect("preset");
    s.rounds = rounds;
    // A strong draft on an easy domain: speculation depth actually pays,
    // so budget placement decides who meets deadlines.
    s.draft_models = vec!["qwen-draft-17b".into()];
    s.domains = vec!["alpaca".into(); 4];
    s.domain_stickiness = 1.0;
    s.trace = Some(TraceConfig {
        arrival: ArrivalProcess::File(trace_path.to_string()),
        slo_waves: 48,
        output_tokens: 16,
        requests_per_client: 0, // file traces carry their own schedule
    });
    s
}

fn analytic(policy: Policy, rounds: u64, trace_path: &str) -> Recorder {
    let mut sim = AnalyticSim::from_scenario(&scenario(rounds, trace_path), policy);
    sim.run();
    std::mem::take(&mut sim.core.recorder)
}

fn live(policy: Policy, rounds: u64, trace_path: &str) -> Recorder {
    serve_once(
        scenario(rounds, trace_path),
        policy,
        Transport::Channel,
        false,
        mock_engine(),
    )
    .expect("live trace run")
    .recorder
}

fn report(label: &str, rec: &Recorder) -> (f64, SloSummary) {
    let s = rec.slo_summary().expect("trace runs carry request records");
    let raw: f64 = rec.cum_goodput().iter().sum();
    println!(
        "{label:<16} slo-goodput {:>7.0}  raw {:>7.0}  attainment {:>5.1}%  \
         e2e p50/p95/p99 {:>5.1}/{:>5.1}/{:>5.1}  (done {} expired {})",
        s.slo_goodput_total,
        raw,
        100.0 * s.attainment,
        s.e2e.0,
        s.e2e.1,
        s.e2e.2,
        s.completed,
        s.expired,
    );
    (s.slo_goodput_total, s)
}

fn main() {
    let rounds = common::rounds(120, 360);
    let trace_path = write_trace(rounds);
    println!(
        "== slo bench: 3 loose + 1 tight client, C = 16, {rounds} waves ==\n\
         -- analytic model (deterministic) --"
    );
    let gs_rec = analytic(Policy::GoodSpeed, rounds, &trace_path);
    let (sim_gs, sim_gs_sum) = report("sim  goodspeed", &gs_rec);
    let tb_rec = analytic(Policy::Turbo, rounds, &trace_path);
    let (sim_tb, sim_tb_sum) = report("sim  turbo", &tb_rec);
    println!("-- live (mock engine) --");
    let (live_gs, _) = report("live goodspeed", &live(Policy::GoodSpeed, rounds, &trace_path));
    let (live_tb, _) = report("live turbo", &live(Policy::Turbo, rounds, &trace_path));

    println!(
        "\nturbo/goodspeed slo-goodput: analytic {:.2}×   live {:.2}×",
        sim_tb / sim_gs.max(1e-12),
        live_tb / live_gs.max(1e-12)
    );
    // The acceptance criterion, asserted: at equal verifier budget C the
    // closed-loop controller's SLO-goodput is at least the fixed-S
    // gradient baseline's on the deterministic analytic model, and it
    // actually rescues deadline-tight work (attainment does not drop).
    assert!(
        sim_tb + 1e-9 >= sim_gs,
        "turbo must not lose SLO-goodput: {sim_tb:.1} vs {sim_gs:.1}"
    );
    assert!(
        sim_tb_sum.attainment + 1e-9 >= sim_gs_sum.attainment,
        "turbo must not lower attainment: {:.3} vs {:.3}",
        sim_tb_sum.attainment,
        sim_gs_sum.attainment
    );
    // Live runs share the logic but not the acceptance process; hold them
    // to a noise band rather than strict dominance.
    assert!(
        live_tb >= 0.9 * live_gs,
        "live turbo fell outside the noise band: {live_tb:.1} vs {live_gs:.1}"
    );
    if sim_tb > sim_gs && live_tb >= live_gs {
        println!("PASS: turbo ≥ gradient on SLO-goodput at equal C (analytic strict, live ≥)");
    } else {
        println!("PASS: turbo ≥ gradient on SLO-goodput at equal C (analytic; live within noise)");
    }
}
