//! Microbenchmark: rejection-sampling verification (per-client verdict
//! computation on the coordinator hot path) and categorical sampling.

use std::time::Instant;

use goodspeed::spec::rejection::verify_client;
use goodspeed::util::Rng;

mod common;

fn bench<F: FnMut()>(label: &str, iters: u64, mut f: F) -> f64 {
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let ns = t0.elapsed().as_nanos() as f64 / iters as f64;
    println!("{label:<44} {ns:>12.0} ns/op");
    ns
}

fn main() {
    println!("== speculative-decoding core microbench ==");
    // `--quick` scales the iteration counts down 10× (same shapes).
    let scale = common::rounds(1, 10);
    let mut rng = Rng::new(2);
    for (s, vocab) in [(4usize, 256usize), (16, 256), (32, 256)] {
        let ratios: Vec<f32> = (0..s).map(|_| rng.f32() * 0.8 + 0.1).collect();
        let resid: Vec<f32> = (0..(s + 1) * vocab).map(|_| rng.f32()).collect();
        let bonus: Vec<f32> = (0..vocab).map(|_| rng.f32()).collect();
        let mut out = 0usize;
        bench(&format!("verify_client S={s:<3} V={vocab}"), scale * 20_000, || {
            out += verify_client(&ratios, &resid, &bonus, vocab, &mut rng).goodput;
        });
        std::hint::black_box(out);
    }
    println!("\n== categorical sampling ==");
    for vocab in [64usize, 256, 1024] {
        let w: Vec<f32> = (0..vocab).map(|_| rng.f32()).collect();
        let mut acc = 0usize;
        bench(&format!("categorical V={vocab}"), scale * 50_000, || {
            acc += rng.categorical(&w);
        });
        std::hint::black_box(acc);
    }
}
