//! Straggler benchmark: sync barrier vs async event-driven pipeline under
//! one client with a 10× slower uplink (the `straggler` preset), over the
//! channel transport with real simulated link sleeps.
//!
//! The paper's Fig 3 identifies receive time — waiting on the slowest
//! uplink — as the dominant round cost of the barrier. This bench measures
//! how much of the fast clients' goodput the async pipeline recovers while
//! log-utility fairness (Jain index over accepted tokens per participated
//! wave) is preserved.

use goodspeed::configsys::{CoordMode, Policy, Scenario};
use goodspeed::coordinator::{RunOutcome, Transport};
use goodspeed::experiments::{mock_engine, serve_once};
use goodspeed::util::stats::jain_index;

mod common;

fn run(mode: CoordMode, rounds: u64) -> RunOutcome {
    let mut s = Scenario::preset("straggler").expect("preset");
    s.rounds = rounds;
    s.coord_mode = mode;
    // Real link sleeps are the whole point.
    serve_once(s, Policy::GoodSpeed, Transport::Channel, true, mock_engine()).expect("run")
}

fn report(label: &str, out: &RunOutcome) -> (f64, f64) {
    let jain = jain_index(&out.recorder.avg_accepted());
    println!(
        "{label:<6} waves {:>5}  tokens {:>7.0}  aggregate {:>8.1} tok/s  jain(accepted/wave) {:.4}",
        out.summary.rounds, out.summary.total_tokens, out.summary.tokens_per_sec, jain
    );
    let part = out.recorder.participation();
    let gp: Vec<String> = out
        .recorder
        .avg_goodput()
        .iter()
        .zip(part)
        .map(|(g, p)| format!("{g:.2}×{p}"))
        .collect();
    println!("       per-client goodput×waves [{}]", gp.join(", "));
    (out.summary.tokens_per_sec, jain)
}

fn main() {
    let rounds = common::rounds(15, 80);
    println!("== straggler bench: client 0 on a 10× slower uplink ({rounds} rounds/client budget) ==");
    let sync = run(CoordMode::Sync, rounds);
    let (sync_rate, sync_jain) = report("sync", &sync);
    let asy = run(CoordMode::Async, rounds);
    let (async_rate, async_jain) = report("async", &asy);
    println!(
        "\nasync/sync aggregate goodput: {:.2}×   fairness drift: {:+.2}%",
        async_rate / sync_rate.max(1e-12),
        100.0 * (async_jain - sync_jain) / sync_jain.max(1e-12)
    );
    if async_rate > sync_rate && (async_jain - sync_jain).abs() <= 0.05 * sync_jain {
        println!("PASS: async recovers goodput with fairness within 5% of sync");
    } else {
        println!("WARN: expected async > sync with fairness within 5%");
    }
}
