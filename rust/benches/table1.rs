//! Table I regeneration bench: the full scenario matrix (3 rows × 2
//! C-variants) end-to-end, reporting goodput / throughput / fairness /
//! latency per policy. Writes `results/table1_scenarios.csv`.

use goodspeed::cli::Args;
use goodspeed::experiments::table1;

mod common;

fn main() {
    goodspeed::util::logger::init();
    let rounds = std::env::var("GOODSPEED_BENCH_ROUNDS")
        .ok()
        .unwrap_or_else(|| common::rounds(10, 50).to_string());
    let args = Args::parse(vec![
        "table1".to_string(),
        "--rounds".into(),
        rounds,
        "--out".into(),
        "results".into(),
    ]);
    if let Err(e) = table1::main(&args) {
        eprintln!("table1 bench failed: {e:#}");
        std::process::exit(1);
    }
}
