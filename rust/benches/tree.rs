//! Tree-speculation benchmark: goodput of the `tree` preset's binary
//! profile vs the chain at the *same* per-client node budget, over the
//! live mock stack and the analytic simulator.
//!
//! Spending the scheduler's S_i(t) node grant on a branching candidate
//! tree raises the expected accepted depth per verified node whenever the
//! acceptance rate is modest (`spec::expected_tree_goodput`): a rejected
//! sibling is retried against the residual instead of ending the round.
//! This bench reports tokens/verdict, accepted depth, and per-node
//! acceptance for both shapes, plus the live-vs-analytic agreement the
//! acceptance criterion asks for.

use goodspeed::configsys::{Policy, Scenario, SpecShape};
use goodspeed::coordinator::Transport;
use goodspeed::experiments::{mock_engine, serve_once};
use goodspeed::metrics::recorder::Recorder;
use goodspeed::simulate::analytic::AnalyticSim;

mod common;

fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

fn scenario(shape: SpecShape, rounds: u64) -> Scenario {
    let mut s = Scenario::preset("tree").expect("preset");
    s.rounds = rounds;
    s.spec_shape = shape;
    s
}

fn live(shape: SpecShape, rounds: u64) -> Recorder {
    serve_once(
        scenario(shape, rounds),
        Policy::GoodSpeed,
        Transport::Channel,
        false,
        mock_engine(),
    )
    .expect("run")
    .recorder
}

fn analytic(shape: SpecShape, rounds: u64) -> Recorder {
    let mut sim = AnalyticSim::from_scenario(&scenario(shape, rounds), Policy::GoodSpeed);
    sim.run();
    sim.core.recorder
}

fn report(label: &str, rec: &Recorder) -> f64 {
    let g = rec.goodput_per_verdict();
    println!(
        "{label:<16} tokens/verdict {g:>6.3}  accepted-depth {:>5.2}  drafted-depth {:>5.2}  node-accept {:>5.2}",
        mean(&rec.avg_accepted()),
        mean(&rec.avg_spec_depth()),
        mean(&rec.node_acceptance()),
    );
    g
}

fn main() {
    let rounds = common::rounds(40, 200);
    let tree_shape = SpecShape::Tree { arity: 2, depth: 8 };
    println!("== tree bench: binary profile vs chain at equal node budget ({rounds} rounds) ==");

    println!("-- live (mock engine) --");
    let live_chain = report("live chain", &live(SpecShape::Chain, rounds));
    let live_tree = report("live tree 2x8", &live(tree_shape, rounds));
    println!("-- analytic simulator --");
    let sim_chain = report("sim  chain", &analytic(SpecShape::Chain, rounds));
    let sim_tree = report("sim  tree 2x8", &analytic(tree_shape, rounds));

    println!(
        "\ntree/chain goodput: live {:.2}×   analytic {:.2}×",
        live_tree / live_chain.max(1e-12),
        sim_tree / sim_chain.max(1e-12)
    );
    let agree = (live_tree - sim_tree).abs() <= 0.35 * sim_tree;
    if live_tree > live_chain && sim_tree > sim_chain && agree {
        println!("PASS: tree beats chain at equal node budget, live and analytic agree");
    } else {
        println!(
            "WARN: expected tree > chain in both stacks (live {live_tree:.3} vs {live_chain:.3}, \
             sim {sim_tree:.3} vs {sim_chain:.3}) with live/sim agreement"
        );
    }
}
