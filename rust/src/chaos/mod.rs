//! Chaos engineering: deterministic, seed-forked fault injection.
//!
//! The schedule types ([`FaultSchedule`], [`FaultEvent`], [`FaultKind`])
//! describe *what* breaks and *when* on the shared wave clock; the
//! survival machinery lives where the system already makes membership
//! decisions — `coordinator/pool.rs` fences crashed shards and migrates
//! their clients to survivors, `simulate/analytic.rs` mirrors the same
//! schedule, and `benches/chaos.rs` asserts the goodput/fairness
//! recovery envelopes around each fault.

mod schedule;

pub use schedule::{flapping_churn, FaultEvent, FaultKind, FaultOp, FaultSchedule};
