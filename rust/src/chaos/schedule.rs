//! Deterministic fault-injection schedules.
//!
//! A [`FaultSchedule`] pins faults to points in virtual time — the same
//! wave clock [`ChurnEvent::at_wave`](crate::configsys::ChurnEvent) uses
//! (in pooled runs: global waves ÷ M) — so the live cluster and the
//! analytic simulator inject the *same* faults at the *same* boundaries
//! and their recovery envelopes stay comparable. Everything here is a
//! pure description: the recovery machinery lives in
//! `coordinator/pool.rs` (crash fencing + client migration) and
//! `simulate/analytic.rs` (the mirrored schedule).
//!
//! Four fault kinds (§ DESIGN.md "Fault injection & recovery"):
//!
//! * [`FaultKind::ShardCrash`] — a verifier shard dies at wave T and its
//!   clients migrate to survivors; optional re-admission at recovery.
//! * [`FaultKind::Partition`] — a client's uplink goes dark and heals.
//! * [`FaultKind::DropBurst`] / [`FaultKind::DuplicateBurst`] — message
//!   loss/duplication bursts on one client's stream.
//!
//! Adversarial *flapping clients* are not a fault kind of their own:
//! [`flapping_churn`] compiles them down to the existing
//! [`ChurnSchedule`] machinery, seed-forked for determinism, so both
//! execution paths get them through code that already exists.

use crate::configsys::{ChurnEvent, ChurnKind, ChurnSchedule, ClientSpec, Scenario};
use crate::util::Rng;

/// What breaks (and, where applicable, when it heals).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Verifier shard `shard` dies at the event wave. Its clients are
    /// migrated to surviving shards via the pool's handoff mailbox;
    /// with `recover_wave` set the shard is re-admitted there and the
    /// rebalancer repopulates it.
    ShardCrash { shard: usize, recover_wave: Option<u64> },
    /// Client `client`'s uplink is partitioned from the event wave until
    /// `heal_wave`: the analytic model inflates its round trip over the
    /// outage window (see `net/link.rs::Link::degraded`).
    Partition { client: usize, heal_wave: u64 },
    /// The next `count` draft messages from `client` are dropped.
    /// Analytic-only: the live closed loop has no retransmit, so a
    /// dropped draft would deadlock the client — the simulator models
    /// the stall (skipped waves) instead.
    DropBurst { client: usize, count: u32 },
    /// The next `count` draft messages from `client` arrive twice. The
    /// duplicate is detected and discarded (counted, never verified
    /// twice) on both paths.
    DuplicateBurst { client: usize, count: u32 },
}

/// One fault pinned to a wave boundary.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    /// Wave boundary at which the fault strikes (applied before the wave
    /// with this index is formed).
    pub at_wave: u64,
    pub kind: FaultKind,
}

/// Fault schedule for a run. Empty = no chaos, and every consumer takes
/// the exact pre-chaos code path (bit-identical RNG streams, wire bytes,
/// and CSV output — pinned by the existing parity tests).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultSchedule {
    pub events: Vec<FaultEvent>,
}

/// One boundary-applied fault action: the compiled form of a
/// [`FaultEvent`]. Recovery/heal halves become entries of their own, so
/// consumers walk a single sorted list against their wave clock instead
/// of tracking in-flight windows.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FaultOp {
    Crash { shard: usize },
    Recover { shard: usize },
    PartitionStart { client: usize, until: u64 },
    PartitionHeal { client: usize },
    Drop { client: usize, count: u32 },
    Duplicate { client: usize, count: u32 },
}

impl FaultSchedule {
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events sorted by wave (stable: ties keep schedule order).
    pub fn sorted(&self) -> Vec<FaultEvent> {
        let mut v = self.events.clone();
        v.sort_by_key(|e| e.at_wave);
        v
    }

    /// Number of scheduled shard crashes.
    pub fn crash_count(&self) -> usize {
        self.events.iter().filter(|e| matches!(e.kind, FaultKind::ShardCrash { .. })).count()
    }

    /// Compile to a sorted `(wave, op)` list — crash/recover and
    /// partition/heal pairs expanded into separate entries. Both the
    /// pool driver and the analytic simulator consume this form, which
    /// is what keeps the two paths on one schedule and one clock.
    pub fn compiled(&self) -> Vec<(u64, FaultOp)> {
        let mut ops = Vec::with_capacity(self.events.len() * 2);
        for ev in &self.events {
            match ev.kind {
                FaultKind::ShardCrash { shard, recover_wave } => {
                    ops.push((ev.at_wave, FaultOp::Crash { shard }));
                    if let Some(r) = recover_wave {
                        ops.push((r, FaultOp::Recover { shard }));
                    }
                }
                FaultKind::Partition { client, heal_wave } => {
                    ops.push((ev.at_wave, FaultOp::PartitionStart { client, until: heal_wave }));
                    ops.push((heal_wave, FaultOp::PartitionHeal { client }));
                }
                FaultKind::DropBurst { client, count } => {
                    ops.push((ev.at_wave, FaultOp::Drop { client, count }));
                }
                FaultKind::DuplicateBurst { client, count } => {
                    ops.push((ev.at_wave, FaultOp::Duplicate { client, count }));
                }
            }
        }
        ops.sort_by_key(|&(w, _)| w);
        ops
    }

    /// The standard demo schedule (`goodspeed run --chaos`): the highest
    /// shard crashes a third of the way in and recovers at the halfway
    /// mark — the crash/heal shape `benches/chaos.rs` asserts envelopes
    /// around. (Recovery sits at rounds/2, not 2·rounds/3: with a shard
    /// fenced the pooled schedule clock advances at (M−1)/M of its
    /// normal rate, so a later recovery could land after the budget is
    /// already consumed.)
    pub fn demo(scenario: &Scenario) -> FaultSchedule {
        let shard = scenario.num_verifiers.saturating_sub(1);
        let at = (scenario.rounds / 3).max(1);
        let recover = (scenario.rounds / 2).max(at + 1);
        FaultSchedule {
            events: vec![FaultEvent {
                at_wave: at,
                kind: FaultKind::ShardCrash { shard, recover_wave: Some(recover) },
            }],
        }
    }

    /// Structural validation against the scenario's population —
    /// [`Scenario::validate`] maps the message into its `ConfigError`.
    pub fn validate_for(&self, num_clients: usize, num_verifiers: usize) -> Result<(), String> {
        for ev in &self.events {
            match ev.kind {
                FaultKind::ShardCrash { shard, recover_wave } => {
                    if num_verifiers < 2 {
                        return Err(
                            "chaos: shard crash needs num_verifiers ≥ 2 (a survivor must \
                             exist to absorb the crashed shard's clients)"
                                .into(),
                        );
                    }
                    if shard >= num_verifiers {
                        return Err(format!(
                            "chaos: crash of shard {shard} but only {num_verifiers} shards exist"
                        ));
                    }
                    if let Some(r) = recover_wave {
                        if r <= ev.at_wave {
                            return Err(format!(
                                "chaos: shard {shard} recovery at wave {r} must come after \
                                 its crash at wave {}",
                                ev.at_wave
                            ));
                        }
                    }
                }
                FaultKind::Partition { client, heal_wave } => {
                    if client >= num_clients {
                        return Err(format!(
                            "chaos: partition of client {client} but only {num_clients} exist"
                        ));
                    }
                    if heal_wave <= ev.at_wave {
                        return Err(format!(
                            "chaos: partition heal at wave {heal_wave} must come after the \
                             partition at wave {}",
                            ev.at_wave
                        ));
                    }
                }
                FaultKind::DropBurst { client, count }
                | FaultKind::DuplicateBurst { client, count } => {
                    if client >= num_clients {
                        return Err(format!(
                            "chaos: burst on client {client} but only {num_clients} exist"
                        ));
                    }
                    if count == 0 {
                        return Err("chaos: burst count must be ≥ 1".into());
                    }
                }
            }
        }
        Ok(())
    }
}

/// Compile a flapping-client adversary into the existing churn
/// machinery: `flaps` join/leave pairs of one client spec (client 0's
/// model/domain), starting at `start_wave`, with up/down intervals of
/// mean `period` waves jittered ±25% by a PRNG forked from the scenario
/// seed (`seed ^ 0xC4A05` — disjoint from every other stream). The
/// result is an ordinary [`ChurnSchedule`], so the live cluster and the
/// analytic simulator both absorb the churn through code that already
/// handles joins and drains.
pub fn flapping_churn(
    scenario: &Scenario,
    flaps: usize,
    start_wave: u64,
    period: u64,
) -> ChurnSchedule {
    let mut rng = Rng::new(scenario.seed ^ 0xC4A05);
    let model = scenario.draft_model(0).to_string();
    let domain = scenario.domain(0).to_string();
    let mut jitter = move |base: u64| -> u64 {
        let f = 0.75 + 0.5 * rng.f64();
        ((base as f64 * f).round() as u64).max(1)
    };
    let mut events = Vec::with_capacity(flaps * 2);
    let mut t = start_wave;
    for k in 0..flaps {
        let up = jitter(period.max(1));
        let down = jitter(period.max(1));
        events.push(ChurnEvent {
            at_wave: t,
            kind: ChurnKind::Join(ClientSpec::new(model.clone(), domain.clone())),
        });
        // Join ids assign in order after the initial population, so the
        // k-th flap's joiner is exactly this slot.
        events.push(ChurnEvent { at_wave: t + up, kind: ChurnKind::Leave(scenario.num_clients + k) });
        t += up + down;
    }
    ChurnSchedule { events }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn demo_schedule_is_well_formed() {
        let s = Scenario::preset("sharded").unwrap();
        let f = FaultSchedule::demo(&s);
        assert_eq!(f.crash_count(), 1);
        assert!(f.validate_for(s.num_clients, s.num_verifiers).is_ok());
        match f.events[0].kind {
            FaultKind::ShardCrash { shard, recover_wave } => {
                assert_eq!(shard, s.num_verifiers - 1);
                assert!(recover_wave.unwrap() > f.events[0].at_wave);
            }
            ref other => panic!("demo must be a crash, got {other:?}"),
        }
    }

    #[test]
    fn compiled_expands_and_sorts() {
        let f = FaultSchedule {
            events: vec![
                FaultEvent {
                    at_wave: 40,
                    kind: FaultKind::Partition { client: 2, heal_wave: 55 },
                },
                FaultEvent {
                    at_wave: 10,
                    kind: FaultKind::ShardCrash { shard: 1, recover_wave: Some(50) },
                },
                FaultEvent { at_wave: 20, kind: FaultKind::DropBurst { client: 0, count: 3 } },
            ],
        };
        let ops = f.compiled();
        assert_eq!(ops.len(), 5, "crash+recover and partition+heal expand");
        assert!(ops.windows(2).all(|w| w[0].0 <= w[1].0), "sorted by wave: {ops:?}");
        assert_eq!(ops[0], (10, FaultOp::Crash { shard: 1 }));
        assert_eq!(ops[4], (55, FaultOp::PartitionHeal { client: 2 }));
    }

    #[test]
    fn validation_rejects_malformed_schedules() {
        let crash = |shard, recover_wave| FaultSchedule {
            events: vec![FaultEvent {
                at_wave: 10,
                kind: FaultKind::ShardCrash { shard, recover_wave },
            }],
        };
        // Crash needs a survivor shard.
        assert!(crash(0, None).validate_for(4, 1).is_err());
        // Shard index must exist.
        assert!(crash(2, None).validate_for(4, 2).is_err());
        // Recovery must follow the crash.
        assert!(crash(1, Some(10)).validate_for(4, 2).is_err());
        assert!(crash(1, Some(11)).validate_for(4, 2).is_ok());
        // Partition: client range + heal ordering.
        let part = |client, heal_wave| FaultSchedule {
            events: vec![FaultEvent {
                at_wave: 10,
                kind: FaultKind::Partition { client, heal_wave },
            }],
        };
        assert!(part(4, 20).validate_for(4, 2).is_err());
        assert!(part(1, 10).validate_for(4, 2).is_err());
        assert!(part(1, 20).validate_for(4, 2).is_ok());
        // Bursts: client range + non-zero count.
        let burst = FaultSchedule {
            events: vec![FaultEvent {
                at_wave: 5,
                kind: FaultKind::DuplicateBurst { client: 0, count: 0 },
            }],
        };
        assert!(burst.validate_for(4, 2).is_err());
    }

    #[test]
    fn flapping_churn_compiles_to_a_valid_schedule() {
        let mut s = Scenario::preset("smoke").unwrap();
        let a = flapping_churn(&s, 3, 5, 8);
        let b = flapping_churn(&s, 3, 5, 8);
        assert_eq!(a.events.len(), 6, "one join + one leave per flap");
        assert_eq!(a.join_count(), 3);
        // Deterministic from the scenario seed.
        for (x, y) in a.events.iter().zip(&b.events) {
            assert_eq!(x.at_wave, y.at_wave);
        }
        let mut other = s.clone();
        other.seed ^= 1;
        let c = flapping_churn(&other, 3, 5, 8);
        assert!(
            a.events.iter().zip(&c.events).any(|(x, y)| x.at_wave != y.at_wave),
            "seed must jitter the flap times"
        );
        // The compiled schedule passes full scenario validation (leave
        // ids line up with join-assigned slots).
        s.churn = a;
        assert!(s.validate().is_ok());
        // Flaps alternate: each join precedes its own leave.
        let sorted = s.churn.sorted();
        assert!(matches!(sorted[0].kind, ChurnKind::Join(_)));
    }
}
