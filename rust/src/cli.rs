//! Tiny CLI argument parser (clap is not in the offline crate set).
//!
//! Grammar: `goodspeed <subcommand> [--key value]... [--flag]...`.
//! Unknown keys are collected and reported by `finish()` so typos fail
//! loudly instead of silently using defaults.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    pub fn parse_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut args = Args::default();
        let mut it = items.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                args.subcommand = it.next();
            }
        }
        while let Some(item) = it.next() {
            if let Some(key) = item.strip_prefix("--") {
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        args.opts.insert(key.to_string(), it.next().unwrap());
                    }
                    _ => args.flags.push(key.to_string()),
                }
            }
            // bare positional after flags: ignore (we have no use for them)
        }
        args
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.opts.get(key).map(String::as_str)
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str) -> Option<T> {
        self.get(key).and_then(|v| v.parse().ok())
    }

    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.iter().any(|f| f == key)
    }

    /// Error on any unconsumed option/flag (call after all `get`s).
    pub fn finish(&self) -> Result<(), String> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<String> = self
            .opts
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !consumed.contains(k))
            .cloned()
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!("unknown arguments: --{}", unknown.join(", --")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("run --scenario qwen-8c-150 --rounds 100 --tcp");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get("scenario"), Some("qwen-8c-150"));
        assert_eq!(a.get_parse::<u64>("rounds"), Some(100));
        assert!(a.flag("tcp"));
        assert!(!a.flag("other"));
        assert!(a.finish().is_ok());
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--rounds 5");
        assert!(a.subcommand.is_none());
        assert_eq!(a.get_parse::<u64>("rounds"), Some(5));
    }

    #[test]
    fn unknown_args_reported() {
        let a = parse("run --real-flag --oops 3");
        assert!(a.flag("real-flag"));
        let err = a.finish().unwrap_err();
        assert!(err.contains("oops"), "{err}");
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.get_or("policy", "goodspeed"), "goodspeed");
        assert_eq!(a.get_parse::<u64>("rounds"), None);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("x --a --b v");
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("v"));
    }
}
