//! Hand-rolled JSON parser/serializer (serde is not in the offline crate
//! set). Parses the AOT `manifest.json`, scenario files, and serializes
//! experiment results. Supports the full JSON grammar except `\u` surrogate
//! pairs outside the BMP (sufficient for our ASCII artifacts).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

#[derive(Debug, thiserror::Error)]
#[error("json error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Value {
    pub fn parse(text: &str) -> Result<Value, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_object().and_then(|o| o.get(key))
    }

    /// `obj.get_path("a.b.c")`
    pub fn get_path(&self, path: &str) -> Option<&Value> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    pub fn from_pairs(pairs: Vec<(&str, Value)>) -> Value {
        Value::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Value::Str(s) => write_escaped(f, s),
            Value::Array(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Value::Object(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (d as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.bytes.len());
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("bad utf-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Value::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-2.5e2").unwrap(), Value::Num(-250.0));
        assert_eq!(Value::parse("\"a\\nb\"").unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get_path("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_array().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("1 2").is_err());
        assert!(Value::parse("{'a': 1}").is_err());
        assert!(Value::parse("").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Value::parse(r#""Aé""#).unwrap(), Value::Str("Aé".into()));
    }

    #[test]
    fn utf8_passthrough() {
        assert_eq!(Value::parse("\"αβγ\"").unwrap(), Value::Str("αβγ".into()));
    }

    fn random_value(rng: &mut crate::util::Rng, depth: usize) -> Value {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.bool(0.5)),
            2 => Value::Num((rng.f64() * 2000.0 - 1000.0).round() / 8.0),
            3 => {
                let n = rng.below(8);
                Value::Str((0..n).map(|_| rng.range_u(32, 126) as u8 as char).collect())
            }
            4 => Value::Array((0..rng.below(4)).map(|_| random_value(rng, depth - 1)).collect()),
            _ => Value::Object(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), random_value(rng, depth - 1)))
                    .collect(),
            ),
        }
    }

    #[test]
    fn prop_print_parse_roundtrip() {
        proptest::check("json_roundtrip", proptest::default_cases(), |rng| {
            let v = random_value(rng, 3);
            let text = v.to_string();
            let back = Value::parse(&text).unwrap();
            assert_eq!(v, back, "text: {text}");
        });
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = Value::parse(&text).unwrap();
            assert!(v.get("models").is_some());
            assert_eq!(v.get("vocab").unwrap().as_usize(), Some(256));
        }
    }
}
