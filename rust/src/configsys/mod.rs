//! Configuration system: hand-rolled JSON + typed scenarios (Table I).

pub mod json;
pub mod scenario;

pub use json::Value;
pub use scenario::{
    ArrivalProcess, ChurnEvent, ChurnKind, ChurnSchedule, ClientSpec, CoordMode, LinkConfig,
    Policy, Scenario, Smoothing, SpecShape, TraceConfig,
};
