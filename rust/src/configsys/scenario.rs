//! Experiment scenarios — the code form of the paper's Table I.
//!
//! A [`Scenario`] fully determines an experiment: model family, client
//! count, verification budget `C`, per-client draft models and primary
//! domains, smoothing parameters, network model, seed, and round count.
//! Presets `qwen-4c-50`, `qwen-8c-150`, and `llama-8c-150` correspond to the
//! three rows of Table I; every field can be overridden from the CLI or a
//! JSON scenario file.

use std::str::FromStr;

use super::json::Value;
use crate::chaos::{FaultEvent, FaultKind, FaultSchedule};
use crate::error::ConfigError;
use crate::workload::domains::DOMAINS;

/// Scheduling policy under test (§IV-B2 baselines, plus the SLO-aware
/// closed-loop controller).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// The paper's gradient scheduling algorithm (GOODSPEED-SCHED).
    GoodSpeed,
    /// `S_i = C / N` every round.
    FixedS,
    /// Random split of the budget across clients.
    RandomS,
    /// The gradient allocator under the TurboSpec-style closed-loop
    /// speculation controller (`sched::controller::TurboController`):
    /// per-client speculation caps shrink when a client is ahead of its
    /// deadline while the verifier is congested, and grow while accept
    /// rates are high — optimizing *SLO-goodput* instead of raw goodput.
    /// Meaningful with a request trace (`Scenario::trace`); without one
    /// every client reads as deadline-free and the caps stay open, so
    /// turbo degrades to the plain gradient policy.
    Turbo,
}

impl FromStr for Policy {
    type Err = ConfigError;

    fn from_str(s: &str) -> Result<Policy, ConfigError> {
        match s.to_ascii_lowercase().as_str() {
            "goodspeed" | "gs" => Ok(Policy::GoodSpeed),
            "fixed" | "fixed-s" | "fixeds" => Ok(Policy::FixedS),
            "random" | "random-s" | "randoms" => Ok(Policy::RandomS),
            "turbo" | "turbo-spec" | "turbospec" => Ok(Policy::Turbo),
            _ => Err(ConfigError::InvalidChoice {
                field: "policy",
                given: s.to_string(),
                expected: &["goodspeed", "fixed-s", "random-s", "turbo"],
            }),
        }
    }
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::GoodSpeed => "goodspeed",
            Policy::FixedS => "fixed-s",
            Policy::RandomS => "random-s",
            Policy::Turbo => "turbo",
        }
    }

    /// The paper's three policies (Fig 3/4 and Table I sweep these; the
    /// SLO-aware [`Policy::Turbo`] is benchmarked separately against
    /// GoodSpeed in `benches/slo.rs`).
    pub fn all() -> [Policy; 3] {
        [Policy::GoodSpeed, Policy::FixedS, Policy::RandomS]
    }
}

/// Coordinator batching discipline.
///
/// * `Sync` — the classic per-round barrier: the leader waits for *every*
///   client's draft before verifying (Algorithm 1 exactly; reproduces all
///   paper experiments bit-for-bit).
/// * `Async` — the event-driven verification pipeline: the leader fires a
///   batched verify as soon as `min_wave_fill` clients are ready or the
///   `batch_window_us` deadline expires, whichever comes first; stragglers
///   simply join a later wave (see DESIGN.md, "Wave lifecycle").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoordMode {
    Sync,
    Async,
}

impl FromStr for CoordMode {
    type Err = ConfigError;

    fn from_str(s: &str) -> Result<CoordMode, ConfigError> {
        match s.to_ascii_lowercase().as_str() {
            "sync" | "barrier" => Ok(CoordMode::Sync),
            "async" | "wave" | "event" => Ok(CoordMode::Async),
            _ => Err(ConfigError::InvalidChoice {
                field: "coordination mode",
                given: s.to_string(),
                expected: &["sync", "async"],
            }),
        }
    }
}

impl CoordMode {
    pub fn name(&self) -> &'static str {
        match self {
            CoordMode::Sync => "sync",
            CoordMode::Async => "async",
        }
    }
}

/// Speculation topology each draft server spends its node budget on.
///
/// The scheduler (eq. 5) always allocates a per-client *node* budget
/// `S_i(t)`; the shape decides how those nodes are arranged:
///
/// * `Chain` — the paper's linear draft (bit-identical to the pre-tree
///   stack: same RNG streams, call order, and wire bytes);
/// * `Tree { arity, depth }` — a fixed branching profile: every level up
///   to `depth` gives each frontier node `arity` sibling candidates,
///   raising the expected accepted depth per verified node when the
///   acceptance rate is modest (`spec::expected_tree_goodput`);
/// * `Adaptive` — each client picks its own (arity, depth) profile from
///   its observed acceptance rate (`spec::tree::adaptive_profile`):
///   low-α clients branch wide, high-α clients go deep.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpecShape {
    Chain,
    Tree { arity: usize, depth: usize },
    Adaptive,
}

impl FromStr for SpecShape {
    type Err = ConfigError;

    /// Parse `chain`, `adaptive`, `tree` (the 2×8 default), or
    /// `tree:<arity>x<depth>` (e.g. `tree:3x4`).
    fn from_str(s: &str) -> Result<SpecShape, ConfigError> {
        let lower = s.to_ascii_lowercase();
        match lower.as_str() {
            "chain" | "linear" => return Ok(SpecShape::Chain),
            "adaptive" | "auto" => return Ok(SpecShape::Adaptive),
            "tree" => return Ok(SpecShape::Tree { arity: 2, depth: 8 }),
            _ => {}
        }
        let reject = || ConfigError::InvalidChoice {
            field: "spec shape",
            given: s.to_string(),
            expected: &["chain", "tree", "tree:<arity>x<depth>", "adaptive"],
        };
        let spec = lower.strip_prefix("tree:").ok_or_else(reject)?;
        let (a, d) = spec.split_once('x').ok_or_else(reject)?;
        Ok(SpecShape::Tree {
            arity: a.parse().map_err(|_| reject())?,
            depth: d.parse().map_err(|_| reject())?,
        })
    }
}

impl SpecShape {
    /// Canonical string form (round-trips through the [`FromStr`] impl).
    pub fn label(&self) -> String {
        match self {
            SpecShape::Chain => "chain".into(),
            SpecShape::Tree { arity, depth } => format!("tree:{arity}x{depth}"),
            SpecShape::Adaptive => "adaptive".into(),
        }
    }

    pub fn is_chain(&self) -> bool {
        matches!(self, SpecShape::Chain)
    }
}

/// Per-client network link (edge → verification server).
#[derive(Clone, Debug)]
pub struct LinkConfig {
    /// One-way propagation latency, seconds.
    pub latency_s: f64,
    /// Bandwidth, bytes/second.
    pub bandwidth_bps: f64,
    /// Multiplicative jitter stddev (0.1 = ±10%).
    pub jitter: f64,
}

impl Default for LinkConfig {
    fn default() -> Self {
        LinkConfig { latency_s: 1e-3, bandwidth_bps: 12.5e6, jitter: 0.1 }
    }
}

/// Everything the cluster needs to admit one new draft server: the draft
/// model it runs, the workload domain it serves, and its uplink. Used by
/// [`ServingHandle::attach`](crate::coordinator::ServingHandle::attach)
/// and by scheduled [`ChurnKind::Join`] events.
#[derive(Clone, Debug)]
pub struct ClientSpec {
    /// Draft model name (must resolve in the engine factory's zoo).
    pub model: String,
    /// Primary workload domain (must be a known domain).
    pub domain: String,
    /// Edge uplink characteristics.
    pub link: LinkConfig,
}

impl ClientSpec {
    /// A spec with the default link.
    pub fn new(model: impl Into<String>, domain: impl Into<String>) -> ClientSpec {
        ClientSpec { model: model.into(), domain: domain.into(), link: LinkConfig::default() }
    }
}

/// One scheduled membership change, applied at a wave boundary.
#[derive(Clone, Debug)]
pub enum ChurnKind {
    /// A new draft server joins the cluster.
    Join(ClientSpec),
    /// The given client id detaches (graceful drain; ids are assigned in
    /// order: initial clients `0..num_clients`, then one per join event).
    Leave(usize),
}

/// A membership change pinned to a point in virtual time (the coordinator
/// wave counter — in sync mode, the round number; in pooled runs the mean
/// per-shard wave count, global waves ÷ M).
#[derive(Clone, Debug)]
pub struct ChurnEvent {
    /// Wave boundary at which the change takes effect (applied before the
    /// wave with this index is formed). With an empty membership, pending
    /// events fire immediately — the frozen wave clock could never reach
    /// them otherwise.
    pub at_wave: u64,
    pub kind: ChurnKind,
}

/// Arrival/departure schedule for a serving run. Both the live cluster
/// ([`Cluster`](crate::coordinator::Cluster)) and the analytic simulator
/// apply the same events at the same wave boundaries, so live and analytic
/// steady state stay comparable through membership changes.
#[derive(Clone, Debug, Default)]
pub struct ChurnSchedule {
    pub events: Vec<ChurnEvent>,
}

impl ChurnSchedule {
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Number of scheduled joins (each consumes one client slot).
    pub fn join_count(&self) -> usize {
        self.events.iter().filter(|e| matches!(e.kind, ChurnKind::Join(_))).count()
    }

    /// Events sorted by wave (stable: ties keep schedule order).
    pub fn sorted(&self) -> Vec<ChurnEvent> {
        let mut v = self.events.clone();
        v.sort_by_key(|e| e.at_wave);
        v
    }

    /// The standard demo schedule for a scenario (`goodspeed run
    /// --churn`): one extra client joins a third of the way in, and
    /// client 0 departs at the two-thirds mark.
    pub fn demo(scenario: &Scenario) -> ChurnSchedule {
        let model = scenario.draft_model(0).to_string();
        let domain = scenario.domain(0).to_string();
        ChurnSchedule {
            events: vec![
                ChurnEvent {
                    at_wave: scenario.rounds / 3,
                    kind: ChurnKind::Join(ClientSpec::new(model, domain)),
                },
                ChurnEvent { at_wave: 2 * scenario.rounds / 3, kind: ChurnKind::Leave(0) },
            ],
        }
    }
}

/// Per-client request arrival process of a trace-driven run (the
/// open-loop side of `serve/`: requests *arrive*, queue, decode, and
/// finish, instead of the default closed loop that always has the next
/// prompt ready). All generators are deterministic from the scenario
/// seed; arrival times are in *waves* — the same virtual clock
/// [`ChurnEvent::at_wave`] uses, shared by the live cluster and the
/// analytic simulator.
#[derive(Clone, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Open-loop Poisson arrivals: i.i.d. exponential inter-arrival gaps
    /// with the given mean, in waves.
    Poisson { mean_gap: f64 },
    /// Bursty arrivals: Poisson-spaced bursts (mean gap in waves) of
    /// `burst` back-to-back requests each.
    Bursty { mean_gap: f64, burst: usize },
    /// Explicit per-client arrival schedule loaded from a JSON trace file
    /// (see `serve::trace::RequestTrace::from_file` for the format).
    File(String),
    /// Flash crowd: baseline Poisson arrivals (mean gap in waves) whose
    /// rate multiplies by `surge` inside the window `[at, at + width)` —
    /// the load spike chaos scenarios recover under.
    FlashCrowd { mean_gap: f64, surge: f64, at: u64, width: u64 },
    /// Diurnal load: Poisson arrivals whose instantaneous rate follows
    /// `1 + amplitude · sin(2π t / period)` around the baseline
    /// `1/mean_gap` — the day/night cycle, compressed to waves.
    Diurnal { mean_gap: f64, amplitude: f64, period: f64 },
}

impl FromStr for ArrivalProcess {
    type Err = ConfigError;

    /// Parse `poisson:<mean_gap>`, `bursty:<mean_gap>x<burst>`,
    /// `flash-crowd:<mean_gap>x<surge>@<at>+<width>`, or
    /// `diurnal:<mean_gap>x<amplitude>@<period>` (all times in waves).
    /// File traces are selected with `goodspeed run --trace <path>`, not
    /// through this parser.
    fn from_str(s: &str) -> Result<ArrivalProcess, ConfigError> {
        let reject = || ConfigError::InvalidChoice {
            field: "arrival process",
            given: s.to_string(),
            expected: &[
                "poisson:<mean_gap>",
                "bursty:<mean_gap>x<burst>",
                "flash-crowd:<mean_gap>x<surge>@<at>+<width>",
                "diurnal:<mean_gap>x<amplitude>@<period>",
            ],
        };
        let lower = s.to_ascii_lowercase();
        if let Some(gap) = lower.strip_prefix("poisson:") {
            return Ok(ArrivalProcess::Poisson { mean_gap: gap.parse().map_err(|_| reject())? });
        }
        if let Some(spec) = lower.strip_prefix("flash-crowd:") {
            let (head, window) = spec.split_once('@').ok_or_else(reject)?;
            let (gap, surge) = head.split_once('x').ok_or_else(reject)?;
            let (at, width) = window.split_once('+').ok_or_else(reject)?;
            return Ok(ArrivalProcess::FlashCrowd {
                mean_gap: gap.parse().map_err(|_| reject())?,
                surge: surge.parse().map_err(|_| reject())?,
                at: at.parse().map_err(|_| reject())?,
                width: width.parse().map_err(|_| reject())?,
            });
        }
        if let Some(spec) = lower.strip_prefix("diurnal:") {
            let (gap, tail) = spec.split_once('x').ok_or_else(reject)?;
            let (amp, period) = tail.split_once('@').ok_or_else(reject)?;
            return Ok(ArrivalProcess::Diurnal {
                mean_gap: gap.parse().map_err(|_| reject())?,
                amplitude: amp.parse().map_err(|_| reject())?,
                period: period.parse().map_err(|_| reject())?,
            });
        }
        let spec = lower.strip_prefix("bursty:").ok_or_else(reject)?;
        let (gap, burst) = spec.split_once('x').ok_or_else(reject)?;
        Ok(ArrivalProcess::Bursty {
            mean_gap: gap.parse().map_err(|_| reject())?,
            burst: burst.parse().map_err(|_| reject())?,
        })
    }
}

impl ArrivalProcess {
    /// Canonical string form (generators round-trip through [`FromStr`]).
    pub fn label(&self) -> String {
        match self {
            ArrivalProcess::Poisson { mean_gap } => format!("poisson:{mean_gap}"),
            ArrivalProcess::Bursty { mean_gap, burst } => format!("bursty:{mean_gap}x{burst}"),
            ArrivalProcess::File(path) => format!("file:{path}"),
            ArrivalProcess::FlashCrowd { mean_gap, surge, at, width } => {
                format!("flash-crowd:{mean_gap}x{surge}@{at}+{width}")
            }
            ArrivalProcess::Diurnal { mean_gap, amplitude, period } => {
                format!("diurnal:{mean_gap}x{amplitude}@{period}")
            }
        }
    }
}

/// Request-level serving configuration: when present, the run is
/// *trace-driven* — discrete requests arrive per client, idle clients'
/// budget water-fills over busy ones, and per-request TTFT/TPOT/E2E and
/// SLO attainment are accounted end to end (see `serve/`). `None` keeps
/// the endless-stream behavior (and output) of the pre-trace stack
/// bit for bit.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceConfig {
    /// How requests arrive at each client.
    pub arrival: ArrivalProcess,
    /// Per-request deadline, in waves from arrival (the SLO). A request
    /// completing within `slo_waves` of its arrival counts toward
    /// SLO-goodput; one that misses keeps its raw-goodput tokens but
    /// contributes nothing to the SLO series.
    pub slo_waves: u64,
    /// Target output tokens per generated request (file traces carry
    /// their own per-request lengths).
    pub output_tokens: usize,
    /// Open-loop requests generated per client (ignored for file traces).
    pub requests_per_client: usize,
}

impl TraceConfig {
    /// A Poisson trace with the standard smoke-scale knobs (24-token
    /// requests, six per client) — the single source of the defaults the
    /// `trace` preset and the `goodspeed run --arrival/--slo` flags
    /// share.
    pub fn poisson(mean_gap: f64, slo_waves: u64) -> TraceConfig {
        TraceConfig {
            arrival: ArrivalProcess::Poisson { mean_gap },
            slo_waves,
            output_tokens: 24,
            requests_per_client: 6,
        }
    }
}

/// Smoothing-parameter schedule (Assumption 3 allows decaying steps).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Smoothing {
    /// Constant η or β (the paper's experiments use fixed values).
    Fixed(f64),
    /// `c / t^p` with `p ∈ (0.5, 1]` (the convergence-theory schedule).
    Decay { c: f64, p: f64 },
}

impl Smoothing {
    pub fn at(&self, t: u64) -> f64 {
        match *self {
            Smoothing::Fixed(v) => v,
            Smoothing::Decay { c, p } => (c / ((t.max(1)) as f64).powf(p)).clamp(1e-4, 1.0),
        }
    }
}

#[derive(Clone, Debug)]
pub struct Scenario {
    pub id: String,
    /// Model family ("qwen" | "llama") — selects verify + draft artifacts.
    pub family: String,
    pub num_clients: usize,
    /// Verification budget C: max total draft tokens per round (Table I).
    pub capacity: usize,
    /// Request length target (50 or 150 in the paper).
    pub max_new_tokens: usize,
    /// Draft model name per client (cycled when shorter than num_clients).
    pub draft_models: Vec<String>,
    /// Primary workload domain per client (cycled).
    pub domains: Vec<String>,
    /// Probability of staying in the primary domain each request
    /// (non-stationarity knob; 1.0 = stationary).
    pub domain_stickiness: f64,
    /// Acceptance-rate smoothing η (paper eq. 3).
    pub eta: Smoothing,
    /// Goodput smoothing β (paper eq. 4).
    pub beta: Smoothing,
    /// Max draft length per client per round (artifact K limit).
    pub max_draft: usize,
    pub rounds: u64,
    pub seed: u64,
    pub links: Vec<LinkConfig>,
    /// Coordinator batching discipline (sync barrier vs async waves).
    pub coord_mode: CoordMode,
    /// Async only: max time the leader waits, after the first draft of a
    /// wave arrives, for more drafts before firing the verify (µs).
    pub batch_window_us: u64,
    /// Async only: fire the wave as soon as this many clients are pending,
    /// even before the window expires. `0` means "all clients" (the window
    /// then bounds the straggler wait).
    pub min_wave_fill: usize,
    /// Verification shards M. `1` = the classic single-verifier leader;
    /// `> 1` runs the sharded pool (`coordinator/pool.rs`): each shard
    /// owns a verifier engine and a transport fan-in, and the global
    /// budget C is split across shards by hierarchical water-filling.
    pub num_verifiers: usize,
    /// Pooled only: recompute the cross-shard budget split (and consider
    /// migrating one client from the most- to the least-pressured shard)
    /// every this many waves. `0` = never rebalance (static split).
    pub shard_rebalance_every: u64,
    /// Speculation topology (chain | tree{arity, depth} | adaptive). The
    /// node budget `S_i(t)` is allocated the same way either way; the
    /// shape decides how each client arranges the granted nodes.
    pub spec_shape: SpecShape,
    /// Scheduled client arrivals/departures (empty = static membership,
    /// which reproduces the pre-churn stack bit-for-bit).
    pub churn: ChurnSchedule,
    /// Scheduled faults (shard crashes, partitions, message bursts) the
    /// run must survive, applied at wave boundaries by both the live
    /// pool and the analytic simulator. Empty (the default) keeps every
    /// pre-chaos code path bit-identical.
    pub chaos: FaultSchedule,
    /// Request-level serving: per-client arrival processes, deadlines,
    /// and SLO accounting (`None` = the classic endless-stream run,
    /// bit-identical to the pre-trace stack).
    pub trace: Option<TraceConfig>,
    /// Streaming-aggregation metrics: recorders fold each wave into
    /// cumulative counters + a latency reservoir and trackers fold each
    /// finished request into a bounded sketch, instead of retaining every
    /// record — memory stays O(clients) for soak-length runs. `false`
    /// (default) retains everything; retained output is byte-identical
    /// to before this mode existed.
    pub stream_metrics: bool,
    /// Two-stage wave pipeline: run the verification forward on a
    /// dedicated stage thread while the coordinator overlaps fan-in
    /// draining and next-wave assembly (`coordinator/pipeline.rs`).
    /// `false` (default) keeps the serial loop; the pipelined path is
    /// bit-identical on RNG streams, wire bytes, and CSV output (pinned
    /// by `tests/pipeline_parity.rs`).
    pub pipelined: bool,
}

impl Scenario {
    /// Draft model for client `i`.
    pub fn draft_model(&self, i: usize) -> &str {
        &self.draft_models[i % self.draft_models.len()]
    }

    /// Primary domain for client `i`.
    pub fn domain(&self, i: usize) -> &str {
        &self.domains[i % self.domains.len()]
    }

    pub fn link(&self, i: usize) -> LinkConfig {
        self.links.get(i % self.links.len().max(1)).cloned().unwrap_or_default()
    }

    /// Sanity-check invariants shared by every consumer.
    pub fn validate(&self) -> Result<(), ConfigError> {
        let err = |msg: String| Err(ConfigError::Invalid(msg));
        if self.num_clients == 0 {
            return err("num_clients must be > 0".into());
        }
        if self.capacity == 0 {
            return err("capacity C must be > 0".into());
        }
        if self.max_draft == 0 || self.max_draft > 32 {
            return err("max_draft must be in 1..=32 (verify artifact K)".into());
        }
        if self.draft_models.is_empty() || self.domains.is_empty() {
            return err("draft_models and domains must be non-empty".into());
        }
        // Unknown domains used to panic deep inside the workload layer;
        // they are a configuration error and surface here instead.
        for d in &self.domains {
            if !crate::workload::domains::is_domain(d) {
                return err(format!(
                    "unknown domain '{d}' (known: {})",
                    crate::workload::domains::DOMAINS.join(", ")
                ));
            }
        }
        if let SpecShape::Tree { arity, depth } = self.spec_shape {
            if !(1..=8).contains(&arity) {
                return err("spec_shape tree arity must be in 1..=8".into());
            }
            if !(1..=32).contains(&depth) {
                return err("spec_shape tree depth must be in 1..=32".into());
            }
        }
        if !(0.0..=1.0).contains(&self.domain_stickiness) {
            return err("domain_stickiness must be in [0,1]".into());
        }
        if self.min_wave_fill > self.num_clients {
            return err("min_wave_fill must be <= num_clients (0 = all)".into());
        }
        if self.coord_mode == CoordMode::Async && self.batch_window_us > 10_000_000 {
            return err("batch_window_us must be <= 10s".into());
        }
        if self.num_verifiers == 0 {
            return err("num_verifiers must be > 0".into());
        }
        if self.num_verifiers > self.num_clients {
            return err("num_verifiers must be <= num_clients".into());
        }
        // Trace-driven runs compose with the sharded pool: each shard
        // drives its own RequestTracker partition on its own wave clock
        // and the per-shard reports merge in the recorder, so
        // num_verifiers > 1 with a trace is a supported configuration
        // (the pre-scale-out stack rejected it here).
        if let Some(trace) = &self.trace {
            if trace.slo_waves == 0 {
                return err("trace: slo_waves must be > 0".into());
            }
            match trace.arrival {
                ArrivalProcess::Poisson { mean_gap } => {
                    let ok = mean_gap.is_finite() && mean_gap > 0.0;
                    if !ok {
                        return err("trace: poisson mean_gap must be > 0".into());
                    }
                }
                ArrivalProcess::Bursty { mean_gap, burst } => {
                    let ok = mean_gap.is_finite() && mean_gap > 0.0;
                    if !ok {
                        return err("trace: bursty mean_gap must be > 0".into());
                    }
                    if burst == 0 {
                        return err("trace: bursty burst must be ≥ 1".into());
                    }
                }
                ArrivalProcess::File(_) => {}
                ArrivalProcess::FlashCrowd { mean_gap, surge, width, .. } => {
                    if !(mean_gap.is_finite() && mean_gap > 0.0) {
                        return err("trace: flash-crowd mean_gap must be > 0".into());
                    }
                    if !(surge.is_finite() && surge >= 1.0) {
                        return err("trace: flash-crowd surge must be ≥ 1".into());
                    }
                    if width == 0 {
                        return err("trace: flash-crowd width must be ≥ 1 wave".into());
                    }
                }
                ArrivalProcess::Diurnal { mean_gap, amplitude, period } => {
                    if !(mean_gap.is_finite() && mean_gap > 0.0) {
                        return err("trace: diurnal mean_gap must be > 0".into());
                    }
                    if !(0.0..1.0).contains(&amplitude) {
                        return err("trace: diurnal amplitude must be in [0, 1)".into());
                    }
                    if !(period.is_finite() && period > 0.0) {
                        return err("trace: diurnal period must be > 0 waves".into());
                    }
                }
            }
            if !matches!(trace.arrival, ArrivalProcess::File(_)) {
                if trace.output_tokens == 0 {
                    return err("trace: output_tokens must be > 0".into());
                }
                if trace.requests_per_client == 0 {
                    return err("trace: requests_per_client must be > 0".into());
                }
            }
        }
        // Churn schedule: joins must name known domains, leaves must name
        // client ids that exist by the time the event fires (ids are
        // assigned in order — initial clients, then one per join event).
        let mut known = self.num_clients;
        let mut gone: Vec<usize> = Vec::new();
        for ev in self.churn.sorted() {
            match ev.kind {
                ChurnKind::Join(ref spec) => {
                    if !crate::workload::domains::is_domain(&spec.domain) {
                        return err(format!("churn join: unknown domain '{}'", spec.domain));
                    }
                    known += 1;
                }
                ChurnKind::Leave(id) => {
                    if id >= known {
                        return err(format!(
                            "churn leave: client {id} does not exist at wave {} \
                             (only {known} ids assigned by then)",
                            ev.at_wave
                        ));
                    }
                    if gone.contains(&id) {
                        return err(format!("churn leave: client {id} departs twice"));
                    }
                    gone.push(id);
                }
            }
        }
        // Fault schedule: shard/client indices must exist and every
        // recovery/heal must follow its fault.
        if let Err(msg) = self.chaos.validate_for(self.num_clients, self.num_verifiers) {
            return err(msg);
        }
        Ok(())
    }

    /// Wave-fill threshold with the `0 = all clients` convention resolved.
    pub fn effective_wave_fill(&self) -> usize {
        if self.min_wave_fill == 0 {
            self.num_clients
        } else {
            self.min_wave_fill.min(self.num_clients)
        }
    }

    /// Default heterogeneous links: seeded spread of latency/bandwidth so
    /// draft servers are genuinely unequal (edge heterogeneity).
    pub fn default_links(n: usize, seed: u64) -> Vec<LinkConfig> {
        let mut rng = crate::util::Rng::new(seed ^ 0x6c696e6b);
        (0..n)
            .map(|_| LinkConfig {
                latency_s: 0.5e-3 + 1.5e-3 * rng.f64(),
                bandwidth_bps: (25.0 + 175.0 * rng.f64()) * 1e6 / 8.0,
                jitter: 0.05 + 0.1 * rng.f64(),
            })
            .collect()
    }

    /// The Table I presets (plus a tiny smoke preset for tests).
    pub fn preset(id: &str) -> Option<Scenario> {
        let seed = 2025;
        let base_domains: Vec<String> = DOMAINS.iter().map(|d| d.to_string()).collect();
        let mut s = match id {
            // Table I row 1: Qwen3-14B / Qwen3-0.6B, C ∈ {24,28}, 4 clients, 50 tok
            "qwen-4c-50" => Scenario {
                id: id.into(),
                family: "qwen".into(),
                num_clients: 4,
                capacity: 24,
                max_new_tokens: 50,
                draft_models: vec!["qwen-draft-06b".into()],
                domains: base_domains[..4].to_vec(),
                domain_stickiness: 0.85,
                eta: Smoothing::Fixed(0.3),
                beta: Smoothing::Fixed(0.5),
                max_draft: 32,
                rounds: 600,
                seed,
                links: Scenario::default_links(4, seed),
                coord_mode: CoordMode::Sync,
                batch_window_us: 500,
                min_wave_fill: 0,
                num_verifiers: 1,
                shard_rebalance_every: 0,
                spec_shape: SpecShape::Chain,
                churn: ChurnSchedule::default(),
                chaos: FaultSchedule::default(),
                trace: None,
                stream_metrics: false,
                pipelined: false,
            },
            // Table I row 2: Qwen3-14B / 0.6B+1.7B, C ∈ {16,20}, 8 clients, 150 tok
            "qwen-8c-150" => Scenario {
                id: id.into(),
                family: "qwen".into(),
                num_clients: 8,
                capacity: 20,
                max_new_tokens: 150,
                draft_models: vec!["qwen-draft-06b".into(), "qwen-draft-17b".into()],
                domains: base_domains.clone(),
                domain_stickiness: 0.85,
                eta: Smoothing::Fixed(0.3),
                beta: Smoothing::Fixed(0.5),
                max_draft: 32,
                rounds: 600,
                seed,
                links: Scenario::default_links(8, seed),
                coord_mode: CoordMode::Sync,
                batch_window_us: 500,
                min_wave_fill: 0,
                num_verifiers: 1,
                shard_rebalance_every: 0,
                spec_shape: SpecShape::Chain,
                churn: ChurnSchedule::default(),
                chaos: FaultSchedule::default(),
                trace: None,
                stream_metrics: false,
                pipelined: false,
            },
            // Table I row 3: Llama-70B / 1B+3B, C ∈ {16,20}, 8 clients, 150 tok
            "llama-8c-150" => Scenario {
                id: id.into(),
                family: "llama".into(),
                num_clients: 8,
                capacity: 20,
                max_new_tokens: 150,
                draft_models: vec!["llama-draft-1b".into(), "llama-draft-3b".into()],
                domains: base_domains,
                domain_stickiness: 0.85,
                eta: Smoothing::Fixed(0.3),
                beta: Smoothing::Fixed(0.5),
                max_draft: 32,
                rounds: 600,
                seed,
                links: Scenario::default_links(8, seed),
                coord_mode: CoordMode::Sync,
                batch_window_us: 500,
                min_wave_fill: 0,
                num_verifiers: 1,
                shard_rebalance_every: 0,
                spec_shape: SpecShape::Chain,
                churn: ChurnSchedule::default(),
                chaos: FaultSchedule::default(),
                trace: None,
                stream_metrics: false,
                pipelined: false,
            },
            // Fast preset for tests and smoke runs.
            "smoke" => Scenario {
                id: id.into(),
                family: "qwen".into(),
                num_clients: 2,
                capacity: 8,
                max_new_tokens: 20,
                draft_models: vec!["qwen-draft-06b".into()],
                domains: vec!["alpaca".into(), "gsm8k".into()],
                domain_stickiness: 0.9,
                eta: Smoothing::Fixed(0.3),
                beta: Smoothing::Fixed(0.5),
                max_draft: 16,
                rounds: 30,
                seed,
                links: Scenario::default_links(2, seed),
                coord_mode: CoordMode::Sync,
                batch_window_us: 500,
                min_wave_fill: 0,
                num_verifiers: 1,
                shard_rebalance_every: 0,
                spec_shape: SpecShape::Chain,
                churn: ChurnSchedule::default(),
                chaos: FaultSchedule::default(),
                trace: None,
                stream_metrics: false,
                pipelined: false,
            },
            // Straggler study: one client with a 10× slower uplink. In sync
            // mode every round stalls on that link; async mode lets the
            // three fast clients keep verifying (the Fig 3 motivation).
            "straggler" => {
                // Client 0: 10× the worst fast-link latency and a 10 Mbps
                // uplink, so it dominates every seeded fast link.
                let mut links = Scenario::default_links(4, seed);
                links[0].latency_s = 20e-3;
                links[0].bandwidth_bps = 10.0e6 / 8.0;
                Scenario {
                    id: id.into(),
                    family: "qwen".into(),
                    num_clients: 4,
                    capacity: 16,
                    max_new_tokens: 30,
                    draft_models: vec!["qwen-draft-06b".into()],
                    domains: base_domains[..4].to_vec(),
                    domain_stickiness: 0.85,
                    eta: Smoothing::Fixed(0.3),
                    beta: Smoothing::Fixed(0.5),
                    max_draft: 16,
                    rounds: 120,
                    seed,
                    links,
                    coord_mode: CoordMode::Sync,
                    batch_window_us: 2_000,
                    min_wave_fill: 2,
                    num_verifiers: 1,
                    shard_rebalance_every: 0,
                    spec_shape: SpecShape::Chain,
                    churn: ChurnSchedule::default(),
                    chaos: FaultSchedule::default(),
                    trace: None,
                    stream_metrics: false,
                    pipelined: false,
                }
            }
            // Sharded-pool scale-up study: 8 heterogeneous clients whose
            // round time is dominated by the uplink (4× the default seeded
            // latencies), served by M verification shards. The batching
            // window (20 ms) exceeds every RTT, so each wave is a true
            // barrier over the shard's members: with M = 1 that is the
            // globally straggler-coupled baseline, while M > 1 shards only
            // wait on their own members — aggregate goodput grows with M
            // and the hierarchical budget split keeps cross-shard fairness
            // near the single-verifier baseline.
            "sharded" => {
                let mut links = Scenario::default_links(8, seed);
                for l in links.iter_mut() {
                    l.latency_s *= 4.0;
                }
                Scenario {
                    id: id.into(),
                    family: "qwen".into(),
                    num_clients: 8,
                    capacity: 32,
                    max_new_tokens: 40,
                    draft_models: vec!["qwen-draft-06b".into(), "qwen-draft-17b".into()],
                    domains: base_domains,
                    domain_stickiness: 0.85,
                    eta: Smoothing::Fixed(0.3),
                    beta: Smoothing::Fixed(0.5),
                    max_draft: 16,
                    rounds: 80,
                    seed,
                    links,
                    coord_mode: CoordMode::Sync,
                    batch_window_us: 20_000,
                    min_wave_fill: 0,
                    num_verifiers: 2,
                    shard_rebalance_every: 16,
                    spec_shape: SpecShape::Chain,
                    churn: ChurnSchedule::default(),
                    chaos: FaultSchedule::default(),
                    trace: None,
                    stream_metrics: false,
                    pipelined: false,
                }
            }
            // Tree-speculation study: four clients drafting with the weak
            // nano model on moderate-acceptance domains — the α ≈ 0.45–0.6
            // regime where a binary profile's sibling retries raise the
            // per-level advance probability enough to beat the chain at
            // equal node budget (see `spec::expected_tree_goodput`).
            "tree" => Scenario {
                id: id.into(),
                family: "qwen".into(),
                num_clients: 4,
                capacity: 24,
                max_new_tokens: 40,
                draft_models: vec!["qwen-draft-nano".into()],
                domains: vec!["gsm8k".into(), "cnn".into(), "orca".into(), "arena".into()],
                domain_stickiness: 0.85,
                eta: Smoothing::Fixed(0.3),
                beta: Smoothing::Fixed(0.5),
                max_draft: 16,
                rounds: 200,
                seed,
                links: Scenario::default_links(4, seed),
                coord_mode: CoordMode::Sync,
                batch_window_us: 500,
                min_wave_fill: 0,
                num_verifiers: 1,
                shard_rebalance_every: 0,
                spec_shape: SpecShape::Tree { arity: 2, depth: 8 },
                churn: ChurnSchedule::default(),
                chaos: FaultSchedule::default(),
                trace: None,
                stream_metrics: false,
                pipelined: false,
            },
            // Dynamic-membership study: four resident clients, one extra
            // client joining a third of the way through the run, and one
            // resident departing at the two-thirds mark. Sync barrier so
            // live waves line up one-to-one with the analytic simulator's
            // rounds (the churn bench cross-checks the two).
            "churn" => {
                let mut s = Scenario {
                    id: id.into(),
                    family: "qwen".into(),
                    num_clients: 4,
                    capacity: 24,
                    max_new_tokens: 40,
                    draft_models: vec!["qwen-draft-06b".into()],
                    domains: base_domains[..4].to_vec(),
                    domain_stickiness: 0.85,
                    eta: Smoothing::Fixed(0.3),
                    beta: Smoothing::Fixed(0.5),
                    max_draft: 16,
                    rounds: 240,
                    seed,
                    links: Scenario::default_links(4, seed),
                    coord_mode: CoordMode::Sync,
                    batch_window_us: 500,
                    min_wave_fill: 0,
                    num_verifiers: 1,
                    shard_rebalance_every: 0,
                    spec_shape: SpecShape::Chain,
                    churn: ChurnSchedule::default(),
                    chaos: FaultSchedule::default(),
                    trace: None,
                    stream_metrics: false,
                    pipelined: false,
                };
                s.churn = ChurnSchedule {
                    events: vec![
                        ChurnEvent {
                            at_wave: 80,
                            kind: ChurnKind::Join(ClientSpec::new("qwen-draft-06b", "cnn")),
                        },
                        ChurnEvent { at_wave: 160, kind: ChurnKind::Leave(1) },
                    ],
                };
                s
            }
            // Request-level serving study: four clients with heterogeneous
            // acceptance rates (alpaca is easy for the draft, hle is the
            // long tail), open-loop Poisson arrivals, and a per-request
            // deadline. The run answers "how many of these users finish
            // within their SLO" — raw goodput alone cannot (see serve/).
            "trace" => Scenario {
                id: id.into(),
                family: "qwen".into(),
                num_clients: 4,
                capacity: 16,
                max_new_tokens: 40,
                draft_models: vec!["qwen-draft-06b".into()],
                domains: vec!["alpaca".into(), "cnn".into(), "gsm8k".into(), "hle".into()],
                domain_stickiness: 0.95,
                eta: Smoothing::Fixed(0.3),
                beta: Smoothing::Fixed(0.5),
                max_draft: 16,
                rounds: 240,
                seed,
                links: Scenario::default_links(4, seed),
                coord_mode: CoordMode::Sync,
                batch_window_us: 500,
                min_wave_fill: 0,
                num_verifiers: 1,
                shard_rebalance_every: 0,
                spec_shape: SpecShape::Chain,
                churn: ChurnSchedule::default(),
                chaos: FaultSchedule::default(),
                // Mean inter-arrival 28 waves vs ≈ 12–19-wave service
                // times: moderate utilization, so deadlines are met by
                // scheduling rather than luck, and all six requests per
                // client land well inside the 240-wave run.
                trace: Some(TraceConfig::poisson(28.0, 48)),
                stream_metrics: false,
                pipelined: false,
            },
            // 10k-session scale-out soak: open-loop Poisson arrivals over
            // M = 4 verification shards with streaming metrics, the shape
            // `goodspeed bench --soak` sweeps (it overrides the session
            // count and shard count per measurement point). Arrivals are
            // sparse per client (mean gap 64 waves) so the aggregate load
            // is carried by the population, not any single session, and
            // the budget floor of one token per member stays feasible.
            "soak" => Scenario {
                id: id.into(),
                family: "qwen".into(),
                num_clients: 10_000,
                capacity: 16_384,
                max_new_tokens: 24,
                draft_models: vec!["qwen-draft-06b".into()],
                domains: base_domains,
                domain_stickiness: 0.9,
                eta: Smoothing::Fixed(0.3),
                beta: Smoothing::Fixed(0.5),
                max_draft: 8,
                rounds: 400,
                seed,
                links: Vec::new(), // resized to the population below
                coord_mode: CoordMode::Sync,
                batch_window_us: 500,
                min_wave_fill: 0,
                num_verifiers: 4,
                shard_rebalance_every: 64,
                spec_shape: SpecShape::Chain,
                churn: ChurnSchedule::default(),
                chaos: FaultSchedule::default(),
                // Diurnal arrivals (mean gap 64 waves, ±50% rate swing
                // over a 200-wave period): the population-scale load
                // breathes the way real traffic does, exercising the
                // water-fill under both the peak and the trough.
                trace: Some(TraceConfig {
                    arrival: ArrivalProcess::Diurnal {
                        mean_gap: 64.0,
                        amplitude: 0.5,
                        period: 200.0,
                    },
                    slo_waves: 96,
                    output_tokens: 24,
                    requests_per_client: 6,
                }),
                stream_metrics: true,
                pipelined: false,
            },
            // Chaos study: the sharded pool under a scheduled shard
            // crash + recovery. Shard 1 dies a third of the way in; its
            // clients migrate to shard 0 (estimators re-seeded from the
            // population prior, freed budget water-filled) and the shard
            // is re-admitted at the halfway mark (a fenced shard slows
            // the pooled schedule clock to (M−1)/M, so a later recovery
            // could land after the budget is spent), repopulated by the
            // rebalancer (every 8 waves, so the recovery envelope closes
            // within the run). `benches/chaos.rs` asserts goodput and
            // Jain fairness re-enter a band around the pre-fault steady
            // state after both the crash and the heal.
            "chaos" => Scenario {
                id: id.into(),
                family: "qwen".into(),
                num_clients: 8,
                capacity: 32,
                max_new_tokens: 40,
                draft_models: vec!["qwen-draft-06b".into(), "qwen-draft-17b".into()],
                domains: DOMAINS.iter().map(|d| d.to_string()).collect(),
                domain_stickiness: 0.85,
                eta: Smoothing::Fixed(0.3),
                beta: Smoothing::Fixed(0.5),
                max_draft: 16,
                rounds: 180,
                seed,
                links: Scenario::default_links(8, seed),
                coord_mode: CoordMode::Sync,
                batch_window_us: 20_000,
                min_wave_fill: 0,
                num_verifiers: 2,
                shard_rebalance_every: 8,
                spec_shape: SpecShape::Chain,
                churn: ChurnSchedule::default(),
                chaos: FaultSchedule {
                    events: vec![FaultEvent {
                        at_wave: 60,
                        kind: FaultKind::ShardCrash { shard: 1, recover_wave: Some(90) },
                    }],
                },
                trace: None,
                stream_metrics: false,
                pipelined: false,
            },
            _ => return None,
        };
        s.validate().expect("preset must validate");
        if s.links.len() != s.num_clients {
            s.links = Scenario::default_links(s.num_clients, s.seed);
        }
        Some(s)
    }

    pub fn preset_ids() -> [&'static str; 11] {
        [
            "qwen-4c-50",
            "qwen-8c-150",
            "llama-8c-150",
            "smoke",
            "straggler",
            "sharded",
            "tree",
            "churn",
            "trace",
            "soak",
            "chaos",
        ]
    }

    /// Serialize for results provenance.
    pub fn to_json(&self) -> Value {
        Value::from_pairs(vec![
            ("id", Value::Str(self.id.clone())),
            ("family", Value::Str(self.family.clone())),
            ("num_clients", Value::Num(self.num_clients as f64)),
            ("capacity", Value::Num(self.capacity as f64)),
            ("max_new_tokens", Value::Num(self.max_new_tokens as f64)),
            (
                "draft_models",
                Value::Array(self.draft_models.iter().cloned().map(Value::Str).collect()),
            ),
            ("domains", Value::Array(self.domains.iter().cloned().map(Value::Str).collect())),
            ("rounds", Value::Num(self.rounds as f64)),
            ("seed", Value::Num(self.seed as f64)),
            ("coord_mode", Value::Str(self.coord_mode.name().into())),
            ("batch_window_us", Value::Num(self.batch_window_us as f64)),
            ("min_wave_fill", Value::Num(self.min_wave_fill as f64)),
            ("num_verifiers", Value::Num(self.num_verifiers as f64)),
            ("shard_rebalance_every", Value::Num(self.shard_rebalance_every as f64)),
            ("spec_shape", Value::Str(self.spec_shape.label())),
            ("churn_events", Value::Num(self.churn.events.len() as f64)),
            ("chaos_events", Value::Num(self.chaos.events.len() as f64)),
            ("stream_metrics", Value::Bool(self.stream_metrics)),
            ("pipelined", Value::Bool(self.pipelined)),
            (
                "trace",
                match &self.trace {
                    None => Value::Null,
                    Some(t) => Value::from_pairs(vec![
                        ("arrival", Value::Str(t.arrival.label())),
                        ("slo_waves", Value::Num(t.slo_waves as f64)),
                        ("output_tokens", Value::Num(t.output_tokens as f64)),
                        ("requests_per_client", Value::Num(t.requests_per_client as f64)),
                    ]),
                },
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for id in Scenario::preset_ids() {
            let s = Scenario::preset(id).unwrap();
            assert!(s.validate().is_ok(), "{id}");
            assert_eq!(s.links.len(), s.num_clients);
        }
        assert!(Scenario::preset("nope").is_none());
    }

    #[test]
    fn table1_rows_match_paper() {
        let q4 = Scenario::preset("qwen-4c-50").unwrap();
        assert_eq!((q4.num_clients, q4.max_new_tokens), (4, 50));
        assert!([24, 28].contains(&q4.capacity));
        let q8 = Scenario::preset("qwen-8c-150").unwrap();
        assert_eq!((q8.num_clients, q8.max_new_tokens), (8, 150));
        assert!([16, 20].contains(&q8.capacity));
        assert_eq!(q8.draft_models.len(), 2); // 0.6B + 1.7B mix
        let l8 = Scenario::preset("llama-8c-150").unwrap();
        assert_eq!(l8.family, "llama");
        assert_eq!(l8.num_clients, 8);
    }

    #[test]
    fn cycling_accessors() {
        let s = Scenario::preset("qwen-8c-150").unwrap();
        assert_eq!(s.draft_model(0), "qwen-draft-06b");
        assert_eq!(s.draft_model(1), "qwen-draft-17b");
        assert_eq!(s.draft_model(2), "qwen-draft-06b");
        assert_eq!(s.domain(0), "alpaca");
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut s = Scenario::preset("smoke").unwrap();
        s.capacity = 0;
        assert!(s.validate().is_err());
        let mut s = Scenario::preset("smoke").unwrap();
        s.max_draft = 40;
        assert!(s.validate().is_err());
        let mut s = Scenario::preset("smoke").unwrap();
        s.domain_stickiness = 1.5;
        assert!(s.validate().is_err());
    }

    #[test]
    fn smoothing_schedules() {
        let f = Smoothing::Fixed(0.5);
        assert_eq!(f.at(1), 0.5);
        assert_eq!(f.at(1000), 0.5);
        let d = Smoothing::Decay { c: 1.0, p: 0.6 };
        assert!(d.at(1) > d.at(10));
        assert!(d.at(10) > d.at(1000));
        assert!(d.at(u64::MAX) >= 1e-4);
    }

    #[test]
    fn links_are_heterogeneous_and_deterministic() {
        let a = Scenario::default_links(8, 1);
        let b = Scenario::default_links(8, 1);
        let c = Scenario::default_links(8, 2);
        assert_eq!(a.len(), 8);
        assert!((a[0].latency_s - b[0].latency_s).abs() < 1e-15);
        assert!((a[0].latency_s - c[0].latency_s).abs() > 1e-9);
        assert!(a.iter().any(|l| (l.latency_s - a[0].latency_s).abs() > 1e-6));
    }

    #[test]
    fn coord_mode_parse_and_defaults() {
        assert_eq!("sync".parse(), Ok(CoordMode::Sync));
        assert_eq!("Async".parse(), Ok(CoordMode::Async));
        assert_eq!("wave".parse(), Ok(CoordMode::Async));
        let err = "nope".parse::<CoordMode>().unwrap_err().to_string();
        assert!(err.contains("sync, async"), "{err}");
        // Every preset defaults to the barrier so existing experiments
        // reproduce bit-for-bit.
        for id in Scenario::preset_ids() {
            assert_eq!(Scenario::preset(id).unwrap().coord_mode, CoordMode::Sync, "{id}");
        }
    }

    #[test]
    fn straggler_preset_has_one_slow_link() {
        let s = Scenario::preset("straggler").unwrap();
        assert_eq!(s.num_clients, 4);
        let slow = s.links[0].latency_s;
        for l in &s.links[1..] {
            assert!(slow > 5.0 * l.latency_s, "client 0 must dominate: {slow} vs {}", l.latency_s);
        }
        assert_eq!(s.effective_wave_fill(), 2);
    }

    #[test]
    fn wave_fill_validation_and_resolution() {
        let mut s = Scenario::preset("smoke").unwrap();
        assert_eq!(s.effective_wave_fill(), s.num_clients); // 0 = all
        s.min_wave_fill = s.num_clients + 1;
        assert!(s.validate().is_err());
        s.min_wave_fill = 1;
        assert!(s.validate().is_ok());
        assert_eq!(s.effective_wave_fill(), 1);
        s.coord_mode = CoordMode::Async;
        s.batch_window_us = 20_000_000;
        assert!(s.validate().is_err());
    }

    #[test]
    fn sharded_preset_and_verifier_validation() {
        let s = Scenario::preset("sharded").unwrap();
        assert_eq!(s.num_clients, 8);
        assert_eq!(s.num_verifiers, 2);
        assert_eq!(s.shard_rebalance_every, 16);
        // Every preset outside the sharded trio stays single-verifier so
        // existing experiments reproduce bit-for-bit.
        for id in Scenario::preset_ids() {
            let p = Scenario::preset(id).unwrap();
            if id != "sharded" && id != "soak" && id != "chaos" {
                assert_eq!(p.num_verifiers, 1, "{id}");
            }
        }
        let mut bad = Scenario::preset("smoke").unwrap();
        bad.num_verifiers = 0;
        assert!(bad.validate().is_err());
        bad.num_verifiers = bad.num_clients + 1;
        assert!(bad.validate().is_err());
        bad.num_verifiers = bad.num_clients;
        assert!(bad.validate().is_ok());
    }

    #[test]
    fn spec_shape_parse_label_roundtrip() {
        assert_eq!("chain".parse(), Ok(SpecShape::Chain));
        assert_eq!("Adaptive".parse(), Ok(SpecShape::Adaptive));
        assert_eq!("tree".parse(), Ok(SpecShape::Tree { arity: 2, depth: 8 }));
        assert_eq!("tree:3x4".parse(), Ok(SpecShape::Tree { arity: 3, depth: 4 }));
        assert!("tree:x4".parse::<SpecShape>().is_err());
        let err = "bush".parse::<SpecShape>().unwrap_err().to_string();
        assert!(err.contains("unknown spec shape 'bush'"), "{err}");
        assert!(err.contains("tree:<arity>x<depth>"), "{err}");
        for shape in [
            SpecShape::Chain,
            SpecShape::Adaptive,
            SpecShape::Tree { arity: 3, depth: 5 },
        ] {
            assert_eq!(shape.label().parse(), Ok(shape));
        }
        assert!(SpecShape::Chain.is_chain());
        assert!(!SpecShape::Adaptive.is_chain());
    }

    #[test]
    fn tree_preset_and_shape_validation() {
        let t = Scenario::preset("tree").unwrap();
        assert_eq!(t.spec_shape, SpecShape::Tree { arity: 2, depth: 8 });
        assert_eq!(t.num_clients, 4);
        // Every other preset stays on the chain so existing experiments
        // reproduce bit-for-bit.
        for id in Scenario::preset_ids() {
            let p = Scenario::preset(id).unwrap();
            if id != "tree" {
                assert_eq!(p.spec_shape, SpecShape::Chain, "{id}");
            }
        }
        let mut bad = Scenario::preset("smoke").unwrap();
        bad.spec_shape = SpecShape::Tree { arity: 0, depth: 4 };
        assert!(bad.validate().is_err());
        bad.spec_shape = SpecShape::Tree { arity: 2, depth: 0 };
        assert!(bad.validate().is_err());
        bad.spec_shape = SpecShape::Tree { arity: 9, depth: 4 };
        assert!(bad.validate().is_err());
        bad.spec_shape = SpecShape::Tree { arity: 4, depth: 4 };
        assert!(bad.validate().is_ok());
    }

    #[test]
    fn validation_rejects_unknown_domains() {
        let mut s = Scenario::preset("smoke").unwrap();
        s.domains = vec!["alpaca".into(), "not-a-domain".into()];
        let err = s.validate().unwrap_err().to_string();
        assert!(err.contains("unknown domain 'not-a-domain'"), "{err}");
        assert!(err.contains("alpaca"), "should list known domains: {err}");
    }

    #[test]
    fn policy_parse() {
        assert_eq!("GoodSpeed".parse(), Ok(Policy::GoodSpeed));
        assert_eq!("fixed-s".parse(), Ok(Policy::FixedS));
        assert_eq!("random".parse(), Ok(Policy::RandomS));
        let err = "zzz".parse::<Policy>().unwrap_err().to_string();
        assert!(err.contains("unknown policy 'zzz'"), "{err}");
        assert!(err.contains("goodspeed, fixed-s, random-s"), "{err}");
    }

    #[test]
    fn churn_preset_and_schedule_validation() {
        let s = Scenario::preset("churn").unwrap();
        assert_eq!(s.churn.events.len(), 2);
        assert_eq!(s.churn.join_count(), 1);
        // Every other preset stays static so existing experiments
        // reproduce bit-for-bit.
        for id in Scenario::preset_ids() {
            let p = Scenario::preset(id).unwrap();
            if id != "churn" {
                assert!(p.churn.is_empty(), "{id}");
            }
        }
        // Leave of a never-assigned id rejected.
        let mut bad = Scenario::preset("smoke").unwrap();
        bad.churn.events.push(ChurnEvent { at_wave: 5, kind: ChurnKind::Leave(7) });
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("does not exist"), "{err}");
        // A join before the leave makes the id legal.
        let mut ok = Scenario::preset("smoke").unwrap();
        ok.churn.events.push(ChurnEvent {
            at_wave: 2,
            kind: ChurnKind::Join(ClientSpec::new("qwen-draft-06b", "alpaca")),
        });
        ok.churn.events.push(ChurnEvent { at_wave: 5, kind: ChurnKind::Leave(2) });
        assert!(ok.validate().is_ok());
        // Unknown join domain rejected; double departure rejected.
        let mut bad = Scenario::preset("smoke").unwrap();
        bad.churn.events.push(ChurnEvent {
            at_wave: 1,
            kind: ChurnKind::Join(ClientSpec::new("qwen-draft-06b", "not-a-domain")),
        });
        assert!(bad.validate().is_err());
        let mut bad = Scenario::preset("smoke").unwrap();
        bad.churn.events.push(ChurnEvent { at_wave: 1, kind: ChurnKind::Leave(0) });
        bad.churn.events.push(ChurnEvent { at_wave: 2, kind: ChurnKind::Leave(0) });
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("departs twice"), "{err}");
    }

    #[test]
    fn trace_preset_and_validation() {
        let t = Scenario::preset("trace").unwrap();
        let trace = t.trace.clone().expect("trace preset carries a trace config");
        assert_eq!(trace.arrival, ArrivalProcess::Poisson { mean_gap: 28.0 });
        assert_eq!(trace.slo_waves, 48);
        // Every preset outside the trace-driven pair stays request-free
        // so existing experiments reproduce bit-for-bit.
        for id in Scenario::preset_ids() {
            let p = Scenario::preset(id).unwrap();
            if id != "trace" && id != "soak" {
                assert!(p.trace.is_none(), "{id}");
            }
        }
        // Traces compose with the sharded pool (each shard drives its own
        // tracker partition), so the historic M = 1 restriction is gone.
        let mut pooled = Scenario::preset("trace").unwrap();
        pooled.num_verifiers = 2;
        assert!(pooled.validate().is_ok());
        // Degenerate knobs are rejected.
        let mut bad = Scenario::preset("trace").unwrap();
        bad.trace.as_mut().unwrap().slo_waves = 0;
        assert!(bad.validate().is_err());
        let mut bad = Scenario::preset("trace").unwrap();
        bad.trace.as_mut().unwrap().arrival = ArrivalProcess::Poisson { mean_gap: 0.0 };
        assert!(bad.validate().is_err());
        let mut bad = Scenario::preset("trace").unwrap();
        bad.trace.as_mut().unwrap().arrival = ArrivalProcess::Bursty { mean_gap: 4.0, burst: 0 };
        assert!(bad.validate().is_err());
        let mut bad = Scenario::preset("trace").unwrap();
        bad.trace.as_mut().unwrap().output_tokens = 0;
        assert!(bad.validate().is_err());
        // File traces skip the generator-knob checks.
        let mut ok = Scenario::preset("trace").unwrap();
        let t = ok.trace.as_mut().unwrap();
        t.arrival = ArrivalProcess::File("trace.json".into());
        t.output_tokens = 0;
        t.requests_per_client = 0;
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn soak_preset_shape() {
        let s = Scenario::preset("soak").unwrap();
        assert_eq!(s.num_clients, 10_000);
        assert_eq!(s.num_verifiers, 4);
        assert!(s.stream_metrics, "soak runs with bounded metrics");
        assert!(s.trace.is_some(), "soak is trace-driven");
        assert_eq!(s.links.len(), s.num_clients);
        // Every other preset keeps retained metrics, whose output is
        // byte-identical to the pre-streaming stack.
        for id in Scenario::preset_ids() {
            if id != "soak" {
                assert!(!Scenario::preset(id).unwrap().stream_metrics, "{id}");
            }
        }
    }

    #[test]
    fn arrival_process_parse_label_roundtrip() {
        assert_eq!("poisson:12.5".parse(), Ok(ArrivalProcess::Poisson { mean_gap: 12.5 }));
        assert_eq!("Bursty:8x3".parse(), Ok(ArrivalProcess::Bursty { mean_gap: 8.0, burst: 3 }));
        assert_eq!(
            "flash-crowd:24x8@60+30".parse(),
            Ok(ArrivalProcess::FlashCrowd { mean_gap: 24.0, surge: 8.0, at: 60, width: 30 })
        );
        assert_eq!(
            "diurnal:64x0.5@200".parse(),
            Ok(ArrivalProcess::Diurnal { mean_gap: 64.0, amplitude: 0.5, period: 200.0 })
        );
        assert!("poisson".parse::<ArrivalProcess>().is_err());
        assert!("bursty:8".parse::<ArrivalProcess>().is_err());
        assert!("flash-crowd:24x8".parse::<ArrivalProcess>().is_err(), "window is required");
        assert!("flash-crowd:24x8@60".parse::<ArrivalProcess>().is_err(), "width is required");
        assert!("diurnal:64x0.5".parse::<ArrivalProcess>().is_err(), "period is required");
        let err = "closed".parse::<ArrivalProcess>().unwrap_err().to_string();
        assert!(err.contains("poisson:<mean_gap>"), "{err}");
        assert!(err.contains("flash-crowd:"), "typo help must list flash-crowd: {err}");
        for a in [
            ArrivalProcess::Poisson { mean_gap: 20.0 },
            ArrivalProcess::Bursty { mean_gap: 6.0, burst: 4 },
            ArrivalProcess::FlashCrowd { mean_gap: 24.0, surge: 8.0, at: 60, width: 30 },
            ArrivalProcess::Diurnal { mean_gap: 64.0, amplitude: 0.5, period: 200.0 },
        ] {
            assert_eq!(a.label().parse(), Ok(a));
        }
    }

    #[test]
    fn flash_crowd_and_diurnal_validation() {
        let with = |arrival: ArrivalProcess| {
            let mut s = Scenario::preset("trace").unwrap();
            s.trace.as_mut().unwrap().arrival = arrival;
            s
        };
        let ok = ArrivalProcess::FlashCrowd { mean_gap: 24.0, surge: 8.0, at: 60, width: 30 };
        assert!(with(ok).validate().is_ok());
        let bad = ArrivalProcess::FlashCrowd { mean_gap: 0.0, surge: 8.0, at: 60, width: 30 };
        assert!(with(bad).validate().is_err());
        let bad = ArrivalProcess::FlashCrowd { mean_gap: 24.0, surge: 0.5, at: 60, width: 30 };
        assert!(with(bad).validate().is_err(), "surge < 1 would be an anti-crowd");
        let bad = ArrivalProcess::FlashCrowd { mean_gap: 24.0, surge: 8.0, at: 60, width: 0 };
        assert!(with(bad).validate().is_err());
        let ok = ArrivalProcess::Diurnal { mean_gap: 64.0, amplitude: 0.5, period: 200.0 };
        assert!(with(ok).validate().is_ok());
        let bad = ArrivalProcess::Diurnal { mean_gap: 64.0, amplitude: 1.0, period: 200.0 };
        assert!(with(bad).validate().is_err(), "amplitude 1 zeroes the trough rate");
        let bad = ArrivalProcess::Diurnal { mean_gap: 64.0, amplitude: 0.5, period: 0.0 };
        assert!(with(bad).validate().is_err());
        // The soak preset rides the diurnal process.
        let soak = Scenario::preset("soak").unwrap();
        assert!(matches!(
            soak.trace.unwrap().arrival,
            ArrivalProcess::Diurnal { amplitude, .. } if amplitude > 0.0
        ));
    }

    #[test]
    fn chaos_preset_and_schedule_validation() {
        use crate::chaos::{FaultEvent, FaultKind};
        let s = Scenario::preset("chaos").unwrap();
        assert_eq!(s.num_verifiers, 2);
        assert_eq!(s.chaos.events.len(), 1);
        assert_eq!(s.chaos.crash_count(), 1);
        match s.chaos.events[0].kind {
            FaultKind::ShardCrash { shard, recover_wave } => {
                assert_eq!(shard, 1);
                assert_eq!(recover_wave, Some(90));
            }
            ref other => panic!("chaos preset must schedule a crash, got {other:?}"),
        }
        // Every other preset stays fault-free so existing experiments
        // reproduce bit-for-bit.
        for id in Scenario::preset_ids() {
            let p = Scenario::preset(id).unwrap();
            if id != "chaos" {
                assert!(p.chaos.is_empty(), "{id}");
            }
        }
        // Validation rejects crashes without a survivor, out-of-range
        // shards, and inverted recovery times.
        let mut bad = Scenario::preset("smoke").unwrap();
        bad.chaos.events.push(FaultEvent {
            at_wave: 5,
            kind: FaultKind::ShardCrash { shard: 0, recover_wave: None },
        });
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("num_verifiers"), "{err}");
        let mut bad = Scenario::preset("chaos").unwrap();
        bad.chaos.events[0].kind = FaultKind::ShardCrash { shard: 2, recover_wave: None };
        assert!(bad.validate().is_err());
        let mut bad = Scenario::preset("chaos").unwrap();
        bad.chaos.events[0].kind = FaultKind::ShardCrash { shard: 1, recover_wave: Some(60) };
        assert!(bad.validate().is_err());
        let mut bad = Scenario::preset("chaos").unwrap();
        bad.chaos.events.push(FaultEvent {
            at_wave: 10,
            kind: FaultKind::Partition { client: 99, heal_wave: 20 },
        });
        assert!(bad.validate().is_err());
        // The demo schedule validates on the preset it is derived from.
        let mut s = Scenario::preset("sharded").unwrap();
        s.chaos = crate::chaos::FaultSchedule::demo(&s);
        assert!(s.validate().is_ok());
    }

    #[test]
    fn turbo_policy_parse_and_name() {
        assert_eq!("turbo".parse(), Ok(Policy::Turbo));
        assert_eq!("TurboSpec".parse(), Ok(Policy::Turbo));
        assert_eq!(Policy::Turbo.name(), "turbo");
        // The paper sweep stays the paper's three policies.
        assert!(!Policy::all().contains(&Policy::Turbo));
        let err = "zzz".parse::<Policy>().unwrap_err().to_string();
        assert!(err.contains("turbo"), "typo help must list turbo: {err}");
    }

    #[test]
    fn churn_demo_schedule_is_well_formed() {
        let mut s = Scenario::preset("smoke").unwrap();
        s.churn = ChurnSchedule::demo(&s);
        assert!(s.validate().is_ok());
        assert_eq!(s.churn.join_count(), 1);
        let sorted = s.churn.sorted();
        assert!(sorted[0].at_wave <= sorted[1].at_wave);
    }
}
