//! Wave batcher: assembles one wave's draft messages — any subset of
//! clients — into one batched [`VerifyRequest`] (paper step ③).
//!
//! Sync mode passes all N clients every round; async mode passes whichever
//! subset was ready when the wave fired. Row `b` of the request maps to
//! `views[b].client_id` (the client-subset → row mapping).
//!
//! Layout contract with `python/compile/model.py::verify_graph`:
//! * row b = client b (fixed order); `tokens[b] = prefix ++ draft`, padded;
//! * `draft_tok[b, j]` = j-th drafted node, `q_probs[b, j]` its proposal
//!   distribution, `parent[b, j]` its parent draft position (−1 = rooted
//!   at the prefix) — a chain is `parent[j] = j − 1`;
//! * **variable-length trick**: for positions without a real node the q
//!   rows are all-zero, so the graph's residual `max(0, p − q)/Σ` reduces
//!   to exactly `p` — those rows therefore *are* bonus/correction
//!   distributions. The chain uses one such row at `j = S`; a tree gets
//!   one **phantom row per leaf** (rows `n .. n + L`, each parented on its
//!   leaf; see `spec/tree.rs`), so a single static-shape artifact serves
//!   heterogeneous draft lengths *and* heterogeneous topologies (the
//!   uniform-length SD-batching limitation called out in §II-C).

use anyhow::{anyhow, Result};

use crate::net::wire::DraftMsg;
use crate::runtime::{pick_bucket, VerifyRequest};
use crate::spec::tree::{DraftTree, NO_PARENT};

/// Per-client view the leader keeps for the wave. Row `b` of the verify
/// request corresponds to `views[b]`; `client_id` is the *actual* client,
/// not the row index.
#[derive(Clone, Debug, PartialEq)]
pub struct ClientRound {
    pub client_id: usize,
    pub prefix_len: usize,
    /// Drafted nodes this round (chain: the draft length).
    pub draft_len: usize,
    /// The draft's topology (chain for legacy messages). The verdict path
    /// and phantom bonus rows are derived from this.
    pub tree: DraftTree,
    /// Whether the message carried an explicit topology (tree-mode client)
    /// — chain messages keep the legacy verify/RNG path bit-for-bit.
    pub explicit_tree: bool,
    pub new_request: bool,
    pub draft_wall_ns: u64,
}

/// Reusable per-wave buffers: the batched [`VerifyRequest`] plus the
/// per-client views, recycled across waves so steady-state assembly never
/// touches the heap.
///
/// * Request buffers are `clear()` + `resize()`d each wave — within the
///   high-water capacity that is a pure memset, no allocation.
/// * Each view slot caches its [`DraftTree`]: when a client redrafts the
///   same shape (chain of the same length, or an identical explicit
///   parent array — the steady state), the topology and its derived
///   tables are reused instead of rebuilt.
///
/// After a failed build the arena contents are unspecified; the next
/// successful [`build_verify_request_into`] fully rewrites them.
#[derive(Debug, Default)]
pub struct WaveArena {
    /// The request assembled by the latest successful build.
    pub req: VerifyRequest,
    /// Row `b` of `req` maps to `views[b]` (latest successful build).
    pub views: Vec<ClientRound>,
}

impl WaveArena {
    pub fn new() -> WaveArena {
        WaveArena::default()
    }
}

/// Build the batched request for one wave. `msgs` holds one message per
/// *participating* client in strictly increasing client-id order (any
/// subset; a full round is simply the subset of everyone).
///
/// Convenience wrapper over [`build_verify_request_into`] that allocates
/// fresh buffers; the wave hot path keeps a [`WaveArena`] instead.
pub fn build_verify_request(
    msgs: &[DraftMsg],
    buckets: &[(usize, usize)],
    k: usize,
    vocab: usize,
) -> Result<(VerifyRequest, Vec<ClientRound>)> {
    let mut arena = WaveArena::new();
    build_verify_request_into(msgs, buckets, k, vocab, &mut arena)?;
    Ok((arena.req, arena.views))
}

/// Assemble one wave's batched request into `arena`, reusing its buffers
/// and cached topologies (see [`WaveArena`]). On success `arena.req` /
/// `arena.views` describe this wave; on error their contents are
/// unspecified.
pub fn build_verify_request_into(
    msgs: &[DraftMsg],
    buckets: &[(usize, usize)],
    k: usize,
    vocab: usize,
    arena: &mut WaveArena,
) -> Result<()> {
    let n = msgs.len();
    if n == 0 {
        return Err(anyhow!("empty wave"));
    }
    let mut need_seq = 0usize;
    for (b, m) in msgs.iter().enumerate() {
        let i = m.client_id as usize;
        if b > 0 && msgs[b - 1].client_id >= m.client_id {
            return Err(anyhow!(
                "wave must be strictly increasing by client id ({} then {})",
                msgs[b - 1].client_id,
                m.client_id
            ));
        }
        if m.draft.len() > k {
            return Err(anyhow!("client {i}: draft {} > K {k}", m.draft.len()));
        }
        if m.q_probs.len() != m.draft.len() * vocab {
            return Err(anyhow!("client {i}: q_probs len mismatch"));
        }
        if m.prefix.is_empty() {
            return Err(anyhow!("client {i}: empty prefix"));
        }
        if !m.parents.is_empty() && m.parents.len() != m.draft.len() {
            return Err(anyhow!(
                "client {i}: {} parents for {} nodes",
                m.parents.len(),
                m.draft.len()
            ));
        }
        // Shape cache: reuse the slot's topology when this wave redrafts
        // the same shape (chains only need a matching length; explicit
        // trees need an identical parent array).
        let reuse = match arena.views.get(b) {
            Some(v) if m.parents.is_empty() => {
                v.tree.is_chain() && v.tree.len() == m.draft.len()
            }
            Some(v) => v.explicit_tree && v.tree.parents() == &m.parents[..],
            None => false,
        };
        let rebuilt = if reuse {
            None
        } else if m.parents.is_empty() {
            Some(DraftTree::chain(m.draft.len()))
        } else {
            Some(
                DraftTree::from_parents(m.parents.clone())
                    .map_err(|e| anyhow!("client {i}: bad topology: {e}"))?,
            )
        };
        if !m.parents.is_empty() {
            // Real nodes + one phantom bonus row per leaf must fit the
            // artifact's K rows (the chain's `S = K` special case instead
            // uses the dedicated bonus output). Re-checked on cache hits
            // too: K is a parameter, not part of the cache key.
            let rows = match &rebuilt {
                Some(t) => t.rows_needed(),
                None => arena.views[b].tree.rows_needed(),
            };
            if rows > k {
                return Err(anyhow!(
                    "client {i}: tree needs {rows} rows (nodes + leaves) > K {k}"
                ));
            }
        }
        // Row must hold prefix + draft; the graph gathers up to
        // pos0 + S_i − 1 (bonus-trick row S_i gathers pos0 + S_i − 1).
        need_seq = need_seq.max(m.prefix.len() + m.draft.len().max(1));
        if b < arena.views.len() {
            let v = &mut arena.views[b];
            v.client_id = i;
            v.prefix_len = m.prefix.len();
            v.draft_len = m.draft.len();
            if let Some(t) = rebuilt {
                v.tree = t;
            }
            v.explicit_tree = !m.parents.is_empty();
            v.new_request = m.new_request;
            v.draft_wall_ns = m.draft_wall_ns;
        } else {
            arena.views.push(ClientRound {
                client_id: i,
                prefix_len: m.prefix.len(),
                draft_len: m.draft.len(),
                tree: rebuilt.expect("fresh slot always rebuilds its tree"),
                explicit_tree: !m.parents.is_empty(),
                new_request: m.new_request,
                draft_wall_ns: m.draft_wall_ns,
            });
        }
    }
    arena.views.truncate(n);
    let (bb, bs) = pick_bucket(buckets, n, need_seq);
    if n > bb || need_seq > bs {
        return Err(anyhow!("round (n={n}, seq={need_seq}) exceeds largest bucket ({bb},{bs})"));
    }

    // Disjoint borrows: request buffers get rewritten while the cached
    // trees in `views` are read.
    let WaveArena { req, views } = arena;
    req.batch = n;
    req.seq = bs;
    req.k = k;
    req.vocab = vocab;
    req.tokens.clear();
    req.tokens.resize(n * bs, 0);
    req.draft_tok.clear();
    req.draft_tok.resize(n * k, 0);
    // All-zero q rows by default — the variable-length/bonus trick.
    req.q_probs.clear();
    req.q_probs.resize(n * k * vocab, 0.0);
    req.pos0.clear();
    req.pos0.resize(n, 0);
    req.parent.clear();
    req.parent.resize(n * k, 0);
    for (b, m) in msgs.iter().enumerate() {
        let tree = &views[b].tree;
        let p = m.prefix.len();
        for (i, &t) in m.prefix.iter().enumerate() {
            req.tokens[b * bs + i] = t as i32;
        }
        for (j, &t) in m.draft.iter().enumerate() {
            req.tokens[b * bs + p + j] = t as i32;
            req.draft_tok[b * k + j] = t as i32;
        }
        req.q_probs[(b * k) * vocab..(b * k + m.draft.len()) * vocab]
            .copy_from_slice(&m.q_probs);
        req.pos0[b] = p as i32;
        // Parent layout: real nodes, then one phantom row per leaf
        // (parented on its leaf — all-zero q ⇒ its residual is the leaf's
        // bonus distribution), then chain-continuation padding. A chain
        // message reduces to `parent[j] = j − 1` on every row — the exact
        // pre-tree linear contexts.
        let nodes = tree.len();
        for (j, &pp) in tree.parents().iter().enumerate() {
            req.parent[b * k + j] = if pp == NO_PARENT { -1 } else { pp as i32 };
        }
        let mut row = nodes;
        if nodes == 0 {
            // The empty tree's phantom roots at the prefix (row 0).
            req.parent[b * k] = -1;
            row = 1;
        } else {
            for leaf in 0..nodes {
                // `row == k` only for a full-K chain, whose bonus comes
                // from the dedicated engine output instead of a phantom
                // row (explicit trees always fit: rows_needed ≤ k).
                if tree.children(leaf).is_empty() && row < k {
                    debug_assert_eq!(tree.bonus_row(leaf), row);
                    req.parent[b * k + row] = leaf as i32;
                    row += 1;
                }
            }
        }
        for j in row..k {
            req.parent[b * k + j] = j as i32 - 1;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(id: u32, prefix: &[u8], draft: &[u8], vocab: usize) -> DraftMsg {
        DraftMsg {
            client_id: id,
            round: 0,
            prefix: prefix.to_vec(),
            prompt_len: prefix.len() as u32,
            draft: draft.to_vec(),
            parents: Vec::new(),
            q_probs: vec![1.0 / vocab as f32; draft.len() * vocab],
            new_request: false,
            draft_wall_ns: 0,
        }
    }

    fn tree_msg(id: u32, prefix: &[u8], draft: &[u8], parents: &[u8], vocab: usize) -> DraftMsg {
        let mut m = msg(id, prefix, draft, vocab);
        m.parents = parents.to_vec();
        m
    }

    const BUCKETS: &[(usize, usize)] = &[(4, 128), (4, 256), (8, 128), (8, 256)];

    #[test]
    fn layout_matches_contract() {
        let v = 16;
        let msgs =
            vec![msg(0, &[1, 2, 3], &[10, 11], v), msg(1, &[4, 5], &[20, 21, 22], v)];
        let (req, views) = build_verify_request(&msgs, BUCKETS, 8, v).unwrap();
        assert_eq!(req.batch, 2);
        assert_eq!(req.seq, 128);
        assert_eq!(req.pos0, vec![3, 2]);
        // tokens row 0: prefix then draft then zero padding
        assert_eq!(&req.tokens[0..6], &[1, 2, 3, 10, 11, 0]);
        assert_eq!(&req.tokens[128..133], &[4, 5, 20, 21, 22]);
        assert_eq!(req.draft_tok[0..3], [10, 11, 0]);
        assert_eq!(req.draft_tok[8..12], [20, 21, 22, 0]);
        // chain parent layout on every row
        assert_eq!(&req.parent[0..8], &[-1, 0, 1, 2, 3, 4, 5, 6]);
        assert_eq!(&req.parent[8..16], &[-1, 0, 1, 2, 3, 4, 5, 6]);
        // q rows beyond S are zero (bonus trick)
        let row2 = &req.q_probs[(0 * 8 + 2) * v..(0 * 8 + 3) * v];
        assert!(row2.iter().all(|&x| x == 0.0));
        let row1 = &req.q_probs[(0 * 8 + 1) * v..(0 * 8 + 2) * v];
        assert!((row1.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert_eq!(views[1].draft_len, 3);
        assert!(views[0].tree.is_chain());
        assert!(!views[0].explicit_tree);
    }

    #[test]
    fn tree_layout_adds_phantom_bonus_rows() {
        let v = 16;
        // Root → {0, 1}, 1 → {2}: leaves are 0 and 2 → phantom rows 3, 4.
        let parents = [255u8, 255, 1];
        let msgs = vec![tree_msg(0, &[1, 2], &[10, 11, 12], &parents, v)];
        let (req, views) = build_verify_request(&msgs, BUCKETS, 8, v).unwrap();
        assert_eq!(&req.parent[0..8], &[-1, -1, 1, 0, 2, 4, 5, 6]);
        assert!(views[0].explicit_tree);
        assert_eq!(views[0].tree.num_leaves(), 2);
        assert_eq!(views[0].tree.bonus_row(0), 3);
        assert_eq!(views[0].tree.bonus_row(2), 4);
        // Phantom rows keep all-zero q (residual ≡ target = bonus).
        for row in 3..5 {
            assert!(req.q_probs[row * v..(row + 1) * v].iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn tree_rejects_bad_topologies() {
        let v = 16;
        // Parent count mismatch.
        let m = tree_msg(0, &[1], &[9, 9], &[255], v);
        assert!(build_verify_request(&[m], BUCKETS, 8, v).is_err());
        // Non-topological parent order.
        let m = tree_msg(0, &[1], &[9, 9], &[1, 255], v);
        assert!(build_verify_request(&[m], BUCKETS, 8, v).is_err());
        // Too many rows: 5 root children = 5 nodes + 5 leaves > K = 8.
        let m = tree_msg(0, &[1], &[9; 5], &[255; 5], v);
        let err = build_verify_request(&[m], BUCKETS, 8, v).unwrap_err();
        assert!(err.to_string().contains("rows"), "{err}");
    }

    #[test]
    fn picks_small_bucket_for_short_rounds() {
        let v = 16;
        let msgs = vec![msg(0, &[1; 50], &[2; 4], v)];
        let (req, _) = build_verify_request(&msgs, BUCKETS, 8, v).unwrap();
        assert_eq!(req.seq, 128);
        let msgs = vec![msg(0, &[1; 200], &[2; 4], v)];
        let (req, _) = build_verify_request(&msgs, BUCKETS, 8, v).unwrap();
        assert_eq!(req.seq, 256);
    }

    #[test]
    fn zero_draft_client_ok() {
        let v = 16;
        let msgs = vec![msg(0, &[1, 2], &[], v)];
        let (req, views) = build_verify_request(&msgs, BUCKETS, 8, v).unwrap();
        assert_eq!(views[0].draft_len, 0);
        // q row 0 all zero → residual = p → correction sampled from target.
        assert!(req.q_probs[..v].iter().all(|&x| x == 0.0));
        assert_eq!(req.parent[0], -1);
    }

    #[test]
    fn rejects_malformed_rounds() {
        let v = 16;
        assert!(build_verify_request(&[], BUCKETS, 8, v).is_err());
        // out-of-order client ids
        let out_of_order = vec![msg(2, &[1], &[], v), msg(0, &[1], &[], v)];
        let err = build_verify_request(&out_of_order, BUCKETS, 8, v).unwrap_err();
        assert!(err.to_string().contains("strictly increasing"), "{err}");
        // duplicate client ids
        let dup = vec![msg(1, &[1], &[], v), msg(1, &[1], &[], v)];
        assert!(build_verify_request(&dup, BUCKETS, 8, v).is_err());
        // draft longer than K
        let m = msg(0, &[1], &[9; 9], v);
        assert!(build_verify_request(&[m], BUCKETS, 8, v).is_err());
        // q length mismatch
        let mut m = msg(0, &[1], &[9, 9], v);
        m.q_probs.pop();
        assert!(build_verify_request(&[m], BUCKETS, 8, v).is_err());
        // empty prefix
        let m = msg(0, &[], &[], v);
        assert!(build_verify_request(&[m], BUCKETS, 8, v).is_err());
        // overflow largest bucket
        let m = msg(0, &[1; 255], &[2; 8], v);
        assert!(build_verify_request(&[m], BUCKETS, 8, v).is_err());
    }

    #[test]
    fn arena_rebuild_matches_fresh_build() {
        let v = 16;
        let mut arena = WaveArena::new();
        // Warm the arena with a *different* wave shape so the rebuild path
        // (slot update, tree replacement, buffer resize) is exercised.
        let warm = vec![msg(0, &[1, 2], &[5, 6, 7], v)];
        build_verify_request_into(&warm, BUCKETS, 8, v, &mut arena).unwrap();
        let parents = [255u8, 255, 1];
        let msgs = vec![
            msg(0, &[1, 2, 3], &[10, 11], v),
            tree_msg(2, &[4, 5], &[20, 21, 22], &parents, v),
        ];
        build_verify_request_into(&msgs, BUCKETS, 8, v, &mut arena).unwrap();
        let (req, views) = build_verify_request(&msgs, BUCKETS, 8, v).unwrap();
        assert_eq!(arena.req, req);
        assert_eq!(arena.views, views);
        // Shrinking wave truncates the view list.
        let small = vec![msg(1, &[9], &[3], v)];
        build_verify_request_into(&small, BUCKETS, 8, v, &mut arena).unwrap();
        assert_eq!(arena.views.len(), 1);
        assert_eq!(arena.views[0].client_id, 1);
    }

    #[test]
    fn warm_arena_rebuild_is_allocation_free() {
        let v = 16;
        let parents = [255u8, 255, 1];
        let msgs = vec![
            msg(0, &[1, 2, 3], &[10, 11], v),
            tree_msg(2, &[4, 5], &[20, 21, 22], &parents, v),
        ];
        let mut arena = WaveArena::new();
        build_verify_request_into(&msgs, BUCKETS, 8, v, &mut arena).unwrap();
        // Same shapes again: cached trees hit, buffers stay within
        // capacity — steady-state assembly never touches the heap.
        let (res, allocs) = crate::util::alloc_track::measure(|| {
            build_verify_request_into(&msgs, BUCKETS, 8, v, &mut arena)
        });
        res.unwrap();
        if crate::util::alloc_track::enabled() {
            assert_eq!(allocs, 0, "warm wave assembly must not allocate");
        }
    }

    #[test]
    fn partial_wave_maps_rows_to_client_ids() {
        // Wave of clients {1, 3} out of a larger cluster: rows are dense,
        // views carry the real ids.
        let v = 16;
        let msgs = vec![msg(1, &[4, 5], &[20], v), msg(3, &[1, 2, 3], &[30, 31], v)];
        let (req, views) = build_verify_request(&msgs, BUCKETS, 8, v).unwrap();
        assert_eq!(req.batch, 2);
        assert_eq!(views[0].client_id, 1);
        assert_eq!(views[1].client_id, 3);
        assert_eq!(req.pos0, vec![2, 3]);
        assert_eq!(&req.tokens[0..3], &[4, 5, 20]);
        assert_eq!(&req.tokens[128..133], &[1, 2, 3, 30, 31]);
    }

    #[test]
    fn singleton_wave_from_nonzero_client() {
        // A straggler verifying alone must be legal in async mode.
        let v = 16;
        let msgs = vec![msg(5, &[9, 8], &[7], v)];
        let (req, views) = build_verify_request(&msgs, BUCKETS, 8, v).unwrap();
        assert_eq!(req.batch, 1);
        assert_eq!(views[0].client_id, 5);
        assert_eq!(views[0].draft_len, 1);
    }
}
