//! Session-oriented serving API: a long-lived cluster with dynamic
//! client churn.
//!
//! The paper's setting is a verification server coordinating a
//! *population* of heterogeneous edge draft servers — and edge drafters
//! arrive and depart continuously. This module is the public face of that
//! closed loop:
//!
//! ```text
//! Cluster::builder(scenario)      // policy, transport, engine factory…
//!     .engine(factory)
//!     .start()?                   // spawns the coordinator; admits the
//!                                 // scenario's initial clients
//!     -> ServingHandle
//!         .attach(ClientSpec)?    // admit a new session  -> ClientId
//!         .detach(ClientId)?      // graceful drain
//!         .snapshot()             // live ClusterStats
//!         .stop()? / .wait()?     // -> RunOutcome
//! ```
//!
//! **Epochs.** Membership is epoch-stamped: every change — a scheduled
//! [`ChurnEvent`], an external [`ServingHandle::attach`]/
//! [`ServingHandle::detach`], or a drain completing — is applied at a
//! *wave boundary*, bumps the epoch, and is recorded as a
//! [`MembershipEvent`](crate::metrics::MembershipEvent) in the run's
//! recorder. Waves never observe a half-applied membership.
//!
//! **Admission.** A joining client gets a fresh slot, estimators seeded
//! from the population prior (`Estimators::seed_from_population`), and an
//! initial grant from the *unreserved* budget
//! ([`RoundCore::admit_member`](super::RoundCore::admit_member)) — the
//! Σ outstanding ≤ C reservation
//! invariant holds through the admission itself. Dynamically attached
//! clients open with the wire hello ([`Message::Join`] →
//! [`Message::JoinAck`]), which carries the protocol version byte.
//!
//! **Graceful drain.** [`ServingHandle::detach`] marks the session
//! draining: it stays a member — its in-flight grant stays reserved —
//! until its final verdict is delivered; that wave grants it 0, the
//! coordinator sends [`Message::Leave`], retires the membership, and the
//! freed budget water-fills over the survivors. A drain never drops or
//! double-counts a verdict.
//!
//! **Static parity.** A cluster whose scenario has no churn schedule (and
//! no external attach/detach) executes the exact call sequence of the
//! pre-redesign `run_serving` batch runner: same transport setup, same
//! per-client RNG forks, same wave order, same RNG streams, same records.
//! (That deprecated shim — literally `builder → start → wait` — was
//! removed once every caller migrated; the parity pin lives in
//! `tests/churn_cluster.rs`.)
//!
//! `num_verifiers > 1` scenarios run the sharded pool
//! ([`super::pool`]) under the same handle; a joining client is routed to
//! the least-pressured shard.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::leader::{Leader, PoolReport, RunConfig, RunOutcome, Transport};
use super::pipeline::{StageObs, VerifyStage, OVERLAP_TICK};
use crate::configsys::{ChurnEvent, ChurnKind, ClientSpec, CoordMode, Policy, Scenario};
use crate::draft::{spawn_draft_server, DraftServerConfig, DraftStats};
use crate::error::{ConfigError, GoodSpeedError};
use crate::metrics::recorder::{MembershipEvent, Recorder};
use crate::net::transport::{channel_transport, ClientPort, ServerSide, TcpTransport};
use crate::net::wire::{DraftMsg, JoinAckMsg, LeaveMsg, Message, VerdictMsg, PROTOCOL_VERSION};
use crate::obs::{ObsHub, ObsOptions};
use crate::runtime::EngineFactory;
use crate::serve::{RequestTrace, RequestTracker};
use crate::util::{Rng, Stopwatch};
use crate::workload::DomainStream;

/// Identifier of one client session. Slots are assigned in order — the
/// scenario's initial clients take `0..num_clients`, then one fresh id
/// per admission — and are never reused.
pub type ClientId = usize;

/// How often idle/blocked coordinator loops wake to look at control
/// traffic and liveness.
const CTL_TICK: Duration = Duration::from_millis(2);

/// How long the sync barrier tolerates silence before checking whether an
/// awaited draft server died (a dead client would otherwise hang the
/// barrier forever).
const LIVENESS_TICK: Duration = Duration::from_millis(200);

/// Lifecycle of one client slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum SlotState {
    /// Reserved but never attached.
    Empty,
    /// Serving.
    Active,
    /// Detach requested; awaiting the final verdict.
    Draining,
    /// Drain complete (or never re-attachable); slot archived.
    Retired,
}

/// Control messages from the [`ServingHandle`] to the coordinator.
pub(crate) enum Ctl {
    Attach { spec: ClientSpec, reply: Sender<Result<ClientId, GoodSpeedError>> },
    Detach { id: ClientId, reply: Sender<Result<(), GoodSpeedError>> },
    Stop,
}

/// A point-in-time view of the cluster, published at every wave boundary.
#[derive(Clone, Debug, Default)]
pub struct ClusterStats {
    /// Membership epoch (bumps on every join/leave).
    pub epoch: u64,
    /// Waves processed so far.
    pub waves: u64,
    /// Verdicts delivered so far.
    pub delivered: u64,
    /// Currently serving client ids (including draining), ascending.
    pub members: Vec<ClientId>,
    /// Subset of `members` in graceful drain.
    pub draining: Vec<ClientId>,
    /// Per-slot lifetime goodput (retired clients keep their totals).
    pub lifetime_goodput: Vec<f64>,
    /// Per-slot wave-participation counts.
    pub participation: Vec<u64>,
    /// Per-slot acceptance-rate estimates α̂ (archived for retired slots).
    pub alpha_hat: Vec<f64>,
    /// Total client slots (initial + churn joins + reserved headroom).
    pub slots: usize,
    /// Sessions admitted over the cluster's lifetime (incl. initial).
    pub attached_total: u64,
    /// Sessions retired over the cluster's lifetime.
    pub retired_total: u64,
    /// Per-shard liveness (single-verifier runs publish one `true`;
    /// pooled runs mirror the survivable pool's live mask).
    pub shard_live: Vec<bool>,
    /// Cross-shard client migrations so far (pooled runs only).
    pub migrations: u64,
    /// Handoffs lost to shard failures so far (pooled runs only).
    pub handoffs_lost: u64,
}

/// Namespace for [`Cluster::builder`] — the entry point of the serving
/// API.
pub struct Cluster;

impl Cluster {
    /// Start describing a serving cluster for `scenario`. The scenario's
    /// `num_clients` clients (models/domains/links cycled exactly like
    /// the batch runner did) are admitted at start; its churn schedule,
    /// if any, is applied as the run progresses.
    pub fn builder(scenario: Scenario) -> ClusterBuilder {
        ClusterBuilder {
            scenario,
            policy: Policy::GoodSpeed,
            transport: Transport::Channel,
            simulate_network: false,
            factory: None,
            extra_slots: 0,
            obs: None,
        }
    }
}

/// Builder for a serving cluster (see [`Cluster::builder`]).
pub struct ClusterBuilder {
    scenario: Scenario,
    policy: Policy,
    transport: Transport,
    simulate_network: bool,
    factory: Option<Arc<dyn EngineFactory>>,
    extra_slots: usize,
    obs: Option<ObsOptions>,
}

impl ClusterBuilder {
    /// Scheduling policy (default: GoodSpeed).
    pub fn policy(mut self, policy: Policy) -> Self {
        self.policy = policy;
        self
    }

    /// Transport carrying draft batches (default: in-process channel).
    pub fn transport(mut self, transport: Transport) -> Self {
        self.transport = transport;
        self
    }

    /// Apply real sleeps for simulated link delays (default: off).
    pub fn simulate_network(mut self, on: bool) -> Self {
        self.simulate_network = on;
        self
    }

    /// Engine factory building the verifier and drafter engines
    /// (required).
    pub fn engine(mut self, factory: Arc<dyn EngineFactory>) -> Self {
        self.factory = Some(factory);
        self
    }

    /// Reserve extra client slots beyond the initial clients and the
    /// churn schedule's joins, for external [`ServingHandle::attach`]
    /// calls (default: 0 — a static cluster admits nobody new).
    pub fn reserve_slots(mut self, extra: usize) -> Self {
        self.extra_slots = extra;
        self
    }

    /// Attach the live telemetry layer (flight recorder, metrics
    /// registry, postmortem trigger — DESIGN.md §10). Off by default;
    /// when off no observability code runs, and when on no RNG stream or
    /// hot-path allocation changes, so output stays bit-identical either
    /// way. Reach the hub via [`ServingHandle::observer`].
    pub fn observability(mut self, opts: ObsOptions) -> Self {
        self.obs = Some(opts);
        self
    }

    /// Validate, spawn the coordinator, admit the initial clients, and
    /// return the serving handle.
    pub fn start(self) -> Result<ServingHandle> {
        let scenario = self.scenario;
        scenario.validate().map_err(|e| anyhow!("invalid scenario: {e}"))?;
        let factory = self
            .factory
            .ok_or_else(|| anyhow!("configuration error: ClusterBuilder requires an engine \
                                    factory (ClusterBuilder::engine)"))?;
        let slots = scenario.num_clients + scenario.churn.join_count() + self.extra_slots;
        let obs = self
            .obs
            .as_ref()
            .map(|opts| Arc::new(ObsHub::new(scenario.num_verifiers.max(1), slots, opts)));
        let cfg = RunConfig {
            scenario,
            policy: self.policy,
            transport: self.transport,
            simulate_network: self.simulate_network,
        };
        let (ctl_tx, ctl_rx) = channel::<Ctl>();
        let snapshot = Arc::new(Mutex::new(ClusterStats::default()));
        let snap = snapshot.clone();
        // Engines are not `Send`, so everything engine-adjacent is built
        // inside the coordinator thread; a readiness channel carries the
        // construction result back to the caller.
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let obs_thread = obs.clone();
        let thread = std::thread::Builder::new()
            .name("goodspeed-cluster".into())
            .spawn(move || -> Result<RunOutcome> {
                if cfg.scenario.num_verifiers > 1 {
                    let out = super::pool::run_pool_dynamic(
                        &cfg,
                        factory,
                        slots,
                        Some(ctl_rx),
                        Some(snap),
                        Some(ready_tx),
                        obs_thread,
                    )?;
                    return Ok(RunOutcome {
                        recorder: out.recorder,
                        summary: out.summary,
                        draft_stats: out.draft_stats,
                        pool: Some(PoolReport {
                            shard_summaries: out.shard_summaries,
                            migrations: out.migrations,
                        }),
                    });
                }
                let built = ClusterEngine::new(&cfg, factory, slots, ctl_rx, snap, obs_thread);
                let mut engine = match built {
                    Ok(engine) => {
                        let _ = ready_tx.send(Ok(()));
                        engine
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(anyhow!("{e:#}")));
                        return Err(e);
                    }
                };
                engine.run()
            })
            .expect("spawn cluster coordinator");
        // Surface construction failures synchronously.
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(_)) | Err(_) => {
                return match thread.join() {
                    Ok(Err(e)) => Err(e),
                    Ok(Ok(_)) => Err(anyhow!("cluster failed to start")),
                    Err(_) => Err(anyhow!("cluster coordinator panicked at startup")),
                };
            }
        }
        Ok(ServingHandle { ctl: Some(ctl_tx), snapshot, thread: Some(thread), obs })
    }
}

/// Handle to a running serving cluster. Dropping the handle leaves the
/// cluster running to natural completion; use [`ServingHandle::stop`] or
/// [`ServingHandle::wait`] to collect the [`RunOutcome`].
pub struct ServingHandle {
    ctl: Option<Sender<Ctl>>,
    snapshot: Arc<Mutex<ClusterStats>>,
    thread: Option<JoinHandle<Result<RunOutcome>>>,
    obs: Option<Arc<ObsHub>>,
}

impl ServingHandle {
    fn ctl(&self) -> Result<&Sender<Ctl>, GoodSpeedError> {
        self.ctl
            .as_ref()
            .ok_or_else(|| GoodSpeedError::Shutdown("cluster already stopped".into()))
    }

    /// Admit a new client session. Applied at the next wave boundary;
    /// returns the assigned [`ClientId`]. Fails (typed) when no slot is
    /// free — reserve headroom with [`ClusterBuilder::reserve_slots`] —
    /// or when the spec names an unknown domain. A model name the engine
    /// factory rejects cannot be caught here (engines are only
    /// constructible inside the actor thread): such a session is admitted
    /// but retired by the coordinator's liveness check on its first wave,
    /// without disturbing the rest of the cluster.
    pub fn attach(&self, spec: ClientSpec) -> Result<ClientId, GoodSpeedError> {
        let (reply, rx) = channel();
        self.ctl()?
            .send(Ctl::Attach { spec, reply })
            .map_err(|_| GoodSpeedError::Shutdown("cluster already stopped".into()))?;
        rx.recv()
            .map_err(|_| GoodSpeedError::Shutdown("cluster stopped before admission".into()))?
    }

    /// Begin a graceful drain of `id`: its in-flight grant stays reserved
    /// until the final verdict, then the session is retired and its
    /// estimator state archived. Returns as soon as the drain is
    /// *scheduled* (the retirement itself completes within one wave of
    /// the client's next participation).
    pub fn detach(&self, id: ClientId) -> Result<(), GoodSpeedError> {
        let (reply, rx) = channel();
        self.ctl()?
            .send(Ctl::Detach { id, reply })
            .map_err(|_| GoodSpeedError::Shutdown("cluster already stopped".into()))?;
        rx.recv()
            .map_err(|_| GoodSpeedError::Shutdown("cluster stopped before detach".into()))?
    }

    /// The latest wave boundary's cluster state.
    pub fn snapshot(&self) -> ClusterStats {
        self.snapshot.lock().expect("snapshot lock").clone()
    }

    /// The telemetry hub, when [`ClusterBuilder::observability`] was set.
    /// Clone it *before* [`ServingHandle::wait`]/[`ServingHandle::stop`]
    /// — both consume the handle — to export traces or serve metrics
    /// while the cluster runs.
    pub fn observer(&self) -> Option<Arc<ObsHub>> {
        self.obs.clone()
    }

    /// Request shutdown at the next wave boundary and collect the run.
    pub fn stop(mut self) -> Result<RunOutcome> {
        if let Some(ctl) = &self.ctl {
            let _ = ctl.send(Ctl::Stop);
        }
        self.join_thread()
    }

    /// Wait for the scenario's budget to complete and collect the run
    /// (a classic one-shot batch run is `start()` + `wait()`).
    pub fn wait(mut self) -> Result<RunOutcome> {
        self.join_thread()
    }

    fn join_thread(&mut self) -> Result<RunOutcome> {
        // Dropping the control sender lets a fully drained cluster (no
        // members, nothing scheduled) finish instead of idling for
        // control traffic that can never arrive.
        self.ctl = None;
        match self.thread.take() {
            Some(t) => t.join().map_err(|_| anyhow!("cluster coordinator panicked"))?,
            None => Err(anyhow!("cluster already collected")),
        }
    }
}

/// Per-client request-latency bookkeeping: latency is counted in
/// *client-local* rounds between `new_request` flags.
struct LatencyTracker {
    start_round: Vec<u64>,
}

impl LatencyTracker {
    fn new(n: usize) -> Self {
        LatencyTracker { start_round: vec![0; n] }
    }

    fn observe(&mut self, recorder: &mut Recorder, client: usize, msg: &DraftMsg) {
        if msg.new_request {
            if msg.round > 0 {
                recorder
                    .request_latency_rounds
                    .push(msg.round - self.start_round[client]);
            }
            self.start_round[client] = msg.round;
        }
    }
}

/// The single-verifier coordinator: owns the transport, the leader, and
/// every client slot's lifecycle. Pooled scenarios use
/// [`super::pool::run_pool_dynamic`] instead.
struct ClusterEngine {
    scenario: Scenario,
    simulate_network: bool,
    factory: Arc<dyn EngineFactory>,
    server: ServerSide,
    /// Unclaimed ports, one per slot (taken at admission).
    ports: Vec<Option<Box<dyn ClientPort>>>,
    leader: Leader,
    state: Vec<SlotState>,
    /// Client-local round each slot will send next (sync-barrier check).
    expected_round: Vec<u64>,
    handles: Vec<Option<JoinHandle<Result<DraftStats>>>>,
    latency: LatencyTracker,
    /// Request-level serving overlay (`Scenario::trace`): per-client
    /// arrival queues, idle masking, and TTFT/TPOT/E2E + SLO accounting.
    /// `None` keeps the classic endless-stream run untouched.
    tracker: Option<RequestTracker>,
    /// Wave counter at loop exit (the tracker's book-closing clock).
    final_wave: u64,
    /// Root RNG the per-client domain streams fork from, in slot order —
    /// the same stream discipline the batch runner used.
    root_rng: Rng,
    ctl_rx: Receiver<Ctl>,
    /// Scheduled churn, sorted by wave; `schedule_cursor` marks progress.
    schedule: Vec<ChurnEvent>,
    schedule_cursor: usize,
    epoch: u64,
    delivered: u64,
    attached_total: u64,
    retired_total: u64,
    stop: bool,
    /// True once the control channel disconnected (handle dropped).
    ctl_gone: bool,
    /// A control message received by an idle-loop blocking wait, parked
    /// until the next wave boundary applies it (the boundary is the only
    /// place membership may change).
    pending_ctl: Option<Ctl>,
    snapshot: Arc<Mutex<ClusterStats>>,
    /// Telemetry hub (`None` = observability off; no code path changes).
    obs: Option<Arc<ObsHub>>,
}

impl ClusterEngine {
    fn new(
        cfg: &RunConfig,
        factory: Arc<dyn EngineFactory>,
        slots: usize,
        ctl_rx: Receiver<Ctl>,
        snapshot: Arc<Mutex<ClusterStats>>,
        obs: Option<Arc<ObsHub>>,
    ) -> Result<ClusterEngine> {
        let scenario = cfg.scenario.clone();
        let n = scenario.num_clients;

        // Transport, sized to the full slot capacity (spare connections
        // are parked until admission).
        let (server, ports): (ServerSide, Vec<_>) = match cfg.transport {
            Transport::Channel => channel_transport(slots),
            Transport::Tcp => {
                let t = TcpTransport::new(slots)?;
                (t.server, t.ports)
            }
        };

        let mut leader = Leader::with_slots(&scenario, cfg.policy, factory.as_ref(), slots)?;
        // Spare slots: not members, no reservation.
        for i in n..slots {
            leader.core.set_member(i, false);
            leader.core.set_outstanding(i, 0);
        }

        let tracker = if scenario.trace.is_some() {
            let trace = RequestTrace::from_scenario(&scenario, slots)?;
            let mut t = RequestTracker::new(trace, slots);
            if scenario.stream_metrics {
                t.stream();
            }
            Some(t)
        } else {
            None
        };
        if scenario.stream_metrics {
            leader.core.recorder.stream();
        }
        let mut engine = ClusterEngine {
            simulate_network: cfg.simulate_network,
            factory,
            server,
            ports: ports.into_iter().map(Some).collect(),
            leader,
            state: vec![SlotState::Empty; slots],
            expected_round: vec![0; slots],
            handles: (0..slots).map(|_| None).collect(),
            latency: LatencyTracker::new(slots),
            tracker,
            final_wave: 0,
            root_rng: Rng::new(scenario.seed),
            ctl_rx,
            schedule: scenario.churn.sorted(),
            schedule_cursor: 0,
            epoch: 0,
            delivered: 0,
            attached_total: 0,
            retired_total: 0,
            stop: false,
            ctl_gone: false,
            pending_ctl: None,
            snapshot,
            obs,
            scenario,
        };

        // Admit the initial membership — the exact spawn sequence (and
        // RNG fork order) of the batch runner: client i gets the cycled
        // model/domain/link and the `seed ^ (0xD00D + i)` stream.
        let max_rounds = engine.draft_round_cap();
        let initial_alloc =
            (engine.scenario.capacity / n.max(1)).min(engine.scenario.max_draft);
        for i in 0..n {
            let stream = DomainStream::new(
                engine.scenario.domain(i),
                engine.scenario.domain_stickiness,
                engine.scenario.max_new_tokens,
                engine.root_rng.fork(i as u64),
            )?;
            let dcfg = DraftServerConfig {
                client_id: i,
                model: engine.scenario.draft_model(i).to_string(),
                initial_alloc,
                link: engine.scenario.link(i),
                simulate_network: engine.simulate_network,
                seed: engine.scenario.seed ^ (0xD00D + i as u64),
                max_rounds,
                spec_shape: engine.scenario.spec_shape,
                verify_k: engine.factory.verify_k(),
                hello: false,
            };
            let port = engine.ports[i].take().expect("initial port");
            engine.handles[i] =
                Some(spawn_draft_server(dcfg, engine.factory.clone(), stream, port));
            engine.state[i] = SlotState::Active;
            engine.attached_total += 1;
        }
        Ok(engine)
    }

    /// Safety cap on client-local rounds (the coordinator normally shuts
    /// sessions down; in async mode one fast client may absorb most of
    /// the budget).
    fn draft_round_cap(&self) -> u64 {
        match self.scenario.coord_mode {
            CoordMode::Sync => self.scenario.rounds + 1,
            CoordMode::Async => {
                self.scenario.rounds.saturating_mul(self.scenario.num_clients as u64) + 1
            }
        }
    }

    fn members(&self) -> Vec<usize> {
        (0..self.state.len())
            .filter(|&i| matches!(self.state[i], SlotState::Active | SlotState::Draining))
            .collect()
    }

    /// Admit one new session (external attach or scheduled join).
    fn admit(&mut self, spec: ClientSpec, wave: u64) -> Result<ClientId, GoodSpeedError> {
        let slot = match self.state.iter().position(|s| *s == SlotState::Empty) {
            Some(s) => s,
            None => {
                return Err(ConfigError::invalid(
                    "no free client slots (reserve headroom with \
                     ClusterBuilder::reserve_slots or the churn schedule)",
                )
                .into())
            }
        };
        if !crate::workload::domains::is_domain(&spec.domain) {
            return Err(ConfigError::invalid(format!(
                "attach: unknown domain '{}' (known: {})",
                spec.domain,
                crate::workload::domains::DOMAINS.join(", ")
            ))
            .into());
        }
        // Build everything fallible first, so a failed admission leaves
        // the membership untouched…
        let stream = DomainStream::new(
            &spec.domain,
            self.scenario.domain_stickiness,
            self.scenario.max_new_tokens,
            self.root_rng.fork(slot as u64),
        )
        .map_err(|e| GoodSpeedError::Engine(format!("{e:#}")))?;
        // …then commit: estimators from the population prior of the
        // current members, grant from the unreserved budget.
        let members = self.leader.core.members();
        self.leader.core.estimators.seed_from_population(slot, &members);
        let grant = self.leader.core.admit_member(slot, self.scenario.max_draft);
        let dcfg = DraftServerConfig {
            client_id: slot,
            model: spec.model,
            initial_alloc: grant,
            link: spec.link,
            simulate_network: self.simulate_network,
            seed: self.scenario.seed ^ (0xD00D + slot as u64),
            max_rounds: self.draft_round_cap(),
            spec_shape: self.scenario.spec_shape,
            verify_k: self.factory.verify_k(),
            hello: true,
        };
        let port = self.ports[slot].take().expect("spare port");
        self.handles[slot] =
            Some(spawn_draft_server(dcfg, self.factory.clone(), stream, port));
        self.state[slot] = SlotState::Active;
        self.expected_round[slot] = 0;
        self.attached_total += 1;
        self.epoch += 1;
        if let Some(hub) = &self.obs {
            hub.note_epoch(0, self.epoch);
        }
        let ev = MembershipEvent {
            wave,
            epoch: self.epoch,
            joined: vec![(slot, grant)],
            left: vec![],
            members: self.members(),
        };
        self.leader.core.recorder.note_membership(ev);
        Ok(slot)
    }

    /// Schedule a graceful drain.
    fn begin_detach(&mut self, id: ClientId) -> Result<(), GoodSpeedError> {
        if id >= self.state.len() || self.state[id] != SlotState::Active {
            return Err(ConfigError::invalid(format!(
                "detach: client {id} is not an active session"
            ))
            .into());
        }
        self.state[id] = SlotState::Draining;
        self.leader.core.set_draining(id, true);
        Ok(())
    }

    /// Complete a drain after the client's final verdict: send the Leave
    /// frame, retire the membership, archive the stats. Any trace
    /// requests still queued for the departed session are censored — a
    /// gone user's unserved arrivals are not scheduler misses.
    fn retire(&mut self, id: ClientId, wave: u64) {
        if let Some(tracker) = &mut self.tracker {
            tracker.untrack(id, wave);
        }
        self.epoch += 1;
        if let Some(hub) = &self.obs {
            hub.note_epoch(0, self.epoch);
        }
        let _ = (self.server.txs[id])(&Message::Leave(LeaveMsg {
            client_id: id as u32,
            epoch: self.epoch,
        }));
        self.leader.core.retire_member(id);
        self.state[id] = SlotState::Retired;
        self.retired_total += 1;
        let ev = MembershipEvent {
            wave,
            epoch: self.epoch,
            joined: vec![],
            left: vec![id],
            members: self.members(),
        };
        self.leader.core.recorder.note_membership(ev);
    }

    /// Wave boundary: apply due schedule events, drain external control,
    /// publish the snapshot. Returns with `self.stop` set when shutdown
    /// was requested. With an empty membership, pending events fire
    /// immediately (the wave clock is frozen, so they could never come
    /// due otherwise) — the same rule the analytic simulator applies.
    fn boundary(&mut self, wave: u64) {
        while self.schedule_cursor < self.schedule.len()
            && (self.schedule[self.schedule_cursor].at_wave <= wave
                || self.members().is_empty())
        {
            let ev = self.schedule[self.schedule_cursor].clone();
            self.schedule_cursor += 1;
            match ev.kind {
                ChurnKind::Join(spec) => {
                    if let Err(e) = self.admit(spec, wave) {
                        log::warn!("scheduled join at wave {wave} failed: {e}");
                    }
                }
                ChurnKind::Leave(id) => {
                    if let Err(e) = self.begin_detach(id) {
                        log::warn!("scheduled leave of client {id} at wave {wave}: {e}");
                    }
                }
            }
        }
        // A control message caught by an idle wait is first in line — it
        // arrived before anything try_recv can return.
        if let Some(ctl) = self.pending_ctl.take() {
            self.apply_ctl(ctl, wave);
        }
        loop {
            match self.ctl_rx.try_recv() {
                Ok(ctl) => self.apply_ctl(ctl, wave),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    self.ctl_gone = true;
                    break;
                }
            }
        }
        self.publish(wave);
    }

    fn apply_ctl(&mut self, ctl: Ctl, wave: u64) {
        match ctl {
            Ctl::Attach { spec, reply } => {
                let _ = reply.send(self.admit(spec, wave));
            }
            Ctl::Detach { id, reply } => {
                let _ = reply.send(self.begin_detach(id));
            }
            Ctl::Stop => self.stop = true,
        }
    }

    /// Idle wait with an empty membership: block on the control channel
    /// for up to one [`CTL_TICK`] instead of sleeping blind — an attach
    /// lands at the next boundary immediately rather than a tick later.
    /// Once the channel is gone a blocking receive would return
    /// `Disconnected` instantly (a busy loop), so that terminal case
    /// keeps the plain sleep.
    fn idle_wait_ctl(&mut self) {
        if self.ctl_gone {
            std::thread::sleep(CTL_TICK);
            return;
        }
        match self.ctl_rx.recv_timeout(CTL_TICK) {
            Ok(ctl) => self.pending_ctl = Some(ctl),
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => self.ctl_gone = true,
        }
    }

    fn publish(&self, wave: u64) {
        let mut snap = self.snapshot.lock().expect("snapshot lock");
        snap.epoch = self.epoch;
        snap.waves = wave;
        snap.delivered = self.delivered;
        snap.members = self.members();
        snap.draining = (0..self.state.len())
            .filter(|&i| self.state[i] == SlotState::Draining)
            .collect();
        snap.lifetime_goodput = self.leader.core.recorder.lifetime_goodput().to_vec();
        snap.participation = self.leader.core.recorder.participation().to_vec();
        snap.alpha_hat = self.leader.core.estimators.alpha_hat.clone();
        snap.slots = self.state.len();
        snap.attached_total = self.attached_total;
        snap.retired_total = self.retired_total;
        snap.shard_live.clear();
        snap.shard_live.push(true);
        snap.migrations = 0;
        snap.handoffs_lost = self.leader.core.recorder.handoffs_lost;
    }

    /// Post-wave telemetry: flight-ring wave span + registry refresh +
    /// the SLO-breach streak feed. Atomics only — no allocation, no RNG,
    /// so an observed run stays bit-identical to an unobserved one.
    fn observe_wave(&self, wave: u64) {
        let Some(hub) = &self.obs else { return };
        if let Some((_, _, recv, verify, send)) = self.leader.core.recorder.last_wave_phases() {
            hub.wave_span(0, wave, recv, verify, send);
        }
        let mut outstanding = 0u64;
        for i in 0..self.state.len() {
            outstanding += self.leader.core.outstanding(i) as u64;
        }
        hub.publish_wave_stats(
            &self.leader.core.recorder,
            outstanding,
            self.scenario.capacity as u64,
        );
        if let Some(tracker) = &self.tracker {
            hub.note_slo_expired(tracker.slo_missed());
        }
    }

    /// Answer a session hello.
    fn ack_join(&mut self, id: usize, protocol: u8) -> Result<()> {
        if protocol > PROTOCOL_VERSION {
            return Err(anyhow!(
                "client {id} speaks protocol {protocol}, newer than {PROTOCOL_VERSION}"
            ));
        }
        (self.server.txs[id])(&Message::JoinAck(JoinAckMsg {
            client_id: id as u32,
            protocol: PROTOCOL_VERSION,
            initial_alloc: self.leader.core.outstanding(id) as u32,
            epoch: self.epoch,
        }))
    }

    fn slot_live(&self, id: usize) -> bool {
        matches!(self.state[id], SlotState::Active | SlotState::Draining)
    }

    /// A member we are waiting on whose actor thread already exited is a
    /// dead client. A dead *initial* client fails the run (the batch
    /// semantics); a dead dynamically-attached session — e.g. an
    /// `attach` whose model the engine factory rejected inside the actor
    /// thread — is retired so one bad admission cannot take down the
    /// long-lived cluster.
    fn check_liveness(&mut self, awaited: &[usize], wave: u64) -> Result<()> {
        for &i in awaited {
            let finished =
                self.handles[i].as_ref().map(|h| h.is_finished()).unwrap_or(false);
            if finished {
                let res = self.handles[i].take().expect("handle").join();
                let detail = match res {
                    Ok(Ok(_)) => format!("client {i} exited mid-session"),
                    Ok(Err(e)) => format!("client {i} failed: {e:#}"),
                    Err(_) => format!("client {i} panicked"),
                };
                if i < self.scenario.num_clients {
                    self.state[i] = SlotState::Retired;
                    self.leader.core.retire_member(i);
                    return Err(anyhow!(detail));
                }
                log::warn!("retiring dead attached session: {detail}");
                self.retire(i, wave);
            }
        }
        Ok(())
    }

    fn run(&mut self) -> Result<RunOutcome> {
        let run_start = Instant::now();
        let loop_result = match self.scenario.coord_mode {
            CoordMode::Sync => self.run_sync(),
            CoordMode::Async => self.run_async(),
        };
        // Shutdown (even on error, so draft threads can exit before join).
        for tx in self.server.txs.iter_mut() {
            let _ = tx(&Message::Shutdown);
        }
        loop_result?;
        let wall = run_start.elapsed().as_secs_f64();

        // Close the request books: expired requests become recorded
        // misses, still-pending ones are censored, and the per-request
        // records + per-client SLO-goodput move into the recorder.
        if let Some(mut tracker) = self.tracker.take() {
            tracker.finish(self.final_wave);
            let (requests, slo_goodput, censored, sketch) = tracker.into_report();
            self.leader.core.recorder.requests = requests;
            self.leader.core.recorder.slo_goodput = slo_goodput;
            self.leader.core.recorder.requests_censored = censored;
            self.leader.core.recorder.request_sketch = sketch;
        }

        let mut draft_stats: Vec<DraftStats> = Vec::with_capacity(self.handles.len());
        for (i, slot) in self.handles.iter_mut().enumerate() {
            match slot.take() {
                Some(h) => match h.join() {
                    Ok(Ok(s)) => draft_stats.push(s),
                    Ok(Err(e)) => return Err(anyhow!("draft server {i} failed: {e}")),
                    Err(_) => return Err(anyhow!("draft server {i} panicked")),
                },
                None => draft_stats.push(DraftStats::default()),
            }
        }
        let recorder = std::mem::take(&mut self.leader.core.recorder);
        let summary = recorder.summary(wall);
        Ok(RunOutcome { recorder, summary, draft_stats, pool: None })
    }

    /// The sync barrier, generalized to epoch-stamped membership: one
    /// dense wave over the *current* members per round.
    fn run_sync(&mut self) -> Result<()> {
        let slots = self.state.len();
        // The pipelined verify stage (opt-in) owns a second engine on its
        // own thread; serial stays the default. Held as a local so the
        // overlap loop can keep borrowing `self` for fan-in ingest.
        let mut stage: Option<VerifyStage> = if self.scenario.pipelined {
            let sobs = self.obs.as_ref().map(|hub| StageObs { hub: Arc::clone(hub), shard: 0 });
            Some(VerifyStage::spawn_observed(
                self.factory.clone(),
                &self.scenario.family,
                "goodspeed-verify-stage",
                sobs,
            )?)
        } else {
            None
        };
        let mut wave: u64 = 0;
        // Wave-loop buffers, hoisted so steady-state waves reuse their
        // capacity instead of reallocating every round.
        let mut pending: Vec<Option<DraftMsg>> = vec![None; slots];
        let mut msgs: Vec<DraftMsg> = Vec::new();
        let mut verdicts: Vec<VerdictMsg> = Vec::new();
        let mut awaited: Vec<usize> = Vec::new();
        while wave < self.scenario.rounds {
            self.boundary(wave);
            if self.stop {
                break;
            }
            let members = self.members();
            if members.is_empty() {
                // Nothing to serve. If nothing can ever change, finish.
                if self.ctl_gone && self.schedule_cursor >= self.schedule.len() {
                    break;
                }
                self.idle_wait_ctl();
                continue;
            }
            // Request boundary: promote due arrivals, refresh the idle
            // mask (idle members are granted 0 this wave), and publish
            // SLO headroom to the turbo controller when one is running.
            if let Some(tracker) = &mut self.tracker {
                tracker.sync_wave_start(&mut self.leader.core, wave, &members);
            }
            let mut sw = Stopwatch::new();
            // 1. Receive: FIFO until every *current* member's batch for
            // its own round arrived (the awaited set is recomputed each
            // pass — a dead attached session retired by the liveness
            // check shrinks the barrier instead of hanging it). Retired
            // stragglers' drained drafts are discarded; hellos are acked
            // inline. (A straggler's draft collected just before its slot
            // retired is dropped here, exactly as the per-wave buffer
            // used to.)
            for slot in pending.iter_mut() {
                *slot = None;
            }
            loop {
                awaited.clear();
                awaited
                    .extend(self.members().into_iter().filter(|&i| pending[i].is_none()));
                if awaited.is_empty() {
                    break;
                }
                let (id, msg) =
                    match self.server.recv_deadline(Instant::now() + LIVENESS_TICK)? {
                        Some(m) => m,
                        None => {
                            self.check_liveness(&awaited, wave)?;
                            continue;
                        }
                    };
                match msg {
                    Message::Draft(d) if self.slot_live(id) => {
                        if d.round != self.expected_round[id] {
                            return Err(anyhow!(
                                "client {id} sent round {} while round {} expected",
                                d.round,
                                self.expected_round[id]
                            ));
                        }
                        pending[id] = Some(d);
                    }
                    Message::Draft(_) => {} // retired straggler: drop
                    Message::Join(j) => self.ack_join(id, j.protocol)?,
                    Message::Leave(_) => {
                        // Client-initiated departure request.
                        let _ = self.begin_detach(id);
                    }
                    Message::Shutdown => {
                        return Err(anyhow!("client {id} shut down early"))
                    }
                    other => return Err(anyhow!("unexpected {other:?}")),
                }
            }
            let members = self.members();
            if members.is_empty() {
                continue; // every awaited session retired mid-collect
            }
            msgs.clear();
            msgs.extend(members.iter().map(|&i| pending[i].take().expect("collected")));
            let recv_ns = sw.lap().as_nanos() as u64;

            for m in msgs.iter() {
                self.latency
                    .observe(&mut self.leader.core.recorder, m.client_id as usize, m);
            }

            // 2. Verify + schedule (one dense wave over the members). The
            // pipelined stage runs the forward on its thread; under the
            // sync barrier every member is awaiting its verdict, so no
            // drafts can arrive mid-verify — block until it completes.
            // Scheduling and verdict emission run here either way, in the
            // exact serial order.
            match stage.as_mut() {
                Some(stage) => {
                    let mut vsw = Stopwatch::new();
                    let (mut arena, out) = self.leader.take_wave_buffers();
                    if let Err(e) = self.leader.assemble_wave_into(&msgs, &mut arena) {
                        self.leader.put_wave_buffers(arena, out);
                        return Err(e);
                    }
                    stage.submit(arena, out);
                    let (arena, out, res) = stage.wait_done().expect("wave in flight");
                    self.leader.put_wave_buffers(arena, out);
                    res?;
                    self.leader.conclude_wave_into(wave, &msgs, recv_ns, &mut vsw, &mut verdicts);
                }
                None => {
                    self.leader.process_wave_into(wave, &msgs, recv_ns, &mut verdicts)?
                }
            }
            let _ = sw.lap();

            // 3. Send verdicts.
            for vd in &verdicts {
                (self.server.txs[vd.client_id as usize])(&Message::Verdict(vd.clone()))?;
                self.expected_round[vd.client_id as usize] += 1;
            }
            self.leader.note_send_ns(sw.lap().as_nanos() as u64);
            self.delivered += verdicts.len() as u64;
            self.observe_wave(wave);

            // Attribute the wave's realized goodput to active requests.
            if let Some(tracker) = &mut self.tracker {
                let outcomes: Vec<(usize, usize)> = verdicts
                    .iter()
                    .map(|vd| (vd.client_id as usize, vd.accepted as usize + 1))
                    .collect();
                tracker.sync_wave_end(wave, &outcomes);
            }

            // 4. Complete drains: the verdict just sent was the final one.
            let drained: Vec<usize> = verdicts
                .iter()
                .map(|vd| vd.client_id as usize)
                .filter(|&id| self.state[id] == SlotState::Draining)
                .collect();
            for id in drained {
                self.retire(id, wave + 1);
            }
            wave += 1;
        }
        self.final_wave = wave;
        self.publish(wave);
        Ok(())
    }

    /// Admit one fan-in message into the async pending set.
    fn ingest(
        &mut self,
        pending: &mut [Option<DraftMsg>],
        pending_n: &mut usize,
        id: usize,
        msg: Message,
    ) -> Result<()> {
        match msg {
            Message::Draft(d) if self.slot_live(id) => {
                self.latency.observe(&mut self.leader.core.recorder, id, &d);
                if pending[id].replace(d).is_some() {
                    return Err(anyhow!("client {id}: two drafts in flight"));
                }
                *pending_n += 1;
                Ok(())
            }
            Message::Draft(_) => Ok(()), // retired straggler: drop
            Message::Join(j) => self.ack_join(id, j.protocol),
            Message::Leave(_) => {
                let _ = self.begin_detach(id);
                Ok(())
            }
            Message::Shutdown => Err(anyhow!("client {id} shut down early")),
            other => Err(anyhow!("unexpected {other:?}")),
        }
    }

    /// The event-driven pipeline, generalized to membership: waves fire
    /// on fill or deadline over the live member set; the run stops after
    /// the same total verification budget as the batch runner
    /// (`num_clients × rounds` verdicts over the initial membership).
    fn run_async(&mut self) -> Result<()> {
        let slots = self.state.len();
        // Opt-in pipelined verify stage (see `run_sync`); in async mode
        // the coordinator overlaps fan-in draining with the forward.
        let mut stage: Option<VerifyStage> = if self.scenario.pipelined {
            let sobs = self.obs.as_ref().map(|hub| StageObs { hub: Arc::clone(hub), shard: 0 });
            Some(VerifyStage::spawn_observed(
                self.factory.clone(),
                &self.scenario.family,
                "goodspeed-verify-stage",
                sobs,
            )?)
        } else {
            None
        };
        let window = Duration::from_micros(self.scenario.batch_window_us);
        let budget: u64 =
            self.scenario.rounds.saturating_mul(self.scenario.num_clients as u64);
        let mut pending: Vec<Option<DraftMsg>> = vec![None; slots];
        let mut pending_n = 0usize;
        let mut wave: u64 = 0;
        // Wave-loop buffers, reused across waves.
        let mut msgs: Vec<DraftMsg> = Vec::new();
        let mut verdicts: Vec<VerdictMsg> = Vec::new();

        while self.delivered < budget {
            self.boundary(wave);
            if self.stop {
                break;
            }
            let members = self.members();
            if members.is_empty() && pending_n == 0 {
                if self.ctl_gone && self.schedule_cursor >= self.schedule.len() {
                    break;
                }
                self.idle_wait_ctl();
                continue;
            }
            // Request boundary (same rules as the sync barrier).
            if let Some(tracker) = &mut self.tracker {
                tracker.sync_wave_start(&mut self.leader.core, wave, &members);
            }
            let mut sw = Stopwatch::new();
            // Phase 1 — wait for the wave's first draft.
            while pending_n == 0 {
                match self.server.recv_deadline(Instant::now() + LIVENESS_TICK)? {
                    Some((id, msg)) => self.ingest(&mut pending, &mut pending_n, id, msg)?,
                    None => {
                        self.check_liveness(&self.members(), wave)?;
                        if self.members().is_empty() {
                            break; // every session retired; re-enter the boundary
                        }
                    }
                }
            }
            if pending_n == 0 {
                continue;
            }
            // Phase 2 — batching window up to the wave-fill target.
            let fill = self.scenario.effective_wave_fill().min(members.len());
            let want = fill.min((budget - self.delivered).min(slots as u64) as usize);
            let deadline = Instant::now() + window;
            while pending_n < want {
                match self.server.recv_deadline(deadline)? {
                    Some((id, msg)) => self.ingest(&mut pending, &mut pending_n, id, msg)?,
                    None => break, // deadline-triggered flush
                }
            }
            // Phase 3 — opportunistic drain.
            for (id, msg) in self.server.try_drain()? {
                self.ingest(&mut pending, &mut pending_n, id, msg)?;
            }
            // Phase 4 — form the wave (index order ⇒ ascending client id).
            msgs.clear();
            for slot in pending.iter_mut() {
                if let Some(d) = slot.take() {
                    msgs.push(d);
                }
            }
            pending_n = 0;
            let recv_ns = sw.lap().as_nanos() as u64;

            // Phase 5 — verify + schedule + send. With the stage engaged,
            // the coordinator keeps draining fan-in for the next wave
            // while the forward runs; scheduling and verdict emission
            // stay here, in the exact serial order.
            match stage.as_mut() {
                Some(stage) => {
                    let mut vsw = Stopwatch::new();
                    let (mut arena, out) = self.leader.take_wave_buffers();
                    if let Err(e) = self.leader.assemble_wave_into(&msgs, &mut arena) {
                        self.leader.put_wave_buffers(arena, out);
                        return Err(e);
                    }
                    stage.submit(arena, out);
                    let (arena, out, res) = loop {
                        for (id, msg) in self.server.try_drain()? {
                            self.ingest(&mut pending, &mut pending_n, id, msg)?;
                        }
                        if let Some(done) = stage.take_done_timeout(OVERLAP_TICK) {
                            break done;
                        }
                    };
                    self.leader.put_wave_buffers(arena, out);
                    res?;
                    self.leader.conclude_wave_into(wave, &msgs, recv_ns, &mut vsw, &mut verdicts);
                }
                None => {
                    self.leader.process_wave_into(wave, &msgs, recv_ns, &mut verdicts)?
                }
            }
            let _ = sw.lap();
            for vd in &verdicts {
                (self.server.txs[vd.client_id as usize])(&Message::Verdict(vd.clone()))?;
            }
            self.delivered += verdicts.len() as u64;
            self.leader.note_send_ns(sw.lap().as_nanos() as u64);
            self.observe_wave(wave);

            // Attribute the wave's realized goodput to active requests.
            if let Some(tracker) = &mut self.tracker {
                let outcomes: Vec<(usize, usize)> = verdicts
                    .iter()
                    .map(|vd| (vd.client_id as usize, vd.accepted as usize + 1))
                    .collect();
                tracker.sync_wave_end(wave, &outcomes);
            }

            // Phase 6 — complete drains.
            let drained: Vec<usize> = verdicts
                .iter()
                .map(|vd| vd.client_id as usize)
                .filter(|&id| self.state[id] == SlotState::Draining)
                .collect();
            for id in drained {
                self.retire(id, wave + 1);
            }
            wave += 1;
        }
        self.final_wave = wave;
        self.publish(wave);
        Ok(())
    }
}
