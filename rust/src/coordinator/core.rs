//! RoundCore — the engine-agnostic wave-processing core.
//!
//! Everything that happens *after* a verification wave's outcomes are known
//! is scheduling/accounting, not model execution: the sparse estimator
//! updates (paper eqs. 3–4), the GOODSPEED-SCHED allocation (eq. 5) under
//! the budget-reservation invariant, and the [`RoundRecord`] emission. This
//! module owns that logic in one place so the live coordinator's sync
//! barrier, its async wave pipeline, *and* the analytic simulator execute
//! the same code path — the simulator can no longer drift from the
//! coordinator when the scheduling rules change.
//!
//! The live [`Leader`](super::leader::Leader) feeds the core real
//! rejection-sampling results (via [`RoundCore::judge`], which owns the
//! verdict RNG so sync-mode runs stay bit-identical to the pre-refactor
//! coordinator); the analytic simulator feeds it the outcomes of its
//! synthetic indicator process. Either way the core sees only [`WaveObs`]
//! rows — it never touches an engine.
//!
//! For sharded deployments ([`super::pool`]) each verification shard owns
//! one `RoundCore` with a *membership mask*: only the shard's own clients
//! count toward its reservation invariant (Σ outstanding ≤ capacity), and
//! the shard's capacity is the budget slice the pool controller hands it.

use crate::configsys::{Policy, Smoothing};
use crate::metrics::recorder::{ClientRoundMetrics, Recorder, RoundRecord};
use crate::sched::baselines::{make_allocator, AllocCaps, Allocator};
use crate::sched::controller::TurboController;
use crate::sched::Estimators;
use crate::spec::rejection::{verify_client, verify_tree, ClientVerdict, TreeVerdict};
use crate::spec::tree::DraftTree;
use crate::util::Rng;

/// One participant's verification outcome, in the engine-agnostic form the
/// core consumes. Rows must be strictly ascending by `client_id`.
#[derive(Clone, Debug)]
pub struct WaveObs {
    pub client_id: usize,
    /// Draft length actually verified this wave.
    pub s_used: usize,
    /// Accepted draft tokens m.
    pub accepted: usize,
    /// Realized goodput x_i(t) = m + 1.
    pub goodput: usize,
    /// Mean acceptance ratio (eq. 3 empirical term; per *node* for trees).
    pub mean_ratio: f64,
    /// Depth of the drafted topology (== `s_used` for a chain; the tree
    /// profile's realized depth otherwise). Metrics-only: lets the
    /// fairness plots separate shape effects from budget effects.
    pub spec_depth: usize,
    /// Cap for this client's *next* allocation: min(artifact K limit,
    /// context room after the verdict is applied).
    pub max_next: usize,
}

/// The shared wave-processing core: estimators, allocator, budget
/// accounting, verdict RNG, and the run's metrics recorder.
pub struct RoundCore {
    pub estimators: Estimators,
    allocator: Box<dyn Allocator>,
    /// Verdict RNG for rejection sampling (the live path only; seeded
    /// `seed ^ 0xC0DE` exactly like the pre-refactor coordinator).
    verdict_rng: Rng,
    /// Verification budget C of this core (a shard's budget slice in
    /// pooled mode; the scenario's full C otherwise).
    capacity: usize,
    /// Upper bound on each client's in-flight draft length (its last
    /// granted allocation; clients only clamp downward). Invariant:
    /// Σ outstanding over *members* ≤ capacity, so no wave's verify batch
    /// — a subset of the outstanding drafts — can exceed the budget even
    /// when waves interleave asynchronously.
    outstanding: Vec<usize>,
    /// Which clients this core is responsible for. Non-members never count
    /// toward the reservation (they draw on some other shard's budget).
    /// All-true outside pooled mode.
    member: Vec<bool>,
    /// Members in graceful drain: still counted in the reservation (their
    /// in-flight draft is owed a verdict) but granted 0 on their final
    /// wave, so the freed budget water-fills over the survivors.
    draining: Vec<bool>,
    /// Members with no active request right now (trace-driven runs).
    /// Granted 0 — the drain grant rule without the retirement — so an
    /// idle client's budget water-fills over busy ones; the flag clears
    /// the moment its next request arrives. All-false outside trace mode.
    idle: Vec<bool>,
    /// Whether the client's *current in-flight draft* was granted 0
    /// because it was idle. Set per wave from the idle mask; covers the
    /// wake wave (idle already cleared, draft still the idle-era S = 0)
    /// so its neutral ratio never reaches the estimators/controller.
    /// All-false outside trace mode.
    idle_grant: Vec<bool>,
    /// The closed-loop speculation controller (`policy=turbo` only):
    /// caps each client's next allocation from its SLO headroom,
    /// observed acceptance, and verifier congestion.
    turbo: Option<TurboController>,
    /// Shard id stamped onto emitted records (0 outside pooled mode).
    shard: usize,
    /// Reusable `finish_wave` scratch: the dense estimator-update rows,
    /// the allocator caps, and the allocation vector itself are recycled
    /// across waves so steady-state scheduling stays off the heap (part of
    /// the wave-arena work; see DESIGN.md "Performance & benchmarking").
    dense: Vec<Option<(f64, f64)>>,
    caps: AllocCaps,
    alloc: Vec<usize>,
    /// Recycled [`RoundRecord`] shell. Retained-mode recorders keep every
    /// record, so this stays `None` there; a streaming recorder hands the
    /// displaced record back and its `clients` vector is reused, keeping
    /// warm waves allocation-free end to end.
    spare: Option<RoundRecord>,
    pub recorder: Recorder,
}

impl RoundCore {
    /// `seed` is the scenario seed; the allocator and verdict RNG derive
    /// their streams from it with the same tweaks the pre-refactor
    /// coordinator used (`^ 0x5eed`, `^ 0xC0DE`).
    pub fn new(
        n: usize,
        eta: Smoothing,
        beta: Smoothing,
        policy: Policy,
        seed: u64,
        capacity: usize,
        initial_alloc: usize,
    ) -> RoundCore {
        RoundCore {
            estimators: Estimators::new(n, eta, beta),
            allocator: make_allocator(policy, seed ^ 0x5eed),
            verdict_rng: Rng::new(seed ^ 0xC0DE),
            capacity,
            outstanding: vec![initial_alloc; n],
            member: vec![true; n],
            draining: vec![false; n],
            idle: vec![false; n],
            idle_grant: vec![false; n],
            // Targets start fully open at C: with no deadline pressure the
            // caps never bind and turbo is the plain gradient policy.
            turbo: (policy == Policy::Turbo).then(|| TurboController::new(n, capacity)),
            shard: 0,
            dense: Vec::new(),
            caps: AllocCaps {
                capacity: 0,
                max_per_client: Vec::new(),
                live: Vec::new(),
            },
            alloc: Vec::new(),
            spare: None,
            recorder: Recorder::new(n),
        }
    }

    pub fn n_clients(&self) -> usize {
        self.estimators.len()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Update the budget slice (the pool controller's hierarchical split).
    pub fn set_capacity(&mut self, c: usize) {
        self.capacity = c;
    }

    pub fn shard_id(&self) -> usize {
        self.shard
    }

    pub fn set_shard(&mut self, shard: usize) {
        self.shard = shard;
    }

    pub fn is_member(&self, client: usize) -> bool {
        self.member[client]
    }

    pub fn set_member(&mut self, client: usize, member: bool) {
        self.member[client] = member;
    }

    pub fn outstanding(&self, client: usize) -> usize {
        self.outstanding[client]
    }

    /// Seed a migrated-in client's in-flight grant (pool rebalancing).
    pub fn set_outstanding(&mut self, client: usize, alloc: usize) {
        self.outstanding[client] = alloc;
    }

    /// Current members, ascending.
    pub fn members(&self) -> Vec<usize> {
        (0..self.member.len()).filter(|&i| self.member[i]).collect()
    }

    /// Σ outstanding grants over current members — the budget currently
    /// reserved by in-flight drafts. Invariant: `reserved_total() ≤
    /// capacity()` at every wave boundary (joins are only granted from the
    /// unreserved remainder).
    pub fn reserved_total(&self) -> usize {
        (0..self.member.len()).filter(|&i| self.member[i]).map(|i| self.outstanding[i]).sum()
    }

    /// Whether a client is in graceful drain (see [`RoundCore::set_draining`]).
    pub fn is_draining(&self, client: usize) -> bool {
        self.draining[client]
    }

    /// Begin a graceful drain: the client stays a member (its in-flight
    /// grant stays reserved until the final verdict) but its next
    /// allocation is forced to 0, so the drain completes within one wave
    /// of participation.
    pub fn set_draining(&mut self, client: usize, draining: bool) {
        self.draining[client] = draining;
    }

    /// Whether a client is idle (no active request; trace-driven runs).
    pub fn is_idle(&self, client: usize) -> bool {
        self.idle[client]
    }

    /// Mark a member idle/busy (the request tracker drives this at wave
    /// boundaries). Idle members are granted 0 — the drain grant rule
    /// without the retirement — so their budget water-fills over busy
    /// clients until their next request arrives.
    pub fn set_idle(&mut self, client: usize, idle: bool) {
        self.idle[client] = idle;
    }

    /// Whether this core runs the closed-loop speculation controller
    /// (`policy=turbo`).
    pub fn turbo_enabled(&self) -> bool {
        self.turbo.is_some()
    }

    /// The controller's current speculation cap for `client` (the full
    /// budget when turbo is off — never binding).
    pub fn turbo_cap(&self, client: usize) -> usize {
        match &self.turbo {
            Some(t) => t.cap(client),
            None => self.capacity,
        }
    }

    /// Publish a client's SLO headroom for the upcoming wave (from the
    /// request tracker; no-op when turbo is off).
    pub fn set_slo_headroom(&mut self, client: usize, headroom: f64) {
        if let Some(t) = &mut self.turbo {
            t.set_headroom(client, headroom);
        }
    }

    /// Admit a new member under the reservation invariant: the grant is
    /// the uniform share `C / (m + 1)` over the new member count, clamped
    /// to `max_draft` and to the budget not currently reserved by other
    /// members' in-flight drafts — so Σ outstanding ≤ C keeps holding at
    /// the instant of admission. Returns the initial grant S_i(0).
    pub fn admit_member(&mut self, client: usize, max_draft: usize) -> usize {
        let others: usize = (0..self.member.len())
            .filter(|&i| self.member[i] && i != client)
            .map(|i| self.outstanding[i])
            .sum();
        let count =
            (0..self.member.len()).filter(|&i| self.member[i] && i != client).count();
        let share = self.capacity / (count + 1).max(1);
        let grant = share.min(max_draft).min(self.capacity.saturating_sub(others));
        self.member[client] = true;
        self.draining[client] = false;
        self.idle[client] = false;
        self.idle_grant[client] = false;
        self.outstanding[client] = grant;
        grant
    }

    /// Retire a member after its final verdict: drop its reservation and
    /// membership. Its estimator entries stay in place as the archived
    /// lifetime state (slots are never reused).
    pub fn retire_member(&mut self, client: usize) {
        self.member[client] = false;
        self.draining[client] = false;
        self.idle[client] = false;
        self.idle_grant[client] = false;
        self.outstanding[client] = 0;
    }

    /// Swap the allocation policy (utility ablations).
    pub fn set_allocator(&mut self, allocator: Box<dyn Allocator>) {
        self.allocator = allocator;
    }

    /// Rejection sampling for one verify-batch row (paper step ④), with
    /// the core-owned verdict RNG — the draw order over rows is the RNG
    /// stream contract that keeps sync mode bit-identical.
    pub fn judge(
        &mut self,
        ratios: &[f32],
        resid: &[f32],
        bonus: &[f32],
        vocab: usize,
    ) -> ClientVerdict {
        verify_client(ratios, resid, bonus, vocab, &mut self.verdict_rng)
    }

    /// Tree rejection sampling for one verify-batch row, on the same
    /// core-owned verdict RNG stream (an arity-1 tree consumes draws
    /// bit-identically to [`RoundCore::judge`]).
    pub fn judge_tree(
        &mut self,
        tree: &DraftTree,
        tokens: &[u8],
        ratios: &[f32],
        resid: &[f32],
        q: &[f32],
        vocab: usize,
    ) -> TreeVerdict {
        verify_tree(tree, tokens, ratios, resid, q, vocab, &mut self.verdict_rng)
    }

    /// Process one wave's observations (paper steps ⑤–⑥):
    ///
    /// 1. sparse estimator update (eqs. 3–4, Algorithm 1 line 14);
    /// 2. GOODSPEED-SCHED over the wave's live set (line 15), with absent
    ///    members' in-flight grants reserved out of the budget;
    /// 3. outstanding-grant bookkeeping;
    /// 4. one wave-indexed [`RoundRecord`] (send time is patched in later
    ///    by [`RoundCore::note_send_ns`] after the verdict fan-out).
    ///
    /// Returns each participant's next allocation, in `obs` order.
    pub fn finish_wave(
        &mut self,
        wave: u64,
        obs: &[WaveObs],
        recv_ns: u64,
        verify_ns: u64,
    ) -> Vec<usize> {
        let mut next = Vec::with_capacity(obs.len());
        self.finish_wave_into(wave, obs, recv_ns, verify_ns, &mut next);
        next
    }

    /// Allocation-free form of [`RoundCore::finish_wave`]: the per-
    /// participant grant vector is caller-owned and recycled across waves
    /// (cleared and refilled), the scheduler runs through the reusable
    /// [`Allocator::allocate_into`] path, and — with a streaming recorder
    /// — the wave record's shell is recycled too. Bit-identical outputs.
    pub fn finish_wave_into(
        &mut self,
        wave: u64,
        obs: &[WaveObs],
        recv_ns: u64,
        verify_ns: u64,
        next: &mut Vec<usize>,
    ) {
        let n = self.estimators.len();
        // Per-wave scratch is recycled: clear + resize within the
        // high-water capacity is a pure refill, no allocation.
        self.dense.clear();
        self.dense.resize(n, None);
        self.caps.live.clear();
        self.caps.live.resize(n, false);
        self.caps.max_per_client.clear();
        self.caps.max_per_client.resize(n, 0);
        for o in obs {
            assert!(o.client_id < n, "client_id {} out of range ({n})", o.client_id);
            // An idle-era zero-draft keep-alive wave is not an
            // observation: S = 0 yields a neutral mean ratio of 1.0, and
            // feeding that in every idle wave (including the wake wave,
            // whose in-flight draft still carries the idle-era 0 grant)
            // would drive α̂ toward the ceiling and X^β toward 1 while the
            // client has no real work — corrupting both the gradient
            // weights and turbo's headroom the moment it wakes. Idle
            // clients' estimates stay frozen at their last busy value,
            // like absent clients'.
            self.dense[o.client_id] = if self.idle[o.client_id] || self.idle_grant[o.client_id] {
                None
            } else {
                Some((o.mean_ratio, o.goodput as f64))
            };
            self.caps.live[o.client_id] = true;
            // A non-member participant is a client that migrated away while
            // its draft was in flight here: its grant is reserved by the
            // *new* shard at the value it had at hand-off, so never grant
            // it more than that — otherwise the drained wave could exceed
            // the budget the other shard set aside for it. A draining
            // member gets 0: this wave delivers its final verdict, and its
            // share water-fills over the surviving members. An *idle*
            // member (no active request; trace-driven runs) gets 0 by the
            // same rule, but keeps its membership — the flag clears when
            // its next request arrives.
            let parked = self.draining[o.client_id] || self.idle[o.client_id];
            self.caps.max_per_client[o.client_id] = if parked {
                0
            } else if self.member[o.client_id] {
                o.max_next
            } else {
                o.max_next.min(self.outstanding[o.client_id])
            };
        }
        // Closed-loop speculation control: one controller step per
        // participant (headroom was published at the wave boundary), then
        // the targets cap the allocation below. Congestion is the
        // reserved-over-capacity fraction at this boundary: shedding only
        // helps when the budget is actually scarce.
        let congestion = self.reserved_total() as f64 / self.capacity.max(1) as f64;
        if let Some(turbo) = &mut self.turbo {
            for o in obs {
                // Like the estimator skip above, an idle-era keep-alive
                // wave is no controller signal: its neutral accept of 1.0
                // and the idle-deflated congestion would regrow a shed cap
                // across every idle gap. The cap freezes while idle; a
                // tight new request reopens it via the behind branch.
                if !self.idle[o.client_id] && !self.idle_grant[o.client_id] {
                    turbo.observe(o.client_id, o.mean_ratio, congestion);
                }
                self.caps.max_per_client[o.client_id] =
                    self.caps.max_per_client[o.client_id].min(turbo.cap(o.client_id));
            }
        }
        self.estimators.update_round(&self.dense);

        // Absent *members* keep their in-flight grants reserved so
        // interleaved waves can never jointly exceed the budget; in a
        // dense (sync) wave the reservation is 0 and this is exactly the
        // paper's per-round allocation.
        let reserved: usize = (0..n)
            .filter(|&i| self.member[i] && !self.caps.live[i])
            .map(|i| self.outstanding[i])
            .sum();
        self.caps.capacity = self.capacity.saturating_sub(reserved);
        self.allocator.allocate_into(&self.estimators, &self.caps, &mut self.alloc);

        next.clear();
        for o in obs {
            self.outstanding[o.client_id] = self.alloc[o.client_id];
            // The grant this wave hands out is the draft the *next* wave
            // verifies: remember whether it was an idle-masked 0 so that
            // wave's neutral sample is skipped too (wake-wave coverage).
            self.idle_grant[o.client_id] = self.idle[o.client_id];
            next.push(self.alloc[o.client_id]);
        }
        let mut rec = self.spare.take().unwrap_or_default();
        rec.round = wave;
        rec.shard = self.shard;
        rec.recv_ns = recv_ns;
        rec.verify_ns = verify_ns;
        rec.send_ns = 0; // noted after the verdict fan-out
        rec.clients.clear();
        rec.clients.extend(obs.iter().map(|o| ClientRoundMetrics {
            client_id: o.client_id,
            s_used: o.s_used,
            accepted: o.accepted,
            goodput: o.goodput,
            mean_ratio: o.mean_ratio,
            spec_depth: o.spec_depth,
            alpha_hat: self.estimators.alpha_hat[o.client_id],
            x_beta: self.estimators.x_beta[o.client_id],
            next_alloc: self.alloc[o.client_id],
        }));
        self.spare = self.recorder.push_reuse(rec);
    }

    /// Record the measured send-phase time on the wave just processed.
    pub fn note_send_ns(&mut self, send_ns: u64) {
        self.recorder.note_send_ns(send_ns);
    }

    /// Fold extra measured time into the wave's verify phase. The live
    /// leader uses this to keep the Fig 3 semantics — `verify_ns` covers
    /// verification *plus scheduling* — since `finish_wave`'s own
    /// estimator/allocation work happens after the caller's verify lap.
    /// (The simulator doesn't call it: its verify phase is virtual time.)
    pub fn note_verify_extra_ns(&mut self, extra_ns: u64) {
        self.recorder.note_verify_extra_ns(extra_ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn core(n: usize, capacity: usize) -> RoundCore {
        RoundCore::new(
            n,
            Smoothing::Fixed(0.3),
            Smoothing::Fixed(0.5),
            Policy::GoodSpeed,
            2025,
            capacity,
            capacity / n.max(1),
        )
    }

    fn obs(client_id: usize, accepted: usize, max_next: usize) -> WaveObs {
        WaveObs {
            client_id,
            s_used: accepted + 1,
            accepted,
            goodput: accepted + 1,
            mean_ratio: 0.7,
            spec_depth: accepted + 1,
            max_next,
        }
    }

    #[test]
    fn dense_wave_allocates_full_budget_and_records() {
        let mut c = core(4, 16);
        let wave: Vec<WaveObs> = (0..4).map(|i| obs(i, 2, 16)).collect();
        let next = c.finish_wave(0, &wave, 111, 222);
        assert_eq!(next.len(), 4);
        assert!(next.iter().sum::<usize>() <= 16);
        let rec = c.recorder.rounds.last().unwrap();
        assert_eq!(rec.round, 0);
        assert_eq!(rec.recv_ns, 111);
        assert_eq!(rec.verify_ns, 222);
        assert_eq!(rec.clients.len(), 4);
        // Estimators moved off the prior for every participant.
        for i in 0..4 {
            assert!((c.estimators.alpha_hat[i] - 0.5).abs() > 1e-6);
        }
        c.note_send_ns(333);
        assert_eq!(c.recorder.rounds.last().unwrap().send_ns, 333);
    }

    #[test]
    fn partial_wave_reserves_absent_members_budget() {
        let mut c = core(4, 16);
        // Clients 0 and 2 participate; 1 and 3 hold outstanding = 4 each.
        let wave = vec![obs(0, 1, 16), obs(2, 1, 16)];
        let next = c.finish_wave(0, &wave, 0, 0);
        // 16 − (4 + 4) reserved ⇒ at most 8 for the wave.
        assert!(next.iter().sum::<usize>() <= 8, "{next:?}");
        // Absent clients' estimates untouched.
        assert!((c.estimators.alpha_hat[1] - 0.5).abs() < 1e-12);
        assert!((c.estimators.alpha_hat[3] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn non_members_do_not_reserve_budget() {
        let mut c = core(4, 16);
        // This core only owns clients 0 and 2 (a 2-shard split).
        c.set_member(1, false);
        c.set_member(3, false);
        c.set_capacity(8);
        let wave = vec![obs(0, 1, 16), obs(2, 1, 16)];
        let next = c.finish_wave(0, &wave, 0, 0);
        // No reservation from the other shard's clients: the full slice
        // is available to this shard's wave.
        assert_eq!(next.iter().sum::<usize>(), 8, "{next:?}");
        assert!(c.is_member(0) && !c.is_member(1));
    }

    #[test]
    fn non_member_participant_capped_at_its_outstanding() {
        // The migration drain path: the client left this shard (member =
        // false) but its in-flight draft is verified here. Its next grant
        // must not exceed the outstanding value the new shard reserved.
        let mut c = core(2, 16);
        c.set_member(1, false);
        c.set_outstanding(1, 3);
        let next = c.finish_wave(0, &[obs(0, 1, 16), obs(1, 1, 16)], 0, 0);
        assert!(next[1] <= 3, "departed client over-granted: {next:?}");
    }

    #[test]
    fn outstanding_tracks_last_grant() {
        let mut c = core(2, 8);
        assert_eq!(c.outstanding(0), 4);
        let next = c.finish_wave(0, &[obs(0, 2, 8), obs(1, 2, 8)], 0, 0);
        assert_eq!(c.outstanding(0), next[0]);
        assert_eq!(c.outstanding(1), next[1]);
        c.set_outstanding(1, 7);
        assert_eq!(c.outstanding(1), 7);
    }

    #[test]
    fn admit_respects_the_reservation_invariant() {
        let mut c = core(4, 16);
        // Slot 3 starts empty: not a member, no reservation.
        c.retire_member(3);
        assert_eq!(c.members(), vec![0, 1, 2]);
        // 3 members × 4 outstanding = 12 reserved of 16.
        assert_eq!(c.reserved_total(), 12);
        // Admission grant: share C/(3+1) = 4, free budget = 4 → grant 4.
        let g = c.admit_member(3, 32);
        assert_eq!(g, 4);
        assert!(c.is_member(3));
        assert_eq!(c.reserved_total(), 16);
        assert!(c.reserved_total() <= c.capacity());
        // A second admission with nothing free grants 0, never overshoots.
        let mut c3 = core(3, 8);
        c3.retire_member(2);
        c3.set_outstanding(0, 4);
        c3.set_outstanding(1, 4);
        assert_eq!(c3.admit_member(2, 32), 0);
        assert!(c3.reserved_total() <= c3.capacity());
    }

    #[test]
    fn draining_member_gets_zero_but_stays_reserved() {
        let mut c = core(4, 16);
        c.set_draining(1, true);
        assert!(c.is_draining(1));
        // Before its final wave the drain keeps the reservation.
        assert_eq!(c.reserved_total(), 16);
        let wave: Vec<WaveObs> = (0..4).map(|i| obs(i, 2, 16)).collect();
        let next = c.finish_wave(0, &wave, 0, 0);
        assert_eq!(next[1], 0, "draining client must be granted 0: {next:?}");
        assert!(next[0] > 0 && next[2] > 0 && next[3] > 0, "{next:?}");
        // Retirement releases the reservation and the drain flag.
        c.retire_member(1);
        assert!(!c.is_member(1));
        assert!(!c.is_draining(1));
        assert_eq!(c.outstanding(1), 0);
        assert_eq!(c.members(), vec![0, 2, 3]);
    }

    #[test]
    fn shard_id_is_stamped_on_records() {
        let mut c = core(2, 8);
        c.set_shard(3);
        c.finish_wave(5, &[obs(0, 0, 8)], 0, 0);
        let rec = c.recorder.rounds.last().unwrap();
        assert_eq!(rec.shard, 3);
        assert_eq!(c.shard_id(), 3);
    }

    #[test]
    fn judge_tree_shares_the_verdict_stream_with_judge() {
        // An arity-1 tree consumes the core's verdict RNG bit-identically
        // to the chain path (resid carries the phantom bonus row at 2).
        let mut a = core(1, 4);
        let mut b = core(1, 4);
        let ratios = [0.9f32, 0.4];
        let resid = vec![0.25f32; 3 * 4];
        let q = vec![0.25f32; 2 * 4];
        let va = a.judge(&ratios, &resid, &resid[2 * 4..3 * 4], 4);
        let vb = b.judge_tree(&DraftTree::chain(2), &[1, 2], &ratios, &resid, &q, 4);
        assert_eq!(va.accepted, vb.path.len());
        assert_eq!(va.correction, vb.correction);
        assert_eq!(va.goodput, vb.goodput);
    }

    #[test]
    fn idle_member_granted_zero_budget_water_fills() {
        let mut c = core(4, 16);
        c.set_idle(1, true);
        assert!(c.is_idle(1));
        let wave: Vec<WaveObs> = (0..4).map(|i| obs(i, 2, 16)).collect();
        let next = c.finish_wave(0, &wave, 0, 0);
        assert_eq!(next[1], 0, "idle client must be granted 0: {next:?}");
        // The idle client's share water-fills over the busy three.
        assert_eq!(next[0] + next[2] + next[3], 16, "{next:?}");
        // Unlike a drain, the slot stays a plain member and wakes up.
        assert!(c.is_member(1) && !c.is_draining(1));
        c.set_idle(1, false);
        let next = c.finish_wave(1, &wave, 0, 0);
        assert!(next[1] > 0, "woken client allocates again: {next:?}");
    }

    #[test]
    fn turbo_without_deadlines_matches_goodspeed_exactly() {
        // No headroom published ⇒ the caps never bind ⇒ turbo and the
        // gradient policy produce identical allocation streams.
        let mut gs = core(3, 12);
        let mut tb = RoundCore::new(
            3,
            Smoothing::Fixed(0.3),
            Smoothing::Fixed(0.5),
            Policy::Turbo,
            2025,
            12,
            4,
        );
        assert!(tb.turbo_enabled() && !gs.turbo_enabled());
        assert_eq!(gs.turbo_cap(0), 12, "turbo-off cap is the full budget");
        for wave in 0..20 {
            let w: Vec<WaveObs> = (0..3).map(|i| obs(i, (wave as usize + i) % 3, 12)).collect();
            let a = gs.finish_wave(wave, &w, 0, 0);
            let b = tb.finish_wave(wave, &w, 0, 0);
            assert_eq!(a, b, "wave {wave}");
        }
    }

    #[test]
    fn turbo_sheds_ahead_clients_toward_tight_ones_under_congestion() {
        let mut c = RoundCore::new(
            2,
            Smoothing::Fixed(0.3),
            Smoothing::Fixed(0.5),
            Policy::Turbo,
            2025,
            8,
            4,
        );
        // Client 0 far ahead of its deadline, client 1 behind; the
        // reservation starts saturated (4 + 4 = 8 = C).
        for wave in 0..30 {
            c.set_slo_headroom(0, 4.0);
            c.set_slo_headroom(1, -0.5);
            let w: Vec<WaveObs> = (0..2).map(|i| obs(i, 2, 8)).collect();
            let next = c.finish_wave(wave, &w, 0, 0);
            assert!(next.iter().sum::<usize>() <= 8);
            if wave > 20 {
                assert!(
                    next[1] > next[0],
                    "wave {wave}: the tight client must out-allocate the ahead one: {next:?}"
                );
            }
        }
        assert!(c.turbo_cap(0) < 8, "ahead client's cap must have shrunk");
        assert_eq!(c.turbo_cap(1), 8, "behind client stays fully open");
    }

    #[test]
    fn judge_consumes_the_verdict_stream_deterministically() {
        let mut a = core(1, 4);
        let mut b = core(1, 4);
        let ratios = [0.9f32, 0.4];
        let resid = vec![0.25f32; 2 * 4];
        let bonus = vec![0.25f32; 4];
        let va = a.judge(&ratios, &resid, &bonus, 4);
        let vb = b.judge(&ratios, &resid, &bonus, 4);
        assert_eq!(va, vb);
        assert_eq!(va.goodput, va.accepted + 1);
    }
}
