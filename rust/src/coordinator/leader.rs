//! The verification-server leader: Algorithm 1's server side.
//!
//! Per round t (paper steps ③–⑥):
//! 1. **Receive** — drain the FIFO fan-in until every client's draft batch
//!    for round t has arrived (wall time here = paper's "receiving time":
//!    draft compute + uplink of the q distributions, dominated by the
//!    slowest client — the straggler effect Fig 3 discusses).
//! 2. **Verify** — one batched forward through the target model (the
//!    bucketed AOT artifact), then per-client rejection sampling; update
//!    α̂ (eq. 3) and X^β (eq. 4); solve GOODSPEED-SCHED (eq. 5) for S(t+1).
//! 3. **Send** — verdicts + next allocations back to every client.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::batcher::build_verify_request;
use crate::configsys::{Policy, Scenario};
use crate::draft::{spawn_draft_server, DraftServerConfig};
use crate::metrics::recorder::{ClientRoundMetrics, Recorder, RoundRecord};
use crate::net::transport::{channel_transport, ServerSide, TcpTransport};
use crate::net::wire::{DraftMsg, Message, VerdictMsg};
use crate::runtime::{EngineFactory, Verifier};
use crate::sched::baselines::{make_allocator, AllocCaps, Allocator};
use crate::sched::Estimators;
use crate::spec::rejection::verify_client;
use crate::util::{Rng, Stopwatch};
use crate::workload::DomainStream;

/// Which transport carries draft batches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transport {
    Channel,
    Tcp,
}

impl Transport {
    pub fn parse(s: &str) -> Option<Transport> {
        match s.to_ascii_lowercase().as_str() {
            "channel" | "chan" => Some(Transport::Channel),
            "tcp" => Some(Transport::Tcp),
            _ => None,
        }
    }
}

/// Everything a full serving run needs.
pub struct RunConfig {
    pub scenario: Scenario,
    pub policy: Policy,
    pub transport: Transport,
    /// Real sleeps for simulated link delays (Fig 3 wants them on).
    pub simulate_network: bool,
}

/// The leader + its verdict RNG and estimators, reusable round to round.
pub struct Leader {
    verifier: Box<dyn Verifier>,
    estimators: Estimators,
    allocator: Box<dyn Allocator>,
    rng: Rng,
    capacity: usize,
    max_draft: usize,
    max_seq: usize,
    verify_k: usize,
    vocab: usize,
    pub recorder: Recorder,
}

impl Leader {
    pub fn new(
        scenario: &Scenario,
        policy: Policy,
        factory: &dyn EngineFactory,
    ) -> Result<Leader> {
        let verifier = factory.make_verifier(&scenario.family)?;
        let estimators =
            Estimators::new(scenario.num_clients, scenario.eta, scenario.beta);
        let allocator = make_allocator(policy, scenario.seed ^ 0x5eed);
        Ok(Leader {
            verifier,
            estimators,
            allocator,
            rng: Rng::new(scenario.seed ^ 0xC0DE),
            capacity: scenario.capacity,
            max_draft: scenario.max_draft.min(factory.verify_k()),
            max_seq: factory.max_seq(),
            verify_k: factory.verify_k(),
            vocab: factory.vocab(),
            recorder: Recorder::new(scenario.num_clients),
        })
    }

    /// Process one assembled round: verification + estimator update +
    /// next-round allocation. Returns the verdicts to send.
    pub fn process_round(&mut self, round: u64, msgs: &[DraftMsg]) -> Result<Vec<VerdictMsg>> {
        let n = msgs.len();
        let (req, views) =
            build_verify_request(msgs, &self.verifier.buckets(), self.verify_k, self.vocab)?;
        let out = self.verifier.verify(&req)?;

        // Rejection sampling per client (paper step ④).
        let v = self.vocab;
        let k = self.verify_k;
        let mut obs: Vec<Option<(f64, f64)>> = Vec::with_capacity(n);
        let mut verdicts = Vec::with_capacity(n);
        let mut metrics = Vec::with_capacity(n);
        for (b, view) in views.iter().enumerate() {
            let s = view.draft_len;
            let ratios = &out.ratio_row(b, k)[..s];
            let resid = out.resid_rows(b, k, v);
            // Bonus distribution: the real bonus output when s == K, else
            // the residual row at j = s (all-zero q ⇒ residual ≡ p).
            let bonus_owned;
            let bonus: &[f32] = if s == k {
                out.bonus_row(b, v)
            } else {
                bonus_owned = &resid[s * v..(s + 1) * v];
                bonus_owned
            };
            let verdict = verify_client(ratios, resid, bonus, v, &mut self.rng);
            obs.push(Some((verdict.mean_ratio, verdict.goodput as f64)));
            metrics.push((verdict.accepted, verdict.goodput, verdict.mean_ratio));
            verdicts.push(VerdictMsg {
                client_id: b as u32,
                round,
                accepted: verdict.accepted as u32,
                correction: verdict.correction,
                next_alloc: 0, // filled below
            });
        }

        // Estimator updates (eqs. 3–4, Algorithm 1 line 14).
        self.estimators.update_round(&obs);

        // GOODSPEED-SCHED (line 15): allocate S(t+1) under context room.
        let max_per_client: Vec<usize> = views
            .iter()
            .zip(&verdicts)
            .map(|(view, vd)| {
                let new_prefix = view.prefix_len + vd.accepted as usize + 1;
                self.max_draft.min(self.max_seq.saturating_sub(new_prefix + 2))
            })
            .collect();
        let caps = AllocCaps { capacity: self.capacity, max_per_client };
        let alloc = self.allocator.allocate(&self.estimators, &caps);
        for (vd, &a) in verdicts.iter_mut().zip(&alloc) {
            vd.next_alloc = a as u32;
        }

        // Metrics.
        let clients = views
            .iter()
            .enumerate()
            .map(|(i, view)| ClientRoundMetrics {
                s_used: view.draft_len,
                accepted: metrics[i].0,
                goodput: metrics[i].1,
                mean_ratio: metrics[i].2,
                alpha_hat: self.estimators.alpha_hat[i],
                x_beta: self.estimators.x_beta[i],
                next_alloc: alloc[i],
            })
            .collect();
        self.recorder.push(RoundRecord {
            round,
            recv_ns: 0,
            verify_ns: 0,
            send_ns: 0,
            clients,
        });
        // Request-latency accounting from new_request transitions.
        for view in &views {
            if view.new_request && round > 0 {
                // The request that just ended is recorded draft-side; the
                // coordinator-side proxy counts rounds between flags.
            }
        }
        Ok(verdicts)
    }

    pub fn estimators(&self) -> &Estimators {
        &self.estimators
    }
}

/// Outcome of [`run_serving`].
pub struct RunOutcome {
    pub recorder: Recorder,
    pub summary: crate::metrics::RunSummary,
    pub draft_stats: Vec<crate::draft::DraftStats>,
}

/// Full distributed run: spawn draft-server threads, drive the leader for
/// `scenario.rounds` rounds, shut down, and collect everything.
pub fn run_serving(cfg: &RunConfig, factory: Arc<dyn EngineFactory>) -> Result<RunOutcome> {
    let scenario = &cfg.scenario;
    scenario.validate().map_err(|e| anyhow!("invalid scenario: {e}"))?;
    let n = scenario.num_clients;

    // Transport.
    let (mut server, ports): (ServerSide, Vec<_>) = match cfg.transport {
        Transport::Channel => channel_transport(n),
        Transport::Tcp => {
            let t = TcpTransport::new(n)?;
            (t.server, t.ports)
        }
    };

    // Draft servers.
    let initial_alloc = scenario.capacity / n.max(1);
    let mut handles = Vec::with_capacity(n);
    let mut root_rng = Rng::new(scenario.seed);
    for (i, port) in ports.into_iter().enumerate() {
        let stream = DomainStream::new(
            scenario.domain(i),
            scenario.domain_stickiness,
            scenario.max_new_tokens,
            root_rng.fork(i as u64),
        );
        let dcfg = DraftServerConfig {
            client_id: i,
            model: scenario.draft_model(i).to_string(),
            initial_alloc: initial_alloc.min(scenario.max_draft),
            link: scenario.link(i),
            simulate_network: cfg.simulate_network,
            seed: scenario.seed ^ (0xD00D + i as u64),
            max_rounds: scenario.rounds + 1,
        };
        handles.push(spawn_draft_server(dcfg, factory.clone(), stream, port));
    }

    let mut leader = Leader::new(scenario, cfg.policy, factory.as_ref())?;
    let run_start = Instant::now();
    let mut request_rounds: Vec<u64> = vec![0; n]; // round of current request start
    for round in 0..scenario.rounds {
        let mut sw = Stopwatch::new();
        // 1. Receive (FIFO until all N batches for this round arrived).
        let mut slots: Vec<Option<DraftMsg>> = vec![None; n];
        let mut have = 0usize;
        while have < n {
            let (id, msg) = server
                .rx
                .recv()
                .map_err(|_| anyhow!("draft servers disconnected at round {round}"))?;
            match msg {
                Message::Draft(d) => {
                    if d.round != round {
                        return Err(anyhow!(
                            "client {id} sent round {} during round {round}",
                            d.round
                        ));
                    }
                    if slots[id].replace(d).is_none() {
                        have += 1;
                    }
                }
                Message::Shutdown => return Err(anyhow!("client {id} shut down early")),
                other => return Err(anyhow!("unexpected {other:?}")),
            }
        }
        let msgs: Vec<DraftMsg> = slots.into_iter().map(Option::unwrap).collect();
        let recv_ns = sw.lap().as_nanos() as u64;

        // Request-latency bookkeeping (coordinator side).
        for (i, m) in msgs.iter().enumerate() {
            if m.new_request {
                if round > 0 {
                    leader
                        .recorder
                        .request_latency_rounds
                        .push(round - request_rounds[i]);
                }
                request_rounds[i] = round;
            }
        }

        // 2. Verify + schedule.
        let verdicts = leader.process_round(round, &msgs)?;
        let verify_ns = sw.lap().as_nanos() as u64;

        // 3. Send verdicts (tiny messages; paper: <0.1 % of wall time).
        for (i, vd) in verdicts.iter().enumerate() {
            (server.txs[i])(&Message::Verdict(vd.clone()))?;
        }
        let send_ns = sw.lap().as_nanos() as u64;

        if let Some(rec) = leader.recorder.rounds.last_mut() {
            rec.recv_ns = recv_ns;
            rec.verify_ns = verify_ns;
            rec.send_ns = send_ns;
        }
    }
    // Shutdown.
    for tx in server.txs.iter_mut() {
        let _ = tx(&Message::Shutdown);
    }
    let wall = run_start.elapsed().as_secs_f64();

    let mut draft_stats = Vec::with_capacity(n);
    for h in handles {
        match h.join() {
            Ok(Ok(s)) => draft_stats.push(s),
            Ok(Err(e)) => return Err(anyhow!("draft server failed: {e}")),
            Err(_) => return Err(anyhow!("draft server panicked")),
        }
    }
    let summary = leader.recorder.summary(wall);
    Ok(RunOutcome { recorder: leader.recorder, summary, draft_stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{MockEngineFactory, MockWorld};

    fn mock_factory() -> Arc<dyn EngineFactory> {
        Arc::new(MockEngineFactory::new(MockWorld {
            vocab: 32,
            max_seq: 128,
            sharpness: 3.0,
            seed: 9,
        }))
    }

    fn smoke_scenario(rounds: u64, clients: usize) -> Scenario {
        let mut s = Scenario::preset("smoke").unwrap();
        s.rounds = rounds;
        s.num_clients = clients;
        s.links = Scenario::default_links(clients, s.seed);
        s
    }

    fn run(policy: Policy, rounds: u64, clients: usize) -> RunOutcome {
        let cfg = RunConfig {
            scenario: smoke_scenario(rounds, clients),
            policy,
            transport: Transport::Channel,
            simulate_network: false,
        };
        run_serving(&cfg, mock_factory()).unwrap()
    }

    #[test]
    fn goodspeed_full_run_over_channel() {
        let out = run(Policy::GoodSpeed, 25, 2);
        assert_eq!(out.recorder.rounds.len(), 25);
        assert_eq!(out.summary.rounds, 25);
        // Every client produced ≥ 1 token per round (the correction).
        for g in &out.summary.per_client_goodput {
            assert!(*g >= 1.0, "{:?}", out.summary.per_client_goodput);
        }
        // Capacity respected every round.
        for r in &out.recorder.rounds {
            let used: usize = r.clients.iter().map(|c| c.s_used).sum();
            assert!(used <= 8, "round {}: {used}", r.round);
        }
        // Acceptance estimates moved off their 0.5 prior.
        let est_moved = out
            .recorder
            .rounds
            .last()
            .unwrap()
            .clients
            .iter()
            .any(|c| (c.alpha_hat - 0.5).abs() > 0.02);
        assert!(est_moved);
    }

    #[test]
    fn all_policies_run() {
        for p in Policy::all() {
            let out = run(p, 10, 2);
            assert_eq!(out.recorder.rounds.len(), 10);
        }
    }

    #[test]
    fn tcp_transport_full_run() {
        let cfg = RunConfig {
            scenario: smoke_scenario(8, 2),
            policy: Policy::GoodSpeed,
            transport: Transport::Tcp,
            simulate_network: false,
        };
        let out = run_serving(&cfg, mock_factory()).unwrap();
        assert_eq!(out.recorder.rounds.len(), 8);
    }

    #[test]
    fn single_client_and_tight_capacity() {
        let mut s = smoke_scenario(10, 1);
        s.capacity = 2;
        let cfg = RunConfig {
            scenario: s,
            policy: Policy::GoodSpeed,
            transport: Transport::Channel,
            simulate_network: false,
        };
        let out = run_serving(&cfg, mock_factory()).unwrap();
        for r in &out.recorder.rounds {
            assert!(r.clients[0].s_used <= 2);
        }
    }

    #[test]
    fn capacity_smaller_than_client_count() {
        // C = 1 with 2 clients: GoodSpeed must starve no one forever
        // (log-utility boundary drift).
        let mut s = smoke_scenario(40, 2);
        s.capacity = 1;
        let cfg = RunConfig {
            scenario: s,
            policy: Policy::GoodSpeed,
            transport: Transport::Channel,
            simulate_network: false,
        };
        let out = run_serving(&cfg, mock_factory()).unwrap();
        // Both clients drafted at least once across the run.
        for i in 0..2 {
            let drafted: usize =
                out.recorder.rounds.iter().map(|r| r.clients[i].s_used).sum();
            assert!(drafted > 0, "client {i} starved");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(Policy::GoodSpeed, 12, 2);
        let b = run(Policy::GoodSpeed, 12, 2);
        for (ra, rb) in a.recorder.rounds.iter().zip(&b.recorder.rounds) {
            for (ca, cb) in ra.clients.iter().zip(&rb.clients) {
                assert_eq!(ca.goodput, cb.goodput);
                assert_eq!(ca.s_used, cb.s_used);
            }
        }
    }

    #[test]
    fn requests_complete_and_latency_recorded() {
        let out = run(Policy::GoodSpeed, 30, 2);
        let total_req: u64 = out.draft_stats.iter().map(|d| d.requests_completed).sum();
        assert!(total_req > 0);
        assert!(!out.recorder.request_latency_rounds.is_empty());
    }
}
