//! The verification-server leader: Algorithm 1's server side.
//!
//! Two coordination disciplines share one verification core
//! ([`Leader::process_wave`]):
//!
//! * **Sync** (`CoordMode::Sync`) — the paper's per-round barrier: drain
//!   the FIFO fan-in until *every* client's draft batch for round t has
//!   arrived (wall time here = paper's "receiving time", dominated by the
//!   slowest client — the straggler effect Fig 3 discusses), verify once,
//!   send verdicts. Reproduces all paper experiments bit-for-bit.
//! * **Async** (`CoordMode::Async`) — the event-driven pipeline: the
//!   leader fires a batched verify as soon as (a) `min_wave_fill` clients
//!   are pending or (b) the `batch_window_us` deadline after the wave's
//!   first arrival expires — whichever comes first — verifying whatever
//!   subset is ready and letting stragglers join a later wave. The run's
//!   verification budget is the same total work as sync
//!   (`num_clients × rounds` verdicts), distributed by arrival order.
//!
//! Per wave (paper steps ③–⑥): batched forward through the target model,
//! then everything engine-agnostic — per-client rejection sampling, α̂
//! (eq. 3) and X^β (eq. 4) sparse updates, GOODSPEED-SCHED (eq. 5) over
//! the wave's live client set — runs in the shared [`RoundCore`], the
//! same code path the analytic simulator executes. See DESIGN.md, "Wave
//! lifecycle", for the state machine.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::batcher::build_verify_request;
use super::core::{RoundCore, WaveObs};
use crate::configsys::{CoordMode, Policy, Scenario};
use crate::draft::{spawn_draft_server, DraftServerConfig};
use crate::metrics::recorder::Recorder;
use crate::net::transport::{channel_transport, ServerSide, TcpTransport};
use crate::net::wire::{DraftMsg, Message, VerdictMsg};
use crate::runtime::{EngineFactory, Verifier};
use crate::util::{Rng, Stopwatch};
use crate::workload::DomainStream;

/// Which transport carries draft batches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transport {
    Channel,
    Tcp,
}

impl Transport {
    pub fn parse(s: &str) -> Option<Transport> {
        match s.to_ascii_lowercase().as_str() {
            "channel" | "chan" => Some(Transport::Channel),
            "tcp" => Some(Transport::Tcp),
            _ => None,
        }
    }
}

/// Everything a full serving run needs.
pub struct RunConfig {
    pub scenario: Scenario,
    pub policy: Policy,
    pub transport: Transport,
    /// Real sleeps for simulated link delays (Fig 3 wants them on).
    pub simulate_network: bool,
}

/// The leader: one verification engine plus the shared wave-processing
/// core (estimators, scheduler, budget accounting, verdict RNG, metrics).
pub struct Leader {
    verifier: Box<dyn Verifier>,
    pub core: RoundCore,
    max_draft: usize,
    max_seq: usize,
    verify_k: usize,
    vocab: usize,
}

impl Leader {
    pub fn new(
        scenario: &Scenario,
        policy: Policy,
        factory: &dyn EngineFactory,
    ) -> Result<Leader> {
        let verifier = factory.make_verifier(&scenario.family)?;
        // Matches the drafters' S_i(0) in `run_serving` (they only clamp
        // further down by context room).
        let initial_alloc = (scenario.capacity / scenario.num_clients.max(1))
            .min(scenario.max_draft);
        Ok(Leader {
            verifier,
            core: RoundCore::new(
                scenario.num_clients,
                scenario.eta,
                scenario.beta,
                policy,
                scenario.seed,
                scenario.capacity,
                initial_alloc,
            ),
            max_draft: scenario.max_draft.min(factory.verify_k()),
            max_seq: factory.max_seq(),
            verify_k: factory.verify_k(),
            vocab: factory.vocab(),
        })
    }

    /// Process one assembled wave: batched verification, then the shared
    /// core's rejection sampling + sparse estimator update + per-wave
    /// allocation over the participating client set. `msgs` holds the
    /// wave's subset in strictly increasing client-id order; a sync round
    /// is simply the wave of everyone. `recv_ns` is the measured
    /// receive-phase wall time; the verify phase is measured here and both
    /// are threaded into the pushed record (the send phase is filled in by
    /// [`Leader::note_send_ns`] after fan-out).
    pub fn process_wave(
        &mut self,
        wave: u64,
        msgs: &[DraftMsg],
        recv_ns: u64,
    ) -> Result<Vec<VerdictMsg>> {
        let mut sw = Stopwatch::new();
        let n_total = self.core.n_clients();
        for m in msgs {
            if m.client_id as usize >= n_total {
                return Err(anyhow!(
                    "client id {} out of range (num_clients = {n_total})",
                    m.client_id
                ));
            }
        }
        let (req, views) =
            build_verify_request(msgs, &self.verifier.buckets(), self.verify_k, self.vocab)?;
        let out = self.verifier.verify(&req)?;

        // Rejection sampling per client (paper step ④), in row order so the
        // core's verdict RNG stream is identical to the pre-core
        // coordinator for dense (sync) waves.
        let v = self.vocab;
        let k = self.verify_k;
        let mut verdicts = Vec::with_capacity(views.len());
        let mut obs = Vec::with_capacity(views.len());
        for (b, view) in views.iter().enumerate() {
            let s = view.draft_len;
            let ratios = &out.ratio_row(b, k)[..s];
            let resid = out.resid_rows(b, k, v);
            let (accepted, path, correction, goodput, mean_ratio, spec_depth) =
                if !view.explicit_tree {
                    // Legacy chain path (bit-identical RNG stream). Bonus
                    // distribution: the real bonus output when s == K, else
                    // the residual row at j = s (all-zero q ⇒ residual ≡ p).
                    let bonus_owned;
                    let bonus: &[f32] = if s == k {
                        out.bonus_row(b, v)
                    } else {
                        bonus_owned = &resid[s * v..(s + 1) * v];
                        bonus_owned
                    };
                    let verdict = self.core.judge(ratios, resid, bonus, v);
                    (
                        verdict.accepted,
                        Vec::new(),
                        verdict.correction,
                        verdict.goodput,
                        verdict.mean_ratio,
                        s,
                    )
                } else {
                    // Tree path: sequential-sibling rejection over the
                    // topology, bonus from the leaf phantom rows.
                    let tv = self.core.judge_tree(
                        &view.tree,
                        &msgs[b].draft,
                        ratios,
                        resid,
                        &msgs[b].q_probs,
                        v,
                    );
                    let path: Vec<u8> = tv.path.iter().map(|&x| x as u8).collect();
                    (
                        tv.path.len(),
                        path,
                        tv.correction,
                        tv.goodput,
                        tv.mean_ratio,
                        view.tree.max_depth(),
                    )
                };
            let new_prefix = view.prefix_len + accepted + 1;
            obs.push(WaveObs {
                client_id: view.client_id,
                s_used: s,
                accepted,
                goodput,
                mean_ratio,
                spec_depth,
                max_next: self.max_draft.min(self.max_seq.saturating_sub(new_prefix + 2)),
            });
            verdicts.push(VerdictMsg {
                client_id: view.client_id as u32,
                // Echo the client's own round (client-local matching; in
                // sync mode this equals the coordinator round).
                round: msgs[b].round,
                accepted: accepted as u32,
                path,
                correction,
                next_alloc: 0, // filled below
                shard: self.core.shard_id() as u32,
            });
        }
        let verify_ns = sw.lap().as_nanos() as u64;

        // Estimator updates + GOODSPEED-SCHED + record emission (Algorithm
        // 1 lines 14–15) — the shared core path. The scheduling time is
        // folded back into the verify phase afterwards so `verify_ns`
        // keeps its Fig 3 meaning: verification *plus* scheduling.
        let next = self.core.finish_wave(wave, &obs, recv_ns, verify_ns);
        self.core.note_verify_extra_ns(sw.lap().as_nanos() as u64);
        for (vd, nx) in verdicts.iter_mut().zip(&next) {
            vd.next_alloc = *nx as u32;
        }
        Ok(verdicts)
    }

    /// Record the measured send-phase time on the wave just processed.
    pub fn note_send_ns(&mut self, send_ns: u64) {
        self.core.note_send_ns(send_ns);
    }

    pub fn estimators(&self) -> &crate::sched::Estimators {
        &self.core.estimators
    }
}

/// Outcome of [`run_serving`].
pub struct RunOutcome {
    pub recorder: Recorder,
    pub summary: crate::metrics::RunSummary,
    pub draft_stats: Vec<crate::draft::DraftStats>,
}

/// Per-client request-latency bookkeeping shared by both modes: latency is
/// counted in *client-local* rounds between `new_request` flags.
struct LatencyTracker {
    start_round: Vec<u64>,
}

impl LatencyTracker {
    fn new(n: usize) -> Self {
        LatencyTracker { start_round: vec![0; n] }
    }

    fn observe(&mut self, recorder: &mut Recorder, client: usize, msg: &DraftMsg) {
        if msg.new_request {
            if msg.round > 0 {
                recorder
                    .request_latency_rounds
                    .push(msg.round - self.start_round[client]);
            }
            self.start_round[client] = msg.round;
        }
    }
}

/// Full distributed run: spawn draft-server threads, drive the leader in
/// the scenario's coordination mode, shut down, and collect everything.
/// Single-verifier path; `num_verifiers > 1` runs go through
/// [`super::pool::run_pool`].
pub fn run_serving(cfg: &RunConfig, factory: Arc<dyn EngineFactory>) -> Result<RunOutcome> {
    let scenario = &cfg.scenario;
    scenario.validate().map_err(|e| anyhow!("invalid scenario: {e}"))?;
    if scenario.num_verifiers > 1 {
        return Err(anyhow!(
            "configuration error: num_verifiers = {} requires the sharded verifier \
             pool — run it via `goodspeed run --verifiers {}` (which dispatches to \
             coordinator::run_pool), or set num_verifiers = 1 for the single-verifier \
             coordinator",
            scenario.num_verifiers,
            scenario.num_verifiers
        ));
    }
    let n = scenario.num_clients;

    // Transport.
    let (mut server, ports): (ServerSide, Vec<_>) = match cfg.transport {
        Transport::Channel => channel_transport(n),
        Transport::Tcp => {
            let t = TcpTransport::new(n)?;
            (t.server, t.ports)
        }
    };

    // Draft servers. In async mode one fast client may absorb most of the
    // total round budget, so the per-client safety cap is the full budget.
    let max_rounds = match scenario.coord_mode {
        CoordMode::Sync => scenario.rounds + 1,
        CoordMode::Async => scenario.rounds.saturating_mul(n as u64) + 1,
    };
    let initial_alloc = scenario.capacity / n.max(1);
    let mut handles = Vec::with_capacity(n);
    let mut root_rng = Rng::new(scenario.seed);
    for (i, port) in ports.into_iter().enumerate() {
        let stream = DomainStream::new(
            scenario.domain(i),
            scenario.domain_stickiness,
            scenario.max_new_tokens,
            root_rng.fork(i as u64),
        )?;
        let dcfg = DraftServerConfig {
            client_id: i,
            model: scenario.draft_model(i).to_string(),
            initial_alloc: initial_alloc.min(scenario.max_draft),
            link: scenario.link(i),
            simulate_network: cfg.simulate_network,
            seed: scenario.seed ^ (0xD00D + i as u64),
            max_rounds,
            spec_shape: scenario.spec_shape,
            verify_k: factory.verify_k(),
        };
        handles.push(spawn_draft_server(dcfg, factory.clone(), stream, port));
    }

    let mut leader = Leader::new(scenario, cfg.policy, factory.as_ref())?;
    let run_start = Instant::now();
    let loop_result = match scenario.coord_mode {
        CoordMode::Sync => run_sync_loop(scenario, &mut server, &mut leader),
        CoordMode::Async => run_async_loop(scenario, &mut server, &mut leader),
    };
    // Shutdown (even on error, so draft threads can exit before join).
    for tx in server.txs.iter_mut() {
        let _ = tx(&Message::Shutdown);
    }
    loop_result?;
    let wall = run_start.elapsed().as_secs_f64();

    let mut draft_stats = Vec::with_capacity(n);
    for h in handles {
        match h.join() {
            Ok(Ok(s)) => draft_stats.push(s),
            Ok(Err(e)) => return Err(anyhow!("draft server failed: {e}")),
            Err(_) => return Err(anyhow!("draft server panicked")),
        }
    }
    let recorder = leader.core.recorder;
    let summary = recorder.summary(wall);
    Ok(RunOutcome { recorder, summary, draft_stats })
}

/// The classic barrier: one dense wave per round, in lockstep.
fn run_sync_loop(
    scenario: &Scenario,
    server: &mut ServerSide,
    leader: &mut Leader,
) -> Result<()> {
    let n = scenario.num_clients;
    let mut latency = LatencyTracker::new(n);
    for round in 0..scenario.rounds {
        let mut sw = Stopwatch::new();
        // 1. Receive (FIFO until all N batches for this round arrived).
        let mut slots: Vec<Option<DraftMsg>> = vec![None; n];
        let mut have = 0usize;
        while have < n {
            let (id, msg) = server
                .recv()
                .map_err(|_| anyhow!("draft servers disconnected at round {round}"))?;
            match msg {
                Message::Draft(d) => {
                    if d.round != round {
                        return Err(anyhow!(
                            "client {id} sent round {} during round {round}",
                            d.round
                        ));
                    }
                    if slots[id].replace(d).is_none() {
                        have += 1;
                    }
                }
                Message::Shutdown => return Err(anyhow!("client {id} shut down early")),
                other => return Err(anyhow!("unexpected {other:?}")),
            }
        }
        let msgs: Vec<DraftMsg> = slots.into_iter().map(Option::unwrap).collect();
        let recv_ns = sw.lap().as_nanos() as u64;

        // Request-latency bookkeeping (coordinator side).
        for (i, m) in msgs.iter().enumerate() {
            latency.observe(&mut leader.core.recorder, i, m);
        }

        // 2. Verify + schedule (one dense wave; verify time is measured
        // inside process_wave — absorb it from the outer lap so the send
        // phase below is measured alone).
        let verdicts = leader.process_wave(round, &msgs, recv_ns)?;
        let _ = sw.lap();

        // 3. Send verdicts (tiny messages; paper: <0.1 % of wall time).
        for vd in &verdicts {
            (server.txs[vd.client_id as usize])(&Message::Verdict(vd.clone()))?;
        }
        leader.note_send_ns(sw.lap().as_nanos() as u64);
    }
    Ok(())
}

/// Admit one fan-in message into the pending set (at most one in-flight
/// draft per client — the actor protocol strictly alternates send/recv).
fn ingest_draft(
    pending: &mut [Option<DraftMsg>],
    pending_n: &mut usize,
    latency: &mut LatencyTracker,
    recorder: &mut Recorder,
    id: usize,
    msg: Message,
) -> Result<()> {
    match msg {
        Message::Draft(d) => {
            latency.observe(recorder, id, &d);
            if pending[id].replace(d).is_some() {
                return Err(anyhow!("client {id}: two drafts in flight"));
            }
            *pending_n += 1;
            Ok(())
        }
        Message::Shutdown => Err(anyhow!("client {id} shut down early")),
        other => Err(anyhow!("unexpected {other:?}")),
    }
}

/// The event-driven pipeline: waves fire on fill or deadline, stragglers
/// join later waves, and the run stops after the same total verification
/// budget as sync (`num_clients × rounds` verdicts).
fn run_async_loop(
    scenario: &Scenario,
    server: &mut ServerSide,
    leader: &mut Leader,
) -> Result<()> {
    let n = scenario.num_clients;
    let window = Duration::from_micros(scenario.batch_window_us);
    let fill_target = scenario.effective_wave_fill();
    let budget: u64 = scenario.rounds.saturating_mul(n as u64);
    let mut delivered: u64 = 0;
    // At most one in-flight draft per client (the actor protocol strictly
    // alternates send/recv).
    let mut pending: Vec<Option<DraftMsg>> = vec![None; n];
    let mut pending_n = 0usize;
    let mut latency = LatencyTracker::new(n);
    let mut wave: u64 = 0;

    while delivered < budget {
        let mut sw = Stopwatch::new();
        // Phase 1 — block for the wave's first draft (nothing to verify
        // until at least one client is ready).
        while pending_n == 0 {
            let (id, msg) = server.recv()?;
            ingest_draft(
                &mut pending,
                &mut pending_n,
                &mut latency,
                &mut leader.core.recorder,
                id,
                msg,
            )?;
        }
        // Phase 2 — batching window: admit more drafts until the wave-fill
        // threshold is met or the deadline expires, whichever comes first.
        let want = fill_target.min((budget - delivered).min(n as u64) as usize);
        let deadline = Instant::now() + window;
        while pending_n < want {
            match server.recv_deadline(deadline)? {
                Some((id, msg)) => ingest_draft(
                    &mut pending,
                    &mut pending_n,
                    &mut latency,
                    &mut leader.core.recorder,
                    id,
                    msg,
                )?,
                None => break, // deadline-triggered flush
            }
        }
        // Phase 3 — opportunistic drain: anything already queued rides
        // along for free (bigger batch, no extra waiting).
        for (id, msg) in server.try_drain()? {
            ingest_draft(
                &mut pending,
                &mut pending_n,
                &mut latency,
                &mut leader.core.recorder,
                id,
                msg,
            )?;
        }

        // Phase 4 — form the wave (index order ⇒ ascending client id).
        let mut msgs: Vec<DraftMsg> = Vec::with_capacity(pending_n);
        for slot in pending.iter_mut() {
            if let Some(d) = slot.take() {
                msgs.push(d);
            }
        }
        pending_n = 0;
        let recv_ns = sw.lap().as_nanos() as u64;

        // Phase 5 — verify + schedule + send (verify time is measured
        // inside process_wave; absorb it so send is measured alone).
        let verdicts = leader.process_wave(wave, &msgs, recv_ns)?;
        let _ = sw.lap();
        for vd in &verdicts {
            (server.txs[vd.client_id as usize])(&Message::Verdict(vd.clone()))?;
        }
        delivered += verdicts.len() as u64;
        leader.note_send_ns(sw.lap().as_nanos() as u64);
        wave += 1;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{MockEngineFactory, MockWorld};

    fn mock_factory() -> Arc<dyn EngineFactory> {
        Arc::new(MockEngineFactory::new(MockWorld {
            vocab: 32,
            max_seq: 128,
            sharpness: 3.0,
            seed: 9,
        }))
    }

    fn smoke_scenario(rounds: u64, clients: usize) -> Scenario {
        let mut s = Scenario::preset("smoke").unwrap();
        s.rounds = rounds;
        s.num_clients = clients;
        s.links = Scenario::default_links(clients, s.seed);
        s
    }

    fn run(policy: Policy, rounds: u64, clients: usize) -> RunOutcome {
        let cfg = RunConfig {
            scenario: smoke_scenario(rounds, clients),
            policy,
            transport: Transport::Channel,
            simulate_network: false,
        };
        run_serving(&cfg, mock_factory()).unwrap()
    }

    fn run_async(
        rounds: u64,
        clients: usize,
        window_us: u64,
        fill: usize,
    ) -> RunOutcome {
        let mut s = smoke_scenario(rounds, clients);
        s.coord_mode = CoordMode::Async;
        s.batch_window_us = window_us;
        s.min_wave_fill = fill;
        let cfg = RunConfig {
            scenario: s,
            policy: Policy::GoodSpeed,
            transport: Transport::Channel,
            simulate_network: false,
        };
        run_serving(&cfg, mock_factory()).unwrap()
    }

    #[test]
    fn transport_parse() {
        assert_eq!(Transport::parse("channel"), Some(Transport::Channel));
        assert_eq!(Transport::parse("Chan"), Some(Transport::Channel));
        assert_eq!(Transport::parse("TCP"), Some(Transport::Tcp));
        assert_eq!(Transport::parse("udp"), None);
        assert_eq!(Transport::parse(""), None);
    }

    #[test]
    fn goodspeed_full_run_over_channel() {
        let out = run(Policy::GoodSpeed, 25, 2);
        assert_eq!(out.recorder.rounds.len(), 25);
        assert_eq!(out.summary.rounds, 25);
        // Every client produced ≥ 1 token per round (the correction).
        for g in &out.summary.per_client_goodput {
            assert!(*g >= 1.0, "{:?}", out.summary.per_client_goodput);
        }
        // Capacity respected every round.
        for r in &out.recorder.rounds {
            let used: usize = r.clients.iter().map(|c| c.s_used).sum();
            assert!(used <= 8, "round {}: {used}", r.round);
        }
        // Acceptance estimates moved off their 0.5 prior.
        let est_moved = out
            .recorder
            .rounds
            .last()
            .unwrap()
            .clients
            .iter()
            .any(|c| (c.alpha_hat - 0.5).abs() > 0.02);
        assert!(est_moved);
    }

    #[test]
    fn all_policies_run() {
        for p in Policy::all() {
            let out = run(p, 10, 2);
            assert_eq!(out.recorder.rounds.len(), 10);
        }
    }

    #[test]
    fn tcp_transport_full_run() {
        let cfg = RunConfig {
            scenario: smoke_scenario(8, 2),
            policy: Policy::GoodSpeed,
            transport: Transport::Tcp,
            simulate_network: false,
        };
        let out = run_serving(&cfg, mock_factory()).unwrap();
        assert_eq!(out.recorder.rounds.len(), 8);
    }

    #[test]
    fn single_client_and_tight_capacity() {
        let mut s = smoke_scenario(10, 1);
        s.capacity = 2;
        let cfg = RunConfig {
            scenario: s,
            policy: Policy::GoodSpeed,
            transport: Transport::Channel,
            simulate_network: false,
        };
        let out = run_serving(&cfg, mock_factory()).unwrap();
        for r in &out.recorder.rounds {
            assert!(r.clients[0].s_used <= 2);
        }
    }

    #[test]
    fn capacity_smaller_than_client_count() {
        // C = 1 with 2 clients: GoodSpeed must starve no one forever
        // (log-utility boundary drift).
        let mut s = smoke_scenario(40, 2);
        s.capacity = 1;
        let cfg = RunConfig {
            scenario: s,
            policy: Policy::GoodSpeed,
            transport: Transport::Channel,
            simulate_network: false,
        };
        let out = run_serving(&cfg, mock_factory()).unwrap();
        // Both clients drafted at least once across the run.
        for i in 0..2 {
            let drafted: usize = out
                .recorder
                .rounds
                .iter()
                .flat_map(|r| r.clients.iter())
                .filter(|c| c.client_id == i)
                .map(|c| c.s_used)
                .sum();
            assert!(drafted > 0, "client {i} starved");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(Policy::GoodSpeed, 12, 2);
        let b = run(Policy::GoodSpeed, 12, 2);
        for (ra, rb) in a.recorder.rounds.iter().zip(&b.recorder.rounds) {
            for (ca, cb) in ra.clients.iter().zip(&rb.clients) {
                assert_eq!(ca.goodput, cb.goodput);
                assert_eq!(ca.s_used, cb.s_used);
            }
        }
    }

    #[test]
    fn requests_complete_and_latency_recorded() {
        let out = run(Policy::GoodSpeed, 30, 2);
        let total_req: u64 = out.draft_stats.iter().map(|d| d.requests_completed).sum();
        assert!(total_req > 0);
        assert!(!out.recorder.request_latency_rounds.is_empty());
    }

    #[test]
    fn sync_phase_timings_are_threaded_through() {
        // Satellite fix: RoundRecord phase times must be the measured
        // values, not zeros.
        let out = run(Policy::GoodSpeed, 10, 2);
        let total_ns: u64 = out.recorder.rounds.iter().map(|r| r.total_ns()).sum();
        assert!(total_ns > 0, "phase timings must be measured");
        let recv_ns: u64 = out.recorder.rounds.iter().map(|r| r.recv_ns).sum();
        assert!(recv_ns > 0, "receive phase must be measured");
    }

    #[test]
    fn process_wave_accepts_client_subsets() {
        // Drive the verification core directly with a partial wave: only
        // clients {1, 3} of 4 are ready.
        let factory = mock_factory();
        let mut s = smoke_scenario(5, 4);
        s.capacity = 12;
        let mut leader = Leader::new(&s, Policy::GoodSpeed, factory.as_ref()).unwrap();
        let msg = |id: u32| DraftMsg {
            client_id: id,
            round: 0,
            prefix: vec![1, 2, 3],
            prompt_len: 3,
            draft: vec![],
            parents: vec![],
            q_probs: vec![],
            new_request: true,
            draft_wall_ns: 7,
        };
        let verdicts = leader.process_wave(0, &[msg(1), msg(3)], 1234).unwrap();
        assert_eq!(verdicts.len(), 2);
        assert_eq!(verdicts[0].client_id, 1);
        assert_eq!(verdicts[1].client_id, 3);
        // Only the participants appear in the wave record…
        let rec = leader.core.recorder.rounds.last().unwrap();
        assert_eq!(rec.recv_ns, 1234);
        let ids: Vec<usize> = rec.clients.iter().map(|c| c.client_id).collect();
        assert_eq!(ids, vec![1, 3]);
        // …and only their estimators moved off the 0.5 prior (an S=0 wave
        // observes a neutral mean ratio of 1.0, pulling α̂ upward).
        let est = leader.estimators();
        assert!((est.alpha_hat[0] - 0.5).abs() < 1e-12);
        assert!((est.alpha_hat[2] - 0.5).abs() < 1e-12);
        assert!((est.alpha_hat[1] - 0.5).abs() > 1e-3);
        assert!((est.alpha_hat[3] - 0.5).abs() > 1e-3);
        // Absent clients get no allocation from this wave.
        let rec_allocs: Vec<usize> = rec.clients.iter().map(|c| c.next_alloc).collect();
        assert!(rec_allocs.iter().sum::<usize>() <= 12);
    }

    #[test]
    fn async_run_delivers_full_budget() {
        let rounds = 15u64;
        let clients = 3usize;
        let out = run_async(rounds, clients, 500, 0);
        let budget = rounds * clients as u64;
        let delivered: u64 = out.recorder.participation().iter().sum();
        // Total verification work matches the sync budget (the final wave
        // may overshoot by at most n−1 verdicts).
        assert!(delivered >= budget, "{delivered} < {budget}");
        assert!(delivered < budget + clients as u64);
        // Every wave holds a non-empty, id-ascending client subset.
        for r in &out.recorder.rounds {
            assert!(!r.clients.is_empty());
            for w in r.clients.windows(2) {
                assert!(w[0].client_id < w[1].client_id);
            }
        }
        // Everyone kept making progress.
        for (i, &p) in out.recorder.participation().iter().enumerate() {
            assert!(p > 0, "client {i} never verified");
        }
    }

    #[test]
    fn async_deadline_flush_forms_partial_waves() {
        // A zero batching window forces deadline flushes: waves fire with
        // whatever arrived, so partial waves must appear and the run must
        // still complete the budget.
        let out = run_async(10, 3, 0, 3);
        let partial = out.recorder.rounds.iter().any(|r| r.clients.len() < 3);
        assert!(partial, "zero window must produce at least one partial wave");
        let delivered: u64 = out.recorder.participation().iter().sum();
        assert!(delivered >= 30);
    }

    #[test]
    fn async_accounting_matches_draft_side() {
        let out = run_async(12, 2, 200, 1);
        for (i, d) in out.draft_stats.iter().enumerate() {
            assert_eq!(
                d.tokens_accepted,
                out.recorder.cum_accepted()[i],
                "client {i} accepted-token accounting"
            );
        }
    }

    #[test]
    fn multi_verifier_scenario_is_a_configuration_error() {
        // Satellite: the single-verifier path must reject pooled scenarios
        // with an actionable message, not a terse internal one.
        let mut s = smoke_scenario(5, 4);
        s.num_verifiers = 2;
        let cfg = RunConfig {
            scenario: s,
            policy: Policy::GoodSpeed,
            transport: Transport::Channel,
            simulate_network: false,
        };
        let err = run_serving(&cfg, mock_factory()).unwrap_err().to_string();
        assert!(err.contains("configuration error"), "{err}");
        assert!(err.contains("goodspeed run --verifiers 2"), "{err}");
        assert!(err.contains("num_verifiers = 2"), "{err}");
    }

    #[test]
    fn tree_mode_full_run_respects_node_budget() {
        // End-to-end tree speculation over the mock engine: every wave's
        // node spend stays within C, depths land between 1 and the node
        // count, and accepted depth never exceeds drafted depth.
        let mut s = smoke_scenario(20, 2);
        s.spec_shape = crate::configsys::SpecShape::Tree { arity: 2, depth: 4 };
        let cfg = RunConfig {
            scenario: s,
            policy: Policy::GoodSpeed,
            transport: Transport::Channel,
            simulate_network: false,
        };
        let out = run_serving(&cfg, mock_factory()).unwrap();
        assert_eq!(out.recorder.rounds.len(), 20);
        let mut saw_branching = false;
        for r in &out.recorder.rounds {
            let used: usize = r.clients.iter().map(|c| c.s_used).sum();
            assert!(used <= 8, "round {}: {used}", r.round);
            for c in &r.clients {
                assert!(c.accepted <= c.spec_depth, "{c:?}");
                assert!(c.spec_depth <= c.s_used.max(1), "{c:?}");
                if c.spec_depth < c.s_used {
                    saw_branching = true;
                }
            }
        }
        assert!(saw_branching, "tree mode must actually branch");
        // Draft-side and coordinator-side accepted accounting still agree.
        for (i, d) in out.draft_stats.iter().enumerate() {
            assert_eq!(d.tokens_accepted, out.recorder.cum_accepted()[i], "client {i}");
        }
    }

    #[test]
    fn adaptive_mode_full_run() {
        let mut s = smoke_scenario(15, 2);
        s.spec_shape = crate::configsys::SpecShape::Adaptive;
        let cfg = RunConfig {
            scenario: s,
            policy: Policy::GoodSpeed,
            transport: Transport::Channel,
            simulate_network: false,
        };
        let out = run_serving(&cfg, mock_factory()).unwrap();
        assert_eq!(out.recorder.rounds.len(), 15);
        for g in &out.summary.per_client_goodput {
            assert!(*g >= 1.0);
        }
    }

    #[test]
    fn chain_mode_is_bit_identical_to_explicit_chain_scenario() {
        // The acceptance criterion: spec_shape = chain reproduces the
        // pre-tree RoundRecords exactly (same seeds → same RNG-determined
        // fields), wave for wave, client for client.
        let a = run(Policy::GoodSpeed, 12, 2);
        let mut s = smoke_scenario(12, 2);
        s.spec_shape = crate::configsys::SpecShape::Chain;
        let cfg = RunConfig {
            scenario: s,
            policy: Policy::GoodSpeed,
            transport: Transport::Channel,
            simulate_network: false,
        };
        let b = run_serving(&cfg, mock_factory()).unwrap();
        assert_eq!(a.recorder.rounds.len(), b.recorder.rounds.len());
        for (ra, rb) in a.recorder.rounds.iter().zip(&b.recorder.rounds) {
            for (ca, cb) in ra.clients.iter().zip(&rb.clients) {
                assert_eq!(ca.goodput, cb.goodput);
                assert_eq!(ca.accepted, cb.accepted);
                assert_eq!(ca.s_used, cb.s_used);
                assert_eq!(ca.next_alloc, cb.next_alloc);
                assert!((ca.alpha_hat - cb.alpha_hat).abs() < 1e-15);
            }
        }
    }

    #[test]
    fn single_verifier_verdicts_carry_shard_zero() {
        let out = run(Policy::GoodSpeed, 5, 2);
        // All records stamped shard 0, and no draft server ever switched.
        for r in &out.recorder.rounds {
            assert_eq!(r.shard, 0);
        }
        for d in &out.draft_stats {
            assert_eq!(d.shard_switches, 0);
        }
    }
}
