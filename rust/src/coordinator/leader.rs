//! The verification-server leader: Algorithm 1's server side.
//!
//! Two coordination disciplines share one verification core
//! ([`Leader::process_wave`]):
//!
//! * **Sync** (`CoordMode::Sync`) — the paper's per-round barrier: drain
//!   the FIFO fan-in until *every* client's draft batch for round t has
//!   arrived (wall time here = paper's "receiving time", dominated by the
//!   slowest client — the straggler effect Fig 3 discusses), verify once,
//!   send verdicts. Reproduces all paper experiments bit-for-bit.
//! * **Async** (`CoordMode::Async`) — the event-driven pipeline: the
//!   leader fires a batched verify as soon as (a) `min_wave_fill` clients
//!   are pending or (b) the `batch_window_us` deadline after the wave's
//!   first arrival expires — whichever comes first — verifying whatever
//!   subset is ready and letting stragglers join a later wave. The run's
//!   verification budget is the same total work as sync
//!   (`num_clients × rounds` verdicts), distributed by arrival order.
//!
//! Per wave (paper steps ③–⑥): batched forward through the target model,
//! then everything engine-agnostic — per-client rejection sampling, α̂
//! (eq. 3) and X^β (eq. 4) sparse updates, GOODSPEED-SCHED (eq. 5) over
//! the wave's live client set — runs in the shared [`RoundCore`], the
//! same code path the analytic simulator executes. See DESIGN.md, "Wave
//! lifecycle", for the state machine.

use std::str::FromStr;

use anyhow::{anyhow, Result};

use super::batcher::{build_verify_request_into, WaveArena};
use super::core::{RoundCore, WaveObs};
use crate::configsys::{Policy, Scenario};
use crate::error::ConfigError;
use crate::net::wire::{DraftMsg, VerdictMsg};
use crate::runtime::{EngineFactory, Verifier, VerifyOutput};
use crate::util::Stopwatch;

/// Which transport carries draft batches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transport {
    Channel,
    Tcp,
}

impl FromStr for Transport {
    type Err = ConfigError;

    fn from_str(s: &str) -> Result<Transport, ConfigError> {
        match s.to_ascii_lowercase().as_str() {
            "channel" | "chan" => Ok(Transport::Channel),
            "tcp" => Ok(Transport::Tcp),
            _ => Err(ConfigError::InvalidChoice {
                field: "transport",
                given: s.to_string(),
                expected: &["channel", "tcp"],
            }),
        }
    }
}

/// Everything a full serving run needs.
pub struct RunConfig {
    pub scenario: Scenario,
    pub policy: Policy,
    pub transport: Transport,
    /// Real sleeps for simulated link delays (Fig 3 wants them on).
    pub simulate_network: bool,
}

/// The leader: one verification engine plus the shared wave-processing
/// core (estimators, scheduler, budget accounting, verdict RNG, metrics).
pub struct Leader {
    verifier: Box<dyn Verifier>,
    pub core: RoundCore,
    max_draft: usize,
    max_seq: usize,
    verify_k: usize,
    vocab: usize,
    /// Shape buckets, cached once from the verifier (stable per engine) so
    /// the wave loop never re-clones them.
    buckets: Vec<(usize, usize)>,
    /// Reusable wave buffers: batched request + per-client views.
    arena: WaveArena,
    /// Reusable verification output.
    out: VerifyOutput,
    /// Reusable per-wave observation buffer.
    obs: Vec<WaveObs>,
    /// Reusable next-allocation buffer (the scheduler's output vector —
    /// recycled so warm waves stay allocation-free through scheduling).
    next: Vec<usize>,
}

impl Leader {
    pub fn new(
        scenario: &Scenario,
        policy: Policy,
        factory: &dyn EngineFactory,
    ) -> Result<Leader> {
        Leader::with_slots(scenario, policy, factory, scenario.num_clients)
    }

    /// A leader whose core is sized to `slots ≥ num_clients` client
    /// slots. The serving cluster reserves slots for scheduled/dynamic
    /// joins; extra slots start as non-members with no reservation, so a
    /// `slots == num_clients` leader is identical to [`Leader::new`].
    pub fn with_slots(
        scenario: &Scenario,
        policy: Policy,
        factory: &dyn EngineFactory,
        slots: usize,
    ) -> Result<Leader> {
        assert!(slots >= scenario.num_clients, "slots must cover the initial clients");
        let verifier = factory.make_verifier(&scenario.family)?;
        // Matches the drafters' S_i(0) in the cluster (they only clamp
        // further down by context room).
        let initial_alloc = (scenario.capacity / scenario.num_clients.max(1))
            .min(scenario.max_draft);
        let mut core = RoundCore::new(
            slots,
            scenario.eta,
            scenario.beta,
            policy,
            scenario.seed,
            scenario.capacity,
            initial_alloc,
        );
        for i in scenario.num_clients..slots {
            core.set_member(i, false);
            core.set_outstanding(i, 0);
        }
        let buckets = verifier.buckets();
        Ok(Leader {
            verifier,
            core,
            max_draft: scenario.max_draft.min(factory.verify_k()),
            max_seq: factory.max_seq(),
            verify_k: factory.verify_k(),
            vocab: factory.vocab(),
            buckets,
            arena: WaveArena::new(),
            out: VerifyOutput::default(),
            obs: Vec::new(),
            next: Vec::new(),
        })
    }

    /// Process one assembled wave: batched verification, then the shared
    /// core's rejection sampling + sparse estimator update + per-wave
    /// allocation over the participating client set. `msgs` holds the
    /// wave's subset in strictly increasing client-id order; a sync round
    /// is simply the wave of everyone. `recv_ns` is the measured
    /// receive-phase wall time; the verify phase is measured here and both
    /// are threaded into the pushed record (the send phase is filled in by
    /// [`Leader::note_send_ns`] after fan-out).
    pub fn process_wave(
        &mut self,
        wave: u64,
        msgs: &[DraftMsg],
        recv_ns: u64,
    ) -> Result<Vec<VerdictMsg>> {
        let mut verdicts = Vec::new();
        self.process_wave_into(wave, msgs, recv_ns, &mut verdicts)?;
        Ok(verdicts)
    }

    /// [`Leader::process_wave`] into a caller-owned verdict buffer,
    /// reusing its slots (including each verdict's `path` capacity). With
    /// warm buffers the whole pipeline — wave assembly, mock
    /// verification, chain rejection sampling — runs without heap
    /// allocation — including scheduling (the allocation vector and the
    /// greedy heap are core/leader scratch) and, with a streaming
    /// recorder, the wave record itself (its shell is recycled; retained
    /// mode keeps every record by design).
    pub fn process_wave_into(
        &mut self,
        wave: u64,
        msgs: &[DraftMsg],
        recv_ns: u64,
        verdicts: &mut Vec<VerdictMsg>,
    ) -> Result<()> {
        let mut sw = Stopwatch::new();
        let mut arena = std::mem::take(&mut self.arena);
        let assembled = self.assemble_wave_into(msgs, &mut arena);
        self.arena = arena;
        assembled?;
        self.verifier.verify_into(&self.arena.req, &mut self.out)?;
        self.conclude_wave_into(wave, msgs, recv_ns, &mut sw, verdicts);
        Ok(())
    }

    /// Stage 1 of the wave: validate the participant ids and assemble the
    /// batched request into `arena`. Takes `&self` — assembly touches no
    /// RNG, estimator, or scheduler state, which is what lets the
    /// pipelined loop run it against caller-owned buffers while the
    /// verify stage owns the leader's spares.
    pub fn assemble_wave_into(&self, msgs: &[DraftMsg], arena: &mut WaveArena) -> Result<()> {
        let n_total = self.core.n_clients();
        for m in msgs {
            if m.client_id as usize >= n_total {
                return Err(anyhow!(
                    "client id {} out of range (num_clients = {n_total})",
                    m.client_id
                ));
            }
        }
        build_verify_request_into(msgs, &self.buckets, self.verify_k, self.vocab, arena)
    }

    /// Hand out the leader's wave buffers for a pipelined round trip
    /// through a [`VerifyStage`](super::pipeline::VerifyStage); the
    /// leader is left with empty (allocation-free) defaults until
    /// [`Leader::put_wave_buffers`] restores them. The pipelined loop is
    /// `take → assemble → submit → (overlap) → collect → put → conclude`.
    pub fn take_wave_buffers(&mut self) -> (WaveArena, VerifyOutput) {
        (std::mem::take(&mut self.arena), std::mem::take(&mut self.out))
    }

    /// Restore the buffers taken by [`Leader::take_wave_buffers`] (with
    /// the stage's verify results in `out`), ready for
    /// [`Leader::conclude_wave_into`].
    pub fn put_wave_buffers(&mut self, arena: WaveArena, out: VerifyOutput) {
        self.arena = arena;
        self.out = out;
    }

    /// Stage 2 of the wave, over the assembled arena and verify output
    /// currently held by the leader: rejection sampling, estimator
    /// updates, GOODSPEED-SCHED, record emission, and verdict fill —
    /// everything whose *order* the bit-identical discipline pins. `sw`
    /// must have been started when the wave's verify phase began, so the
    /// recorded `verify_ns` keeps covering assembly + verify + judging.
    pub fn conclude_wave_into(
        &mut self,
        wave: u64,
        msgs: &[DraftMsg],
        recv_ns: u64,
        sw: &mut Stopwatch,
        verdicts: &mut Vec<VerdictMsg>,
    ) {
        // Rejection sampling per client (paper step ④), in row order so the
        // core's verdict RNG stream is identical to the pre-core
        // coordinator for dense (sync) waves.
        let v = self.vocab;
        let k = self.verify_k;
        let views = &self.arena.views;
        let out = &self.out;
        verdicts.truncate(views.len());
        self.obs.clear();
        self.obs.reserve(views.len());
        for (b, view) in views.iter().enumerate() {
            let s = view.draft_len;
            let ratios = &out.ratio_row(b, k)[..s];
            let resid = out.resid_rows(b, k, v);
            let mut tree_verdict = None;
            let (accepted, correction, goodput, mean_ratio, spec_depth) =
                if !view.explicit_tree {
                    // Legacy chain path (bit-identical RNG stream). Bonus
                    // distribution: the real bonus output when s == K, else
                    // the residual row at j = s (all-zero q ⇒ residual ≡ p).
                    let bonus: &[f32] = if s == k {
                        out.bonus_row(b, v)
                    } else {
                        &resid[s * v..(s + 1) * v]
                    };
                    let verdict = self.core.judge(ratios, resid, bonus, v);
                    (verdict.accepted, verdict.correction, verdict.goodput, verdict.mean_ratio, s)
                } else {
                    // Tree path: sequential-sibling rejection over the
                    // topology, bonus from the leaf phantom rows.
                    let tv = self.core.judge_tree(
                        &view.tree,
                        &msgs[b].draft,
                        ratios,
                        resid,
                        &msgs[b].q_probs,
                        v,
                    );
                    let r = (
                        tv.path.len(),
                        tv.correction,
                        tv.goodput,
                        tv.mean_ratio,
                        view.tree.max_depth(),
                    );
                    tree_verdict = Some(tv);
                    r
                };
            let new_prefix = view.prefix_len + accepted + 1;
            self.obs.push(WaveObs {
                client_id: view.client_id,
                s_used: s,
                accepted,
                goodput,
                mean_ratio,
                spec_depth,
                max_next: self.max_draft.min(self.max_seq.saturating_sub(new_prefix + 2)),
            });
            let shard = self.core.shard_id() as u32;
            if b < verdicts.len() {
                // Recycle the slot (keeps the path buffer's capacity).
                let vd = &mut verdicts[b];
                vd.client_id = view.client_id as u32;
                // Echo the client's own round (client-local matching; in
                // sync mode this equals the coordinator round).
                vd.round = msgs[b].round;
                vd.accepted = accepted as u32;
                vd.path.clear();
                if let Some(tv) = &tree_verdict {
                    vd.path.extend(tv.path.iter().map(|&x| x as u8));
                }
                vd.correction = correction;
                vd.next_alloc = 0; // filled below
                vd.shard = shard;
            } else {
                verdicts.push(VerdictMsg {
                    client_id: view.client_id as u32,
                    round: msgs[b].round,
                    accepted: accepted as u32,
                    path: tree_verdict
                        .as_ref()
                        .map(|tv| tv.path.iter().map(|&x| x as u8).collect())
                        .unwrap_or_default(),
                    correction,
                    next_alloc: 0, // filled below
                    shard,
                });
            }
        }
        let verify_ns = sw.lap().as_nanos() as u64;

        // Estimator updates + GOODSPEED-SCHED + record emission (Algorithm
        // 1 lines 14–15) — the shared core path. The scheduling time is
        // folded back into the verify phase afterwards so `verify_ns`
        // keeps its Fig 3 meaning: verification *plus* scheduling.
        let mut next = std::mem::take(&mut self.next);
        self.core.finish_wave_into(wave, &self.obs, recv_ns, verify_ns, &mut next);
        self.core.note_verify_extra_ns(sw.lap().as_nanos() as u64);
        for (vd, nx) in verdicts.iter_mut().zip(&next) {
            vd.next_alloc = *nx as u32;
        }
        self.next = next;
    }

    /// Record the measured send-phase time on the wave just processed.
    pub fn note_send_ns(&mut self, send_ns: u64) {
        self.core.note_send_ns(send_ns);
    }

    pub fn estimators(&self) -> &crate::sched::Estimators {
        &self.core.estimators
    }
}

/// Per-shard extras of a pooled run, carried by [`RunOutcome::pool`].
#[derive(Clone, Debug, Default)]
pub struct PoolReport {
    /// Per-shard summaries over the same wall clock.
    pub shard_summaries: Vec<crate::metrics::RunSummary>,
    /// Client migrations the pool controller performed.
    pub migrations: u64,
}

/// Outcome of a serving run ([`ServingHandle`](super::ServingHandle)).
pub struct RunOutcome {
    pub recorder: crate::metrics::Recorder,
    pub summary: crate::metrics::RunSummary,
    /// Per client *slot* (initial clients, then one slot per admitted
    /// session; never-attached reserve slots hold defaults).
    pub draft_stats: Vec<crate::draft::DraftStats>,
    /// Present when the run executed on the sharded verifier pool.
    pub pool: Option<PoolReport>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use crate::configsys::CoordMode;
    use crate::coordinator::Cluster;
    use crate::runtime::{MockEngineFactory, MockWorld};

    fn mock_factory() -> Arc<dyn EngineFactory> {
        Arc::new(MockEngineFactory::new(MockWorld {
            vocab: 32,
            max_seq: 128,
            sharpness: 3.0,
            seed: 9,
        }))
    }

    fn smoke_scenario(rounds: u64, clients: usize) -> Scenario {
        let mut s = Scenario::preset("smoke").unwrap();
        s.rounds = rounds;
        s.num_clients = clients;
        s.links = Scenario::default_links(clients, s.seed);
        s
    }

    /// Drive a one-shot run through the session API (builder → wait).
    fn serve(cfg: RunConfig, factory: Arc<dyn EngineFactory>) -> Result<RunOutcome> {
        Cluster::builder(cfg.scenario)
            .policy(cfg.policy)
            .transport(cfg.transport)
            .simulate_network(cfg.simulate_network)
            .engine(factory)
            .start()?
            .wait()
    }

    fn run(policy: Policy, rounds: u64, clients: usize) -> RunOutcome {
        let cfg = RunConfig {
            scenario: smoke_scenario(rounds, clients),
            policy,
            transport: Transport::Channel,
            simulate_network: false,
        };
        serve(cfg, mock_factory()).unwrap()
    }

    fn run_async(
        rounds: u64,
        clients: usize,
        window_us: u64,
        fill: usize,
    ) -> RunOutcome {
        let mut s = smoke_scenario(rounds, clients);
        s.coord_mode = CoordMode::Async;
        s.batch_window_us = window_us;
        s.min_wave_fill = fill;
        let cfg = RunConfig {
            scenario: s,
            policy: Policy::GoodSpeed,
            transport: Transport::Channel,
            simulate_network: false,
        };
        serve(cfg, mock_factory()).unwrap()
    }

    #[test]
    fn transport_parse() {
        assert_eq!("channel".parse(), Ok(Transport::Channel));
        assert_eq!("Chan".parse(), Ok(Transport::Channel));
        assert_eq!("TCP".parse(), Ok(Transport::Tcp));
        let err = "udp".parse::<Transport>().unwrap_err().to_string();
        assert!(err.contains("unknown transport 'udp'"), "{err}");
        assert!(err.contains("channel, tcp"), "{err}");
        assert!("".parse::<Transport>().is_err());
    }

    #[test]
    fn goodspeed_full_run_over_channel() {
        let out = run(Policy::GoodSpeed, 25, 2);
        assert_eq!(out.recorder.rounds.len(), 25);
        assert_eq!(out.summary.rounds, 25);
        // Every client produced ≥ 1 token per round (the correction).
        for g in &out.summary.per_client_goodput {
            assert!(*g >= 1.0, "{:?}", out.summary.per_client_goodput);
        }
        // Capacity respected every round.
        for r in &out.recorder.rounds {
            let used: usize = r.clients.iter().map(|c| c.s_used).sum();
            assert!(used <= 8, "round {}: {used}", r.round);
        }
        // Acceptance estimates moved off their 0.5 prior.
        let est_moved = out
            .recorder
            .rounds
            .last()
            .unwrap()
            .clients
            .iter()
            .any(|c| (c.alpha_hat - 0.5).abs() > 0.02);
        assert!(est_moved);
    }

    #[test]
    fn all_policies_run() {
        for p in Policy::all() {
            let out = run(p, 10, 2);
            assert_eq!(out.recorder.rounds.len(), 10);
        }
    }

    #[test]
    fn tcp_transport_full_run() {
        let cfg = RunConfig {
            scenario: smoke_scenario(8, 2),
            policy: Policy::GoodSpeed,
            transport: Transport::Tcp,
            simulate_network: false,
        };
        let out = serve(cfg, mock_factory()).unwrap();
        assert_eq!(out.recorder.rounds.len(), 8);
    }

    #[test]
    fn single_client_and_tight_capacity() {
        let mut s = smoke_scenario(10, 1);
        s.capacity = 2;
        let cfg = RunConfig {
            scenario: s,
            policy: Policy::GoodSpeed,
            transport: Transport::Channel,
            simulate_network: false,
        };
        let out = serve(cfg, mock_factory()).unwrap();
        for r in &out.recorder.rounds {
            assert!(r.clients[0].s_used <= 2);
        }
    }

    #[test]
    fn capacity_smaller_than_client_count() {
        // C = 1 with 2 clients: GoodSpeed must starve no one forever
        // (log-utility boundary drift).
        let mut s = smoke_scenario(40, 2);
        s.capacity = 1;
        let cfg = RunConfig {
            scenario: s,
            policy: Policy::GoodSpeed,
            transport: Transport::Channel,
            simulate_network: false,
        };
        let out = serve(cfg, mock_factory()).unwrap();
        // Both clients drafted at least once across the run.
        for i in 0..2 {
            let drafted: usize = out
                .recorder
                .rounds
                .iter()
                .flat_map(|r| r.clients.iter())
                .filter(|c| c.client_id == i)
                .map(|c| c.s_used)
                .sum();
            assert!(drafted > 0, "client {i} starved");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(Policy::GoodSpeed, 12, 2);
        let b = run(Policy::GoodSpeed, 12, 2);
        for (ra, rb) in a.recorder.rounds.iter().zip(&b.recorder.rounds) {
            for (ca, cb) in ra.clients.iter().zip(&rb.clients) {
                assert_eq!(ca.goodput, cb.goodput);
                assert_eq!(ca.s_used, cb.s_used);
            }
        }
    }

    #[test]
    fn requests_complete_and_latency_recorded() {
        let out = run(Policy::GoodSpeed, 30, 2);
        let total_req: u64 = out.draft_stats.iter().map(|d| d.requests_completed).sum();
        assert!(total_req > 0);
        assert!(!out.recorder.request_latency_rounds.is_empty());
    }

    #[test]
    fn sync_phase_timings_are_threaded_through() {
        // Satellite fix: RoundRecord phase times must be the measured
        // values, not zeros.
        let out = run(Policy::GoodSpeed, 10, 2);
        let total_ns: u64 = out.recorder.rounds.iter().map(|r| r.total_ns()).sum();
        assert!(total_ns > 0, "phase timings must be measured");
        let recv_ns: u64 = out.recorder.rounds.iter().map(|r| r.recv_ns).sum();
        assert!(recv_ns > 0, "receive phase must be measured");
    }

    #[test]
    fn process_wave_accepts_client_subsets() {
        // Drive the verification core directly with a partial wave: only
        // clients {1, 3} of 4 are ready.
        let factory = mock_factory();
        let mut s = smoke_scenario(5, 4);
        s.capacity = 12;
        let mut leader = Leader::new(&s, Policy::GoodSpeed, factory.as_ref()).unwrap();
        let msg = |id: u32| DraftMsg {
            client_id: id,
            round: 0,
            prefix: vec![1, 2, 3],
            prompt_len: 3,
            draft: vec![],
            parents: vec![],
            q_probs: vec![],
            new_request: true,
            draft_wall_ns: 7,
        };
        let verdicts = leader.process_wave(0, &[msg(1), msg(3)], 1234).unwrap();
        assert_eq!(verdicts.len(), 2);
        assert_eq!(verdicts[0].client_id, 1);
        assert_eq!(verdicts[1].client_id, 3);
        // Only the participants appear in the wave record…
        let rec = leader.core.recorder.rounds.last().unwrap();
        assert_eq!(rec.recv_ns, 1234);
        let ids: Vec<usize> = rec.clients.iter().map(|c| c.client_id).collect();
        assert_eq!(ids, vec![1, 3]);
        // …and only their estimators moved off the 0.5 prior (an S=0 wave
        // observes a neutral mean ratio of 1.0, pulling α̂ upward).
        let est = leader.estimators();
        assert!((est.alpha_hat[0] - 0.5).abs() < 1e-12);
        assert!((est.alpha_hat[2] - 0.5).abs() < 1e-12);
        assert!((est.alpha_hat[1] - 0.5).abs() > 1e-3);
        assert!((est.alpha_hat[3] - 0.5).abs() > 1e-3);
        // Absent clients get no allocation from this wave.
        let rec_allocs: Vec<usize> = rec.clients.iter().map(|c| c.next_alloc).collect();
        assert!(rec_allocs.iter().sum::<usize>() <= 12);
    }

    #[test]
    fn async_run_delivers_full_budget() {
        let rounds = 15u64;
        let clients = 3usize;
        let out = run_async(rounds, clients, 500, 0);
        let budget = rounds * clients as u64;
        let delivered: u64 = out.recorder.participation().iter().sum();
        // Total verification work matches the sync budget (the final wave
        // may overshoot by at most n−1 verdicts).
        assert!(delivered >= budget, "{delivered} < {budget}");
        assert!(delivered < budget + clients as u64);
        // Every wave holds a non-empty, id-ascending client subset.
        for r in &out.recorder.rounds {
            assert!(!r.clients.is_empty());
            for w in r.clients.windows(2) {
                assert!(w[0].client_id < w[1].client_id);
            }
        }
        // Everyone kept making progress.
        for (i, &p) in out.recorder.participation().iter().enumerate() {
            assert!(p > 0, "client {i} never verified");
        }
    }

    #[test]
    fn async_deadline_flush_forms_partial_waves() {
        // A zero batching window forces deadline flushes: waves fire with
        // whatever arrived, so partial waves must appear and the run must
        // still complete the budget.
        let out = run_async(10, 3, 0, 3);
        let partial = out.recorder.rounds.iter().any(|r| r.clients.len() < 3);
        assert!(partial, "zero window must produce at least one partial wave");
        let delivered: u64 = out.recorder.participation().iter().sum();
        assert!(delivered >= 30);
    }

    #[test]
    fn async_accounting_matches_draft_side() {
        let out = run_async(12, 2, 200, 1);
        for (i, d) in out.draft_stats.iter().enumerate() {
            assert_eq!(
                d.tokens_accepted,
                out.recorder.cum_accepted()[i],
                "client {i} accepted-token accounting"
            );
        }
    }

    // (The static-membership parity pin — independent builder runs
    // bit-identical, including CSV bytes — lives in
    // `tests/churn_cluster.rs::static_preset_runs_are_bit_identical_across_sessions`;
    // `deterministic_given_seed` above covers the in-module determinism
    // smoke. The deprecated `run_serving` shim this module used to pin
    // against was exactly `builder → start → wait` and is gone.)

    #[test]
    fn tree_mode_full_run_respects_node_budget() {
        // End-to-end tree speculation over the mock engine: every wave's
        // node spend stays within C, depths land between 1 and the node
        // count, and accepted depth never exceeds drafted depth.
        let mut s = smoke_scenario(20, 2);
        s.spec_shape = crate::configsys::SpecShape::Tree { arity: 2, depth: 4 };
        let cfg = RunConfig {
            scenario: s,
            policy: Policy::GoodSpeed,
            transport: Transport::Channel,
            simulate_network: false,
        };
        let out = serve(cfg, mock_factory()).unwrap();
        assert_eq!(out.recorder.rounds.len(), 20);
        let mut saw_branching = false;
        for r in &out.recorder.rounds {
            let used: usize = r.clients.iter().map(|c| c.s_used).sum();
            assert!(used <= 8, "round {}: {used}", r.round);
            for c in &r.clients {
                assert!(c.accepted <= c.spec_depth, "{c:?}");
                assert!(c.spec_depth <= c.s_used.max(1), "{c:?}");
                if c.spec_depth < c.s_used {
                    saw_branching = true;
                }
            }
        }
        assert!(saw_branching, "tree mode must actually branch");
        // Draft-side and coordinator-side accepted accounting still agree.
        for (i, d) in out.draft_stats.iter().enumerate() {
            assert_eq!(d.tokens_accepted, out.recorder.cum_accepted()[i], "client {i}");
        }
    }

    #[test]
    fn adaptive_mode_full_run() {
        let mut s = smoke_scenario(15, 2);
        s.spec_shape = crate::configsys::SpecShape::Adaptive;
        let cfg = RunConfig {
            scenario: s,
            policy: Policy::GoodSpeed,
            transport: Transport::Channel,
            simulate_network: false,
        };
        let out = serve(cfg, mock_factory()).unwrap();
        assert_eq!(out.recorder.rounds.len(), 15);
        for g in &out.summary.per_client_goodput {
            assert!(*g >= 1.0);
        }
    }

    #[test]
    fn chain_mode_is_bit_identical_to_explicit_chain_scenario() {
        // The acceptance criterion: spec_shape = chain reproduces the
        // pre-tree RoundRecords exactly (same seeds → same RNG-determined
        // fields), wave for wave, client for client.
        let a = run(Policy::GoodSpeed, 12, 2);
        let mut s = smoke_scenario(12, 2);
        s.spec_shape = crate::configsys::SpecShape::Chain;
        let cfg = RunConfig {
            scenario: s,
            policy: Policy::GoodSpeed,
            transport: Transport::Channel,
            simulate_network: false,
        };
        let b = serve(cfg, mock_factory()).unwrap();
        assert_eq!(a.recorder.rounds.len(), b.recorder.rounds.len());
        for (ra, rb) in a.recorder.rounds.iter().zip(&b.recorder.rounds) {
            for (ca, cb) in ra.clients.iter().zip(&rb.clients) {
                assert_eq!(ca.goodput, cb.goodput);
                assert_eq!(ca.accepted, cb.accepted);
                assert_eq!(ca.s_used, cb.s_used);
                assert_eq!(ca.next_alloc, cb.next_alloc);
                assert!((ca.alpha_hat - cb.alpha_hat).abs() < 1e-15);
            }
        }
    }

    #[test]
    fn single_verifier_verdicts_carry_shard_zero() {
        let out = run(Policy::GoodSpeed, 5, 2);
        // All records stamped shard 0, and no draft server ever switched.
        for r in &out.recorder.rounds {
            assert_eq!(r.shard, 0);
        }
        for d in &out.draft_stats {
            assert_eq!(d.shard_switches, 0);
        }
    }
}
