//! The verification-server coordinator — the paper's L3 contribution:
//! wave batching (sync barrier or async event-driven pipeline), batched
//! verification, rejection sampling, sparse estimator updates, gradient
//! scheduling, and verdict fan-out. See DESIGN.md for the wave lifecycle
//! and the sharded-verification architecture.
//!
//! Layering: [`core`] is the engine-agnostic wave-processing core shared
//! with the analytic simulator; [`leader`] drives one verifier engine
//! through it; [`pool`] shards verification across M leaders under a
//! hierarchical proportional-fair budget split; [`cluster`] is the public
//! session-oriented serving API (`Cluster::builder` → [`ServingHandle`])
//! with epoch-stamped membership churn on top of either.
//!
//! Looking for the old one-shot entry point? The deprecated `run_serving`
//! shim was removed once every caller migrated to the builder: a one-shot
//! batch run is [`Cluster::builder`]`(scenario)…start()?.wait()` — the
//! exact call sequence the shim performed, bit-identical to the historic
//! batch runner on static-membership scenarios (pinned by the parity
//! test in `tests/churn_cluster.rs`).

pub mod batcher;
pub mod cluster;
pub mod core;
pub mod leader;
pub mod pipeline;
pub mod pool;

pub use batcher::{build_verify_request, build_verify_request_into, WaveArena};
pub use cluster::{ClientId, Cluster, ClusterBuilder, ClusterStats, ServingHandle};
pub use self::core::{RoundCore, WaveObs};
pub use leader::{Leader, PoolReport, RunConfig, RunOutcome, Transport};
pub use pipeline::VerifyStage;
pub use pool::{run_pool, PoolOutcome};
