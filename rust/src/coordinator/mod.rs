//! The verification-server coordinator — the paper's L3 contribution:
//! FIFO batching, batched verification, rejection sampling, estimator
//! updates, gradient scheduling, and verdict fan-out.

pub mod batcher;
pub mod leader;

pub use batcher::build_verify_request;
pub use leader::{run_serving, Leader, RunConfig, RunOutcome, Transport};
