//! The verification-server coordinator — the paper's L3 contribution:
//! wave batching (sync barrier or async event-driven pipeline), batched
//! verification, rejection sampling, sparse estimator updates, gradient
//! scheduling, and verdict fan-out. See DESIGN.md for the wave lifecycle.

pub mod batcher;
pub mod leader;

pub use batcher::build_verify_request;
pub use leader::{run_serving, Leader, RunConfig, RunOutcome, Transport};
