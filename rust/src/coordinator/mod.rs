//! The verification-server coordinator — the paper's L3 contribution:
//! wave batching (sync barrier or async event-driven pipeline), batched
//! verification, rejection sampling, sparse estimator updates, gradient
//! scheduling, and verdict fan-out. See DESIGN.md for the wave lifecycle
//! and the sharded-verification architecture.
//!
//! Layering: [`core`] is the engine-agnostic wave-processing core shared
//! with the analytic simulator; [`leader`] drives one verifier engine
//! through it; [`pool`] shards verification across M leaders under a
//! hierarchical proportional-fair budget split.

pub mod batcher;
pub mod core;
pub mod leader;
pub mod pool;

pub use batcher::build_verify_request;
pub use self::core::{RoundCore, WaveObs};
pub use leader::{run_serving, Leader, RunConfig, RunOutcome, Transport};
pub use pool::{run_pool, PoolOutcome};
