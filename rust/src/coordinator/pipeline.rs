//! The verify stage of the pipelined wave loop.
//!
//! Engines are not `Send` (PJRT handles live on the thread that built
//! them), so the serial loop's `Box<dyn Verifier>` cannot migrate to a
//! worker. Instead [`VerifyStage::spawn`] gives the stage thread its
//! *own* verifier, built from the shared [`EngineFactory`] with the same
//! family string — for the deterministic engines this repo ships, a
//! second instance is bit-identical to the first, so the pipelined path
//! produces the exact verify outputs of the serial path (pinned by
//! `tests/pipeline_parity.rs`).
//!
//! Division of labor: the coordinator thread keeps *everything* that
//! touches RNG streams, estimators, scheduling, and verdict emission —
//! only the pure `verify_into(&req, &mut out)` call crosses to the stage
//! thread. While it runs, the coordinator overlaps fan-in draining and
//! frame ingest for the next wave (see `pool::run_shard_loop` /
//! `cluster::run_*`), then blocks on [`VerifyStage::take_done_timeout`]
//! at the safe point.
//!
//! Handoff is a single-slot condvar exchange ([`HandoffSlot`]), not an
//! mpsc channel: channel sends heap-allocate a node per message, which
//! would show up in the `alloc_track` warm-wave assertions. The slot is
//! allocation-free in steady state, and the [`WaveArena`]/[`VerifyOutput`]
//! buffers shuttle back and forth by move, so their capacity is reused
//! wave over wave on both sides.

use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::batcher::WaveArena;
use crate::obs::ObsHub;
use crate::runtime::{EngineFactory, VerifyOutput};

/// How long an overlap loop parks on [`VerifyStage::take_done_timeout`]
/// between fan-in drains: long enough that the coordinator isn't spinning,
/// short enough that a draft landing mid-verify is picked up well before
/// the verdict fan-out.
pub const OVERLAP_TICK: Duration = Duration::from_micros(200);

/// A one-deep exchange slot: `put` blocks while full, `take` blocks while
/// empty. Steady-state traffic allocates nothing.
struct HandoffSlot<T> {
    slot: Mutex<Option<T>>,
    cv: Condvar,
}

impl<T> HandoffSlot<T> {
    fn new() -> HandoffSlot<T> {
        HandoffSlot { slot: Mutex::new(None), cv: Condvar::new() }
    }

    fn put(&self, value: T) {
        let mut guard = self.slot.lock().expect("handoff lock");
        while guard.is_some() {
            guard = self.cv.wait(guard).expect("handoff lock");
        }
        *guard = Some(value);
        drop(guard);
        self.cv.notify_all();
    }

    fn take(&self) -> T {
        let mut guard = self.slot.lock().expect("handoff lock");
        loop {
            if let Some(value) = guard.take() {
                drop(guard);
                self.cv.notify_all();
                return value;
            }
            guard = self.cv.wait(guard).expect("handoff lock");
        }
    }

    /// Take if a value arrives within `dur`; `None` on timeout. (A
    /// spurious early return is indistinguishable from a timeout — the
    /// caller's overlap loop simply comes back around.)
    fn take_timeout(&self, dur: Duration) -> Option<T> {
        let guard = self.slot.lock().expect("handoff lock");
        let (mut guard, _timed_out) = self
            .cv
            .wait_timeout_while(guard, dur, |slot| slot.is_none())
            .expect("handoff lock");
        let value = guard.take();
        if value.is_some() {
            drop(guard);
            self.cv.notify_all();
        }
        value
    }
}

enum Job {
    Verify { arena: WaveArena, out: VerifyOutput },
    Stop,
}

struct Done {
    arena: WaveArena,
    out: VerifyOutput,
    result: Result<()>,
}

/// Observability hookup for a stage thread: which hub to feed and which
/// shard's stage track to write. The stage numbers its own waves (jobs
/// don't carry wave ids) — the track shows *stage occupancy*, which is
/// what the overlap question needs.
pub struct StageObs {
    pub hub: Arc<ObsHub>,
    pub shard: usize,
}

/// A dedicated verifier thread executing `verify_into` for one shard.
/// At most one wave is in flight; buffers move through by value and come
/// back with the result, so their capacity is never dropped.
pub struct VerifyStage {
    job: Arc<HandoffSlot<Job>>,
    done: Arc<HandoffSlot<Done>>,
    handle: Option<JoinHandle<()>>,
    in_flight: bool,
}

impl VerifyStage {
    /// Spawn the stage thread and build its verifier inside it (engines
    /// are not `Send`). Blocks until the engine is constructed; a
    /// factory failure is returned here, not deferred to the first wave.
    pub fn spawn(
        factory: Arc<dyn EngineFactory>,
        family: &str,
        thread_name: &str,
    ) -> Result<VerifyStage> {
        VerifyStage::spawn_observed(factory, family, thread_name, None)
    }

    /// [`VerifyStage::spawn`] with an optional flight-recorder hookup:
    /// each forward is timed on the stage thread and recorded as a
    /// stage span (atomics only — the unobserved path is untouched, the
    /// observed path allocation-free).
    pub fn spawn_observed(
        factory: Arc<dyn EngineFactory>,
        family: &str,
        thread_name: &str,
        obs: Option<StageObs>,
    ) -> Result<VerifyStage> {
        let job = Arc::new(HandoffSlot::new());
        let done = Arc::new(HandoffSlot::new());
        let (job2, done2) = (Arc::clone(&job), Arc::clone(&done));
        let family = family.to_string();
        let handle = std::thread::Builder::new()
            .name(thread_name.to_string())
            .spawn(move || {
                // Ready handshake: the first Done carries the engine
                // construction result (and seeds the buffer defaults).
                let mut verifier = match factory.make_verifier(&family) {
                    Ok(v) => {
                        done2.put(Done {
                            arena: WaveArena::default(),
                            out: VerifyOutput::default(),
                            result: Ok(()),
                        });
                        v
                    }
                    Err(e) => {
                        done2.put(Done {
                            arena: WaveArena::default(),
                            out: VerifyOutput::default(),
                            result: Err(e),
                        });
                        return;
                    }
                };
                let mut stage_wave = 0u64;
                loop {
                    match job2.take() {
                        Job::Verify { arena, mut out } => {
                            let result = match &obs {
                                Some(o) => {
                                    let t0 = std::time::Instant::now();
                                    let r = verifier.verify_into(&arena.req, &mut out);
                                    o.hub.stage_span(
                                        o.shard,
                                        stage_wave,
                                        t0.elapsed().as_nanos() as u64,
                                    );
                                    stage_wave += 1;
                                    r
                                }
                                None => verifier.verify_into(&arena.req, &mut out),
                            };
                            done2.put(Done { arena, out, result });
                        }
                        Job::Stop => break,
                    }
                }
            })
            .map_err(|e| anyhow!("spawn verify stage '{thread_name}': {e}"))?;
        let ready = done.take();
        if let Err(e) = ready.result {
            let _ = handle.join();
            return Err(e.context(format!("verify stage '{thread_name}' engine build")));
        }
        Ok(VerifyStage { job, done, handle: Some(handle), in_flight: false })
    }

    /// Hand an assembled wave to the stage. The arena's `req` is
    /// verified into `out`; both come back through
    /// [`VerifyStage::take_done_timeout`] / [`VerifyStage::wait_done`].
    ///
    /// # Panics
    /// If a wave is already in flight (the loop is strictly one-deep).
    pub fn submit(&mut self, arena: WaveArena, out: VerifyOutput) {
        assert!(!self.in_flight, "verify stage already has a wave in flight");
        self.job.put(Job::Verify { arena, out });
        self.in_flight = true;
    }

    /// Collect the in-flight wave if it completes within `dur`. `None`
    /// means still running (or nothing submitted) — overlap loops call
    /// this with a short timeout between fan-in drains.
    pub fn take_done_timeout(
        &mut self,
        dur: Duration,
    ) -> Option<(WaveArena, VerifyOutput, Result<()>)> {
        if !self.in_flight {
            return None;
        }
        let d = self.done.take_timeout(dur)?;
        self.in_flight = false;
        Some((d.arena, d.out, d.result))
    }

    /// Block until the in-flight wave completes; `None` if nothing was
    /// submitted.
    pub fn wait_done(&mut self) -> Option<(WaveArena, VerifyOutput, Result<()>)> {
        if !self.in_flight {
            return None;
        }
        let d = self.done.take();
        self.in_flight = false;
        Some((d.arena, d.out, d.result))
    }
}

impl Drop for VerifyStage {
    fn drop(&mut self) {
        // Drain any in-flight result first so the worker is parked on the
        // job slot, then stop it and reap the thread.
        if self.in_flight {
            let _ = self.done.take();
            self.in_flight = false;
        }
        self.job.put(Job::Stop);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::build_verify_request_into;
    use crate::net::wire::DraftMsg;
    use crate::runtime::{EngineFactory, MockEngineFactory, MockWorld};

    fn factory() -> Arc<dyn EngineFactory> {
        Arc::new(MockEngineFactory::new(MockWorld {
            vocab: 32,
            max_seq: 128,
            sharpness: 3.0,
            seed: 9,
        }))
    }

    fn draft(id: u32, len: usize, vocab: usize) -> DraftMsg {
        DraftMsg {
            client_id: id,
            round: 0,
            prefix: vec![1, 2, 3, (id % 7) as u8],
            prompt_len: 3,
            draft: (0..len).map(|i| ((5 + id as usize + i) % vocab) as u8).collect(),
            parents: Vec::new(),
            q_probs: vec![1.0 / vocab as f32; len * vocab],
            new_request: false,
            draft_wall_ns: 0,
        }
    }

    /// The stage's own verifier instance produces bit-identical output to
    /// a verifier built on the calling thread — the property the
    /// pipelined path's correctness rests on.
    #[test]
    fn stage_output_matches_local_verifier_and_recycles_buffers() {
        let f = factory();
        let (vocab, k) = (f.vocab(), f.verify_k());
        let buckets = f.make_verifier("fam").expect("verifier").buckets();

        let mut stage = VerifyStage::spawn(Arc::clone(&f), "fam", "test-verify-stage")
            .expect("spawn stage");
        let mut local = f.make_verifier("fam").expect("verifier");

        let mut arena = WaveArena::new();
        let mut out = VerifyOutput::default();
        let mut expect = VerifyOutput::default();
        for wave in 0..4u32 {
            let msgs: Vec<DraftMsg> =
                (0..3).map(|c| draft(c, 2 + ((wave + c) % 3) as usize, vocab)).collect();
            build_verify_request_into(&msgs, &buckets, k, vocab, &mut arena)
                .expect("assemble");
            local.verify_into(&arena.req, &mut expect).expect("local verify");

            stage.submit(std::mem::take(&mut arena), std::mem::take(&mut out));
            let (a, o, res) = stage.wait_done().expect("in flight");
            res.expect("stage verify");
            assert_eq!(o, expect, "wave {wave}: stage output diverged");
            arena = a;
            out = o;
        }
    }

    #[test]
    fn take_done_timeout_returns_none_until_submit() {
        let mut stage = VerifyStage::spawn(factory(), "fam", "test-verify-idle")
            .expect("spawn stage");
        assert!(stage.take_done_timeout(Duration::from_millis(1)).is_none());
        assert!(stage.wait_done().is_none());
    }

    #[test]
    fn engine_build_failure_surfaces_at_spawn() {
        struct FailingFactory;
        impl EngineFactory for FailingFactory {
            fn make_drafter(
                &self,
                _model: &str,
            ) -> anyhow::Result<Box<dyn crate::runtime::engine::Drafter>> {
                Err(anyhow!("no drafter"))
            }
            fn make_verifier(
                &self,
                _family: &str,
            ) -> anyhow::Result<Box<dyn crate::runtime::engine::Verifier>> {
                Err(anyhow!("model not in manifest"))
            }
            fn make_target_stepper(
                &self,
                _family: &str,
            ) -> anyhow::Result<Box<dyn crate::runtime::engine::Drafter>> {
                Err(anyhow!("no stepper"))
            }
            fn vocab(&self) -> usize {
                0
            }
            fn max_seq(&self) -> usize {
                0
            }
            fn verify_k(&self) -> usize {
                0
            }
        }
        let err = VerifyStage::spawn(Arc::new(FailingFactory), "fam", "test-verify-fail")
            .expect_err("must fail");
        assert!(format!("{err:#}").contains("model not in manifest"));
    }

    #[test]
    fn handoff_slot_exchanges_in_order() {
        let slot = Arc::new(HandoffSlot::<u32>::new());
        let s2 = Arc::clone(&slot);
        let producer = std::thread::spawn(move || {
            for i in 0..100 {
                s2.put(i);
            }
        });
        for i in 0..100 {
            assert_eq!(slot.take(), i);
        }
        producer.join().expect("producer");
        assert!(slot.take_timeout(Duration::from_millis(1)).is_none());
    }
}
