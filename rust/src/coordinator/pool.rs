//! VerifierPool — sharded verification with hierarchical
//! proportional-fair budgets.
//!
//! `num_verifiers = M > 1` replaces the single leader with M verification
//! *shards*. Each shard owns its own verifier engine, its own transport
//! fan-in, and its own [`RoundCore`] restricted (by membership mask) to
//! the clients currently routed to it, and runs the event-driven wave
//! loop over that subset: a wave fires once all current members are
//! pending or the batching window expires, whichever comes first. Waves
//! on different shards proceed in parallel — one shard's straggler never
//! stalls another shard's clients.
//!
//! **Hierarchical budget split.** The scenario's verification budget C is
//! a *global* contract. A controller (run inline, under the pool lock, by
//! whichever shard's wave crosses the `shard_rebalance_every` boundary)
//! splits C across shards by water-filling (`sched::gradient::
//! hierarchical_split`): every shard gets a floor of one token per
//! member, then the remainder flows to the shards with the largest
//! aggregate gradient pressure `w_s = Σ_{i∈s} ∇U(X_i^β)` — exactly the
//! proportional-fairness rule GOODSPEED-SCHED applies per client, lifted
//! one level up. Inside its slice each shard's core runs the ordinary
//! per-client allocation, so the hierarchy is gradient-consistent top to
//! bottom and Σ_s C_s ≤ C at all times.
//!
//! **Rebalancing.** At the same cadence the controller may migrate one
//! client from the most-pressured shard to the least-pressured one: the
//! router flips the client's next send, the old shard drops it from its
//! membership (after draining any in-flight draft), and the new shard
//! seeds the client's estimator state from the controller's published
//! table so learned α̂ / X^β survive the move. The draft server observes
//! the move via the verdict's shard id (`DraftStats::shard_switches`).
//!
//! The run consumes the same total verification budget as the
//! single-verifier coordinator (`num_clients × rounds` verdicts), so
//! pooled and unpooled runs are work-comparable.
//!
//! **Sharded SLO serving.** Trace-driven scenarios run on the pool too:
//! every shard materializes the full (deterministic) request trace, then
//! restricts its [`RequestTracker`] to its own members
//! (`RequestTracker::retain_members`), so each request is owned by
//! exactly one shard and driven on that shard's wave clock. Migrations
//! carry the client's in-flight request state alongside the estimator
//! hand-off: the donor exports it (age-rebased, nothing censored) into
//! the controller's handoff mailbox and the adopter imports it before its
//! next wave. The per-shard reports merge in [`Recorder::absorb`] exactly
//! like shard verdicts; an unclaimed handoff at run end is censored, not
//! counted as a miss.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::cluster::{ClientId, ClusterStats, Ctl, SlotState};
use super::leader::{Leader, RunConfig, Transport};
use super::pipeline::{StageObs, VerifyStage, OVERLAP_TICK};
use crate::chaos::FaultOp;
use crate::configsys::{ChurnEvent, ChurnKind, ClientSpec, Scenario};
use crate::draft::{spawn_draft_server, DraftServerConfig, DraftStats};
use crate::error::{ConfigError, GoodSpeedError};
use crate::metrics::recorder::{FaultRecord, MembershipEvent, Recorder};
use crate::metrics::RunSummary;
use crate::net::transport::{
    sharded_channel_transport, ClientPort, ServerSide, ShardRouter,
};
use crate::net::wire::{DraftMsg, JoinAckMsg, LeaveMsg, Message, VerdictMsg, PROTOCOL_VERSION};
use crate::obs::ObsHub;
use crate::runtime::EngineFactory;
use crate::sched::gradient::split_budget_by_members;
use crate::sched::utility::{LogUtility, Utility};
use crate::serve::{ClientRequestState, RequestTrace, RequestTracker};
use crate::util::{Rng, Stopwatch, Wakeup};
use crate::workload::DomainStream;

/// How often an idle shard wakes up to check the global stop flag.
const IDLE_TICK: Duration = Duration::from_millis(2);

/// One pending client migration, delivered to a shard between waves.
enum Migration {
    /// Drop this client from the shard's membership.
    Leave(usize),
    /// Adopt this client, seeding its learned state from the controller's
    /// published table (including the decay-schedule observation clock, so
    /// `Smoothing::Decay` continues from the client's real history).
    /// `handoff` marks a migration (vs a fresh admission): the adopter
    /// must also claim the client's in-flight request state from the
    /// handoff mailbox once the donor deposits it.
    Join {
        client: usize,
        alpha_hat: f64,
        x_beta: f64,
        outstanding: usize,
        t_obs: u64,
        handoff: bool,
    },
    /// Begin a graceful drain: the client stays a member until its final
    /// verdict, which the shard answers with a Leave frame.
    Drain(usize),
}

/// Controller state shared by all shards (guarded by one mutex; touched
/// once per wave, which is invisible next to a verification forward).
struct PoolCtl {
    /// Latest published per-client estimates (prior values until a client
    /// first participates somewhere).
    alpha_hat: Vec<f64>,
    x_beta: Vec<f64>,
    outstanding: Vec<usize>,
    /// Per-client observation counts (the decay-schedule clock).
    t_obs: Vec<u64>,
    /// Current per-shard budget slices (Σ ≤ scenario capacity).
    budgets: Vec<usize>,
    /// Per-shard migration inboxes.
    inbox: Vec<Vec<Migration>>,
    /// Global wave counter (all shards) — the rebalance clock.
    waves: u64,
    migrations: u64,
    /// Slot lifecycle (Empty reserve slots → Active → Draining → Retired).
    state: Vec<SlotState>,
    /// Membership epoch (bumps on every join/retire).
    epoch: u64,
    /// Epoch-stamped membership changes, drained into the merged recorder.
    events: Vec<MembershipEvent>,
    /// Published per-shard, per-slot lifetime goodput / participation
    /// (each shard refreshes its own row every wave; a migrated client's
    /// lifetime is the column sum).
    shard_goodput: Vec<Vec<f64>>,
    shard_participation: Vec<Vec<u64>>,
    attached_total: u64,
    retired_total: u64,
    /// Per-shard member lists (ascending slot ids) — the controller-side
    /// membership index, updated at event *creation* (admit / migrate /
    /// retire) while each shard's core masks update at event application.
    /// Lets every controller decision run over members instead of
    /// scanning the whole slot universe.
    members: Vec<Vec<usize>>,
    /// Cached per-shard aggregate gradient pressure Σ ∇U(X^β): refreshed
    /// exactly by each shard for its own row every wave (post_wave) and
    /// adjusted incrementally between waves by admissions/migrations, so
    /// shard picks are O(M), not O(slots).
    pressure: Vec<f64>,
    /// Free (never-yet-admitted) slots, min-first — admission pops the
    /// lowest id, matching the historical linear Empty scan (retired
    /// slots never become Empty again, so this heap is the exact free
    /// set).
    free_slots: BinaryHeap<Reverse<usize>>,
    /// Migration handoff mailbox: the donor shard deposits a migrating
    /// client's age-rebased request state here; the adopting shard claims
    /// it before its next wave. Unclaimed states at run end are censored.
    handoff: Vec<Option<ClientRequestState>>,
    /// Per-shard liveness. A fenced (crashed/abandoned) shard is excluded
    /// from rebalance targets and admissions; its member list empties as
    /// the crash migrates everyone out, so the budget water-fill starves
    /// it automatically. All-true outside chaos runs.
    live: Vec<bool>,
    /// Schedule-clock wave at which each currently-dead shard was crashed
    /// *by the fault schedule* (`None` for live shards and for shards
    /// abandoned on an error path, which are unrecoverable). Drives the
    /// time-to-recover series.
    crash_wave: Vec<Option<u64>>,
    /// Fault/recovery event log, drained into the merged recorder.
    faults: Vec<FaultRecord>,
    /// Schedule-clock waves between each crash and its re-admission.
    time_to_recover: Vec<u64>,
}

impl PoolCtl {
    /// Serving slots (Active | Draining), ascending.
    fn serving(&self) -> Vec<usize> {
        (0..self.state.len())
            .filter(|&i| matches!(self.state[i], SlotState::Active | SlotState::Draining))
            .collect()
    }

    /// Add `client` to `shard`'s member index (keeping it sorted) and
    /// fold its pressure into the cached aggregate.
    fn insert_member(&mut self, shard: usize, client: usize) {
        if let Err(pos) = self.members[shard].binary_search(&client) {
            self.members[shard].insert(pos, client);
        }
        self.pressure[shard] += LogUtility.grad(self.x_beta[client]);
    }

    /// Remove `client` from `shard`'s member index and deduct its cached
    /// pressure (floored at 0 against accumulated float residue; the
    /// owning shard re-publishes the exact row every wave).
    fn remove_member(&mut self, shard: usize, client: usize) {
        if let Ok(pos) = self.members[shard].binary_search(&client) {
            self.members[shard].remove(pos);
            self.pressure[shard] =
                (self.pressure[shard] - LogUtility.grad(self.x_beta[client])).max(0.0);
        }
    }

    /// Per-slot lifetime goodput summed across the shards that served it.
    fn lifetime_goodput(&self) -> Vec<f64> {
        let slots = self.state.len();
        let mut out = vec![0.0; slots];
        for row in &self.shard_goodput {
            for (i, &g) in row.iter().enumerate() {
                out[i] += g;
            }
        }
        out
    }

    /// Per-slot participation summed across shards.
    fn participation(&self) -> Vec<u64> {
        let slots = self.state.len();
        let mut out = vec![0u64; slots];
        for row in &self.shard_participation {
            for (i, &p) in row.iter().enumerate() {
                out[i] += p;
            }
        }
        out
    }
}

struct PoolShared {
    stop: AtomicBool,
    delivered: AtomicU64,
    budget_total: u64,
    /// Retired sessions whose drained stragglers shards must discard.
    retired: Vec<AtomicBool>,
    ctl: Mutex<PoolCtl>,
    /// Progress signal: shards notify after every `post_wave` publish and
    /// whenever the stop flag latches, so the driver's idle wait parks on
    /// a condvar instead of polling a 2 ms sleep tick.
    wakeup: Wakeup,
}

impl PoolShared {
    fn stopping(&self) -> bool {
        self.stop.load(Ordering::Acquire)
    }

    fn is_retired(&self, client: usize) -> bool {
        self.retired[client].load(Ordering::Acquire)
    }
}

/// Outcome of [`run_pool`].
pub struct PoolOutcome {
    /// All shards' waves merged into one client-universe recorder (each
    /// record keeps its shard id).
    pub recorder: Recorder,
    pub summary: RunSummary,
    /// Per-shard summaries over the same wall clock.
    pub shard_summaries: Vec<RunSummary>,
    pub draft_stats: Vec<DraftStats>,
    /// Client migrations the controller performed.
    pub migrations: u64,
}

/// Recompute the hierarchical budget split from the controller's published
/// estimates — the shared rule in `sched::gradient::split_budget_by_members`,
/// over the controller's own member index (no slot-universe scans).
fn compute_budgets(scenario: &Scenario, ctl: &PoolCtl) -> Vec<usize> {
    split_budget_by_members(
        scenario.capacity,
        scenario.max_draft,
        &ctl.members,
        &ctl.alpha_hat,
        &ctl.x_beta,
    )
}

/// Controller step: refresh the budget split, then migrate at most one
/// client from the highest- to the lowest-pressure shard when the
/// imbalance is material (> 1.5×) and the donor keeps ≥ 1 member.
/// The hi/lo pick reads the cached per-shard pressure aggregates (O(M));
/// only the donor's own member list is walked for the starvation pick.
fn controller_step(
    scenario: &Scenario,
    router: &ShardRouter,
    ctl: &mut PoolCtl,
    obs: Option<&ObsHub>,
) {
    ctl.budgets = compute_budgets(scenario, ctl);
    let u = LogUtility;
    let m = ctl.members.len();
    if m < 2 {
        return;
    }
    // Fenced (crashed) shards are neither donors nor targets; with all
    // shards live this reduces to the historical hi/lo scan.
    let (mut hi, mut lo) = (usize::MAX, usize::MAX);
    for s in 0..m {
        if !ctl.live[s] {
            continue;
        }
        if hi == usize::MAX || ctl.pressure[s] > ctl.pressure[hi] {
            hi = s;
        }
        if lo == usize::MAX || ctl.pressure[s] < ctl.pressure[lo] {
            lo = s;
        }
    }
    if hi == usize::MAX || hi == lo || ctl.members[hi].len() < 2 {
        return;
    }
    if ctl.pressure[hi] <= 1.5 * ctl.pressure[lo].max(1e-9) {
        return;
    }
    // Move the donor shard's most-starved client (largest ∇U) to the
    // underloaded shard. Draining sessions stay put — their remaining
    // lifetime is one verdict.
    let client = match ctl
        .members[hi]
        .iter()
        .copied()
        .filter(|&i| ctl.state[i] == SlotState::Active)
        .max_by(|&a, &b| u.grad(ctl.x_beta[a]).total_cmp(&u.grad(ctl.x_beta[b])))
    {
        Some(c) => c,
        None => return,
    };
    router.assign(client, lo);
    ctl.remove_member(hi, client);
    ctl.insert_member(lo, client);
    ctl.inbox[hi].push(Migration::Leave(client));
    ctl.inbox[lo].push(Migration::Join {
        client,
        alpha_hat: ctl.alpha_hat[client],
        x_beta: ctl.x_beta[client],
        outstanding: ctl.outstanding[client],
        t_obs: ctl.t_obs[client],
        handoff: true,
    });
    ctl.migrations += 1;
    if let Some(hub) = obs {
        hub.note_migration(hi, client as u64);
        hub.metrics.migrations_total.add(1);
    }
    // Budgets follow the new membership immediately.
    ctl.budgets = compute_budgets(scenario, ctl);
}

/// Shard-local request accounting for trace-driven pooled runs: this
/// shard's tracker partition, the clients whose migrated request state
/// has not yet landed in the handoff mailbox, and the shard's current
/// wave (the tracker clock migrations re-base against).
struct ShardTracker {
    tracker: RequestTracker,
    awaiting: Vec<usize>,
    wave: u64,
}

/// Apply any pending migrations addressed to this shard: membership flips
/// (core mask + the shard-local member list) plus the full estimator
/// hand-off (α̂, X^β, outstanding grant, and the decay-schedule
/// observation clock). Trace-driven shards also move request state: a
/// Leave exports the client's in-flight/queued requests into the handoff
/// mailbox (censoring nothing); a migration Join claims them — or queues
/// the client on the awaiting list until the donor deposits.
fn apply_inbox(
    shard: usize,
    leader: &mut Leader,
    ctl: &mut PoolCtl,
    members: &mut Vec<usize>,
    mut serve: Option<&mut ShardTracker>,
) {
    for mig in std::mem::take(&mut ctl.inbox[shard]) {
        match mig {
            Migration::Leave(client) => {
                leader.core.set_member(client, false);
                if let Ok(pos) = members.binary_search(&client) {
                    members.remove(pos);
                }
                if let Some(st) = serve.as_mut() {
                    // A client that left before its handoff state ever
                    // arrived has nothing to export here; its state stays
                    // in the mailbox for whichever shard owns it next.
                    st.awaiting.retain(|&c| c != client);
                    if let Some(state) = st.tracker.export_client(client, st.wave) {
                        ctl.handoff[client] = Some(state);
                    }
                }
            }
            Migration::Join { client, alpha_hat, x_beta, outstanding, t_obs, handoff } => {
                leader.core.set_member(client, true);
                leader.core.estimators.alpha_hat[client] = alpha_hat;
                leader.core.estimators.x_beta[client] = x_beta;
                leader.core.estimators.set_observations(client, t_obs);
                leader.core.set_outstanding(client, outstanding);
                if let Err(pos) = members.binary_search(&client) {
                    members.insert(pos, client);
                }
                if handoff {
                    if let Some(st) = serve.as_mut() {
                        match ctl.handoff[client].take() {
                            Some(state) => st.tracker.import_client(client, state, st.wave),
                            None => st.awaiting.push(client),
                        }
                    }
                }
            }
            Migration::Drain(client) => leader.core.set_draining(client, true),
        }
    }
    // Claim any handoff state deposited since its Join was applied.
    if let Some(st) = serve {
        if !st.awaiting.is_empty() {
            let wave = st.wave;
            let tracker = &mut st.tracker;
            st.awaiting.retain(|&c| match ctl.handoff[c].take() {
                Some(state) => {
                    tracker.import_client(c, state, wave);
                    false
                }
                None => true,
            });
        }
    }
}

/// Per-wave bookkeeping a shard performs under the pool lock: publish its
/// members' learned state, advance the rebalance clock (running the
/// controller on the boundary), apply inbound migrations, and adopt the
/// current budget slice. Walks only this shard's member list — never the
/// slot universe — so the per-wave coordinator cost scales with shard
/// occupancy, not fleet size.
#[allow(clippy::too_many_arguments)]
fn post_wave(
    scenario: &Scenario,
    shard: usize,
    leader: &mut Leader,
    router: &ShardRouter,
    shared: &PoolShared,
    members: &mut Vec<usize>,
    serve: &mut Option<ShardTracker>,
    obs: Option<&ObsHub>,
) {
    let mut ctl = shared.ctl.lock().expect("pool lock");
    let lg = leader.core.recorder.lifetime_goodput();
    let part = leader.core.recorder.participation();
    for &i in members.iter() {
        ctl.alpha_hat[i] = leader.core.estimators.alpha_hat[i];
        ctl.x_beta[i] = leader.core.estimators.x_beta[i];
        ctl.outstanding[i] = leader.core.outstanding(i);
        ctl.t_obs[i] = leader.core.estimators.observations(i);
        // Publish this shard's cumulative per-slot views (a migrated
        // client's lifetime is the column sum across shards).
        ctl.shard_goodput[shard][i] = lg[i];
        ctl.shard_participation[shard][i] = part[i];
    }
    // Re-base this shard's cached pressure aggregate on the freshly
    // published estimates (the owner overwrites the controller's
    // incremental adjustments with an exact sum once per wave).
    let u = LogUtility;
    ctl.pressure[shard] = members.iter().map(|&i| u.grad(ctl.x_beta[i])).sum();
    ctl.waves += 1;
    let every = scenario.shard_rebalance_every;
    if every > 0 && ctl.waves % every == 0 {
        controller_step(scenario, router, &mut ctl, obs);
    }
    apply_inbox(shard, leader, &mut ctl, members, serve.as_mut());
    leader.core.set_capacity(ctl.budgets[shard]);
    drop(ctl);
    // Wave published: wake the driver so schedule/stop decisions react
    // now, not at the next poll tick.
    shared.wakeup.notify();
}

/// Answer a session hello with the granted S_i(0) and current epoch (the
/// controller published both at admission, before the client could send).
fn answer_hello(
    server: &mut ServerSide,
    shared: &PoolShared,
    id: usize,
    protocol: u8,
) -> Result<()> {
    if protocol > PROTOCOL_VERSION {
        return Err(anyhow!(
            "client {id} speaks protocol {protocol}, newer than {PROTOCOL_VERSION}"
        ));
    }
    let (initial_alloc, epoch) = {
        let ctl = shared.ctl.lock().expect("pool lock");
        (ctl.outstanding[id] as u32, ctl.epoch)
    };
    (server.txs[id])(&Message::JoinAck(JoinAckMsg {
        client_id: id as u32,
        protocol: PROTOCOL_VERSION,
        initial_alloc,
        epoch,
    }))
}

fn ingest(
    pending: &mut [Option<DraftMsg>],
    pending_n: &mut usize,
    shared: &PoolShared,
    tolerate_dups: bool,
    dup_drops: &mut u64,
    id: usize,
    msg: Message,
) -> Result<()> {
    match msg {
        Message::Draft(d) => {
            // A retired session's drained straggler (the draft it sent
            // between its final verdict and the Leave frame) is dropped.
            if shared.is_retired(id) {
                return Ok(());
            }
            if pending[id].replace(d).is_some() {
                // Chaos runs tolerate a duplicated in-flight draft (a
                // `DuplicateBurst` or a transport replay): the slot keeps
                // one copy, the extra is counted and discarded, never
                // verified twice. Outside chaos this stays the hard
                // protocol error it always was.
                if tolerate_dups {
                    *dup_drops += 1;
                    return Ok(());
                }
                return Err(anyhow!("client {id}: two drafts in flight"));
            }
            *pending_n += 1;
            Ok(())
        }
        Message::Shutdown => Err(anyhow!("client {id} shut down early")),
        other => Err(anyhow!("unexpected {other:?}")),
    }
}

/// One shard's serving loop: the event-driven wave pipeline over the
/// clients currently routed here. Returns the number of waves processed.
///
/// With `stage` present (`scenario.pipelined`), the verification forward
/// runs on the stage thread while this thread keeps draining fan-in for
/// the next wave; everything that touches RNG, estimators, or scheduling
/// stays here, at the same points in the same order as the serial path.
#[allow(clippy::too_many_arguments)]
fn run_shard_loop(
    scenario: &Scenario,
    shard: usize,
    server: &mut ServerSide,
    leader: &mut Leader,
    router: &ShardRouter,
    shared: &PoolShared,
    serve: &mut Option<ShardTracker>,
    mut stage: Option<VerifyStage>,
    obs: Option<&ObsHub>,
) -> Result<u64> {
    let slots = router.num_clients();
    let window = Duration::from_micros(scenario.batch_window_us);
    // Chaos-only tolerances (duplicate drops, idle inbox drains) are
    // keyed off the schedule so chaos-free runs take the exact historical
    // code path.
    let chaos_active = !scenario.chaos.is_empty();
    let mut dup_drops = 0u64;
    let mut pending: Vec<Option<DraftMsg>> = vec![None; slots];
    let mut pending_n = 0usize;
    let mut wave: u64 = 0;
    // Shard-local member list (sorted ascending), kept in sync with the
    // core's membership mask by `apply_inbox` — the wave loop and
    // `post_wave` walk this instead of scanning the slot universe.
    let mut members: Vec<usize> = router.members_of(shard);
    members.sort_unstable();
    // Wave-loop buffers, reused across waves.
    let mut msgs: Vec<DraftMsg> = Vec::new();
    let mut verdicts: Vec<VerdictMsg> = Vec::new();
    let mut outcomes: Vec<(usize, usize)> = Vec::new();

    'run: while !shared.stopping() {
        let mut sw = Stopwatch::new();
        // Phase 1 — wait for the wave's first draft, waking periodically
        // to honor the global stop (a shard whose clients all migrated
        // away must not block forever).
        while pending_n == 0 {
            if shared.stopping() {
                break 'run;
            }
            match server.recv_deadline(Instant::now() + IDLE_TICK)? {
                Some((id, Message::Join(j))) => answer_hello(server, shared, id, j.protocol)?,
                Some((id, msg)) => ingest(
                    &mut pending,
                    &mut pending_n,
                    shared,
                    chaos_active,
                    &mut dup_drops,
                    id,
                    msg,
                )?,
                None => {
                    // A fenced (crashed) shard idles here with zero
                    // members, so its Leave exports would never flow and
                    // the survivors would wait on the handoff mailbox
                    // forever. Chaos runs drain the inbox on idle ticks;
                    // chaos-free runs keep the untouched idle path.
                    if chaos_active {
                        let mut ctl = shared.ctl.lock().expect("pool lock");
                        if let Some(st) = serve.as_mut() {
                            st.wave = wave;
                        }
                        apply_inbox(shard, leader, &mut ctl, &mut members, serve.as_mut());
                        leader.core.set_capacity(ctl.budgets[shard]);
                    }
                    continue;
                }
            }
        }
        // Phase 2 — batching window: wait for the rest of the current
        // membership until the deadline expires.
        let fill = scenario.effective_wave_fill().min(members.len().max(1));
        let deadline = Instant::now() + window;
        while pending_n < fill {
            match server.recv_deadline(deadline)? {
                Some((id, Message::Join(j))) => answer_hello(server, shared, id, j.protocol)?,
                Some((id, msg)) => ingest(
                    &mut pending,
                    &mut pending_n,
                    shared,
                    chaos_active,
                    &mut dup_drops,
                    id,
                    msg,
                )?,
                None => break, // deadline-triggered flush
            }
        }
        // Phase 3 — opportunistic drain.
        for (id, msg) in server.try_drain()? {
            if let Message::Join(j) = msg {
                answer_hello(server, shared, id, j.protocol)?;
            } else {
                ingest(
                    &mut pending,
                    &mut pending_n,
                    shared,
                    chaos_active,
                    &mut dup_drops,
                    id,
                    msg,
                )?;
            }
        }
        // Phase 4 — form the wave (index order ⇒ ascending client id).
        msgs.clear();
        for slot in pending.iter_mut() {
            if let Some(d) = slot.take() {
                msgs.push(d);
            }
        }
        pending_n = 0;
        let recv_ns = sw.lap().as_nanos() as u64;

        // Adopt pending migrations *before* verifying: a freshly routed
        // client's Join is enqueued (under the pool lock) before the
        // router can steer its first draft here, so draining the inbox now
        // guarantees the wave sees it as a member with its handed-off
        // state — and a later drain can't stomp what this wave learns.
        {
            let mut ctl = shared.ctl.lock().expect("pool lock");
            if let Some(st) = serve.as_mut() {
                st.wave = wave;
            }
            apply_inbox(shard, leader, &mut ctl, &mut members, serve.as_mut());
            leader.core.set_capacity(ctl.budgets[shard]);
        }
        if let Some(st) = serve.as_mut() {
            st.tracker.sync_wave_start_tracked(&mut leader.core, wave);
        }

        // Phase 5 — verify + schedule + send. Pipelined shards hand the
        // assembled wave to the stage thread and keep draining fan-in
        // while it verifies; scheduling and verdict emission run here
        // either way, in the exact serial order.
        match stage.as_mut() {
            Some(stage) => {
                let mut vsw = Stopwatch::new();
                let (mut arena, out) = leader.take_wave_buffers();
                if let Err(e) = leader.assemble_wave_into(&msgs, &mut arena) {
                    leader.put_wave_buffers(arena, out);
                    return Err(e);
                }
                stage.submit(arena, out);
                let (arena, out, res) = loop {
                    for (id, msg) in server.try_drain()? {
                        if let Message::Join(j) = msg {
                            answer_hello(server, shared, id, j.protocol)?;
                        } else {
                            ingest(
                                &mut pending,
                                &mut pending_n,
                                shared,
                                chaos_active,
                                &mut dup_drops,
                                id,
                                msg,
                            )?;
                        }
                    }
                    if let Some(done) = stage.take_done_timeout(OVERLAP_TICK) {
                        break done;
                    }
                };
                leader.put_wave_buffers(arena, out);
                res?;
                leader.conclude_wave_into(wave, &msgs, recv_ns, &mut vsw, &mut verdicts);
            }
            None => leader.process_wave_into(wave, &msgs, recv_ns, &mut verdicts)?,
        }
        let _ = sw.lap();
        for vd in &verdicts {
            (server.txs[vd.client_id as usize])(&Message::Verdict(vd.clone()))?;
        }
        leader.note_send_ns(sw.lap().as_nanos() as u64);
        // Flight-recorder wave span (atomics only; no RNG, no alloc).
        if let Some(hub) = obs {
            if let Some((_, _, recv, verify, send)) = leader.core.recorder.last_wave_phases() {
                hub.wave_span(shard, wave, recv, verify, send);
            }
        }
        if let Some(st) = serve.as_mut() {
            outcomes.clear();
            outcomes.extend(
                verdicts
                    .iter()
                    .map(|vd| (vd.client_id as usize, vd.accepted as usize + 1)),
            );
            st.tracker.sync_wave_end(wave, &outcomes);
        }
        wave += 1;
        if let Some(st) = serve.as_mut() {
            st.wave = wave;
        }

        let delivered = shared
            .delivered
            .fetch_add(verdicts.len() as u64, Ordering::AcqRel)
            + verdicts.len() as u64;
        if delivered >= shared.budget_total {
            shared.stop.store(true, Ordering::Release);
            shared.wakeup.notify();
        }
        // Phase 6 — complete graceful drains: the verdict just sent was
        // the final one for any draining participant. Retire it under the
        // pool lock (epoch bump + membership event), answer with Leave,
        // and deactivate its routing slot.
        let drained: Vec<usize> = verdicts
            .iter()
            .map(|vd| vd.client_id as usize)
            .filter(|&id| leader.core.is_draining(id))
            .collect();
        for id in drained {
            let epoch = {
                let mut ctl = shared.ctl.lock().expect("pool lock");
                ctl.epoch += 1;
                ctl.state[id] = SlotState::Retired;
                ctl.retired_total += 1;
                router.set_active(id, false);
                shared.retired[id].store(true, Ordering::Release);
                // Publish the final-wave goodput/participation before the
                // membership indexes drop the slot — `post_wave` walks
                // members only and would miss the retiree's last wave.
                ctl.shard_goodput[shard][id] = leader.core.recorder.lifetime_goodput()[id];
                ctl.shard_participation[shard][id] =
                    leader.core.recorder.participation()[id];
                ctl.remove_member(shard, id);
                if let Ok(pos) = members.binary_search(&id) {
                    members.remove(pos);
                }
                if let Some(st) = serve.as_mut() {
                    // Close the retiree's request books: claim any handoff
                    // state still in flight toward this shard, then censor
                    // whatever it could not finish.
                    st.awaiting.retain(|&c| c != id);
                    if let Some(state) = ctl.handoff[id].take() {
                        st.tracker.import_client(id, state, wave);
                    }
                    st.tracker.untrack(id, wave);
                }
                let ev = MembershipEvent {
                    wave: ctl.waves / router.num_shards().max(1) as u64,
                    epoch: ctl.epoch,
                    joined: vec![],
                    left: vec![id],
                    members: ctl.serving(),
                };
                ctl.events.push(ev);
                ctl.epoch
            };
            if let Some(hub) = obs {
                hub.note_epoch(shard, epoch);
            }
            let _ = (server.txs[id])(&Message::Leave(LeaveMsg {
                client_id: id as u32,
                epoch,
            }));
            leader.core.retire_member(id);
        }
        // Phase 7 — controller interaction (publish, rebalance, adopt).
        post_wave(scenario, shard, leader, router, shared, &mut members, serve, obs);
    }
    if dup_drops > 0 {
        let mut ctl = shared.ctl.lock().expect("pool lock");
        let w = ctl.waves / router.num_shards().max(1) as u64;
        ctl.faults.push(FaultRecord {
            wave: w,
            shard,
            kind: "duplicate-burst".into(),
            detail: format!("{dup_drops} duplicate in-flight drafts discarded"),
        });
    }
    Ok(wave)
}

/// Mean (α̂, X^β) over a member subset of the controller's published
/// tables — the pool-side population prior for admissions, clamped to
/// the same bounds `Estimators::seed_from_population` applies on the
/// single-verifier path.
fn population_mean(ctl: &PoolCtl, members: &[usize]) -> (f64, f64) {
    use crate::sched::estimator::{ALPHA_MAX, ALPHA_MIN};
    if members.is_empty() {
        return (0.5, 1.0);
    }
    let n = members.len() as f64;
    let a = members.iter().map(|&i| ctl.alpha_hat[i]).sum::<f64>() / n;
    let x = members.iter().map(|&i| ctl.x_beta[i]).sum::<f64>() / n;
    (a.clamp(ALPHA_MIN, ALPHA_MAX), x.max(1e-9))
}

/// Live shards other than `shard` — the candidate migration targets when
/// `shard` goes down.
fn live_survivors(ctl: &PoolCtl, m: usize, shard: usize) -> Vec<usize> {
    (0..m).filter(|&s| s != shard && ctl.live[s]).collect()
}

/// Move every member of `shard` to the emptiest live survivor, re-seeding
/// estimators from the population prior (the dead shard's learned state
/// is treated as lost with it). With `donor_alive` the fenced shard still
/// runs its wave loop, so a Leave is queued for it to apply — exporting
/// in-flight request state into the handoff mailbox for the adopters to
/// claim; a dead thread gets no Leave, and its adopters are seeded
/// without a handoff to wait on. Recomputes the budget split so the dead
/// shard's freed slice water-fills to the survivors. Returns the migrated
/// clients.
fn migrate_members_to_survivors(
    scenario: &Scenario,
    router: &ShardRouter,
    ctl: &mut PoolCtl,
    shard: usize,
    survivors: &[usize],
    donor_alive: bool,
    obs: Option<&ObsHub>,
) -> Vec<usize> {
    let members = ctl.members[shard].clone();
    let serving = ctl.serving();
    let (pop_a, pop_x) = population_mean(ctl, &serving);
    for &client in &members {
        let target = survivors
            .iter()
            .copied()
            .min_by_key(|&s| (ctl.members[s].len(), s))
            .expect("survivor shard");
        router.assign(client, target);
        ctl.remove_member(shard, client);
        ctl.alpha_hat[client] = pop_a;
        ctl.x_beta[client] = pop_x;
        ctl.t_obs[client] = 0;
        ctl.insert_member(target, client);
        if donor_alive {
            ctl.inbox[shard].push(Migration::Leave(client));
        }
        ctl.inbox[target].push(Migration::Join {
            client,
            alpha_hat: pop_a,
            x_beta: pop_x,
            outstanding: ctl.outstanding[client],
            t_obs: 0,
            handoff: donor_alive,
        });
        ctl.migrations += 1;
        if let Some(hub) = obs {
            hub.note_migration(shard, client as u64);
            hub.metrics.migrations_total.add(1);
        }
    }
    ctl.budgets = compute_budgets(scenario, ctl);
    members
}

/// A shard thread is dying outside the fault schedule (engine/stage/trace
/// setup failure, or a wave-loop error). Instead of latching the global
/// stop — turning one bad shard into a cluster-wide outage — fence it and
/// move its clients to live survivors. Only when no survivor exists does
/// the stop latch: with nobody left to verify, the budget can never
/// finish. The caller must keep draining the shard's fan-in afterwards
/// ([`zombie_drain`]) so drafts that raced into the dead shard's channel
/// still get answered.
fn abandon_shard(
    scenario: &Scenario,
    router: &ShardRouter,
    shared: &PoolShared,
    shard: usize,
    why: &str,
    obs: Option<&ObsHub>,
) {
    let mut ctl = shared.ctl.lock().expect("pool lock");
    let m = router.num_shards();
    let survivors = live_survivors(&ctl, m, shard);
    ctl.live[shard] = false;
    // An abandoned shard is unrecoverable: a scheduled recovery for it is
    // ignored rather than re-admitting a dead thread.
    ctl.crash_wave[shard] = None;
    if survivors.is_empty() {
        drop(ctl);
        if let Some(hub) = obs {
            hub.note_fault(shard, "shard-abandoned");
        }
        shared.stop.store(true, Ordering::Release);
        shared.wakeup.notify();
        return;
    }
    let moved =
        migrate_members_to_survivors(scenario, router, &mut ctl, shard, &survivors, false, obs);
    let wave = ctl.waves / m.max(1) as u64;
    ctl.faults.push(FaultRecord {
        wave,
        shard,
        kind: "shard-abandoned".into(),
        detail: format!("{why}; clients {moved:?} rerouted to shards {survivors:?}"),
    });
    drop(ctl);
    // A dying shard is the flight recorder's marquee trigger: the instant
    // lands in the ring and the postmortem window dumps (latched).
    if let Some(hub) = obs {
        hub.note_fault(shard, "shard-abandoned");
    }
    shared.wakeup.notify();
}

/// Fenced-shard answering machine. After an abandoned shard's clients are
/// rerouted, drafts already in (or racing into) its fan-in would wait
/// forever — the closed draft → verdict loop has no retransmit. Answer
/// each with an empty verdict (zero accepted tokens; the client ingests
/// the correction and its next draft goes to its new shard), so the crash
/// costs a client one wasted round instead of its liveness. Runs until
/// the global stop latches.
fn zombie_drain(server: &mut ServerSide, shared: &PoolShared, shard: usize) {
    while !shared.stopping() {
        let msg = match server.recv_deadline(Instant::now() + IDLE_TICK) {
            Ok(Some(m)) => m,
            Ok(None) => continue,
            Err(_) => return,
        };
        match msg {
            (id, Message::Join(j)) => {
                let _ = answer_hello(server, shared, id, j.protocol);
            }
            (id, Message::Draft(d)) => {
                let v = VerdictMsg {
                    client_id: id as u32,
                    round: d.round,
                    accepted: 0,
                    path: vec![],
                    correction: 0,
                    next_alloc: (d.draft.len() as u32).max(1),
                    shard: shard as u32,
                };
                let _ = (server.txs[id])(&Message::Verdict(v));
                let delivered = shared.delivered.fetch_add(1, Ordering::AcqRel) + 1;
                if delivered >= shared.budget_total {
                    shared.stop.store(true, Ordering::Release);
                    shared.wakeup.notify();
                }
            }
            _ => {}
        }
    }
}

/// Driver-side state for the pool's session churn: client ports/threads
/// by slot, plus everything an admission needs.
struct PoolDriver {
    scenario: Scenario,
    simulate_network: bool,
    factory: Arc<dyn EngineFactory>,
    router: ShardRouter,
    shared: Arc<PoolShared>,
    ports: Vec<Option<Box<dyn ClientPort>>>,
    handles: Vec<Option<std::thread::JoinHandle<Result<DraftStats>>>>,
    root_rng: Rng,
    max_rounds: u64,
    snapshot: Option<Arc<Mutex<ClusterStats>>>,
    /// Telemetry hub (`None` = observability off; no code path changes).
    obs: Option<Arc<ObsHub>>,
}

impl PoolDriver {
    /// Spawn one draft-server actor into `slot`. Dynamically admitted
    /// sessions (`hello`) open with the Join → JoinAck wire handshake,
    /// answered by their shard; initial clients skip it (the legacy
    /// byte-identical stream).
    fn spawn_client(
        &mut self,
        slot: usize,
        spec: ClientSpec,
        initial_alloc: usize,
        hello: bool,
    ) -> Result<()> {
        let stream = DomainStream::new(
            &spec.domain,
            self.scenario.domain_stickiness,
            self.scenario.max_new_tokens,
            self.root_rng.fork(slot as u64),
        )?;
        let dcfg = DraftServerConfig {
            client_id: slot,
            model: spec.model,
            initial_alloc,
            link: spec.link,
            simulate_network: self.simulate_network,
            seed: self.scenario.seed ^ (0xD00D + slot as u64),
            max_rounds: self.max_rounds,
            spec_shape: self.scenario.spec_shape,
            verify_k: self.factory.verify_k(),
            hello,
        };
        let port = self.ports[slot].take().expect("client port");
        self.handles[slot] =
            Some(spawn_draft_server(dcfg, self.factory.clone(), stream, port));
        Ok(())
    }

    /// Admit a new session: route it to the least-pressured shard, seed
    /// its estimator state from the population prior, grant from the
    /// shard's unreserved budget slice, and enqueue the membership
    /// migration the shard applies pre-wave.
    fn admit(&mut self, spec: ClientSpec) -> Result<ClientId, GoodSpeedError> {
        if self.shared.stopping() {
            return Err(GoodSpeedError::Shutdown("pool is stopping".into()));
        }
        if !crate::workload::domains::is_domain(&spec.domain) {
            return Err(ConfigError::invalid(format!(
                "attach: unknown domain '{}' (known: {})",
                spec.domain,
                crate::workload::domains::DOMAINS.join(", ")
            ))
            .into());
        }
        let (slot, grant) = {
            let mut ctl = self.shared.ctl.lock().expect("pool lock");
            // Lowest free slot id first — identical pick order to the
            // historical linear Empty scan, without the O(slots) walk.
            let slot = match ctl.free_slots.pop() {
                Some(Reverse(s)) => s,
                None => {
                    return Err(ConfigError::invalid(
                        "no free client slots (reserve headroom with \
                         ClusterBuilder::reserve_slots or the churn schedule)",
                    )
                    .into())
                }
            };
            // Least-pressured *live* shard: smallest cached Σ ∇U(X^β);
            // ties break to the smaller membership, then the lower index
            // — O(M). Fenced shards never receive admissions.
            let mut shard = 0usize;
            let mut best = (f64::INFINITY, usize::MAX);
            for s in 0..self.router.num_shards() {
                if !ctl.live[s] {
                    continue;
                }
                let key = (ctl.pressure[s], ctl.members[s].len());
                if key.0 < best.0 || (key.0 == best.0 && key.1 < best.1) {
                    best = key;
                    shard = s;
                }
            }
            let serving = ctl.serving();
            let (a, x) = population_mean(&ctl, &serving);
            let reserved: usize =
                ctl.members[shard].iter().map(|&i| ctl.outstanding[i]).sum();
            let share = ctl.budgets[shard] / (ctl.members[shard].len() + 1).max(1);
            let grant = share
                .min(self.scenario.max_draft)
                .min(ctl.budgets[shard].saturating_sub(reserved));
            ctl.alpha_hat[slot] = a;
            ctl.x_beta[slot] = x;
            ctl.outstanding[slot] = grant;
            ctl.t_obs[slot] = 0;
            ctl.insert_member(shard, slot);
            ctl.inbox[shard].push(Migration::Join {
                client: slot,
                alpha_hat: a,
                x_beta: x,
                outstanding: grant,
                t_obs: 0,
                handoff: false,
            });
            self.router.assign(slot, shard);
            self.router.set_active(slot, true);
            ctl.state[slot] = SlotState::Active;
            ctl.epoch += 1;
            ctl.attached_total += 1;
            if let Some(hub) = &self.obs {
                hub.note_epoch(shard, ctl.epoch);
            }
            // Event waves are on the mean per-shard scale (M = 1 ⇒ the
            // plain wave counter), matching the schedule clock.
            let ev = MembershipEvent {
                wave: ctl.waves / self.router.num_shards().max(1) as u64,
                epoch: ctl.epoch,
                joined: vec![(slot, grant)],
                left: vec![],
                members: ctl.serving(),
            };
            ctl.events.push(ev);
            (slot, grant)
        };
        self.spawn_client(slot, spec, grant, true)
            .map_err(|e| GoodSpeedError::Engine(format!("{e:#}")))?;
        Ok(slot)
    }

    /// Schedule a graceful drain: the owning shard is told pre-wave; the
    /// retirement completes after the client's final verdict there.
    fn detach(&mut self, id: ClientId) -> Result<(), GoodSpeedError> {
        let mut ctl = self.shared.ctl.lock().expect("pool lock");
        if id >= ctl.state.len() || ctl.state[id] != SlotState::Active {
            return Err(ConfigError::invalid(format!(
                "detach: client {id} is not an active session"
            ))
            .into());
        }
        ctl.state[id] = SlotState::Draining;
        let shard = self.router.shard_of(id);
        ctl.inbox[shard].push(Migration::Drain(id));
        Ok(())
    }

    /// Scheduled shard crash: fence the shard (its thread keeps running,
    /// so residual in-flight drafts still get real verdicts and handoff
    /// exports still flow) and migrate its members to live survivors with
    /// population-prior estimator seeds. If no survivor exists the fault
    /// is skipped (never latch the global stop on an injected fault).
    fn crash_shard(&mut self, wave: u64, shard: usize) {
        let mut ctl = self.shared.ctl.lock().expect("pool lock");
        if !ctl.live[shard] {
            return;
        }
        let m = self.router.num_shards();
        let survivors = live_survivors(&ctl, m, shard);
        if survivors.is_empty() {
            ctl.faults.push(FaultRecord {
                wave,
                shard,
                kind: "fault-skipped".into(),
                detail: "no live survivor shard; crash not injected".into(),
            });
            drop(ctl);
            if let Some(hub) = &self.obs {
                hub.note_fault(shard, "fault-skipped");
            }
            return;
        }
        ctl.live[shard] = false;
        ctl.crash_wave[shard] = Some(wave);
        let moved = migrate_members_to_survivors(
            &self.scenario,
            &self.router,
            &mut ctl,
            shard,
            &survivors,
            true,
            self.obs.as_deref(),
        );
        ctl.faults.push(FaultRecord {
            wave,
            shard,
            kind: "shard-crash".into(),
            detail: format!("clients {moved:?} migrated to shards {survivors:?}"),
        });
        drop(ctl);
        if let Some(hub) = &self.obs {
            hub.note_fault(shard, "shard-crash");
        }
        self.shared.wakeup.notify();
    }

    /// Scheduled shard recovery: re-admit the shard as a rebalance target
    /// and run one controller step so the first client migrates back
    /// immediately; subsequent rebalance boundaries repopulate it
    /// gradually. Shards abandoned on an error path stay dead.
    fn recover_shard(&mut self, wave: u64, shard: usize) {
        let mut ctl = self.shared.ctl.lock().expect("pool lock");
        if ctl.live[shard] {
            return;
        }
        let crashed_at = match ctl.crash_wave[shard].take() {
            Some(w) => w,
            None => {
                ctl.faults.push(FaultRecord {
                    wave,
                    shard,
                    kind: "fault-skipped".into(),
                    detail: "shard was abandoned (dead thread); recovery ignored".into(),
                });
                drop(ctl);
                if let Some(hub) = &self.obs {
                    hub.note_fault(shard, "fault-skipped");
                }
                return;
            }
        };
        ctl.live[shard] = true;
        ctl.time_to_recover.push(wave.saturating_sub(crashed_at));
        ctl.faults.push(FaultRecord {
            wave,
            shard,
            kind: "shard-recover".into(),
            detail: format!("re-admitted {} waves after its crash", wave - crashed_at),
        });
        controller_step(&self.scenario, &self.router, &mut ctl, self.obs.as_deref());
        drop(ctl);
        if let Some(hub) = &self.obs {
            hub.note_fault(shard, "shard-recover");
        }
        self.shared.wakeup.notify();
    }

    /// Log a client-scoped fault window. Partition/drop windows have no
    /// live injection — a dropped draft would deadlock the closed
    /// draft → verdict loop (no retransmit) — so the live run records the
    /// schedule event and the analytic mirror models the effect; duplicate
    /// bursts are additionally tolerated live by the ingest path.
    fn log_client_fault(&mut self, wave: u64, client: usize, kind: &str, detail: String) {
        let shard = self.router.shard_of(client);
        let mut ctl = self.shared.ctl.lock().expect("pool lock");
        ctl.faults.push(FaultRecord { wave, shard, kind: kind.into(), detail });
        drop(ctl);
        if let Some(hub) = &self.obs {
            hub.note_fault(shard, kind);
        }
    }

    /// Apply one compiled chaos op at its schedule boundary.
    fn apply_fault(&mut self, wave: u64, op: FaultOp) {
        match op {
            FaultOp::Crash { shard } => self.crash_shard(wave, shard),
            FaultOp::Recover { shard } => self.recover_shard(wave, shard),
            FaultOp::PartitionStart { client, until } => self.log_client_fault(
                wave,
                client,
                "partition",
                format!("client {client} uplink degraded until wave {until} (analytic model)"),
            ),
            FaultOp::PartitionHeal { client } => self.log_client_fault(
                wave,
                client,
                "partition-heal",
                format!("client {client} uplink restored"),
            ),
            FaultOp::Drop { client, count } => self.log_client_fault(
                wave,
                client,
                "drop-burst",
                format!("{count} drafts from client {client} dropped (analytic model)"),
            ),
            FaultOp::Duplicate { client, count } => self.log_client_fault(
                wave,
                client,
                "duplicate-burst",
                format!("{count} drafts from client {client} duplicated"),
            ),
        }
    }

    fn publish(&self) {
        if self.snapshot.is_none() && self.obs.is_none() {
            return;
        }
        let ctl = self.shared.ctl.lock().expect("pool lock");
        if let Some(snap) = &self.snapshot {
            let mut s = snap.lock().expect("snapshot lock");
            s.epoch = ctl.epoch;
            s.waves = ctl.waves;
            s.delivered = self.shared.delivered.load(Ordering::Acquire);
            s.members = ctl.serving();
            s.draining = (0..ctl.state.len())
                .filter(|&i| ctl.state[i] == SlotState::Draining)
                .collect();
            s.lifetime_goodput = ctl.lifetime_goodput();
            s.participation = ctl.participation();
            s.alpha_hat = ctl.alpha_hat.clone();
            s.slots = ctl.state.len();
            s.attached_total = ctl.attached_total;
            s.retired_total = ctl.retired_total;
            s.shard_live.clear();
            s.shard_live.extend_from_slice(&ctl.live);
            s.migrations = ctl.migrations;
            // Handoff losses are only discovered at the end-of-run merge;
            // mid-run the pool has lost nothing yet.
            s.handoffs_lost = 0;
        }
        // Registry refresh from the controller's published tables. This
        // runs on the driver thread (never a shard's wave loop), so the
        // scratch vectors here cost nothing on the hot path.
        if let Some(hub) = &self.obs {
            let m = &hub.metrics;
            let secs = (hub.now_ns() as f64 / 1e9).max(1e-9);
            let good = ctl.lifetime_goodput();
            let part = ctl.participation();
            let total: f64 = good.iter().sum();
            m.waves_total.set(ctl.waves);
            m.tokens_total.set(total as u64);
            m.waves_per_second.set(ctl.waves as f64 / secs);
            m.tokens_per_second.set(total / secs);
            let serving = ctl.serving();
            let outstanding: u64 = serving.iter().map(|&i| ctl.outstanding[i] as u64).sum();
            m.outstanding_tokens.set(outstanding as f64);
            m.capacity_tokens.set(self.scenario.capacity as f64);
            m.migrations_total.set(ctl.migrations);
            let (mut sum, mut sum2, mut n) = (0.0f64, 0.0f64, 0u32);
            for i in 0..good.len() {
                let p = part.get(i).copied().unwrap_or(0);
                let rate = if p > 0 { good[i] / p as f64 } else { 0.0 };
                if let Some(g) = m.client_goodput.get(i) {
                    g.set(rate);
                }
                if p > 0 {
                    sum += rate;
                    sum2 += rate * rate;
                    n += 1;
                }
            }
            let jain = if n > 0 && sum2 > 0.0 {
                (sum * sum) / (n as f64 * sum2)
            } else {
                1.0
            };
            m.jain_index.set(jain);
            for (s, live) in ctl.live.iter().enumerate() {
                if let Some(g) = m.shard_live.get(s) {
                    g.set(u64::from(*live));
                }
            }
            for (s, p) in ctl.pressure.iter().enumerate() {
                if let Some(g) = m.shard_pressure.get(s) {
                    g.set(*p);
                }
            }
        }
    }

    /// Drive scheduled churn and external control until the pool stops
    /// (or, with neither, return immediately — the static path).
    ///
    /// Schedule events are keyed on the *mean per-shard* wave count
    /// (global waves ÷ M), which matches the single-verifier wave clock
    /// at M = 1 and keeps `ChurnEvent::at_wave` on the per-coordinator
    /// scale for pooled runs. With an empty membership, pending events
    /// fire immediately (no waves can pass to reach them otherwise).
    fn drive(&mut self, ctl_rx: Option<Receiver<Ctl>>) {
        let schedule: Vec<ChurnEvent> = self.scenario.churn.sorted();
        // Chaos ops ride the same schedule clock as churn events; the
        // compiled list is empty (and everything below a no-op) without a
        // `Scenario.chaos` schedule.
        let chaos: Vec<(u64, FaultOp)> = self.scenario.chaos.compiled();
        let shards = self.router.num_shards().max(1) as u64;
        let mut cursor = 0usize;
        let mut chaos_cursor = 0usize;
        let mut ctl_rx = ctl_rx;
        while !self.shared.stopping() {
            loop {
                let (waves, serving_empty) = {
                    let ctl = self.shared.ctl.lock().expect("pool lock");
                    (ctl.waves / shards, ctl.serving().is_empty())
                };
                let due = cursor < schedule.len()
                    && (schedule[cursor].at_wave <= waves || serving_empty);
                if !due {
                    break;
                }
                match schedule[cursor].kind.clone() {
                    ChurnKind::Join(spec) => {
                        if let Err(e) = self.admit(spec) {
                            log::warn!("scheduled pool join failed: {e}");
                        }
                    }
                    ChurnKind::Leave(id) => {
                        if let Err(e) = self.detach(id) {
                            log::warn!("scheduled pool leave of client {id}: {e}");
                        }
                    }
                }
                cursor += 1;
            }
            if chaos_cursor < chaos.len() {
                let waves = {
                    let ctl = self.shared.ctl.lock().expect("pool lock");
                    ctl.waves / shards
                };
                while chaos_cursor < chaos.len() && chaos[chaos_cursor].0 <= waves {
                    let (at, op) = chaos[chaos_cursor].clone();
                    self.apply_fault(at, op);
                    chaos_cursor += 1;
                }
            }
            self.publish();
            let polled = ctl_rx.as_ref().map(|rx| rx.recv_timeout(IDLE_TICK));
            match polled {
                Some(Ok(Ctl::Attach { spec, reply })) => {
                    let _ = reply.send(self.admit(spec));
                }
                Some(Ok(Ctl::Detach { id, reply })) => {
                    let _ = reply.send(self.detach(id));
                }
                Some(Ok(Ctl::Stop)) => self.shared.stop.store(true, Ordering::Release),
                Some(Err(RecvTimeoutError::Timeout)) => {}
                Some(Err(RecvTimeoutError::Disconnected)) => ctl_rx = None,
                None => {
                    // Snapshot the wakeup clock *before* reading the
                    // controller state: a shard wave that lands between
                    // the read and the wait bumps the sequence and the
                    // wait returns immediately (no lost wakeups).
                    let seen = self.shared.wakeup.seq();
                    if cursor >= schedule.len() && chaos_cursor >= chaos.len() {
                        // Nothing left to drive. If the membership fully
                        // drained (and no drain is still in flight),
                        // nothing can ever be verified again — latch the
                        // stop so the shards exit; otherwise let them
                        // finish the budget alone.
                        let (serving_empty, draining) = {
                            let ctl = self.shared.ctl.lock().expect("pool lock");
                            (
                                ctl.serving().is_empty(),
                                ctl.state.iter().any(|s| *s == SlotState::Draining),
                            )
                        };
                        if serving_empty {
                            self.shared.stop.store(true, Ordering::Release);
                            self.shared.wakeup.notify();
                            break;
                        }
                        if !draining {
                            break;
                        }
                    }
                    self.shared.wakeup.wait_timeout(seen, IDLE_TICK);
                }
            }
        }
        self.publish();
    }
}

/// Full sharded serving run over a static membership: spawn draft servers
/// and M shard threads, drive the pool until the global verification
/// budget is consumed, and merge everything. Channel transport only (each
/// shard of a multi-host TCP pool would simply bind its own
/// `TcpTransport`; the in-process pool is the single-machine scale-up
/// path). The session API ([`Cluster`](super::Cluster)) layers churn on
/// top via the crate-internal `run_pool_dynamic`.
pub fn run_pool(cfg: &RunConfig, factory: Arc<dyn EngineFactory>) -> Result<PoolOutcome> {
    run_pool_dynamic(cfg, factory, cfg.scenario.num_clients, None, None, None, None)
}

/// The pool under the session API: `slots ≥ num_clients` client slots,
/// scheduled churn from the scenario, and optional external control +
/// snapshot publishing. With `slots == num_clients`, no schedule, and no
/// control channel this is exactly the static [`run_pool`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_pool_dynamic(
    cfg: &RunConfig,
    factory: Arc<dyn EngineFactory>,
    slots: usize,
    ctl_rx: Option<Receiver<Ctl>>,
    snapshot: Option<Arc<Mutex<ClusterStats>>>,
    ready: Option<Sender<Result<()>>>,
    obs: Option<Arc<ObsHub>>,
) -> Result<PoolOutcome> {
    let scenario = &cfg.scenario;
    let fail = |e: String| {
        if let Some(tx) = &ready {
            let _ = tx.send(Err(anyhow!(e.clone())));
        }
        anyhow!(e)
    };
    if let Err(e) = scenario.validate() {
        return Err(fail(format!("invalid scenario: {e}")));
    }
    if cfg.transport != Transport::Channel {
        return Err(fail("the sharded pool runs over the channel transport".into()));
    }
    let n = scenario.num_clients;
    let m = scenario.num_verifiers;
    assert!(slots >= n, "slots must cover the initial clients");
    let (servers, router, ports, master_txs): (_, _, _, Vec<Sender<Message>>) =
        sharded_channel_transport(slots, m);
    // Reserve slots hold a routing entry but are not serving yet.
    for i in n..slots {
        router.set_active(i, false);
    }

    // Shared controller state, seeded with the estimator priors.
    let initial_alloc = (scenario.capacity / n.max(1)).min(scenario.max_draft);
    let mut outstanding = vec![0usize; slots];
    let mut state = vec![SlotState::Empty; slots];
    for i in 0..n {
        outstanding[i] = initial_alloc;
        state[i] = SlotState::Active;
    }
    let mut members: Vec<Vec<usize>> = vec![Vec::new(); m];
    for i in 0..n {
        members[router.shard_of(i)].push(i);
    }
    let x_beta = vec![1.0; slots];
    let pressure: Vec<f64> = members
        .iter()
        .map(|ms| ms.iter().map(|&i| LogUtility.grad(x_beta[i])).sum())
        .collect();
    let mut ctl = PoolCtl {
        alpha_hat: vec![0.5; slots],
        x_beta,
        outstanding,
        t_obs: vec![0; slots],
        budgets: vec![0; m],
        inbox: (0..m).map(|_| Vec::new()).collect(),
        waves: 0,
        migrations: 0,
        state,
        epoch: 0,
        events: Vec::new(),
        shard_goodput: (0..m).map(|_| vec![0.0; slots]).collect(),
        shard_participation: (0..m).map(|_| vec![0u64; slots]).collect(),
        attached_total: n as u64,
        retired_total: 0,
        members,
        pressure,
        free_slots: (n..slots).map(Reverse).collect(),
        handoff: (0..slots).map(|_| None).collect(),
        live: vec![true; m],
        crash_wave: vec![None; m],
        faults: Vec::new(),
        time_to_recover: Vec::new(),
    };
    ctl.budgets = compute_budgets(scenario, &ctl);
    let shared = Arc::new(PoolShared {
        stop: AtomicBool::new(false),
        delivered: AtomicU64::new(0),
        budget_total: scenario.rounds.saturating_mul(n as u64),
        retired: (0..slots).map(|_| AtomicBool::new(false)).collect(),
        ctl: Mutex::new(ctl),
        wakeup: Wakeup::new(),
    });

    // Draft servers (same client-side protocol as the single leader; the
    // wave discipline means one client may outpace another, so the safety
    // cap is the full budget).
    let mut driver = PoolDriver {
        scenario: scenario.clone(),
        simulate_network: cfg.simulate_network,
        factory: factory.clone(),
        router: router.clone(),
        shared: shared.clone(),
        ports: ports.into_iter().map(Some).collect(),
        handles: (0..slots).map(|_| None).collect(),
        root_rng: Rng::new(scenario.seed),
        max_rounds: scenario.rounds.saturating_mul(n as u64) + 1,
        snapshot,
        obs: obs.clone(),
    };
    for i in 0..n {
        let spec = ClientSpec {
            model: scenario.draft_model(i).to_string(),
            domain: scenario.domain(i).to_string(),
            link: scenario.link(i),
        };
        if let Err(e) = driver.spawn_client(i, spec, initial_alloc, false) {
            return Err(fail(format!("draft server {i} failed to spawn: {e:#}")));
        }
    }

    // Shard threads. Engines are built inside each thread (PJRT handles
    // are not Send), exactly like the draft-server actors.
    let run_start = Instant::now();
    let mut shard_handles = Vec::with_capacity(m);
    for (shard, mut server) in servers.into_iter().enumerate() {
        let scenario = scenario.clone();
        let policy = cfg.policy;
        let factory = factory.clone();
        let router = router.clone();
        let shared = shared.clone();
        let obs = obs.clone();
        let handle = std::thread::Builder::new()
            .name(format!("verify-shard-{shard}"))
            .spawn(move || -> (Result<u64>, Option<Recorder>, ServerSide) {
                let mut leader =
                    match Leader::with_slots(&scenario, policy, factory.as_ref(), slots) {
                        Ok(l) => l,
                        Err(e) => {
                            // A dead shard must not take the pool with it:
                            // fence it, move its clients to survivors, and
                            // keep answering drafts that raced into its
                            // fan-in. Only a survivor-less pool latches the
                            // global stop (inside `abandon_shard`).
                            abandon_shard(
                                &scenario,
                                &router,
                                &shared,
                                shard,
                                "engine build failed",
                                obs.as_deref(),
                            );
                            zombie_drain(&mut server, &shared, shard);
                            return (Err(e), None, server);
                        }
                    };
                // The pipelined verify stage owns a second engine built on
                // its own thread (engines are not `Send`); serial remains
                // the default when `scenario.pipelined` is off.
                let stage: Option<VerifyStage> = if scenario.pipelined {
                    let sobs = obs.as_ref().map(|hub| StageObs { hub: Arc::clone(hub), shard });
                    match VerifyStage::spawn_observed(
                        factory.clone(),
                        &scenario.family,
                        &format!("verify-stage-{shard}"),
                        sobs,
                    ) {
                        Ok(s) => Some(s),
                        Err(e) => {
                            abandon_shard(
                                &scenario,
                                &router,
                                &shared,
                                shard,
                                "stage spawn failed",
                                obs.as_deref(),
                            );
                            zombie_drain(&mut server, &shared, shard);
                            return (Err(e), None, server);
                        }
                    }
                } else {
                    None
                };
                leader.core.set_shard(shard);
                {
                    let ctl = shared.ctl.lock().expect("pool lock");
                    leader.core.set_capacity(ctl.budgets[shard]);
                }
                for i in 0..slots {
                    leader
                        .core
                        .set_member(i, router.is_active(i) && router.shard_of(i) == shard);
                }
                if scenario.stream_metrics {
                    leader.core.recorder.stream();
                }
                // Trace-driven pool: this shard tracks only its own
                // members' request streams; migrations carry request
                // state through the handoff mailbox.
                let mut serve: Option<ShardTracker> = if scenario.trace.is_some() {
                    let trace = match RequestTrace::from_scenario(&scenario, slots) {
                        Ok(t) => t,
                        Err(e) => {
                            abandon_shard(
                                &scenario,
                                &router,
                                &shared,
                                shard,
                                "trace build failed",
                                obs.as_deref(),
                            );
                            zombie_drain(&mut server, &shared, shard);
                            return (Err(e), None, server);
                        }
                    };
                    let mut tracker = RequestTracker::new(trace, slots);
                    tracker.retain_members(&router.members_of(shard));
                    if scenario.stream_metrics {
                        tracker.stream();
                    }
                    Some(ShardTracker { tracker, awaiting: Vec::new(), wave: 0 })
                } else {
                    None
                };
                let res = run_shard_loop(
                    &scenario,
                    shard,
                    &mut server,
                    &mut leader,
                    &router,
                    &shared,
                    &mut serve,
                    stage,
                    obs.as_deref(),
                );
                if res.is_err() {
                    abandon_shard(
                        &scenario,
                        &router,
                        &shared,
                        shard,
                        "shard wave loop failed",
                        obs.as_deref(),
                    );
                    zombie_drain(&mut server, &shared, shard);
                }
                if let (Ok(final_wave), Some(mut st)) = (&res, serve) {
                    st.tracker.finish(*final_wave);
                    let (requests, slo_goodput, censored, sketch) = st.tracker.into_report();
                    let rec = &mut leader.core.recorder;
                    rec.requests = requests;
                    rec.slo_goodput = slo_goodput;
                    rec.requests_censored = censored;
                    rec.request_sketch = sketch;
                }
                (res, Some(leader.core.recorder), server)
            })
            .expect("spawn verify shard");
        shard_handles.push(handle);
    }

    if let Some(tx) = &ready {
        let _ = tx.send(Ok(()));
    }
    // Drive churn/control; the static path returns immediately.
    driver.drive(ctl_rx);

    // Collect shards (they all exit once the budget is consumed), then
    // release the clients and collect them too.
    let mut shard_recorders = Vec::with_capacity(m);
    let mut kept_servers = Vec::with_capacity(m);
    let mut shard_err: Option<anyhow::Error> = None;
    for handle in shard_handles {
        match handle.join() {
            Ok((res, recorder, server)) => {
                if let Err(e) = res {
                    shared.stop.store(true, Ordering::Release);
                    if shard_err.is_none() {
                        shard_err = Some(e);
                    }
                }
                if let Some(r) = recorder {
                    shard_recorders.push(r);
                }
                kept_servers.push(server);
            }
            Err(_) => {
                shared.stop.store(true, Ordering::Release);
                if shard_err.is_none() {
                    shard_err = Some(anyhow!("verify shard panicked"));
                }
            }
        }
    }
    let wall = run_start.elapsed().as_secs_f64();
    for tx in &master_txs {
        let _ = tx.send(Message::Shutdown);
    }
    let mut draft_stats = vec![DraftStats::default(); slots];
    for (i, slot) in driver.handles.iter_mut().enumerate() {
        if let Some(h) = slot.take() {
            match h.join() {
                Ok(Ok(s)) => draft_stats[i] = s,
                Ok(Err(e)) => {
                    if shard_err.is_none() {
                        shard_err = Some(anyhow!("draft server failed: {e}"));
                    }
                }
                Err(_) => {
                    if shard_err.is_none() {
                        shard_err = Some(anyhow!("draft server panicked"));
                    }
                }
            }
        }
    }
    // Shard fan-ins must outlive the clients' last sends.
    drop(kept_servers);
    if let Some(e) = shard_err {
        // A shard (or draft server) failed. If the survivors still
        // completed the global budget, the pool did its job — report the
        // degraded-but-successful run (the fault log carries the
        // abandonment); only a run the failure actually cut short errors.
        let survived = shared.delivered.load(Ordering::Acquire) >= shared.budget_total;
        if !survived {
            return Err(e);
        }
        log::warn!("pool absorbed a shard failure and completed its budget: {e:#}");
    }

    let shard_summaries: Vec<RunSummary> =
        shard_recorders.iter().map(|r| r.summary(wall)).collect();
    let mut merged = Recorder::new(slots);
    for rec in shard_recorders {
        merged.absorb(rec);
    }
    {
        // Epoch-stamped membership changes recorded by the controller.
        let mut ctl = shared.ctl.lock().expect("pool lock");
        let mut events = std::mem::take(&mut ctl.events);
        events.sort_by_key(|e| (e.wave, e.epoch));
        merged.membership = events;
        merged.faults = std::mem::take(&mut ctl.faults);
        merged.time_to_recover = std::mem::take(&mut ctl.time_to_recover);
        // Handoff states still in the mailbox (the adopting shard stopped
        // before claiming them) are in-flight requests nobody will finish:
        // censor them, mirroring `RequestTracker::untrack` — and count the
        // loss explicitly (`handoffs_lost` + a fault record + a membership
        // event) instead of silently folding it into the censor total.
        let final_wave = ctl.waves / m.max(1) as u64;
        let mut lost: Vec<usize> = Vec::new();
        for (client, slot) in ctl.handoff.iter_mut().enumerate() {
            if let Some(state) = slot.take() {
                merged.requests_censored += state.censorable();
                merged.handoffs_lost += 1;
                lost.push(client);
            }
        }
        if !lost.is_empty() {
            for &client in &lost {
                merged.faults.push(FaultRecord {
                    wave: final_wave,
                    shard: driver.router.shard_of(client),
                    kind: "handoff-lost".into(),
                    detail: format!("client {client}'s migrated request state was never claimed"),
                });
                if let Some(hub) = &obs {
                    hub.note_fault(driver.router.shard_of(client), "handoff-lost");
                }
            }
            if let Some(hub) = &obs {
                hub.metrics.handoffs_lost_total.set(merged.handoffs_lost);
            }
            ctl.epoch += 1;
            merged.membership.push(MembershipEvent {
                wave: final_wave,
                epoch: ctl.epoch,
                joined: vec![],
                left: lost,
                members: ctl.serving(),
            });
        }
    }
    driver.publish();
    if let Some(snap) = &driver.snapshot {
        snap.lock().expect("snapshot lock").handoffs_lost = merged.handoffs_lost;
    }
    let summary = merged.summary(wall);
    let migrations = shared.ctl.lock().expect("pool lock").migrations;
    Ok(PoolOutcome { recorder: merged, summary, shard_summaries, draft_stats, migrations })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configsys::Policy;
    use crate::runtime::{MockEngineFactory, MockWorld};
    use crate::util::stats::jain_index;

    fn mock_factory() -> Arc<dyn EngineFactory> {
        Arc::new(MockEngineFactory::new(MockWorld {
            vocab: 32,
            max_seq: 256,
            sharpness: 3.0,
            seed: 11,
        }))
    }

    fn pool_scenario(m: usize, rounds: u64) -> Scenario {
        let mut s = Scenario::preset("sharded").unwrap();
        s.num_verifiers = m;
        s.rounds = rounds;
        s
    }

    fn run(m: usize, rounds: u64) -> PoolOutcome {
        let cfg = RunConfig {
            scenario: pool_scenario(m, rounds),
            policy: Policy::GoodSpeed,
            transport: Transport::Channel,
            simulate_network: false,
        };
        run_pool(&cfg, mock_factory()).unwrap()
    }

    #[test]
    fn pool_consumes_the_global_budget() {
        let out = run(2, 12);
        let budget = 12 * 8u64;
        let delivered: u64 = out.recorder.participation().iter().sum();
        // Full budget, with at most one extra wave per shard in flight
        // when the stop flag latched.
        assert!(delivered >= budget, "{delivered} < {budget}");
        assert!(delivered < budget + 2 * 8, "{delivered}");
        // Everyone made progress.
        for (i, &p) in out.recorder.participation().iter().enumerate() {
            assert!(p > 0, "client {i} starved");
        }
    }

    #[test]
    fn pool_waves_never_exceed_their_shard_budget_slice() {
        let out = run(4, 10);
        // Σ shard budgets ≤ C, and each wave's drafts fit its slice. The
        // slice can shrink between the grant and the verify (rebalancing),
        // so check against the conservative global bound per shard count.
        for r in &out.recorder.rounds {
            let used: usize = r.clients.iter().map(|c| c.s_used).sum();
            assert!(used <= 32, "wave on shard {} used {used} > C", r.shard);
        }
        // Waves really ran on multiple shards.
        let mut shards: Vec<usize> = out.recorder.rounds.iter().map(|r| r.shard).collect();
        shards.sort_unstable();
        shards.dedup();
        assert!(shards.len() >= 2, "expected multiple active shards: {shards:?}");
    }

    #[test]
    fn pool_of_one_matches_single_verifier_semantics() {
        let out = run(1, 10);
        assert_eq!(out.shard_summaries.len(), 1);
        assert_eq!(out.migrations, 0); // nothing to rebalance against
        for r in &out.recorder.rounds {
            assert_eq!(r.shard, 0);
        }
        for d in &out.draft_stats {
            assert_eq!(d.shard_switches, 0);
        }
    }

    #[test]
    fn pool_fairness_stays_close_to_single_verifier() {
        // The 5%-of-baseline bound is the acceptance shape demonstrated by
        // `examples/sharded_scaleup` / `benches/sharded`; the unit test
        // allows a whisker more slack and disables rebalancing so the
        // migration sequence (which depends on OS thread scheduling)
        // cannot perturb the comparison — the static hierarchical split
        // is what's under test here.
        let run_static = |m: usize| {
            let mut s = pool_scenario(m, 50);
            s.shard_rebalance_every = 0;
            let cfg = RunConfig {
                scenario: s,
                policy: Policy::GoodSpeed,
                transport: Transport::Channel,
                simulate_network: false,
            };
            run_pool(&cfg, mock_factory()).unwrap()
        };
        let one = run_static(1);
        let four = run_static(4);
        let j1 = jain_index(&one.recorder.avg_goodput());
        let j4 = jain_index(&four.recorder.avg_goodput());
        assert!(
            (j1 - j4).abs() <= 0.06 * j1,
            "cross-shard fairness drift: M=1 {j1:.4} vs M=4 {j4:.4}"
        );
    }

    #[test]
    fn pool_runs_tree_shapes() {
        // Tree speculation flows through the sharded pool unchanged: each
        // shard's Leader handles topologies via the shared batcher/core.
        let mut s = pool_scenario(2, 8);
        s.spec_shape = crate::configsys::SpecShape::Tree { arity: 2, depth: 4 };
        let cfg = RunConfig {
            scenario: s,
            policy: Policy::GoodSpeed,
            transport: Transport::Channel,
            simulate_network: false,
        };
        let out = run_pool(&cfg, mock_factory()).unwrap();
        let delivered: u64 = out.recorder.participation().iter().sum();
        assert!(delivered >= 8 * 8, "{delivered}");
        let branched = out
            .recorder
            .rounds
            .iter()
            .flat_map(|r| r.clients.iter())
            .any(|c| c.spec_depth < c.s_used);
        assert!(branched, "pooled tree waves must branch");
    }

    #[test]
    fn pool_rejects_tcp_transport() {
        let cfg = RunConfig {
            scenario: pool_scenario(2, 5),
            policy: Policy::GoodSpeed,
            transport: Transport::Tcp,
            simulate_network: false,
        };
        assert!(run_pool(&cfg, mock_factory()).is_err());
    }

    #[test]
    fn scheduled_shard_crash_migrates_clients_and_recovers() {
        use crate::chaos::{FaultEvent, FaultKind, FaultSchedule};
        let mut s = pool_scenario(2, 40);
        // Crash a fifth of the way in, recover at the 40% mark — well
        // before the budget runs out even at the fenced pool's slowed
        // schedule clock (budget-out ≈ pooled wave 24 here).
        s.chaos = FaultSchedule {
            events: vec![FaultEvent {
                at_wave: 8,
                kind: FaultKind::ShardCrash { shard: 1, recover_wave: Some(16) },
            }],
        };
        let cfg = RunConfig {
            scenario: s,
            policy: Policy::GoodSpeed,
            transport: Transport::Channel,
            simulate_network: false,
        };
        let out = run_pool(&cfg, mock_factory()).unwrap();
        // The pool survived the crash: the global stop never cut the run
        // short of its budget, and every client kept serving.
        let delivered: u64 = out.recorder.participation().iter().sum();
        assert!(delivered >= 40 * 8, "budget incomplete: {delivered}");
        for (i, &p) in out.recorder.participation().iter().enumerate() {
            assert!(p > 0, "client {i} starved");
        }
        // Crash and recovery were both logged, with a time-to-recover
        // sample on the schedule clock.
        let kinds: Vec<&str> = out.recorder.faults.iter().map(|f| f.kind.as_str()).collect();
        assert!(kinds.contains(&"shard-crash"), "fault log: {kinds:?}");
        assert!(kinds.contains(&"shard-recover"), "fault log: {kinds:?}");
        assert_eq!(out.recorder.time_to_recover.len(), 1);
        assert!(out.recorder.time_to_recover[0] >= 1);
        // The crashed shard's members really moved.
        assert!(out.migrations >= 1, "crash must migrate the dead shard's members");
    }

    fn run_trace(m: usize, rounds: u64, stream: bool) -> PoolOutcome {
        let mut s = Scenario::preset("trace").unwrap();
        s.num_verifiers = m;
        s.rounds = rounds;
        s.stream_metrics = stream;
        let cfg = RunConfig {
            scenario: s,
            policy: Policy::GoodSpeed,
            transport: Transport::Channel,
            simulate_network: false,
        };
        run_pool(&cfg, mock_factory()).unwrap()
    }

    #[test]
    fn sharded_trace_run_merges_request_accounting() {
        let out = run_trace(2, 120, false);
        let rec = &out.recorder;
        assert!(rec.has_requests(), "sharded trace runs keep request books");
        let s = rec.slo_summary().expect("merged request summary");
        assert!(
            s.completed + s.expired + s.censored > 0,
            "no request reached an outcome: {s:?}"
        );
        assert!(s.completed > 0, "120 waves must complete some requests");
        // SLO-goodput is a filtered view of raw goodput, per client.
        assert_eq!(rec.slo_goodput.len(), 4);
        for (i, (&slo, &raw)) in rec.slo_goodput.iter().zip(rec.cum_goodput()).enumerate() {
            assert!(slo <= raw + 1e-9, "client {i}: slo {slo} > raw {raw}");
        }
        // Waves really ran on both shards.
        let mut shards: Vec<usize> = rec.rounds.iter().map(|r| r.shard).collect();
        shards.sort_unstable();
        shards.dedup();
        assert_eq!(shards, vec![0, 1]);
    }

    #[test]
    fn streaming_sharded_trace_retains_no_per_wave_records() {
        let out = run_trace(2, 60, true);
        let rec = &out.recorder;
        assert!(rec.rounds.is_empty(), "streaming mode must not retain waves");
        assert!(rec.requests.is_empty(), "streaming mode must not retain requests");
        assert!(rec.request_sketch.is_some());
        let s = rec.slo_summary().expect("sketch-backed summary");
        assert!(s.completed + s.expired + s.censored > 0);
        // The wave counters still aggregate across shards.
        assert!(rec.participation().iter().sum::<u64>() >= 60 * 4);
    }
}
