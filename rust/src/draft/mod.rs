//! Draft-server actor (the paper's edge SLM node).

pub mod server;

pub use server::{spawn_draft_server, DraftServerConfig, DraftStats};
