//! Draft-server actor: one thread per edge server.
//!
//! Loop (paper Algorithm 1, lines 3–11): pull the next prompt from the
//! client's domain stream, prefill the SLM, then each round autoregressively
//! draft `S_i(t)` tokens (sampling from the model's distribution and keeping
//! every per-token distribution `q_{i,j}` — the verification server needs
//! them for rejection sampling), simulate the uplink delay, ship the batch,
//! wait for the verdict, and reconcile the KV cache:
//!
//! * rejection at position m  → rewind to `pos0 + m`, ingest the correction;
//! * all S accepted           → ingest the last draft token (it never went
//!   through the model) and then the bonus token.
//!
//! Round numbers are *client-local*: the coordinator echoes the draft's
//! round back in its verdict, so the protocol works identically whether the
//! leader runs the sync barrier (rounds advance in lockstep across clients)
//! or the async wave pipeline (each client progresses at its own pace; see
//! DESIGN.md, "Wave lifecycle"). A verdict is matched to the in-flight
//! draft by that echo, never by a global round counter.
//!
//! The engine is built *inside* the thread (PJRT handles are not `Send`).

use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::configsys::{LinkConfig, SpecShape};
use crate::net::link::{
    draft_msg_bytes, tree_draft_msg_bytes, tree_verdict_msg_bytes, verdict_msg_bytes, Link,
};
use crate::net::transport::ClientPort;
use crate::net::wire::{DraftMsg, Message};
use crate::runtime::{Drafter, EngineFactory};
use crate::spec::tree::{adaptive_profile, DraftTree};
use crate::util::Rng;
use crate::workload::DomainStream;

/// Static configuration for one draft server.
pub struct DraftServerConfig {
    pub client_id: usize,
    pub model: String,
    /// Initial allocation S_i(0) (the coordinator takes over from t=1).
    pub initial_alloc: usize,
    pub link: LinkConfig,
    /// Apply real sleeps for simulated network delays (off in unit tests).
    pub simulate_network: bool,
    /// Sampling temperature is fixed at 1 (matches verification math).
    pub seed: u64,
    /// Hard cap on rounds (safety net; coordinator normally shuts down).
    pub max_rounds: u64,
    /// Speculation topology policy: how the granted node budget is
    /// arranged (`Chain` keeps the legacy bit-identical draft loop).
    pub spec_shape: SpecShape,
    /// Verify-artifact row count K — trees must fit `nodes + leaves ≤ K`
    /// (each leaf needs a phantom bonus row; see `spec/tree.rs`).
    pub verify_k: usize,
    /// Open the session with the Join → JoinAck handshake before the
    /// first draft (dynamically attached clients). Statically configured
    /// clients skip it, keeping the legacy frame stream byte-identical.
    pub hello: bool,
}

/// Outcome summary returned when the actor exits.
#[derive(Clone, Debug, Default)]
pub struct DraftStats {
    pub rounds: u64,
    pub requests_completed: u64,
    pub tokens_drafted: u64,
    pub tokens_accepted: u64,
    /// Tree mode only: total sibling *tries* the verifier consumed,
    /// reconstructed from verdict paths (rank of each accepted child among
    /// its siblings, plus every sibling of a fully rejected level). The
    /// adaptive shape rule uses `tokens_accepted / spec_tries` as its
    /// per-try acceptance estimate — unlike accepted/drafted, this is not
    /// floor-bounded by 1/arity, so a high-α client can climb back to the
    /// deep (chain) profile.
    pub spec_tries: u64,
    pub draft_compute: Duration,
    /// Per-request latency (rounds from first draft to completion).
    pub request_latency_rounds: Vec<u64>,
    /// How many times this client's verdicts started arriving from a
    /// different verification shard (pool rebalancing observed client-side
    /// via the verdict's shard id; 0 outside pooled runs).
    pub shard_switches: u64,
}

struct Actor {
    cfg: DraftServerConfig,
    drafter: Box<dyn Drafter>,
    stream: DomainStream,
    port: Box<dyn ClientPort>,
    link: Link,
    rng: Rng,
    stats: DraftStats,
    // Request state.
    prefix: Vec<u8>,
    prompt_len: usize,
    max_new_tokens: usize,
    generated: usize,
    request_start_round: u64,
    /// Distribution for the token at index `drafter.position()`.
    pending_dist: Vec<f32>,
    new_request: bool,
    /// Shard id of the last verdict (u32::MAX until the first one).
    last_shard: u32,
}

impl Actor {
    fn start_request(&mut self, round: u64) -> Result<()> {
        let req = self.stream.next_request();
        let prompt = crate::tokenizer::encode(&req.prompt);
        self.prefix = prompt.clone();
        self.prompt_len = prompt.len();
        self.max_new_tokens = req.max_new_tokens;
        self.generated = 0;
        self.request_start_round = round;
        self.pending_dist = self.drafter.prefill(&prompt)?;
        self.new_request = true;
        Ok(())
    }

    /// Max context room for drafting (prefix + S + 1 must fit max_seq).
    fn context_room(&self) -> usize {
        self.drafter.max_seq().saturating_sub(self.prefix.len() + 2)
    }

    fn draft_round(&mut self, round: u64, alloc: usize) -> Result<DraftMsg> {
        let t0 = Instant::now();
        let s = alloc.min(self.context_room());
        let vocab = self.drafter.vocab();
        let mut draft = Vec::with_capacity(s);
        let mut q_probs = Vec::with_capacity(s * vocab);
        for j in 0..s {
            // Sample token at index position() from the pending distribution.
            let tok = self.rng.categorical(&self.pending_dist) as u8;
            q_probs.extend_from_slice(&self.pending_dist);
            draft.push(tok);
            if j + 1 < s {
                self.pending_dist = self.drafter.step(tok)?;
            }
        }
        let wall = t0.elapsed();
        self.stats.draft_compute += wall;
        self.stats.tokens_drafted += s as u64;
        Ok(DraftMsg {
            client_id: self.cfg.client_id as u32,
            round,
            prefix: self.prefix.clone(),
            prompt_len: self.prompt_len as u32,
            draft,
            parents: Vec::new(),
            q_probs,
            new_request: std::mem::take(&mut self.new_request),
            draft_wall_ns: wall.as_nanos() as u64,
        })
    }

    /// The (arity, depth) profile for this round's tree shape.
    fn tree_profile(&self) -> (usize, usize) {
        match self.cfg.spec_shape {
            SpecShape::Chain => (1, usize::MAX),
            SpecShape::Tree { arity, depth } => (arity, depth),
            // Adaptive: pick from the locally observed *per-try* acceptance
            // rate (0.5 prior until tries have been verified). Accepted
            // path tokens over sibling tries — NOT over nodes drafted,
            // which a branching shape bounds near 1/arity and would latch
            // every client into the widest profile.
            SpecShape::Adaptive => {
                let alpha = if self.stats.spec_tries == 0 {
                    0.5
                } else {
                    self.stats.tokens_accepted as f64 / self.stats.spec_tries as f64
                };
                adaptive_profile(alpha)
            }
        }
    }

    /// Reconstruct how many sibling tries the verifier spent on this
    /// round's tree from the accepted path: an accepted child at sibling
    /// rank j cost j tries (j − 1 rejections + 1 acceptance); the terminal
    /// level — unless the path ended on a leaf — rejected every sibling.
    fn note_spec_tries(&mut self, tree: &DraftTree, path: &[u8]) -> Result<()> {
        let mut tries = 0u64;
        let mut cur: Option<usize> = None;
        for &nid in path {
            let kids = match cur {
                None => tree.root_children(),
                Some(i) => tree.children(i),
            };
            let rank = kids
                .iter()
                .position(|&c| c == nid as usize)
                .ok_or_else(|| anyhow!("verdict path node {nid} is not a child of the path"))?;
            tries += rank as u64 + 1;
            cur = Some(nid as usize);
        }
        let kids = match cur {
            None => tree.root_children(),
            Some(i) => tree.children(i),
        };
        if !kids.is_empty() {
            // Off-path rejection: every sibling of the terminal level was
            // tried and rejected. (Empty = the path reached a leaf.)
            tries += kids.len() as u64;
        }
        self.stats.spec_tries += tries;
        Ok(())
    }

    /// DFS over `kids`: sample every sibling token i.i.d. from the parent
    /// distribution (node order — the sequential-try contract
    /// `verify_tree` assumes), then descend into each internal child,
    /// rewinding the KV cache to the parent position between branches.
    fn draft_subtree(
        &mut self,
        tree: &DraftTree,
        kids: &[usize],
        dist: &[f32],
        draft: &mut [u8],
        q_probs: &mut [f32],
    ) -> Result<()> {
        let vocab = dist.len();
        for &c in kids {
            let tok = self.rng.categorical(dist) as u8;
            draft[c] = tok;
            q_probs[c * vocab..(c + 1) * vocab].copy_from_slice(dist);
        }
        let parent_pos = self.drafter.position();
        for &c in kids {
            let grand = tree.children(c);
            if !grand.is_empty() {
                let next = self.drafter.step(draft[c])?;
                self.draft_subtree(tree, grand, &next, draft, q_probs)?;
                self.drafter.rewind(parent_pos);
            }
        }
        Ok(())
    }

    /// Tree-mode drafting: build the shape for the granted node budget,
    /// fill it by DFS, and ship topology + tokens + q rows. The KV cache
    /// ends back at the root position (the verdict replays the accepted
    /// path).
    fn draft_round_tree(&mut self, round: u64, alloc: usize) -> Result<DraftMsg> {
        let t0 = Instant::now();
        let (arity, depth) = self.tree_profile();
        let tree = DraftTree::shaped(arity, depth, alloc, self.cfg.verify_k, self.context_room());
        let n = tree.len();
        let vocab = self.drafter.vocab();
        let mut draft = vec![0u8; n];
        let mut q_probs = vec![0.0f32; n * vocab];
        let pos0 = self.drafter.position();
        if n > 0 {
            let dist = self.pending_dist.clone();
            let roots: Vec<usize> = tree.root_children().to_vec();
            self.draft_subtree(&tree, &roots, &dist, &mut draft, &mut q_probs)?;
            self.drafter.rewind(pos0);
        }
        let wall = t0.elapsed();
        self.stats.draft_compute += wall;
        self.stats.tokens_drafted += n as u64;
        Ok(DraftMsg {
            client_id: self.cfg.client_id as u32,
            round,
            prefix: self.prefix.clone(),
            prompt_len: self.prompt_len as u32,
            draft,
            parents: tree.parents().to_vec(),
            q_probs,
            new_request: std::mem::take(&mut self.new_request),
            draft_wall_ns: wall.as_nanos() as u64,
        })
    }

    fn apply_verdict(
        &mut self,
        round: u64,
        draft: &[u8],
        accepted: usize,
        correction: u8,
    ) -> Result<()> {
        let s = draft.len();
        let m = accepted.min(s);
        let pos0 = self.prefix.len();
        self.prefix.extend_from_slice(&draft[..m]);
        self.prefix.push(correction);
        self.stats.tokens_accepted += m as u64;
        self.generated += m + 1;

        if m == s && s > 0 {
            // Bonus path: the last draft token was sampled but never
            // stepped through the model; ingest it before the bonus token.
            debug_assert_eq!(self.drafter.position(), pos0 + s - 1);
            self.drafter.step(draft[s - 1])?;
        } else {
            // Rejection (or S=0): discard stale cache rows.
            self.drafter.rewind(pos0 + m);
        }
        debug_assert_eq!(self.drafter.position(), pos0 + m);

        self.finish_round(round)
    }

    /// Tree-mode reconciliation: the DFS left the cache at the root
    /// position, so replay the accepted path (node ids from the verdict,
    /// tokens from our own draft) into the cache, then ingest the
    /// correction/bonus token exactly like the chain path.
    fn apply_verdict_tree(
        &mut self,
        round: u64,
        draft: &[u8],
        path: &[u8],
        correction: u8,
    ) -> Result<()> {
        let m = path.len();
        let pos0 = self.prefix.len();
        debug_assert_eq!(self.drafter.position(), pos0);
        for &nid in path {
            let tok = *draft
                .get(nid as usize)
                .ok_or_else(|| anyhow!("verdict path node {nid} out of range"))?;
            self.drafter.step(tok)?;
            self.prefix.push(tok);
        }
        self.prefix.push(correction);
        self.stats.tokens_accepted += m as u64;
        self.generated += m + 1;
        debug_assert_eq!(self.drafter.position(), pos0 + m);

        self.finish_round(round)
    }

    /// Shared round epilogue: request completion bookkeeping, or ingest
    /// the correction token to seed the next round's first sample.
    fn finish_round(&mut self, round: u64) -> Result<()> {
        let correction = *self.prefix.last().expect("prefix holds the correction");
        let done = self.generated >= self.max_new_tokens
            || self.prefix.len() + 2 >= self.drafter.max_seq();
        if done {
            self.stats.requests_completed += 1;
            self.stats
                .request_latency_rounds
                .push(round + 1 - self.request_start_round);
            self.start_request(round + 1)?;
        } else {
            // Ingest the correction/bonus token; its successor distribution
            // seeds the next round's first draft sample.
            self.pending_dist = self.drafter.step(correction)?;
        }
        Ok(())
    }

    /// Session hello: announce ourselves and wait for the coordinator's
    /// ack (which carries the authoritative first allocation). Returns
    /// `None` if the cluster shut down before acknowledging.
    fn handshake(&mut self) -> Result<Option<usize>> {
        use crate::net::wire::JoinMsg;
        self.port.send(&Message::Join(JoinMsg {
            client_id: self.cfg.client_id as u32,
            protocol: crate::net::wire::PROTOCOL_VERSION,
        }))?;
        match self.port.recv() {
            Ok(Message::JoinAck(ack)) => {
                if ack.client_id as usize != self.cfg.client_id {
                    return Err(anyhow!(
                        "client {}: join ack addressed to {}",
                        self.cfg.client_id,
                        ack.client_id
                    ));
                }
                Ok(Some(ack.initial_alloc as usize))
            }
            Ok(Message::Shutdown) | Ok(Message::Leave(_)) | Err(_) => Ok(None),
            Ok(other) => Err(anyhow!("unexpected handshake reply {other:?}")),
        }
    }

    fn run(&mut self) -> Result<DraftStats> {
        let vocab = self.drafter.vocab();
        let chain_mode = self.cfg.spec_shape.is_chain();
        let mut alloc = self.cfg.initial_alloc;
        if self.cfg.hello {
            match self.handshake()? {
                Some(granted) => alloc = granted,
                None => return Ok(std::mem::take(&mut self.stats)),
            }
        }
        self.start_request(0)?;
        for round in 0..self.cfg.max_rounds {
            // Chain mode keeps the legacy draft loop verbatim (bit-identical
            // RNG stream, engine calls, and wire bytes).
            let msg = if chain_mode {
                self.draft_round(round, alloc)?
            } else {
                self.draft_round_tree(round, alloc)?
            };
            let draft = msg.draft.clone();
            let parents = msg.parents.clone();
            let is_tree_draft = !parents.is_empty();
            if self.cfg.simulate_network {
                let bytes = if is_tree_draft {
                    tree_draft_msg_bytes(msg.prefix.len(), msg.draft.len(), vocab)
                } else {
                    draft_msg_bytes(msg.prefix.len(), msg.draft.len(), vocab)
                };
                std::thread::sleep(self.link.delay(bytes, &mut self.rng));
            }
            self.port.send(&Message::Draft(msg))?;
            match self.port.recv() {
                Ok(Message::Verdict(v)) => {
                    if self.cfg.simulate_network {
                        let bytes = if v.path.is_empty() {
                            verdict_msg_bytes()
                        } else {
                            tree_verdict_msg_bytes(v.path.len())
                        };
                        std::thread::sleep(self.link.delay(bytes, &mut self.rng));
                    }
                    // The verdict must echo the round of the draft we just
                    // sent (client-local matching — no lockstep assumption).
                    if v.round != round {
                        return Err(anyhow!(
                            "client {}: verdict for round {} while round {round} in flight",
                            self.cfg.client_id,
                            v.round
                        ));
                    }
                    if self.last_shard != v.shard {
                        if self.last_shard != u32::MAX {
                            self.stats.shard_switches += 1;
                        }
                        self.last_shard = v.shard;
                    }
                    if chain_mode {
                        self.apply_verdict(round, &draft, v.accepted as usize, v.correction)?;
                    } else {
                        // Tree mode: even a degenerate (empty) tree draft
                        // reconciles through the path — an empty path is
                        // the S = 0 correction-only round.
                        let tree = DraftTree::from_parents(parents)?;
                        self.note_spec_tries(&tree, &v.path)?;
                        self.apply_verdict_tree(round, &draft, &v.path, v.correction)?;
                    }
                    alloc = v.next_alloc as usize;
                }
                // A Leave is the coordinator completing our graceful
                // drain: the final verdict has already been applied.
                Ok(Message::Shutdown) | Ok(Message::Leave(_)) | Err(_) => break,
                Ok(other) => return Err(anyhow!("unexpected message {other:?}")),
            }
            self.stats.rounds = round + 1;
        }
        Ok(std::mem::take(&mut self.stats))
    }
}

/// Spawn a draft-server thread. The engine factory runs inside the thread.
pub fn spawn_draft_server(
    cfg: DraftServerConfig,
    factory: std::sync::Arc<dyn EngineFactory>,
    stream: DomainStream,
    port: Box<dyn ClientPort>,
) -> JoinHandle<Result<DraftStats>> {
    std::thread::Builder::new()
        .name(format!("draft-{}", cfg.client_id))
        .spawn(move || {
            let drafter = factory.make_drafter(&cfg.model)?;
            let link = Link::new(cfg.link.clone());
            let rng = Rng::new(cfg.seed);
            let mut actor = Actor {
                drafter,
                stream,
                port,
                link,
                rng,
                stats: DraftStats::default(),
                prefix: Vec::new(),
                prompt_len: 0,
                max_new_tokens: 0,
                generated: 0,
                request_start_round: 0,
                pending_dist: Vec::new(),
                new_request: false,
                last_shard: u32::MAX,
                cfg,
            };
            actor.run()
        })
        .expect("spawn draft server")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::transport::channel_transport;
    use crate::net::wire::VerdictMsg;
    use crate::runtime::{MockEngineFactory, MockWorld};
    use std::sync::Arc;

    fn factory() -> Arc<dyn EngineFactory> {
        Arc::new(MockEngineFactory::new(MockWorld {
            vocab: 32,
            max_seq: 128,
            sharpness: 3.0,
            seed: 5,
        }))
    }

    fn cfg(id: usize, rounds: u64) -> DraftServerConfig {
        DraftServerConfig {
            client_id: id,
            model: "qwen-draft-06b".into(),
            initial_alloc: 4,
            link: LinkConfig::default(),
            simulate_network: false,
            seed: 42 + id as u64,
            max_rounds: rounds,
            spec_shape: SpecShape::Chain,
            verify_k: 32,
            hello: false,
        }
    }

    /// Drive one actor manually from the coordinator side.
    #[test]
    fn actor_round_trip_with_manual_coordinator() {
        let (mut server, mut ports) = channel_transport(1);
        let stream = DomainStream::new("alpaca", 1.0, 10, Rng::new(1)).unwrap();
        let h = spawn_draft_server(cfg(0, 5), factory(), stream, ports.remove(0));
        for round in 0..5u64 {
            let (id, msg) = server.rx.recv().unwrap();
            assert_eq!(id, 0);
            let d = match msg {
                Message::Draft(d) => d,
                other => panic!("{other:?}"),
            };
            assert_eq!(d.round, round);
            assert!(d.draft.len() <= 4);
            assert_eq!(d.q_probs.len(), d.draft.len() * 32);
            // Every q row must be a distribution.
            for j in 0..d.draft.len() {
                let s: f32 = d.q_probs[j * 32..(j + 1) * 32].iter().sum();
                assert!((s - 1.0).abs() < 1e-4);
            }
            // Accept the first half, reject the rest.
            let acc = (d.draft.len() / 2) as u32;
            (server.txs[0])(&Message::Verdict(VerdictMsg {
                client_id: 0,
                round,
                accepted: acc,
                path: vec![],
                correction: 7,
                next_alloc: 4,
                shard: 0,
            }))
            .unwrap();
        }
        let stats = h.join().unwrap().unwrap();
        assert_eq!(stats.rounds, 5);
        assert!(stats.tokens_drafted > 0);
    }

    #[test]
    fn prefix_grows_by_accepted_plus_one() {
        let (mut server, mut ports) = channel_transport(1);
        let stream = DomainStream::new("gsm8k", 1.0, 100, Rng::new(2)).unwrap();
        let h = spawn_draft_server(cfg(0, 3), factory(), stream, ports.remove(0));
        let mut last_len = None;
        let mut last_accept = 0usize;
        for round in 0..3u64 {
            let (_, msg) = server.rx.recv().unwrap();
            let d = match msg {
                Message::Draft(d) => d,
                _ => panic!(),
            };
            if let Some(l) = last_len {
                assert_eq!(d.prefix.len(), l + last_accept + 1, "prefix growth");
            }
            last_len = Some(d.prefix.len());
            last_accept = d.draft.len(); // accept all
            (server.txs[0])(&Message::Verdict(VerdictMsg {
                client_id: 0,
                round,
                accepted: d.draft.len() as u32,
                path: vec![],
                correction: 3,
                next_alloc: 4,
                shard: 0,
            }))
            .unwrap();
        }
        h.join().unwrap().unwrap();
    }

    #[test]
    fn completes_requests_and_starts_new_ones() {
        let (mut server, mut ports) = channel_transport(1);
        // max_new_tokens = 5 → finishes a request every ~1–2 rounds
        let stream = DomainStream::new("arena", 1.0, 5, Rng::new(3)).unwrap();
        let h = spawn_draft_server(cfg(0, 12), factory(), stream, ports.remove(0));
        let mut new_request_count = 0;
        for round in 0..12u64 {
            let (_, msg) = server.rx.recv().unwrap();
            let d = match msg {
                Message::Draft(d) => d,
                _ => panic!(),
            };
            if d.new_request {
                new_request_count += 1;
            }
            (server.txs[0])(&Message::Verdict(VerdictMsg {
                client_id: 0,
                round,
                accepted: d.draft.len() as u32,
                path: vec![],
                correction: 5,
                next_alloc: 4,
                // Alternate shard ids: the actor must count the switches.
                shard: (round % 2) as u32,
            }))
            .unwrap();
        }
        let stats = h.join().unwrap().unwrap();
        assert!(stats.requests_completed >= 2, "{stats:?}");
        assert!(new_request_count >= 3); // first + completions
        assert_eq!(stats.requests_completed as usize, stats.request_latency_rounds.len());
        // 12 verdicts alternating shard 0/1: the first sets the baseline,
        // every later one is a switch.
        assert_eq!(stats.shard_switches, 11);
    }

    /// Drive a tree-mode actor manually: topology ships on the wire, q
    /// rows are per-node distributions (siblings share their parent's),
    /// and path-based verdicts reconcile the KV cache.
    #[test]
    fn tree_actor_round_trip_with_manual_coordinator() {
        let (mut server, mut ports) = channel_transport(1);
        let stream = DomainStream::new("gsm8k", 1.0, 50, Rng::new(7)).unwrap();
        let mut c = cfg(0, 4);
        c.spec_shape = SpecShape::Tree { arity: 2, depth: 3 };
        c.initial_alloc = 6;
        let h = spawn_draft_server(c, factory(), stream, ports.remove(0));
        let mut accepted_total = 0u64;
        for round in 0..4u64 {
            let (_, msg) = server.rx.recv().unwrap();
            let d = match msg {
                Message::Draft(d) => d,
                other => panic!("{other:?}"),
            };
            assert_eq!(d.parents.len(), d.draft.len());
            assert!(!d.parents.is_empty(), "budget 6 must draft tree nodes");
            let tree = DraftTree::from_parents(d.parents.clone()).unwrap();
            assert!(!tree.is_chain(), "arity 2 with budget 6 must branch");
            assert!(tree.rows_needed() <= 32);
            // Every node's q row is a distribution; siblings share one.
            for j in 0..d.draft.len() {
                let s: f32 = d.q_probs[j * 32..(j + 1) * 32].iter().sum();
                assert!((s - 1.0).abs() < 1e-4, "node {j} q sums {s}");
            }
            let roots = tree.root_children();
            assert_eq!(
                d.q_probs[roots[0] * 32..(roots[0] + 1) * 32],
                d.q_probs[roots[1] * 32..(roots[1] + 1) * 32],
                "siblings sample from the same parent distribution"
            );
            // Accept a real root path: second root child, then its first
            // child when it has one.
            let mut path: Vec<u8> = vec![roots[1] as u8];
            if let Some(&g) = tree.children(roots[1]).first() {
                path.push(g as u8);
            }
            accepted_total += path.len() as u64;
            (server.txs[0])(&Message::Verdict(VerdictMsg {
                client_id: 0,
                round,
                accepted: path.len() as u32,
                path,
                correction: 5,
                next_alloc: 6,
                shard: 0,
            }))
            .unwrap();
        }
        let stats = h.join().unwrap().unwrap();
        assert_eq!(stats.rounds, 4);
        assert_eq!(stats.tokens_drafted, 4 * 6);
        assert_eq!(stats.tokens_accepted, accepted_total);
        // Per-try accounting (the adaptive rule's statistic): each round's
        // path [roots[1], first grandchild] costs 2 tries at level 1
        // (sibling rank 1) + 1 try at level 2, ending on a leaf.
        assert_eq!(stats.spec_tries, 4 * 3);
    }

    #[test]
    fn zero_allocation_rounds_still_progress() {
        let (mut server, mut ports) = channel_transport(1);
        let stream = DomainStream::new("hle", 1.0, 50, Rng::new(4)).unwrap();
        let mut c = cfg(0, 4);
        c.initial_alloc = 0;
        let h = spawn_draft_server(c, factory(), stream, ports.remove(0));
        for round in 0..4u64 {
            let (_, msg) = server.rx.recv().unwrap();
            let d = match msg {
                Message::Draft(d) => d,
                _ => panic!(),
            };
            assert!(d.draft.is_empty());
            assert!(d.q_probs.is_empty());
            (server.txs[0])(&Message::Verdict(VerdictMsg {
                client_id: 0,
                round,
                accepted: 0,
                path: vec![],
                correction: 9,
                next_alloc: 0,
                shard: 0,
            }))
            .unwrap();
        }
        let stats = h.join().unwrap().unwrap();
        // Still generates one (correction) token per round.
        assert_eq!(stats.tokens_drafted, 0);
        assert_eq!(stats.rounds, 4);
    }

    #[test]
    fn hello_handshake_then_leave_exits_cleanly() {
        use crate::net::wire::{JoinAckMsg, LeaveMsg, Message, PROTOCOL_VERSION};
        let (mut server, mut ports) = channel_transport(1);
        let stream = DomainStream::new("alpaca", 1.0, 20, Rng::new(9)).unwrap();
        let mut c = cfg(0, 10);
        c.hello = true;
        c.initial_alloc = 1; // the ack must override this
        let h = spawn_draft_server(c, factory(), stream, ports.remove(0));
        // The first frame is the hello, carrying the protocol version.
        let (id, msg) = server.rx.recv().unwrap();
        assert_eq!(id, 0);
        match msg {
            Message::Join(j) => {
                assert_eq!(j.client_id, 0);
                assert_eq!(j.protocol, PROTOCOL_VERSION);
            }
            other => panic!("expected Join, got {other:?}"),
        }
        (server.txs[0])(&Message::JoinAck(JoinAckMsg {
            client_id: 0,
            protocol: PROTOCOL_VERSION,
            initial_alloc: 3,
            epoch: 1,
        }))
        .unwrap();
        // First draft uses the acked allocation, not the config's.
        let (_, msg) = server.rx.recv().unwrap();
        let d = match msg {
            Message::Draft(d) => d,
            other => panic!("{other:?}"),
        };
        assert_eq!(d.round, 0);
        assert_eq!(d.draft.len(), 3);
        // Deliver the verdict, then complete a graceful drain with Leave.
        (server.txs[0])(&Message::Verdict(VerdictMsg {
            client_id: 0,
            round: 0,
            accepted: 1,
            path: vec![],
            correction: 7,
            next_alloc: 0,
            shard: 0,
        }))
        .unwrap();
        let (_, msg) = server.rx.recv().unwrap(); // the drained (empty) draft
        assert!(matches!(msg, Message::Draft(ref d) if d.draft.is_empty()));
        (server.txs[0])(&Message::Leave(LeaveMsg { client_id: 0, epoch: 2 })).unwrap();
        let stats = h.join().unwrap().unwrap();
        assert_eq!(stats.rounds, 1);
        assert_eq!(stats.tokens_accepted, 1);
    }

    #[test]
    fn shutdown_exits_cleanly() {
        let (mut server, mut ports) = channel_transport(1);
        let stream = DomainStream::new("cnn", 1.0, 50, Rng::new(5)).unwrap();
        let h = spawn_draft_server(cfg(0, 100), factory(), stream, ports.remove(0));
        let (_, _msg) = server.rx.recv().unwrap();
        (server.txs[0])(&Message::Shutdown).unwrap();
        let stats = h.join().unwrap().unwrap();
        assert_eq!(stats.rounds, 0);
    }
}
