//! Crate-wide typed errors.
//!
//! Three layers of structure replace the ad-hoc `String` errors the early
//! prototype used:
//!
//! * [`ConfigError`] — scenario / CLI configuration problems. The
//!   [`ConfigError::InvalidChoice`] variant carries the full candidate
//!   list so `goodspeed run --policy typo` can print what *would* have
//!   been accepted.
//! * [`WireError`] — wire-format decode failures. Unknown tags and
//!   newer-than-supported protocol versions are first-class variants so a
//!   forward-compat peer degrades to a typed error instead of a panic.
//! * [`GoodSpeedError`] — the crate-wide union (config / wire / engine /
//!   shutdown) used by the serving API
//!   ([`ServingHandle`](crate::coordinator::ServingHandle)).
//!
//! All three implement [`std::error::Error`], so they convert into
//! `anyhow::Error` at the binary boundary with `?`.

use std::fmt;

/// A configuration problem (scenario validation or CLI parsing).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ConfigError {
    /// A multiple-choice field received an unrecognized value. Lists the
    /// accepted values so the CLI error is actionable.
    InvalidChoice {
        /// Which field was being parsed (e.g. `"policy"`).
        field: &'static str,
        /// The rejected input.
        given: String,
        /// The canonical accepted values.
        expected: &'static [&'static str],
    },
    /// A scenario-level invariant violation (free-form description).
    Invalid(String),
}

impl ConfigError {
    /// Shorthand for [`ConfigError::Invalid`].
    pub fn invalid(msg: impl Into<String>) -> ConfigError {
        ConfigError::Invalid(msg.into())
    }
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::InvalidChoice { field, given, expected } => {
                write!(f, "unknown {field} '{given}' (expected one of: {})", expected.join(", "))
            }
            ConfigError::Invalid(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for ConfigError {}

/// A wire-format decode failure. Decoding never panics: malformed,
/// unknown, or from-the-future frames all surface as one of these.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// The frame's tag byte is not one this build understands (a newer
    /// peer may legitimately send frame kinds we do not know yet).
    UnknownTag(u8),
    /// A control frame declared a protocol version newer than ours.
    UnsupportedVersion {
        /// Version the peer speaks.
        got: u8,
        /// Highest version this build supports.
        supported: u8,
    },
    /// The payload ended before the frame's declared fields did.
    Eof {
        /// Bytes the decoder wanted next.
        want: usize,
        /// Offset at which it wanted them.
        at: usize,
    },
    /// Bytes remained after the last field of the frame.
    TrailingBytes(usize),
    /// Structurally invalid contents (e.g. a tree draft whose parent
    /// array disagrees with its token count).
    Malformed(String),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::UnknownTag(t) => write!(f, "wire: unknown tag {t}"),
            WireError::UnsupportedVersion { got, supported } => {
                write!(f, "wire: protocol version {got} newer than supported {supported}")
            }
            WireError::Eof { want, at } => write!(f, "wire: eof (want {want} at {at})"),
            WireError::TrailingBytes(n) => write!(f, "wire: {n} trailing bytes"),
            WireError::Malformed(msg) => write!(f, "wire: {msg}"),
        }
    }
}

impl std::error::Error for WireError {}

/// The crate-wide error union the serving API returns.
#[derive(Clone, Debug)]
pub enum GoodSpeedError {
    /// Configuration rejected (scenario validation, CLI parsing, attach
    /// of an invalid [`ClientSpec`](crate::configsys::ClientSpec)).
    Config(ConfigError),
    /// Wire decode failure.
    Wire(WireError),
    /// Engine construction or execution failure (message only — engine
    /// errors originate as `anyhow` chains).
    Engine(String),
    /// The operation raced with (or requires) cluster shutdown.
    Shutdown(String),
}

impl fmt::Display for GoodSpeedError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            GoodSpeedError::Config(e) => write!(f, "configuration error: {e}"),
            GoodSpeedError::Wire(e) => write!(f, "wire error: {e}"),
            GoodSpeedError::Engine(msg) => write!(f, "engine error: {msg}"),
            GoodSpeedError::Shutdown(msg) => write!(f, "shutdown: {msg}"),
        }
    }
}

impl std::error::Error for GoodSpeedError {}

impl From<ConfigError> for GoodSpeedError {
    fn from(e: ConfigError) -> Self {
        GoodSpeedError::Config(e)
    }
}

impl From<WireError> for GoodSpeedError {
    fn from(e: WireError) -> Self {
        GoodSpeedError::Wire(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn invalid_choice_lists_candidates() {
        let e = ConfigError::InvalidChoice {
            field: "policy",
            given: "typo".into(),
            expected: &["goodspeed", "fixed-s", "random-s"],
        };
        let msg = e.to_string();
        assert!(msg.contains("unknown policy 'typo'"), "{msg}");
        assert!(msg.contains("goodspeed"), "{msg}");
        assert!(msg.contains("random-s"), "{msg}");
    }

    #[test]
    fn wire_error_messages() {
        assert_eq!(WireError::UnknownTag(99).to_string(), "wire: unknown tag 99");
        let v = WireError::UnsupportedVersion { got: 9, supported: 1 };
        assert!(v.to_string().contains("version 9 newer than supported 1"));
        assert!(WireError::Eof { want: 4, at: 7 }.to_string().contains("want 4 at 7"));
    }

    #[test]
    fn goodspeed_error_wraps_and_converts() {
        let g: GoodSpeedError = ConfigError::invalid("num_clients must be > 0").into();
        assert!(g.to_string().contains("configuration error"));
        let g: GoodSpeedError = WireError::UnknownTag(7).into();
        assert!(g.to_string().contains("wire error"));
        assert!(GoodSpeedError::Shutdown("cluster stopped".into())
            .to_string()
            .contains("cluster stopped"));
    }
}
