//! Ablations over the design choices DESIGN.md calls out:
//! * smoothing parameters η, β (estimation speed vs stability),
//! * verification budget C (goodput saturation curve),
//! * greedy vs exact-DP scheduler (identical objective, speed gap),
//! * utility choice (log vs linear — fairness collapse without concavity).

use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::cli::Args;
use crate::configsys::{Policy, Scenario, Smoothing};
use crate::metrics::csv::write_csv;
use crate::sched::gradient::{objective, solve_dp, solve_greedy, AllocInput};
use crate::sched::utility::{system_utility, LinearUtility, LogUtility};
use crate::simulate::AnalyticSim;
use crate::util::{jain_index, Rng};

pub fn main(args: &Args) -> Result<()> {
    let out_dir = args.get_or("out", "results");
    let rounds = args.get_parse::<u64>("rounds").unwrap_or(800);
    args.finish().map_err(|e| anyhow!(e))?;

    eta_beta_sweep(&out_dir, rounds)?;
    capacity_sweep(&out_dir, rounds)?;
    greedy_vs_dp(&out_dir)?;
    utility_ablation(&out_dir, rounds)?;
    Ok(())
}

fn base_scenario(rounds: u64) -> Scenario {
    let mut s = Scenario::preset("qwen-8c-150").unwrap();
    s.rounds = rounds;
    s
}

/// η/β grid → final utility + estimator tracking error.
fn eta_beta_sweep(out_dir: &str, rounds: u64) -> Result<()> {
    let grid = [0.05, 0.1, 0.3, 0.5, 0.8];
    let mut rows = Vec::new();
    for &eta in &grid {
        for &beta in &grid {
            let mut s = base_scenario(rounds);
            s.eta = Smoothing::Fixed(eta);
            s.beta = Smoothing::Fixed(beta);
            let mut sim = AnalyticSim::from_scenario(&s, Policy::GoodSpeed);
            sim.run();
            let u = sim.recorder().utility_of_avg(&LogUtility);
            // Tracking error: |α̂ − α_true| at the end.
            let err: f64 = sim
                .true_alphas()
                .iter()
                .zip(&sim.estimators().alpha_hat)
                .map(|(t, e)| (t - e).abs())
                .sum::<f64>()
                / sim.clients.len() as f64;
            rows.push(vec![
                format!("{eta}"),
                format!("{beta}"),
                format!("{u:.4}"),
                format!("{err:.4}"),
            ]);
        }
    }
    let path = format!("{out_dir}/ablation_eta_beta.csv");
    write_csv(&path, &["eta", "beta", "utility", "alpha_tracking_err"], rows)?;
    println!("ablation: eta/beta sweep -> {path}");
    Ok(())
}

/// C sweep: goodput saturates once C exceeds the useful draft budget.
fn capacity_sweep(out_dir: &str, rounds: u64) -> Result<()> {
    let mut rows = Vec::new();
    println!("\nablation: capacity sweep (8 clients):");
    println!("{:>4} {:>12} {:>8}", "C", "tok/round", "jain");
    for c in [4usize, 8, 12, 16, 20, 24, 32, 48, 64] {
        let mut s = base_scenario(rounds);
        s.capacity = c;
        let mut sim = AnalyticSim::from_scenario(&s, Policy::GoodSpeed);
        sim.run();
        let avg = sim.recorder().avg_goodput();
        let total: f64 = avg.iter().sum();
        let jain = jain_index(&avg);
        println!("{c:>4} {total:>12.2} {jain:>8.4}");
        rows.push(vec![c.to_string(), format!("{total:.3}"), format!("{jain:.4}")]);
    }
    let path = format!("{out_dir}/ablation_capacity.csv");
    write_csv(&path, &["C", "goodput_per_round", "jain"], rows)?;
    println!("-> {path}");
    Ok(())
}

/// Greedy vs exact DP: identical objective, orders-of-magnitude speed gap.
fn greedy_vs_dp(out_dir: &str) -> Result<()> {
    let mut rng = Rng::new(123);
    let mut rows = Vec::new();
    println!("\nablation: greedy vs DP scheduler:");
    println!("{:>4} {:>5} {:>12} {:>12} {:>9}", "N", "C", "greedy(µs)", "dp(µs)", "obj gap");
    for (n, c) in [(8usize, 20usize), (16, 64), (64, 256), (256, 1024)] {
        let weights: Vec<f64> = (0..n).map(|_| rng.f64() + 0.05).collect();
        let alphas: Vec<f64> = (0..n).map(|_| rng.f64() * 0.95).collect();
        let caps = vec![32usize; n];
        let input =
            AllocInput { weights: &weights, alphas: &alphas, capacity: c, max_per_client: &caps };
        let reps = 100;
        let t0 = Instant::now();
        let mut g = Vec::new();
        for _ in 0..reps {
            g = solve_greedy(&input);
        }
        let greedy_us = t0.elapsed().as_secs_f64() * 1e6 / reps as f64;
        let t1 = Instant::now();
        let d = solve_dp(&input);
        let dp_us = t1.elapsed().as_secs_f64() * 1e6;
        let gap = objective(&input, &d) - objective(&input, &g);
        println!("{n:>4} {c:>5} {greedy_us:>12.2} {dp_us:>12.2} {gap:>9.2e}");
        rows.push(vec![
            n.to_string(),
            c.to_string(),
            format!("{greedy_us:.2}"),
            format!("{dp_us:.2}"),
            format!("{gap:.3e}"),
        ]);
    }
    let path = format!("{out_dir}/ablation_greedy_dp.csv");
    write_csv(&path, &["N", "C", "greedy_us", "dp_us", "objective_gap"], rows)?;
    println!("-> {path}");
    Ok(())
}

/// Log vs linear utility: linear maximizes throughput but collapses
/// fairness (the starved-client pathology §III-B motivates log for).
fn utility_ablation(out_dir: &str, rounds: u64) -> Result<()> {
    use crate::sched::baselines::{Allocator, GoodSpeedAlloc};
    use std::sync::Arc;
    let mut rows = Vec::new();
    println!("\nablation: utility function:");
    println!("{:<8} {:>12} {:>8} {:>12}", "utility", "tok/round", "jain", "U_log(x̄)");
    for (name, utility) in [
        ("log", Arc::new(LogUtility) as Arc<dyn crate::sched::utility::Utility>),
        ("linear", Arc::new(LinearUtility) as Arc<dyn crate::sched::utility::Utility>),
    ] {
        let s = base_scenario(rounds);
        let mut sim = AnalyticSim::from_scenario(&s, Policy::GoodSpeed);
        // Swap the allocator's utility.
        let alloc: Box<dyn Allocator> = Box::new(GoodSpeedAlloc { utility });
        sim_set_allocator(&mut sim, alloc);
        sim.run();
        let avg = sim.recorder().avg_goodput();
        let total: f64 = avg.iter().sum();
        let jain = jain_index(&avg);
        let ulog = system_utility(&LogUtility, &avg);
        println!("{name:<8} {total:>12.2} {jain:>8.4} {ulog:>12.4}");
        rows.push(vec![
            name.to_string(),
            format!("{total:.3}"),
            format!("{jain:.4}"),
            format!("{ulog:.4}"),
        ]);
    }
    let path = format!("{out_dir}/ablation_utility.csv");
    write_csv(&path, &["utility", "goodput_per_round", "jain", "log_utility"], rows)?;
    println!("-> {path}");
    Ok(())
}

fn sim_set_allocator(sim: &mut AnalyticSim, alloc: Box<dyn crate::sched::baselines::Allocator>) {
    sim.set_allocator(alloc);
}
