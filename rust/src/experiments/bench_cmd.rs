//! `goodspeed bench` — the perf harness (DESIGN.md "Performance &
//! benchmarking").
//!
//! Runs quick serving benches across the standard presets (`sharded`,
//! `tree`, `churn`, `trace`) plus a wave hot-path microbench (arena
//! assembly + batched verify on recycled buffers), and records the result
//! as `BENCH_<n>.json`. CI reruns the harness with `--quick --baseline
//! <last committed recording>` and fails when any preset's wave
//! throughput regresses by more than 10%.
//!
//! `--soak` switches to the scale-out soak suite instead: the `soak`
//! preset's 10k trace-driven sessions (1k with `--quick`) direct-drive
//! per-shard scheduling cores, tracker partitions, and streaming
//! recorders at M ∈ {1, 4, 8} verifier shards, recording coordinator
//! ns/wave/session, waves/s, and peak RSS (gated by `--max-rss-mb`).
//!
//! Built with `--features alloc_track` the recording additionally carries
//! per-wave allocation counts from the thread-local counting allocator
//! (0s otherwise, with `"alloc_tracking": false` so diffs don't confuse
//! the two).

use std::fs;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::{mock_engine, serve_once};
use crate::cli::Args;
use crate::configsys::{Policy, Scenario};
use crate::coordinator::{
    build_verify_request_into, RoundCore, Transport, VerifyStage, WaveArena, WaveObs,
};
use crate::net::wire::{DraftMsg, FrameView, Message};
use crate::runtime::{EngineFactory, Verifier, VerifyOutput};
use crate::serve::{RequestTrace, RequestTracker};
use crate::util::alloc_track;
use crate::util::perfjson::{self, Json};
use crate::util::stats::percentile;

/// The presets the recording covers, in emission order.
pub const BENCH_PRESETS: &[&str] = &["sharded", "tree", "churn", "trace"];

/// Shard counts the soak suite sweeps (the issue's M ∈ {1, 4, 8}).
pub const SOAK_SHARDS: &[usize] = &[1, 4, 8];

/// Default on-disk recording (PR-numbered so history accumulates in git).
pub const DEFAULT_OUT: &str = "BENCH_10.json";

/// Fixed anchor for the cumulative (print-only) delta: how far the stack
/// has come since this recording, independent of the rolling baseline.
const CUMULATIVE_ANCHOR: &str = "BENCH_6.json";

/// Regression gate: fail when a preset's waves/s drops below this
/// fraction of the baseline recording.
const REGRESSION_FLOOR: f64 = 0.9;

/// One serving bench over a preset: full closed-loop run (draft servers,
/// coordinator, verdict fan-out) on the mock engine with network
/// simulation off, so the measured time is the serving machinery itself.
fn bench_preset(id: &str, quick: bool) -> Result<Json> {
    let mut s = Scenario::preset(id)
        .ok_or_else(|| anyhow!("unknown bench preset '{id}' ({:?})", Scenario::preset_ids()))?;
    if quick {
        s.rounds = s.rounds.min(40);
    }
    let out = serve_once(s, Policy::GoodSpeed, Transport::Channel, false, mock_engine())?;
    let wall = out.summary.wall_secs.max(1e-12);
    let waves = out.summary.rounds as f64;
    let waves_per_sec = waves / wall;
    let slo_tok = out.recorder.slo_summary().map(|sl| sl.slo_goodput_total / wall);
    let ns: Vec<f64> = out.recorder.rounds.iter().map(|r| r.total_ns() as f64).collect();
    let (p50, p99) = (percentile(&ns, 50.0), percentile(&ns, 99.0));
    println!(
        "  {id:>8}: {waves:>5} waves  {waves_per_sec:>9.1} waves/s  {:>9.1} tok/s  \
         wave p50/p99 {:.0}/{:.0} µs",
        out.summary.tokens_per_sec,
        p50 / 1e3,
        p99 / 1e3,
    );
    let mut o = Json::obj();
    o.insert("rounds", Json::Num(waves));
    o.insert("wall_secs", Json::Num(wall));
    o.insert("waves_per_sec", Json::Num(waves_per_sec));
    o.insert("tokens_per_sec", Json::Num(out.summary.tokens_per_sec));
    o.insert("slo_tokens_per_sec", slo_tok.map(Json::Num).unwrap_or(Json::Null));
    o.insert("wave_ns_p50", Json::Num(p50));
    o.insert("wave_ns_p99", Json::Num(p99));
    Ok(o)
}

/// The wave hot path in isolation: zero-copy frame parse, arena wave
/// assembly, and batched verification on recycled buffers. Reports
/// steady-state throughput and (under `alloc_track`) the per-stage
/// allocation counts the arena work drove to zero.
fn hot_path_bench(iters: u64) -> Result<Json> {
    let (vocab, k, clients) = (256usize, 8usize, 4u32);
    let factory = mock_engine();
    let mut verifier = factory.make_verifier("qwen")?;
    let buckets = verifier.buckets();
    let msgs: Vec<DraftMsg> = (0..clients)
        .map(|i| DraftMsg {
            client_id: i,
            round: 0,
            prefix: vec![1, 2, 3],
            prompt_len: 3,
            draft: vec![10 + i as u8; 4],
            parents: Vec::new(),
            q_probs: vec![1.0 / vocab as f32; 4 * vocab],
            new_request: false,
            draft_wall_ns: 0,
        })
        .collect();
    let frame = Message::Draft(msgs[0].clone()).encode();
    let payload = &frame[4..];
    let mut arena = WaveArena::new();
    let mut out = VerifyOutput::default();
    // Cold wave: grows the arenas to their steady-state high-water marks.
    build_verify_request_into(&msgs, &buckets, k, vocab, &mut arena)?;
    verifier.verify_into(&arena.req, &mut out)?;
    FrameView::parse(payload).map_err(|e| anyhow!("frame parse: {e}"))?;

    // Warm waves: count allocations per stage (all 0 when tracking is
    // compiled out — the recording labels which via `alloc_tracking`).
    let (res, assembly_allocs) =
        alloc_track::measure(|| build_verify_request_into(&msgs, &buckets, k, vocab, &mut arena));
    res?;
    let (res, verify_allocs) = alloc_track::measure(|| verifier.verify_into(&arena.req, &mut out));
    res?;
    let (res, parse_allocs) = alloc_track::measure(|| FrameView::parse(payload));
    res.map_err(|e| anyhow!("frame parse: {e}"))?;

    let t0 = Instant::now();
    for _ in 0..iters {
        build_verify_request_into(&msgs, &buckets, k, vocab, &mut arena)?;
        verifier.verify_into(&arena.req, &mut out)?;
    }
    let secs = t0.elapsed().as_secs_f64().max(1e-12);
    let waves_per_sec = iters as f64 / secs;
    println!(
        "  hot path: {waves_per_sec:>9.1} waves/s over {iters} warm waves  \
         (allocs/wave: assembly {assembly_allocs}, verify {verify_allocs}, \
         parse {parse_allocs}{})",
        if alloc_track::enabled() { "" } else { "; tracking off" }
    );
    if alloc_track::enabled() && assembly_allocs + verify_allocs + parse_allocs > 0 {
        log::warn!("warm wave hot path allocated — arena regression?");
    }
    let (pipe_wps, pipe_allocs) = pipelined_hot_path(&msgs, &buckets, k, vocab, iters)?;
    println!(
        "  pipelined : {pipe_wps:>9.1} waves/s over {iters} warm waves  \
         (allocs/wave: coordinator {pipe_allocs}{})",
        if alloc_track::enabled() { "" } else { "; tracking off" }
    );
    if alloc_track::enabled() && pipe_allocs > 0 {
        log::warn!("warm pipelined wave allocated on the coordinator side — regression?");
    }

    let mut o = Json::obj();
    o.insert("iters", Json::Num(iters as f64));
    o.insert("waves_per_sec", Json::Num(waves_per_sec));
    o.insert("assembly_allocs_per_wave", Json::Num(assembly_allocs as f64));
    o.insert("verify_allocs_per_wave", Json::Num(verify_allocs as f64));
    o.insert("frame_parse_allocs", Json::Num(parse_allocs as f64));
    o.insert("pipelined_waves_per_sec", Json::Num(pipe_wps));
    o.insert("pipelined_allocs_per_wave", Json::Num(pipe_allocs as f64));
    Ok(o)
}

/// The two-stage software pipeline in isolation: while the
/// [`VerifyStage`] runs wave i's forward on its own thread (and its own
/// verifier instance), the bench thread assembles wave i+1 into the
/// second arena, then swaps buffers at the handoff. Returns steady-state
/// waves/s and the coordinator-side allocations of one warm wave
/// (assemble + handoff round-trip; the stage thread's counter is its
/// own and the forward is arena'd regardless).
fn pipelined_hot_path(
    msgs: &[DraftMsg],
    buckets: &[(usize, usize)],
    k: usize,
    vocab: usize,
    iters: u64,
) -> Result<(f64, u64)> {
    let mut stage = VerifyStage::spawn(mock_engine(), "qwen", "bench-verify-stage")?;
    // Double-buffered arenas: one pair in flight on the stage, one
    // assembling here. Cold waves grow both to steady state.
    let mut arena = WaveArena::new();
    let mut out = VerifyOutput::default();
    let mut back = WaveArena::new();
    build_verify_request_into(msgs, buckets, k, vocab, &mut arena)?;
    build_verify_request_into(msgs, buckets, k, vocab, &mut back)?;
    stage.submit(back, VerifyOutput::default());

    // One warm pipelined wave under the counting allocator: next-wave
    // assembly plus the wait/submit buffer swap must not touch the heap.
    let (res, allocs) = alloc_track::measure(|| -> Result<()> {
        build_verify_request_into(msgs, buckets, k, vocab, &mut arena)?;
        let (a, o, r) = stage.wait_done().expect("wave in flight");
        r?;
        stage.submit(std::mem::replace(&mut arena, a), std::mem::replace(&mut out, o));
        Ok(())
    });
    res?;

    let t0 = Instant::now();
    for _ in 0..iters {
        build_verify_request_into(msgs, buckets, k, vocab, &mut arena)?;
        let (a, o, r) = stage.wait_done().expect("wave in flight");
        r?;
        stage.submit(std::mem::replace(&mut arena, a), std::mem::replace(&mut out, o));
    }
    let (_, _, r) = stage.wait_done().expect("wave in flight");
    r?;
    let secs = t0.elapsed().as_secs_f64().max(1e-12);
    Ok((iters as f64 / secs, allocs))
}

/// Wave-boundary observability overhead in isolation: the warm
/// streaming scheduler wave (estimator update + GOODSPEED-SCHED +
/// recycled record) run plain, then with the per-wave [`ObsHub`]
/// recording an observed cluster adds — flight-ring span + atomic
/// registry refresh. The two rates document the tentpole's <2% overhead
/// claim, and under `alloc_track` the observed wave must stay off the
/// heap.
///
/// [`ObsHub`]: crate::obs::ObsHub
fn observed_wave_bench(iters: u64) -> Result<Json> {
    use crate::obs::{ObsHub, ObsOptions};
    let s = Scenario::preset("smoke").expect("smoke preset");
    let mut core = RoundCore::new(8, s.eta, s.beta, Policy::GoodSpeed, 7, 64, 2);
    core.recorder.stream();
    let obs: Vec<WaveObs> = (0..8)
        .map(|i| WaveObs {
            client_id: i,
            s_used: 2,
            accepted: 1,
            goodput: 2,
            mean_ratio: 0.5,
            spec_depth: 2,
            max_next: 8,
        })
        .collect();
    let mut next = Vec::with_capacity(8);
    // Cold waves grow every internal vector to steady state.
    for w in 0..7 {
        core.finish_wave_into(w, &obs, 10, 20, &mut next);
    }
    let t0 = Instant::now();
    for w in 0..iters {
        core.finish_wave_into(7 + w, &obs, 10, 20, &mut next);
    }
    let plain_wps = iters as f64 / t0.elapsed().as_secs_f64().max(1e-12);

    let hub = ObsHub::new(1, 8, &ObsOptions::default());
    // One warm observed wave under the counting allocator: the span and
    // the registry refresh must not touch the heap.
    let ((), allocs) = alloc_track::measure(|| {
        core.finish_wave_into(7 + iters, &obs, 10, 20, &mut next);
        hub.wave_span(0, 7 + iters, 10, 20, 0);
        hub.publish_wave_stats(&core.recorder, 16, 64);
    });
    let t0 = Instant::now();
    for w in 0..iters {
        let wave = 8 + iters + w;
        core.finish_wave_into(wave, &obs, 10, 20, &mut next);
        hub.wave_span(0, wave, 10, 20, 0);
        hub.publish_wave_stats(&core.recorder, 16, 64);
    }
    let observed_wps = iters as f64 / t0.elapsed().as_secs_f64().max(1e-12);
    println!(
        "  obs wave  : {plain_wps:>9.1} plain vs {observed_wps:>9.1} observed waves/s  \
         ({:+.1}% overhead; allocs/wave {allocs}{})",
        100.0 * (plain_wps / observed_wps.max(1e-12) - 1.0),
        if alloc_track::enabled() { "" } else { "; tracking off" }
    );
    if alloc_track::enabled() && allocs > 0 {
        log::warn!("warm observed wave allocated — obs hot-path regression?");
    }
    let mut o = Json::obj();
    o.insert("iters", Json::Num(iters as f64));
    o.insert("plain_waves_per_sec", Json::Num(plain_wps));
    o.insert("observed_waves_per_sec", Json::Num(observed_wps));
    o.insert("observed_allocs_per_wave", Json::Num(allocs as f64));
    Ok(o)
}

/// This process's peak resident set (`VmHWM`) in MiB, read from
/// `/proc/self/status`. 0.0 where the procfs surface is unavailable
/// (non-Linux hosts record no ceiling and the `--max-rss-mb` gate
/// passes vacuously).
fn peak_rss_mb() -> f64 {
    let Ok(status) = fs::read_to_string("/proc/self/status") else {
        return 0.0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: f64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0.0);
            return kb / 1024.0;
        }
    }
    0.0
}

/// Deterministic synthetic acceptance for the soak drive: a cheap
/// splitmix-style hash of (client, wave) folded into `0..=s_used`, so the
/// drive costs nothing next to the scheduling work it measures and two
/// runs of the same point are identical.
fn synth_accept(client: usize, wave: u64, s_used: usize) -> usize {
    if s_used == 0 {
        return 0;
    }
    let mut h = (client as u64)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(wave.wrapping_mul(0xBF58_476D_1CE4_E5B9));
    h = (h ^ (h >> 31)).wrapping_mul(0x94D0_49BB_1331_11EB);
    (h % (s_used as u64 + 1)) as usize
}

/// One soak measurement point: `scenario.num_clients` trace-driven
/// sessions striped across `m` verifier shards, each shard owning a
/// scheduling core with an even budget slice, a retained-member tracker
/// partition, and a streaming recorder. The wave loop direct-drives the
/// coordinator surface the scale-out work targets — tracker wave-start
/// sync, GOODSPEED-SCHED over the member set, tracker attribution — with
/// synthetic verify outcomes (no threads, no engines), so the measured
/// time is per-wave coordinator cost and the resident set is the
/// steady-state serving state, not model buffers.
fn soak_point(scenario: &Scenario, m: usize, waves: u64) -> Result<Json> {
    let n = scenario.num_clients;
    let mut shards = Vec::with_capacity(m);
    for shard in 0..m {
        let members: Vec<usize> = (shard..n).step_by(m).collect();
        let mut core = RoundCore::new(
            n,
            scenario.eta,
            scenario.beta,
            Policy::GoodSpeed,
            scenario.seed ^ shard as u64,
            scenario.capacity / m,
            1,
        );
        core.set_shard(shard);
        core.recorder.stream();
        for i in 0..n {
            if i % m != shard {
                core.set_member(i, false);
                core.set_outstanding(i, 0);
            }
        }
        let trace = RequestTrace::from_scenario(scenario, n)?;
        let mut tracker = RequestTracker::new(trace, n);
        tracker.retain_members(&members);
        tracker.stream();
        shards.push((core, tracker, members));
    }

    let mut obs: Vec<WaveObs> = Vec::new();
    let mut outcomes: Vec<(usize, usize)> = Vec::new();
    let mut next: Vec<usize> = Vec::new();
    let mut member_waves = 0u64;
    let t0 = Instant::now();
    for wave in 0..waves {
        for (core, tracker, members) in shards.iter_mut() {
            tracker.sync_wave_start_tracked(core, wave);
            obs.clear();
            for &i in members.iter() {
                let s_used = core.outstanding(i);
                let accepted = synth_accept(i, wave, s_used);
                obs.push(WaveObs {
                    client_id: i,
                    s_used,
                    accepted,
                    goodput: accepted + 1,
                    mean_ratio: if s_used == 0 {
                        1.0
                    } else {
                        accepted as f64 / s_used as f64
                    },
                    spec_depth: s_used,
                    max_next: scenario.max_draft,
                });
            }
            core.finish_wave_into(wave, &obs, 0, 0, &mut next);
            outcomes.clear();
            outcomes.extend(obs.iter().map(|o| (o.client_id, o.goodput)));
            tracker.sync_wave_end(wave, &outcomes);
            member_waves += members.len() as u64;
        }
    }
    let secs = t0.elapsed().as_secs_f64().max(1e-12);

    let (mut completed, mut expired, mut censored) = (0u64, 0u64, 0u64);
    for (_core, tracker, _members) in shards.iter_mut() {
        tracker.finish(waves);
        let s = tracker.summary();
        completed += s.completed;
        expired += s.expired;
        censored += s.censored;
    }
    let waves_per_sec = (waves * m as u64) as f64 / secs;
    let ns_per_wave_session = secs * 1e9 / member_waves.max(1) as f64;
    let rss = peak_rss_mb();
    println!(
        "  soak m={m}: {n} sessions  {waves} waves/shard  \
         {waves_per_sec:>8.1} waves/s  {ns_per_wave_session:>7.1} ns/wave/session  \
         {completed} completed  peak rss {rss:.1} MiB"
    );
    let mut o = Json::obj();
    o.insert("shards", Json::Num(m as f64));
    o.insert("sessions", Json::Num(n as f64));
    o.insert("waves_per_shard", Json::Num(waves as f64));
    o.insert("wall_secs", Json::Num(secs));
    o.insert("waves_per_sec", Json::Num(waves_per_sec));
    o.insert("ns_per_wave_session", Json::Num(ns_per_wave_session));
    o.insert("requests_completed", Json::Num(completed as f64));
    o.insert("requests_expired", Json::Num(expired as f64));
    o.insert("requests_censored", Json::Num(censored as f64));
    o.insert("peak_rss_mb", Json::Num(rss));
    Ok(o)
}

/// The `--soak` suite: sweep [`SOAK_SHARDS`] over the `soak` preset
/// (10k sessions full, 1k quick) and gate the process's peak RSS against
/// `--max-rss-mb` when given. Peak RSS is a process-wide high-water mark,
/// so the recorded value is cumulative across points — the gate bounds
/// the whole sweep, which is exactly the flat-memory claim under test.
fn soak_bench(quick: bool, max_rss_mb: Option<f64>) -> Result<Json> {
    let mut s = Scenario::preset("soak").expect("soak preset exists");
    if quick {
        s.num_clients = 1_000;
        s.rounds = s.rounds.min(120);
    }
    // The direct drive never touches link simulation; don't carry one
    // LinkConfig per session around the sweep.
    s.links = Vec::new();
    let waves = s.rounds as u64;
    let mut o = Json::obj();
    o.insert("sessions", Json::Num(s.num_clients as f64));
    o.insert("waves_per_shard", Json::Num(waves as f64));
    for &m in SOAK_SHARDS {
        o.insert(&format!("m{m}"), soak_point(&s, m, waves)?);
    }
    let rss = peak_rss_mb();
    o.insert("peak_rss_mb", Json::Num(rss));
    if let Some(ceiling) = max_rss_mb {
        if rss > ceiling {
            return Err(anyhow!(
                "soak peak RSS {rss:.1} MiB exceeds ceiling {ceiling:.1} MiB"
            ));
        }
        println!("  soak peak RSS {rss:.1} MiB within ceiling {ceiling:.1} MiB");
    }
    Ok(o)
}

/// Compare a fresh recording against the committed baseline. Prints the
/// per-preset delta table; errors (non-zero exit) on any >10% wave-
/// throughput regression. A missing baseline skips the diff (first run).
pub fn diff_against_baseline(new: &Json, baseline_path: &str) -> Result<()> {
    let text = match fs::read_to_string(baseline_path) {
        Ok(t) => t,
        Err(_) => {
            println!("bench: no baseline at {baseline_path}; skipping diff");
            return Ok(());
        }
    };
    let base = perfjson::parse(&text)
        .with_context(|| format!("parse baseline {baseline_path}"))?;
    let mut regressions: Vec<String> = Vec::new();
    println!("bench: diff vs {baseline_path}");
    for &id in BENCH_PRESETS {
        let key = format!("presets.{id}.waves_per_sec");
        let (Some(old), Some(cur)) =
            (base.path(&key).and_then(Json::as_f64), new.path(&key).and_then(Json::as_f64))
        else {
            println!("  {id:>8}: not in both recordings; skipped");
            continue;
        };
        let ratio = cur / old.max(1e-12);
        println!(
            "  {id:>8}: waves/s {old:>9.1} -> {cur:>9.1}  ({:+.1}%)",
            100.0 * (ratio - 1.0)
        );
        if ratio < REGRESSION_FLOOR {
            regressions.push(format!("{id} ({:.1}%)", 100.0 * (ratio - 1.0)));
        }
    }
    // Cumulative view: the same table against the fixed PR 6 anchor in
    // the baseline's directory (print-only — the gate above is always
    // against the rolling baseline). Silently skipped when the anchor is
    // absent or is itself the baseline.
    let anchor = std::path::Path::new(baseline_path).with_file_name(CUMULATIVE_ANCHOR);
    if anchor != std::path::Path::new(baseline_path) {
        if let Some(old_doc) =
            fs::read_to_string(&anchor).ok().and_then(|t| perfjson::parse(&t).ok())
        {
            println!("bench: cumulative delta vs {}", anchor.display());
            for &id in BENCH_PRESETS {
                let key = format!("presets.{id}.waves_per_sec");
                let (Some(old), Some(cur)) = (
                    old_doc.path(&key).and_then(Json::as_f64),
                    new.path(&key).and_then(Json::as_f64),
                ) else {
                    continue;
                };
                println!(
                    "  {id:>8}: waves/s {old:>9.1} -> {cur:>9.1}  ({:+.1}% cumulative)",
                    100.0 * (cur / old.max(1e-12) - 1.0)
                );
            }
        }
    }
    if !regressions.is_empty() {
        return Err(anyhow!(
            "wave throughput regressed >{:.0}% on: {}",
            100.0 * (1.0 - REGRESSION_FLOOR),
            regressions.join(", ")
        ));
    }
    Ok(())
}

pub fn main(args: &Args) -> Result<()> {
    let quick = args.flag("quick");
    let soak = args.flag("soak");
    let out_path = args.get_or("out", DEFAULT_OUT);
    let baseline = args.get("baseline").map(str::to_string);
    let max_rss_mb = args.get_parse::<f64>("max-rss-mb");
    let iters = args
        .get_parse::<u64>("iters")
        .unwrap_or(if quick { 2_000 } else { 20_000 });
    args.finish().map_err(|e| anyhow!(e))?;

    if soak {
        println!(
            "bench: soak suite (M ∈ {SOAK_SHARDS:?}, {})",
            if quick { "quick" } else { "full" }
        );
        let mut doc = Json::obj();
        doc.insert("version", Json::Num(1.0));
        doc.insert("quick", Json::Bool(quick));
        doc.insert("soak", soak_bench(quick, max_rss_mb)?);
        fs::write(&out_path, doc.pretty()).with_context(|| format!("write {out_path}"))?;
        println!("soak recording -> {out_path}");
        return Ok(());
    }

    println!(
        "bench: {} presets + hot path ({}, alloc tracking {})",
        BENCH_PRESETS.len(),
        if quick { "quick" } else { "full" },
        if alloc_track::enabled() { "on" } else { "off" }
    );
    let mut doc = Json::obj();
    doc.insert("version", Json::Num(1.0));
    doc.insert("quick", Json::Bool(quick));
    doc.insert("alloc_tracking", Json::Bool(alloc_track::enabled()));
    let mut presets = Json::obj();
    for &id in BENCH_PRESETS {
        presets.insert(id, bench_preset(id, quick)?);
    }
    doc.insert("presets", presets);
    doc.insert("hot_path", hot_path_bench(iters)?);
    doc.insert("observed_wave", observed_wave_bench(iters)?);
    fs::write(&out_path, doc.pretty())
        .with_context(|| format!("write {out_path}"))?;
    println!("bench recording -> {out_path}");
    if let Some(b) = baseline {
        diff_against_baseline(&doc, &b)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn recording(sharded: f64, trace: f64) -> Json {
        let mut presets = Json::obj();
        for (id, w) in [("sharded", sharded), ("trace", trace)] {
            let mut o = Json::obj();
            o.insert("waves_per_sec", Json::Num(w));
            presets.insert(id, o);
        }
        let mut doc = Json::obj();
        doc.insert("version", Json::Num(1.0));
        doc.insert("presets", presets);
        doc
    }

    #[test]
    fn baseline_diff_gates_on_regression() {
        let dir = std::env::temp_dir().join("goodspeed_bench_diff_test");
        fs::create_dir_all(&dir).unwrap();
        let base_path = dir.join("base.json");
        fs::write(&base_path, recording(1000.0, 500.0).pretty()).unwrap();
        let base_path = base_path.to_str().unwrap();
        // Within the floor: +10% and −5% both pass.
        diff_against_baseline(&recording(1100.0, 475.0), base_path).unwrap();
        // An 11% drop on any preset fails.
        let err = diff_against_baseline(&recording(1000.0, 445.0), base_path).unwrap_err();
        assert!(err.to_string().contains("trace"), "{err}");
        // Missing baseline is not an error (first recording).
        diff_against_baseline(&recording(1.0, 1.0), dir.join("nope.json").to_str().unwrap())
            .unwrap();
    }

    #[test]
    fn soak_point_drives_sharded_serving_books() {
        let mut s = Scenario::preset("soak").unwrap();
        s.num_clients = 48;
        s.links = Vec::new();
        let o = soak_point(&s, 4, 64).unwrap();
        assert_eq!(o.path("shards").and_then(Json::as_f64), Some(4.0));
        assert_eq!(o.path("sessions").and_then(Json::as_f64), Some(48.0));
        assert!(o.path("waves_per_sec").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(o.path("ns_per_wave_session").and_then(Json::as_f64).unwrap() > 0.0);
        let done = o.path("requests_completed").and_then(Json::as_f64).unwrap();
        let expired = o.path("requests_expired").and_then(Json::as_f64).unwrap();
        let censored = o.path("requests_censored").and_then(Json::as_f64).unwrap();
        assert!(done + expired + censored > 0.0, "the trace produced no attributable work");
    }

    #[test]
    fn peak_rss_reads_nonnegative() {
        assert!(peak_rss_mb() >= 0.0);
    }

    /// The PR 6 allocation tail: with a streaming recorder, a *warm*
    /// scheduler wave — estimator update, GOODSPEED-SCHED water-fill,
    /// grant bookkeeping, and the recycled wave record — runs entirely on
    /// reused scratch. Seven cold waves grow every internal vector (and
    /// land the streaming reservoir inside a power-of-two capacity
    /// window); the eighth must not touch the heap.
    #[test]
    fn warm_scheduler_wave_is_allocation_free_when_streaming() {
        let s = Scenario::preset("smoke").unwrap();
        let mut core = RoundCore::new(8, s.eta, s.beta, Policy::GoodSpeed, 7, 64, 2);
        core.recorder.stream();
        let obs: Vec<WaveObs> = (0..8)
            .map(|i| WaveObs {
                client_id: i,
                s_used: 2,
                accepted: 1,
                goodput: 2,
                mean_ratio: 0.5,
                spec_depth: 2,
                max_next: 8,
            })
            .collect();
        let mut next = Vec::with_capacity(8);
        for w in 0..7 {
            core.finish_wave_into(w, &obs, 10, 20, &mut next);
        }
        let ((), allocs) =
            alloc_track::measure(|| core.finish_wave_into(7, &obs, 10, 20, &mut next));
        if alloc_track::enabled() {
            assert_eq!(allocs, 0, "warm streaming scheduler wave allocated");
        }
    }

    #[test]
    fn hot_path_bench_runs_and_reports_zero_allocs() {
        let o = hot_path_bench(3).unwrap();
        assert!(o.path("waves_per_sec").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(o.path("pipelined_waves_per_sec").and_then(Json::as_f64).unwrap() > 0.0);
        if alloc_track::enabled() {
            for key in [
                "assembly_allocs_per_wave",
                "verify_allocs_per_wave",
                "frame_parse_allocs",
                "pipelined_allocs_per_wave",
            ] {
                assert_eq!(o.path(key).and_then(Json::as_f64), Some(0.0), "{key}");
            }
        }
    }

    #[test]
    fn observed_wave_bench_runs_and_stays_allocation_free() {
        let o = observed_wave_bench(16).unwrap();
        assert!(o.path("plain_waves_per_sec").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(o.path("observed_waves_per_sec").and_then(Json::as_f64).unwrap() > 0.0);
        if alloc_track::enabled() {
            assert_eq!(
                o.path("observed_allocs_per_wave").and_then(Json::as_f64),
                Some(0.0),
                "observed warm wave must stay off the heap"
            );
        }
    }

    /// The tentpole's hot-path claim in isolation: a *warm* pipelined
    /// wave — next-wave assembly plus the stage handoff round-trip — is
    /// allocation-free on the coordinator thread, arena capacity
    /// shuttling between the two sides by move.
    #[test]
    fn warm_pipelined_wave_is_allocation_free() {
        let (vocab, k) = (256usize, 8usize);
        let factory = mock_engine();
        let buckets = factory.make_verifier("qwen").unwrap().buckets();
        let msgs: Vec<DraftMsg> = (0..4u32)
            .map(|i| DraftMsg {
                client_id: i,
                round: 0,
                prefix: vec![1, 2, 3],
                prompt_len: 3,
                draft: vec![10 + i as u8; 4],
                parents: Vec::new(),
                q_probs: vec![1.0 / vocab as f32; 4 * vocab],
                new_request: false,
                draft_wall_ns: 0,
            })
            .collect();
        let (wps, allocs) = pipelined_hot_path(&msgs, &buckets, k, vocab, 8).unwrap();
        assert!(wps > 0.0);
        if alloc_track::enabled() {
            assert_eq!(allocs, 0, "warm pipelined wave allocated");
        }
    }
}
