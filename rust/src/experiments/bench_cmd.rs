//! `goodspeed bench` — the perf harness (DESIGN.md "Performance &
//! benchmarking").
//!
//! Runs quick serving benches across the standard presets (`sharded`,
//! `tree`, `churn`, `trace`) plus a wave hot-path microbench (arena
//! assembly + batched verify on recycled buffers), and records the result
//! as `BENCH_<n>.json`. CI reruns the harness with `--quick --baseline
//! <last committed recording>` and fails when any preset's wave
//! throughput regresses by more than 10%.
//!
//! Built with `--features alloc_track` the recording additionally carries
//! per-wave allocation counts from the thread-local counting allocator
//! (0s otherwise, with `"alloc_tracking": false` so diffs don't confuse
//! the two).

use std::fs;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::{mock_engine, serve_once};
use crate::cli::Args;
use crate::configsys::{Policy, Scenario};
use crate::coordinator::{build_verify_request_into, Transport, WaveArena};
use crate::net::wire::{DraftMsg, FrameView, Message};
use crate::runtime::{EngineFactory, Verifier, VerifyOutput};
use crate::util::alloc_track;
use crate::util::perfjson::{self, Json};
use crate::util::stats::percentile;

/// The presets the recording covers, in emission order.
pub const BENCH_PRESETS: &[&str] = &["sharded", "tree", "churn", "trace"];

/// Default on-disk recording (PR-numbered so history accumulates in git).
pub const DEFAULT_OUT: &str = "BENCH_6.json";

/// Regression gate: fail when a preset's waves/s drops below this
/// fraction of the baseline recording.
const REGRESSION_FLOOR: f64 = 0.9;

/// One serving bench over a preset: full closed-loop run (draft servers,
/// coordinator, verdict fan-out) on the mock engine with network
/// simulation off, so the measured time is the serving machinery itself.
fn bench_preset(id: &str, quick: bool) -> Result<Json> {
    let mut s = Scenario::preset(id)
        .ok_or_else(|| anyhow!("unknown bench preset '{id}' ({:?})", Scenario::preset_ids()))?;
    if quick {
        s.rounds = s.rounds.min(40);
    }
    let out = serve_once(s, Policy::GoodSpeed, Transport::Channel, false, mock_engine())?;
    let wall = out.summary.wall_secs.max(1e-12);
    let waves = out.summary.rounds as f64;
    let waves_per_sec = waves / wall;
    let slo_tok = out.recorder.slo_summary().map(|sl| sl.slo_goodput_total / wall);
    let ns: Vec<f64> = out.recorder.rounds.iter().map(|r| r.total_ns() as f64).collect();
    let (p50, p99) = (percentile(&ns, 50.0), percentile(&ns, 99.0));
    println!(
        "  {id:>8}: {waves:>5} waves  {waves_per_sec:>9.1} waves/s  {:>9.1} tok/s  \
         wave p50/p99 {:.0}/{:.0} µs",
        out.summary.tokens_per_sec,
        p50 / 1e3,
        p99 / 1e3,
    );
    let mut o = Json::obj();
    o.insert("rounds", Json::Num(waves));
    o.insert("wall_secs", Json::Num(wall));
    o.insert("waves_per_sec", Json::Num(waves_per_sec));
    o.insert("tokens_per_sec", Json::Num(out.summary.tokens_per_sec));
    o.insert("slo_tokens_per_sec", slo_tok.map(Json::Num).unwrap_or(Json::Null));
    o.insert("wave_ns_p50", Json::Num(p50));
    o.insert("wave_ns_p99", Json::Num(p99));
    Ok(o)
}

/// The wave hot path in isolation: zero-copy frame parse, arena wave
/// assembly, and batched verification on recycled buffers. Reports
/// steady-state throughput and (under `alloc_track`) the per-stage
/// allocation counts the arena work drove to zero.
fn hot_path_bench(iters: u64) -> Result<Json> {
    let (vocab, k, clients) = (256usize, 8usize, 4u32);
    let factory = mock_engine();
    let mut verifier = factory.make_verifier("qwen")?;
    let buckets = verifier.buckets();
    let msgs: Vec<DraftMsg> = (0..clients)
        .map(|i| DraftMsg {
            client_id: i,
            round: 0,
            prefix: vec![1, 2, 3],
            prompt_len: 3,
            draft: vec![10 + i as u8; 4],
            parents: Vec::new(),
            q_probs: vec![1.0 / vocab as f32; 4 * vocab],
            new_request: false,
            draft_wall_ns: 0,
        })
        .collect();
    let frame = Message::Draft(msgs[0].clone()).encode();
    let payload = &frame[4..];
    let mut arena = WaveArena::new();
    let mut out = VerifyOutput::default();
    // Cold wave: grows the arenas to their steady-state high-water marks.
    build_verify_request_into(&msgs, &buckets, k, vocab, &mut arena)?;
    verifier.verify_into(&arena.req, &mut out)?;
    FrameView::parse(payload).map_err(|e| anyhow!("frame parse: {e}"))?;

    // Warm waves: count allocations per stage (all 0 when tracking is
    // compiled out — the recording labels which via `alloc_tracking`).
    let (res, assembly_allocs) =
        alloc_track::measure(|| build_verify_request_into(&msgs, &buckets, k, vocab, &mut arena));
    res?;
    let (res, verify_allocs) = alloc_track::measure(|| verifier.verify_into(&arena.req, &mut out));
    res?;
    let (res, parse_allocs) = alloc_track::measure(|| FrameView::parse(payload));
    res.map_err(|e| anyhow!("frame parse: {e}"))?;

    let t0 = Instant::now();
    for _ in 0..iters {
        build_verify_request_into(&msgs, &buckets, k, vocab, &mut arena)?;
        verifier.verify_into(&arena.req, &mut out)?;
    }
    let secs = t0.elapsed().as_secs_f64().max(1e-12);
    let waves_per_sec = iters as f64 / secs;
    println!(
        "  hot path: {waves_per_sec:>9.1} waves/s over {iters} warm waves  \
         (allocs/wave: assembly {assembly_allocs}, verify {verify_allocs}, \
         parse {parse_allocs}{})",
        if alloc_track::enabled() { "" } else { "; tracking off" }
    );
    if alloc_track::enabled() && assembly_allocs + verify_allocs + parse_allocs > 0 {
        log::warn!("warm wave hot path allocated — arena regression?");
    }
    let mut o = Json::obj();
    o.insert("iters", Json::Num(iters as f64));
    o.insert("waves_per_sec", Json::Num(waves_per_sec));
    o.insert("assembly_allocs_per_wave", Json::Num(assembly_allocs as f64));
    o.insert("verify_allocs_per_wave", Json::Num(verify_allocs as f64));
    o.insert("frame_parse_allocs", Json::Num(parse_allocs as f64));
    Ok(o)
}

/// Compare a fresh recording against the committed baseline. Prints the
/// per-preset delta table; errors (non-zero exit) on any >10% wave-
/// throughput regression. A missing baseline skips the diff (first run).
pub fn diff_against_baseline(new: &Json, baseline_path: &str) -> Result<()> {
    let text = match fs::read_to_string(baseline_path) {
        Ok(t) => t,
        Err(_) => {
            println!("bench: no baseline at {baseline_path}; skipping diff");
            return Ok(());
        }
    };
    let base = perfjson::parse(&text)
        .with_context(|| format!("parse baseline {baseline_path}"))?;
    let mut regressions: Vec<String> = Vec::new();
    println!("bench: diff vs {baseline_path}");
    for &id in BENCH_PRESETS {
        let key = format!("presets.{id}.waves_per_sec");
        let (Some(old), Some(cur)) =
            (base.path(&key).and_then(Json::as_f64), new.path(&key).and_then(Json::as_f64))
        else {
            println!("  {id:>8}: not in both recordings; skipped");
            continue;
        };
        let ratio = cur / old.max(1e-12);
        println!(
            "  {id:>8}: waves/s {old:>9.1} -> {cur:>9.1}  ({:+.1}%)",
            100.0 * (ratio - 1.0)
        );
        if ratio < REGRESSION_FLOOR {
            regressions.push(format!("{id} ({:.1}%)", 100.0 * (ratio - 1.0)));
        }
    }
    if !regressions.is_empty() {
        return Err(anyhow!(
            "wave throughput regressed >{:.0}% on: {}",
            100.0 * (1.0 - REGRESSION_FLOOR),
            regressions.join(", ")
        ));
    }
    Ok(())
}

pub fn main(args: &Args) -> Result<()> {
    let quick = args.flag("quick");
    let out_path = args.get_or("out", DEFAULT_OUT);
    let baseline = args.get("baseline").map(str::to_string);
    let iters = args
        .get_parse::<u64>("iters")
        .unwrap_or(if quick { 2_000 } else { 20_000 });
    args.finish().map_err(|e| anyhow!(e))?;

    println!(
        "bench: {} presets + hot path ({}, alloc tracking {})",
        BENCH_PRESETS.len(),
        if quick { "quick" } else { "full" },
        if alloc_track::enabled() { "on" } else { "off" }
    );
    let mut doc = Json::obj();
    doc.insert("version", Json::Num(1.0));
    doc.insert("quick", Json::Bool(quick));
    doc.insert("alloc_tracking", Json::Bool(alloc_track::enabled()));
    let mut presets = Json::obj();
    for &id in BENCH_PRESETS {
        presets.insert(id, bench_preset(id, quick)?);
    }
    doc.insert("presets", presets);
    doc.insert("hot_path", hot_path_bench(iters)?);
    fs::write(&out_path, doc.pretty())
        .with_context(|| format!("write {out_path}"))?;
    println!("bench recording -> {out_path}");
    if let Some(b) = baseline {
        diff_against_baseline(&doc, &b)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn recording(sharded: f64, trace: f64) -> Json {
        let mut presets = Json::obj();
        for (id, w) in [("sharded", sharded), ("trace", trace)] {
            let mut o = Json::obj();
            o.insert("waves_per_sec", Json::Num(w));
            presets.insert(id, o);
        }
        let mut doc = Json::obj();
        doc.insert("version", Json::Num(1.0));
        doc.insert("presets", presets);
        doc
    }

    #[test]
    fn baseline_diff_gates_on_regression() {
        let dir = std::env::temp_dir().join("goodspeed_bench_diff_test");
        fs::create_dir_all(&dir).unwrap();
        let base_path = dir.join("base.json");
        fs::write(&base_path, recording(1000.0, 500.0).pretty()).unwrap();
        let base_path = base_path.to_str().unwrap();
        // Within the floor: +10% and −5% both pass.
        diff_against_baseline(&recording(1100.0, 475.0), base_path).unwrap();
        // An 11% drop on any preset fails.
        let err = diff_against_baseline(&recording(1000.0, 445.0), base_path).unwrap_err();
        assert!(err.to_string().contains("trace"), "{err}");
        // Missing baseline is not an error (first recording).
        diff_against_baseline(&recording(1.0, 1.0), dir.join("nope.json").to_str().unwrap())
            .unwrap();
    }

    #[test]
    fn hot_path_bench_runs_and_reports_zero_allocs() {
        let o = hot_path_bench(3).unwrap();
        assert!(o.path("waves_per_sec").and_then(Json::as_f64).unwrap() > 0.0);
        if alloc_track::enabled() {
            for key in
                ["assembly_allocs_per_wave", "verify_allocs_per_wave", "frame_parse_allocs"]
            {
                assert_eq!(o.path(key).and_then(Json::as_f64), Some(0.0), "{key}");
            }
        }
    }
}
