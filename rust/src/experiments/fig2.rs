//! Fig 2 — estimated vs. real goodput (8 clients, both families).
//!
//! Paper: MA(10)-smoothed curves of the smoothed estimate `X^β(t)` and the
//! realized goodput `x(t)` (system-wide sums), with ±1 std confidence
//! bands; the two curves should track closely despite SD's stochasticity
//! and prompt variability.

use anyhow::{anyhow, Result};

use super::engine_from_args;
use crate::cli::Args;
use crate::configsys::{Policy, Scenario};
use crate::coordinator::Transport;
use crate::metrics::csv::write_csv;
use crate::metrics::recorder::Recorder;
use crate::metrics::svg::Chart;
use crate::util::MovingAvg;

/// Extract the two MA(10) series (estimated, real) with std bands.
pub fn estimation_series(rec: &Recorder, window: usize) -> Fig2Series {
    let mut est_ma = MovingAvg::new(window);
    let mut real_ma = MovingAvg::new(window);
    let mut rows = Vec::with_capacity(rec.rounds.len());
    for r in &rec.rounds {
        let est: f64 = r.clients.iter().map(|c| c.x_beta).sum();
        let real: f64 = r.clients.iter().map(|c| c.goodput as f64).sum();
        est_ma.push(est);
        real_ma.push(real);
        rows.push(Fig2Row {
            round: r.round,
            est_ma: est_ma.mean(),
            est_std: est_ma.std(),
            real_ma: real_ma.mean(),
            real_std: real_ma.std(),
        });
    }
    Fig2Series { rows }
}

pub struct Fig2Row {
    pub round: u64,
    pub est_ma: f64,
    pub est_std: f64,
    pub real_ma: f64,
    pub real_std: f64,
}

pub struct Fig2Series {
    pub rows: Vec<Fig2Row>,
}

impl Fig2Series {
    /// Mean absolute estimation error over the post-warmup region —
    /// the quantitative "strong alignment" check.
    pub fn mean_abs_error(&self, skip: usize) -> f64 {
        let rows = &self.rows[skip.min(self.rows.len())..];
        if rows.is_empty() {
            return 0.0;
        }
        rows.iter().map(|r| (r.est_ma - r.real_ma).abs()).sum::<f64>() / rows.len() as f64
    }

    /// Fraction of rounds where the real MA lies inside the estimated ±1σ
    /// band (paper: "these regions encompass most observed goodput peaks").
    pub fn band_coverage(&self, skip: usize) -> f64 {
        let rows = &self.rows[skip.min(self.rows.len())..];
        if rows.is_empty() {
            return 1.0;
        }
        let inside = rows
            .iter()
            .filter(|r| (r.real_ma - r.est_ma).abs() <= r.est_std + r.real_std + 1e-9)
            .count();
        inside as f64 / rows.len() as f64
    }
}

pub fn main(args: &Args) -> Result<()> {
    let out_dir = args.get_or("out", "results");
    let rounds = args.get_parse::<u64>("rounds").unwrap_or(300);
    let families = args.get_or("families", "qwen,llama");
    let factory = engine_from_args(args)?;
    args.finish().map_err(|e| anyhow!(e))?;

    for fam in families.split(',') {
        let preset = if fam == "qwen" { "qwen-8c-150" } else { "llama-8c-150" };
        let mut scenario = Scenario::preset(preset).unwrap();
        scenario.rounds = rounds;
        log::info!("fig2: {fam} ({rounds} rounds)");
        let out = super::serve_once(
            scenario,
            Policy::GoodSpeed,
            Transport::Channel,
            false,
            factory.clone(),
        )?;
        let series = estimation_series(&out.recorder, 10);
        let csv_path = format!("{out_dir}/fig2_{fam}.csv");
        write_csv(
            &csv_path,
            &["round", "est_ma", "est_std", "real_ma", "real_std"],
            series.rows.iter().map(|r| {
                vec![
                    r.round.to_string(),
                    format!("{:.4}", r.est_ma),
                    format!("{:.4}", r.est_std),
                    format!("{:.4}", r.real_ma),
                    format!("{:.4}", r.real_std),
                ]
            }),
        )?;
        let mut chart = Chart::new(
            &format!("Fig 2 — estimated vs real goodput ({fam}, 8 clients)"),
            "round",
            "goodput (tokens/round, MA-10)",
        );
        chart.add_with_band(
            "estimated X^β",
            series.rows.iter().map(|r| (r.round as f64, r.est_ma)).collect(),
            series.rows.iter().map(|r| r.est_std).collect(),
        );
        chart.add_with_band(
            "real goodput",
            series.rows.iter().map(|r| (r.round as f64, r.real_ma)).collect(),
            series.rows.iter().map(|r| r.real_std).collect(),
        );
        chart.save(format!("{out_dir}/fig2_{fam}.svg"))?;
        let mae = series.mean_abs_error(50);
        let cover = series.band_coverage(50);
        println!(
            "fig2 {fam}: mean|est−real| = {mae:.3} tok/round, band coverage {:.1}% -> {csv_path}",
            cover * 100.0
        );
    }
    Ok(())
}
