//! Fig 3 — wall-time decomposition (receive / verify / send) for
//! GoodSpeed vs Fixed-S vs Random-S, both model families.
//!
//! Paper findings to reproduce in *shape*: receiving + verification
//! dominate; sending < 0.1 %; Random-S adds 5–25 % total wall time from
//! scheduling inefficiency (straggler variance); GoodSpeed ≈ Fixed-S total
//! with ~5 % lower verification time.

use anyhow::{anyhow, Result};

use super::engine_from_args;
use crate::cli::Args;
use crate::configsys::{Policy, Scenario};
use crate::coordinator::Transport;
use crate::metrics::csv::write_csv;

pub struct Fig3Row {
    pub family: String,
    pub policy: &'static str,
    pub recv_secs: f64,
    pub verify_secs: f64,
    pub send_secs: f64,
    pub total_secs: f64,
    pub tokens: f64,
}

pub fn run_grid(
    factory: std::sync::Arc<dyn crate::runtime::EngineFactory>,
    families: &[&str],
    rounds: u64,
    transport: Transport,
) -> Result<Vec<Fig3Row>> {
    let mut rows = Vec::new();
    for fam in families {
        for policy in Policy::all() {
            let preset = if *fam == "qwen" { "qwen-8c-150" } else { "llama-8c-150" };
            let mut scenario = Scenario::preset(preset).unwrap();
            scenario.rounds = rounds;
            log::info!("fig3: {fam}/{} ({rounds} rounds)", policy.name());
            // The decomposition needs real delays (simulate_network on).
            let out = super::serve_once(scenario, policy, transport, true, factory.clone())?;
            let s = out.summary;
            rows.push(Fig3Row {
                family: fam.to_string(),
                policy: policy.name(),
                recv_secs: s.recv_secs,
                verify_secs: s.verify_secs,
                send_secs: s.send_secs,
                total_secs: s.recv_secs + s.verify_secs + s.send_secs,
                tokens: s.total_tokens,
            });
        }
    }
    Ok(rows)
}

pub fn main(args: &Args) -> Result<()> {
    let out_dir = args.get_or("out", "results");
    let rounds = args.get_parse::<u64>("rounds").unwrap_or(120);
    let families: Vec<String> =
        args.get_or("families", "qwen,llama").split(',').map(String::from).collect();
    let transport: Transport = args
        .get_or("transport", "channel")
        .parse()
        .map_err(|e| anyhow!("--transport: {e}"))?;
    let factory = engine_from_args(args)?;
    args.finish().map_err(|e| anyhow!(e))?;

    let fams: Vec<&str> = families.iter().map(String::as_str).collect();
    let rows = run_grid(factory, &fams, rounds, transport)?;
    let csv_path = format!("{out_dir}/fig3_time_distribution.csv");
    write_csv(
        &csv_path,
        &["family", "policy", "recv_s", "verify_s", "send_s", "total_s", "tokens"],
        rows.iter().map(|r| {
            vec![
                r.family.clone(),
                r.policy.to_string(),
                format!("{:.4}", r.recv_secs),
                format!("{:.4}", r.verify_secs),
                format!("{:.4}", r.send_secs),
                format!("{:.4}", r.total_secs),
                format!("{:.0}", r.tokens),
            ]
        }),
    )?;
    println!("\nFig 3 — wall-time decomposition ({rounds} rounds):");
    println!(
        "{:<7} {:<10} {:>9} {:>9} {:>9} {:>9} {:>8}",
        "family", "policy", "recv(s)", "verify(s)", "send(s)", "total(s)", "send%"
    );
    for r in &rows {
        println!(
            "{:<7} {:<10} {:>9.3} {:>9.3} {:>9.5} {:>9.3} {:>7.3}%",
            r.family,
            r.policy,
            r.recv_secs,
            r.verify_secs,
            r.send_secs,
            r.total_secs,
            100.0 * r.send_secs / r.total_secs.max(1e-12)
        );
    }
    // Paper-shape checks printed for EXPERIMENTS.md.
    for fam in &fams {
        let get = |p: &str| rows.iter().find(|r| r.family == *fam && r.policy == p).unwrap();
        let gs = get("goodspeed");
        let fx = get("fixed-s");
        let rd = get("random-s");
        println!(
            "{fam}: random-s total {:+.1}% vs fixed-s; goodspeed verify {:+.1}% vs fixed-s; send share {:.4}%",
            100.0 * (rd.total_secs / fx.total_secs - 1.0),
            100.0 * (gs.verify_secs / fx.verify_secs - 1.0),
            100.0 * gs.send_secs / gs.total_secs
        );
    }
    println!("csv -> {csv_path}");
    Ok(())
}
