//! Fig 4 — convergence of the utility U(x̄(T)) over iterations for
//! GoodSpeed / Fixed-S / Random-S, per family × client count.
//!
//! Paper shape: GoodSpeed starts lower (exploration while α̂ settles), rises
//! steadily, stabilizes by ~iteration 400, and ends above both baselines.
//!
//! Default engine is the analytic simulator (the full grid is 12 runs of
//! 600 iterations); `--real` drives the full serving stack instead.

use anyhow::{anyhow, Result};

use super::engine_from_args;
use crate::cli::Args;
use crate::configsys::{Policy, Scenario};
use crate::coordinator::Transport;
use crate::metrics::csv::write_csv;
use crate::metrics::recorder::Recorder;
use crate::metrics::svg::Chart;
use crate::sched::utility::LogUtility;
use crate::simulate::AnalyticSim;

/// U(x̄(T)) for every prefix T of a run. Waves may hold arbitrary client
/// subsets, so goodput is accumulated by `client_id` and averaged per
/// *participated* wave (identical to the dense per-round math in sync).
/// Clients with no observations yet are excluded from a prefix's utility
/// rather than entered as 0 (which would clamp to ln(X_MIN) and put a
/// spurious cliff at the start of async curves).
pub fn utility_curve(rec: &Recorder) -> Vec<f64> {
    let n = rec.n_clients();
    let mut cum = vec![0.0f64; n];
    let mut seen = vec![0u64; n];
    let u = LogUtility;
    let mut out = Vec::with_capacity(rec.rounds.len());
    for r in &rec.rounds {
        for c in &r.clients {
            cum[c.client_id] += c.goodput as f64;
            seen[c.client_id] += 1;
        }
        let avg: Vec<f64> = cum
            .iter()
            .zip(&seen)
            .filter(|(_, &t)| t > 0)
            .map(|(&g, &t)| g / t as f64)
            .collect();
        out.push(crate::sched::utility::system_utility(&u, &avg));
    }
    out
}

pub struct Fig4Curve {
    pub family: String,
    pub clients: usize,
    pub policy: &'static str,
    pub curve: Vec<f64>,
}

pub fn run_grid_sim(rounds: u64) -> Vec<Fig4Curve> {
    let mut out = Vec::new();
    for fam in ["qwen", "llama"] {
        for clients in [4usize, 8] {
            for policy in Policy::all() {
                let preset = if fam == "qwen" {
                    if clients == 4 { "qwen-4c-50" } else { "qwen-8c-150" }
                } else {
                    "llama-8c-150"
                };
                let mut s = Scenario::preset(preset).unwrap();
                s.num_clients = clients;
                s.rounds = rounds;
                // Family-specific stochastic stream (the real stacks differ
                // through their models; the simulator differs through seed).
                s.seed ^= fam.bytes().fold(0u64, |h, b| h.wrapping_mul(31).wrapping_add(b as u64));
                s.links = Scenario::default_links(clients, s.seed);
                let mut sim = AnalyticSim::from_scenario(&s, policy);
                sim.run();
                out.push(Fig4Curve {
                    family: fam.to_string(),
                    clients,
                    policy: policy.name(),
                    curve: utility_curve(sim.recorder()),
                });
            }
        }
    }
    out
}

pub fn main(args: &Args) -> Result<()> {
    let out_dir = args.get_or("out", "results");
    let rounds = args.get_parse::<u64>("rounds").unwrap_or(600);
    let real = args.flag("real");
    let curves = if real {
        let factory = engine_from_args(args)?;
        args.finish().map_err(|e| anyhow!(e))?;
        let mut out = Vec::new();
        for fam in ["qwen", "llama"] {
            for clients in [4usize, 8] {
                for policy in Policy::all() {
                    let preset = if fam == "qwen" {
                        if clients == 4 { "qwen-4c-50" } else { "qwen-8c-150" }
                    } else {
                        "llama-8c-150"
                    };
                    let mut s = Scenario::preset(preset).unwrap();
                    s.num_clients = clients;
                    s.rounds = rounds;
                    s.links = Scenario::default_links(clients, s.seed);
                    log::info!("fig4(real): {fam}/{clients}c/{}", policy.name());
                    let run = super::serve_once(
                        s,
                        policy,
                        Transport::Channel,
                        false,
                        factory.clone(),
                    )?;
                    out.push(Fig4Curve {
                        family: fam.to_string(),
                        clients,
                        policy: policy.name(),
                        curve: utility_curve(&run.recorder),
                    });
                }
            }
        }
        out
    } else {
        args.finish().map_err(|e| anyhow!(e))?;
        run_grid_sim(rounds)
    };

    // CSV: one row per (setting, policy, iteration).
    let csv_path = format!("{out_dir}/fig4_convergence.csv");
    write_csv(
        &csv_path,
        &["family", "clients", "policy", "iteration", "utility"],
        curves.iter().flat_map(|c| {
            c.curve.iter().enumerate().map(move |(t, &u)| {
                vec![
                    c.family.clone(),
                    c.clients.to_string(),
                    c.policy.to_string(),
                    t.to_string(),
                    format!("{u:.5}"),
                ]
            })
        }),
    )?;
    // One SVG per (family, clients) panel — like the paper's subplots.
    for fam in ["qwen", "llama"] {
        for clients in [4usize, 8] {
            let panel: Vec<&Fig4Curve> = curves
                .iter()
                .filter(|c| c.family == fam && c.clients == clients)
                .collect();
            if panel.is_empty() {
                continue;
            }
            let mut chart = Chart::new(
                &format!("Fig 4 — U(x̄(T)) convergence ({fam}, {clients} clients)"),
                "iteration",
                "U(x̄(T)) = Σ log x̄_i",
            );
            for c in panel {
                chart.add(
                    c.policy,
                    c.curve.iter().enumerate().map(|(t, &u)| (t as f64, u)).collect(),
                );
            }
            chart.save(format!("{out_dir}/fig4_{fam}_{clients}c.svg"))?;
        }
    }
    // Paper-shape summary.
    println!("\nFig 4 — final U(x̄(T)) after {rounds} iterations:");
    println!("{:<7} {:>3}  {:>11} {:>11} {:>11}  winner", "family", "N", "goodspeed", "fixed-s", "random-s");
    for fam in ["qwen", "llama"] {
        for clients in [4usize, 8] {
            let val = |p: &str| {
                curves
                    .iter()
                    .find(|c| c.family == fam && c.clients == clients && c.policy == p)
                    .map(|c| *c.curve.last().unwrap())
            };
            if let (Some(gs), Some(fx), Some(rd)) =
                (val("goodspeed"), val("fixed-s"), val("random-s"))
            {
                let winner = if gs >= fx && gs >= rd { "goodspeed ✓" } else { "BASELINE ✗" };
                println!(
                    "{fam:<7} {clients:>3}  {gs:>11.4} {fx:>11.4} {rd:>11.4}  {winner}"
                );
            }
        }
    }
    println!("csv -> {csv_path}");
    Ok(())
}
