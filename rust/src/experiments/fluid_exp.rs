//! Theorem 1 validation — fluid-limit concentration as β → 0.
//!
//! For a fixed heterogeneous instance: compute x* by Frank–Wolfe, then run
//! the stochastic system at decreasing β and measure the stationary
//! distance ‖X^β(t) − x*‖ over the tail. Theorem 1 predicts the distance
//! shrinks with β; we report the full decay table.

use anyhow::{anyhow, Result};

use crate::cli::Args;
use crate::configsys::{Policy, Scenario, Smoothing};
use crate::metrics::csv::write_csv;
use crate::simulate::fluid::optimal_allocation;
use crate::simulate::AnalyticSim;

pub struct BetaRow {
    pub beta: f64,
    pub tail_dist_mean: f64,
    pub tail_dist_max: f64,
    pub utility_gap: f64,
}

pub fn beta_sweep(betas: &[f64], rounds: u64, clients: usize) -> Vec<BetaRow> {
    // Stationary setting (no domain switching) so x* is well-defined.
    let mut scenario = Scenario::preset("qwen-8c-150").unwrap();
    scenario.num_clients = clients;
    scenario.rounds = rounds;
    scenario.domain_stickiness = 1.0;
    let mut rows = Vec::new();
    for &beta in betas {
        scenario.beta = Smoothing::Fixed(beta);
        scenario.eta = Smoothing::Fixed((beta * 0.6).min(0.3)); // η/β bounded
        let mut sim = AnalyticSim::from_scenario(&scenario, Policy::GoodSpeed);
        let alphas = sim.true_alphas();
        let (x_star, u_star) = optimal_allocation(&alphas, scenario.capacity, scenario.max_draft);
        sim.run();
        // Tail statistics over the last third of the run.
        let tail_start = (rounds as usize * 2) / 3;
        let mut dist_sum = 0.0;
        let mut dist_max: f64 = 0.0;
        let mut count = 0usize;
        for r in &sim.recorder().rounds[tail_start..] {
            // Keyed by client_id (waves may hold subsets; dense in sync).
            let d: f64 = r
                .clients
                .iter()
                .map(|c| {
                    let xs = x_star[c.client_id];
                    (c.x_beta - xs) * (c.x_beta - xs)
                })
                .sum::<f64>()
                .sqrt();
            dist_sum += d;
            dist_max = dist_max.max(d);
            count += 1;
        }
        let u_final = sim.recorder().utility_of_avg(&crate::sched::utility::LogUtility);
        rows.push(BetaRow {
            beta,
            tail_dist_mean: dist_sum / count.max(1) as f64,
            tail_dist_max: dist_max,
            utility_gap: u_star - u_final,
        });
    }
    rows
}

pub fn main(args: &Args) -> Result<()> {
    let out_dir = args.get_or("out", "results");
    let rounds = args.get_parse::<u64>("rounds").unwrap_or(4000);
    args.finish().map_err(|e| anyhow!(e))?;

    let betas = [0.5, 0.2, 0.1, 0.05, 0.02];
    let rows = beta_sweep(&betas, rounds, 8);
    let csv_path = format!("{out_dir}/fluid_beta_sweep.csv");
    write_csv(
        &csv_path,
        &["beta", "tail_dist_mean", "tail_dist_max", "utility_gap"],
        rows.iter().map(|r| {
            vec![
                format!("{:.3}", r.beta),
                format!("{:.4}", r.tail_dist_mean),
                format!("{:.4}", r.tail_dist_max),
                format!("{:.5}", r.utility_gap),
            ]
        }),
    )?;
    println!("\nTheorem 1 validation — ‖X^β − x*‖ tail statistics ({rounds} rounds):");
    println!("{:>7} {:>15} {:>14} {:>12}", "beta", "mean tail dist", "max tail dist", "U gap");
    for r in &rows {
        println!(
            "{:>7.3} {:>15.4} {:>14.4} {:>12.5}",
            r.beta, r.tail_dist_mean, r.tail_dist_max, r.utility_gap
        );
    }
    let first = rows.first().unwrap();
    let last = rows.last().unwrap();
    println!(
        "concentration: mean tail distance {:.4} (β={}) -> {:.4} (β={}) — Theorem 1 predicts ↓",
        first.tail_dist_mean, first.beta, last.tail_dist_mean, last.beta
    );
    println!("csv -> {csv_path}");
    Ok(())
}
