//! Experiment harnesses — one per paper table/figure (DESIGN.md §4).
//!
//! Each harness is callable from the CLI (`goodspeed fig2 …`) and from the
//! bench targets (`cargo bench`), writes `results/*.csv` (+ `.svg`), and
//! prints the paper-comparable rows.

pub mod ablation;
pub mod bench_cmd;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fluid_exp;
pub mod quickstart;
pub mod run_cmd;
pub mod table1;

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::cli::Args;
use crate::configsys::{Policy, Scenario};
use crate::coordinator::{Cluster, RunOutcome, Transport};
use crate::runtime::{
    default_artifacts_dir, EngineFactory, Manifest, MockEngineFactory, MockWorld,
    XlaEngineFactory,
};

/// One-shot serving run through the session API (`Cluster::builder` →
/// `start` → `wait`) — the experiment harnesses' standard entry point.
/// Dispatches to the sharded pool automatically when the scenario asks
/// for multiple verifiers.
pub fn serve_once(
    scenario: Scenario,
    policy: Policy,
    transport: Transport,
    simulate_network: bool,
    factory: Arc<dyn EngineFactory>,
) -> Result<RunOutcome> {
    Cluster::builder(scenario)
        .policy(policy)
        .transport(transport)
        .simulate_network(simulate_network)
        .engine(factory)
        .start()?
        .wait()
}

/// Engine selection: `--engine xla|mock` (default: xla when artifacts are
/// present, mock otherwise).
pub fn engine_from_args(args: &Args) -> Result<Arc<dyn EngineFactory>> {
    let choice = args.get_or("engine", "auto");
    let artifacts = default_artifacts_dir();
    let have = artifacts.join("manifest.json").exists();
    match choice.as_str() {
        "xla" => {
            let manifest = Manifest::load(&artifacts)?;
            manifest.validate_files()?;
            Ok(Arc::new(XlaEngineFactory::new(manifest)))
        }
        "mock" => Ok(mock_engine()),
        "auto" => {
            if have {
                let manifest = Manifest::load(&artifacts)?;
                manifest.validate_files()?;
                Ok(Arc::new(XlaEngineFactory::new(manifest)))
            } else {
                log::warn!("artifacts missing; using mock engine");
                Ok(mock_engine())
            }
        }
        other => Err(anyhow!("unknown engine '{other}' (xla|mock|auto)")),
    }
}

/// The standard mock world used by tests/benches (vocab matches artifacts).
pub fn mock_engine() -> Arc<dyn EngineFactory> {
    Arc::new(MockEngineFactory::new(MockWorld {
        vocab: 256,
        max_seq: 256,
        sharpness: 3.0,
        seed: 7,
    }))
}

pub fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("run") => run_cmd::main(args),
        Some("quickstart") => quickstart::main(args),
        Some("fig2") => fig2::main(args),
        Some("fig3") => fig3::main(args),
        Some("fig4") => fig4::main(args),
        Some("table1") => table1::main(args),
        Some("fluid") => fluid_exp::main(args),
        Some("ablation") => ablation::main(args),
        Some("bench") => bench_cmd::main(args),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => Err(anyhow!("unknown subcommand '{other}' (try `goodspeed help`)")),
    }
}

fn print_help() {
    println!(
        "goodspeed — fair-goodput speculative-decoding coordinator (paper reproduction)

USAGE: goodspeed <command> [options]

COMMANDS
  run        one serving run        --scenario|--preset <id> --policy <p>
                                    --rounds <n> --transport channel|tcp
                                    --engine xla|mock --capacity <C>
                                    --clients <n> --no-network
                                    --mode sync|async --batch-window-us <µs>
                                    --min-wave-fill <n> --verifiers <m>
                                    --rebalance-every <waves> --churn
                                    --chaos (demo fault schedule: shard crash
                                    at rounds/3, recovery at rounds/2)
                                    --trace <file.json> --slo <waves>
                                    --arrival poisson:<gap>|bursty:<gap>x<burst>
                                    |flash-crowd:<gap>x<surge>@<at>+<width>
                                    |diurnal:<gap>x<amp>@<period>
                                    --pipelined (overlap assembly with verify;
                                    bit-identical output, off by default)
                                    --trace-out <file.json> (Chrome/Perfetto
                                    trace of wave spans + fault instants)
                                    --metrics-addr <ip:port> (live Prometheus
                                    endpoint) --metrics-linger-ms <ms>
                                    --postmortem <file> (flight-recorder dump
                                    target on shard death / SLO breach streak)
  quickstart single client speculative vs autoregressive speedup
  fig2       goodput estimation fidelity (paper Fig 2)   --out results
  fig3       wall-time decomposition   (paper Fig 3)     --out results
  fig4       utility convergence       (paper Fig 4)     --out results [--real]
  table1     Table I scenario matrix                     --out results
  fluid      fluid-limit / Theorem 1 validation          --out results
  ablation   eta/beta/C sweeps, greedy-vs-DP, buckets    --out results
  bench      perf recording (BENCH_<n>.json)             --quick --out <path>
                                                         --baseline <path> --iters <n>
                                                         --soak --max-rss-mb <MiB>

Scenario presets: qwen-4c-50, qwen-8c-150, llama-8c-150, smoke, straggler,
sharded, tree, churn, trace, soak, chaos.

Policies: goodspeed, fixed-s, random-s, turbo (SLO-aware closed-loop
speculation control; pair with a trace, e.g. `run --preset trace --policy
turbo`)."
    );
}
