//! `goodspeed quickstart` — single draft + target: speculative decoding vs
//! plain autoregressive decoding on one prompt, with the measured speedup
//! (the Leviathan et al. headline, and the paper's §II-A2 2–3× claim).

use anyhow::{anyhow, Result};
use std::time::Instant;

use super::engine_from_args;
use crate::cli::Args;
use crate::runtime::{EngineFactory, VerifyRequest};
use crate::spec::rejection::verify_client;
use crate::tokenizer;
use crate::util::Rng;

pub struct QuickstartReport {
    pub prompt: String,
    pub spec_text: String,
    pub auto_text: String,
    pub spec_secs: f64,
    pub auto_secs: f64,
    pub spec_rounds: usize,
    pub accepted_rate: f64,
    pub tokens: usize,
    /// Mean tokens emitted per verification round — μ(S, α) realized.
    pub tokens_per_round: f64,
    /// Per-token acceptance estimate α̂ from the verification ratios.
    pub alpha_hat: f64,
}

/// Generate `n_tokens` with speculative decoding (draft model + batched
/// verification) and with plain autoregressive target decoding; compare.
pub fn run_quickstart(
    factory: &dyn EngineFactory,
    family: &str,
    draft_model: &str,
    prompt_text: &str,
    n_tokens: usize,
    draft_len: usize,
    seed: u64,
) -> Result<QuickstartReport> {
    let vocab = factory.vocab();
    let k = factory.verify_k();
    let prompt = tokenizer::encode(prompt_text);
    if prompt.is_empty() {
        return Err(anyhow!("empty prompt"));
    }
    let mut rng = Rng::new(seed);

    // ---------------- speculative lane ----------------
    let t0 = Instant::now();
    let mut drafter = factory.make_drafter(draft_model)?;
    let mut verifier = factory.make_verifier(family)?;
    let mut prefix = prompt.clone();
    let mut dist = drafter.prefill(&prefix)?;
    let mut accepted_total = 0usize;
    let mut drafted_total = 0usize;
    let mut ratio_sum = 0.0f64;
    let mut rounds = 0usize;
    while prefix.len() - prompt.len() < n_tokens && prefix.len() + draft_len + 2 < factory.max_seq()
    {
        let s = draft_len.min(k);
        let pos0 = prefix.len();
        let mut draft = Vec::with_capacity(s);
        let mut q_probs = Vec::with_capacity(s * vocab);
        for j in 0..s {
            let tok = rng.categorical(&dist) as u8;
            q_probs.extend_from_slice(&dist);
            draft.push(tok);
            if j + 1 < s {
                dist = drafter.step(tok)?;
            }
        }
        // Batched verification (batch of 1).
        let buckets = verifier.buckets();
        let (_, bs) = crate::runtime::pick_bucket(&buckets, 1, pos0 + s.max(1));
        let mut tokens = vec![0i32; bs];
        for (i, &t) in prefix.iter().enumerate() {
            tokens[i] = t as i32;
        }
        for (j, &t) in draft.iter().enumerate() {
            tokens[pos0 + j] = t as i32;
        }
        let mut draft_tok = vec![0i32; k];
        let mut q_full = vec![0.0f32; k * vocab];
        for (j, &t) in draft.iter().enumerate() {
            draft_tok[j] = t as i32;
        }
        q_full[..s * vocab].copy_from_slice(&q_probs);
        let req = VerifyRequest {
            tokens,
            batch: 1,
            seq: bs,
            draft_tok,
            q_probs: q_full,
            pos0: vec![pos0 as i32],
            parent: crate::runtime::chain_parent_array(1, k),
            k,
            vocab,
        };
        let out = verifier.verify(&req)?;
        let ratios = &out.ratio_row(0, k)[..s];
        let resid = out.resid_rows(0, k, vocab);
        let bonus: &[f32] =
            if s == k { out.bonus_row(0, vocab) } else { &resid[s * vocab..(s + 1) * vocab] };
        let verdict = verify_client(ratios, resid, bonus, vocab, &mut rng);
        let m = verdict.accepted;
        accepted_total += m;
        drafted_total += s;
        ratio_sum += verdict.mean_ratio * s as f64;
        prefix.extend_from_slice(&draft[..m]);
        prefix.push(verdict.correction);
        // Reconcile drafter cache (same protocol as the draft server).
        if m == s && s > 0 {
            drafter.step(draft[s - 1])?;
        } else {
            drafter.rewind(pos0 + m);
        }
        dist = drafter.step(verdict.correction)?;
        rounds += 1;
    }
    let spec_secs = t0.elapsed().as_secs_f64();
    let spec_text = tokenizer::decode(&prefix[prompt.len()..]);
    let spec_tokens = prefix.len() - prompt.len();

    // ---------------- autoregressive lane ----------------
    let t1 = Instant::now();
    let mut target = factory.make_target_stepper(family)?;
    let mut auto_prefix = prompt.clone();
    let mut dist = target.prefill(&auto_prefix)?;
    while auto_prefix.len() - prompt.len() < spec_tokens
        && auto_prefix.len() + 2 < factory.max_seq()
    {
        let tok = rng.categorical(&dist) as u8;
        auto_prefix.push(tok);
        dist = target.step(tok)?;
    }
    let auto_secs = t1.elapsed().as_secs_f64();
    let auto_text = tokenizer::decode(&auto_prefix[prompt.len()..]);

    Ok(QuickstartReport {
        prompt: prompt_text.to_string(),
        spec_text,
        auto_text,
        spec_secs,
        auto_secs,
        spec_rounds: rounds,
        accepted_rate: if drafted_total == 0 {
            0.0
        } else {
            accepted_total as f64 / drafted_total as f64
        },
        tokens: spec_tokens,
        tokens_per_round: spec_tokens as f64 / rounds.max(1) as f64,
        alpha_hat: if drafted_total == 0 { 0.0 } else { ratio_sum / drafted_total as f64 },
    })
}

pub fn main(args: &Args) -> Result<()> {
    let family = args.get_or("family", "qwen");
    let draft = args.get_or(
        "draft",
        if family == "qwen" { "qwen-draft-06b" } else { "llama-draft-1b" },
    );
    let prompt = args.get_or("prompt", "### Instruction: describe the river. ### Response:");
    let n_tokens = args.get_parse::<usize>("tokens").unwrap_or(60);
    let draft_len = args.get_parse::<usize>("draft-len").unwrap_or(6);
    let factory = engine_from_args(args)?;
    args.finish().map_err(|e| anyhow!(e))?;

    let r = run_quickstart(factory.as_ref(), &family, &draft, &prompt, n_tokens, draft_len, 42)?;
    println!("prompt        : {}", r.prompt);
    println!("speculative   : {}", r.spec_text.trim_end());
    println!("autoregressive: {}", r.auto_text.trim_end());
    println!(
        "\n{} tokens | spec {:.3}s in {} rounds vs autoregressive {:.3}s",
        r.tokens, r.spec_secs, r.spec_rounds, r.auto_secs
    );
    println!(
        "per-token acceptance α̂ = {:.2}; tokens per verification round μ = {:.2}",
        r.alpha_hat, r.tokens_per_round
    );
    println!("wall-clock speedup (this 1-core CPU testbed): {:.2}×", r.auto_secs / r.spec_secs.max(1e-9));
    println!(
        "paper-hardware speedup model (verify ∥ ≈ one step, Leviathan eq.): {:.2}×",
        crate::spec::math::expected_speedup(r.alpha_hat, draft_len)
    );
    println!(
        "\nNote: a 1-core CPU serializes the verification forward, so the paper's\n\
         single-stream wall-clock speedup cannot physically appear here; the\n\
         multi-client batched-verification economics (Figs 2–4) do — see\n\
         EXPERIMENTS.md §Hardware-Adaptation."
    );
    Ok(())
}
