//! `goodspeed run` — one configurable serving run with a full report.

use anyhow::{anyhow, Result};

use super::engine_from_args;
use crate::cli::Args;
use crate::configsys::{Policy, Scenario};
use crate::coordinator::{run_pool, run_serving, RunConfig, Transport};
use crate::metrics::csv::write_rounds;

/// Regenerate the seeded links after a --clients/--seed override while
/// preserving any preset-specific link (the `straggler` preset's defining
/// 10× slow uplink on client 0 must survive CLI overrides).
fn regen_links(s: &mut Scenario) {
    let keep_slow = if s.id == "straggler" { s.links.first().cloned() } else { None };
    s.links = Scenario::default_links(s.num_clients, s.seed);
    if let (Some(slow), Some(slot)) = (keep_slow, s.links.first_mut()) {
        *slot = slow;
    }
}

/// Build a scenario from CLI overrides.
pub fn scenario_from_args(args: &Args) -> Result<Scenario> {
    let id = args.get_or("scenario", "qwen-8c-150");
    let mut s = Scenario::preset(&id)
        .ok_or_else(|| anyhow!("unknown scenario '{id}' ({:?})", Scenario::preset_ids()))?;
    if let Some(c) = args.get_parse::<usize>("capacity") {
        s.capacity = c;
    }
    if let Some(n) = args.get_parse::<usize>("clients") {
        s.num_clients = n;
        regen_links(&mut s);
    }
    if let Some(r) = args.get_parse::<u64>("rounds") {
        s.rounds = r;
    }
    if let Some(seed) = args.get_parse::<u64>("seed") {
        s.seed = seed;
        regen_links(&mut s);
    }
    if let Some(m) = args.get_parse::<usize>("max-new-tokens") {
        s.max_new_tokens = m;
    }
    if let Some(e) = args.get_parse::<f64>("eta") {
        s.eta = crate::configsys::Smoothing::Fixed(e);
    }
    if let Some(b) = args.get_parse::<f64>("beta") {
        s.beta = crate::configsys::Smoothing::Fixed(b);
    }
    if let Some(st) = args.get_parse::<f64>("stickiness") {
        s.domain_stickiness = st;
    }
    if let Some(m) = args.get("mode") {
        s.coord_mode = crate::configsys::CoordMode::parse(m)
            .ok_or_else(|| anyhow!("bad --mode (sync|async)"))?;
    }
    if let Some(w) = args.get_parse::<u64>("batch-window-us") {
        s.batch_window_us = w;
    }
    if let Some(f) = args.get_parse::<usize>("min-wave-fill") {
        s.min_wave_fill = f;
    }
    if let Some(m) = args.get_parse::<usize>("verifiers") {
        s.num_verifiers = m;
    }
    if let Some(k) = args.get_parse::<u64>("rebalance-every") {
        s.shard_rebalance_every = k;
    }
    if let Some(shape) = args.get("spec-shape") {
        s.spec_shape = crate::configsys::SpecShape::parse(shape)
            .ok_or_else(|| anyhow!("bad --spec-shape (chain|tree[:AxD]|adaptive)"))?;
    }
    s.validate().map_err(|e| anyhow!("scenario: {e}"))?;
    Ok(s)
}

pub fn main(args: &Args) -> Result<()> {
    let scenario = scenario_from_args(args)?;
    let policy = Policy::parse(&args.get_or("policy", "goodspeed"))
        .ok_or_else(|| anyhow!("bad --policy"))?;
    let transport = Transport::parse(&args.get_or("transport", "channel"))
        .ok_or_else(|| anyhow!("bad --transport"))?;
    let simulate_network = !args.flag("no-network");
    let out_dir = args.get_or("out", "results");
    let factory = engine_from_args(args)?;
    args.finish().map_err(|e| anyhow!(e))?;

    log::info!(
        "run: scenario={} policy={} mode={} shape={} verifiers={} transport={transport:?} rounds={}",
        scenario.id,
        policy.name(),
        scenario.coord_mode.name(),
        scenario.spec_shape.label(),
        scenario.num_verifiers,
        scenario.rounds
    );
    let cfg = RunConfig { scenario: scenario.clone(), policy, transport, simulate_network };
    let recorder = if scenario.num_verifiers > 1 {
        let out = run_pool(&cfg, factory)?;
        out.summary.print(&format!(
            "{} / {} / {} shards",
            scenario.id,
            policy.name(),
            scenario.num_verifiers
        ));
        // No per-shard Jain here: each shard's recorder spans the full
        // client universe, so its index would read ~|members|/n even under
        // perfect fairness. The merged summary above carries the real one.
        for (s, sum) in out.shard_summaries.iter().enumerate() {
            println!(
                "  shard {s}: waves {:>5}  tokens {:>8.0}",
                sum.rounds, sum.total_tokens
            );
        }
        println!("  client migrations: {}", out.migrations);
        out.recorder
    } else {
        let out = run_serving(&cfg, factory)?;
        out.summary.print(&format!("{} / {}", scenario.id, policy.name()));
        out.recorder
    };
    let path = format!("{out_dir}/run_{}_{}.csv", scenario.id, policy.name());
    write_rounds(&path, &recorder)?;
    println!("per-round CSV -> {path}");
    Ok(())
}
