//! `goodspeed run` — one configurable serving run with a full report.
//!
//! Runs through the session API ([`Cluster::builder`] →
//! [`ServingHandle`](crate::coordinator::ServingHandle)): static scenarios
//! behave exactly like the historic batch runner, while `--churn` (or the
//! `churn` preset) exercises dynamic membership — clients joining and
//! draining mid-run — and additionally writes the membership-epoch CSV.

use anyhow::{anyhow, Result};

use super::engine_from_args;
use crate::chaos::FaultSchedule;
use crate::cli::Args;
use crate::configsys::{ArrivalProcess, ChurnSchedule, Policy, Scenario, TraceConfig};
use crate::coordinator::{Cluster, Transport};
use crate::metrics::csv::{write_membership, write_requests, write_rounds, write_slo_summary};
use crate::obs::{write_trace, MetricsServer, ObsOptions};

/// Regenerate the seeded links after a --clients/--seed override while
/// preserving any preset-specific link (the `straggler` preset's defining
/// 10× slow uplink on client 0 must survive CLI overrides).
fn regen_links(s: &mut Scenario) {
    let keep_slow = if s.id == "straggler" { s.links.first().cloned() } else { None };
    s.links = Scenario::default_links(s.num_clients, s.seed);
    if let (Some(slow), Some(slot)) = (keep_slow, s.links.first_mut()) {
        *slot = slow;
    }
}

/// Build a scenario from CLI overrides.
pub fn scenario_from_args(args: &Args) -> Result<Scenario> {
    // `--preset` is an alias for `--scenario` (the serving docs say
    // "preset"); when both are given, `--scenario` wins.
    let preset = args.get("preset").map(str::to_string);
    let id = args.get("scenario").map(str::to_string).or(preset).unwrap_or_else(|| {
        "qwen-8c-150".to_string()
    });
    let mut s = Scenario::preset(&id)
        .ok_or_else(|| anyhow!("unknown scenario '{id}' ({:?})", Scenario::preset_ids()))?;
    if let Some(c) = args.get_parse::<usize>("capacity") {
        s.capacity = c;
    }
    if let Some(n) = args.get_parse::<usize>("clients") {
        s.num_clients = n;
        regen_links(&mut s);
    }
    if let Some(r) = args.get_parse::<u64>("rounds") {
        s.rounds = r;
    }
    if let Some(seed) = args.get_parse::<u64>("seed") {
        s.seed = seed;
        regen_links(&mut s);
    }
    if let Some(m) = args.get_parse::<usize>("max-new-tokens") {
        s.max_new_tokens = m;
    }
    if let Some(e) = args.get_parse::<f64>("eta") {
        s.eta = crate::configsys::Smoothing::Fixed(e);
    }
    if let Some(b) = args.get_parse::<f64>("beta") {
        s.beta = crate::configsys::Smoothing::Fixed(b);
    }
    if let Some(st) = args.get_parse::<f64>("stickiness") {
        s.domain_stickiness = st;
    }
    if let Some(m) = args.get("mode") {
        s.coord_mode = m.parse().map_err(|e| anyhow!("--mode: {e}"))?;
    }
    if let Some(w) = args.get_parse::<u64>("batch-window-us") {
        s.batch_window_us = w;
    }
    if let Some(f) = args.get_parse::<usize>("min-wave-fill") {
        s.min_wave_fill = f;
    }
    if let Some(m) = args.get_parse::<usize>("verifiers") {
        s.num_verifiers = m;
    }
    if let Some(k) = args.get_parse::<u64>("rebalance-every") {
        s.shard_rebalance_every = k;
    }
    if let Some(shape) = args.get("spec-shape") {
        s.spec_shape = shape.parse().map_err(|e| anyhow!("--spec-shape: {e}"))?;
    }
    if args.flag("pipelined") {
        s.pipelined = true;
    }
    // `--churn` layers the standard demo schedule (one join at rounds/3,
    // one departure at 2·rounds/3) onto whatever scenario was selected.
    if args.flag("churn") && s.churn.is_empty() {
        s.churn = ChurnSchedule::demo(&s);
    }
    // `--chaos` layers the standard fault schedule (the highest shard
    // crashes at rounds/3 and recovers at rounds/2) onto the selected
    // scenario. A shard crash needs a survivor, so single-verifier
    // scenarios are widened to a two-shard pool first.
    if args.flag("chaos") && s.chaos.is_empty() {
        if s.num_verifiers < 2 {
            log::warn!("--chaos: widening to 2 verifier shards (a crash needs a survivor)");
            s.num_verifiers = 2;
        }
        s.chaos = FaultSchedule::demo(&s);
    }
    // Request-level serving knobs: `--trace <file.json>` loads an
    // explicit schedule, `--arrival poisson:<gap>|bursty:<gap>x<burst>`
    // selects a generator, `--slo <waves>` sets the per-request deadline.
    // Any of them layers the trace preset's defaults onto scenarios that
    // have no trace config of their own.
    let trace_file = args.get("trace").map(str::to_string);
    let arrival = args.get("arrival").map(str::to_string);
    let slo = args.get_parse::<u64>("slo");
    if trace_file.is_some() && (arrival.is_some() || slo.is_some()) {
        return Err(anyhow!(
            "--trace is mutually exclusive with --arrival/--slo (a trace file carries \
             its own arrival schedule and per-request deadlines)"
        ));
    }
    if trace_file.is_some() || arrival.is_some() || slo.is_some() {
        let mut t = s.trace.take().unwrap_or_else(|| TraceConfig::poisson(28.0, 48));
        if let Some(a) = arrival {
            t.arrival = a.parse().map_err(|e| anyhow!("--arrival: {e}"))?;
        }
        if let Some(path) = trace_file {
            t.arrival = ArrivalProcess::File(path);
        }
        if let Some(w) = slo {
            t.slo_waves = w;
        }
        s.trace = Some(t);
    }
    s.validate().map_err(|e| anyhow!("scenario: {e}"))?;
    Ok(s)
}

pub fn main(args: &Args) -> Result<()> {
    let scenario = scenario_from_args(args)?;
    let policy: Policy =
        args.get_or("policy", "goodspeed").parse().map_err(|e| anyhow!("--policy: {e}"))?;
    let transport: Transport = args
        .get_or("transport", "channel")
        .parse()
        .map_err(|e| anyhow!("--transport: {e}"))?;
    let simulate_network = !args.flag("no-network");
    let out_dir = args.get_or("out", "results");
    // Observability (DESIGN.md §10): any one of the three flags attaches
    // the flight recorder; each output stays independently optional.
    let trace_out = args.get("trace-out").map(std::path::PathBuf::from);
    let metrics_addr = args.get("metrics-addr").map(str::to_string);
    let metrics_linger_ms = args.get_parse::<u64>("metrics-linger-ms");
    let postmortem = args.get("postmortem").map(std::path::PathBuf::from);
    let factory = engine_from_args(args)?;
    args.finish().map_err(|e| anyhow!(e))?;

    log::info!(
        "run: scenario={} policy={} mode={} shape={} verifiers={} transport={transport:?} \
         rounds={} churn-events={} chaos-events={} trace={}",
        scenario.id,
        policy.name(),
        scenario.coord_mode.name(),
        scenario.spec_shape.label(),
        scenario.num_verifiers,
        scenario.rounds,
        scenario.churn.events.len(),
        scenario.chaos.events.len(),
        scenario.trace.as_ref().map(|t| t.arrival.label()).unwrap_or_else(|| "none".into())
    );
    let churned = !scenario.churn.is_empty();
    let mut builder = Cluster::builder(scenario.clone())
        .policy(policy)
        .transport(transport)
        .simulate_network(simulate_network)
        .engine(factory);
    if trace_out.is_some() || metrics_addr.is_some() || postmortem.is_some() {
        builder = builder.observability(ObsOptions {
            postmortem: postmortem.clone(),
            ring_capacity: 0,
        });
    }
    let handle = builder.start()?;
    let hub = handle.observer();
    let mut metrics_server = match (&metrics_addr, &hub) {
        (Some(addr), Some(hub)) => {
            let srv = MetricsServer::start(addr, std::sync::Arc::clone(hub))?;
            println!("metrics endpoint -> http://{}/metrics", srv.local_addr());
            Some(srv)
        }
        _ => None,
    };
    let out = handle.wait()?;
    if let (Some(path), Some(hub)) = (&trace_out, &hub) {
        write_trace(path, hub)?;
        println!("chrome trace -> {} (load in ui.perfetto.dev)", path.display());
    }

    if let Some(pool) = &out.pool {
        out.summary.print(&format!(
            "{} / {} / {} shards",
            scenario.id,
            policy.name(),
            scenario.num_verifiers
        ));
        // No per-shard Jain here: each shard's recorder spans the full
        // client universe, so its index would read ~|members|/n even under
        // perfect fairness. The merged summary above carries the real one.
        for (s, sum) in pool.shard_summaries.iter().enumerate() {
            println!(
                "  shard {s}: waves {:>5}  tokens {:>8.0}",
                sum.rounds, sum.total_tokens
            );
        }
        println!("  client migrations: {}", pool.migrations);
    } else {
        out.summary.print(&format!("{} / {}", scenario.id, policy.name()));
    }
    if churned {
        println!("  membership epochs: {}", out.recorder.membership.len());
        for ev in &out.recorder.membership {
            let joined: Vec<String> =
                ev.joined.iter().map(|(id, g)| format!("+{id}(S0={g})")).collect();
            let left: Vec<String> = ev.left.iter().map(|id| format!("-{id}")).collect();
            println!(
                "    wave {:>5} epoch {:>3}: {} members={:?}",
                ev.wave,
                ev.epoch,
                joined.iter().chain(left.iter()).cloned().collect::<Vec<_>>().join(" "),
                ev.members
            );
        }
    }
    // Chaos runs: the fault/recovery event log and the waves each
    // crashed shard took to rejoin.
    if !out.recorder.faults.is_empty() {
        println!("  fault events: {}", out.recorder.faults.len());
        for f in &out.recorder.faults {
            println!(
                "    wave {:>5} shard {}: {:<15} {}",
                f.wave, f.shard, f.kind, f.detail
            );
        }
        if !out.recorder.time_to_recover.is_empty() {
            let ttr: Vec<String> =
                out.recorder.time_to_recover.iter().map(u64::to_string).collect();
            println!("  time-to-recover (waves): {}", ttr.join(", "));
        }
    }
    // Trace-driven runs: the request-level report — TTFT/TPOT/E2E
    // percentiles, SLO attainment, and the SLO-goodput series next to
    // the raw one.
    if let Some(slo) = out.recorder.slo_summary() {
        println!(
            "  requests: {} completed, {} expired, {} censored   SLO attainment {:.1}%",
            slo.completed,
            slo.expired,
            slo.censored,
            100.0 * slo.attainment
        );
        println!(
            "  ttft p50/p95/p99 {:.1}/{:.1}/{:.1}  tpot {:.2}/{:.2}/{:.2}  \
             e2e {:.1}/{:.1}/{:.1} waves",
            slo.ttft.0,
            slo.ttft.1,
            slo.ttft.2,
            slo.tpot.0,
            slo.tpot.1,
            slo.tpot.2,
            slo.e2e.0,
            slo.e2e.1,
            slo.e2e.2
        );
        let raw: f64 = out.recorder.cum_goodput().iter().sum();
        println!(
            "  goodput: raw {raw:.0} tokens, SLO {:.0} tokens ({:.1}% within deadline)",
            slo.slo_goodput_total,
            100.0 * slo.slo_goodput_total / raw.max(1e-12)
        );
    }
    let path = format!("{out_dir}/run_{}_{}.csv", scenario.id, policy.name());
    write_rounds(&path, &out.recorder)?;
    println!("per-round CSV -> {path}");
    if churned {
        let mpath = format!("{out_dir}/run_{}_{}_membership.csv", scenario.id, policy.name());
        write_membership(&mpath, &out.recorder)?;
        println!("membership CSV -> {mpath}");
    }
    if out.recorder.has_requests() {
        let rpath = format!("{out_dir}/run_{}_{}_requests.csv", scenario.id, policy.name());
        write_requests(&rpath, &out.recorder)?;
        println!("per-request CSV -> {rpath}");
        let spath = format!("{out_dir}/run_{}_{}_slo.csv", scenario.id, policy.name());
        write_slo_summary(&spath, &out.recorder)?;
        println!("SLO summary CSV -> {spath}");
    }
    if let Some(srv) = &mut metrics_server {
        // Hold the endpoint open past the run's end so one final scrape
        // (CI smoke, a lagging Prometheus cycle) reads the completed
        // registry instead of racing the shutdown.
        if let Some(ms) = metrics_linger_ms {
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
        srv.stop();
    }
    Ok(())
}
