//! Table I — the scenario matrix: every configuration row run end-to-end,
//! reporting goodput / throughput / fairness per (row, C-variant, policy).

use anyhow::{anyhow, Result};

use super::engine_from_args;
use crate::cli::Args;
use crate::configsys::{Policy, Scenario};
use crate::coordinator::Transport;
use crate::metrics::csv::write_csv;

pub struct Table1Row {
    pub scenario: String,
    pub capacity: usize,
    pub policy: &'static str,
    pub goodput_per_round: f64,
    pub tokens_per_sec: f64,
    pub jain: f64,
    pub mean_latency_rounds: f64,
}

/// The (preset, C variants) grid exactly as Table I lists it.
pub fn grid() -> Vec<(&'static str, Vec<usize>)> {
    vec![
        ("qwen-4c-50", vec![24, 28]),
        ("qwen-8c-150", vec![16, 20]),
        ("llama-8c-150", vec![16, 20]),
    ]
}

pub fn main(args: &Args) -> Result<()> {
    let out_dir = args.get_or("out", "results");
    let rounds = args.get_parse::<u64>("rounds").unwrap_or(150);
    let policies = args.get_or("policies", "goodspeed,fixed-s");
    let factory = engine_from_args(args)?;
    args.finish().map_err(|e| anyhow!(e))?;

    let mut rows = Vec::new();
    for (preset, capacities) in grid() {
        for &c in &capacities {
            for policy in Policy::all() {
                if !policies.contains(policy.name()) {
                    continue;
                }
                let mut s = Scenario::preset(preset).unwrap();
                s.capacity = c;
                s.rounds = rounds;
                log::info!("table1: {preset} C={c} {}", policy.name());
                let out = super::serve_once(
                    s,
                    policy,
                    Transport::Channel,
                    false,
                    factory.clone(),
                )?;
                rows.push(Table1Row {
                    scenario: preset.to_string(),
                    capacity: c,
                    policy: policy.name(),
                    goodput_per_round: out.summary.total_tokens / out.summary.rounds as f64,
                    tokens_per_sec: out.summary.tokens_per_sec,
                    jain: out.summary.jain,
                    mean_latency_rounds: out.summary.mean_request_latency_rounds,
                });
            }
        }
    }
    let csv_path = format!("{out_dir}/table1_scenarios.csv");
    write_csv(
        &csv_path,
        &["scenario", "C", "policy", "goodput_per_round", "tokens_per_sec", "jain", "latency_rounds"],
        rows.iter().map(|r| {
            vec![
                r.scenario.clone(),
                r.capacity.to_string(),
                r.policy.to_string(),
                format!("{:.3}", r.goodput_per_round),
                format!("{:.1}", r.tokens_per_sec),
                format!("{:.4}", r.jain),
                format!("{:.2}", r.mean_latency_rounds),
            ]
        }),
    )?;
    println!("\nTable I scenario matrix ({rounds} rounds each):");
    println!(
        "{:<13} {:>3} {:<10} {:>9} {:>9} {:>7} {:>9}",
        "scenario", "C", "policy", "tok/round", "tok/s", "jain", "lat(rnds)"
    );
    for r in &rows {
        println!(
            "{:<13} {:>3} {:<10} {:>9.2} {:>9.1} {:>7.4} {:>9.2}",
            r.scenario, r.capacity, r.policy, r.goodput_per_round, r.tokens_per_sec, r.jain,
            r.mean_latency_rounds
        );
    }
    println!("csv -> {csv_path}");
    Ok(())
}
