//! # GoodSpeed
//!
//! Reproduction of *"GoodSpeed: Optimizing Fair Goodput with Adaptive
//! Speculative Decoding in Distributed Edge Inference"* (CS.DC 2025) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the serving coordinator: draft-server
//!   actors, verification server with sync-barrier *and* async
//!   event-driven wave batching (straggler-tolerant continuous
//!   verification), chain *and* tree speculation (`spec::DraftTree`:
//!   node budgets arranged as branching candidate trees, lossless
//!   sequential-sibling rejection sampling), smoothed estimators
//!   (paper eqs. 3–4), and the gradient scheduler (GOODSPEED-SCHED,
//!   eq. 5) with Fixed-S / Random-S baselines. The public serving API
//!   is session-oriented ([`coordinator::Cluster::builder`] →
//!   [`coordinator::ServingHandle`]): a long-lived cluster that edge
//!   draft servers join and leave dynamically, with epoch-stamped
//!   membership applied at wave boundaries. On top, [`serve`] layers
//!   request-level serving — trace-driven arrivals, per-request
//!   TTFT/TPOT/E2E and SLO accounting, and the SLO-goodput series the
//!   closed-loop speculation controller ([`sched::controller`],
//!   `policy=turbo`) optimizes.
//! * **Layer 2** — `python/compile/model.py`: the tiny-transformer model
//!   zoo AOT-lowered to HLO text at build time.
//! * **Layer 1** — `python/compile/kernels/`: Pallas flash-attention and
//!   fused verification kernels inside those graphs.
//!
//! Python never runs at serving time: `runtime::XlaEngine` loads the HLO
//! artifacts via PJRT (CPU) and executes them from the Rust hot path.
//!
//! See `DESIGN.md` (repo root) for the system inventory, the sync/async
//! wave lifecycle, and the experiment index.

// Perf instrumentation: count heap allocations per thread so the bench
// harness and the allocation-free-wave tests can assert on them. Only
// bench/test builds opt in (`--features alloc_track`); the default build
// keeps the plain system allocator.
#[cfg(feature = "alloc_track")]
#[global_allocator]
static ALLOC_COUNTER: util::alloc_track::CountingAlloc = util::alloc_track::CountingAlloc;

pub mod chaos;
pub mod cli;
pub mod configsys;
pub mod coordinator;
pub mod draft;
pub mod error;
pub mod experiments;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod simulate;
pub mod spec;
pub mod tokenizer;
pub mod util;
pub mod workload;
