//! GoodSpeed launcher — see `goodspeed help`.

use goodspeed::experiments;

fn main() {
    goodspeed::util::logger::init();
    let args = goodspeed::cli::Args::parse_env();
    if let Err(e) = experiments::dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
