//! CSV export for figure regeneration (`results/*.csv`).

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use super::recorder::Recorder;

/// Escape a CSV field into `out` (we only emit simple fields, but be
/// correct anyway). Appends in place so the per-row writer can reuse one
/// line buffer instead of allocating a `String` per field.
fn esc_into(out: &mut String, s: &str) {
    if s.contains([',', '"', '\n']) {
        out.push('"');
        for c in s.chars() {
            if c == '"' {
                out.push('"');
            }
            out.push(c);
        }
        out.push('"');
    } else {
        out.push_str(s);
    }
}

/// Escape one CSV field (allocating form of [`esc_into`]; the parity
/// tests compare it against the in-place writer).
#[cfg(test)]
fn esc(s: &str) -> String {
    let mut out = String::new();
    esc_into(&mut out, s);
    out
}

/// Generic writer: header + row iterator. Fields are streamed through one
/// recycled line buffer — the emitted bytes are pinned by the CSV parity
/// tests, so this stays byte-identical to the old collect+join writer.
pub fn write_csv<P: AsRef<Path>>(
    path: P,
    header: &[&str],
    rows: impl IntoIterator<Item = Vec<String>>,
) -> Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir).ok();
    }
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path.as_ref())
            .with_context(|| format!("create {:?}", path.as_ref()))?,
    );
    let mut line = String::with_capacity(256);
    for (i, h) in header.iter().enumerate() {
        if i > 0 {
            line.push(',');
        }
        esc_into(&mut line, h);
    }
    writeln!(f, "{line}")?;
    for row in rows {
        line.clear();
        for (i, c) in row.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            esc_into(&mut line, c);
        }
        writeln!(f, "{line}")?;
    }
    Ok(())
}

/// Full per-round, per-client dump of a run.
pub fn write_rounds<P: AsRef<Path>>(path: P, rec: &Recorder) -> Result<()> {
    let header = [
        "round", "client", "s_used", "accepted", "goodput", "mean_ratio", "alpha_hat", "x_beta",
        "next_alloc", "recv_ns", "verify_ns", "send_ns", "shard", "spec_depth", "node_accept",
    ];
    let rows = rec.rounds.iter().flat_map(|r| {
        r.clients.iter().map(move |c| {
            // Per-node acceptance: accepted path depth over nodes spent —
            // distinguishes shape efficiency from budget size.
            let node_accept =
                if c.s_used == 0 { 0.0 } else { c.accepted as f64 / c.s_used as f64 };
            vec![
                r.round.to_string(),
                c.client_id.to_string(),
                c.s_used.to_string(),
                c.accepted.to_string(),
                c.goodput.to_string(),
                format!("{:.6}", c.mean_ratio),
                format!("{:.6}", c.alpha_hat),
                format!("{:.6}", c.x_beta),
                c.next_alloc.to_string(),
                r.recv_ns.to_string(),
                r.verify_ns.to_string(),
                r.send_ns.to_string(),
                r.shard.to_string(),
                c.spec_depth.to_string(),
                format!("{node_accept:.6}"),
            ]
        })
    });
    write_csv(path, &header, rows)
}

/// Membership-epoch dump of a churn run: one row per epoch change, with
/// the joined/left ids and the resulting member set (`|`-separated).
/// Written alongside the per-round CSV only when the run actually churned,
/// so static runs keep producing the exact same file set.
pub fn write_membership<P: AsRef<Path>>(path: P, rec: &Recorder) -> Result<()> {
    let header = ["wave", "epoch", "joined", "left", "members", "lifetime_goodput"];
    let lifetime = rec.lifetime_goodput();
    let rows = rec.membership.iter().map(|ev| {
        let joined: Vec<String> =
            ev.joined.iter().map(|(id, grant)| format!("{id}:{grant}")).collect();
        let left: Vec<String> = ev.left.iter().map(|id| id.to_string()).collect();
        let members: Vec<String> = ev.members.iter().map(|id| id.to_string()).collect();
        let lg: Vec<String> =
            ev.members.iter().map(|&id| format!("{:.1}", lifetime[id])).collect();
        vec![
            ev.wave.to_string(),
            ev.epoch.to_string(),
            joined.join("|"),
            left.join("|"),
            members.join("|"),
            lg.join("|"),
        ]
    });
    write_csv(path, &header, rows)
}

/// Per-request dump of a trace-driven run: one row per finished/expired
/// request with its lifecycle timestamps (waves), TTFT/TPOT/E2E, and SLO
/// outcome. Written only when the run carried a trace, so request-free
/// runs keep producing the exact same file set.
pub fn write_requests<P: AsRef<Path>>(path: P, rec: &Recorder) -> Result<()> {
    let header = [
        "client", "arrival", "first_token", "completion", "tokens", "slo", "completed", "met",
        "ttft", "tpot", "e2e",
    ];
    let rows = rec.requests.iter().map(|r| {
        vec![
            r.client.to_string(),
            r.arrival.to_string(),
            r.first_token.map(|w| w.to_string()).unwrap_or_default(),
            r.completion.to_string(),
            r.tokens.to_string(),
            r.slo_waves.to_string(),
            (r.completed as u8).to_string(),
            (r.met as u8).to_string(),
            format!("{:.3}", r.ttft_waves()),
            format!("{:.3}", r.tpot_waves()),
            format!("{:.3}", r.e2e_waves()),
        ]
    });
    write_csv(path, &header, rows)
}

/// One-row SLO report of a trace-driven run: request counts, attainment,
/// the p50/p95/p99 latency columns, and both goodput series (raw and
/// SLO) so the deadline cost is visible in one place.
pub fn write_slo_summary<P: AsRef<Path>>(path: P, rec: &Recorder) -> Result<()> {
    let header = [
        "completed", "expired", "censored", "attainment", "ttft_p50", "ttft_p95", "ttft_p99",
        "tpot_p50", "tpot_p95", "tpot_p99", "e2e_p50", "e2e_p95", "e2e_p99", "raw_goodput",
        "slo_goodput", "lost_handoffs",
    ];
    let s = rec.slo_summary().unwrap_or_default();
    let raw: f64 = rec.cum_goodput().iter().sum();
    let row = vec![
        s.completed.to_string(),
        s.expired.to_string(),
        s.censored.to_string(),
        format!("{:.4}", s.attainment),
        format!("{:.3}", s.ttft.0),
        format!("{:.3}", s.ttft.1),
        format!("{:.3}", s.ttft.2),
        format!("{:.3}", s.tpot.0),
        format!("{:.3}", s.tpot.1),
        format!("{:.3}", s.tpot.2),
        format!("{:.3}", s.e2e.0),
        format!("{:.3}", s.e2e.1),
        format!("{:.3}", s.e2e.2),
        format!("{raw:.1}"),
        format!("{:.1}", s.slo_goodput_total),
        rec.handoffs_lost.to_string(),
    ];
    write_csv(path, &header, [row])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::recorder::{ClientRoundMetrics, MembershipEvent, RoundRecord};
    use crate::serve::RequestRecord;

    #[test]
    fn escapes_fields() {
        assert_eq!(esc("plain"), "plain");
        assert_eq!(esc("a,b"), "\"a,b\"");
        assert_eq!(esc("say \"hi\""), "\"say \"\"hi\"\"\"");
    }

    #[test]
    fn writes_rounds_csv() {
        let dir = std::env::temp_dir().join("goodspeed_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rounds.csv");
        let mut rec = Recorder::new(2);
        rec.push(RoundRecord {
            round: 0,
            shard: 0,
            recv_ns: 10,
            verify_ns: 20,
            send_ns: 1,
            clients: vec![
                ClientRoundMetrics { client_id: 0, goodput: 2, ..Default::default() },
                ClientRoundMetrics { client_id: 1, goodput: 3, ..Default::default() },
            ],
        });
        write_rounds(&path, &rec).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3); // header + 2 clients
        assert!(lines[0].starts_with("round,client"));
        assert!(lines[1].starts_with("0,0,"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn writes_request_and_slo_csvs() {
        let dir = std::env::temp_dir().join("goodspeed_requests_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut rec = Recorder::new(1);
        rec.requests.push(RequestRecord {
            client: 0,
            arrival: 2,
            first_token: Some(2),
            completion: 5,
            tokens: 8,
            slo_waves: 10,
            completed: true,
            met: true,
        });
        rec.requests.push(RequestRecord {
            client: 0,
            arrival: 7,
            first_token: None,
            completion: 9,
            tokens: 0,
            slo_waves: 2,
            completed: false,
            met: false,
        });
        rec.slo_goodput = vec![8.0];
        let rpath = dir.join("requests.csv");
        write_requests(&rpath, &rec).unwrap();
        let text = std::fs::read_to_string(&rpath).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert_eq!(
            lines[0],
            "client,arrival,first_token,completion,tokens,slo,completed,met,ttft,tpot,e2e"
        );
        assert!(lines[1].starts_with("0,2,2,5,8,10,1,1,"), "{}", lines[1]);
        // Never-served requests leave first_token empty.
        assert!(lines[2].starts_with("0,7,,9,0,2,0,0,"), "{}", lines[2]);

        let spath = dir.join("slo.csv");
        write_slo_summary(&spath, &rec).unwrap();
        let text = std::fs::read_to_string(&spath).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("completed,expired,censored,attainment,ttft_p50"));
        assert!(lines[0].ends_with("raw_goodput,slo_goodput,lost_handoffs"));
        assert!(lines[1].starts_with("1,1,0,0.5000,"), "{}", lines[1]);
        assert!(lines[1].ends_with(",8.0,0"), "{}", lines[1]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn writes_membership_csv() {
        let dir = std::env::temp_dir().join("goodspeed_membership_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("membership.csv");
        let mut rec = Recorder::new(3);
        rec.push(RoundRecord {
            round: 0,
            shard: 0,
            recv_ns: 0,
            verify_ns: 0,
            send_ns: 0,
            clients: vec![ClientRoundMetrics {
                client_id: 2,
                goodput: 5,
                ..Default::default()
            }],
        });
        rec.note_membership(MembershipEvent {
            wave: 4,
            epoch: 1,
            joined: vec![(2, 3)],
            left: vec![0],
            members: vec![1, 2],
        });
        write_membership(&path, &rec).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[0], "wave,epoch,joined,left,members,lifetime_goodput");
        assert_eq!(lines[1], "4,1,2:3,0,1|2,0.0|5.0");
        std::fs::remove_dir_all(&dir).ok();
    }
}
