//! Metrics: per-round records, CSV export, SVG charts, report tables.

pub mod csv;
pub mod recorder;
pub mod svg;

pub use recorder::{ClientRoundMetrics, MembershipEvent, Recorder, RoundRecord, RunSummary};
