//! Metrics: per-round records, CSV export, SVG charts, report tables.

pub mod csv;
pub mod recorder;
pub mod sketch;
pub mod svg;

pub use recorder::{
    ClientRoundMetrics, FaultRecord, MembershipEvent, Recorder, RoundRecord, RunSummary,
};
pub use sketch::{RequestSketch, Reservoir};
