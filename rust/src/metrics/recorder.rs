//! Per-round experiment records — everything Figs 2–4 and Table I need.

use super::sketch::{RequestSketch, Reservoir};
use crate::sched::utility::{system_utility, Utility};
use crate::serve::tracker::{summarize_requests, RequestRecord, SloSummary};
use crate::util::stats::{jain_index, p50_p95_p99};

/// One client's slice of one wave (a sync round is a wave of everyone).
#[derive(Clone, Debug, Default)]
pub struct ClientRoundMetrics {
    /// Which client this row belongs to. Waves carry arbitrary client
    /// subsets, so the position inside `RoundRecord::clients` is *not* the
    /// client id (it is in sync mode, where every wave is dense).
    pub client_id: usize,
    /// Draft length actually used this round.
    pub s_used: usize,
    /// Accepted draft tokens m.
    pub accepted: usize,
    /// Realized goodput x_i(t) = m + 1.
    pub goodput: usize,
    /// Mean acceptance ratio (eq. 3 empirical term; per *node* for trees).
    pub mean_ratio: f64,
    /// Depth of the drafted topology (== `s_used` for a chain). With
    /// trees, `accepted ≤ spec_depth ≤ s_used`: the accepted-depth /
    /// node-budget split the shape plots need.
    pub spec_depth: usize,
    /// Estimates α̂_i(t), X_i^β(t) *after* the round's update.
    pub alpha_hat: f64,
    pub x_beta: f64,
    /// Allocation for the next round.
    pub next_alloc: usize,
}

/// One coordinator wave (sync mode: one wave per round, all clients).
#[derive(Clone, Debug, Default)]
pub struct RoundRecord {
    /// Wave index (== the round number in sync mode). Per-shard counter in
    /// pooled runs.
    pub round: u64,
    /// Verification shard that processed this wave (0 outside pooled
    /// mode).
    pub shard: usize,
    /// Wall-time decomposition (paper Fig 3): waiting for draft batches,
    /// verification (+ scheduling), sending verdicts. These are the
    /// *measured* phase times threaded in by the coordinator.
    pub recv_ns: u64,
    pub verify_ns: u64,
    pub send_ns: u64,
    /// Participating clients only, ascending by `client_id`.
    pub clients: Vec<ClientRoundMetrics>,
}

impl RoundRecord {
    pub fn total_goodput(&self) -> usize {
        self.clients.iter().map(|c| c.goodput).sum()
    }

    pub fn total_ns(&self) -> u64 {
        self.recv_ns + self.verify_ns + self.send_ns
    }
}

/// One membership epoch change: who joined/left at which wave boundary,
/// and the resulting member set. Emitted by the serving cluster (and its
/// analytic counterpart) whenever the epoch advances; static-membership
/// runs record nothing, keeping their outputs byte-identical.
#[derive(Clone, Debug, Default)]
pub struct MembershipEvent {
    /// Wave boundary at which the change took effect (the first wave
    /// formed under the new membership).
    pub wave: u64,
    /// Epoch counter after the change (starts at 0 with the initial set).
    pub epoch: u64,
    /// Admitted clients with their initial grants S_i(0).
    pub joined: Vec<(usize, usize)>,
    /// Retired clients (graceful drain complete).
    pub left: Vec<usize>,
    /// The member set after the change, ascending.
    pub members: Vec<usize>,
}

/// One fault-injection or recovery action, stamped with the schedule's
/// wave clock (pooled runs: global waves ÷ M). Recorded by the pool
/// driver / analytic simulator as the chaos schedule fires, plus
/// run-end accounting events (e.g. `handoff-lost`). Chaos-free runs
/// record nothing, keeping their outputs byte-identical.
#[derive(Clone, Debug, Default)]
pub struct FaultRecord {
    /// Wave boundary (schedule clock) at which the event took effect.
    pub wave: u64,
    /// The shard the event concerns (crashes/recoveries), or the shard
    /// doing the accounting for client-scoped events.
    pub shard: usize,
    /// Stable machine-readable tag: `shard-crash`, `shard-recover`,
    /// `partition`, `partition-heal`, `drop-burst`, `duplicate-burst`,
    /// `shard-abandoned`, `fault-skipped`, `handoff-lost`.
    pub kind: String,
    /// Human-readable context (client lists, factors, reasons).
    pub detail: String,
}

/// Accumulates waves and derives the report quantities.
#[derive(Debug, Default)]
pub struct Recorder {
    pub rounds: Vec<RoundRecord>,
    /// Per-epoch membership changes (empty on static runs).
    pub membership: Vec<MembershipEvent>,
    /// Per-request latency in rounds, as requests complete.
    pub request_latency_rounds: Vec<u64>,
    /// Trace-driven runs: per-request lifecycle records (TTFT/TPOT/E2E,
    /// SLO attainment) from the request tracker. Empty on request-free
    /// runs, whose outputs stay byte-identical.
    pub requests: Vec<RequestRecord>,
    /// Trace-driven runs: per-client Σ tokens of deadline-met requests —
    /// the SLO-goodput series alongside the paper's raw goodput. Empty
    /// (not zero-filled) on request-free runs.
    pub slo_goodput: Vec<f64>,
    /// Requests still pending with future deadlines when the run ended
    /// (excluded from attainment).
    pub requests_censored: u64,
    /// Migration handoff states nobody claimed by run end (their requests
    /// are censored, and each loss is also logged as a `handoff-lost`
    /// fault record plus a membership event). Zero on clean runs.
    pub handoffs_lost: u64,
    /// Fault/recovery event log (empty without a chaos schedule).
    pub faults: Vec<FaultRecord>,
    /// Time-to-recover series: for each recovered shard crash, the
    /// schedule-clock waves between the crash taking effect and the
    /// shard's re-admission.
    pub time_to_recover: Vec<u64>,
    /// Cumulative realized goodput per client (for x̄(T) and Fig 4).
    cum_goodput: Vec<f64>,
    /// Cumulative *accepted* draft tokens per client (fairness audits).
    /// For trees this is the accepted root-path depth.
    cum_accepted: Vec<u64>,
    /// Cumulative drafted-topology depth per client (== s_used on chains).
    cum_spec_depth: Vec<u64>,
    /// Cumulative nodes spent per client (the budget actually consumed).
    cum_nodes: Vec<u64>,
    /// Number of waves each client participated in (== rounds in sync).
    participation: Vec<u64>,
    /// Streaming-aggregation mode: wave records are folded into the
    /// cumulative counters and the wave-latency reservoir instead of
    /// being retained, so a soak run's memory is O(clients) + O(sketch)
    /// no matter how many waves it serves. Retained (default) mode keeps
    /// `rounds` byte-identical to before this mode existed.
    streaming: bool,
    /// Streaming mode holds the newest wave for one step before folding
    /// it, so the coordinator's post-fan-out patch points
    /// ([`Recorder::note_send_ns`] / [`Recorder::note_verify_extra_ns`])
    /// still land on it; retained mode patches `rounds.last_mut()`.
    pending: Option<RoundRecord>,
    /// Waves already folded (streaming mode; retained mode counts
    /// `rounds.len()`).
    s_waves: u64,
    /// Folded wall-time decomposition sums (streaming mode).
    s_recv_ns: u64,
    s_verify_ns: u64,
    s_send_ns: u64,
    /// Reservoir over per-wave `total_ns` — the p50/p99 wave-latency
    /// source once records are no longer retained.
    wave_ns: Reservoir,
    /// Streaming request aggregation (the bounded counterpart of
    /// `requests`), installed by trackers running in streaming mode.
    pub request_sketch: Option<RequestSketch>,
}

impl Recorder {
    pub fn new(n_clients: usize) -> Self {
        Recorder {
            rounds: Vec::new(),
            membership: Vec::new(),
            request_latency_rounds: Vec::new(),
            requests: Vec::new(),
            slo_goodput: Vec::new(),
            requests_censored: 0,
            handoffs_lost: 0,
            faults: Vec::new(),
            time_to_recover: Vec::new(),
            cum_goodput: vec![0.0; n_clients],
            cum_accepted: vec![0; n_clients],
            cum_spec_depth: vec![0; n_clients],
            cum_nodes: vec![0; n_clients],
            participation: vec![0; n_clients],
            streaming: false,
            pending: None,
            s_waves: 0,
            s_recv_ns: 0,
            s_verify_ns: 0,
            s_send_ns: 0,
            wave_ns: Reservoir::default(),
            request_sketch: None,
        }
    }

    /// A streaming-aggregation recorder: O(clients) memory regardless of
    /// run length. `rounds` stays empty — waves fold into the cumulative
    /// counters and a wave-latency reservoir as they retire. Consumers
    /// that iterate `rounds` (per-round CSVs, charts) see nothing; the
    /// summary/report accessors are mode-agnostic.
    pub fn new_streaming(n_clients: usize) -> Self {
        let mut r = Recorder::new(n_clients);
        r.streaming = true;
        r
    }

    /// Whether this recorder folds waves instead of retaining them.
    pub fn is_streaming(&self) -> bool {
        self.streaming
    }

    /// Flip an existing recorder into streaming mode in place (the pool
    /// path: the shard's `Leader` builds its recorder before the scenario's
    /// metrics mode is consulted). Already-retained waves fold into the
    /// streaming counters — their cumulative accounting happened at push
    /// time, so only the wave-level sums and the latency reservoir move.
    pub fn stream(&mut self) {
        if self.streaming {
            return;
        }
        self.streaming = true;
        for rec in std::mem::take(&mut self.rounds) {
            self.fold(&rec);
        }
    }

    /// Cumulative per-client accounting, shared by both modes.
    fn account(&mut self, rec: &RoundRecord) {
        for c in &rec.clients {
            let i = c.client_id;
            assert!(i < self.cum_goodput.len(), "client_id {i} out of range");
            self.cum_goodput[i] += c.goodput as f64;
            self.cum_accepted[i] += c.accepted as u64;
            self.cum_spec_depth[i] += c.spec_depth as u64;
            self.cum_nodes[i] += c.s_used as u64;
            self.participation[i] += 1;
        }
    }

    /// Retire a held wave into the streaming counters.
    fn fold(&mut self, rec: &RoundRecord) {
        self.s_waves += 1;
        self.s_recv_ns += rec.recv_ns;
        self.s_verify_ns += rec.verify_ns;
        self.s_send_ns += rec.send_ns;
        self.wave_ns.push(rec.total_ns() as f64);
    }

    pub fn push(&mut self, rec: RoundRecord) {
        let _ = self.push_reuse(rec);
    }

    /// Record a wave. Retained mode keeps it (returns `None`); streaming
    /// mode folds the *previous* wave into the counters and hands its
    /// drained shell (`clients` cleared, capacity intact) back to the
    /// caller for reuse — the allocation-free wave loop feeds each shell
    /// back in, so warm waves allocate nothing in either mode.
    pub fn push_reuse(&mut self, rec: RoundRecord) -> Option<RoundRecord> {
        self.account(&rec);
        if !self.streaming {
            self.rounds.push(rec);
            return None;
        }
        let mut shell = self.pending.take();
        if let Some(prev) = shell.as_mut() {
            self.fold(&*prev);
            prev.clients.clear();
        }
        self.pending = Some(rec);
        shell
    }

    /// Streaming mode: fold the held wave (no more patch points are
    /// coming). Idempotent; retained mode is a no-op. Called at
    /// end-of-run and before merging shard recorders.
    pub fn flush(&mut self) {
        if let Some(rec) = self.pending.take() {
            self.fold(&rec);
        }
    }

    /// Patch the send-phase time onto the most recently recorded wave —
    /// the coordinator only knows it after the verdict fan-out.
    pub fn note_send_ns(&mut self, send_ns: u64) {
        if let Some(rec) = self.pending.as_mut() {
            rec.send_ns = send_ns;
        } else if let Some(rec) = self.rounds.last_mut() {
            rec.send_ns = send_ns;
        }
    }

    /// Add post-allocation scheduling time to the most recent wave's
    /// verify phase (measured after the record was pushed).
    pub fn note_verify_extra_ns(&mut self, extra_ns: u64) {
        if let Some(rec) = self.pending.as_mut() {
            rec.verify_ns += extra_ns;
        } else if let Some(rec) = self.rounds.last_mut() {
            rec.verify_ns += extra_ns;
        }
    }

    /// The most recently recorded wave's identity and phase timings —
    /// `(round, shard, recv_ns, verify_ns, send_ns)` — read from the
    /// held wave (streaming) or the last retained record. This is the
    /// flight recorder's wave-span source: the coordinator calls it
    /// right after [`Recorder::note_send_ns`], when all three phases
    /// are in place. Borrows only — no allocation, no state change.
    pub fn last_wave_phases(&self) -> Option<(u64, usize, u64, u64, u64)> {
        self.pending
            .as_ref()
            .or_else(|| self.rounds.last())
            .map(|r| (r.round, r.shard, r.recv_ns, r.verify_ns, r.send_ns))
    }

    /// Waves recorded so far: retained + folded + held.
    pub fn waves(&self) -> u64 {
        self.rounds.len() as u64 + self.s_waves + self.pending.is_some() as u64
    }

    /// (p50, p95, p99) of per-wave total latency, ns. Streaming mode
    /// reads the reservoir (flush first for the final wave); retained
    /// mode computes it exactly from the records.
    pub fn wave_ns_percentiles(&self) -> (f64, f64, f64) {
        if self.streaming {
            self.wave_ns.triple()
        } else {
            let xs: Vec<f64> = self.rounds.iter().map(|r| r.total_ns() as f64).collect();
            p50_p95_p99(&xs)
        }
    }

    /// Fold another recorder (same client universe) into this one — used
    /// to merge per-shard recorders into the pool-wide view. Cumulative
    /// per-client accounting adds elementwise (each shard derived its own
    /// from its records), retained waves concatenate, and streaming
    /// counters/sketches merge — so retained and streaming shards can mix.
    pub fn absorb(&mut self, mut other: Recorder) {
        assert_eq!(
            self.cum_goodput.len(),
            other.cum_goodput.len(),
            "recorders must share the client universe"
        );
        self.flush();
        other.flush();
        self.rounds.reserve(other.rounds.len());
        self.rounds.extend(other.rounds);
        for (a, b) in self.cum_goodput.iter_mut().zip(&other.cum_goodput) {
            *a += b;
        }
        for (a, b) in self.cum_accepted.iter_mut().zip(&other.cum_accepted) {
            *a += b;
        }
        for (a, b) in self.cum_spec_depth.iter_mut().zip(&other.cum_spec_depth) {
            *a += b;
        }
        for (a, b) in self.cum_nodes.iter_mut().zip(&other.cum_nodes) {
            *a += b;
        }
        for (a, b) in self.participation.iter_mut().zip(&other.participation) {
            *a += b;
        }
        self.s_waves += other.s_waves;
        self.s_recv_ns += other.s_recv_ns;
        self.s_verify_ns += other.s_verify_ns;
        self.s_send_ns += other.s_send_ns;
        self.wave_ns.merge(&other.wave_ns);
        match (&mut self.request_sketch, other.request_sketch) {
            (Some(a), Some(b)) => a.merge(&b),
            (slot @ None, Some(b)) => *slot = Some(b),
            _ => {}
        }
        self.membership.extend(other.membership);
        self.request_latency_rounds.extend(other.request_latency_rounds);
        self.requests.extend(other.requests);
        self.requests_censored += other.requests_censored;
        self.handoffs_lost += other.handoffs_lost;
        self.faults.extend(other.faults);
        self.time_to_recover.extend(other.time_to_recover);
        if self.slo_goodput.is_empty() {
            self.slo_goodput = other.slo_goodput;
        } else if !other.slo_goodput.is_empty() {
            for (a, b) in self.slo_goodput.iter_mut().zip(&other.slo_goodput) {
                *a += b;
            }
        }
    }

    /// Record a membership epoch change (serving clusters with churn).
    pub fn note_membership(&mut self, ev: MembershipEvent) {
        self.membership.push(ev);
    }

    /// Record a fault-injection / recovery event (chaos runs only).
    pub fn note_fault(&mut self, ev: FaultRecord) {
        self.faults.push(ev);
    }

    /// Per-client lifetime goodput: total realized tokens over the
    /// client's whole session (identical to [`Recorder::cum_goodput`];
    /// named for the churn reports, where departed clients keep their
    /// archived totals).
    pub fn lifetime_goodput(&self) -> &[f64] {
        &self.cum_goodput
    }

    pub fn n_clients(&self) -> usize {
        self.cum_goodput.len()
    }

    pub fn cum_goodput(&self) -> &[f64] {
        &self.cum_goodput
    }

    pub fn cum_accepted(&self) -> &[u64] {
        &self.cum_accepted
    }

    pub fn participation(&self) -> &[u64] {
        &self.participation
    }

    /// Empirical average goodput per *participated* wave,
    /// x̄_i(T) = (1/T_i) Σ_t x_i(t). In sync mode T_i == T for everyone, so
    /// this is exactly the paper's x̄(T); in async mode it is the per-wave
    /// goodput rate the log-utility scheduler equalizes.
    pub fn avg_goodput(&self) -> Vec<f64> {
        self.cum_goodput
            .iter()
            .zip(&self.participation)
            .map(|(&g, &t)| if t == 0 { 0.0 } else { g / t as f64 })
            .collect()
    }

    /// Average accepted draft tokens per participated wave (the fairness
    /// quantity for Jain-index audits across coordinator modes). For
    /// trees this is the mean accepted root-path *depth*.
    pub fn avg_accepted(&self) -> Vec<f64> {
        self.cum_accepted
            .iter()
            .zip(&self.participation)
            .map(|(&a, &t)| if t == 0 { 0.0 } else { a as f64 / t as f64 })
            .collect()
    }

    /// Average drafted-topology depth per participated wave (== the mean
    /// draft length on chains; the shape axis of the tree plots).
    pub fn avg_spec_depth(&self) -> Vec<f64> {
        self.cum_spec_depth
            .iter()
            .zip(&self.participation)
            .map(|(&d, &t)| if t == 0 { 0.0 } else { d as f64 / t as f64 })
            .collect()
    }

    /// Mean realized goodput per delivered verdict (tokens/verdict) — the
    /// budget-normalized steady-state figure shape and mode comparisons
    /// use (equal node budgets ⇒ directly comparable).
    pub fn goodput_per_verdict(&self) -> f64 {
        let verdicts: u64 = self.participation.iter().sum();
        if verdicts == 0 {
            0.0
        } else {
            self.cum_goodput.iter().sum::<f64>() / verdicts as f64
        }
    }

    /// Per-node acceptance: accepted path length over nodes spent — the
    /// budget-efficiency of a shape (1.0 means every verified node landed
    /// on the accepted path).
    pub fn node_acceptance(&self) -> Vec<f64> {
        self.cum_accepted
            .iter()
            .zip(&self.cum_nodes)
            .map(|(&a, &n)| if n == 0 { 0.0 } else { a as f64 / n as f64 })
            .collect()
    }

    /// U(x̄(T)) — the Fig 4 curve evaluated at the current T.
    pub fn utility_of_avg(&self, u: &dyn Utility) -> f64 {
        system_utility(u, &self.avg_goodput())
    }

    /// Whether this run carried a request trace (request-level series
    /// present).
    pub fn has_requests(&self) -> bool {
        !self.requests.is_empty() || !self.slo_goodput.is_empty() || self.request_sketch.is_some()
    }

    /// Trace-driven runs: the p50/p95/p99 TTFT/TPOT/E2E + attainment
    /// report row over the run's request records. `None` on request-free
    /// runs. Streaming runs answer from the request sketch (no retained
    /// records); if both exist (mixed-mode shard merge), the retained
    /// records win only when the sketch is absent.
    pub fn slo_summary(&self) -> Option<SloSummary> {
        if let Some(sk) = &self.request_sketch {
            return Some(sk.summary(self.requests_censored));
        }
        self.has_requests().then(|| summarize_requests(&self.requests, self.requests_censored))
    }

    /// Per-client SLO-goodput per participated wave — the deadline-aware
    /// counterpart of [`Recorder::avg_goodput`] (tokens of requests that
    /// missed their deadline count 0). Empty on request-free runs.
    pub fn avg_slo_goodput(&self) -> Vec<f64> {
        self.slo_goodput
            .iter()
            .zip(&self.participation)
            .map(|(&g, &t)| if t == 0 { 0.0 } else { g / t as f64 })
            .collect()
    }

    pub fn summary(&self, wall_secs: f64) -> RunSummary {
        let avg = self.avg_goodput();
        let total_tokens: f64 = self.cum_goodput.iter().sum();
        let mean_latency = if self.request_latency_rounds.is_empty() {
            0.0
        } else {
            self.request_latency_rounds.iter().sum::<u64>() as f64
                / self.request_latency_rounds.len() as f64
        };
        // Phase sums start from the streaming-folded counters (0 in
        // retained mode), then add retained and still-held waves.
        let (mut recv, mut verify, mut send) =
            (self.s_recv_ns, self.s_verify_ns, self.s_send_ns);
        for r in self.rounds.iter().chain(self.pending.as_ref()) {
            recv += r.recv_ns;
            verify += r.verify_ns;
            send += r.send_ns;
        }
        let jain = jain_index(&avg);
        RunSummary {
            rounds: self.waves(),
            per_client_goodput: avg,
            total_tokens,
            tokens_per_sec: if wall_secs > 0.0 { total_tokens / wall_secs } else { 0.0 },
            jain,
            mean_request_latency_rounds: mean_latency,
            requests_completed: self.request_latency_rounds.len() as u64,
            recv_secs: recv as f64 * 1e-9,
            verify_secs: verify as f64 * 1e-9,
            send_secs: send as f64 * 1e-9,
            wall_secs,
        }
    }
}

/// End-of-run report row (Table I scenarios, Fig 3 decomposition…).
#[derive(Clone, Debug, Default)]
pub struct RunSummary {
    pub rounds: u64,
    pub per_client_goodput: Vec<f64>,
    pub total_tokens: f64,
    pub tokens_per_sec: f64,
    pub jain: f64,
    pub mean_request_latency_rounds: f64,
    pub requests_completed: u64,
    pub recv_secs: f64,
    pub verify_secs: f64,
    pub send_secs: f64,
    pub wall_secs: f64,
}

impl RunSummary {
    pub fn print(&self, label: &str) {
        println!("== {label} ==");
        println!(
            "rounds {:>5}  tokens {:>8.0}  throughput {:>8.1} tok/s  jain {:.4}",
            self.rounds, self.total_tokens, self.tokens_per_sec, self.jain
        );
        println!(
            "requests {:>4}  mean latency {:.2} rounds  wall {:.2}s (recv {:.2} / verify {:.2} / send {:.4})",
            self.requests_completed,
            self.mean_request_latency_rounds,
            self.wall_secs,
            self.recv_secs,
            self.verify_secs,
            self.send_secs
        );
        let gp: Vec<String> =
            self.per_client_goodput.iter().map(|g| format!("{g:.2}")).collect();
        println!("per-client goodput [{}]", gp.join(", "));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::utility::LogUtility;

    fn round(goodputs: &[usize]) -> RoundRecord {
        RoundRecord {
            round: 0,
            shard: 0,
            recv_ns: 1000,
            verify_ns: 2000,
            send_ns: 10,
            clients: goodputs
                .iter()
                .enumerate()
                .map(|(i, &g)| ClientRoundMetrics {
                    client_id: i,
                    goodput: g,
                    accepted: g.saturating_sub(1),
                    ..Default::default()
                })
                .collect(),
        }
    }

    /// A wave touching only the given (client, goodput) pairs.
    fn wave(pairs: &[(usize, usize)]) -> RoundRecord {
        RoundRecord {
            round: 0,
            shard: 0,
            recv_ns: 10,
            verify_ns: 20,
            send_ns: 1,
            clients: pairs
                .iter()
                .map(|&(id, g)| ClientRoundMetrics {
                    client_id: id,
                    goodput: g,
                    accepted: g.saturating_sub(1),
                    ..Default::default()
                })
                .collect(),
        }
    }

    #[test]
    fn averages_accumulate() {
        let mut r = Recorder::new(2);
        r.push(round(&[2, 4]));
        r.push(round(&[4, 4]));
        assert_eq!(r.avg_goodput(), vec![3.0, 4.0]);
        let u = r.utility_of_avg(&LogUtility);
        assert!((u - (3.0f64.ln() + 4.0f64.ln())).abs() < 1e-12);
    }

    #[test]
    fn summary_decomposition_sums() {
        let mut r = Recorder::new(1);
        r.push(round(&[3]));
        r.push(round(&[5]));
        r.request_latency_rounds.push(4);
        let s = r.summary(2.0);
        assert_eq!(s.rounds, 2);
        assert!((s.total_tokens - 8.0).abs() < 1e-12);
        assert!((s.tokens_per_sec - 4.0).abs() < 1e-12);
        assert!((s.recv_secs - 2e-6).abs() < 1e-15);
        assert_eq!(s.requests_completed, 1);
        assert!((s.mean_request_latency_rounds - 4.0).abs() < 1e-12);
    }

    #[test]
    fn round_record_totals() {
        let r = round(&[1, 2, 3]);
        assert_eq!(r.total_goodput(), 6);
        assert_eq!(r.total_ns(), 3010);
    }

    #[test]
    fn partial_waves_average_per_participation() {
        let mut r = Recorder::new(3);
        r.push(wave(&[(0, 4), (1, 2)]));
        r.push(wave(&[(0, 6)]));
        r.push(wave(&[(2, 3)]));
        assert_eq!(r.participation(), &[2, 1, 1]);
        assert_eq!(r.avg_goodput(), vec![5.0, 2.0, 3.0]);
        assert_eq!(r.cum_goodput(), &[10.0, 2.0, 3.0]);
        assert_eq!(r.cum_accepted(), &[8, 1, 2]);
        assert_eq!(r.avg_accepted(), vec![4.0, 1.0, 2.0]);
        let s = r.summary(1.0);
        assert_eq!(s.rounds, 3); // 3 waves
        assert!((s.total_tokens - 15.0).abs() < 1e-12);
    }

    #[test]
    fn shape_metrics_accumulate() {
        let mut r = Recorder::new(1);
        let rec = |s_used: usize, accepted: usize, spec_depth: usize| RoundRecord {
            round: 0,
            shard: 0,
            recv_ns: 0,
            verify_ns: 0,
            send_ns: 0,
            clients: vec![ClientRoundMetrics {
                client_id: 0,
                s_used,
                accepted,
                goodput: accepted + 1,
                spec_depth,
                ..Default::default()
            }],
        };
        r.push(rec(6, 2, 3));
        r.push(rec(6, 4, 3));
        assert_eq!(r.avg_spec_depth(), vec![3.0]);
        // 6 accepted over 12 nodes spent.
        assert_eq!(r.node_acceptance(), vec![0.5]);
    }

    #[test]
    fn membership_events_accumulate_and_absorb() {
        let mut a = Recorder::new(3);
        a.note_membership(MembershipEvent {
            wave: 5,
            epoch: 1,
            joined: vec![(2, 4)],
            left: vec![],
            members: vec![0, 1, 2],
        });
        let mut b = Recorder::new(3);
        b.note_membership(MembershipEvent {
            wave: 9,
            epoch: 2,
            joined: vec![],
            left: vec![0],
            members: vec![1, 2],
        });
        a.absorb(b);
        assert_eq!(a.membership.len(), 2);
        assert_eq!(a.membership[0].joined, vec![(2, 4)]);
        assert_eq!(a.membership[1].left, vec![0]);
        // Lifetime goodput is the cumulative view.
        a.push(wave(&[(1, 3)]));
        assert_eq!(a.lifetime_goodput(), &[0.0, 3.0, 0.0]);
    }

    #[test]
    fn absorb_merges_shard_recorders() {
        let mut a = Recorder::new(3);
        a.push(wave(&[(0, 4), (1, 2)]));
        a.request_latency_rounds.push(3);
        let mut b = Recorder::new(3);
        b.push(wave(&[(2, 5)]));
        b.push(wave(&[(2, 3)]));
        b.request_latency_rounds.push(7);
        a.absorb(b);
        assert_eq!(a.rounds.len(), 3);
        assert_eq!(a.participation(), &[1, 1, 2]);
        assert_eq!(a.cum_goodput(), &[4.0, 2.0, 8.0]);
        assert_eq!(a.request_latency_rounds, vec![3, 7]);
    }

    #[test]
    fn request_series_absorb_and_summarize() {
        let mut a = Recorder::new(2);
        assert!(!a.has_requests() && a.slo_summary().is_none());
        a.requests.push(RequestRecord {
            client: 0,
            arrival: 0,
            first_token: Some(1),
            completion: 3,
            tokens: 8,
            slo_waves: 10,
            completed: true,
            met: true,
        });
        a.slo_goodput = vec![8.0, 0.0];
        let mut b = Recorder::new(2);
        b.requests.push(RequestRecord {
            client: 1,
            arrival: 2,
            first_token: None,
            completion: 9,
            tokens: 3,
            slo_waves: 5,
            completed: false,
            met: false,
        });
        b.slo_goodput = vec![0.0, 0.0];
        b.requests_censored = 1;
        a.absorb(b);
        assert_eq!(a.requests.len(), 2);
        assert_eq!(a.slo_goodput, vec![8.0, 0.0]);
        let s = a.slo_summary().unwrap();
        assert_eq!((s.completed, s.expired, s.censored), (1, 1, 1));
        assert!((s.attainment - 0.5).abs() < 1e-12);
        assert!((s.slo_goodput_total - 8.0).abs() < 1e-12);
        // Per-wave normalization uses participation, like avg_goodput.
        a.push(wave(&[(0, 4), (1, 2)]));
        a.push(wave(&[(0, 4)]));
        assert_eq!(a.avg_slo_goodput(), vec![4.0, 0.0]);
    }

    #[test]
    fn streaming_mode_matches_retained_aggregates() {
        // Drive twin recorders through the same waves (with the post-push
        // patch points, like the coordinator does) and compare every
        // mode-agnostic report quantity.
        let mut ret = Recorder::new(2);
        let mut st = Recorder::new_streaming(2);
        for w in 0..6u64 {
            let mut rec = round(&[2 + (w % 3) as usize, 4]);
            rec.round = w;
            ret.push(rec.clone());
            st.push(rec);
            ret.note_verify_extra_ns(5);
            st.note_verify_extra_ns(5);
            ret.note_send_ns(40 + w);
            st.note_send_ns(40 + w);
        }
        st.flush();
        assert!(st.is_streaming() && st.rounds.is_empty(), "streaming retains nothing");
        assert_eq!(ret.rounds.len(), 6);
        assert_eq!(st.waves(), 6);
        assert_eq!(st.avg_goodput(), ret.avg_goodput());
        assert_eq!(st.participation(), ret.participation());
        let (a, b) = (ret.summary(2.0), st.summary(2.0));
        assert_eq!(a.rounds, b.rounds);
        assert!((a.total_tokens - b.total_tokens).abs() < 1e-12);
        assert!((a.recv_secs - b.recv_secs).abs() < 1e-15);
        assert!((a.verify_secs - b.verify_secs).abs() < 1e-15);
        assert!((a.send_secs - b.send_secs).abs() < 1e-15);
        assert!((a.jain - b.jain).abs() < 1e-12);
        // Wave-latency percentiles: exact in both modes below reservoir
        // capacity.
        assert_eq!(ret.wave_ns_percentiles(), st.wave_ns_percentiles());
    }

    #[test]
    fn streaming_push_reuse_hands_back_drained_shells() {
        let mut st = Recorder::new_streaming(2);
        // Retained mode never returns a shell.
        let mut ret = Recorder::new(2);
        assert!(ret.push_reuse(round(&[1, 1])).is_none());
        // Streaming: first push holds the wave (no shell yet); the second
        // returns the first wave's drained shell with capacity intact.
        assert!(st.push_reuse(round(&[2, 4])).is_none());
        assert_eq!(st.waves(), 1, "held wave counts");
        let shell = st.push_reuse(round(&[3, 3])).expect("previous shell");
        assert!(shell.clients.is_empty());
        assert!(shell.clients.capacity() >= 2, "shell keeps its allocation");
        st.flush();
        st.flush(); // idempotent
        assert_eq!(st.waves(), 2);
        assert_eq!(st.avg_goodput(), vec![2.5, 3.5]);
    }

    #[test]
    fn absorb_merges_streaming_shards() {
        // Two streaming shard recorders over disjoint client slices merge
        // into the same aggregates a retained merge would produce.
        let mut a = Recorder::new_streaming(3);
        a.push(wave(&[(0, 4), (1, 2)]));
        a.note_send_ns(7);
        let mut b = Recorder::new_streaming(3);
        b.push(wave(&[(2, 5)]));
        b.push(wave(&[(2, 3)]));
        a.absorb(b);
        assert_eq!(a.waves(), 3);
        assert!(a.rounds.is_empty());
        assert_eq!(a.participation(), &[1, 1, 2]);
        assert_eq!(a.cum_goodput(), &[4.0, 2.0, 8.0]);
        // The pre-absorb note_send_ns patch landed on the held wave.
        let s = a.summary(1.0);
        assert_eq!(s.rounds, 3);
        assert!((s.send_secs - (7.0 + 1.0 + 1.0) * 1e-9).abs() < 1e-18);
    }

    #[test]
    fn streaming_request_sketch_feeds_the_slo_summary() {
        let mut r = Recorder::new_streaming(1);
        let mut sk = crate::metrics::sketch::RequestSketch::new();
        sk.push(&RequestRecord {
            client: 0,
            arrival: 0,
            first_token: Some(1),
            completion: 3,
            tokens: 8,
            slo_waves: 10,
            completed: true,
            met: true,
        });
        r.request_sketch = Some(sk);
        r.requests_censored = 2;
        assert!(r.has_requests());
        let s = r.slo_summary().expect("sketch-backed summary");
        assert_eq!((s.completed, s.expired, s.censored), (1, 0, 2));
        assert!((s.slo_goodput_total - 8.0).abs() < 1e-12);
    }

    #[test]
    fn last_wave_phases_reads_both_modes() {
        let mut ret = Recorder::new(2);
        assert_eq!(ret.last_wave_phases(), None);
        let mut rec = round(&[2, 4]);
        rec.round = 9;
        rec.shard = 1;
        ret.push(rec.clone());
        ret.note_send_ns(77);
        assert_eq!(ret.last_wave_phases(), Some((9, 1, 1000, 2000, 77)));
        // Streaming mode reads the held wave, which the patch points
        // still target.
        let mut st = Recorder::new_streaming(2);
        st.push(rec);
        st.note_send_ns(88);
        assert_eq!(st.last_wave_phases(), Some((9, 1, 1000, 2000, 88)));
    }

    #[test]
    fn wave_accounting_matches_dense_rounds_in_sync_shape() {
        // Dense waves (sync mode) must reproduce the old per-round math.
        let mut r = Recorder::new(2);
        r.push(round(&[2, 4]));
        r.push(round(&[4, 4]));
        assert_eq!(r.participation(), &[2, 2]);
        assert_eq!(r.avg_goodput(), vec![3.0, 4.0]);
    }
}
