//! Per-round experiment records — everything Figs 2–4 and Table I need.

use crate::sched::utility::{system_utility, Utility};
use crate::util::stats::jain_index;

/// One client's slice of one round.
#[derive(Clone, Debug, Default)]
pub struct ClientRoundMetrics {
    /// Draft length actually used this round.
    pub s_used: usize,
    /// Accepted draft tokens m.
    pub accepted: usize,
    /// Realized goodput x_i(t) = m + 1.
    pub goodput: usize,
    /// Mean acceptance ratio (eq. 3 empirical term).
    pub mean_ratio: f64,
    /// Estimates α̂_i(t), X_i^β(t) *after* the round's update.
    pub alpha_hat: f64,
    pub x_beta: f64,
    /// Allocation for the next round.
    pub next_alloc: usize,
}

/// One coordinator round.
#[derive(Clone, Debug, Default)]
pub struct RoundRecord {
    pub round: u64,
    /// Wall-time decomposition (paper Fig 3): waiting for draft batches,
    /// verification (+ scheduling), sending verdicts.
    pub recv_ns: u64,
    pub verify_ns: u64,
    pub send_ns: u64,
    pub clients: Vec<ClientRoundMetrics>,
}

impl RoundRecord {
    pub fn total_goodput(&self) -> usize {
        self.clients.iter().map(|c| c.goodput).sum()
    }

    pub fn total_ns(&self) -> u64 {
        self.recv_ns + self.verify_ns + self.send_ns
    }
}

/// Accumulates rounds and derives the report quantities.
#[derive(Debug, Default)]
pub struct Recorder {
    pub rounds: Vec<RoundRecord>,
    /// Per-request latency in rounds, as requests complete.
    pub request_latency_rounds: Vec<u64>,
    /// Cumulative realized goodput per client (for x̄(T) and Fig 4).
    cum_goodput: Vec<f64>,
}

impl Recorder {
    pub fn new(n_clients: usize) -> Self {
        Recorder {
            rounds: Vec::new(),
            request_latency_rounds: Vec::new(),
            cum_goodput: vec![0.0; n_clients],
        }
    }

    pub fn push(&mut self, rec: RoundRecord) {
        for (i, c) in rec.clients.iter().enumerate() {
            self.cum_goodput[i] += c.goodput as f64;
        }
        self.rounds.push(rec);
    }

    pub fn n_clients(&self) -> usize {
        self.cum_goodput.len()
    }

    /// Empirical average goodput x̄_i(T) = (1/T) Σ_t x_i(t).
    pub fn avg_goodput(&self) -> Vec<f64> {
        let t = self.rounds.len().max(1) as f64;
        self.cum_goodput.iter().map(|&g| g / t).collect()
    }

    /// U(x̄(T)) — the Fig 4 curve evaluated at the current T.
    pub fn utility_of_avg(&self, u: &dyn Utility) -> f64 {
        system_utility(u, &self.avg_goodput())
    }

    pub fn summary(&self, wall_secs: f64) -> RunSummary {
        let t = self.rounds.len();
        let avg = self.avg_goodput();
        let total_tokens: f64 = self.cum_goodput.iter().sum();
        let mean_latency = if self.request_latency_rounds.is_empty() {
            0.0
        } else {
            self.request_latency_rounds.iter().sum::<u64>() as f64
                / self.request_latency_rounds.len() as f64
        };
        let (mut recv, mut verify, mut send) = (0u64, 0u64, 0u64);
        for r in &self.rounds {
            recv += r.recv_ns;
            verify += r.verify_ns;
            send += r.send_ns;
        }
        RunSummary {
            rounds: t as u64,
            per_client_goodput: avg.clone(),
            total_tokens,
            tokens_per_sec: if wall_secs > 0.0 { total_tokens / wall_secs } else { 0.0 },
            jain: jain_index(&avg),
            mean_request_latency_rounds: mean_latency,
            requests_completed: self.request_latency_rounds.len() as u64,
            recv_secs: recv as f64 * 1e-9,
            verify_secs: verify as f64 * 1e-9,
            send_secs: send as f64 * 1e-9,
            wall_secs,
        }
    }
}

/// End-of-run report row (Table I scenarios, Fig 3 decomposition…).
#[derive(Clone, Debug, Default)]
pub struct RunSummary {
    pub rounds: u64,
    pub per_client_goodput: Vec<f64>,
    pub total_tokens: f64,
    pub tokens_per_sec: f64,
    pub jain: f64,
    pub mean_request_latency_rounds: f64,
    pub requests_completed: u64,
    pub recv_secs: f64,
    pub verify_secs: f64,
    pub send_secs: f64,
    pub wall_secs: f64,
}

impl RunSummary {
    pub fn print(&self, label: &str) {
        println!("== {label} ==");
        println!(
            "rounds {:>5}  tokens {:>8.0}  throughput {:>8.1} tok/s  jain {:.4}",
            self.rounds, self.total_tokens, self.tokens_per_sec, self.jain
        );
        println!(
            "requests {:>4}  mean latency {:.2} rounds  wall {:.2}s (recv {:.2} / verify {:.2} / send {:.4})",
            self.requests_completed,
            self.mean_request_latency_rounds,
            self.wall_secs,
            self.recv_secs,
            self.verify_secs,
            self.send_secs
        );
        let gp: Vec<String> =
            self.per_client_goodput.iter().map(|g| format!("{g:.2}")).collect();
        println!("per-client goodput [{}]", gp.join(", "));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::utility::LogUtility;

    fn round(goodputs: &[usize]) -> RoundRecord {
        RoundRecord {
            round: 0,
            recv_ns: 1000,
            verify_ns: 2000,
            send_ns: 10,
            clients: goodputs
                .iter()
                .map(|&g| ClientRoundMetrics { goodput: g, ..Default::default() })
                .collect(),
        }
    }

    #[test]
    fn averages_accumulate() {
        let mut r = Recorder::new(2);
        r.push(round(&[2, 4]));
        r.push(round(&[4, 4]));
        assert_eq!(r.avg_goodput(), vec![3.0, 4.0]);
        let u = r.utility_of_avg(&LogUtility);
        assert!((u - (3.0f64.ln() + 4.0f64.ln())).abs() < 1e-12);
    }

    #[test]
    fn summary_decomposition_sums() {
        let mut r = Recorder::new(1);
        r.push(round(&[3]));
        r.push(round(&[5]));
        r.request_latency_rounds.push(4);
        let s = r.summary(2.0);
        assert_eq!(s.rounds, 2);
        assert!((s.total_tokens - 8.0).abs() < 1e-12);
        assert!((s.tokens_per_sec - 4.0).abs() < 1e-12);
        assert!((s.recv_secs - 2e-6).abs() < 1e-15);
        assert_eq!(s.requests_completed, 1);
        assert!((s.mean_request_latency_rounds - 4.0).abs() < 1e-12);
    }

    #[test]
    fn round_record_totals() {
        let r = round(&[1, 2, 3]);
        assert_eq!(r.total_goodput(), 6);
        assert_eq!(r.total_ns(), 3010);
    }
}
