//! Bounded-memory streaming sketches for soak-scale runs.
//!
//! The retained recorder keeps every [`RoundRecord`] and
//! [`RequestRecord`](crate::serve::tracker::RequestRecord) — O(waves) and
//! O(requests) memory, which is exactly what a 10k-session soak cannot
//! afford. This module holds the bounded replacements: a deterministic
//! [`Reservoir`] sample (Algorithm R over a seeded [`Rng`]) for percentile
//! estimates, and a [`RequestSketch`] that folds request lifecycles into
//! counters plus TTFT/TPOT/E2E reservoirs so the SLO report row survives
//! without the record vector. Both are O(1) per observation and O(cap)
//! resident.
//!
//! [`RoundRecord`]: crate::metrics::recorder::RoundRecord

use crate::serve::tracker::{RequestRecord, SloSummary};
use crate::util::stats::p50_p95_p99;
use crate::util::Rng;

/// Default reservoir capacity. 4096 doubles give percentile estimates
/// with worst-case p99 standard error well under 1% at soak scale while
/// keeping each sketch at 32 KiB.
pub const RESERVOIR_CAP: usize = 4096;

/// Uniform reservoir sample (Vitter's Algorithm R) with a deterministic
/// seeded stream: two runs over the same observation sequence produce the
/// same sample, so sketched percentiles are reproducible run to run.
///
/// While fewer than `cap` values have been seen the sample is the exact
/// population ([`Reservoir::is_exact`]); beyond that, percentiles are
/// unbiased estimates. [`Reservoir::merge`] is the standard approximate
/// proportional subsample (draws with replacement weighted by each side's
/// population size) — good enough for report rows, documentedly not an
/// exact distributed reservoir.
#[derive(Clone, Debug)]
pub struct Reservoir {
    cap: usize,
    seen: u64,
    sum: f64,
    samples: Vec<f64>,
    rng: Rng,
}

impl Default for Reservoir {
    fn default() -> Self {
        Reservoir::new(RESERVOIR_CAP)
    }
}

impl Reservoir {
    /// An empty reservoir holding at most `cap` samples. The replacement
    /// stream is seeded by a fixed constant: determinism over entropy.
    pub fn new(cap: usize) -> Reservoir {
        assert!(cap > 0, "reservoir needs room for at least one sample");
        Reservoir { cap, seen: 0, sum: 0.0, samples: Vec::new(), rng: Rng::new(0x5EE7_C0DE) }
    }

    pub fn push(&mut self, x: f64) {
        self.seen += 1;
        self.sum += x;
        if self.samples.len() < self.cap {
            self.samples.push(x);
        } else {
            // Algorithm R: the i-th value replaces a resident sample with
            // probability cap/i, keeping the sample uniform.
            let j = self.rng.below(self.seen);
            if (j as usize) < self.cap {
                self.samples[j as usize] = x;
            }
        }
    }

    /// Values observed (not retained — retained is `min(seen, cap)`).
    pub fn count(&self) -> u64 {
        self.seen
    }

    /// Whether the sample still *is* the population (no eviction yet).
    pub fn is_exact(&self) -> bool {
        self.seen <= self.cap as u64
    }

    /// Exact running mean (the sum is tracked outside the sample).
    pub fn mean(&self) -> f64 {
        if self.seen == 0 {
            0.0
        } else {
            self.sum / self.seen as f64
        }
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Percentile estimate, `p ∈ [0, 100]` (exact while
    /// [`Reservoir::is_exact`] holds). Empty reservoir yields 0.
    pub fn percentile(&self, p: f64) -> f64 {
        crate::util::stats::percentile(&self.samples, p)
    }

    /// The standard report triple (p50, p95, p99).
    pub fn triple(&self) -> (f64, f64, f64) {
        p50_p95_p99(&self.samples)
    }

    /// Fold another reservoir in. If the union still fits, the merge is
    /// exact; otherwise both samples are subsampled proportionally to
    /// their population sizes (with replacement — approximate, bounded).
    pub fn merge(&mut self, other: &Reservoir) {
        if other.seen == 0 {
            return;
        }
        let total = self.seen + other.seen;
        self.sum += other.sum;
        if self.is_exact() && self.samples.len() + other.samples.len() <= self.cap {
            self.samples.extend_from_slice(&other.samples);
            self.seen = total;
            return;
        }
        let k_self = ((self.cap as u128 * self.seen as u128 / total as u128) as usize)
            .min(self.samples.len());
        let k_other = (self.cap - k_self).min(other.samples.len());
        let mut merged = Vec::with_capacity(k_self + k_other);
        for _ in 0..k_self {
            merged.push(self.samples[self.rng.below(self.samples.len() as u64) as usize]);
        }
        for _ in 0..k_other {
            merged.push(other.samples[self.rng.below(other.samples.len() as u64) as usize]);
        }
        self.samples = merged;
        self.seen = total;
    }
}

/// Streaming aggregation of request lifecycles: the counters and
/// percentile reservoirs needed to reproduce the [`SloSummary`] report
/// row without retaining a [`RequestRecord`] per request. Fed by the
/// request tracker in streaming mode; merged across shards like the
/// recorder's other per-shard state.
#[derive(Clone, Debug)]
pub struct RequestSketch {
    /// Requests that produced their full target output.
    pub completed: u64,
    /// Requests whose deadline passed before they finished.
    pub expired: u64,
    /// Requests that met their deadline.
    pub met: u64,
    /// Σ tokens of deadline-met requests.
    pub slo_goodput_total: f64,
    ttft: Reservoir,
    tpot: Reservoir,
    e2e: Reservoir,
}

impl Default for RequestSketch {
    fn default() -> Self {
        RequestSketch::new()
    }
}

impl RequestSketch {
    pub fn new() -> RequestSketch {
        RequestSketch {
            completed: 0,
            expired: 0,
            met: 0,
            slo_goodput_total: 0.0,
            ttft: Reservoir::default(),
            tpot: Reservoir::default(),
            e2e: Reservoir::default(),
        }
    }

    /// Fold one finished/expired request in. Mirrors
    /// [`summarize_requests`](crate::serve::tracker::summarize_requests):
    /// percentiles over completed requests only, attainment over
    /// completed + expired.
    pub fn push(&mut self, r: &RequestRecord) {
        if r.met {
            self.met += 1;
            self.slo_goodput_total += r.tokens as f64;
        }
        if r.completed {
            self.completed += 1;
            self.ttft.push(r.ttft_waves());
            self.tpot.push(r.tpot_waves());
            self.e2e.push(r.e2e_waves());
        } else {
            self.expired += 1;
        }
    }

    /// The report row. `censored` is carried by the recorder (it is a
    /// run-level count, not a per-request observation).
    pub fn summary(&self, censored: u64) -> SloSummary {
        let attributable = self.completed + self.expired;
        SloSummary {
            completed: self.completed,
            expired: self.expired,
            censored,
            attainment: if attributable == 0 {
                1.0
            } else {
                self.met as f64 / attributable as f64
            },
            ttft: self.ttft.triple(),
            tpot: self.tpot.triple(),
            e2e: self.e2e.triple(),
            slo_goodput_total: self.slo_goodput_total,
        }
    }

    /// Fold a shard's sketch into this one (pool merge path).
    pub fn merge(&mut self, other: &RequestSketch) {
        self.completed += other.completed;
        self.expired += other.expired;
        self.met += other.met;
        self.slo_goodput_total += other.slo_goodput_total;
        self.ttft.merge(&other.ttft);
        self.tpot.merge(&other.tpot);
        self.e2e.merge(&other.e2e);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reservoir_is_exact_below_capacity() {
        let mut r = Reservoir::new(8);
        for x in [5.0, 1.0, 9.0, 3.0] {
            r.push(x);
        }
        assert!(r.is_exact());
        assert_eq!(r.count(), 4);
        assert!((r.mean() - 4.5).abs() < 1e-12);
        assert!((r.percentile(50.0) - 4.0).abs() < 1e-12);
        let (p50, _, p99) = r.triple();
        assert!((p50 - 4.0).abs() < 1e-12);
        assert!((p99 - crate::util::stats::percentile(&[5.0, 1.0, 9.0, 3.0], 99.0)).abs() < 1e-12);
    }

    #[test]
    fn reservoir_percentiles_track_the_population_past_capacity() {
        // 20k uniform draws through a 1k reservoir: the p50 estimate must
        // land near the true median. Deterministic seed ⇒ no flake.
        let mut r = Reservoir::new(1024);
        let mut src = Rng::new(42);
        for _ in 0..20_000 {
            r.push(src.below(1000) as f64);
        }
        assert!(!r.is_exact());
        assert_eq!(r.count(), 20_000);
        let p50 = r.percentile(50.0);
        assert!((p50 - 500.0).abs() < 60.0, "p50 estimate {p50} too far from 500");
        // The mean is exact regardless of sampling.
        assert!((r.mean() - 499.5).abs() < 5.0);
    }

    #[test]
    fn reservoir_push_is_deterministic() {
        let feed = |n: u64| {
            let mut r = Reservoir::new(16);
            let mut src = Rng::new(7);
            for _ in 0..n {
                r.push(src.below(100) as f64);
            }
            r.triple()
        };
        assert_eq!(feed(5000), feed(5000));
    }

    #[test]
    fn reservoir_merge_exact_when_union_fits() {
        let mut a = Reservoir::new(16);
        let mut b = Reservoir::new(16);
        for x in [1.0, 2.0, 3.0] {
            a.push(x);
        }
        for x in [4.0, 5.0] {
            b.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), 5);
        assert!(a.is_exact());
        assert!((a.mean() - 3.0).abs() < 1e-12);
        assert!((a.percentile(100.0) - 5.0).abs() < 1e-12);
        // Merging an empty reservoir is a no-op.
        a.merge(&Reservoir::new(16));
        assert_eq!(a.count(), 5);
    }

    #[test]
    fn reservoir_merge_subsamples_proportionally() {
        // A sees 10k values near 100, B sees 10k near 900: the merged
        // median must land between the clusters, and counts must add.
        let mut a = Reservoir::new(256);
        let mut b = Reservoir::new(256);
        let mut src = Rng::new(3);
        for _ in 0..10_000 {
            a.push(90.0 + src.below(20) as f64);
            b.push(890.0 + src.below(20) as f64);
        }
        a.merge(&b);
        assert_eq!(a.count(), 20_000);
        let p50 = a.percentile(50.0);
        assert!(p50 > 95.0 && p50 < 905.0, "merged p50 {p50} outside the clusters");
        // Both clusters survive the subsample.
        assert!(a.percentile(5.0) < 120.0);
        assert!(a.percentile(95.0) > 880.0);
    }

    fn req(completed: bool, met: bool, tokens: usize) -> RequestRecord {
        RequestRecord {
            client: 0,
            arrival: 0,
            first_token: completed.then_some(1),
            completion: 4,
            tokens,
            slo_waves: 10,
            completed,
            met,
        }
    }

    #[test]
    fn request_sketch_matches_summarize_requests() {
        let records =
            vec![req(true, true, 8), req(true, false, 8), req(false, false, 2), req(true, true, 4)];
        let mut sk = RequestSketch::new();
        for r in &records {
            sk.push(r);
        }
        let want = crate::serve::tracker::summarize_requests(&records, 3);
        let got = sk.summary(3);
        assert_eq!((got.completed, got.expired, got.censored), (3, 1, 3));
        assert!((got.attainment - want.attainment).abs() < 1e-12);
        assert!((got.slo_goodput_total - want.slo_goodput_total).abs() < 1e-12);
        // Exact below reservoir capacity ⇒ identical percentiles.
        assert_eq!(got.ttft, want.ttft);
        assert_eq!(got.tpot, want.tpot);
        assert_eq!(got.e2e, want.e2e);
    }

    #[test]
    fn request_sketch_merge_adds_counts() {
        let mut a = RequestSketch::new();
        a.push(&req(true, true, 8));
        let mut b = RequestSketch::new();
        b.push(&req(false, false, 1));
        b.push(&req(true, true, 2));
        a.merge(&b);
        let s = a.summary(0);
        assert_eq!((s.completed, s.expired), (2, 1));
        assert!((s.slo_goodput_total - 10.0).abs() < 1e-12);
        assert!((s.attainment - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_sketch_summary_is_well_defined() {
        let s = RequestSketch::new().summary(0);
        assert_eq!((s.completed, s.expired, s.censored), (0, 0, 0));
        assert!((s.attainment - 1.0).abs() < 1e-12, "nothing attributable ⇒ vacuous 1.0");
        assert_eq!(s.ttft, (0.0, 0.0, 0.0));
    }
}
