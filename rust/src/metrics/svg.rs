//! Minimal SVG line-chart writer — figures render with zero external
//! tooling (`results/*.svg` open in any browser).

use std::path::Path;

use anyhow::{Context, Result};

const PALETTE: [&str; 8] =
    ["#1f77b4", "#ff7f0e", "#2ca02c", "#d62728", "#9467bd", "#8c564b", "#e377c2", "#7f7f7f"];

/// One polyline.
pub struct Series {
    pub label: String,
    pub points: Vec<(f64, f64)>,
    /// Optional ±band half-width per point (confidence shading, Fig 2).
    pub band: Option<Vec<f64>>,
}

pub struct Chart {
    pub title: String,
    pub x_label: String,
    pub y_label: String,
    pub series: Vec<Series>,
    pub width: u32,
    pub height: u32,
}

impl Chart {
    pub fn new(title: &str, x_label: &str, y_label: &str) -> Self {
        Chart {
            title: title.into(),
            x_label: x_label.into(),
            y_label: y_label.into(),
            series: Vec::new(),
            width: 860,
            height: 480,
        }
    }

    pub fn add(&mut self, label: &str, points: Vec<(f64, f64)>) -> &mut Self {
        self.series.push(Series { label: label.into(), points, band: None });
        self
    }

    pub fn add_with_band(&mut self, label: &str, points: Vec<(f64, f64)>, band: Vec<f64>) {
        assert_eq!(points.len(), band.len());
        self.series.push(Series { label: label.into(), points, band: Some(band) });
    }

    fn bounds(&self) -> (f64, f64, f64, f64) {
        let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
        let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
        for s in &self.series {
            for (i, &(x, y)) in s.points.iter().enumerate() {
                let b = s.band.as_ref().map(|b| b[i]).unwrap_or(0.0);
                x0 = x0.min(x);
                x1 = x1.max(x);
                y0 = y0.min(y - b);
                y1 = y1.max(y + b);
            }
        }
        if !x0.is_finite() {
            return (0.0, 1.0, 0.0, 1.0);
        }
        if (x1 - x0).abs() < 1e-12 {
            x1 = x0 + 1.0;
        }
        if (y1 - y0).abs() < 1e-12 {
            y1 = y0 + 1.0;
        }
        let pad = (y1 - y0) * 0.05;
        (x0, x1, y0 - pad, y1 + pad)
    }

    pub fn render(&self) -> String {
        let (w, h) = (self.width as f64, self.height as f64);
        let (ml, mr, mt, mb) = (70.0, 20.0, 40.0, 55.0);
        let (x0, x1, y0, y1) = self.bounds();
        let sx = |x: f64| ml + (x - x0) / (x1 - x0) * (w - ml - mr);
        let sy = |y: f64| h - mb - (y - y0) / (y1 - y0) * (h - mt - mb);
        let mut out = String::with_capacity(16 << 10);
        out.push_str(&format!(
            "<svg xmlns=\"http://www.w3.org/2000/svg\" width=\"{}\" height=\"{}\" \
             font-family=\"sans-serif\" font-size=\"12\">\n",
            self.width, self.height
        ));
        out.push_str(&format!(
            "<rect width=\"{}\" height=\"{}\" fill=\"white\"/>\n",
            self.width, self.height
        ));
        out.push_str(&format!(
            "<text x=\"{}\" y=\"22\" text-anchor=\"middle\" font-size=\"15\">{}</text>\n",
            w / 2.0,
            xml(&self.title)
        ));
        // Axes + gridlines with tick labels.
        for i in 0..=4 {
            let fy = y0 + (y1 - y0) * i as f64 / 4.0;
            let py = sy(fy);
            out.push_str(&format!(
                "<line x1=\"{ml}\" y1=\"{py:.1}\" x2=\"{:.1}\" y2=\"{py:.1}\" stroke=\"#ddd\"/>\n",
                w - mr
            ));
            out.push_str(&format!(
                "<text x=\"{:.1}\" y=\"{:.1}\" text-anchor=\"end\">{}</text>\n",
                ml - 6.0,
                py + 4.0,
                fmt_tick(fy)
            ));
            let fx = x0 + (x1 - x0) * i as f64 / 4.0;
            let px = sx(fx);
            out.push_str(&format!(
                "<text x=\"{px:.1}\" y=\"{:.1}\" text-anchor=\"middle\">{}</text>\n",
                h - mb + 18.0,
                fmt_tick(fx)
            ));
        }
        out.push_str(&format!(
            "<line x1=\"{ml}\" y1=\"{:.1}\" x2=\"{:.1}\" y2=\"{:.1}\" stroke=\"black\"/>\n",
            h - mb,
            w - mr,
            h - mb
        ));
        out.push_str(&format!(
            "<line x1=\"{ml}\" y1=\"{mt}\" x2=\"{ml}\" y2=\"{:.1}\" stroke=\"black\"/>\n",
            h - mb
        ));
        out.push_str(&format!(
            "<text x=\"{:.1}\" y=\"{:.1}\" text-anchor=\"middle\">{}</text>\n",
            w / 2.0,
            h - 12.0,
            xml(&self.x_label)
        ));
        out.push_str(&format!(
            "<text x=\"16\" y=\"{:.1}\" text-anchor=\"middle\" transform=\"rotate(-90 16 {:.1})\">{}</text>\n",
            h / 2.0,
            h / 2.0,
            xml(&self.y_label)
        ));
        // Bands first (under the lines).
        for (si, s) in self.series.iter().enumerate() {
            let color = PALETTE[si % PALETTE.len()];
            if let Some(band) = &s.band {
                let mut d = String::from("M");
                for (i, &(x, y)) in s.points.iter().enumerate() {
                    d.push_str(&format!(" {:.1} {:.1}", sx(x), sy(y + band[i])));
                }
                for (i, &(x, y)) in s.points.iter().enumerate().rev() {
                    d.push_str(&format!(" L {:.1} {:.1}", sx(x), sy(y - band[i])));
                }
                d.push('Z');
                out.push_str(&format!(
                    "<path d=\"{d}\" fill=\"{color}\" opacity=\"0.15\" stroke=\"none\"/>\n"
                ));
            }
        }
        for (si, s) in self.series.iter().enumerate() {
            let color = PALETTE[si % PALETTE.len()];
            let pts: Vec<String> =
                s.points.iter().map(|&(x, y)| format!("{:.1},{:.1}", sx(x), sy(y))).collect();
            out.push_str(&format!(
                "<polyline points=\"{}\" fill=\"none\" stroke=\"{color}\" stroke-width=\"1.6\"/>\n",
                pts.join(" ")
            ));
            let ly = mt + 16.0 * si as f64 + 8.0;
            out.push_str(&format!(
                "<rect x=\"{:.1}\" y=\"{:.1}\" width=\"12\" height=\"3\" fill=\"{color}\"/>\n",
                ml + 10.0,
                ly - 4.0
            ));
            out.push_str(&format!(
                "<text x=\"{:.1}\" y=\"{:.1}\">{}</text>\n",
                ml + 26.0,
                ly,
                xml(&s.label)
            ));
        }
        out.push_str("</svg>\n");
        out
    }

    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir).ok();
        }
        std::fs::write(path.as_ref(), self.render())
            .with_context(|| format!("writing {:?}", path.as_ref()))
    }
}

fn xml(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}

fn fmt_tick(v: f64) -> String {
    if v.abs() >= 1000.0 {
        format!("{v:.0}")
    } else if v.abs() >= 10.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_valid_svg_with_band() {
        let mut c = Chart::new("t", "x", "y");
        c.add("a", vec![(0.0, 1.0), (1.0, 2.0), (2.0, 1.5)]);
        c.add_with_band("b", vec![(0.0, 0.5), (1.0, 0.7), (2.0, 0.9)], vec![0.1, 0.1, 0.2]);
        let svg = c.render();
        assert!(svg.starts_with("<svg"));
        assert!(svg.ends_with("</svg>\n"));
        assert_eq!(svg.matches("<polyline").count(), 2);
        assert_eq!(svg.matches("opacity=\"0.15\"").count(), 1);
        assert!(svg.contains(">t<"));
    }

    #[test]
    fn empty_chart_does_not_panic() {
        let c = Chart::new("empty", "x", "y");
        let svg = c.render();
        assert!(svg.contains("</svg>"));
    }

    #[test]
    fn xml_escaping() {
        let mut c = Chart::new("a<b & c", "x", "y");
        c.add("s", vec![(0.0, 0.0)]);
        assert!(c.render().contains("a&lt;b &amp; c"));
    }
}
