//! Edge-link model: per-client latency + bandwidth + jitter.
//!
//! The paper's Fig 3 attributes most wall time to *receiving* (waiting for
//! the slowest draft server's upload — which carries the full per-token
//! proposal distributions, S·V·4 bytes) and *verification*; the model here
//! reproduces exactly that byte-accounting. Delays are applied as real
//! sleeps on the draft-server side so coordinator wall-clock measurements
//! decompose the same way the paper's do.

use std::time::Duration;

use crate::configsys::LinkConfig;
use crate::util::Rng;

/// Simulated one-way link.
#[derive(Clone, Debug)]
pub struct Link {
    cfg: LinkConfig,
}

impl Link {
    pub fn new(cfg: LinkConfig) -> Self {
        Link { cfg }
    }

    /// One-way delay for a message of `bytes` with multiplicative jitter.
    pub fn delay(&self, bytes: usize, rng: &mut Rng) -> Duration {
        let jitter = 1.0 + self.cfg.jitter * rng.normal();
        let secs = (self.cfg.latency_s + bytes as f64 / self.cfg.bandwidth_bps.max(1.0))
            * jitter.clamp(0.25, 4.0);
        Duration::from_secs_f64(secs.max(0.0))
    }

    /// Deterministic mean delay (no jitter) — used by the analytic
    /// simulator where real sleeping would waste wall-clock.
    pub fn mean_delay(&self, bytes: usize) -> Duration {
        Duration::from_secs_f64(self.cfg.latency_s + bytes as f64 / self.cfg.bandwidth_bps.max(1.0))
    }

    pub fn config(&self) -> &LinkConfig {
        &self.cfg
    }

    /// The degraded service a partitioned client sees while traffic
    /// routes around the outage: propagation latency inflated `factor`×
    /// and bandwidth divided by the same factor. The analytic simulator
    /// applies this over a
    /// [`FaultKind::Partition`](crate::chaos::FaultKind) window and
    /// restores the original link at the heal wave; `factor ≤ 1` is the
    /// identity (a partition never *improves* a link).
    pub fn degraded(&self, factor: f64) -> Link {
        if !(factor.is_finite() && factor > 1.0) {
            return self.clone();
        }
        Link::new(LinkConfig {
            latency_s: self.cfg.latency_s * factor,
            bandwidth_bps: (self.cfg.bandwidth_bps / factor).max(1.0),
            jitter: self.cfg.jitter,
        })
    }
}

/// Uplink payload size of a draft message: prefix tokens + draft tokens +
/// the full q distributions (the dominant term the paper highlights).
pub fn draft_msg_bytes(prefix_len: usize, draft_len: usize, vocab: usize) -> usize {
    let header = 32;
    header + prefix_len + draft_len + draft_len * vocab * 4
}

/// Uplink payload of a *tree* draft: the chain payload plus the compact
/// parent-index array (one byte per node, plus its length prefix).
pub fn tree_draft_msg_bytes(prefix_len: usize, nodes: usize, vocab: usize) -> usize {
    draft_msg_bytes(prefix_len, nodes, vocab) + 4 + nodes
}

/// Downlink payload of a verdict: accept count + correction + allocation.
pub fn verdict_msg_bytes() -> usize {
    24
}

/// Downlink payload of a *tree* verdict: the chain verdict plus the
/// accepted root-path node indices (one byte each, plus length prefix).
pub fn tree_verdict_msg_bytes(path_len: usize) -> usize {
    verdict_msg_bytes() + 4 + path_len
}

#[cfg(test)]
mod tests {
    use super::*;

    fn link(lat: f64, bw: f64) -> Link {
        Link::new(LinkConfig { latency_s: lat, bandwidth_bps: bw, jitter: 0.0 })
    }

    #[test]
    fn delay_scales_with_bytes() {
        let l = link(1e-3, 1e6);
        let d_small = l.mean_delay(1_000);
        let d_big = l.mean_delay(100_000);
        assert!((d_small.as_secs_f64() - 2e-3).abs() < 1e-9);
        assert!((d_big.as_secs_f64() - 0.101).abs() < 1e-9);
        assert!(d_big > d_small);
    }

    #[test]
    fn jitter_bounded() {
        let l = Link::new(LinkConfig { latency_s: 1e-3, bandwidth_bps: 1e9, jitter: 0.5 });
        let mut rng = Rng::new(0);
        for _ in 0..1000 {
            let d = l.delay(100, &mut rng).as_secs_f64();
            assert!(d >= 0.25e-3 * 0.9 && d <= 4.0e-3 * 1.1, "{d}");
        }
    }

    /// Satellite pin: the documented jitter envelope. The multiplicative
    /// jitter factor is clamped to [0.25, 4] *before* it scales the
    /// deterministic delay, so even adversarial draws (huge jitter
    /// stddev, extreme normals in both tails) keep every delay inside
    /// [0.25×, 4×] of the jitter-free mean — and in particular
    /// non-negative, despite `1 + σ·N` going deeply negative.
    #[test]
    fn adversarial_jitter_stays_inside_the_documented_envelope() {
        for (lat, bw, bytes) in
            [(1e-3, 1e9, 100usize), (20e-3, 1.25e6, 50_000), (0.0, 1e6, 1), (5e-4, 1e9, 0)]
        {
            let base = Link::new(LinkConfig { latency_s: lat, bandwidth_bps: bw, jitter: 0.0 })
                .mean_delay(bytes)
                .as_secs_f64();
            // σ = 50: |1 + σ·N| exceeds the clamp bounds almost every
            // draw, in both directions.
            let l =
                Link::new(LinkConfig { latency_s: lat, bandwidth_bps: bw, jitter: 50.0 });
            let mut rng = Rng::new(0xBAD_1);
            let (mut lo_hits, mut hi_hits) = (0u32, 0u32);
            // Duration rounds to whole nanoseconds: allow 2 ns of slack.
            const NS: f64 = 2e-9;
            for _ in 0..5_000 {
                let d = l.delay(bytes, &mut rng).as_secs_f64();
                assert!(d >= 0.0, "negative delay {d}");
                assert!(
                    d >= 0.25 * base - NS && d <= 4.0 * base + NS,
                    "delay {d} outside [{}, {}]",
                    0.25 * base,
                    4.0 * base
                );
                if (d - 0.25 * base).abs() <= NS {
                    lo_hits += 1;
                }
                if (d - 4.0 * base).abs() <= NS {
                    hi_hits += 1;
                }
            }
            // With σ = 50 the clamp binds on essentially every draw:
            // both envelope edges must actually be exercised.
            if base > 0.0 {
                assert!(lo_hits > 100, "lower clamp never bound ({lo_hits})");
                assert!(hi_hits > 100, "upper clamp never bound ({hi_hits})");
            }
        }
    }

    #[test]
    fn degraded_link_inflates_both_terms_and_clamps_below_one() {
        let l = link(2e-3, 1e6);
        let d = l.degraded(8.0);
        // Latency-dominated message: delay scales ≈ 8×.
        let small = d.mean_delay(10).as_secs_f64() / l.mean_delay(10).as_secs_f64();
        assert!((small - 8.0).abs() < 0.1, "latency term must scale: {small}");
        // Bandwidth-dominated message: also ≈ 8× (bandwidth divides).
        let big = d.mean_delay(1_000_000).as_secs_f64() / l.mean_delay(1_000_000).as_secs_f64();
        assert!((big - 8.0).abs() < 0.1, "bandwidth term must scale: {big}");
        // A partition never improves a link: factor ≤ 1 (and NaN) are
        // the identity.
        for f in [1.0, 0.5, 0.0, -3.0, f64::NAN] {
            let same = l.degraded(f);
            assert_eq!(same.config().latency_s, l.config().latency_s, "factor {f}");
            assert_eq!(same.config().bandwidth_bps, l.config().bandwidth_bps, "factor {f}");
        }
    }

    #[test]
    fn q_distributions_dominate_uplink() {
        // S=20 drafts over V=256 → q payload ≈ 20 KiB ≫ tokens.
        let bytes = draft_msg_bytes(100, 20, 256);
        assert!(bytes > 20_000);
        assert!(bytes < 21_000);
        assert!(verdict_msg_bytes() < 100); // paper: sending < 0.1 % of wall
    }
}
