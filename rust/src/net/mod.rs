//! Networking substrate: link simulation, wire format, transports.

pub mod link;
pub mod transport;
pub mod wire;

pub use link::{draft_msg_bytes, verdict_msg_bytes, Link};
pub use transport::{channel_transport, ClientPort, ServerSide, TcpTransport};
pub use wire::{DraftMsg, Message, VerdictMsg};
