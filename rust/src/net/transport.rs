//! Transports between draft servers and the coordinator.
//!
//! The coordinator owns one fan-in receiver (true FIFO arrival order — the
//! paper's verification-server queue) and one sender per client. Two
//! implementations:
//! * **channel** — in-process `std::sync::mpsc` (fast, used by tests,
//!   simulations, and single-machine experiments);
//! * **tcp** — localhost TCP with the length-prefixed wire format (real
//!   sockets + serialization; the Fig 3 "distributed" configuration).

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::wire::Message;

/// Client-side endpoint held by one draft server.
pub trait ClientPort: Send {
    fn send(&mut self, msg: &Message) -> Result<()>;
    /// Blocking receive.
    fn recv(&mut self) -> Result<Message>;
}

/// Coordinator-side endpoints.
pub struct ServerSide {
    /// Fan-in of all client messages in arrival order (FIFO queue).
    pub rx: Receiver<(usize, Message)>,
    /// Per-client verdict senders.
    pub txs: Vec<Box<dyn FnMut(&Message) -> Result<()> + Send>>,
}

impl ServerSide {
    /// Blocking receive of the next fan-in message.
    pub fn recv(&mut self) -> Result<(usize, Message)> {
        self.rx.recv().map_err(|_| anyhow!("all draft servers disconnected"))
    }

    /// Receive with an absolute deadline. `Ok(None)` means the deadline
    /// passed with nothing queued — the async coordinator's batching-window
    /// expiry. Works identically over channel and TCP because the TCP
    /// reader threads feed the same mpsc fan-in.
    pub fn recv_deadline(&mut self, deadline: Instant) -> Result<Option<(usize, Message)>> {
        let timeout = deadline.saturating_duration_since(Instant::now());
        match self.rx.recv_timeout(timeout) {
            Ok(m) => Ok(Some(m)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => {
                Err(anyhow!("all draft servers disconnected"))
            }
        }
    }

    /// Drain everything already queued without blocking (opportunistic
    /// batching after a wave threshold is met). Disconnection surfaces as
    /// an error only when nothing was drained — queued messages are never
    /// dropped.
    pub fn try_drain(&mut self) -> Result<Vec<(usize, Message)>> {
        let mut out = Vec::new();
        loop {
            match self.rx.try_recv() {
                Ok(m) => out.push(m),
                Err(TryRecvError::Empty) => return Ok(out),
                Err(TryRecvError::Disconnected) => {
                    if out.is_empty() {
                        return Err(anyhow!("all draft servers disconnected"));
                    }
                    return Ok(out);
                }
            }
        }
    }
}

// ---------------------------------------------------------------- channel

/// Build an in-process transport for `n` clients.
pub fn channel_transport(n: usize) -> (ServerSide, Vec<Box<dyn ClientPort>>) {
    let (fan_tx, fan_rx) = channel::<(usize, Message)>();
    let mut txs: Vec<Box<dyn FnMut(&Message) -> Result<()> + Send>> = Vec::new();
    let mut ports: Vec<Box<dyn ClientPort>> = Vec::new();
    for i in 0..n {
        let (v_tx, v_rx) = channel::<Message>();
        let fan = fan_tx.clone();
        txs.push(Box::new(move |m: &Message| {
            v_tx.send(m.clone()).map_err(|_| anyhow!("client {i} gone"))
        }));
        ports.push(Box::new(ChannelPort { id: i, tx: fan, rx: v_rx }));
    }
    (ServerSide { rx: fan_rx, txs }, ports)
}

struct ChannelPort {
    id: usize,
    tx: Sender<(usize, Message)>,
    rx: Receiver<Message>,
}

impl ClientPort for ChannelPort {
    fn send(&mut self, msg: &Message) -> Result<()> {
        self.tx.send((self.id, msg.clone())).map_err(|_| anyhow!("coordinator gone"))
    }

    fn recv(&mut self) -> Result<Message> {
        self.rx.recv().map_err(|_| anyhow!("coordinator closed"))
    }
}

// -------------------------------------------------------- sharded channel

/// Client → shard routing table, shared between every client port and the
/// pool controller. A client's *next* send observes a reassignment
/// immediately (acquire/release); the message already queued at the old
/// shard is still verified there — nothing is lost in flight.
///
/// Slots can be marked inactive (`set_active`): reserved-but-unattached
/// and retired sessions keep a routing entry but are excluded from
/// `members_of`, so shard membership, budget floors, and wave-fill counts
/// see only the serving population.
#[derive(Clone)]
pub struct ShardRouter {
    assignment: Arc<Vec<AtomicUsize>>,
    active: Arc<Vec<AtomicBool>>,
    num_shards: usize,
}

impl ShardRouter {
    /// Round-robin initial placement: client i → shard i mod m, all
    /// active.
    pub fn new(n: usize, m: usize) -> ShardRouter {
        assert!(m > 0, "at least one shard");
        ShardRouter {
            assignment: Arc::new((0..n).map(|i| AtomicUsize::new(i % m)).collect()),
            active: Arc::new((0..n).map(|_| AtomicBool::new(true)).collect()),
            num_shards: m,
        }
    }

    pub fn num_clients(&self) -> usize {
        self.assignment.len()
    }

    pub fn num_shards(&self) -> usize {
        self.num_shards
    }

    pub fn shard_of(&self, client: usize) -> usize {
        self.assignment[client].load(Ordering::Acquire)
    }

    /// Whether the slot currently holds a serving session.
    pub fn is_active(&self, client: usize) -> bool {
        self.active[client].load(Ordering::Acquire)
    }

    /// Mark a slot as serving (admission) or not (reserve/retired).
    pub fn set_active(&self, client: usize, active: bool) {
        self.active[client].store(active, Ordering::Release);
    }

    /// Move a client to another shard (pool rebalancing / admission).
    pub fn assign(&self, client: usize, shard: usize) {
        assert!(shard < self.num_shards, "shard {shard} out of range");
        self.assignment[client].store(shard, Ordering::Release);
    }

    /// Active clients currently routed to `shard`, ascending.
    pub fn members_of(&self, shard: usize) -> Vec<usize> {
        (0..self.num_clients())
            .filter(|&i| self.is_active(i) && self.shard_of(i) == shard)
            .collect()
    }
}

struct ShardedPort {
    id: usize,
    fans: Vec<Sender<(usize, Message)>>,
    router: ShardRouter,
    rx: Receiver<Message>,
}

impl ClientPort for ShardedPort {
    fn send(&mut self, msg: &Message) -> Result<()> {
        let shard = self.router.shard_of(self.id);
        self.fans[shard]
            .send((self.id, msg.clone()))
            .map_err(|_| anyhow!("shard {shard} gone"))
    }

    fn recv(&mut self) -> Result<Message> {
        self.rx.recv().map_err(|_| anyhow!("coordinator closed"))
    }
}

/// Build an in-process transport for `n` clients fanned into `m`
/// verification shards. Each shard gets its own FIFO fan-in (only its
/// routed clients' messages ever appear there) plus verdict senders for
/// *all* clients (any shard can answer any client — needed while a
/// migrated client's last draft drains at its old shard). The extra
/// `Vec<Sender<Message>>` is a master set of verdict senders the pool
/// driver keeps for the end-of-run shutdown broadcast.
#[allow(clippy::type_complexity)]
pub fn sharded_channel_transport(
    n: usize,
    m: usize,
) -> (Vec<ServerSide>, ShardRouter, Vec<Box<dyn ClientPort>>, Vec<Sender<Message>>) {
    let router = ShardRouter::new(n, m);
    let mut fan_txs = Vec::with_capacity(m);
    let mut fan_rxs = Vec::with_capacity(m);
    for _ in 0..m {
        let (tx, rx) = channel::<(usize, Message)>();
        fan_txs.push(tx);
        fan_rxs.push(rx);
    }
    let mut verdict_txs = Vec::with_capacity(n);
    let mut ports: Vec<Box<dyn ClientPort>> = Vec::with_capacity(n);
    for i in 0..n {
        let (v_tx, v_rx) = channel::<Message>();
        verdict_txs.push(v_tx);
        ports.push(Box::new(ShardedPort {
            id: i,
            fans: fan_txs.clone(),
            router: router.clone(),
            rx: v_rx,
        }));
    }
    let servers = fan_rxs
        .into_iter()
        .map(|rx| {
            let txs: Vec<Box<dyn FnMut(&Message) -> Result<()> + Send>> = verdict_txs
                .iter()
                .enumerate()
                .map(|(i, v_tx)| {
                    let v_tx = v_tx.clone();
                    Box::new(move |msg: &Message| {
                        v_tx.send(msg.clone()).map_err(|_| anyhow!("client {i} gone"))
                    }) as Box<dyn FnMut(&Message) -> Result<()> + Send>
                })
                .collect();
            ServerSide { rx, txs }
        })
        .collect();
    (servers, router, ports, verdict_txs)
}

// -------------------------------------------------------------------- tcp

/// Encode `msg` into the recycled `wbuf` and flush with a single
/// `write_all`. Every sender (verdict tx closures, the client port) owns
/// a persistent `wbuf`, so steady-state sends never allocate and each
/// frame hits the socket in one syscall instead of one per `encode`'d
/// vector.
fn write_frame(stream: &mut TcpStream, msg: &Message, wbuf: &mut Vec<u8>) -> Result<()> {
    wbuf.clear();
    msg.encode_into(wbuf);
    stream.write_all(wbuf).context("tcp write")?;
    Ok(())
}

/// Reassembles length-prefixed frames from arbitrary read chunks — the
/// receive half of the coalescing discipline. A reader thread feeds it
/// whatever one `read` returned (which may split a frame mid-length-
/// prefix or mid-payload, or carry many coalesced frames) and drains
/// every complete frame before reading again, preserving the stream's
/// FIFO order.
#[derive(Debug, Default)]
pub struct FrameAccumulator {
    buf: Vec<u8>,
    /// Start of unconsumed bytes within `buf`.
    pos: usize,
}

impl FrameAccumulator {
    pub fn new() -> FrameAccumulator {
        FrameAccumulator::default()
    }

    /// Append one read's bytes. The consumed prefix is compacted away
    /// first, so the buffer's high-water capacity tracks the largest
    /// burst of in-flight bytes, not the whole stream.
    pub fn feed(&mut self, bytes: &[u8]) {
        if self.pos > 0 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Pop the next complete frame, in arrival order. `Ok(None)` means
    /// more bytes are needed; a malformed or oversized frame is an error
    /// (the connection is beyond recovery — framing is lost).
    pub fn next_frame(&mut self) -> Result<Option<Message>> {
        let avail = self.buf.len() - self.pos;
        if avail < 4 {
            return Ok(None);
        }
        let len = u32::from_le_bytes(
            self.buf[self.pos..self.pos + 4].try_into().expect("4-byte slice"),
        ) as usize;
        if len > 64 << 20 {
            return Err(anyhow!("tcp frame too large: {len}"));
        }
        if avail < 4 + len {
            return Ok(None);
        }
        let payload = &self.buf[self.pos + 4..self.pos + 4 + len];
        let msg = Message::decode(payload)?;
        self.pos += 4 + len;
        Ok(Some(msg))
    }
}

/// Read one length-prefixed frame into `buf` (reused across calls — within
/// its high-water capacity the refill never allocates) and decode it.
fn read_frame(stream: &mut TcpStream, buf: &mut Vec<u8>) -> Result<Message> {
    let mut len_buf = [0u8; 4];
    stream.read_exact(&mut len_buf).context("tcp read len")?;
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > 64 << 20 {
        return Err(anyhow!("tcp frame too large: {len}"));
    }
    buf.clear();
    buf.resize(len, 0);
    stream.read_exact(buf).context("tcp read payload")?;
    Ok(Message::decode(buf)?)
}

struct TcpPort {
    stream: TcpStream,
    buf: Vec<u8>,
    wbuf: Vec<u8>,
}

impl ClientPort for TcpPort {
    fn send(&mut self, msg: &Message) -> Result<()> {
        write_frame(&mut self.stream, msg, &mut self.wbuf)
    }

    fn recv(&mut self) -> Result<Message> {
        read_frame(&mut self.stream, &mut self.buf)
    }
}

/// TCP transport on an ephemeral localhost port. The coordinator side
/// spawns one reader thread per connection, all feeding the fan-in channel
/// (arrival order = socket readiness order).
pub struct TcpTransport {
    pub server: ServerSide,
    pub ports: Vec<Box<dyn ClientPort>>,
    reader_handles: Vec<JoinHandle<()>>,
}

impl TcpTransport {
    pub fn new(n: usize) -> Result<TcpTransport> {
        let listener = TcpListener::bind("127.0.0.1:0").context("bind")?;
        let addr = listener.local_addr()?;
        // Client connections (same process, different threads in prod use).
        let mut client_streams = Vec::with_capacity(n);
        let mut server_streams = Vec::with_capacity(n);
        for i in 0..n {
            let c = TcpStream::connect(addr).with_context(|| format!("connect {i}"))?;
            let (s, _) = listener.accept().context("accept")?;
            c.set_nodelay(true).ok();
            s.set_nodelay(true).ok();
            client_streams.push(c);
            server_streams.push(s);
        }
        let (fan_tx, fan_rx) = channel::<(usize, Message)>();
        let mut txs: Vec<Box<dyn FnMut(&Message) -> Result<()> + Send>> = Vec::new();
        let mut reader_handles = Vec::new();
        for (i, s) in server_streams.into_iter().enumerate() {
            let mut writer = s.try_clone().context("clone stream")?;
            let mut wbuf = Vec::new();
            txs.push(Box::new(move |m: &Message| write_frame(&mut writer, m, &mut wbuf)));
            let fan = fan_tx.clone();
            let mut reader = s;
            reader_handles.push(std::thread::spawn(move || {
                // Batch-drain: one read may carry many coalesced frames;
                // forward them all before touching the socket again (a
                // client's frames stay in FIFO order — one stream, one
                // accumulator).
                let mut acc = FrameAccumulator::new();
                let mut chunk = [0u8; 16 * 1024];
                'conn: loop {
                    let n = match reader.read(&mut chunk) {
                        Ok(0) | Err(_) => break, // peer closed
                        Ok(n) => n,
                    };
                    acc.feed(&chunk[..n]);
                    loop {
                        match acc.next_frame() {
                            Ok(Some(Message::Shutdown)) => {
                                let _ = fan.send((i, Message::Shutdown));
                                break 'conn;
                            }
                            Ok(Some(m)) => {
                                if fan.send((i, m)).is_err() {
                                    break 'conn;
                                }
                            }
                            Ok(None) => break, // need more bytes
                            Err(_) => break 'conn, // framing lost
                        }
                    }
                }
            }));
        }
        let ports = client_streams
            .into_iter()
            .map(|s| {
                Box::new(TcpPort { stream: s, buf: Vec::new(), wbuf: Vec::new() })
                    as Box<dyn ClientPort>
            })
            .collect();
        Ok(TcpTransport { server: ServerSide { rx: fan_rx, txs }, ports, reader_handles })
    }

    pub fn join(self) {
        for h in self.reader_handles {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::wire::{DraftMsg, VerdictMsg};

    fn draft(id: u32, round: u64) -> Message {
        Message::Draft(DraftMsg {
            client_id: id,
            round,
            prefix: vec![1, 2, 3],
            prompt_len: 3,
            draft: vec![7],
            parents: Vec::new(),
            q_probs: vec![0.25; 4],
            new_request: round == 0,
            draft_wall_ns: 5,
        })
    }

    #[test]
    fn channel_roundtrip_preserves_fifo() {
        let (server, mut ports) = channel_transport(3);
        for (i, p) in ports.iter_mut().enumerate() {
            p.send(&draft(i as u32, 0)).unwrap();
        }
        for expect in 0..3usize {
            let (id, msg) = server.rx.recv().unwrap();
            assert_eq!(id, expect); // sent sequentially → FIFO order
            match msg {
                Message::Draft(d) => assert_eq!(d.client_id as usize, expect),
                _ => panic!("wrong type"),
            }
        }
    }

    #[test]
    fn channel_verdicts_routed_per_client() {
        let (mut server, mut ports) = channel_transport(2);
        let v = Message::Verdict(VerdictMsg {
            client_id: 1,
            round: 0,
            accepted: 2,
            path: vec![],
            correction: 9,
            next_alloc: 4,
            shard: 0,
        });
        (server.txs[1])(&v).unwrap();
        let got = ports[1].recv().unwrap();
        assert_eq!(got, v);
    }

    #[test]
    fn tcp_roundtrip() {
        let mut t = TcpTransport::new(2).unwrap();
        // client -> server
        t.ports[1].send(&draft(1, 3)).unwrap();
        let (id, msg) = t.server.rx.recv().unwrap();
        assert_eq!(id, 1);
        assert!(matches!(msg, Message::Draft(ref d) if d.round == 3));
        // server -> client
        let v = Message::Verdict(VerdictMsg {
            client_id: 0,
            round: 3,
            accepted: 1,
            path: vec![],
            correction: 2,
            next_alloc: 8,
            shard: 0,
        });
        (t.server.txs[0])(&v).unwrap();
        assert_eq!(t.ports[0].recv().unwrap(), v);
        // shutdown both clients, reader threads exit
        for p in t.ports.iter_mut() {
            p.send(&Message::Shutdown).unwrap();
        }
        let mut shutdowns = 0;
        while let Ok((_, m)) = t.server.rx.recv() {
            if m == Message::Shutdown {
                shutdowns += 1;
                if shutdowns == 2 {
                    break;
                }
            }
        }
        drop(t.ports);
    }

    #[test]
    fn recv_deadline_times_out_then_delivers() {
        let (mut server, mut ports) = channel_transport(1);
        // Nothing queued: an already-expired deadline returns None.
        let expired = Instant::now();
        assert!(server.recv_deadline(expired).unwrap().is_none());
        // Queued message is delivered even with an expired deadline.
        ports[0].send(&draft(0, 0)).unwrap();
        let got = server.recv_deadline(Instant::now()).unwrap();
        assert!(matches!(got, Some((0, Message::Draft(_)))));
    }

    #[test]
    fn try_drain_returns_all_queued_without_blocking() {
        let (mut server, mut ports) = channel_transport(3);
        assert!(server.try_drain().unwrap().is_empty());
        for (i, p) in ports.iter_mut().enumerate() {
            p.send(&draft(i as u32, 1)).unwrap();
        }
        let drained = server.try_drain().unwrap();
        assert_eq!(drained.len(), 3);
        let ids: Vec<usize> = drained.iter().map(|(id, _)| *id).collect();
        assert_eq!(ids, vec![0, 1, 2]); // FIFO order preserved
        assert!(server.try_drain().unwrap().is_empty());
    }

    #[test]
    fn drain_surfaces_disconnect_only_when_empty() {
        let (mut server, mut ports) = channel_transport(1);
        ports[0].send(&draft(0, 0)).unwrap();
        drop(ports); // all clients gone
        let drained = server.try_drain().unwrap(); // queued msg survives
        assert_eq!(drained.len(), 1);
        assert!(server.try_drain().is_err());
        assert!(server.recv().is_err());
    }

    #[test]
    fn tcp_recv_deadline_roundtrip() {
        let mut t = TcpTransport::new(2).unwrap();
        let deadline = Instant::now() + std::time::Duration::from_millis(5);
        assert!(t.server.recv_deadline(deadline).unwrap().is_none());
        t.ports[0].send(&draft(0, 2)).unwrap();
        // Reader thread forwards into the fan-in; a generous deadline sees it.
        let deadline = Instant::now() + std::time::Duration::from_secs(5);
        let got = t.server.recv_deadline(deadline).unwrap();
        assert!(matches!(got, Some((0, Message::Draft(ref d))) if d.round == 2));
    }

    #[test]
    fn sharded_fanins_have_no_cross_shard_leakage() {
        // 4 clients over 2 shards: 0,2 → shard 0; 1,3 → shard 1. Every
        // message must land only in its own shard's fan-in.
        let (mut servers, router, mut ports, _master) = sharded_channel_transport(4, 2);
        assert_eq!(router.members_of(0), vec![0, 2]);
        assert_eq!(router.members_of(1), vec![1, 3]);
        for (i, p) in ports.iter_mut().enumerate() {
            p.send(&draft(i as u32, 0)).unwrap();
        }
        let ids = |drained: Vec<(usize, Message)>| -> Vec<usize> {
            drained.into_iter().map(|(id, _)| id).collect::<Vec<_>>()
        };
        assert_eq!(ids(servers[0].try_drain().unwrap()), vec![0, 2]);
        assert_eq!(ids(servers[1].try_drain().unwrap()), vec![1, 3]);
        // Nothing left anywhere.
        assert!(servers[0].try_drain().unwrap().is_empty());
        assert!(servers[1].try_drain().unwrap().is_empty());
    }

    #[test]
    fn sharded_recv_deadline_sees_only_own_shard() {
        let (mut servers, _router, mut ports, _master) = sharded_channel_transport(2, 2);
        ports[1].send(&draft(1, 0)).unwrap();
        // Shard 0's deadline receive must time out — client 1's draft is
        // shard 1 traffic.
        let expired = Instant::now();
        assert!(servers[0].recv_deadline(expired).unwrap().is_none());
        let got = servers[1].recv_deadline(Instant::now()).unwrap();
        assert!(matches!(got, Some((1, Message::Draft(_)))));
    }

    #[test]
    fn inactive_slots_are_excluded_from_membership() {
        let (_servers, router, _ports, _master) = sharded_channel_transport(4, 2);
        assert_eq!(router.members_of(0), vec![0, 2]);
        // Retire client 2: routing survives, membership does not.
        router.set_active(2, false);
        assert!(!router.is_active(2));
        assert_eq!(router.shard_of(2), 0);
        assert_eq!(router.members_of(0), vec![0]);
        // Re-admit into shard 1.
        router.assign(2, 1);
        router.set_active(2, true);
        assert_eq!(router.members_of(1), vec![1, 2, 3]);
    }

    #[test]
    fn sharded_reassignment_routes_next_send() {
        let (mut servers, router, mut ports, _master) = sharded_channel_transport(2, 2);
        ports[1].send(&draft(1, 0)).unwrap();
        router.assign(1, 0);
        ports[1].send(&draft(1, 1)).unwrap();
        // Round 0 went to the old shard, round 1 to the new one.
        let old = servers[1].try_drain().unwrap();
        assert_eq!(old.len(), 1);
        assert!(matches!(&old[0].1, Message::Draft(d) if d.round == 0));
        let new = servers[0].try_drain().unwrap();
        assert_eq!(new.len(), 1);
        assert!(matches!(&new[0].1, Message::Draft(d) if d.round == 1));
        assert_eq!(router.shard_of(1), 0);
    }

    #[test]
    fn sharded_verdicts_reach_clients_from_any_shard() {
        let (mut servers, _router, mut ports, _master) = sharded_channel_transport(2, 2);
        // Shard 1 answers client 0 even though client 0 routes to shard 0
        // (the drain-after-migration path).
        let v = Message::Verdict(VerdictMsg {
            client_id: 0,
            round: 0,
            accepted: 1,
            path: vec![],
            correction: 3,
            next_alloc: 2,
            shard: 1,
        });
        (servers[1].txs[0])(&v).unwrap();
        assert_eq!(ports[0].recv().unwrap(), v);
    }

    #[test]
    fn sharded_concurrent_fanins_stay_isolated() {
        // Satellite: try_drain / recv_deadline under multiple concurrent
        // shard fan-ins — no cross-shard message leakage, nothing lost.
        let n = 6;
        let m = 3;
        let per_client = 40u64;
        let (mut servers, router, ports, _master) = sharded_channel_transport(n, m);
        let mut senders = Vec::new();
        for (i, mut p) in ports.into_iter().enumerate() {
            senders.push(std::thread::spawn(move || {
                for round in 0..per_client {
                    p.send(&draft(i as u32, round)).unwrap();
                }
            }));
        }
        let mut counts = vec![0u64; n];
        for (shard, server) in servers.iter_mut().enumerate() {
            let mut got = 0u64;
            let want = per_client * router.members_of(shard).len() as u64;
            while got < want {
                // Alternate the two drain APIs under concurrency.
                let batch = server.try_drain().unwrap();
                let msgs = if batch.is_empty() {
                    let deadline = Instant::now() + std::time::Duration::from_secs(5);
                    match server.recv_deadline(deadline).unwrap() {
                        Some(x) => vec![x],
                        None => panic!("shard {shard} starved"),
                    }
                } else {
                    batch
                };
                for (id, msg) in msgs {
                    assert_eq!(
                        router.shard_of(id),
                        shard,
                        "client {id} leaked into shard {shard}"
                    );
                    assert!(matches!(msg, Message::Draft(_)));
                    counts[id] += 1;
                    got += 1;
                }
            }
            // And nothing further is queued for this shard (an Err here
            // means disconnected-and-empty once the senders finished —
            // queued messages are never dropped, so that also proves it).
            assert!(server.try_drain().map(|v| v.is_empty()).unwrap_or(true));
        }
        for h in senders {
            h.join().unwrap();
        }
        assert_eq!(counts, vec![per_client; n]);
    }

    #[test]
    fn frame_accumulator_handles_byte_at_a_time_feeds() {
        // Worst-case short reads: one byte per feed, frames completing
        // only at their exact final byte (including mid-length-prefix
        // splits).
        let msgs =
            [draft(0, 0), Message::Shutdown, draft(0, 1), draft(0, 2), Message::Shutdown];
        let mut wire = Vec::new();
        for m in &msgs {
            m.encode_into(&mut wire);
        }
        let mut acc = FrameAccumulator::new();
        let mut got = Vec::new();
        for &b in &wire {
            acc.feed(&[b]);
            while let Some(m) = acc.next_frame().unwrap() {
                got.push(m);
            }
        }
        assert_eq!(got.as_slice(), msgs.as_slice());
        assert!(acc.next_frame().unwrap().is_none(), "stream fully consumed");
    }

    #[test]
    fn frame_accumulator_batch_drains_one_feed() {
        // The batch-drain shape: many frames arrive in a single read and
        // must all come out, in order, before the next feed.
        let msgs: Vec<Message> = (0..10).map(|r| draft(3, r)).collect();
        let mut wire = Vec::new();
        for m in &msgs {
            m.encode_into(&mut wire);
        }
        let mut acc = FrameAccumulator::new();
        acc.feed(&wire);
        let mut got = Vec::new();
        while let Some(m) = acc.next_frame().unwrap() {
            got.push(m);
        }
        assert_eq!(got, msgs);
    }

    #[test]
    fn frame_accumulator_rejects_oversized_and_malformed_frames() {
        // Oversized length prefix: framing is beyond recovery.
        let mut acc = FrameAccumulator::new();
        acc.feed(&((64u32 << 20) + 1).to_le_bytes());
        assert!(acc.next_frame().is_err());
        // Malformed payload under a valid length prefix.
        let mut acc = FrameAccumulator::new();
        acc.feed(&2u32.to_le_bytes());
        acc.feed(&[99, 99]); // unknown tag + trailing byte
        assert!(acc.next_frame().is_err());
    }

    #[test]
    fn frame_accumulator_handles_every_two_chunk_split() {
        // Adversarial chunk boundary: a two-frame stream cut at every
        // possible offset into two reads — including cuts inside the
        // second frame's length prefix — must decode identically.
        let msgs = [draft(0, 1), draft(1, 2)];
        let mut wire = Vec::new();
        for m in &msgs {
            m.encode_into(&mut wire);
        }
        for cut in 0..=wire.len() {
            let mut acc = FrameAccumulator::new();
            let mut got = Vec::new();
            acc.feed(&wire[..cut]);
            while let Some(m) = acc.next_frame().unwrap() {
                got.push(m);
            }
            acc.feed(&wire[cut..]);
            while let Some(m) = acc.next_frame().unwrap() {
                got.push(m);
            }
            assert_eq!(got.as_slice(), msgs.as_slice(), "stream split at byte {cut}");
        }
    }

    #[test]
    fn frame_accumulator_survives_connection_drop_mid_frame() {
        // A peer dying mid-frame leaves a torn tail in the accumulator:
        // the complete frame before it must already have decoded, the
        // tail must never surface as a frame or an error, and a
        // reconnect (fresh accumulator) re-fed from the frame boundary
        // decodes cleanly. Exercised at every drop offset inside the
        // second frame, including inside its length prefix.
        let mut wire = Vec::new();
        draft(1, 7).encode_into(&mut wire);
        let boundary = wire.len();
        draft(2, 8).encode_into(&mut wire);
        for cut in boundary..wire.len() {
            let mut acc = FrameAccumulator::new();
            acc.feed(&wire[..cut]);
            assert_eq!(acc.next_frame().unwrap(), Some(draft(1, 7)));
            assert_eq!(acc.next_frame().unwrap(), None, "torn frame surfaced at cut {cut}");
            drop(acc); // the connection drops; the partial tail dies with it
            let mut acc = FrameAccumulator::new();
            acc.feed(&wire[boundary..]);
            assert_eq!(acc.next_frame().unwrap(), Some(draft(2, 8)));
            assert_eq!(acc.next_frame().unwrap(), None);
        }
    }

    #[test]
    fn tcp_batch_drain_preserves_per_client_order() {
        // A burst of frames from one client — likely coalesced into few
        // reads on the loopback socket — arrives in round order.
        let mut t = TcpTransport::new(1).unwrap();
        let rounds = 50u64;
        for r in 0..rounds {
            t.ports[0].send(&draft(0, r)).unwrap();
        }
        for expect in 0..rounds {
            let (id, msg) = t.server.rx.recv().unwrap();
            assert_eq!(id, 0);
            match msg {
                Message::Draft(d) => assert_eq!(d.round, expect, "reordered"),
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn tcp_large_payload() {
        let mut t = TcpTransport::new(1).unwrap();
        let big = Message::Draft(DraftMsg {
            client_id: 0,
            round: 1,
            prefix: vec![5; 200],
            prompt_len: 10,
            draft: vec![1; 32],
            parents: Vec::new(),
            q_probs: vec![0.1; 32 * 256], // 32 KiB — the paper's q payload
            new_request: false,
            draft_wall_ns: 0,
        });
        t.ports[0].send(&big).unwrap();
        let (_, got) = t.server.rx.recv().unwrap();
        assert_eq!(got, big);
    }
}
