//! Binary wire format (hand-rolled; no serde in the offline crate set).
//!
//! Length-prefixed frames: `u32 LE total-length | u8 tag | payload`.
//! Numbers are little-endian; vectors are `u32 LE count` + raw elements.
//! Used verbatim by the TCP transport and for exact byte accounting by the
//! in-process transport.

use anyhow::{anyhow, Result};

/// Coordinator ⇄ draft-server protocol.
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Draft server → coordinator: one round's speculative batch.
    Draft(DraftMsg),
    /// Coordinator → draft server: verdict + next-round allocation.
    Verdict(VerdictMsg),
    /// Orderly end of stream.
    Shutdown,
}

#[derive(Clone, Debug, PartialEq)]
pub struct DraftMsg {
    pub client_id: u32,
    pub round: u64,
    /// Full current prefix (prompt + accepted output so far).
    pub prefix: Vec<u8>,
    /// Length of the prompt within `prefix`.
    pub prompt_len: u32,
    /// Drafted tokens (length = this round's allocation, may be 0).
    pub draft: Vec<u8>,
    /// Proposal distributions, row-major `[draft.len() * vocab]` — the
    /// dominant payload (the paper's transmission-cost observation).
    pub q_probs: Vec<f32>,
    /// True when `prefix` starts a fresh request.
    pub new_request: bool,
    /// Draft-side compute time for this batch (ns), for metrics.
    pub draft_wall_ns: u64,
}

#[derive(Clone, Debug, PartialEq)]
pub struct VerdictMsg {
    pub client_id: u32,
    pub round: u64,
    /// Accepted draft prefix length m.
    pub accepted: u32,
    /// Correction (m < S) or bonus (m == S) token.
    pub correction: u8,
    /// Next-round draft allocation S_i(t+1).
    pub next_alloc: u32,
    /// Verification shard that served this verdict (0 outside pooled
    /// deployments). Lets a client observe rebalancing — in a multi-host
    /// pool this is where a redirect endpoint would ride.
    pub shard: u32,
}

const TAG_DRAFT: u8 = 1;
const TAG_VERDICT: u8 = 2;
const TAG_SHUTDOWN: u8 = 3;

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new() -> Self {
        Writer { buf: Vec::with_capacity(256) }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }

    fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn u8(&mut self) -> Result<u8> {
        let v = *self.buf.get(self.pos).ok_or_else(|| anyhow!("wire: eof"))?;
        self.pos += 1;
        Ok(v)
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(anyhow!("wire: eof (want {n} at {})", self.pos));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

impl Message {
    /// Encode to a length-prefixed frame.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.u32(0); // frame length placeholder
        match self {
            Message::Draft(d) => {
                w.u8(TAG_DRAFT);
                w.u32(d.client_id);
                w.u64(d.round);
                w.bytes(&d.prefix);
                w.u32(d.prompt_len);
                w.bytes(&d.draft);
                w.f32s(&d.q_probs);
                w.u8(d.new_request as u8);
                w.u64(d.draft_wall_ns);
            }
            Message::Verdict(v) => {
                w.u8(TAG_VERDICT);
                w.u32(v.client_id);
                w.u64(v.round);
                w.u32(v.accepted);
                w.u8(v.correction);
                w.u32(v.next_alloc);
                w.u32(v.shard);
            }
            Message::Shutdown => w.u8(TAG_SHUTDOWN),
        }
        let total = (w.buf.len() - 4) as u32;
        w.buf[..4].copy_from_slice(&total.to_le_bytes());
        w.buf
    }

    /// Decode the payload of one frame (without the 4-byte length prefix).
    pub fn decode(payload: &[u8]) -> Result<Message> {
        let mut r = Reader { buf: payload, pos: 0 };
        let msg = match r.u8()? {
            TAG_DRAFT => Message::Draft(DraftMsg {
                client_id: r.u32()?,
                round: r.u64()?,
                prefix: r.bytes()?,
                prompt_len: r.u32()?,
                draft: r.bytes()?,
                q_probs: r.f32s()?,
                new_request: r.u8()? != 0,
                draft_wall_ns: r.u64()?,
            }),
            TAG_VERDICT => Message::Verdict(VerdictMsg {
                client_id: r.u32()?,
                round: r.u64()?,
                accepted: r.u32()?,
                correction: r.u8()?,
                next_alloc: r.u32()?,
                shard: r.u32()?,
            }),
            TAG_SHUTDOWN => Message::Shutdown,
            t => return Err(anyhow!("wire: unknown tag {t}")),
        };
        if !r.done() {
            return Err(anyhow!("wire: trailing bytes"));
        }
        Ok(msg)
    }

    /// Encoded size (for network-delay accounting without encoding).
    pub fn wire_bytes(&self) -> usize {
        match self {
            Message::Draft(d) => {
                4 + 1 + 4 + 8 + (4 + d.prefix.len()) + 4 + (4 + d.draft.len())
                    + (4 + d.q_probs.len() * 4) + 1 + 8
            }
            Message::Verdict(_) => 4 + 1 + 4 + 8 + 4 + 1 + 4 + 4,
            Message::Shutdown => 4 + 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    fn sample_draft(rng: &mut crate::util::Rng) -> DraftMsg {
        let s = rng.below(6) as usize;
        let v = 16usize;
        DraftMsg {
            client_id: rng.below(8) as u32,
            round: rng.next_u64() % 1000,
            prefix: (0..rng.below(40)).map(|_| rng.below(256) as u8).collect(),
            prompt_len: rng.below(20) as u32,
            draft: (0..s).map(|_| rng.below(256) as u8).collect(),
            q_probs: (0..s * v).map(|_| rng.f32()).collect(),
            new_request: rng.bool(0.5),
            draft_wall_ns: rng.next_u64() % 1_000_000,
        }
    }

    #[test]
    fn prop_roundtrip() {
        proptest::check("wire_roundtrip", proptest::default_cases(), |rng| {
            let msgs = [
                Message::Draft(sample_draft(rng)),
                Message::Verdict(VerdictMsg {
                    client_id: rng.below(8) as u32,
                    round: rng.next_u64() % 1000,
                    accepted: rng.below(33) as u32,
                    correction: rng.below(256) as u8,
                    next_alloc: rng.below(33) as u32,
                    shard: rng.below(8) as u32,
                }),
                Message::Shutdown,
            ];
            for m in msgs {
                let frame = m.encode();
                let len = u32::from_le_bytes(frame[..4].try_into().unwrap()) as usize;
                assert_eq!(len, frame.len() - 4);
                assert_eq!(len + 4, m.wire_bytes(), "wire_bytes must match encode");
                let back = Message::decode(&frame[4..]).unwrap();
                assert_eq!(m, back);
            }
        });
    }

    #[test]
    fn decode_rejects_corruption() {
        let frame = Message::Shutdown.encode();
        assert!(Message::decode(&frame[4..]).is_ok());
        assert!(Message::decode(&[99]).is_err());
        assert!(Message::decode(&[]).is_err());
        // truncated draft
        let d = Message::Draft(DraftMsg {
            client_id: 0,
            round: 0,
            prefix: vec![1, 2, 3],
            prompt_len: 3,
            draft: vec![4],
            q_probs: vec![0.5; 16],
            new_request: false,
            draft_wall_ns: 0,
        });
        let frame = d.encode();
        assert!(Message::decode(&frame[4..frame.len() - 2]).is_err());
        // trailing garbage
        let mut long = frame[4..].to_vec();
        long.push(0);
        assert!(Message::decode(&long).is_err());
    }
}
