//! Binary wire format (hand-rolled; no serde in the offline crate set).
//!
//! Length-prefixed frames: `u32 LE total-length | u8 tag | payload`.
//! Numbers are little-endian; vectors are `u32 LE count` + raw elements.
//! Used verbatim by the TCP transport and for exact byte accounting by the
//! in-process transport.
//!
//! Decoding is total: malformed bytes, unknown tags (a newer peer may
//! speak frame kinds this build has never heard of), and control frames
//! declaring a newer protocol version all surface as a typed
//! [`WireError`], never a panic.
//!
//! **Control frames** (session churn): a dynamically attached draft server
//! opens with [`Message::Join`] — the hello, carrying the protocol version
//! byte — and waits for [`Message::JoinAck`] before drafting; the
//! coordinator ends a graceful drain with [`Message::Leave`] after the
//! client's final verdict. Statically configured clients skip the
//! handshake, keeping the legacy frame stream byte-for-byte identical.

pub use crate::error::WireError;

// (No `anyhow` in this module: the decode path is fully typed.)

/// Highest wire-protocol version this build speaks. The hello
/// ([`Message::Join`]) carries the client's version; anything newer than
/// this decodes to [`WireError::UnsupportedVersion`].
pub const PROTOCOL_VERSION: u8 = 1;

/// Coordinator ⇄ draft-server protocol.
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Draft server → coordinator: one round's speculative batch.
    Draft(DraftMsg),
    /// Coordinator → draft server: verdict + next-round allocation.
    Verdict(VerdictMsg),
    /// Orderly end of stream.
    Shutdown,
    /// Draft server → coordinator: session hello (dynamic attach).
    Join(JoinMsg),
    /// Coordinator → draft server: hello accepted; start drafting.
    JoinAck(JoinAckMsg),
    /// Coordinator → draft server: graceful-drain complete — the final
    /// verdict has been delivered and the session is retired.
    Leave(LeaveMsg),
}

/// Session hello: the first frame a dynamically attached client sends.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct JoinMsg {
    pub client_id: u32,
    /// Wire-protocol version the client speaks (see [`PROTOCOL_VERSION`]).
    pub protocol: u8,
}

/// Hello acknowledgement: grants the session and its first allocation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct JoinAckMsg {
    pub client_id: u32,
    /// Protocol version the coordinator speaks.
    pub protocol: u8,
    /// First draft allocation S_i(0) for the new session.
    pub initial_alloc: u32,
    /// Membership epoch the session was admitted in.
    pub epoch: u64,
}

/// Graceful-drain completion: the session is retired.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LeaveMsg {
    pub client_id: u32,
    /// Membership epoch after the departure.
    pub epoch: u64,
}

#[derive(Clone, Debug, PartialEq)]
pub struct DraftMsg {
    pub client_id: u32,
    pub round: u64,
    /// Full current prefix (prompt + accepted output so far).
    pub prefix: Vec<u8>,
    /// Length of the prompt within `prefix`.
    pub prompt_len: u32,
    /// Drafted tokens — one per tree node, in node-index order (length =
    /// this round's node allocation, may be 0).
    pub draft: Vec<u8>,
    /// Tree topology as a compact parent-index array (one byte per node;
    /// `0xFF` = child of the root — `spec::tree::NO_PARENT`). **Empty =
    /// linear chain**: chain drafts omit the topology entirely and are
    /// encoded with the legacy [`TAG_DRAFT`] frame, byte-for-byte
    /// identical to the pre-tree wire format.
    pub parents: Vec<u8>,
    /// Proposal distributions, row-major `[draft.len() * vocab]` — the
    /// dominant payload (the paper's transmission-cost observation).
    pub q_probs: Vec<f32>,
    /// True when `prefix` starts a fresh request.
    pub new_request: bool,
    /// Draft-side compute time for this batch (ns), for metrics.
    pub draft_wall_ns: u64,
}

#[derive(Clone, Debug, PartialEq)]
pub struct VerdictMsg {
    pub client_id: u32,
    pub round: u64,
    /// Accepted draft tokens m (tree: accepted root-path depth).
    pub accepted: u32,
    /// Accepted root-path node indices, root → leaf order (one byte per
    /// node id). **Empty for chain verdicts** — a chain's accepted path is
    /// implied by `accepted`, and the legacy [`TAG_VERDICT`] frame stays
    /// byte-for-byte identical.
    pub path: Vec<u8>,
    /// Correction (rejection) or bonus (full path accepted) token.
    pub correction: u8,
    /// Next-round draft allocation S_i(t+1).
    pub next_alloc: u32,
    /// Verification shard that served this verdict (0 outside pooled
    /// deployments). Lets a client observe rebalancing — in a multi-host
    /// pool this is where a redirect endpoint would ride.
    pub shard: u32,
}

/// Legacy chain draft (no topology; byte-identical to the pre-tree frame).
pub const TAG_DRAFT: u8 = 1;
/// Legacy chain verdict (no path).
pub const TAG_VERDICT: u8 = 2;
/// Orderly end of stream.
pub const TAG_SHUTDOWN: u8 = 3;
/// A draft carrying an explicit tree topology (non-empty `parents`).
pub const TAG_DRAFT_TREE: u8 = 4;
/// A verdict carrying an explicit accepted path (non-empty `path`).
pub const TAG_VERDICT_TREE: u8 = 5;
/// Session hello (dynamic attach); carries the protocol-version byte.
pub const TAG_JOIN: u8 = 6;
/// Hello acknowledgement.
pub const TAG_JOIN_ACK: u8 = 7;
/// Graceful-drain completion.
pub const TAG_LEAVE: u8 = 8;

/// Frame writer appending into a caller-owned buffer, so the coalescing
/// send path can pack many frames into one recycled allocation.
struct Writer<'a> {
    buf: &'a mut Vec<u8>,
}

impl Writer<'_> {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }

    fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn u8(&mut self) -> Result<u8, WireError> {
        let v = *self
            .buf
            .get(self.pos)
            .ok_or(WireError::Eof { want: 1, at: self.pos })?;
        self.pos += 1;
        Ok(v)
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.pos + n > self.buf.len() {
            return Err(WireError::Eof { want: n, at: self.pos });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4-byte slice")))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8-byte slice")))
    }

    /// Borrowed length-prefixed byte vector (`u32 LE count` + raw bytes).
    fn bytes(&mut self) -> Result<&'a [u8], WireError> {
        let n = self.u32()? as usize;
        self.take(n)
    }

    /// Borrowed length-prefixed f32 vector, returned as its raw
    /// little-endian bytes (`count * 4` long). Deferring the f32
    /// conversion keeps the parse zero-copy: `&[u8]` has no alignment
    /// requirement, while a `&[f32]` reinterpretation of an arbitrary
    /// frame offset would.
    fn f32s_le(&mut self) -> Result<&'a [u8], WireError> {
        let n = self.u32()? as usize;
        self.take(n * 4)
    }

    fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

/// Decode raw little-endian f32 bytes (as returned by
/// [`DraftView::q_probs_le`]) into `out`, reusing its capacity. The
/// byte-wise `from_le_bytes` loop compiles to a straight copy on
/// little-endian targets and stays correct on big-endian ones.
pub fn copy_f32s_le(raw: &[u8], out: &mut Vec<f32>) {
    debug_assert_eq!(raw.len() % 4, 0);
    out.clear();
    out.reserve(raw.len() / 4);
    out.extend(raw.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])));
}

/// Reject a control frame claiming a newer protocol than we speak.
fn check_version(got: u8) -> Result<u8, WireError> {
    if got > PROTOCOL_VERSION {
        Err(WireError::UnsupportedVersion { got, supported: PROTOCOL_VERSION })
    } else {
        Ok(got)
    }
}

/// Zero-copy draft frame: every variable-length field borrows the wire
/// payload. The dominant field — the `[draft.len() * vocab]` proposal
/// matrix — stays as raw little-endian bytes (`q_probs_le`) so parsing
/// never copies it; convert with [`copy_f32s_le`] only where f32s are
/// actually consumed (the estimator/judging boundary).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DraftView<'a> {
    pub client_id: u32,
    pub round: u64,
    pub prefix: &'a [u8],
    pub prompt_len: u32,
    pub draft: &'a [u8],
    /// Empty = linear chain (see [`DraftMsg::parents`]).
    pub parents: &'a [u8],
    /// Raw little-endian bytes of the proposal matrix
    /// (`draft.len() * vocab * 4` long).
    pub q_probs_le: &'a [u8],
    pub new_request: bool,
    pub draft_wall_ns: u64,
}

impl DraftView<'_> {
    /// Copy into an owned [`DraftMsg`] (allocates; off the hot path).
    pub fn to_msg(self) -> DraftMsg {
        let mut q_probs = Vec::new();
        copy_f32s_le(self.q_probs_le, &mut q_probs);
        DraftMsg {
            client_id: self.client_id,
            round: self.round,
            prefix: self.prefix.to_vec(),
            prompt_len: self.prompt_len,
            draft: self.draft.to_vec(),
            parents: self.parents.to_vec(),
            q_probs,
            new_request: self.new_request,
            draft_wall_ns: self.draft_wall_ns,
        }
    }
}

/// Zero-copy verdict frame (the accepted path borrows the payload).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct VerdictView<'a> {
    pub client_id: u32,
    pub round: u64,
    pub accepted: u32,
    /// Empty for chain verdicts (see [`VerdictMsg::path`]).
    pub path: &'a [u8],
    pub correction: u8,
    pub next_alloc: u32,
    pub shard: u32,
}

impl VerdictView<'_> {
    /// Copy into an owned [`VerdictMsg`].
    pub fn to_msg(self) -> VerdictMsg {
        VerdictMsg {
            client_id: self.client_id,
            round: self.round,
            accepted: self.accepted,
            path: self.path.to_vec(),
            correction: self.correction,
            next_alloc: self.next_alloc,
            shard: self.shard,
        }
    }
}

/// Zero-copy decoded frame. [`FrameView::parse`] reads a frame payload
/// without allocating: the bulk variants (`Draft`, `Verdict`) borrow
/// every variable-length field, and the control variants carry their
/// handful of fixed-width fields by value. [`Message::decode`] is the
/// owned wrapper; both share the exact same read order, validation, and
/// typed [`WireError`]s.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FrameView<'a> {
    Draft(DraftView<'a>),
    Verdict(VerdictView<'a>),
    Shutdown,
    Join(JoinMsg),
    JoinAck(JoinAckMsg),
    Leave(LeaveMsg),
}

impl<'a> FrameView<'a> {
    /// Parse the payload of one frame (without the 4-byte length prefix)
    /// without copying any variable-length field. Total: malformed input
    /// yields a typed [`WireError`], never a panic.
    pub fn parse(payload: &'a [u8]) -> Result<FrameView<'a>, WireError> {
        let mut r = Reader { buf: payload, pos: 0 };
        let view = match r.u8()? {
            tag @ (TAG_DRAFT | TAG_DRAFT_TREE) => {
                let client_id = r.u32()?;
                let round = r.u64()?;
                let prefix = r.bytes()?;
                let prompt_len = r.u32()?;
                let draft = r.bytes()?;
                let parents: &[u8] =
                    if tag == TAG_DRAFT_TREE { r.bytes()? } else { &[] };
                if tag == TAG_DRAFT_TREE && parents.len() != draft.len() {
                    return Err(WireError::Malformed(format!(
                        "tree draft with {} parents for {} nodes",
                        parents.len(),
                        draft.len()
                    )));
                }
                FrameView::Draft(DraftView {
                    client_id,
                    round,
                    prefix,
                    prompt_len,
                    draft,
                    parents,
                    q_probs_le: r.f32s_le()?,
                    new_request: r.u8()? != 0,
                    draft_wall_ns: r.u64()?,
                })
            }
            tag @ (TAG_VERDICT | TAG_VERDICT_TREE) => {
                let client_id = r.u32()?;
                let round = r.u64()?;
                let accepted = r.u32()?;
                let path: &[u8] =
                    if tag == TAG_VERDICT_TREE { r.bytes()? } else { &[] };
                FrameView::Verdict(VerdictView {
                    client_id,
                    round,
                    accepted,
                    path,
                    correction: r.u8()?,
                    next_alloc: r.u32()?,
                    shard: r.u32()?,
                })
            }
            TAG_SHUTDOWN => FrameView::Shutdown,
            TAG_JOIN => {
                let client_id = r.u32()?;
                let protocol = check_version(r.u8()?)?;
                FrameView::Join(JoinMsg { client_id, protocol })
            }
            TAG_JOIN_ACK => {
                let client_id = r.u32()?;
                let protocol = check_version(r.u8()?)?;
                FrameView::JoinAck(JoinAckMsg {
                    client_id,
                    protocol,
                    initial_alloc: r.u32()?,
                    epoch: r.u64()?,
                })
            }
            TAG_LEAVE => {
                FrameView::Leave(LeaveMsg { client_id: r.u32()?, epoch: r.u64()? })
            }
            t => return Err(WireError::UnknownTag(t)),
        };
        if !r.done() {
            return Err(WireError::TrailingBytes(r.buf.len() - r.pos));
        }
        Ok(view)
    }

    /// Copy into an owned [`Message`] (allocates for the bulk variants).
    pub fn to_msg(self) -> Message {
        match self {
            FrameView::Draft(d) => Message::Draft(d.to_msg()),
            FrameView::Verdict(v) => Message::Verdict(v.to_msg()),
            FrameView::Shutdown => Message::Shutdown,
            FrameView::Join(j) => Message::Join(j),
            FrameView::JoinAck(a) => Message::JoinAck(a),
            FrameView::Leave(l) => Message::Leave(l),
        }
    }
}

impl Message {
    /// Encode to a length-prefixed frame.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(256);
        self.encode_into(&mut out);
        out
    }

    /// Append this message's length-prefixed frame to `out`. The buffer
    /// is *not* cleared: the coalescing send path packs every frame bound
    /// for one destination into a single recycled buffer and flushes it
    /// with one write. The appended bytes are identical to what
    /// [`Message::encode`] returns.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        let start = out.len();
        let mut w = Writer { buf: out };
        w.u32(0); // frame length placeholder
        match self {
            Message::Draft(d) => {
                // Chain drafts keep the legacy frame byte-for-byte; a tree
                // frame inserts the parent array after the drafted tokens.
                w.u8(if d.parents.is_empty() { TAG_DRAFT } else { TAG_DRAFT_TREE });
                w.u32(d.client_id);
                w.u64(d.round);
                w.bytes(&d.prefix);
                w.u32(d.prompt_len);
                w.bytes(&d.draft);
                if !d.parents.is_empty() {
                    w.bytes(&d.parents);
                }
                w.f32s(&d.q_probs);
                w.u8(d.new_request as u8);
                w.u64(d.draft_wall_ns);
            }
            Message::Verdict(v) => {
                w.u8(if v.path.is_empty() { TAG_VERDICT } else { TAG_VERDICT_TREE });
                w.u32(v.client_id);
                w.u64(v.round);
                w.u32(v.accepted);
                if !v.path.is_empty() {
                    w.bytes(&v.path);
                }
                w.u8(v.correction);
                w.u32(v.next_alloc);
                w.u32(v.shard);
            }
            Message::Shutdown => w.u8(TAG_SHUTDOWN),
            Message::Join(j) => {
                w.u8(TAG_JOIN);
                w.u32(j.client_id);
                w.u8(j.protocol);
            }
            Message::JoinAck(a) => {
                w.u8(TAG_JOIN_ACK);
                w.u32(a.client_id);
                w.u8(a.protocol);
                w.u32(a.initial_alloc);
                w.u64(a.epoch);
            }
            Message::Leave(l) => {
                w.u8(TAG_LEAVE);
                w.u32(l.client_id);
                w.u64(l.epoch);
            }
        }
        let total = (w.buf.len() - start - 4) as u32;
        w.buf[start..start + 4].copy_from_slice(&total.to_le_bytes());
    }

    /// Decode the payload of one frame (without the 4-byte length prefix)
    /// into an owned [`Message`]. Total: every failure mode is a typed
    /// [`WireError`]. This is the convenience wrapper over the zero-copy
    /// [`FrameView::parse`]; hot paths that can consume borrowed payloads
    /// should parse a [`FrameView`] instead and convert only what they
    /// keep.
    pub fn decode(payload: &[u8]) -> Result<Message, WireError> {
        FrameView::parse(payload).map(|v| v.to_msg())
    }

    /// Encoded size (for network-delay accounting without encoding).
    pub fn wire_bytes(&self) -> usize {
        match self {
            Message::Draft(d) => {
                let topology =
                    if d.parents.is_empty() { 0 } else { 4 + d.parents.len() };
                4 + 1 + 4 + 8 + (4 + d.prefix.len()) + 4 + (4 + d.draft.len())
                    + topology + (4 + d.q_probs.len() * 4) + 1 + 8
            }
            Message::Verdict(v) => {
                let path = if v.path.is_empty() { 0 } else { 4 + v.path.len() };
                4 + 1 + 4 + 8 + 4 + path + 1 + 4 + 4
            }
            Message::Shutdown => 4 + 1,
            Message::Join(_) => 4 + 1 + 4 + 1,
            Message::JoinAck(_) => 4 + 1 + 4 + 1 + 4 + 8,
            Message::Leave(_) => 4 + 1 + 4 + 8,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    fn sample_draft(rng: &mut crate::util::Rng) -> DraftMsg {
        let s = rng.below(6) as usize;
        let v = 16usize;
        DraftMsg {
            client_id: rng.below(8) as u32,
            round: rng.next_u64() % 1000,
            prefix: (0..rng.below(40)).map(|_| rng.below(256) as u8).collect(),
            prompt_len: rng.below(20) as u32,
            draft: (0..s).map(|_| rng.below(256) as u8).collect(),
            parents: Vec::new(),
            q_probs: (0..s * v).map(|_| rng.f32()).collect(),
            new_request: rng.bool(0.5),
            draft_wall_ns: rng.next_u64() % 1_000_000,
        }
    }

    /// A draft carrying a random (valid) tree topology.
    fn sample_tree_draft(rng: &mut crate::util::Rng) -> DraftMsg {
        use crate::spec::tree::DraftTree;
        let arity = rng.below(3) as usize + 1;
        let depth = rng.below(4) as usize + 1;
        let budget = rng.below(12) as usize + 1;
        let tree = DraftTree::shaped(arity, depth, budget, 32, 16);
        let mut d = sample_draft(rng);
        d.draft = (0..tree.len()).map(|_| rng.below(256) as u8).collect();
        d.parents = tree.parents().to_vec();
        d.q_probs = (0..tree.len() * 16).map(|_| rng.f32()).collect();
        d
    }

    fn roundtrip(m: &Message) {
        let frame = m.encode();
        let len = u32::from_le_bytes(frame[..4].try_into().unwrap()) as usize;
        assert_eq!(len, frame.len() - 4);
        assert_eq!(len + 4, m.wire_bytes(), "wire_bytes must match encode");
        let back = Message::decode(&frame[4..]).unwrap();
        assert_eq!(*m, back);
    }

    #[test]
    fn prop_roundtrip() {
        proptest::check("wire_roundtrip", proptest::default_cases(), |rng| {
            let msgs = [
                Message::Draft(sample_draft(rng)),
                Message::Verdict(VerdictMsg {
                    client_id: rng.below(8) as u32,
                    round: rng.next_u64() % 1000,
                    accepted: rng.below(33) as u32,
                    path: Vec::new(),
                    correction: rng.below(256) as u8,
                    next_alloc: rng.below(33) as u32,
                    shard: rng.below(8) as u32,
                }),
                Message::Shutdown,
            ];
            for m in msgs {
                roundtrip(&m);
            }
        });
    }

    /// Tree topologies round-trip (parents and accepted paths survive, and
    /// the decoded topology reconstructs the same `DraftTree`).
    #[test]
    fn prop_tree_roundtrip() {
        use crate::spec::tree::DraftTree;
        proptest::check("wire_tree_roundtrip", proptest::default_cases(), |rng| {
            let d = sample_tree_draft(rng);
            let tree = DraftTree::from_parents(d.parents.clone()).unwrap();
            let m = Message::Draft(d);
            roundtrip(&m);
            if let Message::Draft(back) =
                Message::decode(&m.encode()[4..]).unwrap()
            {
                assert_eq!(DraftTree::from_parents(back.parents).unwrap(), tree);
            } else {
                panic!("decoded to a different variant");
            }
            let depth = rng.below(6) as usize;
            let v = Message::Verdict(VerdictMsg {
                client_id: rng.below(8) as u32,
                round: rng.next_u64() % 1000,
                accepted: depth as u32,
                path: (0..depth).map(|i| i as u8).collect(),
                correction: rng.below(256) as u8,
                next_alloc: rng.below(33) as u32,
                shard: rng.below(8) as u32,
            });
            roundtrip(&v);
        });
    }

    #[test]
    fn chain_frames_are_bit_identical_to_legacy_layout() {
        // The legacy TAG_DRAFT/TAG_VERDICT byte layouts are load-bearing:
        // chain-mode runs must produce the exact pre-tree frames (same
        // tags, same sizes — the delay model sleeps on these bytes).
        let d = DraftMsg {
            client_id: 3,
            round: 7,
            prefix: vec![1, 2, 3],
            prompt_len: 3,
            draft: vec![4, 5],
            parents: Vec::new(),
            q_probs: vec![0.5; 32],
            new_request: true,
            draft_wall_ns: 99,
        };
        let frame = Message::Draft(d.clone()).encode();
        assert_eq!(frame[4], 1); // TAG_DRAFT
        assert_eq!(
            frame.len(),
            4 + 1 + 4 + 8 + (4 + 3) + 4 + (4 + 2) + (4 + 32 * 4) + 1 + 8
        );
        let mut tree_d = d;
        tree_d.parents = vec![255, 0];
        let tree_frame = Message::Draft(tree_d).encode();
        assert_eq!(tree_frame[4], 4); // TAG_DRAFT_TREE
        assert_eq!(tree_frame.len(), frame.len() + 4 + 2);
        let v = VerdictMsg {
            client_id: 0,
            round: 1,
            accepted: 2,
            path: Vec::new(),
            correction: 9,
            next_alloc: 4,
            shard: 0,
        };
        let vframe = Message::Verdict(v.clone()).encode();
        assert_eq!(vframe[4], 2); // TAG_VERDICT
        assert_eq!(vframe.len(), 4 + 1 + 4 + 8 + 4 + 1 + 4 + 4);
        let mut tv = v;
        tv.path = vec![0, 1];
        let tvframe = Message::Verdict(tv).encode();
        assert_eq!(tvframe[4], 5); // TAG_VERDICT_TREE
        assert_eq!(tvframe.len(), vframe.len() + 4 + 2);
    }

    #[test]
    fn tree_draft_with_mismatched_parents_rejected() {
        let mut d = DraftMsg {
            client_id: 0,
            round: 0,
            prefix: vec![1],
            prompt_len: 1,
            draft: vec![2, 3],
            parents: vec![255, 0],
            q_probs: vec![0.5; 32],
            new_request: false,
            draft_wall_ns: 0,
        };
        let frame = Message::Draft(d.clone()).encode();
        assert!(Message::decode(&frame[4..]).is_ok());
        // Corrupt: drop one draft token so counts disagree.
        d.draft.pop();
        d.q_probs.truncate(16);
        let frame = Message::Draft(d).encode();
        assert!(Message::decode(&frame[4..]).is_err());
    }

    /// Control frames (hello / ack / leave) round-trip, including their
    /// exact `wire_bytes` accounting.
    #[test]
    fn prop_control_frame_roundtrip() {
        proptest::check("wire_control_roundtrip", proptest::default_cases(), |rng| {
            let msgs = [
                Message::Join(JoinMsg {
                    client_id: rng.below(1024) as u32,
                    protocol: PROTOCOL_VERSION,
                }),
                Message::JoinAck(JoinAckMsg {
                    client_id: rng.below(1024) as u32,
                    protocol: PROTOCOL_VERSION,
                    initial_alloc: rng.below(33) as u32,
                    epoch: rng.next_u64() % 10_000,
                }),
                Message::Leave(LeaveMsg {
                    client_id: rng.below(1024) as u32,
                    epoch: rng.next_u64() % 10_000,
                }),
            ];
            for m in msgs {
                roundtrip(&m);
            }
        });
    }

    /// Forward compatibility: frames from a newer peer — an unknown tag or
    /// a newer protocol version — decode to a typed error, never a panic.
    #[test]
    fn unknown_tag_and_newer_version_are_typed_errors() {
        // Unknown tag: every undefined tag byte (arbitrary payload after).
        for tag in 9u8..=255 {
            let payload = [tag, 1, 2, 3, 4];
            match Message::decode(&payload) {
                Err(WireError::UnknownTag(t)) => assert_eq!(t, tag),
                other => panic!("tag {tag}: expected UnknownTag, got {other:?}"),
            }
        }
        // Tag 0 was never assigned either.
        assert_eq!(Message::decode(&[0]), Err(WireError::UnknownTag(0)));
        // Newer protocol version in the hello: encode a valid Join, then
        // bump its version byte past ours.
        let join = Message::Join(JoinMsg { client_id: 3, protocol: PROTOCOL_VERSION });
        let mut payload = join.encode()[4..].to_vec();
        let vpos = payload.len() - 1; // protocol is the last byte
        payload[vpos] = PROTOCOL_VERSION + 1;
        match Message::decode(&payload) {
            Err(WireError::UnsupportedVersion { got, supported }) => {
                assert_eq!(got, PROTOCOL_VERSION + 1);
                assert_eq!(supported, PROTOCOL_VERSION);
            }
            other => panic!("expected UnsupportedVersion, got {other:?}"),
        }
        // Same for the ack (version sits mid-frame there).
        let ack = Message::JoinAck(JoinAckMsg {
            client_id: 1,
            protocol: PROTOCOL_VERSION,
            initial_alloc: 4,
            epoch: 9,
        });
        let mut payload = ack.encode()[4..].to_vec();
        payload[5] = PROTOCOL_VERSION + 7; // tag(1) + client_id(4), then version
        assert!(matches!(
            Message::decode(&payload),
            Err(WireError::UnsupportedVersion { .. })
        ));
    }

    /// Random byte soup never panics the decoder — it returns some typed
    /// error (or, rarely, a valid frame).
    #[test]
    fn prop_decode_is_total_on_garbage() {
        proptest::check("wire_decode_total", proptest::default_cases(), |rng| {
            let len = rng.below(64) as usize;
            let payload: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            let _ = Message::decode(&payload); // must not panic
        });
    }

    #[test]
    fn decode_rejects_corruption() {
        let frame = Message::Shutdown.encode();
        assert!(Message::decode(&frame[4..]).is_ok());
        assert!(Message::decode(&[99]).is_err());
        assert!(Message::decode(&[]).is_err());
        // truncated draft
        let d = Message::Draft(DraftMsg {
            client_id: 0,
            round: 0,
            prefix: vec![1, 2, 3],
            prompt_len: 3,
            draft: vec![4],
            parents: Vec::new(),
            q_probs: vec![0.5; 16],
            new_request: false,
            draft_wall_ns: 0,
        });
        let frame = d.encode();
        assert!(Message::decode(&frame[4..frame.len() - 2]).is_err());
        // trailing garbage
        let mut long = frame[4..].to_vec();
        long.push(0);
        assert!(Message::decode(&long).is_err());
    }

    /// The pre-`FrameView` owned decoder, kept verbatim as the oracle for
    /// the zero-copy rewrite: `FrameView::parse(..).map(to_msg)` must
    /// agree with it on every input — same messages, same typed errors.
    fn legacy_decode(payload: &[u8]) -> Result<Message, WireError> {
        struct OwnedReader<'a> {
            r: Reader<'a>,
        }
        impl OwnedReader<'_> {
            fn bytes(&mut self) -> Result<Vec<u8>, WireError> {
                Ok(self.r.bytes()?.to_vec())
            }
            fn f32s(&mut self) -> Result<Vec<f32>, WireError> {
                let n = self.r.u32()? as usize;
                let mut out = Vec::with_capacity(n);
                for _ in 0..n {
                    let raw = self.r.take(4)?;
                    out.push(f32::from_le_bytes(raw.try_into().expect("4-byte slice")));
                }
                Ok(out)
            }
        }
        let mut o = OwnedReader { r: Reader { buf: payload, pos: 0 } };
        let msg = match o.r.u8()? {
            tag @ (TAG_DRAFT | TAG_DRAFT_TREE) => {
                let client_id = o.r.u32()?;
                let round = o.r.u64()?;
                let prefix = o.bytes()?;
                let prompt_len = o.r.u32()?;
                let draft = o.bytes()?;
                let parents = if tag == TAG_DRAFT_TREE { o.bytes()? } else { Vec::new() };
                if tag == TAG_DRAFT_TREE && parents.len() != draft.len() {
                    return Err(WireError::Malformed(format!(
                        "tree draft with {} parents for {} nodes",
                        parents.len(),
                        draft.len()
                    )));
                }
                Message::Draft(DraftMsg {
                    client_id,
                    round,
                    prefix,
                    prompt_len,
                    draft,
                    parents,
                    q_probs: o.f32s()?,
                    new_request: o.r.u8()? != 0,
                    draft_wall_ns: o.r.u64()?,
                })
            }
            tag @ (TAG_VERDICT | TAG_VERDICT_TREE) => {
                let client_id = o.r.u32()?;
                let round = o.r.u64()?;
                let accepted = o.r.u32()?;
                let path = if tag == TAG_VERDICT_TREE { o.bytes()? } else { Vec::new() };
                Message::Verdict(VerdictMsg {
                    client_id,
                    round,
                    accepted,
                    path,
                    correction: o.r.u8()?,
                    next_alloc: o.r.u32()?,
                    shard: o.r.u32()?,
                })
            }
            TAG_SHUTDOWN => Message::Shutdown,
            TAG_JOIN => {
                let client_id = o.r.u32()?;
                let protocol = check_version(o.r.u8()?)?;
                Message::Join(JoinMsg { client_id, protocol })
            }
            TAG_JOIN_ACK => {
                let client_id = o.r.u32()?;
                let protocol = check_version(o.r.u8()?)?;
                Message::JoinAck(JoinAckMsg {
                    client_id,
                    protocol,
                    initial_alloc: o.r.u32()?,
                    epoch: o.r.u64()?,
                })
            }
            TAG_LEAVE => Message::Leave(LeaveMsg { client_id: o.r.u32()?, epoch: o.r.u64()? }),
            t => return Err(WireError::UnknownTag(t)),
        };
        if !o.r.done() {
            return Err(WireError::TrailingBytes(o.r.buf.len() - o.r.pos));
        }
        Ok(msg)
    }

    /// Zero-copy decode agrees with the legacy owned decoder on arbitrary
    /// valid frames of every message kind.
    #[test]
    fn prop_frameview_agrees_with_legacy_on_valid_frames() {
        proptest::check("wire_view_legacy_valid", proptest::default_cases(), |rng| {
            let msgs = [
                Message::Draft(sample_draft(rng)),
                Message::Draft(sample_tree_draft(rng)),
                Message::Verdict(VerdictMsg {
                    client_id: rng.below(8) as u32,
                    round: rng.next_u64() % 1000,
                    accepted: rng.below(33) as u32,
                    path: (0..rng.below(6)).map(|i| i as u8).collect(),
                    correction: rng.below(256) as u8,
                    next_alloc: rng.below(33) as u32,
                    shard: rng.below(8) as u32,
                }),
                Message::Shutdown,
                Message::Join(JoinMsg {
                    client_id: rng.below(1024) as u32,
                    protocol: PROTOCOL_VERSION,
                }),
                Message::JoinAck(JoinAckMsg {
                    client_id: rng.below(1024) as u32,
                    protocol: PROTOCOL_VERSION,
                    initial_alloc: rng.below(33) as u32,
                    epoch: rng.next_u64() % 10_000,
                }),
                Message::Leave(LeaveMsg {
                    client_id: rng.below(1024) as u32,
                    epoch: rng.next_u64() % 10_000,
                }),
            ];
            for m in msgs {
                let payload = &m.encode()[4..];
                assert_eq!(Message::decode(payload), legacy_decode(payload));
                assert_eq!(Message::decode(payload).unwrap(), m);
            }
        });
    }

    /// Zero-copy decode agrees with the legacy owned decoder on malformed
    /// input too: random byte soup, truncations of valid frames, and
    /// trailing garbage all yield the *same* typed `WireError` (and never
    /// panic).
    #[test]
    fn prop_frameview_agrees_with_legacy_on_malformed_input() {
        proptest::check("wire_view_legacy_malformed", proptest::default_cases(), |rng| {
            // Pure garbage.
            let len = rng.below(64) as usize;
            let garbage: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            assert_eq!(Message::decode(&garbage), legacy_decode(&garbage));
            // Every truncation of a valid frame (worst case: mid-field EOFs).
            let m = if rng.bool(0.5) {
                Message::Draft(sample_tree_draft(rng))
            } else {
                Message::Draft(sample_draft(rng))
            };
            let payload = &m.encode()[4..];
            let cut = rng.below(payload.len() as u64 + 1) as usize;
            assert_eq!(Message::decode(&payload[..cut]), legacy_decode(&payload[..cut]));
            // Trailing garbage after a complete frame.
            let mut long = payload.to_vec();
            long.push(rng.below(256) as u8);
            assert_eq!(Message::decode(&long), legacy_decode(&long));
            assert!(matches!(
                Message::decode(&long),
                Err(WireError::TrailingBytes(1))
            ));
        });
    }

    #[test]
    fn encode_into_appends_without_clearing() {
        let a = Message::Shutdown;
        let b = Message::Join(JoinMsg { client_id: 7, protocol: PROTOCOL_VERSION });
        let mut buf = vec![0xAA]; // pre-existing bytes must survive
        a.encode_into(&mut buf);
        b.encode_into(&mut buf);
        let mut expect = vec![0xAA];
        expect.extend(a.encode());
        expect.extend(b.encode());
        assert_eq!(buf, expect);
    }

    /// Transport hardening: a coalesced multi-frame stream — many
    /// messages packed into one send buffer by `encode_into` — is
    /// byte-identical to the per-frame encodes concatenated, both
    /// decoders agree on every framed payload, and re-splitting the
    /// stream at arbitrary read boundaries through the reader's
    /// [`FrameAccumulator`] recovers exactly the original frames in
    /// order.
    #[test]
    fn prop_coalesced_stream_survives_arbitrary_splits() {
        use crate::net::transport::FrameAccumulator;
        proptest::check("wire_coalesced_splits", proptest::default_cases(), |rng| {
            let n = rng.below(6) as usize + 1;
            let msgs: Vec<Message> = (0..n)
                .map(|_| match rng.below(4) {
                    0 => Message::Draft(sample_draft(rng)),
                    1 => Message::Draft(sample_tree_draft(rng)),
                    2 => Message::Verdict(VerdictMsg {
                        client_id: rng.below(8) as u32,
                        round: rng.next_u64() % 1000,
                        accepted: rng.below(33) as u32,
                        path: (0..rng.below(6)).map(|i| i as u8).collect(),
                        correction: rng.below(256) as u8,
                        next_alloc: rng.below(33) as u32,
                        shard: rng.below(8) as u32,
                    }),
                    _ => Message::Join(JoinMsg {
                        client_id: rng.below(64) as u32,
                        protocol: PROTOCOL_VERSION,
                    }),
                })
                .collect();
            // One coalesced send buffer…
            let mut wire = Vec::new();
            for m in &msgs {
                m.encode_into(&mut wire);
            }
            // …byte-identical to the per-frame encodes concatenated.
            let concat: Vec<u8> = msgs.iter().flat_map(|m| m.encode()).collect();
            assert_eq!(wire, concat);
            // Walk the stream by length prefix: the zero-copy decoder and
            // the legacy oracle agree on every framed payload.
            let mut pos = 0usize;
            let mut walked: Vec<Message> = Vec::new();
            while pos < wire.len() {
                let len =
                    u32::from_le_bytes(wire[pos..pos + 4].try_into().unwrap()) as usize;
                let payload = &wire[pos + 4..pos + 4 + len];
                assert_eq!(Message::decode(payload), legacy_decode(payload));
                walked.push(legacy_decode(payload).unwrap());
                pos += 4 + len;
            }
            assert_eq!(walked, msgs);
            // Short reads: feed the accumulator random-size chunks (frames
            // split mid-length-prefix, mid-payload, or many per chunk) and
            // drain completed frames as they materialize.
            let mut acc = FrameAccumulator::new();
            let mut got: Vec<Message> = Vec::new();
            let mut fed = 0usize;
            while fed < wire.len() {
                let chunk = (rng.below(40) as usize + 1).min(wire.len() - fed);
                acc.feed(&wire[fed..fed + chunk]);
                fed += chunk;
                while let Some(m) = acc.next_frame().unwrap() {
                    got.push(m);
                }
            }
            assert_eq!(got, msgs);
        });
    }

    /// The zero-copy parse itself never touches the heap (only meaningful
    /// under `--features alloc_track`; a no-op count otherwise).
    #[test]
    fn frameview_parse_is_allocation_free() {
        use crate::util::alloc_track;
        let mut rng = crate::util::Rng::new(0xF00D);
        let m = Message::Draft(sample_tree_draft(&mut rng));
        let frame = m.encode();
        let payload = &frame[4..];
        // Warm-up parse, then measure.
        let _ = FrameView::parse(payload).unwrap();
        let (view, allocs) = alloc_track::measure(|| FrameView::parse(payload).unwrap());
        assert_eq!(view.to_msg(), m);
        if alloc_track::enabled() {
            assert_eq!(allocs, 0, "FrameView::parse must not allocate");
        }
    }
}
