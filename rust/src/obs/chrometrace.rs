//! Chrome/Perfetto `trace_event` export of the flight-recorder stream
//! (DESIGN.md §10).
//!
//! `goodspeed run --trace-out trace.json` serializes the recorded spans
//! into the Trace Event Format (load the file at `ui.perfetto.dev` or
//! `chrome://tracing`): one track (`tid`) per verifier shard carrying
//! the recv/verify/send wave spans, one track per pipelined
//! [`VerifyStage`](crate::coordinator::VerifyStage) at `tid = 1000 +
//! shard`, and instant events for faults, membership epochs, and
//! migrations. The writer is dependency-free (hand-rolled JSON, like
//! `util/perfjson.rs` on the parse side) — every emitted name is a
//! static identifier, so no string escaping is needed.
//!
//! The analytic simulator emits the same span stream in **virtual
//! time** (its clock, not the wall), so a live trace and an analytic
//! trace of the same scenario can be diffed visually timeline against
//! timeline.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

use super::flight::{
    fault_name, FlightEvent, KIND_EPOCH, KIND_FAULT, KIND_MIGRATION, KIND_STAGE, KIND_WAVE,
};
use super::ObsHub;

/// Render the hub's surviving event window as a Trace Event Format
/// document (ts/dur in microseconds, as the format specifies).
pub fn render(hub: &ObsHub) -> String {
    render_events(&hub.snapshot_events(), hub.shards())
}

/// Render an explicit event list (the hub snapshot is already sorted by
/// end time; order is cosmetic — trace viewers sort on load).
pub fn render_events(events: &[FlightEvent], shards: usize) -> String {
    let mut o = String::with_capacity(events.len() * 160 + 1024);
    o.push_str("{\"traceEvents\":[\n");
    let mut first = true;
    for s in 0..shards {
        thread_name(&mut o, &mut first, s as u64, &format!("shard {s}"));
        thread_name(&mut o, &mut first, 1000 + s as u64, &format!("verify-stage {s}"));
    }
    for e in events {
        match e.kind {
            KIND_WAVE => {
                // The three phases laid back-to-back, ending at the
                // recorded end time.
                let mut ts = e.start_ns() as f64 / 1e3;
                for (name, dur_ns) in
                    [("recv", e.recv_ns), ("verify", e.verify_ns), ("send", e.send_ns)]
                {
                    let dur = dur_ns as f64 / 1e3;
                    sep(&mut o, &mut first);
                    let _ = write!(
                        o,
                        "{{\"name\":\"{name}\",\"ph\":\"X\",\"pid\":1,\"tid\":{},\
                         \"ts\":{ts:.3},\"dur\":{dur:.3},\"args\":{{\"wave\":{}}}}}",
                        e.shard, e.wave
                    );
                    ts += dur;
                }
            }
            KIND_STAGE => {
                let ts = e.end_ns.saturating_sub(e.verify_ns) as f64 / 1e3;
                let dur = e.verify_ns as f64 / 1e3;
                sep(&mut o, &mut first);
                let _ = write!(
                    o,
                    "{{\"name\":\"stage-verify\",\"ph\":\"X\",\"pid\":1,\"tid\":{},\
                     \"ts\":{ts:.3},\"dur\":{dur:.3},\"args\":{{\"wave\":{}}}}}",
                    1000 + e.shard,
                    e.wave
                );
            }
            KIND_FAULT => {
                instant(&mut o, &mut first, fault_name(e.aux), e, "fault_code");
            }
            KIND_EPOCH => {
                instant(&mut o, &mut first, "epoch", e, "epoch");
            }
            KIND_MIGRATION => {
                instant(&mut o, &mut first, "migration", e, "client");
            }
            _ => {}
        }
    }
    o.push_str("\n]}\n");
    o
}

/// Write the rendered trace to `path`.
pub fn write_trace(path: &Path, hub: &ObsHub) -> Result<()> {
    std::fs::write(path, render(hub))
        .with_context(|| format!("write trace {}", path.display()))
}

fn sep(out: &mut String, first: &mut bool) {
    if !*first {
        out.push_str(",\n");
    }
    *first = false;
}

fn thread_name(out: &mut String, first: &mut bool, tid: u64, name: &str) {
    sep(out, first);
    let _ = write!(
        out,
        "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{tid},\
         \"args\":{{\"name\":\"{name}\"}}}}"
    );
}

/// A global-scoped instant event pinned at the event's end time.
fn instant(out: &mut String, first: &mut bool, name: &str, e: &FlightEvent, aux_key: &str) {
    sep(out, first);
    let ts = e.end_ns as f64 / 1e3;
    let _ = write!(
        out,
        "{{\"name\":\"{name}\",\"ph\":\"i\",\"s\":\"g\",\"pid\":1,\"tid\":{},\
         \"ts\":{ts:.3},\"args\":{{\"{aux_key}\":{}}}}}",
        e.shard, e.aux
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::flight::fault_code;
    use crate::obs::ObsOptions;
    use crate::util::perfjson::{self, Json};

    #[test]
    fn trace_round_trips_through_the_json_parser() {
        let hub = ObsHub::new(2, 2, &ObsOptions::default());
        hub.wave_span_at(0, 0, 10_000, 2_000, 5_000, 1_000);
        hub.wave_span_at(1, 0, 12_000, 3_000, 5_000, 1_500);
        hub.stage_span_at(0, 1, 20_000, 4_000);
        hub.note_fault_at(1, "shard-crash", 15_000);
        hub.note_epoch_at(0, 2, 16_000);
        hub.note_migration_at(1, 7, 17_000);

        let text = render(&hub);
        let doc = perfjson::parse(&text).expect("trace must be valid JSON");
        let Some(Json::Arr(evs)) = doc.path("traceEvents") else {
            panic!("traceEvents must be an array: {text}");
        };
        // 2 shards × 2 metadata + 2 waves × 3 phases + 1 stage + 3 instants.
        assert_eq!(evs.len(), 4 + 6 + 1 + 3, "{text}");

        // Wave phases land back-to-back ending at end_ns.
        assert!(text.contains("\"name\":\"recv\""), "{text}");
        assert!(text.contains("\"name\":\"verify\""), "{text}");
        assert!(text.contains("\"name\":\"send\""), "{text}");
        assert!(text.contains("\"name\":\"stage-verify\""), "{text}");
        assert!(text.contains("\"tid\":1000"), "stage track offset: {text}");
        // Fault instants carry the chaos kind as the event name.
        assert!(text.contains("\"name\":\"shard-crash\""), "{text}");
        assert!(text.contains(&format!("\"fault_code\":{}", fault_code("shard-crash"))));
        assert!(text.contains("\"name\":\"epoch\""), "{text}");
        assert!(text.contains("\"name\":\"migration\""), "{text}");
        assert!(text.contains("\"ph\":\"i\""), "{text}");
    }

    #[test]
    fn empty_hub_renders_a_valid_document() {
        let hub = ObsHub::new(1, 1, &ObsOptions::default());
        let text = render(&hub);
        perfjson::parse(&text).expect("empty trace parses");
        assert!(text.contains("thread_name"));
    }
}
