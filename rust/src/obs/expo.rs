//! Live metrics endpoint: a std-only TCP listener serving Prometheus
//! text exposition off an atomic gauge/counter registry (DESIGN.md
//! §10).
//!
//! The registry is the *only* thing the wave loops touch — updating a
//! gauge is one relaxed atomic store (f64 bits in an `AtomicU64`), so
//! wave-boundary refreshes are allocation-free and never contend. The
//! listener thread renders the exposition page per request; rendering
//! allocates, but only on the scrape path, never on a wave.

use std::fmt::Write as _;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use anyhow::{Context, Result};

use super::ObsHub;

/// An `f64` gauge stored as bits in an `AtomicU64`. `set` is a single
/// relaxed store — safe from any wave loop.
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn new() -> Gauge {
        Gauge(AtomicU64::new(0f64.to_bits()))
    }

    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

impl Default for Gauge {
    fn default() -> Gauge {
        Gauge::new()
    }
}

/// A monotonic `u64` counter. `set` exists because several sources are
/// already cumulative (the recorder's wave count, the pool controller's
/// migration tally) — the publisher stores the authoritative total
/// rather than diffing it.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn set(&self, n: u64) {
        self.0.store(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// The atomic registry behind the exposition page. Sized once at hub
/// construction (client slots × shard count); every update thereafter
/// is an atomic store into preallocated storage.
pub struct MetricsRegistry {
    pub waves_per_second: Gauge,
    pub tokens_per_second: Gauge,
    pub jain_index: Gauge,
    /// Σ outstanding speculative tokens across clients (vs `capacity`).
    pub outstanding_tokens: Gauge,
    pub capacity_tokens: Gauge,
    pub waves_total: Counter,
    pub tokens_total: Counter,
    pub handoffs_lost_total: Counter,
    pub migrations_total: Counter,
    pub faults_total: Counter,
    /// Per client slot: cumulative goodput per participating wave.
    pub client_goodput: Vec<Gauge>,
    /// Per client slot: SLO-credited goodput per participating wave.
    pub client_slo_goodput: Vec<Gauge>,
    /// Per shard: 1 live, 0 crashed.
    pub shard_live: Vec<Counter>,
    /// Per shard: scheduling pressure (Σ demand / shard budget).
    pub shard_pressure: Vec<Gauge>,
}

impl MetricsRegistry {
    pub fn new(clients: usize, shards: usize) -> MetricsRegistry {
        let shard_live: Vec<Counter> = (0..shards)
            .map(|_| {
                let c = Counter::default();
                c.set(1); // shards start live
                c
            })
            .collect();
        MetricsRegistry {
            waves_per_second: Gauge::new(),
            tokens_per_second: Gauge::new(),
            jain_index: Gauge::new(),
            outstanding_tokens: Gauge::new(),
            capacity_tokens: Gauge::new(),
            waves_total: Counter::default(),
            tokens_total: Counter::default(),
            handoffs_lost_total: Counter::default(),
            migrations_total: Counter::default(),
            faults_total: Counter::default(),
            client_goodput: (0..clients).map(|_| Gauge::new()).collect(),
            client_slo_goodput: (0..clients).map(|_| Gauge::new()).collect(),
            shard_live,
            shard_pressure: (0..shards).map(|_| Gauge::new()).collect(),
        }
    }

    /// Render the Prometheus text-exposition page (version 0.0.4).
    /// Scrape-path only — allocates freely.
    pub fn render(&self) -> String {
        let mut o = String::with_capacity(4096);
        gauge(
            &mut o,
            "goodspeed_waves_per_second",
            "Verification waves completed per second over the run",
            self.waves_per_second.get(),
        );
        gauge(
            &mut o,
            "goodspeed_tokens_per_second",
            "Goodput tokens (accepted + correction) per second over the run",
            self.tokens_per_second.get(),
        );
        gauge(
            &mut o,
            "goodspeed_jain_index",
            "Jain fairness index over per-client goodput rates",
            self.jain_index.get(),
        );
        gauge(
            &mut o,
            "goodspeed_outstanding_tokens",
            "Sum of outstanding speculative-token reservations",
            self.outstanding_tokens.get(),
        );
        gauge(
            &mut o,
            "goodspeed_capacity_tokens",
            "Verification budget C the scheduler fills",
            self.capacity_tokens.get(),
        );
        counter(&mut o, "goodspeed_waves_total", "Waves completed", self.waves_total.get());
        counter(
            &mut o,
            "goodspeed_tokens_total",
            "Goodput tokens delivered",
            self.tokens_total.get(),
        );
        counter(
            &mut o,
            "goodspeed_handoffs_lost_total",
            "In-flight request states censored by shard loss",
            self.handoffs_lost_total.get(),
        );
        counter(
            &mut o,
            "goodspeed_migrations_total",
            "Client migrations between verifier shards",
            self.migrations_total.get(),
        );
        counter(
            &mut o,
            "goodspeed_faults_total",
            "Chaos/fault events observed",
            self.faults_total.get(),
        );
        head(
            &mut o,
            "goodspeed_client_goodput",
            "Per-client goodput tokens per participating wave",
            "gauge",
        );
        for (i, g) in self.client_goodput.iter().enumerate() {
            let _ = writeln!(o, "goodspeed_client_goodput{{client=\"{i}\"}} {}", g.get());
        }
        head(
            &mut o,
            "goodspeed_client_slo_goodput",
            "Per-client SLO-credited goodput tokens per participating wave",
            "gauge",
        );
        for (i, g) in self.client_slo_goodput.iter().enumerate() {
            let _ = writeln!(o, "goodspeed_client_slo_goodput{{client=\"{i}\"}} {}", g.get());
        }
        head(&mut o, "goodspeed_shard_live", "Shard liveness (1 live, 0 crashed)", "gauge");
        for (s, c) in self.shard_live.iter().enumerate() {
            let _ = writeln!(o, "goodspeed_shard_live{{shard=\"{s}\"}} {}", c.get());
        }
        head(
            &mut o,
            "goodspeed_shard_pressure",
            "Per-shard scheduling pressure (demand over budget)",
            "gauge",
        );
        for (s, g) in self.shard_pressure.iter().enumerate() {
            let _ = writeln!(o, "goodspeed_shard_pressure{{shard=\"{s}\"}} {}", g.get());
        }
        o
    }
}

fn head(out: &mut String, name: &str, help: &str, ty: &str) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {ty}");
}

fn gauge(out: &mut String, name: &str, help: &str, v: f64) {
    head(out, name, help, "gauge");
    let _ = writeln!(out, "{name} {v}");
}

fn counter(out: &mut String, name: &str, help: &str, v: u64) {
    head(out, name, help, "counter");
    let _ = writeln!(out, "{name} {v}");
}

/// The scrape endpoint: one listener thread, blocking accepts, one
/// response per connection (any request path gets the exposition page).
/// `stop` flips the flag and self-connects to unblock the accept, then
/// joins the thread — also run on drop, so a `?`-propagated error path
/// can't leak the listener.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9100`; port 0 picks a free port) and
    /// serve `hub`'s registry until [`MetricsServer::stop`].
    pub fn start(addr: &str, hub: Arc<ObsHub>) -> Result<MetricsServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("bind metrics endpoint {addr}"))?;
        let local = listener.local_addr().context("metrics endpoint local_addr")?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let thread = thread::Builder::new()
            .name("goodspeed-metrics".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop2.load(Ordering::Acquire) {
                        break;
                    }
                    let Ok(mut stream) = conn else { continue };
                    serve_one(&mut stream, &hub);
                }
            })
            .context("spawn metrics listener thread")?;
        Ok(MetricsServer { addr: local, stop, thread: Some(thread) })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stop(&mut self) {
        if let Some(t) = self.thread.take() {
            self.stop.store(true, Ordering::Release);
            // Unblock the accept; the flag check runs before the serve.
            let _ = TcpStream::connect(self.addr);
            let _ = t.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_one(stream: &mut TcpStream, hub: &ObsHub) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
    // Drain (up to) the request head; the path is ignored — every
    // request gets the exposition page, which is what curl/Prometheus
    // need and keeps the server dependency-free.
    let mut buf = [0u8; 1024];
    let _ = stream.read(&mut buf);
    let body = hub.metrics.render();
    let resp = format!(
        "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{}",
        body.len(),
        body
    );
    let _ = stream.write_all(resp.as_bytes());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::ObsOptions;

    #[test]
    fn registry_renders_every_metric_family() {
        let reg = MetricsRegistry::new(2, 2);
        reg.waves_per_second.set(123.5);
        reg.waves_total.set(40);
        reg.client_goodput[1].set(3.25);
        reg.shard_live[1].set(0);
        let page = reg.render();
        for name in [
            "goodspeed_waves_per_second",
            "goodspeed_tokens_per_second",
            "goodspeed_jain_index",
            "goodspeed_outstanding_tokens",
            "goodspeed_capacity_tokens",
            "goodspeed_waves_total",
            "goodspeed_tokens_total",
            "goodspeed_handoffs_lost_total",
            "goodspeed_migrations_total",
            "goodspeed_faults_total",
        ] {
            assert!(page.contains(&format!("# TYPE {name} ")), "{name} missing:\n{page}");
        }
        assert!(page.contains("goodspeed_waves_per_second 123.5"));
        assert!(page.contains("goodspeed_waves_total 40"));
        assert!(page.contains("goodspeed_client_goodput{client=\"1\"} 3.25"));
        assert!(page.contains("goodspeed_shard_live{shard=\"0\"} 1"));
        assert!(page.contains("goodspeed_shard_live{shard=\"1\"} 0"));
        assert!(page.contains("goodspeed_shard_pressure{shard=\"1\"}"));
    }

    #[test]
    fn endpoint_serves_the_exposition_page() {
        let hub = Arc::new(ObsHub::new(1, 2, &ObsOptions::default()));
        hub.metrics.waves_per_second.set(77.0);
        let mut server = MetricsServer::start("127.0.0.1:0", Arc::clone(&hub)).unwrap();
        let addr = server.local_addr();

        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let _ = conn.shutdown(std::net::Shutdown::Write);
        let mut resp = String::new();
        conn.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
        assert!(resp.contains("goodspeed_waves_per_second 77"), "{resp}");

        server.stop();
        // Idempotent; drop after stop is a no-op.
        server.stop();
    }
}
