//! Flight recorder: fixed-capacity, preallocated ring buffers of
//! structured wave-lifecycle events (DESIGN.md §10).
//!
//! Each [`FlightRing`] is a power-of-two array of event slots written
//! with **atomic stores only** — recording an event never allocates, so
//! a warm wave with the recorder attached stays allocation-free under
//! `--features alloc_track` (asserted in the obs tests). The ring is
//! single-writer by construction (the hub assigns one ring per shard
//! loop and one per pipelined verify stage); the head counter is
//! published with `Release` so a cross-thread reader that `Acquire`s it
//! sees every field of the slots *before* the head. A reader racing the
//! writer on the *current* slot can observe a torn event — acceptable
//! for a postmortem/export surface and documented here rather than
//! locked away: the hot path pays eight relaxed stores and nothing
//! else.
//!
//! Overwrite semantics: the ring keeps the **last `capacity` events**;
//! older events are silently overwritten (seq numbers stay monotonic,
//! so a decoded snapshot reports exactly which window survived).

use std::sync::atomic::{AtomicU64, Ordering};

/// Event kinds stored in a slot's `kind` field.
pub const KIND_WAVE: u64 = 1;
/// Chaos/fault instant (the fault kind is in `aux`, see [`fault_code`]).
pub const KIND_FAULT: u64 = 2;
/// Membership epoch bump (new epoch id in `aux`).
pub const KIND_EPOCH: u64 = 3;
/// Client migration between shards (client id in `aux`).
pub const KIND_MIGRATION: u64 = 4;
/// Pipelined verify-stage span (`verify_ns` holds the forward time).
pub const KIND_STAGE: u64 = 5;

/// Human name for an event kind (postmortem dumps).
pub fn kind_name(kind: u64) -> &'static str {
    match kind {
        KIND_WAVE => "wave",
        KIND_FAULT => "fault",
        KIND_EPOCH => "epoch",
        KIND_MIGRATION => "migration",
        KIND_STAGE => "stage",
        _ => "unknown",
    }
}

/// The fault kinds the chaos layer emits ([`FaultRecord`]`::kind`
/// strings), in code order. Rings store only `u64`s, so fault instants
/// carry `fault_code(kind)` in `aux` and the exporters map back with
/// [`fault_name`].
///
/// [`FaultRecord`]: crate::metrics::FaultRecord
const FAULT_NAMES: &[&str] = &[
    "shard-crash",
    "shard-recover",
    "partition",
    "partition-heal",
    "drop-burst",
    "duplicate-burst",
    "shard-abandoned",
    "fault-skipped",
    "handoff-lost",
    "slo-breach-streak",
];

/// Numeric code for a fault-kind string (1-based; 0 = unknown). A plain
/// slice scan — no hashing, no allocation — sized for a ten-entry table
/// on a cold path.
pub fn fault_code(kind: &str) -> u64 {
    FAULT_NAMES
        .iter()
        .position(|&n| n == kind)
        .map(|i| i as u64 + 1)
        .unwrap_or(0)
}

/// Inverse of [`fault_code`] (unknown codes render as `"fault"`).
pub fn fault_name(code: u64) -> &'static str {
    code.checked_sub(1)
        .and_then(|i| FAULT_NAMES.get(i as usize))
        .copied()
        .unwrap_or("fault")
}

/// One preallocated ring slot. All fields are written relaxed by the
/// single writer; the ring head's `Release`/`Acquire` pair orders them
/// for readers of *completed* slots.
#[derive(Default)]
struct Slot {
    kind: AtomicU64,
    shard: AtomicU64,
    wave: AtomicU64,
    end_ns: AtomicU64,
    recv_ns: AtomicU64,
    verify_ns: AtomicU64,
    send_ns: AtomicU64,
    aux: AtomicU64,
}

/// One decoded flight-recorder event (a plain-data copy of a slot plus
/// its monotonic sequence number).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FlightEvent {
    /// Monotonic per-ring sequence number (never wraps with the ring).
    pub seq: u64,
    /// One of the `KIND_*` constants.
    pub kind: u64,
    pub shard: u64,
    pub wave: u64,
    /// Event end, in ns since the hub epoch (wall or virtual time).
    pub end_ns: u64,
    pub recv_ns: u64,
    pub verify_ns: u64,
    pub send_ns: u64,
    /// Kind-specific payload: fault code, epoch id, or client id.
    pub aux: u64,
}

impl FlightEvent {
    /// Span start: the phases are laid back-to-back ending at `end_ns`.
    pub fn start_ns(&self) -> u64 {
        self.end_ns
            .saturating_sub(self.recv_ns + self.verify_ns + self.send_ns)
    }
}

/// A fixed-capacity ring of wave-lifecycle events. Capacity rounds up
/// to a power of two so the slot index is a mask, not a division.
pub struct FlightRing {
    slots: Box<[Slot]>,
    /// Events ever written; next slot = `head & (capacity - 1)`.
    head: AtomicU64,
}

impl FlightRing {
    pub fn new(capacity: usize) -> FlightRing {
        let cap = capacity.max(8).next_power_of_two();
        FlightRing {
            slots: (0..cap).map(|_| Slot::default()).collect(),
            head: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total events ever recorded (not just the surviving window).
    pub fn written(&self) -> u64 {
        self.head.load(Ordering::Acquire)
    }

    /// Record one event: eight relaxed stores plus a release head bump.
    /// Never allocates, never blocks.
    #[allow(clippy::too_many_arguments)]
    pub fn record(
        &self,
        kind: u64,
        shard: u64,
        wave: u64,
        end_ns: u64,
        recv_ns: u64,
        verify_ns: u64,
        send_ns: u64,
        aux: u64,
    ) {
        let h = self.head.load(Ordering::Relaxed);
        let slot = &self.slots[(h as usize) & (self.slots.len() - 1)];
        slot.kind.store(kind, Ordering::Relaxed);
        slot.shard.store(shard, Ordering::Relaxed);
        slot.wave.store(wave, Ordering::Relaxed);
        slot.end_ns.store(end_ns, Ordering::Relaxed);
        slot.recv_ns.store(recv_ns, Ordering::Relaxed);
        slot.verify_ns.store(verify_ns, Ordering::Relaxed);
        slot.send_ns.store(send_ns, Ordering::Relaxed);
        slot.aux.store(aux, Ordering::Relaxed);
        self.head.store(h + 1, Ordering::Release);
    }

    /// Decode the surviving window, oldest first. Allocates — this is
    /// the cold postmortem/export path, never the wave loop.
    pub fn snapshot(&self) -> Vec<FlightEvent> {
        let h = self.head.load(Ordering::Acquire);
        let len = self.slots.len() as u64;
        let start = h.saturating_sub(len);
        (start..h)
            .map(|seq| {
                let s = &self.slots[(seq as usize) & (self.slots.len() - 1)];
                FlightEvent {
                    seq,
                    kind: s.kind.load(Ordering::Relaxed),
                    shard: s.shard.load(Ordering::Relaxed),
                    wave: s.wave.load(Ordering::Relaxed),
                    end_ns: s.end_ns.load(Ordering::Relaxed),
                    recv_ns: s.recv_ns.load(Ordering::Relaxed),
                    verify_ns: s.verify_ns.load(Ordering::Relaxed),
                    send_ns: s.send_ns.load(Ordering::Relaxed),
                    aux: s.aux.load(Ordering::Relaxed),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_keeps_the_last_capacity_events() {
        let ring = FlightRing::new(8);
        assert_eq!(ring.capacity(), 8);
        for i in 0..20u64 {
            ring.record(KIND_WAVE, 0, i, i * 100, 10, 20, 30, 0);
        }
        assert_eq!(ring.written(), 20);
        let evs = ring.snapshot();
        assert_eq!(evs.len(), 8);
        // Oldest surviving event is seq 12; newest is seq 19.
        assert_eq!(evs.first().unwrap().seq, 12);
        assert_eq!(evs.last().unwrap().seq, 19);
        for e in &evs {
            assert_eq!(e.wave, e.seq, "slot content tracks the overwrite");
            assert_eq!(e.end_ns, e.seq * 100);
        }
    }

    #[test]
    fn span_start_subtracts_the_phases() {
        let e = FlightEvent {
            seq: 0,
            kind: KIND_WAVE,
            shard: 0,
            wave: 0,
            end_ns: 1000,
            recv_ns: 100,
            verify_ns: 200,
            send_ns: 300,
            aux: 0,
        };
        assert_eq!(e.start_ns(), 400);
        // Saturates instead of underflowing on a torn/garbage slot.
        let torn = FlightEvent { recv_ns: 5000, ..e };
        assert_eq!(torn.start_ns(), 0);
    }

    #[test]
    fn fault_codes_round_trip() {
        for kind in ["shard-crash", "handoff-lost", "slo-breach-streak"] {
            let code = fault_code(kind);
            assert!(code > 0, "{kind}");
            assert_eq!(fault_name(code), kind);
        }
        assert_eq!(fault_code("no-such-fault"), 0);
        assert_eq!(fault_name(0), "fault");
        assert_eq!(fault_name(999), "fault");
    }

    #[test]
    fn capacity_rounds_up_to_power_of_two() {
        assert_eq!(FlightRing::new(0).capacity(), 8);
        assert_eq!(FlightRing::new(100).capacity(), 128);
        assert_eq!(FlightRing::new(256).capacity(), 256);
    }
}
