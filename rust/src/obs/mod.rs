//! Live telemetry (DESIGN.md §10): flight recorder, Chrome-trace
//! export, and a scrapeable Prometheus metrics endpoint.
//!
//! Three coordinated pieces behind one [`ObsHub`]:
//!
//! * [`flight`] — fixed-capacity, preallocated per-thread ring buffers
//!   of structured wave-lifecycle events, written with atomics only
//!   (warm waves stay allocation-free with the recorder attached) and
//!   dumped automatically when a shard dies, an SLO breach streak is
//!   detected, or a chaos fault fires.
//! * [`chrometrace`] — `goodspeed run --trace-out trace.json`
//!   serializes the recorded spans into Chrome/Perfetto `trace_event`
//!   JSON; the analytic simulator emits the same span stream in
//!   virtual time.
//! * [`expo`] — `goodspeed run --metrics-addr 127.0.0.1:9100` serves
//!   Prometheus text exposition off a std-only TCP listener reading an
//!   atomic gauge/counter registry updated at wave boundaries.
//!
//! Everything is **off by default**: without an `ObsHub` no code path
//! changes, and with one attached no RNG stream or hot-path allocation
//! is touched — runs stay bit-identical either way (pinned by
//! `tests/obs_parity.rs` and the `alloc_track` guards).

pub mod chrometrace;
pub mod expo;
pub mod flight;

pub use chrometrace::write_trace;
pub use expo::{Counter, Gauge, MetricsRegistry, MetricsServer};
pub use flight::{fault_code, fault_name, FlightEvent, FlightRing};

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Instant;

use flight::{KIND_EPOCH, KIND_FAULT, KIND_MIGRATION, KIND_STAGE, KIND_WAVE};

use crate::metrics::Recorder;

/// Default per-ring event capacity (events, power of two).
pub const DEFAULT_RING_CAPACITY: usize = 256;

/// Consecutive wave boundaries with *fresh* SLO expiries that latch a
/// postmortem dump.
pub const SLO_BREACH_STREAK: u64 = 3;

/// How observability is switched on: pass to
/// [`ClusterBuilder::observability`](crate::coordinator::ClusterBuilder::observability).
#[derive(Clone, Debug, Default)]
pub struct ObsOptions {
    /// Postmortem dump target (`None` = stderr).
    pub postmortem: Option<PathBuf>,
    /// Per-ring event capacity (0 = [`DEFAULT_RING_CAPACITY`]; rounded
    /// up to a power of two).
    pub ring_capacity: usize,
}

/// The hub every instrumented loop holds (behind `Option<Arc<..>>`):
/// per-shard flight rings, the atomic metrics registry, and the latched
/// postmortem trigger. All recording methods are `&self`, atomics-only,
/// allocation-free; the snapshot/dump/render surfaces are the cold
/// paths that allocate.
pub struct ObsHub {
    /// Time zero for wall-clock spans ([`ObsHub::now_ns`]); virtual-time
    /// emitters bypass it via the `*_at` variants.
    epoch: Instant,
    shards: usize,
    /// `2 × shards` rings: `[s]` carries shard `s`'s wave spans and
    /// instant events, `[shards + s]` its pipelined verify-stage spans —
    /// one writer each, so recording never contends.
    rings: Vec<FlightRing>,
    pub metrics: MetricsRegistry,
    postmortem: Option<PathBuf>,
    /// Postmortem latch: the first trigger dumps, the rest are no-ops
    /// (the interesting window is the one around the *first* fault).
    dumped: AtomicBool,
    /// SLO-breach streak detector state (cumulative expired count at the
    /// last wave boundary, and the current run of increases).
    last_expired: AtomicU64,
    breach_streak: AtomicU64,
}

impl ObsHub {
    /// A hub for `shards` verifier shards and `clients` client slots.
    pub fn new(shards: usize, clients: usize, opts: &ObsOptions) -> ObsHub {
        let shards = shards.max(1);
        let cap = if opts.ring_capacity == 0 {
            DEFAULT_RING_CAPACITY
        } else {
            opts.ring_capacity
        };
        ObsHub {
            epoch: Instant::now(),
            shards,
            rings: (0..2 * shards).map(|_| FlightRing::new(cap)).collect(),
            metrics: MetricsRegistry::new(clients, shards),
            postmortem: opts.postmortem.clone(),
            dumped: AtomicBool::new(false),
            last_expired: AtomicU64::new(0),
            breach_streak: AtomicU64::new(0),
        }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Nanoseconds since the hub was built (the trace's time zero).
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    fn wave_ring(&self, shard: usize) -> &FlightRing {
        &self.rings[shard.min(self.shards - 1)]
    }

    fn stage_ring(&self, shard: usize) -> &FlightRing {
        &self.rings[self.shards + shard.min(self.shards - 1)]
    }

    /// Record one completed wave's phase decomposition, ending now.
    pub fn wave_span(&self, shard: usize, wave: u64, recv_ns: u64, verify_ns: u64, send_ns: u64) {
        self.wave_span_at(shard, wave, self.now_ns(), recv_ns, verify_ns, send_ns);
    }

    /// Virtual-time variant (the analytic simulator stamps its own
    /// clock, in ns, as the span end).
    pub fn wave_span_at(
        &self,
        shard: usize,
        wave: u64,
        end_ns: u64,
        recv_ns: u64,
        verify_ns: u64,
        send_ns: u64,
    ) {
        self.wave_ring(shard)
            .record(KIND_WAVE, shard as u64, wave, end_ns, recv_ns, verify_ns, send_ns, 0);
    }

    /// Record one pipelined verify-stage forward, ending now.
    pub fn stage_span(&self, shard: usize, wave: u64, verify_ns: u64) {
        self.stage_span_at(shard, wave, self.now_ns(), verify_ns);
    }

    pub fn stage_span_at(&self, shard: usize, wave: u64, end_ns: u64, verify_ns: u64) {
        self.stage_ring(shard).record(KIND_STAGE, shard as u64, wave, end_ns, 0, verify_ns, 0, 0);
    }

    /// Membership epoch bump (instant event).
    pub fn note_epoch(&self, shard: usize, epoch: u64) {
        self.note_epoch_at(shard, epoch, self.now_ns());
    }

    pub fn note_epoch_at(&self, shard: usize, epoch: u64, end_ns: u64) {
        self.wave_ring(shard).record(KIND_EPOCH, shard as u64, 0, end_ns, 0, 0, 0, epoch);
    }

    /// Client migration between shards (instant event on the *source*).
    pub fn note_migration(&self, shard: usize, client: u64) {
        self.note_migration_at(shard, client, self.now_ns());
    }

    pub fn note_migration_at(&self, shard: usize, client: u64, end_ns: u64) {
        self.wave_ring(shard).record(KIND_MIGRATION, shard as u64, 0, end_ns, 0, 0, 0, client);
    }

    /// Chaos/fault instant (`kind` is a [`FaultRecord`] kind string,
    /// encoded via [`fault_code`]). Bumps the fault counter and latches
    /// the postmortem — a firing fault is one of its triggers.
    ///
    /// [`FaultRecord`]: crate::metrics::FaultRecord
    pub fn note_fault(&self, shard: usize, kind: &str) {
        self.note_fault_at(shard, kind, self.now_ns());
    }

    pub fn note_fault_at(&self, shard: usize, kind: &str, end_ns: u64) {
        self.metrics.faults_total.add(1);
        self.wave_ring(shard)
            .record(KIND_FAULT, shard as u64, 0, end_ns, 0, 0, 0, fault_code(kind));
        self.dump_postmortem(kind);
    }

    /// Feed the cumulative SLO-expired request count at a wave boundary.
    /// [`SLO_BREACH_STREAK`] consecutive boundaries that each added new
    /// expiries latch a postmortem. Atomics only — safe per-wave.
    pub fn note_slo_expired(&self, total_expired: u64) {
        let prev = self.last_expired.swap(total_expired, Ordering::Relaxed);
        if total_expired > prev {
            let streak = self.breach_streak.fetch_add(1, Ordering::Relaxed) + 1;
            if streak >= SLO_BREACH_STREAK {
                self.dump_postmortem("slo-breach-streak");
            }
        } else {
            self.breach_streak.store(0, Ordering::Relaxed);
        }
    }

    /// Wave-boundary registry refresh from the recorder's cumulative
    /// slices. Atomic stores over preallocated gauges — no allocation,
    /// no RNG, no branching on recorded values.
    pub fn publish_wave_stats(&self, recorder: &Recorder, outstanding: u64, capacity: u64) {
        let m = &self.metrics;
        let waves = recorder.waves();
        let secs = self.epoch.elapsed().as_secs_f64().max(1e-9);
        let good = recorder.cum_goodput();
        let part = recorder.participation();
        let slo = &recorder.slo_goodput;
        let total: f64 = good.iter().sum();
        m.waves_total.set(waves);
        m.tokens_total.set(total as u64);
        m.waves_per_second.set(waves as f64 / secs);
        m.tokens_per_second.set(total / secs);
        m.outstanding_tokens.set(outstanding as f64);
        m.capacity_tokens.set(capacity as f64);
        m.handoffs_lost_total.set(recorder.handoffs_lost);
        // Per-client rates + Jain (Σx)²/(n·Σx²) over participants, inline
        // so no scratch vector is needed.
        let (mut sum, mut sum2, mut n) = (0.0f64, 0.0f64, 0u32);
        for i in 0..good.len() {
            let p = part.get(i).copied().unwrap_or(0);
            let rate = if p > 0 { good[i] / p as f64 } else { 0.0 };
            if let Some(g) = m.client_goodput.get(i) {
                g.set(rate);
            }
            if let (Some(g), Some(&s)) = (m.client_slo_goodput.get(i), slo.get(i)) {
                g.set(if p > 0 { s / p as f64 } else { 0.0 });
            }
            if p > 0 {
                sum += rate;
                sum2 += rate * rate;
                n += 1;
            }
        }
        let jain = if n > 0 && sum2 > 0.0 {
            (sum * sum) / (n as f64 * sum2)
        } else {
            1.0
        };
        m.jain_index.set(jain);
    }

    /// Merged snapshot of every ring's surviving window, ordered by end
    /// time. Cold path (allocates) — export and postmortem only.
    pub fn snapshot_events(&self) -> Vec<FlightEvent> {
        let mut evs: Vec<FlightEvent> = self.rings.iter().flat_map(|r| r.snapshot()).collect();
        evs.sort_by_key(|e| (e.end_ns, e.shard, e.seq));
        evs
    }

    /// Whether the postmortem already fired (for tests and callers that
    /// want to force a final dump only if none happened).
    pub fn postmortem_fired(&self) -> bool {
        self.dumped.load(Ordering::Acquire)
    }

    /// Latched postmortem: dump the surviving event window (to the
    /// configured path, stderr otherwise) the *first* time a trigger
    /// fires — shard death, SLO breach streak, or a chaos fault.
    pub fn dump_postmortem(&self, reason: &str) {
        if self.dumped.swap(true, Ordering::AcqRel) {
            return;
        }
        let evs = self.snapshot_events();
        let mut out = String::with_capacity(evs.len() * 96 + 256);
        use std::fmt::Write as _;
        let _ = writeln!(
            out,
            "goodspeed postmortem ({reason}): last {} flight-recorder events",
            evs.len()
        );
        for e in &evs {
            let _ = match e.kind {
                KIND_WAVE => writeln!(
                    out,
                    "  [{:>12} ns] shard {} wave {:>5}  recv {} / verify {} / send {} ns",
                    e.end_ns, e.shard, e.wave, e.recv_ns, e.verify_ns, e.send_ns
                ),
                KIND_STAGE => writeln!(
                    out,
                    "  [{:>12} ns] shard {} stage wave {:>5}  verify {} ns",
                    e.end_ns, e.shard, e.wave, e.verify_ns
                ),
                KIND_FAULT => writeln!(
                    out,
                    "  [{:>12} ns] shard {} FAULT {}",
                    e.end_ns,
                    e.shard,
                    fault_name(e.aux)
                ),
                KIND_EPOCH => {
                    writeln!(out, "  [{:>12} ns] shard {} epoch -> {}", e.end_ns, e.shard, e.aux)
                }
                KIND_MIGRATION => {
                    writeln!(
                        out,
                        "  [{:>12} ns] shard {} migrated client {}",
                        e.end_ns, e.shard, e.aux
                    )
                }
                _ => writeln!(
                    out,
                    "  [{:>12} ns] shard {} {}",
                    e.end_ns,
                    e.shard,
                    flight::kind_name(e.kind)
                ),
            };
        }
        match &self.postmortem {
            Some(path) => match std::fs::write(path, &out) {
                Ok(()) => eprintln!("goodspeed postmortem ({reason}) -> {}", path.display()),
                Err(e) => {
                    eprintln!("postmortem write {} failed: {e}", path.display());
                    eprint!("{out}");
                }
            },
            None => eprint!("{out}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{ClientRoundMetrics, RoundRecord};
    use crate::util::alloc_track;

    fn hub(shards: usize, clients: usize) -> ObsHub {
        ObsHub::new(shards, clients, &ObsOptions::default())
    }

    #[test]
    fn spans_and_instants_land_in_per_shard_rings() {
        let h = hub(2, 4);
        h.wave_span(0, 0, 10, 20, 30);
        h.wave_span(1, 0, 10, 20, 30);
        h.stage_span(1, 0, 15);
        h.note_fault(0, "shard-crash");
        h.note_epoch(0, 3);
        h.note_migration(1, 2);
        let evs = h.snapshot_events();
        assert_eq!(evs.len(), 6);
        assert_eq!(evs.iter().filter(|e| e.kind == KIND_WAVE).count(), 2);
        assert_eq!(evs.iter().filter(|e| e.kind == KIND_STAGE).count(), 1);
        assert_eq!(evs.iter().filter(|e| e.kind == KIND_FAULT).count(), 1);
        assert_eq!(h.metrics.faults_total.get(), 1);
        assert!(h.postmortem_fired(), "a chaos fault latches the postmortem");
    }

    #[test]
    fn slo_breach_streak_latches_after_three_increases() {
        let h = hub(1, 1);
        h.note_slo_expired(1);
        h.note_slo_expired(2);
        assert!(!h.postmortem_fired());
        // A flat boundary resets the streak.
        h.note_slo_expired(2);
        h.note_slo_expired(3);
        h.note_slo_expired(4);
        assert!(!h.postmortem_fired());
        h.note_slo_expired(5);
        assert!(h.postmortem_fired(), "3 consecutive increases trigger the dump");
    }

    #[test]
    fn publish_wave_stats_fills_the_registry() {
        let h = hub(1, 2);
        let mut rec = Recorder::new(2);
        rec.push(RoundRecord {
            round: 0,
            shard: 0,
            recv_ns: 1,
            verify_ns: 2,
            send_ns: 3,
            clients: (0..2)
                .map(|i| ClientRoundMetrics {
                    client_id: i,
                    goodput: 3 + i,
                    ..Default::default()
                })
                .collect(),
        });
        rec.slo_goodput = vec![2.0, 4.0];
        h.publish_wave_stats(&rec, 6, 8);
        let m = &h.metrics;
        assert_eq!(m.waves_total.get(), 1);
        assert_eq!(m.tokens_total.get(), 7);
        assert_eq!(m.outstanding_tokens.get(), 6.0);
        assert_eq!(m.capacity_tokens.get(), 8.0);
        assert_eq!(m.client_goodput[0].get(), 3.0);
        assert_eq!(m.client_goodput[1].get(), 4.0);
        assert_eq!(m.client_slo_goodput[1].get(), 4.0);
        let jain = m.jain_index.get();
        let expect = (7.0f64 * 7.0) / (2.0 * (9.0 + 16.0));
        assert!((jain - expect).abs() < 1e-12, "{jain} vs {expect}");
    }

    /// The tentpole's hot-path claim: recording a wave span *and*
    /// refreshing the registry allocates nothing (meaningful under
    /// `--features alloc_track`; vacuous otherwise, like the other
    /// alloc guards).
    #[test]
    fn warm_wave_recording_is_allocation_free() {
        let h = hub(2, 8);
        let mut rec = Recorder::new(8);
        for w in 0..4u64 {
            rec.push(RoundRecord {
                round: w,
                shard: 0,
                recv_ns: 10,
                verify_ns: 20,
                send_ns: 5,
                clients: (0..8)
                    .map(|i| ClientRoundMetrics { client_id: i, goodput: 2, ..Default::default() })
                    .collect(),
            });
        }
        // Warm the rings past their first lap.
        for w in 0..300u64 {
            h.wave_span(0, w, 10, 20, 5);
            h.stage_span(0, w, 20);
        }
        let ((), allocs) = alloc_track::measure(|| {
            h.wave_span(1, 300, 10, 20, 5);
            h.stage_span(1, 300, 20);
            h.note_slo_expired(0);
            h.publish_wave_stats(&rec, 16, 64);
        });
        if alloc_track::enabled() {
            assert_eq!(allocs, 0, "observability touched the heap on a warm wave");
        }
    }

    #[test]
    fn postmortem_writes_the_configured_file_once() {
        let dir = std::env::temp_dir().join("goodspeed_obs_postmortem_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("postmortem.txt");
        let _ = std::fs::remove_file(&path);
        let h = ObsHub::new(
            1,
            1,
            &ObsOptions { postmortem: Some(path.clone()), ring_capacity: 16 },
        );
        h.wave_span(0, 0, 1, 2, 3);
        h.note_fault(0, "shard-abandoned");
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("postmortem (shard-abandoned)"), "{text}");
        assert!(text.contains("FAULT shard-abandoned"), "{text}");
        assert!(text.contains("wave     0"), "{text}");
        // Latched: a second trigger must not rewrite the file.
        std::fs::remove_file(&path).unwrap();
        h.note_fault(0, "shard-crash");
        assert!(!path.exists(), "postmortem must fire once");
    }
}
