//! Engine abstractions: the seam between the Rust coordinator (L3) and the
//! AOT-compiled model graphs (L2/L1).
//!
//! Two implementations:
//! * [`crate::runtime::XlaEngineFactory`] — loads `artifacts/*.hlo.txt` via
//!   PJRT (the production path; python never runs at serving time);
//! * [`crate::runtime::MockEngineFactory`] — a deterministic synthetic
//!   "world model" with controllable draft/target divergence so every test
//!   and benchmark runs without artifacts.
//!
//! PJRT objects are `Rc`-based (not `Send`), so factories hand out engines
//! *inside* the thread that will use them: `EngineFactory` is `Send + Sync`,
//! the engines it builds are not required to be.

use anyhow::Result;

/// Draft-side engine: owns the KV cache for one request stream.
///
/// Position semantics: after `prefill(prompt)` the cache holds rows
/// `0..prompt.len()` and `position() == prompt.len()`; the returned
/// distribution predicts the token at index `position()`. Each
/// `step(tok)` writes `tok` at row `position()`, advances by one, and
/// returns the distribution for the next index. `rewind(p)` discards rows
/// `>= p` (used when verification rejects a draft suffix — stale rows are
/// harmless because causal masking never looks past `position()`).
pub trait Drafter {
    fn prefill(&mut self, prompt: &[u8]) -> Result<Vec<f32>>;
    fn step(&mut self, tok: u8) -> Result<Vec<f32>>;
    fn position(&self) -> usize;
    fn rewind(&mut self, position: usize);
    fn max_seq(&self) -> usize;
    fn vocab(&self) -> usize;
}

/// One verification round over a batch of clients (the bucketed shapes are
/// chosen by the implementation from `batch`/`seq`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct VerifyRequest {
    /// Row-major `[batch, seq]` token ids (prefix ++ draft, right-padded).
    pub tokens: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
    /// Row-major `[batch, k]` drafted token ids (right-padded).
    pub draft_tok: Vec<i32>,
    /// Row-major `[batch, k, vocab]` draft proposal distributions.
    pub q_probs: Vec<f32>,
    /// Prefix length per client (draft j sits at sequence index pos0+j).
    pub pos0: Vec<i32>,
    /// Row-major `[batch, k]` draft-position parent indices: the context
    /// of draft position `j` is the prefix plus the tokens along its
    /// parent chain (`−1` = rooted at the prefix). A linear chain is
    /// `parent[j] = j − 1` — see [`chain_parent_array`] — which makes the
    /// engines' per-position contexts exactly the pre-tree linear ones;
    /// tree topologies carry real branching plus phantom bonus rows (see
    /// `spec/tree.rs` for the row-layout contract).
    pub parent: Vec<i32>,
    pub k: usize,
    pub vocab: usize,
}

/// The chain parent layout: within each client row, position `j`'s parent
/// is `j − 1` (position 0 roots at the prefix).
pub fn chain_parent_array(batch: usize, k: usize) -> Vec<i32> {
    (0..batch * k).map(|idx| (idx % k) as i32 - 1).collect()
}

/// Verification outputs (see `python/compile/model.py::verify_graph`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct VerifyOutput {
    /// `[batch, k]` min(1, p/q) at each draft position.
    pub ratio: Vec<f32>,
    /// `[batch, k, vocab]` normalized residual distributions.
    pub resid: Vec<f32>,
    /// `[batch, vocab]` target distribution after the full draft.
    pub bonus: Vec<f32>,
}

impl VerifyOutput {
    pub fn ratio_row(&self, b: usize, k: usize) -> &[f32] {
        &self.ratio[b * k..(b + 1) * k]
    }

    pub fn resid_rows(&self, b: usize, k: usize, vocab: usize) -> &[f32] {
        &self.resid[b * k * vocab..(b + 1) * k * vocab]
    }

    pub fn bonus_row(&self, b: usize, vocab: usize) -> &[f32] {
        &self.bonus[b * vocab..(b + 1) * vocab]
    }
}

/// Target-side verification engine.
pub trait Verifier {
    fn verify(&mut self, req: &VerifyRequest) -> Result<VerifyOutput>;

    /// Verify into a caller-owned output, reusing its buffer capacity —
    /// the allocation-free form of [`Verifier::verify`] for the wave hot
    /// path. Implementations must fill `out` with results *identical* to
    /// what [`Verifier::verify`] returns for the same request; the
    /// default simply delegates (allocating a fresh output per call).
    fn verify_into(&mut self, req: &VerifyRequest, out: &mut VerifyOutput) -> Result<()> {
        *out = self.verify(req)?;
        Ok(())
    }

    /// Available (batch, seq) shape buckets, ascending.
    fn buckets(&self) -> Vec<(usize, usize)>;
}

/// Builds engines inside consumer threads.
pub trait EngineFactory: Send + Sync {
    fn make_drafter(&self, model: &str) -> Result<Box<dyn Drafter>>;
    fn make_verifier(&self, family: &str) -> Result<Box<dyn Verifier>>;
    /// Optional autoregressive *target* stepper for baseline comparisons
    /// (quickstart's "plain decoding" lane).
    fn make_target_stepper(&self, family: &str) -> Result<Box<dyn Drafter>>;
    fn vocab(&self) -> usize;
    fn max_seq(&self) -> usize;
    fn verify_k(&self) -> usize;
}

/// Pick the smallest bucket covering (need_batch, need_seq); falls back to
/// the largest available (callers must then clamp).
pub fn pick_bucket(buckets: &[(usize, usize)], need_batch: usize, need_seq: usize) -> (usize, usize) {
    let mut best: Option<(usize, usize)> = None;
    for &(b, s) in buckets {
        if b >= need_batch && s >= need_seq {
            let better = match best {
                None => true,
                Some((bb, bs)) => (b, s) < (bb, bs) || (b * s) < (bb * bs),
            };
            if better {
                best = Some((b, s));
            }
        }
    }
    best.unwrap_or_else(|| *buckets.iter().max_by_key(|(b, s)| b * s).expect("no buckets"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_picks_smallest_fit() {
        let buckets = vec![(4, 128), (4, 256), (8, 128), (8, 256)];
        assert_eq!(pick_bucket(&buckets, 3, 100), (4, 128));
        assert_eq!(pick_bucket(&buckets, 4, 129), (4, 256));
        assert_eq!(pick_bucket(&buckets, 5, 50), (8, 128));
        assert_eq!(pick_bucket(&buckets, 8, 256), (8, 256));
    }

    #[test]
    fn bucket_falls_back_to_largest() {
        let buckets = vec![(4, 128), (8, 256)];
        assert_eq!(pick_bucket(&buckets, 16, 512), (8, 256));
    }

    #[test]
    fn chain_parent_layout() {
        assert_eq!(chain_parent_array(2, 3), vec![-1, 0, 1, -1, 0, 1]);
        assert_eq!(chain_parent_array(0, 4), Vec::<i32>::new());
    }

    #[test]
    fn verify_output_row_views() {
        let k = 2;
        let v = 3;
        let out = VerifyOutput {
            ratio: vec![0.1, 0.2, 0.3, 0.4],
            resid: (0..12).map(|x| x as f32).collect(),
            bonus: vec![0.0, 1.0, 0.0, 0.5, 0.25, 0.25],
        };
        assert_eq!(out.ratio_row(1, k), &[0.3, 0.4]);
        assert_eq!(out.resid_rows(1, k, v), &[6.0, 7.0, 8.0, 9.0, 10.0, 11.0]);
        assert_eq!(out.bonus_row(1, v), &[0.5, 0.25, 0.25]);
    }
}
