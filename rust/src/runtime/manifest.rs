//! Typed view of `artifacts/manifest.json` (written by `python -m
//! compile.aot`): the contract between the build-time python compiler and
//! the serving-time Rust loader — model configs, parameter ordering, HLO
//! file layout, and verify shape buckets.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::configsys::Value;

#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub param_count: usize,
    /// `[L, 2, S, H, dh]`.
    pub cache_shape: Vec<usize>,
    /// Flat parameter order (matches HLO entry parameters 0..n).
    pub param_names: Vec<String>,
    pub weights_npz: String,
    pub prefill_hlo: String,
    pub step_hlo: String,
}

#[derive(Clone, Debug)]
pub struct VerifyBucket {
    pub batch: usize,
    pub seq: usize,
    pub k: usize,
    pub hlo: String,
}

#[derive(Clone, Debug)]
pub struct FamilyEntry {
    pub target: String,
    pub drafts: Vec<String>,
    pub verify_buckets: Vec<VerifyBucket>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub max_seq: usize,
    pub vocab: usize,
    pub verify_k: usize,
    pub models: BTreeMap<String, ModelEntry>,
    pub families: BTreeMap<String, FamilyEntry>,
}

fn req_usize(v: &Value, key: &str) -> Result<usize> {
    v.get(key).and_then(Value::as_usize).ok_or_else(|| anyhow!("manifest missing '{key}'"))
}

fn req_str(v: &Value, key: &str) -> Result<String> {
    Ok(v.get(key)
        .and_then(Value::as_str)
        .ok_or_else(|| anyhow!("manifest missing '{key}'"))?
        .to_string())
}

impl Manifest {
    /// Load `<root>/manifest.json`.
    pub fn load(root: impl AsRef<Path>) -> Result<Manifest> {
        let root = root.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let v = Value::parse(&text).context("parsing manifest.json")?;
        Self::from_value(&v, root)
    }

    pub fn from_value(v: &Value, root: PathBuf) -> Result<Manifest> {
        let mut models = BTreeMap::new();
        for (name, m) in v.get("models").and_then(Value::as_object).into_iter().flatten() {
            let param_names = m
                .get("param_names")
                .and_then(Value::as_array)
                .ok_or_else(|| anyhow!("model {name}: missing param_names"))?
                .iter()
                .map(|x| x.as_str().unwrap_or_default().to_string())
                .collect();
            let cache_shape = m
                .get("cache_shape")
                .and_then(Value::as_array)
                .ok_or_else(|| anyhow!("model {name}: missing cache_shape"))?
                .iter()
                .map(|x| x.as_usize().unwrap_or(0))
                .collect();
            models.insert(
                name.clone(),
                ModelEntry {
                    name: name.clone(),
                    n_layers: req_usize(m, "n_layers")?,
                    d_model: req_usize(m, "d_model")?,
                    n_heads: req_usize(m, "n_heads")?,
                    d_ff: req_usize(m, "d_ff")?,
                    param_count: req_usize(m, "param_count")?,
                    cache_shape,
                    param_names,
                    weights_npz: req_str(m, "weights_npz")?,
                    prefill_hlo: req_str(m, "prefill_hlo")?,
                    step_hlo: req_str(m, "step_hlo")?,
                },
            );
        }
        let mut families = BTreeMap::new();
        for (name, f) in v.get("families").and_then(Value::as_object).into_iter().flatten() {
            let verify_buckets = f
                .get("verify_buckets")
                .and_then(Value::as_array)
                .ok_or_else(|| anyhow!("family {name}: missing verify_buckets"))?
                .iter()
                .map(|b| {
                    Ok(VerifyBucket {
                        batch: req_usize(b, "batch")?,
                        seq: req_usize(b, "seq")?,
                        k: req_usize(b, "k")?,
                        hlo: req_str(b, "hlo")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let drafts = f
                .get("drafts")
                .and_then(Value::as_array)
                .unwrap_or(&[])
                .iter()
                .map(|x| x.as_str().unwrap_or_default().to_string())
                .collect();
            families.insert(
                name.clone(),
                FamilyEntry { target: req_str(f, "target")?, drafts, verify_buckets },
            );
        }
        Ok(Manifest {
            root,
            max_seq: req_usize(v, "max_seq")?,
            vocab: req_usize(v, "vocab")?,
            verify_k: req_usize(v, "verify_k")?,
            models,
            families,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models.get(name).ok_or_else(|| anyhow!("unknown model '{name}'"))
    }

    pub fn family(&self, name: &str) -> Result<&FamilyEntry> {
        self.families.get(name).ok_or_else(|| anyhow!("unknown family '{name}'"))
    }

    pub fn path(&self, rel: &str) -> PathBuf {
        self.root.join(rel)
    }

    /// Every referenced file exists on disk.
    pub fn validate_files(&self) -> Result<()> {
        for m in self.models.values() {
            for rel in [&m.weights_npz, &m.prefill_hlo, &m.step_hlo] {
                let p = self.path(rel);
                if !p.exists() {
                    return Err(anyhow!("missing artifact {p:?}"));
                }
            }
        }
        for f in self.families.values() {
            for b in &f.verify_buckets {
                let p = self.path(&b.hlo);
                if !p.exists() {
                    return Err(anyhow!("missing artifact {p:?}"));
                }
            }
        }
        Ok(())
    }
}

/// Default artifacts dir: `$GOODSPEED_ARTIFACTS` or `<crate>/artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("GOODSPEED_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_manifest_json() -> String {
        r#"{
          "max_seq": 256, "vocab": 256, "verify_b": 8, "verify_k": 32,
          "models": {
            "m1": {
              "n_layers": 1, "d_model": 64, "n_heads": 2, "d_ff": 128,
              "param_count": 100, "cache_shape": [1,2,256,2,32],
              "param_names": ["emb","pos"],
              "weights_npz": "weights/m1.npz",
              "prefill_hlo": "hlo/prefill_m1.hlo.txt",
              "step_hlo": "hlo/step_m1.hlo.txt"
            }
          },
          "families": {
            "fam": {
              "target": "m1", "drafts": ["m1"],
              "verify_buckets": [
                {"batch": 4, "seq": 128, "k": 32, "hlo": "hlo/v1.hlo.txt"},
                {"batch": 8, "seq": 256, "k": 32, "hlo": "hlo/v2.hlo.txt"}
              ]
            }
          }
        }"#
        .to_string()
    }

    #[test]
    fn parses_toy_manifest() {
        let v = Value::parse(&toy_manifest_json()).unwrap();
        let m = Manifest::from_value(&v, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.vocab, 256);
        assert_eq!(m.model("m1").unwrap().n_layers, 1);
        assert_eq!(m.family("fam").unwrap().verify_buckets.len(), 2);
        assert!(m.model("nope").is_err());
        assert!(m.family("nope").is_err());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let dir = default_artifacts_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert_eq!(m.vocab, 256);
            assert!(m.families.contains_key("qwen"));
            assert!(m.families.contains_key("llama"));
            m.validate_files().unwrap();
            // param ordering contract: emb first, ln_f last
            let t = m.model("qwen-target").unwrap();
            assert_eq!(t.param_names.first().map(String::as_str), Some("emb"));
            assert_eq!(t.param_names.last().map(String::as_str), Some("ln_f"));
        }
    }
}
