//! Deterministic synthetic engines: a shared "world model" in which the
//! target distribution is a seeded, peaked function of the recent context
//! and each draft model sees a *noised* version of it.
//!
//! This gives every test/bench the statistical structure the real stack
//! has — heterogeneous per-client acceptance rates strictly between 0 and
//! 1, real rejection sampling, real residual corrections — with zero
//! artifact or PJRT dependency, and runs ~10⁴ rounds/second.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::engine::{Drafter, EngineFactory, Verifier, VerifyOutput, VerifyRequest};

/// Shared ground-truth distribution generator.
#[derive(Clone, Debug)]
pub struct MockWorld {
    pub vocab: usize,
    pub max_seq: usize,
    /// Peakedness of the target distribution (higher = more predictable).
    pub sharpness: f32,
    pub seed: u64,
}

impl Default for MockWorld {
    fn default() -> Self {
        MockWorld { vocab: 64, max_seq: 256, sharpness: 3.0, seed: 7 }
    }
}

fn mix(mut h: u64, x: u64) -> u64 {
    h ^= x.wrapping_mul(0x9E3779B97F4A7C15);
    h = (h ^ (h >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    h ^ (h >> 27)
}

impl MockWorld {
    fn ctx_hash(&self, ctx: &[u8]) -> u64 {
        // Last 3 tokens of context determine the next-token distribution —
        // a tiny Markov "language".
        let mut h = self.seed;
        for &t in ctx.iter().rev().take(3) {
            h = mix(h, t as u64 + 1);
        }
        h
    }

    /// Target model distribution p(· | ctx).
    pub fn target_dist(&self, ctx: &[u8]) -> Vec<f32> {
        self.dist_from_hash(self.ctx_hash(ctx), self.sharpness)
    }

    /// [`MockWorld::target_dist`] into a reused buffer (the verifier hot
    /// path calls this once per draft position; identical output).
    pub fn target_dist_into(&self, ctx: &[u8], out: &mut Vec<f32>) {
        self.dist_from_hash_into(self.ctx_hash(ctx), self.sharpness, out);
    }

    /// Draft model distribution q(· | ctx) for a client with divergence
    /// `noise ∈ [0, 1]`: 0 = identical to target (α → 1), 1 = unrelated.
    pub fn draft_dist(&self, ctx: &[u8], noise: f32, client_tag: u64) -> Vec<f32> {
        let p = self.target_dist(ctx);
        if noise <= 0.0 {
            return p;
        }
        let alt = self.dist_from_hash(mix(self.ctx_hash(ctx), client_tag ^ 0xA5A5), self.sharpness);
        let mut q: Vec<f32> = p
            .iter()
            .zip(&alt)
            .map(|(&a, &b)| (1.0 - noise) * a + noise * b)
            .collect();
        let s: f32 = q.iter().sum();
        for x in q.iter_mut() {
            *x /= s;
        }
        q
    }

    fn dist_from_hash(&self, h: u64, sharpness: f32) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.vocab);
        self.dist_from_hash_into(h, sharpness, &mut out);
        out
    }

    /// Same math as [`MockWorld::dist_from_hash`], computed in place in
    /// `out` (softmax applied to the logits buffer itself — identical
    /// float-op sequence, so the distribution is bit-for-bit the same).
    fn dist_from_hash_into(&self, h: u64, sharpness: f32, out: &mut Vec<f32>) {
        let mut rng = crate::util::Rng::new(h);
        out.clear();
        out.extend((0..self.vocab).map(|_| rng.f32() * sharpness));
        // A few strong modes to mimic a trained LM's peaked conditionals.
        for _ in 0..3 {
            let i = rng.below(self.vocab as u64) as usize;
            out[i] += sharpness * 2.0;
        }
        let m = out.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        for x in out.iter_mut() {
            *x = (*x - m).exp();
        }
        let s: f32 = out.iter().sum();
        for x in out.iter_mut() {
            *x /= s;
        }
    }
}

/// Drafter over the mock world (context is replayed; no KV cache needed).
pub struct MockDrafter {
    world: Arc<MockWorld>,
    noise: f32,
    client_tag: u64,
    ctx: Vec<u8>,
}

impl Drafter for MockDrafter {
    fn prefill(&mut self, prompt: &[u8]) -> Result<Vec<f32>> {
        if prompt.is_empty() {
            return Err(anyhow!("empty prompt"));
        }
        if prompt.len() >= self.world.max_seq {
            return Err(anyhow!("prompt longer than max_seq"));
        }
        self.ctx = prompt.to_vec();
        Ok(self.world.draft_dist(&self.ctx, self.noise, self.client_tag))
    }

    fn step(&mut self, tok: u8) -> Result<Vec<f32>> {
        if self.ctx.len() >= self.world.max_seq {
            return Err(anyhow!("context overflow"));
        }
        self.ctx.push(tok);
        Ok(self.world.draft_dist(&self.ctx, self.noise, self.client_tag))
    }

    fn position(&self) -> usize {
        self.ctx.len()
    }

    fn rewind(&mut self, position: usize) {
        assert!(position <= self.ctx.len(), "rewind forward");
        self.ctx.truncate(position);
    }

    fn max_seq(&self) -> usize {
        self.world.max_seq
    }

    fn vocab(&self) -> usize {
        self.world.vocab
    }
}

/// Verifier over the mock world: recomputes the target distribution at
/// every draft position and applies exactly the fused-kernel math
/// (ratio / residual / bonus) of `python/compile/kernels/verify.py`.
pub struct MockVerifier {
    world: Arc<MockWorld>,
    buckets: Vec<(usize, usize)>,
    // Scratch reused across calls so warm `verify_into` never allocates.
    p: Vec<f32>,
    ctx: Vec<u8>,
    path: Vec<u8>,
}

/// Context of draft position `j` in row `row`: the prefix plus the tokens
/// along `j`'s parent chain, truncated to the bucket length (the verify
/// graph's row clamp). For the chain layout (`parent[j] = j − 1`) this is
/// exactly the pre-tree linear context `tokens[..pos0 + j]`. Written into
/// `ctx` (with `path` as parent-chain scratch), reusing both capacities.
fn ctx_of_into(req: &VerifyRequest, row: usize, j: usize, path: &mut Vec<u8>, ctx: &mut Vec<u8>) {
    let k = req.k;
    path.clear();
    let mut p = req.parent[row * k + j];
    while p >= 0 {
        path.push(req.draft_tok[row * k + p as usize] as u8);
        let next = req.parent[row * k + p as usize];
        // Topological order is validated upstream; never loop on bad data.
        if next >= p {
            break;
        }
        p = next;
    }
    path.reverse();
    let pos0 = (req.pos0[row] as usize).min(req.seq);
    ctx.clear();
    ctx.extend(req.tokens[row * req.seq..row * req.seq + pos0].iter().map(|&t| t as u8));
    ctx.extend_from_slice(path);
    ctx.truncate(req.seq);
}

impl Verifier for MockVerifier {
    fn verify(&mut self, req: &VerifyRequest) -> Result<VerifyOutput> {
        let mut out = VerifyOutput::default();
        self.verify_into(req, &mut out)?;
        Ok(out)
    }

    fn verify_into(&mut self, req: &VerifyRequest, out: &mut VerifyOutput) -> Result<()> {
        let v = req.vocab;
        if v != self.world.vocab {
            return Err(anyhow!("vocab mismatch: {} vs {}", v, self.world.vocab));
        }
        let (b, k) = (req.batch, req.k);
        if req.parent.len() != b * k {
            return Err(anyhow!("parent array {} != batch*k {}", req.parent.len(), b * k));
        }
        out.ratio.clear();
        out.ratio.resize(b * k, 0.0);
        out.resid.clear();
        out.resid.resize(b * k * v, 0.0);
        out.bonus.clear();
        out.bonus.resize(b * v, 0.0);
        for row in 0..b {
            for j in 0..k {
                // Context from the parent chain (rows past the client's
                // true node count are ignored by the coordinator).
                ctx_of_into(req, row, j, &mut self.path, &mut self.ctx);
                self.world.target_dist_into(&self.ctx, &mut self.p);
                let p = &self.p;
                let q = &req.q_probs[(row * k + j) * v..(row * k + j + 1) * v];
                let tok = req.draft_tok[row * k + j] as usize;
                let pt = p[tok.min(v - 1)];
                let qt = q[tok.min(v - 1)].max(1e-9);
                out.ratio[row * k + j] = (pt / qt).min(1.0);
                let res = &mut out.resid[(row * k + j) * v..(row * k + j + 1) * v];
                let mut s = 0.0f32;
                for t in 0..v {
                    let d = (p[t] - q[t]).max(0.0);
                    res[t] = d;
                    s += d;
                }
                if s > 1e-9 {
                    for x in res.iter_mut() {
                        *x /= s;
                    }
                } else {
                    res.copy_from_slice(p);
                }
            }
            // Bonus output: the target after the last row's context plus
            // its own token — for the chain layout this is exactly the
            // legacy `tokens[..pos0 + k]` context. (Tree clients never use
            // this output: each leaf has its own phantom bonus row.)
            ctx_of_into(req, row, k - 1, &mut self.path, &mut self.ctx);
            self.ctx.push(req.draft_tok[row * k + (k - 1)] as u8);
            self.ctx.truncate(req.seq);
            self.world.target_dist_into(&self.ctx, &mut self.p);
            out.bonus[row * v..(row + 1) * v].copy_from_slice(&self.p);
        }
        Ok(())
    }

    fn buckets(&self) -> Vec<(usize, usize)> {
        self.buckets.clone()
    }
}

/// Factory handing out mock engines. Draft divergence per model name is
/// configured up front (heterogeneity knob).
pub struct MockEngineFactory {
    pub world: Arc<MockWorld>,
    /// (model-name → divergence) pairs; unknown names get `default_noise`.
    pub noises: Vec<(String, f32)>,
    pub default_noise: f32,
    pub verify_k: usize,
    pub buckets: Vec<(usize, usize)>,
}

impl MockEngineFactory {
    pub fn new(world: MockWorld) -> Self {
        let max_seq = world.max_seq;
        MockEngineFactory {
            world: Arc::new(world),
            noises: vec![
                // Mirror the real zoo: bigger drafts diverge less. The
                // nano tier is the low-acceptance regime where branching
                // speculation pays (the `tree` preset's draft).
                ("qwen-draft-nano".into(), 0.75),
                ("qwen-draft-06b".into(), 0.5),
                ("qwen-draft-17b".into(), 0.3),
                ("llama-draft-1b".into(), 0.55),
                ("llama-draft-3b".into(), 0.35),
            ],
            default_noise: 0.4,
            verify_k: 32,
            buckets: vec![(4, 128.min(max_seq)), (4, max_seq), (8, 128.min(max_seq)), (8, max_seq)],
        }
    }

    fn noise_for(&self, model: &str) -> f32 {
        self.noises
            .iter()
            .find(|(m, _)| m == model)
            .map(|(_, n)| *n)
            .unwrap_or(self.default_noise)
    }
}

impl EngineFactory for MockEngineFactory {
    fn make_drafter(&self, model: &str) -> Result<Box<dyn Drafter>> {
        let tag = model.bytes().fold(0u64, |h, b| mix(h, b as u64));
        Ok(Box::new(MockDrafter {
            world: self.world.clone(),
            noise: self.noise_for(model),
            client_tag: tag,
            ctx: Vec::new(),
        }))
    }

    fn make_verifier(&self, _family: &str) -> Result<Box<dyn Verifier>> {
        Ok(Box::new(MockVerifier {
            world: self.world.clone(),
            buckets: self.buckets.clone(),
            p: Vec::new(),
            ctx: Vec::new(),
            path: Vec::new(),
        }))
    }

    fn make_target_stepper(&self, _family: &str) -> Result<Box<dyn Drafter>> {
        Ok(Box::new(MockDrafter {
            world: self.world.clone(),
            noise: 0.0, // target == world truth
            client_tag: 0,
            ctx: Vec::new(),
        }))
    }

    fn vocab(&self) -> usize {
        self.world.vocab
    }

    fn max_seq(&self) -> usize {
        self.world.max_seq
    }

    fn verify_k(&self) -> usize {
        self.verify_k
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn world() -> MockWorld {
        MockWorld { vocab: 32, max_seq: 64, sharpness: 3.0, seed: 11 }
    }

    #[test]
    fn distributions_normalized_and_deterministic() {
        let w = world();
        let ctx = [1u8, 2, 3];
        let p1 = w.target_dist(&ctx);
        let p2 = w.target_dist(&ctx);
        assert_eq!(p1, p2);
        let s: f32 = p1.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!(p1.iter().all(|&x| x >= 0.0));
        // context-sensitive
        assert_ne!(p1, w.target_dist(&[9u8, 9, 9]));
    }

    #[test]
    fn zero_noise_draft_equals_target() {
        let w = world();
        let ctx = [5u8, 6];
        assert_eq!(w.draft_dist(&ctx, 0.0, 1), w.target_dist(&ctx));
    }

    #[test]
    fn noise_increases_divergence() {
        let w = world();
        let ctx = [7u8, 8, 9];
        let p = w.target_dist(&ctx);
        let tv = |q: &[f32]| -> f32 {
            q.iter().zip(&p).map(|(&a, &b)| (a - b).abs()).sum::<f32>() / 2.0
        };
        let q_low = w.draft_dist(&ctx, 0.2, 1);
        let q_high = w.draft_dist(&ctx, 0.8, 1);
        assert!(tv(&q_high) > tv(&q_low));
    }

    #[test]
    fn drafter_position_semantics() {
        let f = MockEngineFactory::new(world());
        let mut d = f.make_drafter("x").unwrap();
        let probs = d.prefill(&[1, 2, 3]).unwrap();
        assert_eq!(probs.len(), 32);
        assert_eq!(d.position(), 3);
        d.step(4).unwrap();
        assert_eq!(d.position(), 4);
        d.rewind(3);
        assert_eq!(d.position(), 3);
    }

    #[test]
    fn drafter_rejects_bad_prompts() {
        let f = MockEngineFactory::new(world());
        let mut d = f.make_drafter("x").unwrap();
        assert!(d.prefill(&[]).is_err());
        assert!(d.prefill(&vec![0u8; 64]).is_err());
    }

    #[test]
    fn verifier_consistent_with_world() {
        let w = world();
        let f = MockEngineFactory::new(w.clone());
        let mut ver = f.make_verifier("fam").unwrap();
        let mut drafter = f.make_drafter("qwen-draft-06b").unwrap();
        let prompt = [10u8, 11, 12, 13];
        let mut q_all = drafter.prefill(&prompt).unwrap();
        let mut rng = Rng::new(0);
        let k = 4usize;
        let (b, s, v) = (1usize, 16usize, 32usize);
        let mut tokens = vec![0i32; b * s];
        for (i, &t) in prompt.iter().enumerate() {
            tokens[i] = t as i32;
        }
        let mut draft_tok = vec![0i32; k];
        let mut q_probs = vec![0.0f32; k * v];
        for j in 0..k {
            let t = rng.categorical(&q_all) as u8;
            draft_tok[j] = t as i32;
            tokens[prompt.len() + j] = t as i32;
            q_probs[j * v..(j + 1) * v].copy_from_slice(&q_all);
            q_all = drafter.step(t).unwrap();
        }
        let req = VerifyRequest {
            tokens,
            batch: b,
            seq: s,
            draft_tok,
            q_probs: q_probs.clone(),
            pos0: vec![prompt.len() as i32],
            parent: super::engine::chain_parent_array(b, k),
            k,
            vocab: v,
        };
        let out = ver.verify(&req).unwrap();
        // First ratio must equal min(1, p(tok|prompt)/q(tok|prompt)).
        let p = w.target_dist(&prompt);
        let tok = req.draft_tok[0] as usize;
        let expect = (p[tok] / q_probs[tok].max(1e-9)).min(1.0);
        assert!((out.ratio[0] - expect).abs() < 1e-5);
        // Residual rows are distributions.
        for j in 0..k {
            let s: f32 = out.resid[j * v..(j + 1) * v].iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "row {j} sums {s}");
        }
        let sb: f32 = out.bonus.iter().sum();
        assert!((sb - 1.0).abs() < 1e-4);
    }

    #[test]
    fn verifier_tree_contexts_follow_parent_pointers() {
        let w = world();
        let f = MockEngineFactory::new(w.clone());
        let mut ver = f.make_verifier("fam").unwrap();
        let (b, s, v, k) = (1usize, 16usize, 32usize, 4usize);
        let prompt = [3u8, 4, 5];
        let mut tokens = vec![0i32; b * s];
        for (i, &t) in prompt.iter().enumerate() {
            tokens[i] = t as i32;
        }
        // Nodes 0 and 1 are siblings off the root; node 2 is a child of
        // node 1; row 3 is unused padding.
        let draft_tok = vec![7i32, 9, 11, 0];
        tokens[3] = 7;
        tokens[4] = 9;
        tokens[5] = 11;
        let parent = vec![-1i32, -1, 1, 2];
        let q_probs = vec![1.0f32 / v as f32; k * v];
        let req = VerifyRequest {
            tokens,
            batch: b,
            seq: s,
            draft_tok,
            q_probs,
            pos0: vec![3],
            parent,
            k,
            vocab: v,
        };
        let out = ver.verify(&req).unwrap();
        // Siblings share the root context ⇒ identical residual rows.
        assert_eq!(&out.resid[0..v], &out.resid[v..2 * v]);
        // Node 2's context is the prefix plus its parent's token (9), NOT
        // the linear prefix+[7, 9] a chain layout would use.
        let p = w.target_dist(&[3, 4, 5, 9]);
        let expect = (p[11] / (1.0 / 32.0)).min(1.0);
        assert!((out.ratio[2] - expect).abs() < 1e-5, "{} vs {expect}", out.ratio[2]);
    }

    #[test]
    fn verify_into_matches_verify_and_reuses_buffers() {
        let f = MockEngineFactory::new(world());
        let mut ver = f.make_verifier("fam").unwrap();
        let (b, s, v, k) = (2usize, 16usize, 32usize, 4usize);
        let mut rng = Rng::new(3);
        let mut tokens = vec![0i32; b * s];
        let mut draft_tok = vec![0i32; b * k];
        let mut q_probs = vec![0.0f32; b * k * v];
        for row in 0..b {
            for i in 0..6 {
                tokens[row * s + i] = rng.below(32) as i32;
            }
            for j in 0..k {
                draft_tok[row * k + j] = rng.below(32) as i32;
                tokens[row * s + 3 + j] = draft_tok[row * k + j];
                for t in 0..v {
                    q_probs[(row * k + j) * v + t] = 1.0 / v as f32;
                }
            }
        }
        let req = VerifyRequest {
            tokens,
            batch: b,
            seq: s,
            draft_tok,
            q_probs,
            pos0: vec![3; b],
            parent: super::engine::chain_parent_array(b, k),
            k,
            vocab: v,
        };
        let expect = ver.verify(&req).unwrap();
        let mut out = VerifyOutput::default();
        ver.verify_into(&req, &mut out).unwrap();
        assert_eq!(out, expect);
        // Warm call: scratch and output capacities are in place, so the
        // verifier never touches the heap (observable under alloc_track).
        let (res, allocs) =
            crate::util::alloc_track::measure(|| ver.verify_into(&req, &mut out));
        res.unwrap();
        assert_eq!(out, expect);
        if crate::util::alloc_track::enabled() {
            assert_eq!(allocs, 0, "warm verify_into must not allocate");
        }
    }

    #[test]
    fn acceptance_rate_orders_by_noise() {
        // Monte-Carlo E_q[min(1,p/q)] must decrease with noise.
        let w = world();
        let mut rng = Rng::new(1);
        let mut alpha_for = |noise: f32| -> f64 {
            let mut acc = 0.0f64;
            let n = 2000;
            for _ in 0..n {
                let ctx: Vec<u8> = (0..4).map(|_| rng.below(32) as u8).collect();
                let p = w.target_dist(&ctx);
                let q = w.draft_dist(&ctx, noise, 3);
                let tok = rng.categorical(&q);
                acc += (p[tok] as f64 / q[tok].max(1e-9) as f64).min(1.0);
            }
            acc / n as f64
        };
        let a_low = alpha_for(0.1);
        let a_mid = alpha_for(0.45);
        let a_high = alpha_for(0.9);
        assert!(a_low > a_mid && a_mid > a_high, "{a_low} {a_mid} {a_high}");
        assert!(a_low > 0.8);
        assert!(a_high < 0.7);
    }
}
