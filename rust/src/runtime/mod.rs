//! Runtime layer: AOT-artifact loading and execution (PJRT) plus the
//! synthetic mock engines used by tests and fast simulations.

pub mod engine;
pub mod manifest;
pub mod mock;
/// Real PJRT engines when the `xla` feature (and the vendored xla-rs
/// crate) is available; a fail-at-use stub otherwise so the default build
/// needs no native toolchain.
#[cfg(feature = "xla")]
pub mod xla_engine;
#[cfg(not(feature = "xla"))]
#[path = "xla_stub.rs"]
pub mod xla_engine;

pub use engine::{
    chain_parent_array, pick_bucket, Drafter, EngineFactory, Verifier, VerifyOutput,
    VerifyRequest,
};
pub use manifest::{default_artifacts_dir, Manifest};
pub use mock::{MockEngineFactory, MockWorld};
pub use xla_engine::{XlaDrafter, XlaEngineFactory, XlaVerifier};
