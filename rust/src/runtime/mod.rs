//! Runtime layer: AOT-artifact loading and execution (PJRT) plus the
//! synthetic mock engines used by tests and fast simulations.

pub mod engine;
pub mod manifest;
pub mod mock;
pub mod xla_engine;

pub use engine::{pick_bucket, Drafter, EngineFactory, Verifier, VerifyOutput, VerifyRequest};
pub use manifest::{default_artifacts_dir, Manifest};
pub use mock::{MockEngineFactory, MockWorld};
pub use xla_engine::{XlaDrafter, XlaEngineFactory, XlaVerifier};
