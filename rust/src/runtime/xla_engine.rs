//! PJRT-backed engines: load HLO-text artifacts, compile once per process,
//! execute from the Rust hot path. Python never runs here.
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute_b`. Model weights are uploaded once per
//! engine as device buffers (read straight from the training `.npz` via the
//! crate's npy reader) and reused every call; only the small per-call
//! inputs (tokens, positions, KV cache) move per invocation.
//!
//! PJRT handles here are `Rc`-based (not `Send`): the factory is cheap,
//! `Send + Sync` metadata; each consumer thread builds its own engines.

use std::path::Path;

use anyhow::{anyhow, Context, Result};
use xla::{FromRawBytes, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use super::engine::{
    pick_bucket, Drafter, EngineFactory, Verifier, VerifyOutput, VerifyRequest,
};
use super::manifest::{Manifest, ModelEntry};

fn compile(client: &PjRtClient, path: &Path) -> Result<PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow!("loading HLO text {path:?}: {e}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).map_err(|e| anyhow!("compiling {path:?}: {e}"))
}

fn upload_weights(client: &PjRtClient, manifest: &Manifest, model: &ModelEntry) -> Result<Vec<PjRtBuffer>> {
    let path = manifest.path(&model.weights_npz);
    let names: Vec<&str> = model.param_names.iter().map(String::as_str).collect();
    // NOTE: go through Literal (not PjRtBuffer::read_npz_by_name): the
    // vendored crate's raw-bytes upload passes `ElementType as i32` where
    // the C API expects a PrimitiveType, silently reinterpreting f32 as
    // f16. The Literal path converts element types correctly.
    let literals = Literal::read_npz_by_name(&path, &(), &names)
        .map_err(|e| anyhow!("loading weights {path:?}: {e}"))?;
    literals
        .iter()
        .map(|lit| {
            client
                .buffer_from_host_literal(None, lit)
                .map_err(|e| anyhow!("uploading weights {path:?}: {e}"))
        })
        .collect()
}

fn literal_i32(data: &[i32], dims: &[i64]) -> Result<Literal> {
    Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape i32{dims:?}: {e}"))
}

fn literal_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
    Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape f32{dims:?}: {e}"))
}

/// KV-cached autoregressive drafter over `step_*.hlo.txt` /
/// `prefill_*.hlo.txt`. Results are untupled (see the third_party/xla-rs
/// patch), so the KV cache stays **device-resident** between steps — the
/// per-token hot path uploads two scalars and downloads one `[V]` row.
pub struct XlaDrafter {
    client: PjRtClient,
    prefill_exe: PjRtLoadedExecutable,
    step_exe: PjRtLoadedExecutable,
    weights: Vec<PjRtBuffer>,
    cache: Option<PjRtBuffer>,
    position: usize,
    max_seq: usize,
    vocab: usize,
}

impl XlaDrafter {
    pub fn new(manifest: &Manifest, model_name: &str) -> Result<Self> {
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e}"))?;
        let model = manifest.model(model_name)?;
        let prefill_exe = compile(&client, &manifest.path(&model.prefill_hlo))?;
        let step_exe = compile(&client, &manifest.path(&model.step_hlo))?;
        let weights = upload_weights(&client, manifest, model)?;
        Ok(XlaDrafter {
            client,
            prefill_exe,
            step_exe,
            weights,
            cache: None,
            position: 0,
            max_seq: manifest.max_seq,
            vocab: manifest.vocab,
        })
    }

    /// Execute with the resident weights plus per-call inputs (small host
    /// literals and/or device buffers); returns the untupled output leaves.
    fn run(
        &self,
        exe: &PjRtLoadedExecutable,
        literals: &[&Literal],
        extra_buffers: &[&PjRtBuffer],
    ) -> Result<Vec<PjRtBuffer>> {
        let mut bufs: Vec<PjRtBuffer> = Vec::with_capacity(literals.len());
        let mut refs: Vec<&PjRtBuffer> =
            Vec::with_capacity(self.weights.len() + literals.len() + extra_buffers.len());
        refs.extend(self.weights.iter());
        for lit in literals {
            bufs.push(
                self.client
                    .buffer_from_host_literal(None, lit)
                    .map_err(|e| anyhow!("upload input: {e}"))?,
            );
        }
        refs.extend(bufs.iter());
        refs.extend(extra_buffers.iter().copied());
        let mut out = exe.execute_b(&refs).map_err(|e| anyhow!("execute: {e}"))?;
        Ok(std::mem::take(&mut out[0]))
    }
}

impl Drafter for XlaDrafter {
    fn prefill(&mut self, prompt: &[u8]) -> Result<Vec<f32>> {
        if prompt.is_empty() {
            return Err(anyhow!("empty prompt"));
        }
        if prompt.len() >= self.max_seq {
            return Err(anyhow!("prompt ({}) ≥ max_seq ({})", prompt.len(), self.max_seq));
        }
        let mut tokens = vec![0i32; self.max_seq];
        for (i, &b) in prompt.iter().enumerate() {
            tokens[i] = b as i32;
        }
        let lit = literal_i32(&tokens, &[1, self.max_seq as i64])?;
        let mut outs = self.run(&self.prefill_exe, &[&lit], &[])?;
        if outs.len() != 2 {
            return Err(anyhow!("prefill returned {} outputs, want 2", outs.len()));
        }
        // Output order: (cache, probs[S, V]); keep the cache on device.
        let probs = outs.pop().unwrap().to_literal_sync()?;
        self.cache = Some(outs.pop().unwrap());
        let flat = probs.to_vec::<f32>()?;
        let v = self.vocab;
        let row = prompt.len() - 1;
        self.position = prompt.len();
        Ok(flat[row * v..(row + 1) * v].to_vec())
    }

    fn step(&mut self, tok: u8) -> Result<Vec<f32>> {
        if self.position >= self.max_seq {
            return Err(anyhow!("context overflow at {}", self.position));
        }
        let cache = self.cache.take().ok_or_else(|| anyhow!("step before prefill"))?;
        let tok_lit = Literal::scalar(tok as i32);
        let pos_lit = Literal::scalar(self.position as i32);
        let mut outs = self.run(&self.step_exe, &[&tok_lit, &pos_lit], &[&cache])?;
        if outs.len() != 2 {
            return Err(anyhow!("step returned {} outputs, want 2", outs.len()));
        }
        // Output order: (probs[V], cache'); keep the cache on device.
        self.cache = Some(outs.pop().unwrap());
        let probs = outs.pop().unwrap().to_literal_sync()?;
        self.position += 1;
        probs.to_vec::<f32>().map_err(|e| anyhow!("download probs: {e}"))
    }

    fn position(&self) -> usize {
        self.position
    }

    fn rewind(&mut self, position: usize) {
        assert!(position <= self.position, "rewind must move backwards");
        // Stale cache rows beyond `position` are never attended to: the
        // step graph masks to `pos_ids <= pos`.
        self.position = position;
    }

    fn max_seq(&self) -> usize {
        self.max_seq
    }

    fn vocab(&self) -> usize {
        self.vocab
    }
}

/// Batched verification over the bucketed `verify_*.hlo.txt` graphs.
pub struct XlaVerifier {
    client: PjRtClient,
    /// (batch, seq) → compiled executable (lazy per bucket).
    compiled: Vec<((usize, usize), PjRtLoadedExecutable)>,
    bucket_files: Vec<((usize, usize), std::path::PathBuf)>,
    weights: Vec<PjRtBuffer>,
    k: usize,
    vocab: usize,
}

impl XlaVerifier {
    pub fn new(manifest: &Manifest, family: &str) -> Result<Self> {
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e}"))?;
        let fam = manifest.family(family)?;
        let target = manifest.model(&fam.target)?;
        let weights = upload_weights(&client, manifest, target)?;
        let bucket_files = fam
            .verify_buckets
            .iter()
            .map(|b| ((b.batch, b.seq), manifest.path(&b.hlo)))
            .collect();
        Ok(XlaVerifier {
            client,
            compiled: Vec::new(),
            bucket_files,
            weights,
            k: manifest.verify_k,
            vocab: manifest.vocab,
        })
    }

    fn exe_for(&mut self, bucket: (usize, usize)) -> Result<usize> {
        if let Some(i) = self.compiled.iter().position(|(b, _)| *b == bucket) {
            return Ok(i);
        }
        let path = self
            .bucket_files
            .iter()
            .find(|(b, _)| *b == bucket)
            .map(|(_, p)| p.clone())
            .ok_or_else(|| anyhow!("no verify bucket {bucket:?}"))?;
        let exe = compile(&self.client, &path)?;
        self.compiled.push((bucket, exe));
        Ok(self.compiled.len() - 1)
    }
}

impl Verifier for XlaVerifier {
    fn verify(&mut self, req: &VerifyRequest) -> Result<VerifyOutput> {
        // GOODSPEED_FORCE_MAX_BUCKET=1 disables shape bucketing (always the
        // largest bucket) — the ablation lane for EXPERIMENTS.md §Perf.
        let bucket = if std::env::var("GOODSPEED_FORCE_MAX_BUCKET").is_ok() {
            *self
                .bucket_files
                .iter()
                .map(|(b, _)| b)
                .max_by_key(|(b, s)| b * s)
                .expect("no buckets")
        } else {
            pick_bucket(&self.buckets(), req.batch, req.seq)
        };
        let (bb, bs) = bucket;
        if req.batch > bb || req.seq > bs {
            return Err(anyhow!("request ({}, {}) exceeds largest bucket {bucket:?}", req.batch, req.seq));
        }
        if req.k != self.k {
            return Err(anyhow!("k mismatch: req {} vs artifact {}", req.k, self.k));
        }
        // The AOT verify graph gathers *linear* per-position contexts; a
        // branching topology needs a tree-attention artifact. Until one is
        // compiled, tree waves run on the mock engine.
        let chain = req
            .parent
            .iter()
            .enumerate()
            .all(|(idx, &p)| p == (idx % req.k) as i32 - 1);
        if !chain {
            return Err(anyhow!(
                "XLA verify artifacts are chain-only; tree topologies need a \
                 tree-attention graph (use --engine mock or spec_shape=chain)"
            ));
        }
        let v = self.vocab;
        // Pad the request into the bucket shape.
        let mut tokens = vec![0i32; bb * bs];
        for row in 0..req.batch {
            tokens[row * bs..row * bs + req.seq]
                .copy_from_slice(&req.tokens[row * req.seq..(row + 1) * req.seq]);
        }
        let mut draft_tok = vec![0i32; bb * self.k];
        draft_tok[..req.batch * self.k].copy_from_slice(&req.draft_tok);
        let mut q_probs = vec![1.0f32 / v as f32; bb * self.k * v];
        q_probs[..req.batch * self.k * v].copy_from_slice(&req.q_probs);
        let mut pos0 = vec![1i32; bb];
        pos0[..req.batch].copy_from_slice(&req.pos0);

        let idx = self.exe_for(bucket)?;
        let inputs = vec![
            literal_i32(&tokens, &[bb as i64, bs as i64])?,
            literal_i32(&draft_tok, &[bb as i64, self.k as i64])?,
            literal_f32(&q_probs, &[bb as i64, self.k as i64, v as i64])?,
            literal_i32(&pos0, &[bb as i64])?,
        ];
        let mut refs: Vec<&PjRtBuffer> = self.weights.iter().collect();
        let mut bufs = Vec::with_capacity(inputs.len());
        for lit in &inputs {
            bufs.push(
                self.client
                    .buffer_from_host_literal(None, lit)
                    .map_err(|e| anyhow!("upload verify input: {e}"))?,
            );
        }
        refs.extend(bufs.iter());
        let exe = &self.compiled[idx].1;
        let out = exe.execute_b(&refs).map_err(|e| anyhow!("verify execute: {e}"))?;
        // Untupled outputs: (ratio, resid, bonus).
        if out[0].len() != 3 {
            return Err(anyhow!("verify returned {} outputs, want 3", out[0].len()));
        }
        let ratio_full = out[0][0].to_literal_sync()?.to_vec::<f32>()?;
        let resid_full = out[0][1].to_literal_sync()?.to_vec::<f32>()?;
        let bonus_full = out[0][2].to_literal_sync()?.to_vec::<f32>()?;
        // Un-pad back to the request batch.
        Ok(VerifyOutput {
            ratio: ratio_full[..req.batch * self.k].to_vec(),
            resid: resid_full[..req.batch * self.k * v].to_vec(),
            bonus: bonus_full[..req.batch * v].to_vec(),
        })
    }

    fn buckets(&self) -> Vec<(usize, usize)> {
        self.bucket_files.iter().map(|(b, _)| *b).collect()
    }
}

/// `Send + Sync` factory: holds only the manifest; engines (and their PJRT
/// clients) are constructed inside the consuming thread.
pub struct XlaEngineFactory {
    pub manifest: Manifest,
}

impl XlaEngineFactory {
    pub fn new(manifest: Manifest) -> Self {
        XlaEngineFactory { manifest }
    }

    pub fn from_default_dir() -> Result<Self> {
        let dir = super::manifest::default_artifacts_dir();
        let manifest = Manifest::load(&dir)?;
        manifest.validate_files().context("artifacts incomplete — run `make artifacts`")?;
        Ok(XlaEngineFactory { manifest })
    }
}

impl EngineFactory for XlaEngineFactory {
    fn make_drafter(&self, model: &str) -> Result<Box<dyn Drafter>> {
        Ok(Box::new(XlaDrafter::new(&self.manifest, model)?))
    }

    fn make_verifier(&self, family: &str) -> Result<Box<dyn Verifier>> {
        Ok(Box::new(XlaVerifier::new(&self.manifest, family)?))
    }

    fn make_target_stepper(&self, family: &str) -> Result<Box<dyn Drafter>> {
        let fam = self.manifest.family(family)?;
        let target = fam.target.clone();
        Ok(Box::new(XlaDrafter::new(&self.manifest, &target)?))
    }

    fn vocab(&self) -> usize {
        self.manifest.vocab
    }

    fn max_seq(&self) -> usize {
        self.manifest.max_seq
    }

    fn verify_k(&self) -> usize {
        self.manifest.verify_k
    }
}

#[cfg(test)]
mod tests {
    //! Gated on artifacts being present (Makefile runs `make artifacts`
    //! before `cargo test`); each test skips cleanly otherwise.
    use super::*;
    use crate::runtime::manifest::default_artifacts_dir;

    fn factory() -> Option<XlaEngineFactory> {
        let dir = default_artifacts_dir();
        if dir.join("manifest.json").exists() {
            Some(XlaEngineFactory::new(Manifest::load(&dir).unwrap()))
        } else {
            None
        }
    }

    #[test]
    fn drafter_prefill_and_step_shapes() {
        let Some(f) = factory() else { return };
        let mut d = f.make_drafter("qwen-draft-06b").unwrap();
        let prompt = crate::tokenizer::encode("### Instruction: list the river.");
        let probs = d.prefill(&prompt).unwrap();
        assert_eq!(probs.len(), 256);
        let s: f32 = probs.iter().sum();
        assert!((s - 1.0).abs() < 1e-3, "prefill probs sum {s}");
        assert_eq!(d.position(), prompt.len());
        let probs2 = d.step(b' ').unwrap();
        assert_eq!(probs2.len(), 256);
        let s2: f32 = probs2.iter().sum();
        assert!((s2 - 1.0).abs() < 1e-3);
        assert_eq!(d.position(), prompt.len() + 1);
    }

    #[test]
    fn trained_model_is_peaked_on_template() {
        // After "### Instruction: " the trained draft should be far from
        // uniform (it has seen thousands of these).
        let Some(f) = factory() else { return };
        let mut d = f.make_drafter("qwen-draft-06b").unwrap();
        let probs = d.prefill(crate::tokenizer::encode("### Instruction:").as_slice()).unwrap();
        let max = probs.iter().cloned().fold(0.0f32, f32::max);
        assert!(max > 0.5, "expected peaked distribution, max={max}");
    }

    #[test]
    fn verifier_runs_and_normalizes() {
        let Some(f) = factory() else { return };
        let mut ver = f.make_verifier("qwen").unwrap();
        let (b, s, k, v) = (2usize, 128usize, 32usize, 256usize);
        let prompt = crate::tokenizer::encode("q: tom has 3 apples and buys 4 more.");
        let mut tokens = vec![0i32; b * s];
        for row in 0..b {
            for (i, &t) in prompt.iter().enumerate() {
                tokens[row * s + i] = t as i32;
            }
            for j in 0..k {
                tokens[row * s + prompt.len() + j] = b' ' as i32;
            }
        }
        let req = VerifyRequest {
            tokens,
            batch: b,
            seq: s,
            draft_tok: vec![b' ' as i32; b * k],
            q_probs: vec![1.0 / v as f32; b * k * v],
            pos0: vec![prompt.len() as i32; b],
            parent: super::engine::chain_parent_array(b, k),
            k,
            vocab: v,
        };
        let out = ver.verify(&req).unwrap();
        assert_eq!(out.ratio.len(), b * k);
        assert!(out.ratio.iter().all(|&r| (0.0..=1.0 + 1e-5).contains(&r)));
        for row in 0..b * k {
            let sum: f32 = out.resid[row * v..(row + 1) * v].iter().sum();
            assert!((sum - 1.0).abs() < 1e-3, "resid row {row} sums {sum}");
        }
        for row in 0..b {
            let sum: f32 = out.bonus[row * v..(row + 1) * v].iter().sum();
            assert!((sum - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn prefill_step_consistency_with_verify() {
        // The drafter's own q at a position, when passed to the verifier
        // with the *target's* family == draft model, must yield ratio ≈ 1
        // (p == q when target and draft are the same model).
        let Some(f) = factory() else { return };
        // Build a "family" on the fly: verify graph uses the qwen target,
        // so instead use the target stepper both sides.
        let mut tgt = f.make_target_stepper("qwen").unwrap();
        let prompt = crate::tokenizer::encode("act as a pilot.");
        let q0 = tgt.prefill(&prompt).unwrap();
        // greedy token from target
        let tok = q0
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0 as u8;
        let (b, s, k, v) = (1usize, 128usize, 32usize, 256usize);
        let mut tokens = vec![0i32; b * s];
        for (i, &t) in prompt.iter().enumerate() {
            tokens[i] = t as i32;
        }
        tokens[prompt.len()] = tok as i32;
        let mut q_probs = vec![1.0 / v as f32; b * k * v];
        q_probs[..v].copy_from_slice(&q0);
        let mut draft_tok = vec![0i32; b * k];
        draft_tok[0] = tok as i32;
        let mut ver = f.make_verifier("qwen").unwrap();
        let req = VerifyRequest {
            tokens,
            batch: b,
            seq: s,
            draft_tok,
            q_probs,
            pos0: vec![prompt.len() as i32],
            parent: super::engine::chain_parent_array(b, k),
            k,
            vocab: v,
        };
        let out = ver.verify(&req).unwrap();
        assert!(
            (out.ratio[0] - 1.0).abs() < 5e-2,
            "p==q should give ratio ≈ 1, got {}",
            out.ratio[0]
        );
    }
}
