//! Stub for the PJRT engines when the crate is built **without** the
//! `xla` feature (the default — the vendored `xla-rs` bindings and the
//! AOT artifacts are only present on full build hosts).
//!
//! The stub keeps every call site compiling (CLI `--engine xla`, the
//! runtime benches, the gated integration tests) and fails *at use* with
//! an actionable message instead of at build time. All tests, benches,
//! simulations, and experiments run on [`crate::runtime::MockEngineFactory`]
//! either way; see `src/runtime/xla_engine.rs` for the real engines.

use anyhow::{anyhow, Result};

use super::engine::{Drafter, EngineFactory, Verifier, VerifyOutput, VerifyRequest};
use super::manifest::Manifest;

const UNAVAILABLE: &str =
    "built without the `xla` feature — rebuild with `--features xla` (requires the vendored xla-rs crate) or use `--engine mock`";

/// Stub draft engine; construction always fails.
pub struct XlaDrafter {
    _private: (),
}

impl XlaDrafter {
    pub fn new(_manifest: &Manifest, _model_name: &str) -> Result<Self> {
        Err(anyhow!(UNAVAILABLE))
    }
}

impl Drafter for XlaDrafter {
    fn prefill(&mut self, _prompt: &[u8]) -> Result<Vec<f32>> {
        Err(anyhow!(UNAVAILABLE))
    }

    fn step(&mut self, _tok: u8) -> Result<Vec<f32>> {
        Err(anyhow!(UNAVAILABLE))
    }

    fn position(&self) -> usize {
        0
    }

    fn rewind(&mut self, _position: usize) {}

    fn max_seq(&self) -> usize {
        0
    }

    fn vocab(&self) -> usize {
        0
    }
}

/// Stub verification engine; construction always fails.
pub struct XlaVerifier {
    _private: (),
}

impl XlaVerifier {
    pub fn new(_manifest: &Manifest, _family: &str) -> Result<Self> {
        Err(anyhow!(UNAVAILABLE))
    }
}

impl Verifier for XlaVerifier {
    fn verify(&mut self, _req: &VerifyRequest) -> Result<VerifyOutput> {
        Err(anyhow!(UNAVAILABLE))
    }

    fn buckets(&self) -> Vec<(usize, usize)> {
        Vec::new()
    }
}

/// Stub factory: carries the manifest for shape metadata, errors on any
/// engine construction.
pub struct XlaEngineFactory {
    pub manifest: Manifest,
}

impl XlaEngineFactory {
    pub fn new(manifest: Manifest) -> Self {
        XlaEngineFactory { manifest }
    }

    pub fn from_default_dir() -> Result<Self> {
        Err(anyhow!(UNAVAILABLE))
    }
}

impl EngineFactory for XlaEngineFactory {
    fn make_drafter(&self, _model: &str) -> Result<Box<dyn Drafter>> {
        Err(anyhow!(UNAVAILABLE))
    }

    fn make_verifier(&self, _family: &str) -> Result<Box<dyn Verifier>> {
        Err(anyhow!(UNAVAILABLE))
    }

    fn make_target_stepper(&self, _family: &str) -> Result<Box<dyn Drafter>> {
        Err(anyhow!(UNAVAILABLE))
    }

    fn vocab(&self) -> usize {
        self.manifest.vocab
    }

    fn max_seq(&self) -> usize {
        self.manifest.max_seq
    }

    fn verify_k(&self) -> usize {
        self.manifest.verify_k
    }
}
