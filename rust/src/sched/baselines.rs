//! Allocation policies: GoodSpeed's gradient scheduler plus the two
//! baselines the paper evaluates against (§IV-B2).

use std::sync::Arc;

use super::estimator::Estimators;
use super::gradient::{solve_greedy, AllocInput};
use super::utility::Utility;
use crate::configsys::Policy;
use crate::util::Rng;

/// Per-wave allocation caps (budget + per-client context room).
#[derive(Clone, Debug)]
pub struct AllocCaps {
    /// Verification budget C (already net of any reservations).
    pub capacity: usize,
    /// Per-client max draft length (min of artifact K and context room).
    /// May be 0 for a *live* client whose context is momentarily full.
    pub max_per_client: Vec<usize>,
    /// Clients eligible for this allocation (the wave's participants).
    /// Sync rounds pass all-true; async waves pass their subset so
    /// uniform/random baselines split the budget over the live set
    /// instead of diluting it across absent clients.
    pub live: Vec<bool>,
}

impl AllocCaps {
    /// Caps with every client live (the sync-barrier shape).
    pub fn dense(capacity: usize, max_per_client: Vec<usize>) -> AllocCaps {
        let live = vec![true; max_per_client.len()];
        AllocCaps { capacity, max_per_client, live }
    }
}

/// A per-round draft-length allocator. Implementations must be
/// deterministic given their own state (Random-S carries its PRNG).
pub trait Allocator: Send {
    fn allocate(&mut self, est: &Estimators, caps: &AllocCaps) -> Vec<usize>;
    fn name(&self) -> &'static str;
}

/// The paper's gradient scheduling algorithm (Algorithm 1, line 15).
pub struct GoodSpeedAlloc {
    pub utility: Arc<dyn Utility>,
}

impl GoodSpeedAlloc {
    pub fn log() -> Self {
        GoodSpeedAlloc { utility: Arc::new(super::utility::LogUtility) }
    }
}

impl Allocator for GoodSpeedAlloc {
    fn allocate(&mut self, est: &Estimators, caps: &AllocCaps) -> Vec<usize> {
        let weights: Vec<f64> = est.x_beta.iter().map(|&x| self.utility.grad(x)).collect();
        // Enforce the live mask here (not only at call sites): absent
        // clients must never receive budget — their in-flight grant is
        // already reserved by the coordinator.
        let capped: Vec<usize> = caps
            .max_per_client
            .iter()
            .zip(&caps.live)
            .map(|(&m, &live)| if live { m } else { 0 })
            .collect();
        let input = AllocInput {
            weights: &weights,
            alphas: &est.alpha_hat,
            capacity: caps.capacity,
            max_per_client: &capped,
        };
        solve_greedy(&input)
    }

    fn name(&self) -> &'static str {
        "goodspeed"
    }
}

/// Fixed-S: `S_i = C / N` every round (uniform static split).
pub struct FixedSAlloc;

impl Allocator for FixedSAlloc {
    fn allocate(&mut self, est: &Estimators, caps: &AllocCaps) -> Vec<usize> {
        // Uniform split over the *live* clients (== C / N in sync mode).
        let live_n = caps.live.iter().filter(|&&l| l).count().max(1);
        let share = caps.capacity / live_n;
        (0..est.len())
            .map(|i| if caps.live[i] { share.min(caps.max_per_client[i]) } else { 0 })
            .collect()
    }

    fn name(&self) -> &'static str {
        "fixed-s"
    }
}

/// Random-S: each budget unit lands on a uniformly random client with
/// remaining room, so Σ S_i ≤ C always holds (paper's constraint).
pub struct RandomSAlloc {
    pub rng: Rng,
}

impl RandomSAlloc {
    pub fn new(seed: u64) -> Self {
        RandomSAlloc { rng: Rng::new(seed) }
    }
}

impl Allocator for RandomSAlloc {
    fn allocate(&mut self, est: &Estimators, caps: &AllocCaps) -> Vec<usize> {
        let n = est.len();
        let mut alloc = vec![0usize; n];
        // Darts land only on live clients (identical RNG stream to the
        // pre-wave allocator in sync mode, where everyone is live).
        let live_idx: Vec<usize> = (0..n).filter(|&i| caps.live[i]).collect();
        if live_idx.is_empty() {
            return alloc;
        }
        for _ in 0..caps.capacity {
            // Rejection-sample a client with room (bounded retries keep the
            // loop O(C) in expectation even when most clients are full).
            for _ in 0..8 {
                let i = live_idx[self.rng.below(live_idx.len() as u64) as usize];
                if alloc[i] < caps.max_per_client[i] {
                    alloc[i] += 1;
                    break;
                }
            }
        }
        alloc
    }

    fn name(&self) -> &'static str {
        "random-s"
    }
}

/// Build the allocator for a scenario policy. `Turbo` runs the same
/// gradient allocator as GoodSpeed — the closed-loop part is the
/// per-client speculation caps the
/// [`TurboController`](super::controller::TurboController) applies inside
/// [`RoundCore`](crate::coordinator::RoundCore) before each allocation.
pub fn make_allocator(policy: Policy, seed: u64) -> Box<dyn Allocator> {
    match policy {
        Policy::GoodSpeed | Policy::Turbo => Box::new(GoodSpeedAlloc::log()),
        Policy::FixedS => Box::new(FixedSAlloc),
        Policy::RandomS => Box::new(RandomSAlloc::new(seed)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configsys::Smoothing;

    fn est(n: usize) -> Estimators {
        Estimators::new(n, Smoothing::Fixed(0.3), Smoothing::Fixed(0.5))
    }

    fn caps(n: usize, c: usize) -> AllocCaps {
        AllocCaps::dense(c, vec![32; n])
    }

    #[test]
    fn fixed_s_is_uniform_floor() {
        let mut f = FixedSAlloc;
        let alloc = f.allocate(&est(4), &caps(4, 22));
        assert_eq!(alloc, vec![5, 5, 5, 5]); // floor(22/4)
    }

    #[test]
    fn fixed_s_respects_context_room() {
        let mut f = FixedSAlloc;
        let mut cap = caps(4, 20);
        cap.max_per_client[2] = 2;
        let alloc = f.allocate(&est(4), &cap);
        assert_eq!(alloc, vec![5, 5, 2, 5]);
    }

    #[test]
    fn random_s_within_budget_every_time() {
        let mut r = RandomSAlloc::new(7);
        for _ in 0..200 {
            let alloc = r.allocate(&est(5), &caps(5, 17));
            assert!(alloc.iter().sum::<usize>() <= 17);
        }
    }

    #[test]
    fn random_s_covers_all_clients_eventually() {
        let mut r = RandomSAlloc::new(8);
        let mut seen = vec![false; 4];
        for _ in 0..50 {
            for (i, &s) in r.allocate(&est(4), &caps(4, 8)).iter().enumerate() {
                if s > 0 {
                    seen[i] = true;
                }
            }
        }
        assert!(seen.iter().all(|&b| b), "{seen:?}");
    }

    #[test]
    fn baselines_split_over_live_subset_only() {
        // Async partial wave: only clients {1, 3} participate; the budget
        // must go to them, not be diluted across absent clients.
        let mut cap = caps(4, 12);
        cap.live = vec![false, true, false, true];
        cap.max_per_client = vec![0, 32, 0, 32];
        let mut f = FixedSAlloc;
        let alloc = f.allocate(&est(4), &cap);
        assert_eq!(alloc, vec![0, 6, 0, 6]); // C / live_count, not C / N
        let mut r = RandomSAlloc::new(3);
        let alloc = r.allocate(&est(4), &cap);
        assert_eq!(alloc[0], 0);
        assert_eq!(alloc[2], 0);
        // Live clients have ample room, so no dart is ever wasted.
        assert_eq!(alloc[1] + alloc[3], 12);
    }

    #[test]
    fn goodspeed_prefers_starved_clients() {
        let mut e = est(2);
        // Client 1 has been getting everything: X^β large.
        for _ in 0..50 {
            e.update_round(&[Some((0.6, 1.0)), Some((0.6, 8.0))]);
        }
        let mut gs = GoodSpeedAlloc::log();
        let alloc = gs.allocate(&e, &caps(2, 10));
        assert!(alloc[0] > alloc[1], "starved client must get more: {alloc:?}");
    }

    #[test]
    fn make_allocator_names() {
        assert_eq!(make_allocator(Policy::GoodSpeed, 0).name(), "goodspeed");
        assert_eq!(make_allocator(Policy::FixedS, 0).name(), "fixed-s");
        assert_eq!(make_allocator(Policy::RandomS, 0).name(), "random-s");
    }
}
