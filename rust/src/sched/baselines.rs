//! Allocation policies: GoodSpeed's gradient scheduler plus the two
//! baselines the paper evaluates against (§IV-B2).

use std::sync::Arc;

use super::estimator::Estimators;
use super::gradient::{solve_greedy_into, AllocInput, GreedyScratch};
use super::utility::Utility;
use crate::configsys::Policy;
use crate::util::Rng;

/// Per-wave allocation caps (budget + per-client context room).
#[derive(Clone, Debug)]
pub struct AllocCaps {
    /// Verification budget C (already net of any reservations).
    pub capacity: usize,
    /// Per-client max draft length (min of artifact K and context room).
    /// May be 0 for a *live* client whose context is momentarily full.
    pub max_per_client: Vec<usize>,
    /// Clients eligible for this allocation (the wave's participants).
    /// Sync rounds pass all-true; async waves pass their subset so
    /// uniform/random baselines split the budget over the live set
    /// instead of diluting it across absent clients.
    pub live: Vec<bool>,
}

impl AllocCaps {
    /// Caps with every client live (the sync-barrier shape).
    pub fn dense(capacity: usize, max_per_client: Vec<usize>) -> AllocCaps {
        let live = vec![true; max_per_client.len()];
        AllocCaps { capacity, max_per_client, live }
    }
}

/// A per-round draft-length allocator. Implementations must be
/// deterministic given their own state (Random-S carries its PRNG).
pub trait Allocator: Send {
    fn allocate(&mut self, est: &Estimators, caps: &AllocCaps) -> Vec<usize> {
        let mut out = Vec::new();
        self.allocate_into(est, caps, &mut out);
        out
    }

    /// Allocation-free form: the output vector is caller-owned and reused
    /// across waves (cleared and refilled every call). The hot wave loop
    /// (`RoundCore::finish_wave`) calls this; the result must be
    /// bit-identical to [`Allocator::allocate`].
    fn allocate_into(&mut self, est: &Estimators, caps: &AllocCaps, out: &mut Vec<usize>);

    fn name(&self) -> &'static str;
}

/// The paper's gradient scheduling algorithm (Algorithm 1, line 15).
/// Carries its solver scratch (gradient weights, live-masked caps, and the
/// greedy heap) so warm-wave allocations stay at zero.
pub struct GoodSpeedAlloc {
    pub utility: Arc<dyn Utility>,
    weights: Vec<f64>,
    capped: Vec<usize>,
    scratch: GreedyScratch,
}

impl GoodSpeedAlloc {
    pub fn log() -> Self {
        GoodSpeedAlloc {
            utility: Arc::new(super::utility::LogUtility),
            weights: Vec::new(),
            capped: Vec::new(),
            scratch: GreedyScratch::default(),
        }
    }
}

impl Allocator for GoodSpeedAlloc {
    fn allocate_into(&mut self, est: &Estimators, caps: &AllocCaps, out: &mut Vec<usize>) {
        self.weights.clear();
        self.weights.extend(est.x_beta.iter().map(|&x| self.utility.grad(x)));
        // Enforce the live mask here (not only at call sites): absent
        // clients must never receive budget — their in-flight grant is
        // already reserved by the coordinator.
        self.capped.clear();
        self.capped.extend(
            caps.max_per_client
                .iter()
                .zip(&caps.live)
                .map(|(&m, &live)| if live { m } else { 0 }),
        );
        let input = AllocInput {
            weights: &self.weights,
            alphas: &est.alpha_hat,
            capacity: caps.capacity,
            max_per_client: &self.capped,
        };
        solve_greedy_into(&input, &mut self.scratch, out);
    }

    fn name(&self) -> &'static str {
        "goodspeed"
    }
}

/// Fixed-S: `S_i = C / N` every round (uniform static split).
pub struct FixedSAlloc;

impl Allocator for FixedSAlloc {
    fn allocate_into(&mut self, est: &Estimators, caps: &AllocCaps, out: &mut Vec<usize>) {
        // Uniform split over the *live* clients (== C / N in sync mode).
        let live_n = caps.live.iter().filter(|&&l| l).count().max(1);
        let share = caps.capacity / live_n;
        out.clear();
        out.extend(
            (0..est.len())
                .map(|i| if caps.live[i] { share.min(caps.max_per_client[i]) } else { 0 }),
        );
    }

    fn name(&self) -> &'static str {
        "fixed-s"
    }
}

/// Random-S: each budget unit lands on a uniformly random client with
/// remaining room, so Σ S_i ≤ C always holds (paper's constraint).
pub struct RandomSAlloc {
    pub rng: Rng,
    live_idx: Vec<usize>,
}

impl RandomSAlloc {
    pub fn new(seed: u64) -> Self {
        RandomSAlloc { rng: Rng::new(seed), live_idx: Vec::new() }
    }
}

impl Allocator for RandomSAlloc {
    fn allocate_into(&mut self, est: &Estimators, caps: &AllocCaps, out: &mut Vec<usize>) {
        let n = est.len();
        out.clear();
        out.resize(n, 0);
        // Darts land only on live clients (identical RNG stream to the
        // pre-wave allocator in sync mode, where everyone is live).
        self.live_idx.clear();
        self.live_idx.extend((0..n).filter(|&i| caps.live[i]));
        if self.live_idx.is_empty() {
            return;
        }
        for _ in 0..caps.capacity {
            // Rejection-sample a client with room (bounded retries keep the
            // loop O(C) in expectation even when most clients are full).
            for _ in 0..8 {
                let i = self.live_idx[self.rng.below(self.live_idx.len() as u64) as usize];
                if out[i] < caps.max_per_client[i] {
                    out[i] += 1;
                    break;
                }
            }
        }
    }

    fn name(&self) -> &'static str {
        "random-s"
    }
}

/// Build the allocator for a scenario policy. `Turbo` runs the same
/// gradient allocator as GoodSpeed — the closed-loop part is the
/// per-client speculation caps the
/// [`TurboController`](super::controller::TurboController) applies inside
/// [`RoundCore`](crate::coordinator::RoundCore) before each allocation.
pub fn make_allocator(policy: Policy, seed: u64) -> Box<dyn Allocator> {
    match policy {
        Policy::GoodSpeed | Policy::Turbo => Box::new(GoodSpeedAlloc::log()),
        Policy::FixedS => Box::new(FixedSAlloc),
        Policy::RandomS => Box::new(RandomSAlloc::new(seed)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configsys::Smoothing;

    fn est(n: usize) -> Estimators {
        Estimators::new(n, Smoothing::Fixed(0.3), Smoothing::Fixed(0.5))
    }

    fn caps(n: usize, c: usize) -> AllocCaps {
        AllocCaps::dense(c, vec![32; n])
    }

    #[test]
    fn fixed_s_is_uniform_floor() {
        let mut f = FixedSAlloc;
        let alloc = f.allocate(&est(4), &caps(4, 22));
        assert_eq!(alloc, vec![5, 5, 5, 5]); // floor(22/4)
    }

    #[test]
    fn fixed_s_respects_context_room() {
        let mut f = FixedSAlloc;
        let mut cap = caps(4, 20);
        cap.max_per_client[2] = 2;
        let alloc = f.allocate(&est(4), &cap);
        assert_eq!(alloc, vec![5, 5, 2, 5]);
    }

    #[test]
    fn random_s_within_budget_every_time() {
        let mut r = RandomSAlloc::new(7);
        for _ in 0..200 {
            let alloc = r.allocate(&est(5), &caps(5, 17));
            assert!(alloc.iter().sum::<usize>() <= 17);
        }
    }

    #[test]
    fn random_s_covers_all_clients_eventually() {
        let mut r = RandomSAlloc::new(8);
        let mut seen = vec![false; 4];
        for _ in 0..50 {
            for (i, &s) in r.allocate(&est(4), &caps(4, 8)).iter().enumerate() {
                if s > 0 {
                    seen[i] = true;
                }
            }
        }
        assert!(seen.iter().all(|&b| b), "{seen:?}");
    }

    #[test]
    fn baselines_split_over_live_subset_only() {
        // Async partial wave: only clients {1, 3} participate; the budget
        // must go to them, not be diluted across absent clients.
        let mut cap = caps(4, 12);
        cap.live = vec![false, true, false, true];
        cap.max_per_client = vec![0, 32, 0, 32];
        let mut f = FixedSAlloc;
        let alloc = f.allocate(&est(4), &cap);
        assert_eq!(alloc, vec![0, 6, 0, 6]); // C / live_count, not C / N
        let mut r = RandomSAlloc::new(3);
        let alloc = r.allocate(&est(4), &cap);
        assert_eq!(alloc[0], 0);
        assert_eq!(alloc[2], 0);
        // Live clients have ample room, so no dart is ever wasted.
        assert_eq!(alloc[1] + alloc[3], 12);
    }

    #[test]
    fn goodspeed_prefers_starved_clients() {
        let mut e = est(2);
        // Client 1 has been getting everything: X^β large.
        for _ in 0..50 {
            e.update_round(&[Some((0.6, 1.0)), Some((0.6, 8.0))]);
        }
        let mut gs = GoodSpeedAlloc::log();
        let alloc = gs.allocate(&e, &caps(2, 10));
        assert!(alloc[0] > alloc[1], "starved client must get more: {alloc:?}");
    }

    #[test]
    fn allocate_into_matches_allocate_with_reused_buffer() {
        // The into-form reuses one output vector across waves; it must
        // stay bit-identical to the allocating form for every policy
        // (Random-S needs twin PRNGs so both sides see the same stream).
        let e = est(4);
        let cap = caps(4, 14);
        let mut out = vec![99usize; 32]; // stale garbage must be cleared
        let mut a = GoodSpeedAlloc::log();
        let mut b = GoodSpeedAlloc::log();
        a.allocate_into(&e, &cap, &mut out);
        assert_eq!(out, b.allocate(&e, &cap));
        let mut a = FixedSAlloc;
        a.allocate_into(&e, &cap, &mut out);
        assert_eq!(out, FixedSAlloc.allocate(&e, &cap));
        let mut a = RandomSAlloc::new(5);
        let mut b = RandomSAlloc::new(5);
        for _ in 0..10 {
            a.allocate_into(&e, &cap, &mut out);
            assert_eq!(out, b.allocate(&e, &cap));
        }
    }

    #[test]
    fn make_allocator_names() {
        assert_eq!(make_allocator(Policy::GoodSpeed, 0).name(), "goodspeed");
        assert_eq!(make_allocator(Policy::FixedS, 0).name(), "fixed-s");
        assert_eq!(make_allocator(Policy::RandomS, 0).name(), "random-s");
    }
}
