//! Closed-loop speculation control (`policy=turbo`).
//!
//! TurboSpec (Liu et al.) observes that the right speculation length is
//! not the one maximizing raw goodput but the one maximizing *goodput
//! under SLO*: once a request is certain to meet its deadline, further
//! speculation for that client only burns shared verifier budget that a
//! deadline-tight client needs. The [`TurboController`] implements that
//! loop on top of the gradient allocator: it maintains a per-client
//! speculation-budget target `S_i` and, each wave,
//!
//! * **shrinks** `S_i` (×0.8) when client *i* is comfortably ahead of its
//!   deadline (headroom > [`TurboController::SHRINK_HEADROOM`]) while the
//!   verifier is congested (reserved budget ≥
//!   [`TurboController::CONGESTED`] of C) — the freed budget water-fills
//!   over deadline-tight clients through the ordinary allocation;
//! * **grows** `S_i` (×1.25, toward fully open) whenever the client is
//!   behind its deadline, or when its accept rate is high
//!   (> [`TurboController::GROW_ACCEPT`]) and the verifier has slack.
//!
//! The target starts fully open (the verification budget C), so with no
//! request trace — every client deadline-free, headroom +∞ — the caps
//! never bind and `turbo` degrades to the plain gradient policy. The
//! controller lives inside [`RoundCore`](crate::coordinator::RoundCore)
//! and is therefore identical in the live coordinator and the analytic
//! simulator; SLO headroom is published per wave by the request tracker
//! (`serve::tracker`).

/// Per-client closed-loop speculation-budget controller.
#[derive(Clone, Debug)]
pub struct TurboController {
    /// Per-client speculation target S_i (continuous; rounded at use).
    target: Vec<f64>,
    /// Per-client SLO headroom published at the last wave boundary
    /// (+∞ = no deadline pressure; < 0 = behind schedule).
    headroom: Vec<f64>,
    /// Upper bound for every target (the fully-open cap).
    open: usize,
}

impl TurboController {
    /// Headroom above which a client counts as "comfortably ahead": its
    /// expected rate is ≥ 2× what the deadline requires, so halving its
    /// speculation still meets the SLO with margin.
    pub const SHRINK_HEADROOM: f64 = 1.0;
    /// Reserved-over-capacity fraction above which the verifier counts
    /// as congested (shedding only helps when budget is actually scarce).
    pub const CONGESTED: f64 = 0.95;
    /// Accept rate above which speculation grows while there is slack.
    pub const GROW_ACCEPT: f64 = 0.7;

    /// A controller over `n` clients with all targets fully open at
    /// `open` (the verification budget C: per-wave caps are additionally
    /// bounded by context room and the artifact K, so "open" means
    /// "never binding").
    pub fn new(n: usize, open: usize) -> TurboController {
        TurboController {
            target: vec![open.max(1) as f64; n],
            headroom: vec![f64::INFINITY; n],
            open: open.max(1),
        }
    }

    /// Publish client `i`'s SLO headroom for the upcoming wave (from the
    /// request tracker).
    pub fn set_headroom(&mut self, i: usize, headroom: f64) {
        self.headroom[i] = headroom;
    }

    /// The controller's current speculation cap for client `i`.
    pub fn cap(&self, i: usize) -> usize {
        (self.target[i].round() as usize).clamp(1, self.open)
    }

    /// One closed-loop step for client `i` after a wave it participated
    /// in: `accept` is the wave's mean acceptance ratio, `congestion` the
    /// reserved-over-capacity fraction at the wave boundary.
    pub fn observe(&mut self, i: usize, accept: f64, congestion: f64) {
        let h = self.headroom[i];
        let open = self.open as f64;
        let t = &mut self.target[i];
        if h < 0.0 {
            // Behind schedule (or backlogged): open the throttle fast —
            // a missed deadline zeroes the request's SLO-goodput, which
            // no amount of saved budget repays.
            *t = (*t * 1.25 + 0.5).min(open);
        } else if h.is_finite() && h > Self::SHRINK_HEADROOM && congestion >= Self::CONGESTED {
            // (+∞ headroom means "no deadline known", not "ahead": a
            // deadline-free client is never throttled.)
            // Comfortably ahead while the verifier is saturated: shed
            // speculation; the freed budget water-fills over the
            // deadline-tight clients in the very next allocation.
            *t *= 0.8;
        } else if accept > Self::GROW_ACCEPT && congestion < Self::CONGESTED {
            *t = (*t * 1.1 + 0.25).min(open);
        }
        *t = t.clamp(1.0, open);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_fully_open_and_stays_open_without_deadlines() {
        let mut c = TurboController::new(3, 16);
        for i in 0..3 {
            assert_eq!(c.cap(i), 16);
        }
        // Deadline-free clients (headroom +∞) never shrink, whatever the
        // congestion — turbo degrades to the plain gradient policy.
        for _ in 0..50 {
            c.observe(0, 0.9, 1.0);
            c.observe(1, 0.1, 1.0);
        }
        assert_eq!(c.cap(0), 16);
        assert_eq!(c.cap(1), 16);
    }

    #[test]
    fn sheds_when_ahead_and_congested_only() {
        let mut c = TurboController::new(2, 16);
        c.set_headroom(0, 3.0);
        c.set_headroom(1, 3.0);
        for _ in 0..10 {
            c.observe(0, 0.5, 1.0); // congested: shed
            c.observe(1, 0.5, 0.5); // slack: hold
        }
        assert!(c.cap(0) < 16, "ahead + congested must shrink: {}", c.cap(0));
        assert!(c.cap(0) >= 1, "the floor is one node");
        assert_eq!(c.cap(1), 16, "no congestion ⇒ nothing to shed");
    }

    #[test]
    fn reopens_when_behind_and_grows_on_high_accept() {
        let mut c = TurboController::new(1, 16);
        c.set_headroom(0, 5.0);
        for _ in 0..20 {
            c.observe(0, 0.5, 1.0);
        }
        let shrunk = c.cap(0);
        assert!(shrunk < 8, "{shrunk}");
        // Falling behind reopens fast.
        c.set_headroom(0, -0.5);
        for _ in 0..10 {
            c.observe(0, 0.5, 1.0);
        }
        assert_eq!(c.cap(0), 16, "behind schedule must reopen to the full cap");
        // High accept with slack grows a shrunk target too.
        let mut c = TurboController::new(1, 16);
        c.set_headroom(0, 5.0);
        for _ in 0..20 {
            c.observe(0, 0.5, 1.0);
        }
        c.set_headroom(0, 0.5); // no longer far ahead
        for _ in 0..20 {
            c.observe(0, 0.9, 0.5);
        }
        assert_eq!(c.cap(0), 16);
    }

    #[test]
    fn cap_clamps_to_sane_range() {
        let mut c = TurboController::new(1, 4);
        c.set_headroom(0, 100.0);
        for _ in 0..200 {
            c.observe(0, 0.0, 1.0);
        }
        assert_eq!(c.cap(0), 1);
        c.set_headroom(0, -1.0);
        for _ in 0..200 {
            c.observe(0, 0.0, 1.0);
        }
        assert_eq!(c.cap(0), 4);
    }
}
