//! Exponential-smoothing estimators — paper equations (3) and (4).
//!
//! The verification server maintains, per draft server i:
//! * `α̂_i(t) = (1−η)·α̂_i(t−1) + η·(1/S_i)Σ_j min(1, p_j/q_{i,j})`   (3)
//! * `X_i^β(t) = (1−β)·X_i^β(t−1) + β·x_i(t)`                        (4)
//!
//! η and β may be fixed (the experiments) or decaying `O(1/t^p)` with
//! `p ∈ (0.5, 1]` (Assumption 3, under which η/β → 0 and the fluid-limit
//! theory applies).

use crate::configsys::Smoothing;

#[derive(Clone, Debug)]
pub struct Estimators {
    /// Smoothed acceptance-rate estimates α̂(t) ∈ (0,1)^N.
    pub alpha_hat: Vec<f64>,
    /// Smoothed goodput estimates X^β(t) ∈ R₊^N.
    pub x_beta: Vec<f64>,
    eta: Smoothing,
    beta: Smoothing,
    /// Waves observed (global clock; == rounds in sync mode).
    t: u64,
    /// Per-client observation counts — the decay-schedule clock. Under
    /// async waves the global `t` advances up to N× faster than any one
    /// client participates; `Smoothing::Decay` must follow each client's
    /// own observation count (identical to `t` in sync mode, where every
    /// client participates in every wave).
    t_client: Vec<u64>,
}

/// Clamp keeping α̂ inside (0, α_max] — Assumption 2's uniform bound.
pub const ALPHA_MAX: f64 = 0.995;
pub const ALPHA_MIN: f64 = 1e-3;

impl Estimators {
    pub fn new(n: usize, eta: Smoothing, beta: Smoothing) -> Self {
        Estimators {
            alpha_hat: vec![0.5; n],
            x_beta: vec![1.0; n],
            eta,
            beta,
            t: 0,
            t_client: vec![0; n],
        }
    }

    pub fn with_init(n: usize, eta: Smoothing, beta: Smoothing, alpha0: f64, x0: f64) -> Self {
        Estimators {
            alpha_hat: vec![alpha0.clamp(ALPHA_MIN, ALPHA_MAX); n],
            x_beta: vec![x0.max(1e-6); n],
            eta,
            beta,
            t: 0,
            t_client: vec![0; n],
        }
    }

    pub fn len(&self) -> usize {
        self.alpha_hat.len()
    }

    pub fn is_empty(&self) -> bool {
        self.alpha_hat.is_empty()
    }

    pub fn round(&self) -> u64 {
        self.t
    }

    /// One verification wave's observations: the mean acceptance ratio
    /// (eq. 3's empirical term) and the realized goodput x_i(t). Clients
    /// that did not participate in this wave pass `None` — this sparse
    /// form is the common path for both the sync barrier (all `Some`) and
    /// the async pipeline (the wave's subset only).
    pub fn update_round(&mut self, obs: &[Option<(f64, f64)>]) {
        assert_eq!(obs.len(), self.len());
        self.t += 1;
        for (i, o) in obs.iter().enumerate() {
            if let Some((mean_ratio, goodput)) = *o {
                // Decay schedules follow the client's own observation
                // count (== the global round count in sync mode).
                self.t_client[i] += 1;
                let eta = self.eta.at(self.t_client[i]);
                let beta = self.beta.at(self.t_client[i]);
                let a = (1.0 - eta) * self.alpha_hat[i] + eta * mean_ratio.clamp(0.0, 1.0);
                self.alpha_hat[i] = a.clamp(ALPHA_MIN, ALPHA_MAX);
                self.x_beta[i] = ((1.0 - beta) * self.x_beta[i] + beta * goodput).max(1e-9);
            }
        }
    }

    /// Population prior over a member subset: the mean α̂ and X^β of the
    /// currently serving clients, falling back to the global prior
    /// `(0.5, 1.0)` when the set is empty. A newcomer seeded with this
    /// starts from what the cluster has already learned about its
    /// population instead of the cold-start prior.
    pub fn population_prior(&self, members: &[usize]) -> (f64, f64) {
        if members.is_empty() {
            return (0.5, 1.0);
        }
        let n = members.len() as f64;
        let a = members.iter().map(|&i| self.alpha_hat[i]).sum::<f64>() / n;
        let x = members.iter().map(|&i| self.x_beta[i]).sum::<f64>() / n;
        (a, x)
    }

    /// Initialize a joining client's estimates from the population prior
    /// of `members` (see [`Estimators::population_prior`]) with a fresh
    /// observation clock — a decay schedule starts at η(1) for the
    /// newcomer while its *level* starts at the population mean.
    pub fn seed_from_population(&mut self, i: usize, members: &[usize]) {
        let (a, x) = self.population_prior(members);
        self.alpha_hat[i] = a.clamp(ALPHA_MIN, ALPHA_MAX);
        self.x_beta[i] = x.max(1e-9);
        self.t_client[i] = 0;
    }

    /// Per-client observation count — the decay-schedule clock. A sharded
    /// pool hands this off on client migration so `Smoothing::Decay`
    /// continues from the client's real history instead of restarting at
    /// η(1)/β(1) on the new shard.
    pub fn observations(&self, i: usize) -> u64 {
        self.t_client[i]
    }

    /// Seed a migrated-in client's observation count (see
    /// [`Estimators::observations`]).
    pub fn set_observations(&mut self, i: usize, t: u64) {
        self.t_client[i] = t;
    }

    /// Estimated next-round goodput x̂_i(t+1) for a hypothetical draft
    /// length — the objective term of GOODSPEED-SCHED (eq. 5).
    pub fn predicted_goodput(&self, i: usize, s: usize) -> f64 {
        crate::spec::expected_goodput(self.alpha_hat[i], s)
    }

    pub fn current_eta(&self) -> f64 {
        self.eta.at(self.t.max(1))
    }

    pub fn current_beta(&self) -> f64 {
        self.beta.at(self.t.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::Rng;

    fn fixed(n: usize, eta: f64, beta: f64) -> Estimators {
        Estimators::new(n, Smoothing::Fixed(eta), Smoothing::Fixed(beta))
    }

    #[test]
    fn converges_to_constant_signal() {
        let mut e = fixed(1, 0.3, 0.5);
        for _ in 0..200 {
            e.update_round(&[Some((0.8, 4.0))]);
        }
        assert!((e.alpha_hat[0] - 0.8).abs() < 1e-6);
        assert!((e.x_beta[0] - 4.0).abs() < 1e-6);
    }

    #[test]
    fn ewma_formula_exact_one_step() {
        let mut e = fixed(2, 0.25, 0.5);
        e.update_round(&[Some((1.0, 3.0)), None]);
        // α̂ = 0.75*0.5 + 0.25*1.0 ; X = 0.5*1.0 + 0.5*3.0
        assert!((e.alpha_hat[0] - 0.625).abs() < 1e-12);
        assert!((e.x_beta[0] - 2.0).abs() < 1e-12);
        // non-participating client untouched
        assert!((e.alpha_hat[1] - 0.5).abs() < 1e-12);
        assert!((e.x_beta[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn decay_clock_follows_participation_not_waves() {
        // A straggler's first observation after many waves it sat out
        // must be applied with η(1), not η(#waves).
        let mut e = Estimators::new(
            2,
            Smoothing::Decay { c: 1.0, p: 0.7 },
            Smoothing::Fixed(0.5),
        );
        for _ in 0..50 {
            e.update_round(&[Some((0.9, 1.0)), None]);
        }
        e.update_round(&[None, Some((0.9, 1.0))]);
        // η(1) = 1.0 ⇒ client 1's α̂ jumps straight to the observation.
        assert!((e.alpha_hat[1] - 0.9).abs() < 1e-9, "{}", e.alpha_hat[1]);
        assert_eq!(e.round(), 51); // the global wave clock still advances
    }

    #[test]
    fn observation_clock_is_transferable() {
        // The migration hand-off: carrying t_client across keeps a decay
        // schedule at the client's real learning rate.
        let mut e = fixed(2, 0.25, 0.5);
        e.update_round(&[Some((0.9, 3.0)), None]);
        e.update_round(&[Some((0.8, 2.0)), None]);
        assert_eq!(e.observations(0), 2);
        assert_eq!(e.observations(1), 0);
        let mut other = fixed(2, 0.25, 0.5);
        other.set_observations(0, e.observations(0));
        assert_eq!(other.observations(0), 2);
    }

    #[test]
    fn population_prior_and_seeding() {
        let mut e = fixed(4, 0.5, 0.5);
        e.update_round(&[Some((0.9, 5.0)), Some((0.5, 3.0)), None, None]);
        let (a, x) = e.population_prior(&[0, 1]);
        assert!((a - (e.alpha_hat[0] + e.alpha_hat[1]) / 2.0).abs() < 1e-12);
        assert!((x - (e.x_beta[0] + e.x_beta[1]) / 2.0).abs() < 1e-12);
        // Empty population falls back to the cold-start prior.
        assert_eq!(e.population_prior(&[]), (0.5, 1.0));
        // Seeding a newcomer adopts the level with a fresh decay clock.
        e.set_observations(3, 7);
        e.seed_from_population(3, &[0, 1]);
        assert!((e.alpha_hat[3] - a).abs() < 1e-12);
        assert!((e.x_beta[3] - x).abs() < 1e-12);
        assert_eq!(e.observations(3), 0);
    }

    #[test]
    fn respects_alpha_bounds() {
        let mut e = fixed(1, 0.9, 0.5);
        for _ in 0..100 {
            e.update_round(&[Some((1.0, 10.0))]);
        }
        assert!(e.alpha_hat[0] <= ALPHA_MAX);
        for _ in 0..100 {
            e.update_round(&[Some((0.0, 0.0))]);
        }
        assert!(e.alpha_hat[0] >= ALPHA_MIN);
        assert!(e.x_beta[0] > 0.0); // strictly positive for log utility
    }

    #[test]
    fn decay_schedule_lipschitz_shrinks() {
        // Assumption 2: |α̂(t+1) − α̂(t)| ≤ L·η with L ≤ 1.
        let mut e = Estimators::new(1, Smoothing::Decay { c: 1.0, p: 0.7 }, Smoothing::Fixed(0.5));
        let mut rng = Rng::new(0);
        let mut prev = e.alpha_hat[0];
        for t in 1..500u64 {
            let eta_t = e.eta.at(t + 1);
            e.update_round(&[Some((rng.f64(), 1.0))]);
            assert!(
                (e.alpha_hat[0] - prev).abs() <= eta_t + 1e-12,
                "step exceeded η at t={t}"
            );
            prev = e.alpha_hat[0];
        }
    }

    #[test]
    fn tracks_nonstationary_signal() {
        let mut e = fixed(1, 0.3, 0.5);
        for _ in 0..100 {
            e.update_round(&[Some((0.2, 1.0))]);
        }
        assert!((e.alpha_hat[0] - 0.2).abs() < 0.01);
        for _ in 0..100 {
            e.update_round(&[Some((0.9, 1.0))]);
        }
        assert!((e.alpha_hat[0] - 0.9).abs() < 0.01, "must re-adapt after domain shift");
    }

    #[test]
    fn prop_ewma_is_convex_combination() {
        proptest::check("ewma_bounds", proptest::default_cases(), |rng| {
            let mut e = fixed(1, rng.f64() * 0.9 + 0.05, rng.f64() * 0.9 + 0.05);
            let mut lo = 0.5f64;
            let mut hi = 0.5f64;
            for _ in 0..50 {
                let obs = rng.f64();
                lo = lo.min(obs);
                hi = hi.max(obs);
                e.update_round(&[Some((obs, rng.f64() * 5.0))]);
                assert!(e.alpha_hat[0] >= lo - 1e-9 && e.alpha_hat[0] <= hi + 1e-9);
            }
        });
    }

    #[test]
    fn predicted_goodput_uses_alpha_hat() {
        let mut e = fixed(1, 1.0, 0.5);
        e.update_round(&[Some((0.5, 1.0))]);
        let p = e.predicted_goodput(0, 2);
        assert!((p - (1.0 + 0.5 + 0.25)).abs() < 1e-9);
    }
}
