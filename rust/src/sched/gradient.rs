//! GOODSPEED-SCHED (paper eq. 5): per-round integer allocation of the
//! verification budget C across draft servers.
//!
//! ```text
//! max_{S}  Σ_i  w_i · μ(α̂_i, S_i)
//! s.t.     Σ_i S_i ≤ C,  0 ≤ S_i ≤ cap_i
//! ```
//!
//! with `w_i = ∇U_i(X_i^β(t))` and `μ(α, S) = (1 − α^{S+1})/(1 − α)`.
//!
//! Because each term is concave and increasing in `S_i` with marginal gain
//! `Δ_i(s) = w_i · α̂_i^{s+1}` (strictly decreasing in s), the **greedy
//! marginal-gain algorithm is exact**: repeatedly give the next token slot
//! to the client with the largest remaining marginal gain. This is the
//! classic result for separable concave resource allocation (Fox 1966), and
//! `solve_dp` (an exact O(N·C·K) dynamic program) certifies it in the
//! property tests. Complexity: O(C log N) with a binary heap — ~1 µs per
//! round at Table I sizes, invisible next to the verification forward.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::utility::{LogUtility, Utility};
use crate::spec::math::{expected_goodput, marginal_gain};

/// One allocation problem instance.
#[derive(Clone, Debug)]
pub struct AllocInput<'a> {
    /// Gradient weights w_i = ∇U_i(X_i^β) (all ≥ 0).
    pub weights: &'a [f64],
    /// Acceptance-rate estimates α̂_i ∈ [0, 1].
    pub alphas: &'a [f64],
    /// Verification budget C (Σ S_i ≤ C).
    pub capacity: usize,
    /// Per-client upper bound (artifact K limit and context room).
    pub max_per_client: &'a [usize],
}

impl AllocInput<'_> {
    fn n(&self) -> usize {
        debug_assert_eq!(self.weights.len(), self.alphas.len());
        debug_assert_eq!(self.weights.len(), self.max_per_client.len());
        self.weights.len()
    }
}

#[derive(PartialEq)]
struct Gain {
    gain: f64,
    client: usize,
}

impl Eq for Gain {}

impl PartialOrd for Gain {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Gain {
    fn cmp(&self, other: &Self) -> Ordering {
        // Max-heap by gain; tie-break by client id for determinism.
        self.gain
            .partial_cmp(&other.gain)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.client.cmp(&self.client))
    }
}

/// Reusable heap storage for [`solve_greedy_into`]. One scratch per
/// scheduling loop keeps warm waves allocation-free: the heap's backing
/// buffer is drained (not dropped) every solve and regrows only past its
/// high-water mark.
#[derive(Default)]
pub struct GreedyScratch {
    heap: BinaryHeap<Gain>,
}

/// Exact greedy solver (the production path).
///
/// Slots with zero marginal gain are *not* allocated: drafting a token that
/// will surely be rejected only wastes draft-server compute and uplink
/// bandwidth — the budget constraint is `≤ C`, not `= C`.
pub fn solve_greedy(input: &AllocInput) -> Vec<usize> {
    let mut scratch = GreedyScratch::default();
    let mut alloc = Vec::new();
    solve_greedy_into(input, &mut scratch, &mut alloc);
    alloc
}

/// Allocation-free form of [`solve_greedy`]: identical pop order (and so
/// bit-identical output), but the heap and the output vector are caller-
/// owned and recycled across waves. `alloc` is cleared and resized; its
/// capacity is retained.
pub fn solve_greedy_into(input: &AllocInput, scratch: &mut GreedyScratch, alloc: &mut Vec<usize>) {
    let n = input.n();
    alloc.clear();
    alloc.resize(n, 0);
    if n == 0 || input.capacity == 0 {
        return;
    }
    let heap = &mut scratch.heap;
    heap.clear();
    for i in 0..n {
        if input.max_per_client[i] > 0 {
            let g = input.weights[i] * marginal_gain(input.alphas[i], 0);
            if g > 0.0 {
                heap.push(Gain { gain: g, client: i });
            }
        }
    }
    let mut remaining = input.capacity;
    while remaining > 0 {
        let Some(Gain { client, .. }) = heap.pop() else { break };
        alloc[client] += 1;
        remaining -= 1;
        if alloc[client] < input.max_per_client[client] {
            let g = input.weights[client] * marginal_gain(input.alphas[client], alloc[client]);
            if g > 0.0 {
                heap.push(Gain { gain: g, client });
            }
        }
    }
}

/// Exact dynamic program — O(N · C · K). Test/ablation oracle for the
/// greedy solver; also exercised by `benches/ablations.rs` to report the
/// greedy speedup factor.
pub fn solve_dp(input: &AllocInput) -> Vec<usize> {
    let n = input.n();
    let c = input.capacity;
    // best[i][b] = max objective using clients 0..i with budget b
    let mut best = vec![vec![0.0f64; c + 1]; n + 1];
    let mut choice = vec![vec![0usize; c + 1]; n + 1];
    for i in 0..n {
        let cap_i = input.max_per_client[i].min(c);
        for b in 0..=c {
            let mut best_val = f64::NEG_INFINITY;
            let mut best_s = 0;
            for s in 0..=cap_i.min(b) {
                let val = best[i][b - s]
                    + input.weights[i] * (expected_goodput(input.alphas[i], s) - 1.0);
                if val > best_val + 1e-15 {
                    best_val = val;
                    best_s = s;
                }
            }
            best[i + 1][b] = best_val;
            choice[i + 1][b] = best_s;
        }
    }
    // Backtrack.
    let mut alloc = vec![0usize; n];
    let mut b = c;
    for i in (0..n).rev() {
        alloc[i] = choice[i + 1][b];
        b -= alloc[i];
    }
    alloc
}

/// Hierarchical water-filling for the sharded verifier pool: split a
/// total budget across M shards. Each shard first receives a *floor*
/// (normally its member count, so no shard's clients are starved outright),
/// then the remainder is distributed by the same exact greedy marginal-gain
/// rule as the per-client allocation — shard weight `w_s = Σ_{i∈s} ∇U_i`
/// and a representative acceptance rate `α_s` stand in for the client
/// terms. Invariants: `Σ out ≤ total` and `out[s] ≤ caps[s]`.
///
/// Degenerate inputs are first-class: an empty shard passes `floor = 0`,
/// `weight = 0`, `cap = 0` and receives nothing.
pub fn hierarchical_split(
    total: usize,
    floors: &[usize],
    weights: &[f64],
    alphas: &[f64],
    caps: &[usize],
) -> Vec<usize> {
    let m = floors.len();
    debug_assert_eq!(m, weights.len());
    debug_assert_eq!(m, alphas.len());
    debug_assert_eq!(m, caps.len());
    let mut out = vec![0usize; m];
    let mut left = total;
    for i in 0..m {
        let f = floors[i].min(caps[i]).min(left);
        out[i] = f;
        left -= f;
    }
    if left > 0 {
        let rem_caps: Vec<usize> = caps.iter().zip(&out).map(|(&c, &o)| c - o).collect();
        let extra = solve_greedy(&AllocInput {
            weights,
            alphas,
            capacity: left,
            max_per_client: &rem_caps,
        });
        for i in 0..m {
            out[i] += extra[i];
        }
    }
    out
}

/// The pool controller's budget rule, shared verbatim by the live
/// verifier pool (`coordinator/pool.rs`) and the sharded analytic
/// simulator so the two can never apply different split policies: per
/// shard, floor = member count, weight = Σ member ∇U(X_i^β) (log
/// utility), representative α = member mean (prior 0.5 when empty), cap =
/// member count × `max_draft`; then [`hierarchical_split`].
pub fn split_budget_by_members(
    total: usize,
    max_draft: usize,
    members_per_shard: &[Vec<usize>],
    alpha_hat: &[f64],
    x_beta: &[f64],
) -> Vec<usize> {
    let u = LogUtility;
    let m = members_per_shard.len();
    let mut floors = Vec::with_capacity(m);
    let mut weights = Vec::with_capacity(m);
    let mut alphas = Vec::with_capacity(m);
    let mut caps = Vec::with_capacity(m);
    for members in members_per_shard {
        floors.push(members.len());
        weights.push(members.iter().map(|&i| u.grad(x_beta[i])).sum());
        alphas.push(if members.is_empty() {
            0.5
        } else {
            members.iter().map(|&i| alpha_hat[i]).sum::<f64>() / members.len() as f64
        });
        caps.push(members.len() * max_draft);
    }
    hierarchical_split(total, &floors, &weights, &alphas, &caps)
}

/// Objective value Σ w_i μ(α_i, S_i) of an allocation.
pub fn objective(input: &AllocInput, alloc: &[usize]) -> f64 {
    alloc
        .iter()
        .enumerate()
        .map(|(i, &s)| input.weights[i] * expected_goodput(input.alphas[i], s))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::Rng;

    fn random_instance(rng: &mut Rng, max_n: usize, max_c: usize) -> (Vec<f64>, Vec<f64>, usize, Vec<usize>) {
        let n = rng.below(max_n as u64) as usize + 1;
        let weights: Vec<f64> = (0..n).map(|_| rng.f64() * 2.0 + 1e-3).collect();
        let alphas: Vec<f64> = (0..n).map(|_| rng.f64() * 0.98).collect();
        let capacity = rng.below(max_c as u64 + 1) as usize;
        let caps: Vec<usize> = (0..n).map(|_| rng.below(33) as usize).collect();
        (weights, alphas, capacity, caps)
    }

    #[test]
    fn respects_capacity_and_caps() {
        proptest::check("alloc_feasible", proptest::default_cases(), |rng| {
            let (w, a, c, caps) = random_instance(rng, 12, 64);
            let input = AllocInput { weights: &w, alphas: &a, capacity: c, max_per_client: &caps };
            let alloc = solve_greedy(&input);
            assert!(alloc.iter().sum::<usize>() <= c);
            for (s, cap) in alloc.iter().zip(&caps) {
                assert!(s <= cap);
            }
        });
    }

    #[test]
    fn greedy_equals_dp_objective() {
        proptest::check("greedy_optimal", proptest::default_cases(), |rng| {
            let (w, a, c, caps) = random_instance(rng, 8, 40);
            let input = AllocInput { weights: &w, alphas: &a, capacity: c, max_per_client: &caps };
            let g = solve_greedy(&input);
            let d = solve_dp(&input);
            let og = objective(&input, &g);
            let od = objective(&input, &d);
            assert!(
                (og - od).abs() < 1e-7 * (1.0 + od.abs()),
                "greedy {og} vs dp {od}\nw={w:?}\na={a:?}\nc={c} caps={caps:?}\ng={g:?} d={d:?}"
            );
        });
    }

    #[test]
    fn symmetric_clients_get_balanced_split() {
        let w = vec![1.0; 4];
        let a = vec![0.8; 4];
        let caps = vec![32; 4];
        let input = AllocInput { weights: &w, alphas: &a, capacity: 20, max_per_client: &caps };
        let alloc = solve_greedy(&input);
        assert_eq!(alloc.iter().sum::<usize>(), 20);
        for &s in &alloc {
            assert!((s as i64 - 5).unsigned_abs() <= 1, "{alloc:?}");
        }
    }

    #[test]
    fn higher_weight_gets_more() {
        // Client 0 starved (low X^β ⇒ large weight) must receive ≥ tokens.
        let w = vec![10.0, 1.0];
        let a = vec![0.7, 0.7];
        let caps = vec![32, 32];
        let input = AllocInput { weights: &w, alphas: &a, capacity: 10, max_per_client: &caps };
        let alloc = solve_greedy(&input);
        assert!(alloc[0] > alloc[1], "{alloc:?}");
    }

    #[test]
    fn higher_alpha_gets_more_at_equal_weight() {
        let w = vec![1.0, 1.0];
        let a = vec![0.9, 0.3];
        let caps = vec![32, 32];
        let input = AllocInput { weights: &w, alphas: &a, capacity: 12, max_per_client: &caps };
        let alloc = solve_greedy(&input);
        assert!(alloc[0] > alloc[1], "{alloc:?}");
    }

    #[test]
    fn zero_alpha_client_gets_nothing() {
        let w = vec![1.0, 1.0];
        let a = vec![0.0, 0.5];
        let caps = vec![32, 32];
        let input = AllocInput { weights: &w, alphas: &a, capacity: 6, max_per_client: &caps };
        let alloc = solve_greedy(&input);
        assert_eq!(alloc[0], 0, "drafting for α=0 wastes budget: {alloc:?}");
    }

    #[test]
    fn capacity_smaller_than_clients() {
        // C < N: only the most valuable clients get a slot (C=2, N=4).
        let w = vec![1.0, 2.0, 3.0, 4.0];
        let a = vec![0.5; 4];
        let caps = vec![32; 4];
        let input = AllocInput { weights: &w, alphas: &a, capacity: 2, max_per_client: &caps };
        let alloc = solve_greedy(&input);
        assert_eq!(alloc, vec![0, 0, 1, 1]);
    }

    #[test]
    fn empty_and_degenerate_inputs() {
        let input = AllocInput { weights: &[], alphas: &[], capacity: 10, max_per_client: &[] };
        assert!(solve_greedy(&input).is_empty());
        let w = vec![1.0];
        let a = vec![0.5];
        let caps = vec![0];
        let input = AllocInput { weights: &w, alphas: &a, capacity: 10, max_per_client: &caps };
        assert_eq!(solve_greedy(&input), vec![0]);
        let caps = vec![5];
        let input = AllocInput { weights: &w, alphas: &a, capacity: 0, max_per_client: &caps };
        assert_eq!(solve_greedy(&input), vec![0]);
    }

    #[test]
    fn deterministic_tie_break() {
        let w = vec![1.0; 3];
        let a = vec![0.5; 3];
        let caps = vec![32; 3];
        let input = AllocInput { weights: &w, alphas: &a, capacity: 4, max_per_client: &caps };
        let a1 = solve_greedy(&input);
        let a2 = solve_greedy(&input);
        assert_eq!(a1, a2);
    }

    #[test]
    fn prop_greedy_matches_dp_under_degenerate_caps_and_weights() {
        // Sharding produces degenerate wave membership: absent clients are
        // capped at 0 and fully-served clients carry weight 0. The greedy
        // allocator must stay exact (== the DP oracle) and must never hand
        // tokens to a zero-cap client.
        proptest::check("greedy_degenerate", proptest::default_cases(), |rng| {
            let (mut w, a, c, mut caps) = random_instance(rng, 8, 40);
            for i in 0..w.len() {
                if rng.bool(0.35) {
                    caps[i] = 0;
                }
                if rng.bool(0.35) {
                    w[i] = 0.0;
                }
            }
            let input = AllocInput { weights: &w, alphas: &a, capacity: c, max_per_client: &caps };
            let g = solve_greedy(&input);
            let d = solve_dp(&input);
            let og = objective(&input, &g);
            let od = objective(&input, &d);
            assert!(
                (og - od).abs() < 1e-7 * (1.0 + od.abs()),
                "greedy {og} vs dp {od}\nw={w:?}\na={a:?}\nc={c} caps={caps:?}\ng={g:?} d={d:?}"
            );
            for i in 0..caps.len() {
                assert!(g[i] <= caps[i], "cap violated: {g:?} vs {caps:?}");
                if w[i] == 0.0 {
                    assert_eq!(g[i], 0, "zero-weight client got budget: {g:?}");
                }
            }
            assert!(g.iter().sum::<usize>() <= c);
        });
    }

    #[test]
    fn prop_greedy_into_matches_dp_with_reused_scratch() {
        // The allocation-free form must be exact too — same degenerate-cap
        // harness as above, with ONE scratch + output vector reused across
        // every case so stale heap/alloc state from a previous instance
        // would be caught immediately.
        let mut scratch = GreedyScratch::default();
        let mut g = Vec::new();
        proptest::check("greedy_into_degenerate", proptest::default_cases(), |rng| {
            let (mut w, a, c, mut caps) = random_instance(rng, 8, 40);
            for i in 0..w.len() {
                if rng.bool(0.35) {
                    caps[i] = 0;
                }
                if rng.bool(0.35) {
                    w[i] = 0.0;
                }
            }
            let input = AllocInput { weights: &w, alphas: &a, capacity: c, max_per_client: &caps };
            solve_greedy_into(&input, &mut scratch, &mut g);
            // Bit-identical to the allocating form (same pop order)…
            assert_eq!(g, solve_greedy(&input));
            // …and exact against the DP oracle.
            let d = solve_dp(&input);
            let og = objective(&input, &g);
            let od = objective(&input, &d);
            assert!(
                (og - od).abs() < 1e-7 * (1.0 + od.abs()),
                "greedy_into {og} vs dp {od}\nw={w:?}\na={a:?}\nc={c} caps={caps:?}\ng={g:?} d={d:?}"
            );
        });
    }

    #[test]
    fn hierarchical_split_floors_then_waterfills() {
        // Two shards of 2 members each, one far more pressured.
        let out = hierarchical_split(16, &[2, 2], &[8.0, 1.0], &[0.7, 0.7], &[32, 32]);
        assert_eq!(out.len(), 2);
        assert!(out.iter().sum::<usize>() <= 16);
        assert!(out[0] >= 2 && out[1] >= 2, "floors first: {out:?}");
        assert!(out[0] > out[1], "pressure must attract budget: {out:?}");
    }

    #[test]
    fn hierarchical_split_degenerate_shards() {
        // Empty shard (floor/weight/cap all 0) gets nothing; tight totals
        // never overflow.
        let out = hierarchical_split(3, &[2, 0, 2], &[1.0, 0.0, 1.0], &[0.5, 0.5, 0.5], &[8, 0, 8]);
        assert_eq!(out[1], 0);
        assert_eq!(out.iter().sum::<usize>(), 3);
        // Total smaller than the floors: grant in shard order, never more
        // than the total.
        let out = hierarchical_split(1, &[2, 2], &[1.0, 1.0], &[0.5, 0.5], &[8, 8]);
        assert_eq!(out.iter().sum::<usize>(), 1);
        // Zero total.
        let out = hierarchical_split(0, &[2, 2], &[1.0, 1.0], &[0.5, 0.5], &[8, 8]);
        assert_eq!(out, vec![0, 0]);
    }

    #[test]
    fn prop_allocation_monotone_in_capacity() {
        // More budget never reduces the objective.
        proptest::check("alloc_monotone_capacity", proptest::default_cases(), |rng| {
            let (w, a, c, caps) = random_instance(rng, 8, 40);
            let i1 = AllocInput { weights: &w, alphas: &a, capacity: c, max_per_client: &caps };
            let i2 = AllocInput { weights: &w, alphas: &a, capacity: c + 4, max_per_client: &caps };
            let o1 = objective(&i1, &solve_greedy(&i1));
            let o2 = objective(&i2, &solve_greedy(&i2));
            assert!(o2 >= o1 - 1e-12);
        });
    }
}
