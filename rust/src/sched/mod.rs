//! GoodSpeed scheduling: utilities, smoothed estimators (eqs. 3–4), the
//! gradient scheduler (GOODSPEED-SCHED, eq. 5), the §IV baselines, and
//! the SLO-aware closed-loop speculation controller (`policy=turbo`).

pub mod baselines;
pub mod controller;
pub mod estimator;
pub mod gradient;
pub mod utility;

pub use baselines::{Allocator, FixedSAlloc, GoodSpeedAlloc, RandomSAlloc};
pub use controller::TurboController;
pub use estimator::Estimators;
pub use gradient::{
    hierarchical_split, objective, solve_dp, solve_greedy, split_budget_by_members, AllocInput,
};
pub use utility::{AlphaFair, LinearUtility, LogUtility, Utility};
