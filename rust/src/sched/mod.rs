//! GoodSpeed scheduling: utilities, smoothed estimators (eqs. 3–4), the
//! gradient scheduler (GOODSPEED-SCHED, eq. 5), and the §IV baselines.

pub mod baselines;
pub mod estimator;
pub mod gradient;
pub mod utility;

pub use baselines::{Allocator, FixedSAlloc, GoodSpeedAlloc, RandomSAlloc};
pub use estimator::Estimators;
pub use gradient::{
    hierarchical_split, objective, solve_dp, solve_greedy, split_budget_by_members, AllocInput,
};
pub use utility::{AlphaFair, LinearUtility, LogUtility, Utility};
