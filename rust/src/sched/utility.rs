//! Utility functions U_i for the fairness objective (paper eq. 1).
//!
//! The paper uses U_i(x) = log x (proportional fairness, Kelly). We also
//! implement the α-fair family and a linear utility as ablations — the
//! linear case degenerates the scheduler to pure throughput maximization
//! (allocate everything to the highest-α client), which the fairness bench
//! uses as a contrast.

/// Continuously differentiable, strictly increasing, strictly concave
/// utility (linear being the boundary case used only for ablation).
pub trait Utility: Send + Sync {
    fn value(&self, x: f64) -> f64;
    /// ∇U(x); implementations must stay finite near x = 0 (clamped) so the
    /// scheduler's weights never overflow — this mirrors the boundary-drift
    /// argument in Lemma 2 (gradient → ∞ pushes allocation toward starved
    /// clients).
    fn grad(&self, x: f64) -> f64;
    fn name(&self) -> &'static str;
}

const X_MIN: f64 = 1e-6;

/// U(x) = log x — proportional fairness (the paper's choice).
#[derive(Clone, Copy, Debug, Default)]
pub struct LogUtility;

impl Utility for LogUtility {
    fn value(&self, x: f64) -> f64 {
        x.max(X_MIN).ln()
    }

    fn grad(&self, x: f64) -> f64 {
        1.0 / x.max(X_MIN)
    }

    fn name(&self) -> &'static str {
        "log"
    }
}

/// α-fair utility: U(x) = x^{1−a}/(1−a) (a ≠ 1), → log as a → 1.
#[derive(Clone, Copy, Debug)]
pub struct AlphaFair {
    pub a: f64,
}

impl Utility for AlphaFair {
    fn value(&self, x: f64) -> f64 {
        let x = x.max(X_MIN);
        if (self.a - 1.0).abs() < 1e-9 {
            x.ln()
        } else {
            x.powf(1.0 - self.a) / (1.0 - self.a)
        }
    }

    fn grad(&self, x: f64) -> f64 {
        x.max(X_MIN).powf(-self.a)
    }

    fn name(&self) -> &'static str {
        "alpha-fair"
    }
}

/// U(x) = x — pure throughput (no fairness), ablation only.
#[derive(Clone, Copy, Debug, Default)]
pub struct LinearUtility;

impl Utility for LinearUtility {
    fn value(&self, x: f64) -> f64 {
        x
    }

    fn grad(&self, _x: f64) -> f64 {
        1.0
    }

    fn name(&self) -> &'static str {
        "linear"
    }
}

/// System utility U(x) = Σ U_i(x_i) (Fig 4's y-axis).
pub fn system_utility(u: &dyn Utility, xs: &[f64]) -> f64 {
    xs.iter().map(|&x| u.value(x)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    #[test]
    fn log_gradient_is_reciprocal() {
        let u = LogUtility;
        assert!((u.grad(2.0) - 0.5).abs() < 1e-12);
        assert!(u.grad(0.0).is_finite()); // clamped near zero
        assert!(u.grad(1e-12) > 1e5); // …but still huge (boundary drift)
    }

    #[test]
    fn alpha_fair_approaches_log() {
        let af = AlphaFair { a: 1.0 };
        let lg = LogUtility;
        for &x in &[0.5, 1.0, 3.0] {
            assert!((af.value(x) - lg.value(x)).abs() < 1e-9);
            assert!((af.grad(x) - lg.grad(x)).abs() < 1e-9);
        }
    }

    #[test]
    fn prop_concavity_and_monotonicity() {
        proptest::check("utility_concave", proptest::default_cases(), |rng| {
            let us: [&dyn Utility; 3] =
                [&LogUtility, &AlphaFair { a: 0.5 }, &AlphaFair { a: 2.0 }];
            let x = rng.f64() * 10.0 + 0.01;
            let h = 0.01;
            for u in us {
                // increasing
                assert!(u.value(x + h) > u.value(x), "{}", u.name());
                // gradient decreasing (concavity)
                assert!(u.grad(x) >= u.grad(x + h), "{}", u.name());
                // grad matches finite difference
                let fd = (u.value(x + h) - u.value(x - h)) / (2.0 * h);
                assert!((u.grad(x) - fd).abs() < 0.05 * u.grad(x).abs() + 1e-4);
            }
        });
    }

    #[test]
    fn system_utility_sums() {
        let u = LogUtility;
        let xs = [1.0, std::f64::consts::E];
        assert!((system_utility(&u, &xs) - 1.0).abs() < 1e-9);
    }
}
