//! Request-level serving: trace-driven arrivals, SLO accounting, and the
//! raw-goodput → SLO-goodput bridge.
//!
//! The paper measures *goodput* — accepted tokens per draft server — but
//! real-time multi-user serving is judged per *request*: a request
//! arrives, queues, decodes, and either meets its deadline or does not.
//! This subsystem layers that lifecycle onto the cluster without touching
//! the wave machinery:
//!
//! * [`trace`] — [`RequestTrace`]: open-loop Poisson/bursty arrival
//!   generators (deterministic from the scenario seed) and a JSON
//!   trace-file loader, configured by
//!   [`Scenario::trace`](crate::configsys::Scenario) /
//!   [`TraceConfig`](crate::configsys::TraceConfig);
//! * [`tracker`] — [`RequestTracker`]: per-client request queues driven
//!   at wave boundaries by both the live cluster
//!   ([`Cluster`](crate::coordinator::Cluster)) and the analytic
//!   simulator ([`AnalyticSim`](crate::simulate::AnalyticSim)). Idle
//!   clients are granted 0 (their budget water-fills over busy ones, the
//!   drain grant rule without the retirement); every request yields
//!   TTFT / TPOT / E2E and SLO attainment, reduced to p50/p95/p99 by
//!   [`SloSummary`].
//!
//! Traces pair with any shard count (`--verifiers <m>` — the historic
//! M = 1 restriction is gone): each shard builds the full trace and
//! restricts its tracker to its own members
//! ([`RequestTracker::retain_members`]), so every request is owned by
//! exactly one shard; migrations hand the in-flight request state across
//! shards ([`RequestTracker::export_client`] /
//! [`RequestTracker::import_client`], re-based onto the destination
//! shard's wave clock) and the recorder merge
//! ([`Recorder::absorb`](crate::metrics::Recorder::absorb)) folds the
//! per-shard books into one run-level report. For soak-length runs,
//! [`RequestTracker::stream`] swaps record retention for a bounded
//! [`RequestSketch`](crate::metrics::RequestSketch) so memory stays
//! O(clients).
//!
//! **SLO-goodput** — accepted tokens belonging to requests that met their
//! deadline — is the series the closed-loop speculation controller
//! ([`sched::controller`](crate::sched::controller), `policy=turbo`)
//! optimizes: it shrinks a client's speculation when the client is ahead
//! of its deadline while the verifier is congested, and grows it while
//! accept rates are high. See DESIGN.md, "Request-level serving & SLOs".

pub mod trace;
pub mod tracker;

pub use trace::{RequestTrace, TraceRequest};
pub use tracker::{
    summarize_requests, ActiveExport, ClientRequestState, QueuedExport, RequestRecord,
    RequestTracker, SloSummary,
};
